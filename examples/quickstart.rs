//! Quickstart: train a GCN on the tiny synthetic dataset with ScaleGNN's
//! communication-free uniform vertex sampling, through the full three-layer
//! stack (Rust coordinator -> PJRT -> AOT-compiled JAX/Pallas artifacts).
//!
//! Run: `make artifacts && cargo run --release --example quickstart`

use scalegnn::sampling::SamplerKind;
use scalegnn::trainer::{train, TrainConfig};

fn main() -> anyhow::Result<()> {
    let mut cfg = TrainConfig::quick("tiny", SamplerKind::ScaleGnnUniform);
    cfg.max_steps = 200;
    cfg.lr = 5e-3;
    cfg.verbose = true;

    println!("== ScaleGNN quickstart: tiny planted-partition graph ==");
    let report = train(&cfg)?;

    println!("\nloss curve (every epoch):");
    for (step, loss) in &report.loss_curve {
        println!("  step {step:>4}  loss {loss:.4}");
    }
    println!("\naccuracy curve:");
    for (step, val, test) in &report.acc_curve {
        println!("  step {step:>4}  val {val:.4}  test {test:.4}");
    }
    println!(
        "\ntrained {} steps in {:.2}s (train only; eval {:.2}s) -> best test acc {:.3}",
        report.steps, report.train_time_s, report.eval_time_s, report.best_test_acc
    );
    anyhow::ensure!(report.best_test_acc > 0.5, "quickstart failed to learn");
    println!("OK");
    Ok(())
}
