//! Quickstart: train a GCN on the tiny synthetic dataset with ScaleGNN's
//! communication-free uniform vertex sampling through the unified session
//! API.  The pure-Rust rank-thread engine is the default path — no
//! build-time artifacts are needed; the AOT-compiled JAX/Pallas PJRT
//! artifacts are an optional acceleration used by the `reference` backend
//! (see `examples/train_e2e.rs`, `make artifacts`).
//!
//! Run: `cargo run --release --example quickstart`
//! The same run as a shareable spec: `scalegnn run --spec examples/specs/tiny.json`

use scalegnn::session::{self, BackendKind, LogObserver, RunSpec, StepObserver};

fn main() -> anyhow::Result<()> {
    // RunSpec::new already picks the dataset's default model dims
    // (ModelSpec::for_dataset: 16x2 for tiny, dropout 0)
    let spec = RunSpec::new(BackendKind::Pmm, "tiny")
        .grid(1, 2, 2, 2)
        .steps(200)
        .lr(5e-3)
        .final_eval(true);

    println!("== ScaleGNN quickstart: tiny planted-partition graph ==");
    println!(
        "pmm backend, grid {} ({} rank threads), {} steps\n",
        spec.grid.to_string(),
        spec.grid.world_size(),
        spec.steps
    );
    let mut obs: Vec<Box<dyn StepObserver>> = vec![Box::new(LogObserver::every(50))];
    let report = session::run(&spec, &mut obs)?;

    println!("\nloss curve (every 25 steps):");
    for (step, loss) in report.loss_curve.iter().step_by(25) {
        println!("  step {step:>4}  loss {loss:.4}");
    }
    let pmm = report.pmm.as_ref().expect("pmm backend returns a pmm report");
    let (val, test) = pmm.eval.expect("final_eval was requested");
    println!(
        "\ntrained {} steps in {:.2}s -> full-graph val {:.3} test {:.3}",
        report.steps, report.wall_s, val, test
    );
    anyhow::ensure!(test > 0.5, "quickstart failed to learn");
    println!("OK");
    Ok(())
}
