//! Table I reproduction (example-sized): train the same GCN with the three
//! sampling algorithms — ScaleGNN uniform vertex sampling, GraphSAINT node
//! sampling, GraphSAGE neighbor sampling — through the session API's
//! `reference` backend and report the best test accuracy of each.
//! `cargo bench --bench table1_accuracy` runs the full-length version on
//! both accuracy datasets.
//!
//! Run: `cargo run --release --example accuracy_comparison [epochs]`

use scalegnn::sampling::SamplerKind;
use scalegnn::session::{self, BackendKind, RunSpec};

fn main() -> anyhow::Result<()> {
    let epochs: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(6);
    let dataset = "products_sim";
    println!("== Table I (example): test accuracy by sampling algorithm ==");
    println!("dataset {dataset}, {epochs} epochs each\n");

    let mut rows = vec![];
    for kind in [
        SamplerKind::GraphSaintNode,
        SamplerKind::GraphSage,
        SamplerKind::ScaleGnnUniform,
    ] {
        let spec = RunSpec::new(BackendKind::Reference, dataset)
            .sampler(kind)
            .epochs(epochs)
            .lr(1e-2);
        let t0 = std::time::Instant::now();
        let run = session::run_silent(&spec)?;
        let r = run.trainer.as_ref().expect("reference backend returns a trainer report");
        println!(
            "  {:<18} best test acc {:.4} (val {:.4}) in {:.1}s",
            kind.name(),
            r.best_test_acc,
            r.best_val_acc,
            t0.elapsed().as_secs_f64()
        );
        rows.push((kind, r.best_test_acc));
    }

    println!("\npaper Table I (ogbn-products): GraphSAINT 80.2, GraphSAGE 79.6, ScaleGNN 81.3");
    let ours = rows.iter().find(|r| r.0 == SamplerKind::ScaleGnnUniform).unwrap().1;
    let sage = rows.iter().find(|r| r.0 == SamplerKind::GraphSage).unwrap().1;
    anyhow::ensure!(
        ours >= sage - 0.02,
        "uniform sampling should match/beat GraphSAGE: {ours} vs {sage}"
    );
    println!("OK: ScaleGNN sampling matches or exceeds GraphSAGE (shape of Table I)");
    Ok(())
}
