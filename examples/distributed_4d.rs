//! 4D-parallel training demo on the rank-thread 3D-PMM engine: 16 "GPUs"
//! as 2 data-parallel groups x a 2x2x2 PMM grid, with communication-free
//! per-rank sampling (Algorithm 2), real sharded matrices, real collectives
//! and a distributed full-graph evaluation at the end — all through the
//! session API's `pmm` backend.
//!
//! Run: `cargo run --release --example distributed_4d`

use scalegnn::comm::Precision;
use scalegnn::session::{self, BackendKind, LogObserver, RunSpec, StepObserver};

fn main() -> anyhow::Result<()> {
    let spec = RunSpec::new(BackendKind::Pmm, "tiny")
        .grid(2, 2, 2, 2) // Gd x Gx x Gy x Gz = 16 ranks
        .model(16, 2, 0.3)
        .batch(64)
        .steps(30)
        .lr(5e-3)
        .seed(7)
        .precision(Precision::Bf16)
        .final_eval(true);

    println!("== 4D hybrid parallel training: {} rank threads ==", spec.grid.world_size());
    println!(
        "grid Gd={} x Gx={} x Gy={} x Gz={}, bf16 TP collectives\n",
        spec.grid.gd, spec.grid.gx, spec.grid.gy, spec.grid.gz
    );

    let mut obs: Vec<Box<dyn StepObserver>> = vec![Box::new(LogObserver::every(10))];
    let report = session::run(&spec, &mut obs)?;
    let pmm = report.pmm.as_ref().expect("pmm backend returns a pmm report");

    let first = report.loss_curve.first().map(|x| x.1).unwrap_or(f32::NAN);
    let (val, test) = pmm.eval.expect("final_eval was requested");
    println!(
        "\nloss {first:.3} -> {:.3} over {} steps, full-graph val {val:.3} test {test:.3}",
        report.final_loss, report.steps
    );

    let t = &pmm.timers_mean;
    println!("\nmean per-rank phase times over {} steps:", report.steps);
    println!("  sampling    {:>8.2} ms (Algorithm 2, zero communication)", t.sampling * 1e3);
    println!("  spmm        {:>8.2} ms", t.spmm * 1e3);
    println!("  gemm        {:>8.2} ms", t.gemm * 1e3);
    println!("  elementwise {:>8.2} ms", t.elementwise * 1e3);
    println!("  tp_comm     {:>8.2} ms (X/Y/Z all-reduces, bf16)", t.tp_comm * 1e3);
    println!("  dp_comm     {:>8.2} ms (gradient sync across groups)", t.dp_comm * 1e3);
    println!("  reshard     {:>8.2} ms (residual re-layout)", t.reshard * 1e3);

    println!("\ncomm volume per axis (ops, bytes, hidden fraction):");
    for ax in &pmm.axes {
        println!(
            "  {:<3} ops {:<6} bytes {:<12} hidden {:.2}",
            ax.axis, ax.ops, ax.bytes, ax.hidden_frac
        );
    }
    println!("tp aggregate hidden fraction: {:.3}", pmm.tp_hidden_frac);

    anyhow::ensure!(report.final_loss < first, "loss did not decrease");
    println!("OK");
    Ok(())
}
