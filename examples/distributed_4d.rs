//! 4D-parallel training demo on the rank-thread 3D-PMM engine: 16 "GPUs"
//! as 2 data-parallel groups x a 2x2x2 PMM grid, with communication-free
//! per-rank sampling (Algorithm 2), real sharded matrices, real collectives
//! and a distributed full-graph evaluation at the end.
//!
//! Run: `cargo run --release --example distributed_4d`

use std::sync::Arc;

use scalegnn::comm::{CommWorld, Precision};
use scalegnn::graph::datasets;
use scalegnn::grid::Grid4D;
use scalegnn::model::GcnDims;
use scalegnn::pmm::{PmmCtx, PmmGcn, PmmTimers};

fn main() -> anyhow::Result<()> {
    let grid = Grid4D::new(2, 2, 2, 2); // Gd x Gx x Gy x Gz = 16 ranks
    let steps = 30u64;
    let data = Arc::new(datasets::load("tiny").unwrap());
    let dims = GcnDims {
        d_in: 16,
        d_h: 16,
        d_out: 4,
        layers: 2,
        dropout: 0.3,
        weight_decay: 0.0,
    };

    println!("== 4D hybrid parallel training: {} rank threads ==", grid.world_size());
    println!("grid Gd={} x Gx={} x Gy={} x Gz={}, bf16 TP collectives\n", grid.gd, grid.gx, grid.gy, grid.gz);

    let world = Arc::new(CommWorld::new(grid));
    let mut handles = vec![];
    for r in 0..grid.world_size() {
        let w = world.clone();
        let d = data.clone();
        handles.push(std::thread::spawn(move || {
            let ctx = PmmCtx::new(grid, r, &w, Precision::Bf16);
            let mut eng = PmmGcn::new(ctx, dims, 64, d, 7);
            let mut losses = vec![];
            for s in 0..steps {
                losses.push(eng.train_step(s, 5e-3).loss);
            }
            let accs = eng.eval_full_graph();
            (r, losses, accs, eng.timers)
        }));
    }

    let mut total = PmmTimers::default();
    for h in handles {
        let (r, losses, (val, test), timers) = h.join().unwrap();
        total.add(&timers);
        if r == 0 || r == grid.group_size() {
            println!(
                "rank {r:>2} (group {}): loss {:.3} -> {:.3}, full-graph val {val:.3} test {test:.3}",
                grid.coord(r).d,
                losses[0],
                losses[losses.len() - 1]
            );
        }
    }
    let n = grid.world_size() as f64;
    println!("\nmean per-rank phase times over {steps} steps:");
    println!("  sampling    {:>8.2} ms (Algorithm 2, zero communication)", total.sampling / n * 1e3);
    println!("  spmm        {:>8.2} ms", total.spmm / n * 1e3);
    println!("  gemm        {:>8.2} ms", total.gemm / n * 1e3);
    println!("  elementwise {:>8.2} ms", total.elementwise / n * 1e3);
    println!("  tp_comm     {:>8.2} ms (X/Y/Z all-reduces, bf16)", total.tp_comm / n * 1e3);
    println!("  dp_comm     {:>8.2} ms (gradient sync across groups)", total.dp_comm / n * 1e3);
    println!("  reshard     {:>8.2} ms (residual re-layout)", total.reshard / n * 1e3);
    println!("\ncomm volume: X {:?} Y {:?} Z {:?} DP {:?} (ops, bytes)",
        world.stats(scalegnn::grid::Axis::X),
        world.stats(scalegnn::grid::Axis::Y),
        world.stats(scalegnn::grid::Axis::Z),
        world.stats(scalegnn::grid::Axis::Dp));
    println!("OK");
    Ok(())
}
