//! End-to-end driver (deliverable (b)/EXPERIMENTS.md §E2E): train the large
//! GCN variant (d_h=512, 4 layers, ~1.4 M parameters — GNN models are small;
//! the graph is the scale axis) on the 65 k-vertex `e2e_big` planted
//! community graph for a few hundred steps through the session API's
//! `reference` backend, logging the loss curve and periodic full-graph
//! accuracy.  Exercises every layer of the stack on a real workload: Rust
//! sampling/coordination -> PJRT -> AOT JAX+Pallas artifacts, with the
//! §V-A prefetch pipeline on.
//!
//! Run: `make artifacts && cargo run --release --example train_e2e`

use scalegnn::session::{self, BackendKind, LogObserver, RunSpec, StepObserver};

fn main() -> anyhow::Result<()> {
    let steps: u64 = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(300);

    let spec = RunSpec::new(BackendKind::Reference, "e2e_big")
        .steps(steps)
        .lr(3e-3)
        .eval_every(2);

    println!("== ScaleGNN end-to-end driver ==");
    println!("dataset e2e_big: 65536 vertices, ~1M edges, d_in=256, 32 classes");
    println!("model: 4-layer GCN, d_h=512 (~1.4M params), dropout 0.3, Adam");
    println!("running {steps} steps (batch 1024, prefetch on)\n");

    let t0 = std::time::Instant::now();
    let mut obs: Vec<Box<dyn StepObserver>> = vec![Box::new(LogObserver::every(0))];
    let run = session::run(&spec, &mut obs)?;
    let wall = t0.elapsed().as_secs_f64();
    let report = run.trainer.as_ref().expect("reference backend returns a trainer report");

    println!("\nloss curve:");
    for (step, loss) in &report.loss_curve {
        println!("  step {step:>5}  loss {loss:.4}");
    }
    println!("\nfull-graph accuracy:");
    for (step, val, test) in &report.acc_curve {
        println!("  step {step:>5}  val {val:.4}  test {test:.4}");
    }
    println!(
        "\n{} steps: wall {:.1}s (train {:.1}s + eval {:.1}s), {:.0} ms/step",
        report.steps,
        wall,
        report.train_time_s,
        report.eval_time_s,
        report.train_time_s / report.steps.max(1) as f64 * 1e3,
    );
    println!(
        "per-step breakdown: sample-wait {:.2} ms, pack {:.2} ms, exec {:.2} ms",
        report.breakdown.sample_wait_s * 1e3,
        report.breakdown.pack_s * 1e3,
        report.breakdown.exec_s * 1e3
    );
    println!(
        "final loss {:.4}, best val acc {:.4}, best test acc {:.4}",
        report.final_loss, report.best_val_acc, report.best_test_acc
    );
    let first = report.loss_curve.first().map(|x| x.1).unwrap_or(f32::NAN);
    anyhow::ensure!(
        report.final_loss < first * 0.7,
        "loss did not improve: {first} -> {}",
        report.final_loss
    );
    anyhow::ensure!(report.best_test_acc > 0.5, "model failed to learn");
    println!("E2E OK");
    Ok(())
}
