//! Fig. 7 sweep (example-sized): projected strong scaling of ScaleGNN on
//! all three machines and all five datasets, from the calibrated analytical
//! model.  `cargo bench --bench fig7_scaling` prints the full figure data.
//!
//! Run: `cargo run --release --example scaling_sweep`

use scalegnn::graph::datasets;
use scalegnn::grid::Grid4D;
use scalegnn::sim;

fn main() {
    let machines = [sim::PERLMUTTER, sim::FRONTIER, sim::TUOLUMNE];
    let sets = [
        "products_sim",
        "isolate_sim",
        "products14m_sim",
        "papers100m_sim",
    ];
    for m in &machines {
        println!("== {} ==", m.name);
        for ds in sets {
            let spec = datasets::spec(ds).unwrap();
            let w = sim::Workload::from_spec(&spec, 128.0, 3.0);
            let (x, y, z) = sim::base_grid_for(ds);
            let base = x * y * z;
            print!("  {ds:<16}");
            let mut first = None;
            for gd in [1usize, 2, 4, 8, 16, 32] {
                let gpus = base * gd;
                if gpus > 2048 {
                    break;
                }
                let t = sim::scalegnn_epoch(&w, m, Grid4D::new(gd, x, y, z), sim::OptFlags::ALL)
                    .total();
                let f = *first.get_or_insert(t);
                print!(" {:>6.0}ms({:>4.1}x)", t * 1e3, f / t);
            }
            println!();
        }
    }
    println!("\npaper anchors: papers100M on Perlmutter 64->2048 GPUs = 21.7x (4095->189 ms);");
    println!("Products-14M on Frontier 32->1024 GCDs = 22.4x; Tuolumne 32->1024 = 17.2x");
}
