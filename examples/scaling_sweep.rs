//! Fig. 7 sweep (example-sized): projected strong scaling of ScaleGNN on
//! all three machines and four datasets, each projection running through
//! the session API's `sim` backend.  `cargo bench --bench fig7_scaling`
//! prints the full figure data.
//!
//! Run: `cargo run --release --example scaling_sweep`

use scalegnn::comm::Precision;
use scalegnn::session::{self, BackendKind, GridSpec, RunSpec};
use scalegnn::sim;

fn main() -> anyhow::Result<()> {
    let machines = ["perlmutter", "frontier", "tuolumne"];
    let sets = [
        "products_sim",
        "isolate_sim",
        "products14m_sim",
        "papers100m_sim",
    ];
    for machine in machines {
        println!("== {machine} ==");
        for ds in sets {
            let (x, y, z) = sim::base_grid_for(ds);
            let base = x * y * z;
            let sweep: Vec<usize> =
                [1usize, 2, 4, 8, 16, 32].into_iter().filter(|gd| base * gd <= 2048).collect();
            let mut spec = RunSpec::new(BackendKind::Sim, ds).sim(machine, None, sweep);
            spec.grid = GridSpec { gd: 1, gx: x, gy: y, gz: z };
            spec.precision = Precision::Bf16; // §V-B on, as in the paper runs
            let report = session::run_silent(&spec)?;
            let points = report.sim.expect("sim backend returns a sim report").points;
            print!("  {ds:<16}");
            let first = points.first().map(|p| p.breakdown.total()).unwrap_or(f64::NAN);
            for p in &points {
                let t = p.breakdown.total();
                print!(" {:>6.0}ms({:>4.1}x)", t * 1e3, first / t);
            }
            println!();
        }
    }
    println!("\npaper anchors: papers100M on Perlmutter 64->2048 GPUs = 21.7x (4095->189 ms);");
    println!("Products-14M on Frontier 32->1024 GCDs = 22.4x; Tuolumne 32->1024 = 17.2x");
    Ok(())
}
