//! Fig. 6: end-to-end training time to target accuracy vs the four
//! baseline frameworks, on Perlmutter and Frontier, for reddit_sim and
//! products_sim.  Projected from the calibrated cost models; convergence
//! behaviour (epochs-to-target growth under vanilla data parallelism,
//! §VII-B) generates the baselines' non-scaling curves.
//!
//! Paper anchors: Reddit/Perlmutter: ScaleGNN 1.33 s @4 -> 0.98 s @16;
//! SALIENT++ 1.83 -> 3.13 s; products @64: ScaleGNN 3.80 s = 3.5x over
//! SALIENT++ (13.25 s), 10.6x over BNS-GCN (40.46 s); Frontier: DistDGL
//! and MassiveGNN are orders of magnitude slower.

use scalegnn::graph::datasets;
use scalegnn::sim;

fn main() {
    println!("=== Fig. 6: end-to-end time-to-accuracy (s) ===");
    for machine in [sim::PERLMUTTER, sim::FRONTIER] {
        for ds in ["reddit_sim", "products_sim"] {
            let spec = datasets::spec(ds).unwrap();
            let w = sim::Workload::from_spec(&spec, 128.0, 3.0);
            println!("\n-- {} / {} --", ds, machine.name);
            print!("{:>8}", "devices");
            for fw in sim::Framework::all() {
                print!(" {:>12}", fw.name());
            }
            println!();
            let counts: &[usize] = if ds == "reddit_sim" { &[4, 8, 16] } else { &[8, 16, 32, 64] };
            for &gpus in counts {
                print!("{:>8}", gpus);
                for fw in sim::Framework::all() {
                    let t = e2e_time(fw, &w, ds, &machine, gpus);
                    match t {
                        Some(t) => print!(" {:>11.2}s", t),
                        None => print!(" {:>12}", "-"),
                    }
                }
                println!();
            }
        }
    }
    println!("\nclaims reproduced (shapes): ScaleGNN fastest everywhere and");
    println!("improving with scale; SALIENT++/DistDGL flat or degrading (epochs");
    println!("grow with global batch); DistDGL/MassiveGNN orders of magnitude");
    println!("slower; CUDA-only baselines absent on Frontier.");

    // machine-checkable shape assertions
    let m = sim::PERLMUTTER;
    let wp = sim::Workload::from_spec(&datasets::spec("products_sim").unwrap(), 128.0, 3.0);
    let ours64 = e2e_time(sim::Framework::ScaleGnn, &wp, "products_sim", &m, 64).unwrap();
    let ours8 = e2e_time(sim::Framework::ScaleGnn, &wp, "products_sim", &m, 8).unwrap();
    let sal64 = e2e_time(sim::Framework::SalientPp, &wp, "products_sim", &m, 64).unwrap();
    assert!(ours64 < ours8, "ScaleGNN must scale");
    assert!(sal64 / ours64 > 2.0, "ScaleGNN must beat SALIENT++ at 64");
    println!("\nshape checks: PASS (ScaleGNN scales; >2x over SALIENT++ at 64 GPUs)");
}

fn e2e_time(
    fw: sim::Framework,
    w: &sim::Workload,
    ds: &str,
    m: &sim::Machine,
    gpus: usize,
) -> Option<f64> {
    if m.name != "Perlmutter" && !fw.supports_rocm() {
        return None;
    }
    let epochs = sim::epochs_to_target(fw, ds, gpus);
    let epoch = if fw == sim::Framework::ScaleGnn {
        let g = sim::grid_for(ds, gpus)?;
        sim::scalegnn_epoch(w, m, g, sim::OptFlags::ALL).total()
    } else {
        sim::baseline_epoch(fw, w, m, gpus)
    };
    Some(epochs * epoch)
}
