//! Fig. 5: epoch-time breakdown as the §V optimizations are applied
//! cumulatively, at DP1 (8 devices, 2x2x2) and DP4 (32 devices).
//!
//! Part 1 projects the paper-scale bars from the calibrated model
//! (paper: cumulative 1.75x at DP1, 1.66x at DP4; -24 % prefetch,
//! -17/16 % bf16, -6/4 % fusion, -3/2 % overlap).
//! Part 2 measures the *mechanisms* for real on the rank-thread engine:
//! per-phase times and the fp32-vs-bf16 collective payload reduction.

use std::sync::Arc;

use scalegnn::comm::{CommWorld, Precision};
use scalegnn::graph::datasets;
use scalegnn::grid::{Axis, Grid4D};
use scalegnn::model::GcnDims;
use scalegnn::pmm::{PmmCtx, PmmGcn, PmmTimers};
use scalegnn::sim;

fn main() {
    println!("=== Fig. 5: cumulative optimization breakdown ===\n");
    let w = sim::Workload::from_spec(&datasets::spec("products_sim").unwrap(), 128.0, 3.0);
    let m = sim::PERLMUTTER;
    let stages: [(&str, sim::OptFlags); 5] = [
        ("baseline", sim::OptFlags::NONE),
        ("+sampling overlap", sim::OptFlags { prefetch: true, ..sim::OptFlags::NONE }),
        (
            "+bf16 collectives",
            sim::OptFlags { prefetch: true, bf16: true, ..sim::OptFlags::NONE },
        ),
        (
            "+kernel fusion",
            sim::OptFlags { prefetch: true, bf16: true, fusion: true, overlap: false },
        ),
        ("+comm overlap", sim::OptFlags::ALL),
    ];
    for (label, gd) in [("DP1 (8 GPUs)", 1usize), ("DP4 (32 GPUs)", 4usize)] {
        println!("-- {label}: projected epoch breakdown (ms) --");
        println!(
            "{:<20} {:>8} {:>8} {:>8} {:>8} {:>8} {:>8} {:>8}",
            "stage", "total", "sampling", "tp_comm", "dp_comm", "elemwise", "compute", "other"
        );
        let mut base = None;
        for (name, opts) in stages {
            let b = sim::scalegnn_epoch(&w, &m, Grid4D::new(gd, 2, 2, 2), opts);
            let t = b.total();
            let speedup = *base.get_or_insert(t) / t;
            println!(
                "{:<20} {:>8.1} {:>8.1} {:>8.1} {:>8.1} {:>8.1} {:>8.1} {:>8.1}  ({speedup:.2}x)",
                name,
                t * 1e3,
                b.sampling * 1e3,
                b.tp_comm * 1e3,
                b.dp_comm * 1e3,
                b.elementwise * 1e3,
                (b.spmm + b.gemm) * 1e3,
                b.other * 1e3
            );
        }
        println!();
    }
    println!("paper: cumulative 1.75x (DP1) / 1.66x (DP4)\n");

    // -- measured mechanisms on the rank-thread engine --
    println!("-- measured (rank threads, products_sim 131k vertices, 2x2x2, 10 steps) --");
    for (name, prec) in [("fp32 collectives", Precision::Fp32), ("bf16 collectives", Precision::Bf16)] {
        let (timers, bytes) = run_engine(prec);
        println!(
            "  {name}: sampling {:.1} ms, spmm {:.1} ms, gemm {:.1} ms, ew {:.1} ms, tp {:.1} ms, reshard {:.1} ms | TP payload {:.1} MB",
            timers.sampling * 1e3,
            timers.spmm * 1e3,
            timers.gemm * 1e3,
            timers.elementwise * 1e3,
            timers.tp_comm * 1e3,
            timers.reshard * 1e3,
            bytes as f64 / 1e6
        );
    }
    println!("  (bf16 halves the accounted TP all-reduce payload, §V-B)");
}

fn run_engine(prec: Precision) -> (PmmTimers, u64) {
    let grid = Grid4D::new(1, 2, 2, 2);
    let data = Arc::new(datasets::load("products_sim").unwrap());
    let dims = GcnDims {
        d_in: 128,
        d_h: 128,
        d_out: 48,
        layers: 3,
        dropout: 0.5,
        weight_decay: 0.0,
    };
    let world = Arc::new(CommWorld::new(grid));
    let mut handles = vec![];
    for r in 0..grid.world_size() {
        let w = world.clone();
        let d = data.clone();
        handles.push(std::thread::spawn(move || {
            let ctx = PmmCtx::new(grid, r, &w, prec);
            let mut eng = PmmGcn::new(ctx, dims, 1024, d, 42);
            for s in 0..10 {
                eng.train_step(s, 1e-2);
            }
            eng.timers
        }));
    }
    let mut total = PmmTimers::default();
    for h in handles {
        total.add(&h.join().unwrap());
    }
    let n = grid.world_size() as f64;
    let scaled = PmmTimers {
        sampling: total.sampling / n,
        spmm: total.spmm / n,
        gemm: total.gemm / n,
        elementwise: total.elementwise / n,
        tp_comm: total.tp_comm / n,
        dp_comm: total.dp_comm / n,
        reshard: total.reshard / n,
        other: total.other / n,
    };
    let bytes = world.stats(Axis::X).1 + world.stats(Axis::Y).1 + world.stats(Axis::Z).1;
    (scaled, bytes)
}
