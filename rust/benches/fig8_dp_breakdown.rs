//! Fig. 8: epoch-time breakdown on Products-14M as Gd grows.
//!
//! Claim (§VII-C): the DP gradient all-reduce grows from nothing to a
//! visible fraction, while per-step 3D-PMM and sampling costs stay
//! constant (epoch totals shrink because each group runs fewer steps).
//!
//! Part 2 measures the same effect for real on rank threads: Gd in {1, 2,
//! 4} with a fixed 1x2x2 PMM grid on products_sim.

use std::sync::Arc;

use scalegnn::comm::{CommWorld, Precision};
use scalegnn::graph::datasets;
use scalegnn::grid::Grid4D;
use scalegnn::model::GcnDims;
use scalegnn::pmm::{PmmCtx, PmmGcn, PmmTimers};
use scalegnn::sim;

fn main() {
    println!("=== Fig. 8: epoch breakdown vs Gd (Products-14M, Perlmutter) ===\n");
    let w = sim::Workload::from_spec(&datasets::spec("products14m_sim").unwrap(), 128.0, 3.0);
    let (x, y, z) = sim::base_grid_for("products14m_sim");
    println!(
        "{:>4} {:>8} | {:>10} {:>10} {:>10} {:>10} {:>10} (ms)",
        "Gd", "devices", "sampling", "pmm comm", "dp comm", "compute", "total"
    );
    let mut dp_frac_grows = vec![];
    for gd in [1usize, 2, 4, 8, 16, 32] {
        let b = sim::scalegnn_epoch(
            &w,
            &sim::PERLMUTTER,
            Grid4D::new(gd, x, y, z),
            sim::OptFlags::ALL,
        );
        dp_frac_grows.push(b.dp_comm / b.total());
        println!(
            "{:>4} {:>8} | {:>10.1} {:>10.1} {:>10.1} {:>10.1} {:>10.1}",
            gd,
            gd * x * y * z,
            b.sampling * 1e3,
            (b.tp_comm + b.other) * 1e3,
            b.dp_comm * 1e3,
            (b.spmm + b.gemm + b.elementwise) * 1e3,
            b.total() * 1e3
        );
    }
    let grows = dp_frac_grows.windows(2).all(|w| w[1] >= w[0]);
    println!(
        "\nshape check (DP all-reduce fraction grows with Gd): {}",
        if grows { "PASS" } else { "FAIL" }
    );

    println!("\n-- measured (rank threads, products_sim, 1x2x2 PMM grid, 6 steps) --");
    println!(
        "{:>4} {:>7} | {:>9} {:>9} {:>9} {:>9} (ms/step/rank)",
        "Gd", "ranks", "sampling", "tp_comm", "dp_comm", "compute"
    );
    for gd in [1usize, 2, 4] {
        let t = run_engine(gd, 6);
        println!(
            "{:>4} {:>7} | {:>9.2} {:>9.2} {:>9.2} {:>9.2}",
            gd,
            gd * 4,
            t.sampling * 1e3 / 6.0,
            (t.tp_comm + t.reshard) * 1e3 / 6.0,
            t.dp_comm * 1e3 / 6.0,
            (t.spmm + t.gemm + t.elementwise) * 1e3 / 6.0
        );
    }
    println!("\n(measured dp_comm appears at Gd>1 while the other phases stay flat)");
}

fn run_engine(gd: usize, steps: u64) -> PmmTimers {
    let grid = Grid4D::new(gd, 1, 2, 2);
    let data = Arc::new(datasets::load("products_sim").unwrap());
    let dims = GcnDims {
        d_in: 128,
        d_h: 128,
        d_out: 48,
        layers: 3,
        dropout: 0.5,
        weight_decay: 0.0,
    };
    let world = Arc::new(CommWorld::new(grid));
    let mut handles = vec![];
    for r in 0..grid.world_size() {
        let w = world.clone();
        let d = data.clone();
        handles.push(std::thread::spawn(move || {
            let ctx = PmmCtx::new(grid, r, &w, Precision::Bf16);
            let mut eng = PmmGcn::new(ctx, dims, 1024, d, 42);
            for s in 0..steps {
                eng.train_step(s, 1e-2);
            }
            eng.timers
        }));
    }
    let mut total = PmmTimers::default();
    for h in handles {
        total.add(&h.join().unwrap());
    }
    let n = grid.world_size() as f64;
    PmmTimers {
        sampling: total.sampling / n,
        spmm: total.spmm / n,
        gemm: total.gemm / n,
        elementwise: total.elementwise / n,
        tp_comm: total.tp_comm / n,
        dp_comm: total.dp_comm / n,
        reshard: total.reshard / n,
        other: total.other / n,
    }
}
