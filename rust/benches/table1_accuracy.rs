//! Table I: test accuracy of ScaleGNN uniform vertex sampling vs
//! GraphSAINT (node) and GraphSAGE on the two accuracy datasets.
//!
//! Paper's rows (Reddit / ogbn-products): GraphSAINT 96.2/80.2,
//! GraphSAGE 95.4/79.6, ScaleGNN 96.3/81.3.  The claim reproduced here is
//! the *ordering*: uniform vertex sampling with unbiased rescaling matches
//! or exceeds both baselines on the scaled stand-in datasets.
//!
//! `SCALEGNN_BENCH_EPOCHS` overrides the training length (default 6).

use scalegnn::sampling::SamplerKind;
use scalegnn::trainer::{train, TrainConfig};

fn main() {
    let epochs: usize = std::env::var("SCALEGNN_BENCH_EPOCHS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(6);
    println!("=== Table I: test accuracy (%) by sampling algorithm ===");
    println!("(each cell: best full-graph test accuracy after {epochs} epochs)\n");
    println!(
        "{:<20} {:>12} {:>16}",
        "System", "reddit_sim", "products_sim"
    );

    let kinds = [
        SamplerKind::GraphSaintNode,
        SamplerKind::GraphSage,
        SamplerKind::ScaleGnnUniform,
    ];
    let mut results = std::collections::BTreeMap::new();
    for kind in kinds {
        let mut row = vec![];
        for ds in ["reddit_sim", "products_sim"] {
            let mut cfg = TrainConfig::quick(ds, kind);
            cfg.max_epochs = epochs;
            cfg.lr = 1e-2;
            cfg.eval_every_epochs = 1;
            let r = train(&cfg).expect("training failed");
            row.push(r.best_test_acc);
        }
        println!(
            "{:<20} {:>11.2}% {:>15.2}%",
            kind.name(),
            row[0] * 100.0,
            row[1] * 100.0
        );
        results.insert(kind.name(), row);
    }

    println!("\npaper Table I:      Reddit  ogbn-products");
    println!("GraphSAINT (node)    96.2       80.2");
    println!("GraphSAGE            95.4       79.6");
    println!("ScaleGNN             96.3       81.3");

    let ours = &results["ScaleGNN"];
    let sage = &results["GraphSAGE"];
    let shape_ok = ours[0] >= sage[0] - 0.02 && ours[1] >= sage[1] - 0.02;
    println!(
        "\nshape check (ScaleGNN >= GraphSAGE on both datasets): {}",
        if shape_ok { "PASS" } else { "FAIL" }
    );
}
