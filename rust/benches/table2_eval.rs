//! Table II: time per evaluation round.
//!
//! Two parts:
//! 1. **Measured** — the real distributed full-graph evaluation mechanism
//!    (one 3D-PMM forward, no sampling) on rank threads, vs a simulated
//!    sampling-based eval pipeline on the same substrate, on reddit_sim.
//! 2. **Projected** — the paper-scale table from the calibrated cost
//!    models (paper: ScaleGNN 0.05 s/0.19 s vs baselines 1.1-20.8 s).

use std::sync::Arc;

use scalegnn::comm::{CommWorld, Precision};
use scalegnn::graph::datasets;
use scalegnn::grid::Grid4D;
use scalegnn::model::GcnDims;
use scalegnn::pmm::{PmmCtx, PmmGcn};
use scalegnn::sim;

fn measured_pmm_eval(dataset: &str, grid: Grid4D) -> (f64, f32) {
    let data = Arc::new(datasets::load(dataset).unwrap());
    let spec = datasets::spec(dataset).unwrap();
    let dims = GcnDims {
        d_in: spec.planted.d_in,
        d_h: if dataset == "tiny" { 16 } else { 128 },
        d_out: spec.planted.classes,
        layers: if dataset == "tiny" { 2 } else { 3 },
        dropout: 0.0,
        weight_decay: 0.0,
    };
    let world = Arc::new(CommWorld::new(grid));
    let t0 = std::time::Instant::now();
    let mut handles = vec![];
    for r in 0..grid.world_size() {
        let w = world.clone();
        let d = data.clone();
        handles.push(std::thread::spawn(move || {
            let ctx = PmmCtx::new(grid, r, &w, Precision::Fp32);
            let mut eng = PmmGcn::new(ctx, dims, spec.batch, d, 42);
            eng.eval_full_graph()
        }));
    }
    let mut acc = 0.0;
    for h in handles {
        acc = h.join().unwrap().1;
    }
    (t0.elapsed().as_secs_f64(), acc)
}

fn main() {
    println!("=== Table II: time per evaluation round ===\n");

    // NOTE: this box exposes a single CPU core, so the rank threads time-
    // slice: the mechanism (one distributed forward, no sampling) is what
    // is demonstrated, not a speedup over ranks.
    println!("-- measured (rank threads, tiny dataset; single-core box) --");
    for grid in [Grid4D::new(1, 1, 1, 1), Grid4D::new(1, 2, 2, 1), Grid4D::new(1, 2, 2, 2)] {
        let (t, acc) = measured_pmm_eval("tiny", grid);
        println!(
            "  ScaleGNN 3D-PMM full-graph eval, {} ranks: {:.3} s (test acc {:.3})",
            grid.world_size(),
            t,
            acc
        );
    }

    println!("\n-- projected at paper scale (calibrated cost models) --");
    println!(
        "{:<22} {:>18} {:>24}",
        "System", "Reddit (4 GPUs)", "ogbn-products (8 GPUs)"
    );
    let m = sim::PERLMUTTER;
    let wr = sim::Workload::from_spec(&datasets::spec("reddit_sim").unwrap(), 128.0, 3.0);
    let wp = sim::Workload::from_spec(&datasets::spec("products_sim").unwrap(), 128.0, 3.0);
    for fw in [
        sim::Framework::DistDgl,
        sim::Framework::SalientPp,
        sim::Framework::BnsGcn,
        sim::Framework::ScaleGnn,
    ] {
        let (tr, tp) = if fw == sim::Framework::ScaleGnn {
            (
                sim::scalegnn_eval_round(&wr, &m, Grid4D::new(1, 2, 2, 1)),
                sim::scalegnn_eval_round(&wp, &m, Grid4D::new(1, 2, 2, 2)),
            )
        } else {
            (
                sim::baseline_eval_round(fw, &wr, &m, 4),
                sim::baseline_eval_round(fw, &wp, &m, 8),
            )
        };
        println!("{:<22} {:>16.2} s {:>22.2} s", fw.name(), tr, tp);
    }
    println!("\npaper Table II: DistDGL/MassiveGNN 12.50/20.82, SALIENT++ 1.13/10.12,");
    println!("                BNS-GCN 1.79/6.89, ScaleGNN 0.05/0.19  (s/round)");
}
