//! Ablations of the design choices DESIGN.md calls out:
//!
//! 1. **near-cubic vs skewed 3D grids** (§VII-C: "as close to a cube as
//!    possible is the most efficient configuration") — epoch time of every
//!    factorization of 8 and 16 devices per group.
//! 2. **sparse edge-list vs dense-ified SpMM lowering** (DESIGN.md §5) —
//!    measured PJRT step time of the two tiny artifacts on the same batch.
//! 3. **layer rotation vs naive reshard-per-layer** — communication volume
//!    of the rotation schedule (adjacency pre-sharded per layout, no extra
//!    comm) vs resharding features to a fixed layout every layer.
//! 4. **DP gradient bucketing** — the latency/bandwidth trade of 1..16
//!    buckets in the DP all-reduce model at Gd=32.

use scalegnn::graph::datasets;
use scalegnn::grid::Grid4D;
use scalegnn::runtime::{lit_f32, lit_i32, lit_u32, Runtime};
use scalegnn::sim;
use scalegnn::util::stats::bench;

fn main() {
    println!("=== design-choice ablations ===\n");
    grid_shape_ablation();
    lowering_ablation();
    rotation_ablation();
    bucketing_ablation();
}

fn grid_shape_ablation() {
    println!("-- 1. 3D grid shape (products_sim, Perlmutter, Gd=1) --");
    let w = sim::Workload::from_spec(&datasets::spec("products_sim").unwrap(), 128.0, 3.0);
    for &(x, y, z) in &[
        (2usize, 2usize, 2usize),
        (4, 2, 1),
        (8, 1, 1),
        (1, 8, 1),
        (1, 1, 8),
        (4, 4, 1),
        (4, 2, 2),
        (16, 1, 1),
    ] {
        let t = sim::scalegnn_epoch(
            &w,
            &sim::PERLMUTTER,
            Grid4D::new(1, x, y, z),
            sim::OptFlags::ALL,
        )
        .total();
        let cube = if x == y && y == z { " (cube)" } else { "" };
        println!("   {x}x{y}x{z}: {:>8.1} ms{cube}", t * 1e3);
    }
    println!("   claim: the near-cubic factorization minimizes epoch time\n");
}

fn lowering_ablation() {
    println!("-- 2. SpMM lowering: sparse edge-list vs dense B x B (tiny, PJRT) --");
    let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let rt = match Runtime::open(&dir) {
        Ok(rt) => rt,
        Err(e) => {
            println!("   (skipped: {e})");
            return;
        }
    };
    let g = scalegnn::util::json::Json::parse(
        &std::fs::read_to_string(dir.join("golden.json")).unwrap(),
    )
    .unwrap();
    let to_i32 = |k: &str| -> Vec<i32> {
        g.get(k).unwrap().as_arr().unwrap().iter().map(|v| v.as_f64().unwrap() as i32).collect()
    };
    let meta = rt.model("tiny").unwrap().clone();
    let (b, e) = (meta.batch, meta.edge_cap);
    let a = g.get("a").unwrap().as_f32_vec().unwrap();
    let (src, dst) = (to_i32("src"), to_i32("dst"));
    let val = g.get("val").unwrap().as_f32_vec().unwrap();
    let x = g.get("x").unwrap().as_f32_vec().unwrap();
    let y = to_i32("y");
    let wm = g.get("wmask").unwrap().as_f32_vec().unwrap();
    let params: Vec<Vec<f32>> = g
        .get("init_params")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|p| p.as_f32_vec().unwrap())
        .collect();
    let zeros: Vec<Vec<f32>> = params.iter().map(|p| vec![0.0; p.len()]).collect();
    let key = [1000u32, 0];

    let tail = |v: &mut Vec<xla::Literal>| {
        v.push(xla::Literal::scalar(1e-2f32));
        v.push(xla::Literal::scalar(0.0f32));
        for group in [&params, &zeros, &zeros] {
            for (data, shape) in group.iter().zip(&meta.param_shapes) {
                v.push(lit_f32(data, shape).unwrap());
            }
        }
    };
    let mut sparse_in = vec![
        lit_i32(&src, &[e]).unwrap(),
        lit_i32(&dst, &[e]).unwrap(),
        lit_f32(&val, &[e]).unwrap(),
        lit_f32(&x, &[b, meta.d_in]).unwrap(),
        lit_i32(&y, &[b]).unwrap(),
        lit_f32(&wm, &[b]).unwrap(),
        lit_u32(&key, &[2]).unwrap(),
    ];
    tail(&mut sparse_in);
    let mut dense_in = vec![
        lit_f32(&a, &[b, b]).unwrap(),
        lit_f32(&x, &[b, meta.d_in]).unwrap(),
        lit_i32(&y, &[b]).unwrap(),
        lit_f32(&wm, &[b]).unwrap(),
        lit_u32(&key, &[2]).unwrap(),
    ];
    tail(&mut dense_in);

    let sparse = rt.load("train_step_tiny").unwrap();
    let dense = rt.load("train_step_tiny_dense").unwrap();
    let r1 = bench("   sparse edge-list step (B=32)", 3, 30, || {
        std::hint::black_box(sparse.run(&sparse_in).unwrap().len());
    });
    let r2 = bench("   dense B x B step (B=32)", 3, 30, || {
        std::hint::black_box(dense.run(&dense_in).unwrap().len());
    });
    println!("{}", r1.report());
    println!("{}", r2.report());
    println!(
        "   (at B=1024 the gap is ~27x — EXPERIMENTS.md §Perf #2; the dense\n    path is the TPU/MXU schedule)\n"
    );
}

fn rotation_ablation() {
    println!("-- 3. layer rotation vs reshard-every-layer (comm volume / step) --");
    // rotation: adjacency pre-sharded per layout; features never reshard
    // except the residual skip.  naive: features forced back to (X,Y) after
    // every layer = one full reshard (two all-gathers) per layer extra.
    let w = sim::Workload::from_spec(&datasets::spec("products_sim").unwrap(), 128.0, 3.0);
    let m = sim::PERLMUTTER;
    let g = Grid4D::new(1, 2, 2, 2);
    let base = sim::scalegnn_epoch(&w, &m, g, sim::OptFlags::ALL);
    // extra reshard ~ all-gather of B x d_h strip over two axes per layer,
    // fwd + bwd
    let strip = w.batch / 2.0 * w.d_h / 2.0 * 4.0;
    let extra_per_step = 2.0
        * w.layers
        * (m.all_gather_time(strip, 2, false) + m.all_gather_time(strip * 2.0, 2, true));
    let steps = w.n / w.batch;
    let naive = base.total() + extra_per_step * steps;
    println!(
        "   rotation (paper):   {:>8.1} ms/epoch\n   reshard-per-layer:  {:>8.1} ms/epoch (+{:.0} %)",
        base.total() * 1e3,
        naive * 1e3,
        (naive / base.total() - 1.0) * 100.0
    );
    println!("   claim: rotation's <=3 adjacency shards avoid all per-layer resharding\n");
}

fn bucketing_ablation() {
    println!("-- 4. DP gradient bucketing (papers100m_sim, Gd=32, per step) --");
    let w = sim::Workload::from_spec(&datasets::spec("papers100m_sim").unwrap(), 128.0, 3.0);
    let m = sim::PERLMUTTER;
    let bytes = w.params() * 4.0 / 64.0; // per-rank shard on the 4x4x4 grid
    for buckets in [1usize, 2, 4, 8, 16] {
        let t = buckets as f64
            * m.all_reduce_time(bytes / buckets as f64, 32, true);
        println!("   {buckets:>2} buckets: {:>7.3} ms", t * 1e3);
    }
    println!("   (1 bucket minimizes latency; many buckets enable overlap — the\n    model uses 4, matching gradient-bucketed NCCL practice)");
}
