//! Fig. 7: strong scaling of ScaleGNN on Perlmutter, Frontier and
//! Tuolumne for all scaling datasets.  Each curve starts at the smallest
//! 3D-PMM configuration (Gd=1) and scales out by growing Gd with the 3D
//! grid fixed — exactly the paper's methodology (§VII-C).
//!
//! Paper anchors: papers100M on Perlmutter 64->2048 = 21.7x (4095->189 ms);
//! Products-14M on Frontier 32->1024 GCDs = 22.4x (8809->394 ms);
//! Tuolumne Products-14M 32->1024 = 17.2x (9710->566 ms); Frontier slower
//! than Perlmutter at equal counts (RCCL, [60]).

use scalegnn::graph::datasets;
use scalegnn::grid::Grid4D;
use scalegnn::sim;

const DATASETS: [&str; 5] = [
    "products_sim",
    "reddit_sim",
    "isolate_sim",
    "products14m_sim",
    "papers100m_sim",
];

fn main() {
    println!("=== Fig. 7: strong scaling (epoch time, ms) ===");
    let mut frontier_slower = true;
    for m in [sim::PERLMUTTER, sim::FRONTIER, sim::TUOLUMNE] {
        println!("\n-- {} --", m.name);
        println!(
            "{:<18} {:>7} {:>9} {:>9} {:>9} {:>9} {:>9} {:>9} {:>10}",
            "dataset", "base", "Gd=1", "Gd=2", "Gd=4", "Gd=8", "Gd=16", "Gd=32", "speedup"
        );
        for ds in DATASETS {
            let spec = datasets::spec(ds).unwrap();
            let w = sim::Workload::from_spec(&spec, 128.0, 3.0);
            let (x, y, z) = sim::base_grid_for(ds);
            let base = x * y * z;
            print!("{:<18} {:>7}", ds, base);
            let mut first = 0.0;
            let mut last = 0.0;
            for gd in [1usize, 2, 4, 8, 16, 32] {
                if base * gd > 2048 {
                    print!(" {:>9}", "-");
                    continue;
                }
                let t = sim::scalegnn_epoch(&w, &m, Grid4D::new(gd, x, y, z), sim::OptFlags::ALL)
                    .total();
                if gd == 1 {
                    first = t;
                }
                last = t;
                print!(" {:>9.1}", t * 1e3);
            }
            println!(" {:>9.1}x", first / last);
        }
        // Frontier-vs-Perlmutter check at equal counts
        if m.name == "Frontier" {
            let w = sim::Workload::from_spec(
                &datasets::spec("products14m_sim").unwrap(),
                128.0,
                3.0,
            );
            let (x, y, z) = sim::base_grid_for("products14m_sim");
            let tf = sim::scalegnn_epoch(&w, &m, Grid4D::new(4, x, y, z), sim::OptFlags::ALL)
                .total();
            let tp = sim::scalegnn_epoch(
                &w,
                &sim::PERLMUTTER,
                Grid4D::new(4, x, y, z),
                sim::OptFlags::ALL,
            )
            .total();
            frontier_slower = tf > tp;
        }
    }
    println!("\npaper anchors: papers100M Perlmutter 64->2048 21.7x; Products-14M");
    println!("Frontier 32->1024 22.4x; Tuolumne 32->1024 17.2x");
    println!(
        "shape check (Frontier slower than Perlmutter at equal device counts): {}",
        if frontier_slower { "PASS" } else { "FAIL" }
    );
}
