//! Microbenchmarks of the Layer-3 hot paths (perf-pass instrumentation,
//! EXPERIMENTS.md §Perf): the parallel tiled compute kernels (serial vs.
//! multithreaded GEMM/SpMM/fused SpMM+GEMM), Algorithm 2 sampling,
//! dense-ification, literal packing, the PJRT train step, shared-memory
//! collectives, and the workspace train step.
//!
//! Kernel results are also written to `BENCH_kernels.json` as
//! machine-readable records `(op, shape, threads, ns_per_iter, gflops)` so
//! the perf trajectory can be tracked across PRs.

use std::path::PathBuf;
use std::sync::Arc;

use scalegnn::comm::{CommWorld, Endpoint, Precision};
use scalegnn::graph::{datasets, generate, partition_2d};
use scalegnn::grid::{Axis, Grid4D};
use scalegnn::runtime::{lit_f32, Runtime};
use scalegnn::sampling::{densify_into, DistributedSubgraphBuilder, UniformVertexSampler};
use scalegnn::tensor::{matmul_into_threads, pool, Mat};
use scalegnn::trainer::batch::BatchMaker;
use scalegnn::util::rng::Rng;
use scalegnn::util::stats::{bench, fmt_time, median};

/// One machine-readable kernel measurement.
struct KernelRecord {
    op: &'static str,
    shape: String,
    threads: usize,
    ns_per_iter: f64,
    gflops: f64,
}

fn json_escape(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}

fn write_kernel_json(records: &[KernelRecord]) {
    let mut out = String::from("{\n  \"kernels\": [\n");
    for (i, r) in records.iter().enumerate() {
        out.push_str(&format!(
            "    {{\"op\": \"{}\", \"shape\": \"{}\", \"threads\": {}, \
             \"ns_per_iter\": {:.1}, \"gflops\": {:.3}}}{}\n",
            json_escape(r.op),
            json_escape(&r.shape),
            r.threads,
            r.ns_per_iter,
            r.gflops,
            if i + 1 == records.len() { "" } else { "," }
        ));
    }
    out.push_str("  ]\n}\n");
    match std::fs::write("BENCH_kernels.json", &out) {
        Ok(()) => println!("\nwrote BENCH_kernels.json ({} records)", records.len()),
        Err(e) => eprintln!("could not write BENCH_kernels.json: {e}"),
    }
}

/// Benchmark `f` and record it: `flops` is the work per iteration.
fn kbench<F: FnMut()>(
    records: &mut Vec<KernelRecord>,
    op: &'static str,
    shape: String,
    threads: usize,
    flops: usize,
    iters: usize,
    f: F,
) -> f64 {
    let label = format!("{op} {shape} t={threads}");
    let r = bench(&label, 2, iters, f);
    println!("{}", r.report());
    records.push(KernelRecord {
        op,
        shape,
        threads,
        ns_per_iter: r.mean_s * 1e9,
        gflops: flops as f64 / r.mean_s / 1e9,
    });
    r.mean_s
}

fn thread_sweep() -> Vec<usize> {
    let mut ts = vec![1usize, 2, 4];
    let avail = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
    if !ts.contains(&avail) {
        ts.push(avail);
    }
    ts.retain(|&t| t <= avail.max(4));
    ts.dedup();
    ts
}

fn kernel_section(records: &mut Vec<KernelRecord>) {
    println!("--- parallel tiled kernels (serial baseline = t=1) ---");
    let mut rng = Rng::new(1);

    // GEMM at the realistic mini-batch shape: 8192 x 128 @ 128 x 128
    let (m, k, n) = (8192usize, 128usize, 128usize);
    let a = Mat::randn(m, k, &mut rng, 1.0);
    let b = Mat::randn(k, n, &mut rng, 1.0);
    let mut c = Mat::zeros(m, n);
    let flops = 2 * m * k * n;
    let mut serial = f64::NAN;
    for t in thread_sweep() {
        let dt = kbench(
            records,
            "matmul",
            format!("{m}x{k}x{n}"),
            t,
            flops,
            10,
            || {
                matmul_into_threads(&a, &b, &mut c, false, t);
                std::hint::black_box(c.data[0]);
            },
        );
        if t == 1 {
            serial = dt;
        } else {
            println!("    -> speedup vs serial: {:.2}x", serial / dt);
        }
    }

    // SpMM on an 8192-vertex rmat graph (~16 nnz/row), d = 128
    let g = generate::rmat(13, 16, 7).gcn_normalize();
    let x = Mat::randn(g.cols, 128, &mut rng, 1.0);
    let mut y = Mat::zeros(g.rows, 128);
    let spmm_flops = 2 * g.nnz() * 128;
    let mut serial = f64::NAN;
    for t in thread_sweep() {
        let dt = kbench(
            records,
            "spmm",
            format!("{}x{}nnz{}x128", g.rows, g.cols, g.nnz()),
            t,
            spmm_flops,
            10,
            || {
                g.spmm_into_threads(&x, &mut y, t);
                std::hint::black_box(y.data[0]);
            },
        );
        if t == 1 {
            serial = dt;
        } else {
            println!("    -> speedup vs serial: {:.2}x", serial / dt);
        }
    }

    // fused SpMM+GEMM (aggregate + transform in one pass) vs unfused
    let w = Mat::randn(128, 128, &mut rng, 1.0);
    let mut agg = Mat::zeros(g.rows, 128);
    let mut out = Mat::zeros(g.rows, 128);
    let fused_flops = spmm_flops + 2 * g.rows * 128 * 128;
    for t in thread_sweep() {
        kbench(
            records,
            "spmm_matmul_fused",
            format!("{}x128x128", g.rows),
            t,
            fused_flops,
            10,
            || {
                g.spmm_matmul_into_threads(&x, &w, Some(&mut agg), &mut out, t);
                std::hint::black_box(out.data[0]);
            },
        );
    }
    let t = pool::num_threads();
    kbench(
        records,
        "spmm_then_matmul_unfused",
        format!("{}x128x128", g.rows),
        t,
        fused_flops,
        10,
        || {
            g.spmm_into_threads(&x, &mut agg, t);
            matmul_into_threads(&agg, &w, &mut out, false, t);
            std::hint::black_box(out.data[0]);
        },
    );

    // §V-B bf16 widen/narrow: runtime-dispatched SIMD vs the retained
    // scalar reference (acceptance bar: >= 1.5x when a vector level is
    // detected; under PALLAS_SIMD=0 both rows execute the scalar path)
    {
        use scalegnn::tensor::simd;
        let n = 1usize << 20;
        let xs: Vec<f32> = (0..n).map(|_| rng.f32() * 2.0 - 1.0).collect();
        let mut bits = vec![0u16; n];
        let mut wide = vec![0.0f32; n];
        println!("    (simd dispatch level: {:?})", simd::level());
        let d_nar = kbench(records, "bf16_narrow", format!("{n} elems"), 1, n, 50, || {
            simd::narrow_bf16(&xs, &mut bits);
            std::hint::black_box(bits[0]);
        });
        let s_nar = kbench(records, "bf16_narrow_scalar", format!("{n} elems"), 1, n, 50, || {
            simd::narrow_bf16_scalar(&xs, &mut bits);
            std::hint::black_box(bits[0]);
        });
        println!("    -> narrow speedup vs scalar: {:.2}x", s_nar / d_nar);
        let d_wid = kbench(records, "bf16_widen", format!("{n} elems"), 1, n, 50, || {
            simd::widen_bf16(&bits, &mut wide);
            std::hint::black_box(wide[0]);
        });
        let s_wid = kbench(records, "bf16_widen_scalar", format!("{n} elems"), 1, n, 50, || {
            simd::widen_bf16_scalar(&bits, &mut wide);
            std::hint::black_box(wide[0]);
        });
        println!("    -> widen speedup vs scalar: {:.2}x", s_wid / d_wid);
    }

    // workspace train step (zero-allocation serial hot loop)
    let dims = scalegnn::model::GcnDims {
        d_in: 128,
        d_h: 128,
        d_out: 32,
        layers: 3,
        dropout: 0.0,
        weight_decay: 0.0,
    };
    let bsz = 1024usize;
    let gb = generate::rmat(10, 16, 9).gcn_normalize();
    let s: Vec<u32> = (0..bsz as u32).collect();
    let mb = scalegnn::sampling::induce_rescaled(&gb, &s, 0.5);
    let xb = Mat::randn(bsz, dims.d_in, &mut rng, 1.0);
    let yb: Vec<u32> = (0..bsz).map(|i| (i % 32) as u32).collect();
    let wb = vec![1.0f32; bsz];
    let masks = vec![Mat::filled(bsz, dims.d_h, 1.0); dims.layers];
    let mut params = scalegnn::model::init_params(&dims, 3);
    let mut opt = scalegnn::model::AdamState::new(&dims);
    let mut ws = scalegnn::model::StepWorkspace::new();
    let step_flops = 3 * 2 * (2 * mb.adj.nnz() * 128 + 2 * bsz * 128 * 128);
    kbench(
        records,
        "train_step_ws",
        format!("B={bsz},d_h=128,L=3"),
        pool::num_threads(),
        step_flops,
        10,
        || {
            let (l, _) = scalegnn::model::train_step_ws(
                &dims, &mut params, &mut opt, &mb.adj, &mb.adj_t, &xb, &yb, &wb, &masks,
                1e-3, &mut ws,
            );
            std::hint::black_box(l);
        },
    );
    println!();
}

/// §V-D end-to-end ablation: run the 8-rank PMM engine with overlap on and
/// off on the products_sim config and emit `BENCH_e2e.json` — the per-step
/// epoch-time breakdown with the measured hidden-comm fraction per axis,
/// so the perf trajectory has executed end-to-end numbers per PR.  A third
/// run repeats the overlap-on config at bf16 (§V-B: TP matmul all-reduces
/// and activation gathers ride as rounded 2-byte payloads) and the doc
/// records the measured TP comm-byte reduction and the loss/accuracy delta
/// against the fp32 baseline.
fn e2e_overlap_section() {
    use scalegnn::model::GcnDims;
    use scalegnn::pmm::{PmmCtx, PmmGcn, PmmTimers};
    use scalegnn::util::json::{obj, Json};

    let grid = Grid4D::new(2, 2, 2, 1); // 8 rank threads; Gd=2 exercises DP buckets
    let data = Arc::new(datasets::load("products_sim").unwrap());
    let spec = datasets::spec("products_sim").unwrap();
    let dims = GcnDims {
        d_in: spec.planted.d_in,
        d_h: 128,
        d_out: spec.planted.classes,
        layers: 3,
        dropout: 0.0,
        weight_decay: 0.0,
    };
    let batch = spec.batch;
    let steps: u64 = 16;
    let warmup = 4usize;

    struct E2eRun {
        step_s: f64,
        timers: PmmTimers,
        hidden: [f64; 4],
        tp_hidden: f64,
        /// cumulative payload bytes per axis over the whole run [x, y, z, dp]
        bytes: [u64; 4],
        final_loss: f32,
        final_acc: f32,
    }

    let run = |overlap: bool, prec: Precision| -> E2eRun {
        let world = Arc::new(CommWorld::new(grid));
        let mut hs = vec![];
        for r in 0..grid.world_size() {
            let w = world.clone();
            let d = data.clone();
            hs.push(std::thread::spawn(move || {
                let ctx = PmmCtx::new(grid, r, &w, prec);
                let mut eng = PmmGcn::new(ctx, dims, batch, d, 42);
                eng.set_overlap(overlap);
                let mut per_step = Vec::with_capacity(steps as usize);
                let mut last = (0.0f32, 0.0f32);
                for s in 0..steps {
                    let t0 = std::time::Instant::now();
                    let out = eng.train_step(s, 5e-3);
                    per_step.push(t0.elapsed().as_secs_f64());
                    last = (out.loss, out.acc);
                }
                (per_step, eng.timers, last)
            }));
        }
        let mut all_steps: Vec<Vec<f64>> = vec![];
        let mut timers = PmmTimers::default();
        let mut last = (0.0f32, 0.0f32);
        for (r, h) in hs.into_iter().enumerate() {
            let (ps, t, l) = h.join().unwrap();
            all_steps.push(ps);
            timers.add(&t);
            if r == 0 {
                last = l;
            }
        }
        // per-step critical path = slowest rank; median over post-warmup steps
        let per_step_max: Vec<f64> = (warmup..steps as usize)
            .map(|s| all_steps.iter().map(|v| v[s]).fold(0.0f64, f64::max))
            .collect();
        E2eRun {
            step_s: median(&per_step_max),
            timers,
            hidden: [
                world.hidden_fraction(Axis::X),
                world.hidden_fraction(Axis::Y),
                world.hidden_fraction(Axis::Z),
                world.hidden_fraction(Axis::Dp),
            ],
            tp_hidden: world.tp_hidden_fraction(),
            bytes: [
                world.stats(Axis::X).1,
                world.stats(Axis::Y).1,
                world.stats(Axis::Z).1,
                world.stats(Axis::Dp).1,
            ],
            final_loss: last.0,
            final_acc: last.1,
        }
    };

    println!("--- §V-D end-to-end overlap ablation (8 rank threads, products_sim) ---");
    let on = run(true, Precision::Fp32);
    let off = run(false, Precision::Fp32);
    println!(
        "overlap on : median step {}  (tp hidden frac {:.3})",
        fmt_time(on.step_s),
        on.tp_hidden
    );
    println!(
        "overlap off: median step {}  (tp hidden frac {:.3})  -> on/off speedup {:.2}x",
        fmt_time(off.step_s),
        off.tp_hidden,
        off.step_s / on.step_s
    );

    // §V-B precision ablation: identical config and seed, overlap on; the
    // overlap-on fp32 run above doubles as the baseline side
    let bf = run(true, Precision::Bf16);
    let steps_f = steps as f64;
    let tp_bytes = |r: &E2eRun| (r.bytes[0] + r.bytes[1] + r.bytes[2]) as f64;
    let reduction = tp_bytes(&on) / tp_bytes(&bf);
    println!(
        "precision fp32: median step {}  tp comm {:.1} KiB/step  final loss {:.4} acc {:.3}",
        fmt_time(on.step_s),
        tp_bytes(&on) / steps_f / 1024.0,
        on.final_loss,
        on.final_acc
    );
    println!(
        "precision bf16: median step {}  tp comm {:.1} KiB/step  final loss {:.4} acc {:.3}  \
         -> {:.2}x fewer tp bytes",
        fmt_time(bf.step_s),
        tp_bytes(&bf) / steps_f / 1024.0,
        bf.final_loss,
        bf.final_acc,
        reduction
    );

    let n = grid.world_size() as f64;
    let side = |r: &E2eRun| -> Json {
        let t = &r.timers;
        obj(vec![
            ("step_s_median", Json::from(r.step_s)),
            (
                "per_rank_mean_s",
                obj(vec![
                    ("sampling", Json::from(t.sampling / n)),
                    ("spmm", Json::from(t.spmm / n)),
                    ("gemm", Json::from(t.gemm / n)),
                    ("elementwise", Json::from(t.elementwise / n)),
                    ("tp_comm", Json::from(t.tp_comm / n)),
                    ("dp_comm", Json::from(t.dp_comm / n)),
                    ("reshard", Json::from(t.reshard / n)),
                    ("other", Json::from(t.other / n)),
                ]),
            ),
            (
                "hidden_frac",
                obj(vec![
                    ("x", Json::from(r.hidden[0])),
                    ("y", Json::from(r.hidden[1])),
                    ("z", Json::from(r.hidden[2])),
                    ("dp", Json::from(r.hidden[3])),
                    ("tp_aggregate", Json::from(r.tp_hidden)),
                ]),
            ),
            (
                "comm_bytes_per_step",
                obj(vec![
                    ("x", Json::from(r.bytes[0] as f64 / steps_f)),
                    ("y", Json::from(r.bytes[1] as f64 / steps_f)),
                    ("z", Json::from(r.bytes[2] as f64 / steps_f)),
                    ("dp", Json::from(r.bytes[3] as f64 / steps_f)),
                    ("tp_total", Json::from(tp_bytes(r) / steps_f)),
                ]),
            ),
        ])
    };
    let prec_side = |r: &E2eRun| -> Json {
        obj(vec![
            ("step_s_median", Json::from(r.step_s)),
            ("tp_comm_bytes_per_step", Json::from(tp_bytes(r) / steps_f)),
            ("dp_comm_bytes_per_step", Json::from(r.bytes[3] as f64 / steps_f)),
            ("final_loss", Json::from(r.final_loss as f64)),
            ("final_train_acc", Json::from(r.final_acc as f64)),
        ])
    };
    let doc = obj(vec![
        (
            "config",
            obj(vec![
                ("dataset", Json::from("products_sim")),
                ("grid", Json::from("2x2x2x1")),
                ("ranks", Json::from(grid.world_size())),
                ("batch", Json::from(batch)),
                ("d_h", Json::from(128usize)),
                ("layers", Json::from(3usize)),
                ("steps", Json::from(steps as usize)),
                ("warmup_steps", Json::from(warmup)),
            ]),
        ),
        ("overlap_on", side(&on)),
        ("overlap_off", side(&off)),
        ("speedup_off_over_on", Json::from(off.step_s / on.step_s)),
        (
            "precision_ablation",
            obj(vec![
                (
                    "what",
                    Json::from(
                        "§V-B: same seed and config as overlap_on; bf16 sends the TP matmul \
                         all-reduces and activation-reshard gathers as rounded 2-byte payloads \
                         (class-axis softmax ops and DP gradient buckets stay fp32)",
                    ),
                ),
                ("fp32", prec_side(&on)),
                ("bf16", prec_side(&bf)),
                ("tp_comm_byte_reduction", Json::from(reduction)),
                ("step_speedup_bf16_over_fp32", Json::from(on.step_s / bf.step_s)),
                ("final_loss_delta", Json::from((bf.final_loss - on.final_loss) as f64)),
                ("final_acc_delta", Json::from((bf.final_acc - on.final_acc) as f64)),
            ]),
        ),
    ]);
    match std::fs::write("BENCH_e2e.json", doc.to_string() + "\n") {
        Ok(()) => println!("wrote BENCH_e2e.json\n"),
        Err(e) => eprintln!("could not write BENCH_e2e.json: {e}\n"),
    }
}

fn main() {
    println!("=== Layer-3 microbenchmarks ===\n");
    let mut records: Vec<KernelRecord> = Vec::new();
    kernel_section(&mut records);

    let data = Arc::new(datasets::load("products_sim").unwrap());
    let spec = datasets::spec("products_sim").unwrap();
    let b = spec.batch;

    // --- Algorithm 2 (single shard = whole graph) ---
    let sampler = UniformVertexSampler::new(data.n, b, 42);
    let shard = partition_2d(&data.adj, 1, 1).remove(0);
    let mut builder = DistributedSubgraphBuilder::new(sampler.clone(), shard);
    let mut step = 0u64;
    println!(
        "{}",
        bench("alg2 subgraph build (131k graph, B=1024)", 3, 30, || {
            let out = builder.build(step);
            step += 1;
            std::hint::black_box(out.adj.nnz());
        })
        .report()
    );

    // 2x2 sharded build (per-rank work)
    let shards = partition_2d(&data.adj, 2, 2);
    let mut builders: Vec<_> = shards
        .into_iter()
        .map(|s| DistributedSubgraphBuilder::new(sampler.clone(), s))
        .collect();
    let mut step = 0u64;
    println!(
        "{}",
        bench("alg2 per-rank build (2x2 shard grid)", 3, 30, || {
            for bu in builders.iter_mut() {
                std::hint::black_box(bu.build(step).adj.nnz());
            }
            step += 1;
        })
        .report()
    );

    // --- raw uniform sample ---
    let mut step = 0u64;
    println!(
        "{}",
        bench("uniform sample B=1024 of N=131k (sorted)", 3, 100, || {
            std::hint::black_box(sampler.sample(step));
            step += 1;
        })
        .report()
    );

    // --- batch assembly (sampling + densify + gather) ---
    let mut maker = BatchMaker::new(
        data.clone(),
        scalegnn::sampling::SamplerKind::ScaleGnnUniform,
        b,
        16384,
        3,
        7,
    );
    let mut step = 0u64;
    println!(
        "{}",
        bench("full batch assembly (edges+features+labels)", 3, 20, || {
            std::hint::black_box(maker.make(step).val[0]);
            step += 1;
        })
        .report()
    );

    // --- batch construction throughput: in-memory vs out-of-core ---
    // same sampler seed on both makers; the store path re-reads graph +
    // feature bytes through the bounded block cache (graph::store).  pack()
    // is atomic (tmp + rename), so an existing file is always complete; a
    // stale file from an older format version just gets repacked.
    let store_path = std::env::temp_dir().join("pallas_bench_products_sim.pallas");
    let reusable = scalegnn::graph::store::OocGraph::open(&store_path, 32 << 20)
        .ok()
        // a cached store from an earlier run must hold exactly this graph,
        // or the mem-vs-ooc comparison silently diverges
        .filter(|s| {
            s.source_tag == scalegnn::graph::store::name_tag(&data.name)
                && s.n == data.n
                && s.d_in == data.features.cols
                && s.nnz == data.adj.nnz()
        });
    let store = match reusable {
        Some(s) => Arc::new(s),
        None => {
            scalegnn::graph::store::pack(&data, &store_path).expect("packing bench store");
            Arc::new(
                scalegnn::graph::store::OocGraph::open(&store_path, 32 << 20)
                    .expect("opening bench store"),
            )
        }
    };
    let mut step = 0u64;
    kbench(
        &mut records,
        "batch_assembly_mem",
        format!("B={b},131k graph"),
        1,
        0,
        20,
        || {
            // reuses the batch-assembly maker above (same config); steps
            // restart at 0 so the ooc maker below samples the same batches
            std::hint::black_box(maker.make(step).val[0]);
            step += 1;
        },
    );
    let mut ooc_maker = BatchMaker::from_store(store.clone(), b, 16384, 7);
    let mut step = 0u64;
    kbench(
        &mut records,
        "batch_assembly_ooc",
        format!("B={b},131k store"),
        1,
        0,
        20,
        || {
            std::hint::black_box(ooc_maker.make(step).val[0]);
            step += 1;
        },
    );
    let cs = store.cache_stats();
    println!(
        "    -> store {} MiB; cache resident {} KiB ({} hits / {} misses)\n",
        store.store_bytes() >> 20,
        cs.resident_bytes >> 10,
        cs.hits,
        cs.misses
    );

    // --- densify ---
    let mb = scalegnn::sampling::induce_rescaled(
        &data.adj,
        &sampler.sample(0),
        sampler.inclusion_prob(),
    );
    let mut buf = vec![0.0f32; b * b];
    println!(
        "{}",
        bench("densify 1024x1024 adjacency", 3, 50, || {
            densify_into(&mb.adj, &mut buf);
            std::hint::black_box(buf[0]);
        })
        .report()
    );

    // --- collectives ---
    for (elems, label) in [(65536usize, "256 KB"), (1 << 20, "4 MB")] {
        let grid = Grid4D::new(1, 8, 1, 1);
        let world = Arc::new(CommWorld::new(grid));
        let world2 = world.clone();
        let r = bench(&format!("8-thread all-reduce {label}"), 2, 20, move || {
            let world = world2.clone();
            let mut hs = vec![];
            for rank in 0..8 {
                let w = world.clone();
                hs.push(std::thread::spawn(move || {
                    let mut v = vec![rank as f32; elems];
                    w.all_reduce(rank, Axis::X, &mut v, Precision::Fp32);
                    std::hint::black_box(v[0]);
                }));
            }
            for h in hs {
                h.join().unwrap();
            }
        });
        println!("{}", r.report());
    }

    // --- local GEMM (rust) vs PJRT pallas kernel ---
    let mut rng = Rng::new(1);
    let a = Mat::randn(512, 128, &mut rng, 1.0);
    let bm = Mat::randn(128, 128, &mut rng, 1.0);
    println!(
        "{}",
        bench("rust gemm 512x128x128", 3, 50, || {
            std::hint::black_box(a.matmul(&bm).data[0]);
        })
        .report()
    );
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if let Ok(rt) = Runtime::open(&dir) {
        let exe = rt.load("local_gemm_512x128x128").unwrap();
        let la = lit_f32(&a.data, &[512, 128]).unwrap();
        let lb = lit_f32(&bm.data, &[128, 128]).unwrap();
        println!(
            "{}",
            bench("pjrt pallas gemm 512x128x128", 3, 50, || {
                std::hint::black_box(exe.run(&[la.clone(), lb.clone()]).unwrap().len());
            })
            .report()
        );

        // --- PJRT fused train step (products_sim shape) ---
        let meta = rt.model("products_sim").unwrap().clone();
        let step_exe = rt.load("train_step_products_sim").unwrap();
        let mut maker = BatchMaker::new(
            data.clone(),
            scalegnn::sampling::SamplerKind::ScaleGnnUniform,
            b,
            meta.edge_cap,
            3,
            7,
        );
        let bd = maker.make(0);
        let dims = scalegnn::trainer::meta_to_dims(&meta);
        let params = scalegnn::model::init_params(&dims, 0);
        let e = meta.edge_cap;
        let mut inputs = vec![
            scalegnn::runtime::lit_i32(&bd.src, &[e]).unwrap(),
            scalegnn::runtime::lit_i32(&bd.dst, &[e]).unwrap(),
            lit_f32(&bd.val, &[e]).unwrap(),
            lit_f32(&bd.x, &[b, meta.d_in]).unwrap(),
            scalegnn::runtime::lit_i32(&bd.y, &[b]).unwrap(),
            lit_f32(&bd.wmask, &[b]).unwrap(),
            scalegnn::runtime::lit_u32(&[1, 2], &[2]).unwrap(),
            xla::Literal::scalar(1e-2f32),
            xla::Literal::scalar(0.0f32),
        ];
        for _ in 0..3 {
            for (p, s) in params.iter().zip(&meta.param_shapes) {
                inputs.push(lit_f32(&p.data, s).unwrap());
            }
        }
        println!(
            "{}",
            bench("pjrt fused train step (B=1024, d_h=128, L=3, sparse)", 2, 10, || {
                std::hint::black_box(step_exe.run(&inputs).unwrap().len());
            })
            .report()
        );
    } else {
        println!("(artifacts not built; skipping PJRT benches)");
    }

    sampling_sweep_section();
    e2e_overlap_section();
    session_overhead_section();
    checkpoint_throughput_section();
    transport_section();
    recovery_latency_section();

    write_kernel_json(&records);
}

/// `iters` timed X-axis fp32 all-reduces on one rank of a 2-rank world,
/// after one warmup op that also synchronizes the ranks; returns seconds
/// per op.
fn timed_reduces(w: &CommWorld, rank: usize, elems: usize, iters: usize) -> f64 {
    let mut v = vec![rank as f32 + 1.0; elems];
    w.all_reduce(rank, Axis::X, &mut v, Precision::Fp32);
    let t0 = std::time::Instant::now();
    for _ in 0..iters {
        w.all_reduce(rank, Axis::X, &mut v, Precision::Fp32);
    }
    std::hint::black_box(v[0]);
    t0.elapsed().as_secs_f64() / iters as f64
}

/// Both ranks of `grid` reduce concurrently — through one shared
/// in-process world when `ep` is `None`, else each through its own
/// socket connection to the coordinator at `ep`.  Returns the slowest
/// rank's per-op seconds.
fn transport_pair_step_s(grid: Grid4D, ep: Option<&Endpoint>, elems: usize, iters: usize) -> f64 {
    let mut hs: Vec<std::thread::JoinHandle<f64>> = Vec::new();
    match ep {
        None => {
            let world = Arc::new(CommWorld::new(grid));
            for rank in 0..grid.world_size() {
                let w = world.clone();
                hs.push(std::thread::spawn(move || timed_reduces(&w, rank, elems, iters)));
            }
        }
        Some(ep) => {
            for rank in 0..grid.world_size() {
                let ep = ep.clone();
                hs.push(std::thread::spawn(move || {
                    let w = CommWorld::connect(grid, rank, &ep).expect("rank connect");
                    timed_reduces(&w, rank, elems, iters)
                }));
            }
        }
    }
    hs.into_iter().map(|h| h.join().unwrap()).fold(0.0, f64::max)
}

/// Transport-backend comparison (EXPERIMENTS.md §Transport): per-op
/// latency and effective payload bandwidth of a 2-rank fp32 all-reduce
/// through the in-process engine, a Unix-socket coordinator world and a
/// TCP-loopback coordinator world.  Emits `BENCH_transport.json`.
fn transport_section() {
    use scalegnn::comm::{CoordConfig, Coordinator};
    use scalegnn::util::json::{obj, Json};

    println!("--- transport backends (2-rank all-reduce, fp32) ---");
    let grid = Grid4D::new(1, 2, 1, 1);
    let mut entries: Vec<Json> = Vec::new();
    for &(elems, iters) in &[(1usize << 10, 200usize), (1 << 14, 100), (1 << 18, 30)] {
        for backend in ["inproc", "uds", "tcp"] {
            let step_s = if backend == "inproc" {
                transport_pair_step_s(grid, None, elems, iters)
            } else {
                let ep = if backend == "uds" {
                    Endpoint::Unix(std::env::temp_dir().join(format!(
                        "sgnn_bench_{}_{elems}.sock",
                        std::process::id()
                    )))
                } else {
                    Endpoint::Tcp("127.0.0.1:0".to_string())
                };
                let coord =
                    Coordinator::bind(grid, &ep, CoordConfig::default()).expect("coord bind");
                let ep = coord.endpoint().clone();
                let h = coord.spawn();
                let s = transport_pair_step_s(grid, Some(&ep), elems, iters);
                let failure = h.join().expect("coordinator thread").expect("coordinator run");
                assert!(failure.is_none(), "bench world failed: {failure:?}");
                s
            };
            let mib = (elems * 4) as f64 / (1 << 20) as f64;
            println!(
                "all-reduce {elems:>7} elems  {backend:>6}: {:>10}/op  ({:.1} MiB/s payload)",
                fmt_time(step_s),
                mib / step_s
            );
            entries.push(obj(vec![
                ("backend", Json::from(backend)),
                ("elems", Json::from(elems)),
                ("payload_bytes", Json::from(elems * 4)),
                ("iters", Json::from(iters)),
                ("step_s", Json::from(step_s)),
                ("payload_mib_per_s", Json::from(mib / step_s)),
            ]));
        }
    }
    let doc = obj(vec![
        (
            "what",
            Json::from(
                "2-rank X-axis fp32 all-reduce through each comm transport: shared-memory \
                 in-process engine vs Unix-socket vs TCP-loopback coordinator worlds \
                 (per-op latency after one warmup, payload bandwidth = elems*4B / op)",
            ),
        ),
        ("sweep", Json::Arr(entries)),
    ]);
    match std::fs::write("BENCH_transport.json", doc.to_string() + "\n") {
        Ok(()) => println!("wrote BENCH_transport.json\n"),
        Err(e) => eprintln!("could not write BENCH_transport.json: {e}\n"),
    }
}

/// Fault-tolerance latency (EXPERIMENTS.md §Chaos): how far past the
/// configured deadline a silent rank is actually diagnosed, how long a
/// world takes to (re-)form on each transport, and what a mid-run rank
/// kill plus snapshot replay costs end to end against the clean run.
/// Emits `BENCH_recovery.json`.
fn recovery_latency_section() {
    use scalegnn::comm::{CoordConfig, Coordinator, TransportTuning};
    use scalegnn::session::{self, BackendKind, FaultSpec, RunSpec};
    use scalegnn::util::json::{obj, Json};

    println!("--- recovery latency (2-rank worlds) ---");
    let grid = Grid4D::new(1, 2, 1, 1);

    // Stall-detection latency: rank 1 never contributes, so rank 0's
    // deadline expires and poisons the group with a `Stalled` origin.
    // The interesting number is the slop past the configured budget.
    let mut detection: Vec<Json> = Vec::new();
    for &deadline_ms in &[50u32, 100, 200] {
        let tuning =
            TransportTuning { wait_timeout_ms: Some(deadline_ms), ..Default::default() };
        let world = Arc::new(CommWorld::with_tuning(grid, 1 << 10, &tuning, None));
        let h = std::thread::spawn(move || {
            let mut v = vec![1.0f32; 256];
            let t0 = std::time::Instant::now();
            let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                world.all_reduce(0, Axis::X, &mut v, Precision::Fp32);
            }));
            assert!(r.is_err(), "a silent peer must poison the wait, not complete it");
            t0.elapsed().as_secs_f64()
        });
        let detect_s = h.join().expect("stall probe thread");
        let slop_s = detect_s - deadline_ms as f64 / 1e3;
        println!(
            "stall detection, {deadline_ms:>4} ms deadline: diagnosed in {} ({} past budget)",
            fmt_time(detect_s),
            fmt_time(slop_s.max(0.0))
        );
        detection.push(obj(vec![
            ("deadline_ms", Json::from(deadline_ms as usize)),
            ("detect_s", Json::from(detect_s)),
            ("slop_s", Json::from(slop_s)),
        ]));
    }

    // World (re-)formation: what a recovery pays before the first replayed
    // step — construct the world, bring every rank up, and complete one
    // synchronizing collective.
    let reform_inproc = || -> f64 {
        let t0 = std::time::Instant::now();
        let world = Arc::new(CommWorld::new(grid));
        let hs: Vec<_> = (0..grid.world_size())
            .map(|rank| {
                let w = world.clone();
                std::thread::spawn(move || {
                    let mut v = vec![rank as f32; 64];
                    w.all_reduce(rank, Axis::X, &mut v, Precision::Fp32);
                })
            })
            .collect();
        for h in hs {
            h.join().unwrap();
        }
        t0.elapsed().as_secs_f64()
    };
    let reform_uds = || -> f64 {
        let sock = std::env::temp_dir()
            .join(format!("sgnn_bench_reform_{}.sock", std::process::id()));
        let t0 = std::time::Instant::now();
        let coord = Coordinator::bind(grid, &Endpoint::Unix(sock), CoordConfig::default())
            .expect("coord bind");
        let ep = coord.endpoint().clone();
        let ch = coord.spawn();
        let hs: Vec<_> = (0..grid.world_size())
            .map(|rank| {
                let ep = ep.clone();
                std::thread::spawn(move || {
                    let w = CommWorld::connect(grid, rank, &ep).expect("rank connect");
                    let mut v = vec![rank as f32; 64];
                    w.all_reduce(rank, Axis::X, &mut v, Precision::Fp32);
                })
            })
            .collect();
        for h in hs {
            h.join().unwrap();
        }
        let failure = ch.join().expect("coordinator thread").expect("coordinator run");
        assert!(failure.is_none(), "reform world failed: {failure:?}");
        t0.elapsed().as_secs_f64()
    };
    let mut reform: Vec<Json> = Vec::new();
    let mut push_reform = |backend: &str, probe: &dyn Fn() -> f64| {
        let samples: Vec<f64> = (0..5).map(|_| probe()).collect();
        let m = median(&samples);
        println!("world re-form, {backend:>6}: {} (bind + 2 ranks + first op)", fmt_time(m));
        reform.push(obj(vec![
            ("backend", Json::from(backend)),
            ("reform_s_median", Json::from(m)),
        ]));
    };
    push_reform("inproc", &reform_inproc);
    push_reform("uds", &reform_uds);

    // Kill + replay, end to end: a mid-run rank death on the session
    // backend vs the identical clean run.  The overhead is detection +
    // re-formation + the replayed steps.
    let steps = 30u64;
    let run = |fault: bool, rep: usize| -> (f64, session::RunReport) {
        let dir = std::env::temp_dir().join(format!(
            "sgnn_bench_recovery_{}_{}_{rep}",
            std::process::id(),
            fault
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let mut spec = RunSpec::new(BackendKind::Pmm, "tiny")
            .grid(1, 2, 1, 1)
            .model(16, 2, 0.0)
            .steps(steps)
            .lr(5e-3)
            .checkpoint(dir.clone(), 5, 4);
        if fault {
            spec = spec.fault(FaultSpec::KillRank { rank: 1, step: 15 });
        }
        let t0 = std::time::Instant::now();
        let report = session::run_silent(&spec).expect("bench run");
        let s = t0.elapsed().as_secs_f64();
        let _ = std::fs::remove_dir_all(&dir);
        (s, report)
    };
    let mut clean_s = Vec::new();
    let mut faulted_s = Vec::new();
    let mut resumed_from = 0u64;
    for rep in 0..3 {
        clean_s.push(run(false, rep).0);
        let (s, report) = run(true, rep);
        faulted_s.push(s);
        resumed_from = report.failures[0].resumed_from_step.expect("recovered run");
    }
    let (cm, fm) = (median(&clean_s), median(&faulted_s));
    println!(
        "kill at step 15 of {steps}: clean {} vs faulted {} (+{}, replayed from step \
         {resumed_from})",
        fmt_time(cm),
        fmt_time(fm),
        fmt_time((fm - cm).max(0.0))
    );
    println!(
        "effective steps/s: clean {:.1} vs faulted {:.1}",
        steps as f64 / cm,
        steps as f64 / fm
    );

    let doc = obj(vec![
        (
            "what",
            Json::from(
                "fault-tolerance latency on 2-rank tiny worlds: stall-detection slop past \
                 the configured wait deadline, world (re-)formation time per transport, \
                 and the end-to-end cost of a mid-run rank kill + snapshot replay vs the \
                 clean run (medians of 3-5 samples)",
            ),
        ),
        ("stall_detection", Json::Arr(detection)),
        ("world_reform", Json::Arr(reform)),
        (
            "kill_replay",
            obj(vec![
                ("steps", Json::from(steps as usize)),
                ("kill_step", Json::from(15usize)),
                ("resumed_from_step", Json::from(resumed_from as usize)),
                ("clean_s_median", Json::from(cm)),
                ("faulted_s_median", Json::from(fm)),
                ("recovery_overhead_s", Json::from(fm - cm)),
                ("clean_steps_per_s", Json::from(steps as f64 / cm)),
                ("faulted_steps_per_s", Json::from(steps as f64 / fm)),
            ]),
        ),
    ]);
    match std::fs::write("BENCH_recovery.json", doc.to_string() + "\n") {
        Ok(()) => println!("wrote BENCH_recovery.json\n"),
        Err(e) => eprintln!("could not write BENCH_recovery.json: {e}\n"),
    }
}

/// Sampling fast-path sweep (EXPERIMENTS.md §Sampling): sort-free
/// workspace induction vs the pre-fast-path reference
/// (`induce_rescaled_reference`: triple list + sorting `from_triples` +
/// allocating transpose), across batch sizes and graph densities, serial
/// and parallel.  Emits `BENCH_sampling.json`; the acceptance bar is a
/// >= 2x single-thread speedup on the largest swept batch.
fn sampling_sweep_section() {
    use scalegnn::sampling::{
        induce_rescaled_into_threads, induce_rescaled_reference, InduceWorkspace, MiniBatch,
    };
    use scalegnn::util::json::{obj, Json};

    println!("--- sampling fast path (sort-free induction vs reference) ---");
    let graphs = [
        ("rmat13_ef16", generate::rmat(13, 16, 7).gcn_normalize()),
        ("rmat12_ef64", generate::rmat(12, 64, 9).gcn_normalize()),
    ];
    let par_t = pool::num_threads().max(2);
    let mut entries: Vec<Json> = Vec::new();
    for (gname, g) in &graphs {
        for &batch in &[256usize, 1024, 4096] {
            if batch > g.rows {
                continue;
            }
            let sampler = UniformVertexSampler::new(g.rows, batch, 42);
            let p = sampler.inclusion_prob();
            // rotate through a few samples so no path benefits from a
            // warm single working set
            let samples: Vec<Vec<u32>> = (0..8u64).map(|t| sampler.sample(t)).collect();
            let shape = format!("{gname} nnz={} B={batch}", g.nnz());

            let mut i = 0usize;
            let r_ref = bench(&format!("induce reference   {shape}"), 2, 15, || {
                let mb = induce_rescaled_reference(g, &samples[i % 8], p);
                i += 1;
                std::hint::black_box(mb.adj.nnz());
            });
            println!("{}", r_ref.report());

            let mut ws = InduceWorkspace::new();
            let mut out = MiniBatch::default();
            let mut i = 0usize;
            let r_fast = bench(&format!("induce fast t=1    {shape}"), 2, 15, || {
                induce_rescaled_into_threads(g, &samples[i % 8], p, true, 1, &mut ws, &mut out);
                i += 1;
                std::hint::black_box(out.adj.nnz());
            });
            println!("{}", r_fast.report());
            println!(
                "    -> sort-free speedup vs reference (t=1): {:.2}x",
                r_ref.mean_s / r_fast.mean_s
            );

            // the parallel row is only honest when the work estimate
            // actually engages the thread pool (small batches run the
            // identical inline path regardless of the requested count)
            let engages = batch * 512 >= pool::MIN_PARALLEL_WORK && par_t > 1;
            let (par_ns, par_speedup) = if engages {
                let mut i = 0usize;
                let r_par = bench(&format!("induce fast t={par_t}    {shape}"), 2, 15, || {
                    let s = &samples[i % 8];
                    induce_rescaled_into_threads(g, s, p, true, par_t, &mut ws, &mut out);
                    i += 1;
                    std::hint::black_box(out.adj.nnz());
                });
                println!("{}", r_par.report());
                println!(
                    "    -> parallel speedup vs reference: {:.2}x\n",
                    r_ref.mean_s / r_par.mean_s
                );
                (
                    Json::from(r_par.mean_s * 1e9),
                    Json::from(r_ref.mean_s / r_par.mean_s),
                )
            } else {
                println!("    (B={batch} is below the parallel work threshold; inline path only)\n");
                (Json::Null, Json::Null)
            };

            entries.push(obj(vec![
                ("graph", Json::from(*gname)),
                ("nnz", Json::from(g.nnz())),
                ("batch", Json::from(batch)),
                ("reference_ns", Json::from(r_ref.mean_s * 1e9)),
                ("fast_serial_ns", Json::from(r_fast.mean_s * 1e9)),
                ("fast_parallel_ns", par_ns),
                ("parallel_threads", if engages { Json::from(par_t) } else { Json::Null }),
                ("speedup_serial_vs_reference", Json::from(r_ref.mean_s / r_fast.mean_s)),
                ("speedup_parallel_vs_reference", par_speedup),
            ]));
        }
    }
    let doc = obj(vec![
        (
            "what",
            Json::from(
                "mini-batch induction: reference (triple sort + allocating transpose) \
                 vs sort-free workspace fast path, serial and parallel",
            ),
        ),
        ("sweep", Json::Arr(entries)),
    ]);
    match std::fs::write("BENCH_sampling.json", doc.to_string() + "\n") {
        Ok(()) => println!("wrote BENCH_sampling.json\n"),
        Err(e) => eprintln!("could not write BENCH_sampling.json: {e}\n"),
    }
}

/// Session-API overhead: the same tiny PMM run through the legacy direct
/// rank-thread loop and through `session::run`, emitting
/// `BENCH_session.json`.  The session layer adds only spec validation at
/// prepare time and one channel send per step, so the per-step medians
/// must agree within noise (asserted loosely downstream, recorded here).
fn session_overhead_section() {
    use scalegnn::model::GcnDims;
    use scalegnn::pmm::{PmmCtx, PmmGcn};
    use scalegnn::session::{self, BackendKind, RunSpec};
    use scalegnn::util::json::{arr_f64, obj, Json};

    let grid = Grid4D::new(1, 2, 2, 2);
    let steps = 30u64;
    let reps = 5usize;

    let legacy_run = || -> f64 {
        // timer covers dataset load + world setup + run, matching what
        // session::run's prepare() does on the other side
        let t0 = std::time::Instant::now();
        let data = Arc::new(datasets::load("tiny").unwrap());
        let ds = datasets::spec("tiny").unwrap();
        let dims = GcnDims {
            d_in: ds.planted.d_in,
            d_h: 16,
            d_out: ds.planted.classes,
            layers: 2,
            dropout: 0.0,
            weight_decay: 0.0,
        };
        let batch = ds.batch;
        let world = Arc::new(CommWorld::new(grid));
        let mut handles = vec![];
        for r in 0..grid.world_size() {
            let w = world.clone();
            let d = data.clone();
            handles.push(std::thread::spawn(move || {
                let ctx = PmmCtx::new(grid, r, &w, Precision::Fp32);
                let mut eng = PmmGcn::new(ctx, dims, batch, d, 42);
                for s in 0..steps {
                    std::hint::black_box(eng.train_step(s, 5e-3).loss);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        t0.elapsed().as_secs_f64() / steps as f64
    };
    let session_run = || -> f64 {
        let spec = RunSpec::new(BackendKind::Pmm, "tiny")
            .grid(1, 2, 2, 2)
            .model(16, 2, 0.0)
            .steps(steps)
            .lr(5e-3);
        let t0 = std::time::Instant::now();
        let report = session::run_silent(&spec).unwrap();
        std::hint::black_box(report.final_loss);
        t0.elapsed().as_secs_f64() / steps as f64
    };

    let mut legacy = Vec::with_capacity(reps);
    let mut sess = Vec::with_capacity(reps);
    for _ in 0..reps {
        legacy.push(legacy_run());
        sess.push(session_run());
    }
    let lm = median(&legacy);
    let sm = median(&sess);
    println!(
        "session entry overhead: legacy {} vs session {} per step ({:+.1}%)",
        fmt_time(lm),
        fmt_time(sm),
        (sm - lm) / lm * 100.0
    );
    let doc = obj(vec![
        (
            "what",
            Json::from("tiny PMM engine, 1x2x2x2 grid, 30 steps/run, 5 runs each entry"),
        ),
        ("legacy_step_s_median", Json::from(lm)),
        ("session_step_s_median", Json::from(sm)),
        ("overhead_frac", Json::from((sm - lm) / lm)),
        ("legacy_step_s_samples", arr_f64(&legacy)),
        ("session_step_s_samples", arr_f64(&sess)),
    ]);
    match std::fs::write("BENCH_session.json", doc.to_string() + "\n") {
        Ok(()) => println!("wrote BENCH_session.json\n"),
        Err(e) => eprintln!("could not write BENCH_session.json: {e}\n"),
    }
}

/// Checkpoint-subsystem throughput: atomic save and validated restore
/// latency across model sizes, plus the end-to-end per-step training
/// overhead of snapshotting every 10 and every 50 steps on the tiny PMM
/// engine.  Emits `BENCH_checkpoint.json`.
fn checkpoint_throughput_section() {
    use scalegnn::checkpoint::{self, Snapshot};
    use scalegnn::session::{self, BackendKind, RunSpec};
    use scalegnn::util::json::{obj, Json};

    println!("--- checkpoint save/restore throughput ---");
    let dir = std::env::temp_dir().join(format!("scalegnn_bench_ckpt_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();

    // save/restore latency vs model size (params + both Adam moments,
    // i.e. 12 bytes per element on disk plus header/table)
    let mut sizes: Vec<Json> = Vec::new();
    for &elems in &[1usize << 16, 1 << 20, 1 << 22] {
        let tensor: Vec<f32> = (0..elems).map(|i| (i as f32 * 0.37).sin()).collect();
        let snap = Snapshot::from_flat(
            1,
            42,
            0xBEEF,
            vec![tensor.clone()],
            vec![tensor.clone()],
            vec![tensor],
            1.0,
        );
        let bytes = snap.encode().len();
        let mib = bytes as f64 / (1 << 20) as f64;
        let iters = if elems >= 1 << 22 { 4 } else { 10 };
        let r_save = bench(&format!("ckpt save    {elems} elems ({mib:.1} MiB)"), 1, iters, || {
            std::hint::black_box(checkpoint::save(&dir, "bench", &snap).unwrap());
        });
        println!("{}", r_save.report());
        let path = checkpoint::path_for(&dir, "bench", 1);
        let r_load = bench(&format!("ckpt restore {elems} elems ({mib:.1} MiB)"), 1, iters, || {
            std::hint::black_box(checkpoint::load(&path).unwrap().step);
        });
        println!("{}", r_load.report());
        sizes.push(obj(vec![
            ("elements", Json::from(elems)),
            ("file_bytes", Json::from(bytes)),
            ("save_s", Json::from(r_save.mean_s)),
            ("restore_s", Json::from(r_load.mean_s)),
            ("save_mib_per_s", Json::from(mib / r_save.mean_s)),
            ("restore_mib_per_s", Json::from(mib / r_load.mean_s)),
        ]));
    }

    // end-to-end overhead: the same tiny PMM run with and without a
    // snapshot cadence (every = 0 disables checkpointing)
    let per_step = |every: u64| -> f64 {
        let steps = 50u64;
        let mut spec = RunSpec::new(BackendKind::Pmm, "tiny")
            .grid(1, 2, 1, 1)
            .model(16, 2, 0.0)
            .steps(steps)
            .lr(5e-3);
        if every > 0 {
            spec = spec.checkpoint(dir.join(format!("every{every}")), every, 2);
        }
        let t0 = std::time::Instant::now();
        let report = session::run_silent(&spec).unwrap();
        std::hint::black_box(report.final_loss);
        t0.elapsed().as_secs_f64() / steps as f64
    };
    let reps = 3usize;
    let med = |every: u64| -> f64 {
        let samples: Vec<f64> = (0..reps).map(|_| per_step(every)).collect();
        median(&samples)
    };
    let base = med(0);
    let every10 = med(10);
    let every50 = med(50);
    println!(
        "train overhead: baseline {}/step, every-10 {}/step ({:+.1}%), every-50 {}/step ({:+.1}%)",
        fmt_time(base),
        fmt_time(every10),
        (every10 - base) / base * 100.0,
        fmt_time(every50),
        (every50 - base) / base * 100.0,
    );

    let doc = obj(vec![
        (
            "what",
            Json::from(
                "versioned CRC32 snapshot format: atomic save + validated restore latency \
                 vs model size, and per-step overhead of checkpoint cadences on the tiny \
                 PMM engine (1x2x1x1, 50 steps, median of 3 runs)",
            ),
        ),
        ("sizes", Json::Arr(sizes)),
        (
            "train_overhead",
            obj(vec![
                ("baseline_step_s", Json::from(base)),
                ("every10_step_s", Json::from(every10)),
                ("every50_step_s", Json::from(every50)),
                ("every10_overhead_frac", Json::from((every10 - base) / base)),
                ("every50_overhead_frac", Json::from((every50 - base) / base)),
            ]),
        ),
    ]);
    match std::fs::write("BENCH_checkpoint.json", doc.to_string() + "\n") {
        Ok(()) => println!("wrote BENCH_checkpoint.json\n"),
        Err(e) => eprintln!("could not write BENCH_checkpoint.json: {e}\n"),
    }
    let _ = std::fs::remove_dir_all(&dir);
}
