//! Microbenchmarks of the Layer-3 hot paths (perf-pass instrumentation,
//! EXPERIMENTS.md §Perf): Algorithm 2 sampling, dense-ification, literal
//! packing, the PJRT train step, shared-memory collectives, and the local
//! GEMM kernels.

use std::path::PathBuf;
use std::sync::Arc;

use scalegnn::comm::{CommWorld, Precision};
use scalegnn::graph::{datasets, partition_2d};
use scalegnn::grid::{Axis, Grid4D};
use scalegnn::runtime::{lit_f32, Runtime};
use scalegnn::sampling::{densify_into, DistributedSubgraphBuilder, UniformVertexSampler};
use scalegnn::tensor::Mat;
use scalegnn::trainer::batch::BatchMaker;
use scalegnn::util::rng::Rng;
use scalegnn::util::stats::bench;

fn main() {
    println!("=== Layer-3 microbenchmarks ===\n");
    let data = Arc::new(datasets::load("products_sim").unwrap());
    let spec = datasets::spec("products_sim").unwrap();
    let b = spec.batch;

    // --- Algorithm 2 (single shard = whole graph) ---
    let sampler = UniformVertexSampler::new(data.n, b, 42);
    let shard = partition_2d(&data.adj, 1, 1).remove(0);
    let mut builder = DistributedSubgraphBuilder::new(sampler.clone(), shard);
    let mut step = 0u64;
    println!(
        "{}",
        bench("alg2 subgraph build (131k graph, B=1024)", 3, 30, || {
            let out = builder.build(step);
            step += 1;
            std::hint::black_box(out.adj.nnz());
        })
        .report()
    );

    // 2x2 sharded build (per-rank work)
    let shards = partition_2d(&data.adj, 2, 2);
    let mut builders: Vec<_> = shards
        .into_iter()
        .map(|s| DistributedSubgraphBuilder::new(sampler.clone(), s))
        .collect();
    let mut step = 0u64;
    println!(
        "{}",
        bench("alg2 per-rank build (2x2 shard grid)", 3, 30, || {
            for bu in builders.iter_mut() {
                std::hint::black_box(bu.build(step).adj.nnz());
            }
            step += 1;
        })
        .report()
    );

    // --- raw uniform sample ---
    let mut step = 0u64;
    println!(
        "{}",
        bench("uniform sample B=1024 of N=131k (sorted)", 3, 100, || {
            std::hint::black_box(sampler.sample(step));
            step += 1;
        })
        .report()
    );

    // --- batch assembly (sampling + densify + gather) ---
    let mut maker = BatchMaker::new(
        data.clone(),
        scalegnn::sampling::SamplerKind::ScaleGnnUniform,
        b,
        16384,
        3,
        7,
    );
    let mut step = 0u64;
    println!(
        "{}",
        bench("full batch assembly (edges+features+labels)", 3, 20, || {
            std::hint::black_box(maker.make(step).val[0]);
            step += 1;
        })
        .report()
    );

    // --- densify ---
    let mb = scalegnn::sampling::induce_rescaled(&data.adj, &sampler.sample(0), sampler.inclusion_prob());
    let mut buf = vec![0.0f32; b * b];
    println!(
        "{}",
        bench("densify 1024x1024 adjacency", 3, 50, || {
            densify_into(&mb.adj, &mut buf);
            std::hint::black_box(buf[0]);
        })
        .report()
    );

    // --- collectives ---
    for (elems, label) in [(65536usize, "256 KB"), (1 << 20, "4 MB")] {
        let grid = Grid4D::new(1, 8, 1, 1);
        let world = Arc::new(CommWorld::new(grid));
        let world2 = world.clone();
        let r = bench(&format!("8-thread all-reduce {label}"), 2, 20, move || {
            let world = world2.clone();
            let mut hs = vec![];
            for rank in 0..8 {
                let w = world.clone();
                hs.push(std::thread::spawn(move || {
                    let mut v = vec![rank as f32; elems];
                    w.all_reduce(rank, Axis::X, &mut v, Precision::Fp32);
                    std::hint::black_box(v[0]);
                }));
            }
            for h in hs {
                h.join().unwrap();
            }
        });
        println!("{}", r.report());
    }

    // --- local GEMM (rust) vs PJRT pallas kernel ---
    let mut rng = Rng::new(1);
    let a = Mat::randn(512, 128, &mut rng, 1.0);
    let bm = Mat::randn(128, 128, &mut rng, 1.0);
    println!(
        "{}",
        bench("rust gemm 512x128x128", 3, 50, || {
            std::hint::black_box(a.matmul(&bm).data[0]);
        })
        .report()
    );
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if let Ok(rt) = Runtime::open(&dir) {
        let exe = rt.load("local_gemm_512x128x128").unwrap();
        let la = lit_f32(&a.data, &[512, 128]).unwrap();
        let lb = lit_f32(&bm.data, &[128, 128]).unwrap();
        println!(
            "{}",
            bench("pjrt pallas gemm 512x128x128", 3, 50, || {
                std::hint::black_box(exe.run(&[la.clone(), lb.clone()]).unwrap().len());
            })
            .report()
        );

        // --- PJRT fused train step (products_sim shape) ---
        let meta = rt.model("products_sim").unwrap().clone();
        let step_exe = rt.load("train_step_products_sim").unwrap();
        let mut maker = BatchMaker::new(
            data.clone(),
            scalegnn::sampling::SamplerKind::ScaleGnnUniform,
            b,
            meta.edge_cap,
            3,
            7,
        );
        let bd = maker.make(0);
        let dims = scalegnn::trainer::meta_to_dims(&meta);
        let params = scalegnn::model::init_params(&dims, 0);
        let e = meta.edge_cap;
        let mut inputs = vec![
            scalegnn::runtime::lit_i32(&bd.src, &[e]).unwrap(),
            scalegnn::runtime::lit_i32(&bd.dst, &[e]).unwrap(),
            lit_f32(&bd.val, &[e]).unwrap(),
            lit_f32(&bd.x, &[b, meta.d_in]).unwrap(),
            scalegnn::runtime::lit_i32(&bd.y, &[b]).unwrap(),
            lit_f32(&bd.wmask, &[b]).unwrap(),
            scalegnn::runtime::lit_u32(&[1, 2], &[2]).unwrap(),
            xla::Literal::scalar(1e-2f32),
            xla::Literal::scalar(0.0f32),
        ];
        for _ in 0..3 {
            for (p, s) in params.iter().zip(&meta.param_shapes) {
                inputs.push(lit_f32(&p.data, s).unwrap());
            }
        }
        println!(
            "{}",
            bench("pjrt fused train step (B=1024, d_h=128, L=3, sparse)", 2, 10, || {
                std::hint::black_box(step_exe.run(&inputs).unwrap().len());
            })
            .report()
        );
    } else {
        println!("(artifacts not built; skipping PJRT benches)");
    }
}
