//! Stub of the `xla` PJRT binding surface used by the coordinator.
//!
//! The real crate wraps libxla/PJRT, which is not available in the offline
//! build environment.  This stub keeps the whole coordinator compiling and
//! lets `Literal` packing/unpacking work as real host-side containers, but
//! `PjRtClient::cpu()` reports an unavailable backend, so every
//! artifact-executing path degrades to a clean runtime error that callers
//! already handle ("artifacts not built" / skipped benches and tests).

use std::fmt;

#[derive(Debug)]
pub struct XlaError(pub String);

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla: {}", self.0)
    }
}

impl std::error::Error for XlaError {}

pub type Result<T> = std::result::Result<T, XlaError>;

/// Element storage of a literal.
#[derive(Clone, Debug)]
enum LitData {
    F32(Vec<f32>),
    I32(Vec<i32>),
    U32(Vec<u32>),
    Tuple(Vec<Literal>),
}

/// Host-side tensor literal: typed element buffer + dims.  Fully functional
/// (this part of the binding is pure host memory).
#[derive(Clone, Debug)]
pub struct Literal {
    data: LitData,
    dims: Vec<i64>,
}

/// Types that can live in a `Literal`.
pub trait NativeType: Copy {
    fn wrap(data: Vec<Self>) -> LitDataToken;
    fn unwrap(lit: &Literal) -> Option<Vec<Self>>;
}

/// Opaque constructor token so `LitData` can stay private.
pub struct LitDataToken(LitData);

macro_rules! native {
    ($t:ty, $variant:ident) => {
        impl NativeType for $t {
            fn wrap(data: Vec<Self>) -> LitDataToken {
                LitDataToken(LitData::$variant(data))
            }
            fn unwrap(lit: &Literal) -> Option<Vec<Self>> {
                match &lit.data {
                    LitData::$variant(v) => Some(v.clone()),
                    _ => None,
                }
            }
        }
    };
}

native!(f32, F32);
native!(i32, I32);
native!(u32, U32);

impl Literal {
    /// 1-D literal from a slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        let n = data.len() as i64;
        Literal { data: T::wrap(data.to_vec()).0, dims: vec![n] }
    }

    /// Rank-0 f32 literal.
    pub fn scalar(v: f32) -> Literal {
        Literal { data: LitData::F32(vec![v]), dims: vec![] }
    }

    /// Tuple literal (what artifacts lowered with `return_tuple=True` yield).
    pub fn tuple(parts: Vec<Literal>) -> Literal {
        Literal { data: LitData::Tuple(parts), dims: vec![] }
    }

    pub fn element_count(&self) -> usize {
        match &self.data {
            LitData::F32(v) => v.len(),
            LitData::I32(v) => v.len(),
            LitData::U32(v) => v.len(),
            LitData::Tuple(v) => v.len(),
        }
    }

    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    /// Reinterpret with new dims (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let want: i64 = dims.iter().product();
        if matches!(self.data, LitData::Tuple(_)) {
            return Err(XlaError("cannot reshape a tuple literal".into()));
        }
        if want as usize != self.element_count() {
            return Err(XlaError(format!(
                "reshape: {} elements into dims {:?}",
                self.element_count(),
                dims
            )));
        }
        Ok(Literal { data: self.data.clone(), dims: dims.to_vec() })
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::unwrap(self).ok_or_else(|| XlaError("literal dtype mismatch".into()))
    }

    pub fn get_first_element<T: NativeType>(&self) -> Result<T> {
        let v = self.to_vec::<T>()?;
        v.first()
            .copied()
            .ok_or_else(|| XlaError("empty literal".into()))
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        match &self.data {
            LitData::Tuple(v) => Ok(v.clone()),
            _ => Err(XlaError("literal is not a tuple".into())),
        }
    }
}

/// Parsed HLO module handle.  `from_text_file` only checks readability; the
/// stub cannot compile or run HLO.
pub struct HloModuleProto {
    pub path: String,
}

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        std::fs::read_to_string(path)
            .map_err(|e| XlaError(format!("reading HLO text {path}: {e}")))?;
        Ok(HloModuleProto { path: path.to_string() })
    }
}

pub struct XlaComputation {
    pub path: String,
}

impl XlaComputation {
    pub fn from_proto(p: &HloModuleProto) -> XlaComputation {
        XlaComputation { path: p.path.clone() }
    }
}

/// PJRT device buffer handle (never constructed by the stub).
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(XlaError("PJRT backend not available (stub)".into()))
    }
}

impl std::ops::Index<usize> for PjRtBufferVec {
    type Output = PjRtBuffer;
    fn index(&self, i: usize) -> &PjRtBuffer {
        &self.0[i]
    }
}

/// One device's output buffers.
pub struct PjRtBufferVec(pub Vec<PjRtBuffer>);

/// Compiled executable handle (never successfully constructed by the stub).
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<PjRtBufferVec>> {
        Err(XlaError("PJRT backend not available (stub)".into()))
    }
}

/// PJRT client.  `cpu()` always fails in the stub: no native XLA runtime is
/// linked, so callers fall back to the pure-Rust compute backend.
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(XlaError(
            "PJRT CPU backend not available in this build (vendored xla stub; \
             artifacts cannot be executed)"
                .into(),
        ))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _c: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(XlaError("PJRT backend not available (stub)".into()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_and_reshape() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        assert_eq!(l.dims(), &[4]);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.dims(), &[2, 2]);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.reshape(&[3, 2]).is_err());
        assert!(r.to_vec::<i32>().is_err());
    }

    #[test]
    fn scalar_and_tuple() {
        let s = Literal::scalar(2.5);
        assert_eq!(s.get_first_element::<f32>().unwrap(), 2.5);
        let t = Literal::tuple(vec![s.clone(), Literal::vec1(&[1i32])]);
        let parts = t.to_tuple().unwrap();
        assert_eq!(parts.len(), 2);
        assert!(s.to_tuple().is_err());
    }

    #[test]
    fn client_is_unavailable() {
        let e = PjRtClient::cpu().unwrap_err();
        assert!(format!("{e}").contains("not available"));
    }
}
