//! Minimal, API-compatible subset of the `anyhow` crate, vendored so the
//! workspace builds with no crates.io access.
//!
//! Covers exactly what this repository uses: `Error`, `Result<T>`,
//! `anyhow!`, `bail!`, `ensure!`, and the `Context` extension trait with
//! context-chain printing via alternate `Display` (`{:#}`).

use std::fmt;

/// Error type: a chain of messages, outermost context first.
pub struct Error {
    /// `chain[0]` is the outermost (most recently attached) message; the
    /// last element is the root cause.
    chain: Vec<String>,
}

impl Error {
    /// Construct from a printable message (what `anyhow!` expands to).
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { chain: vec![m.to_string()] }
    }

    /// Attach an outer context message.
    pub fn context<C: fmt::Display>(mut self, c: C) -> Error {
        self.chain.insert(0, c.to_string());
        self
    }

    /// Iterate the chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The root cause message (innermost).
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` prints the whole context chain like real anyhow
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(|s| s.as_str()).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.join(": "))
    }
}

// Note: `Error` deliberately does NOT implement `std::error::Error`, which
// is what keeps this blanket conversion coherent (same trick as real
// anyhow).
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)` to results.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| e.into().context(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => { $crate::Error::msg(format!($msg)) };
    ($err:expr $(,)?) => { $crate::Error::msg($err) };
    ($fmt:expr, $($arg:tt)*) => { $crate::Error::msg(format!($fmt, $($arg)*)) };
}

#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => { return Err($crate::anyhow!($($arg)*)) };
}

#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing file")
    }

    #[test]
    fn display_plain_and_alternate() {
        let e: Error = Err::<(), _>(io_err())
            .with_context(|| "reading manifest".to_string())
            .unwrap_err();
        assert_eq!(format!("{e}"), "reading manifest");
        assert_eq!(format!("{e:#}"), "reading manifest: missing file");
    }

    #[test]
    fn anyhow_macro_accepts_expressions() {
        let s = String::from("boom");
        let e = anyhow!(s);
        assert_eq!(format!("{e}"), "boom");
        let e2 = anyhow!("literal");
        assert_eq!(format!("{e2}"), "literal");
    }

    #[test]
    fn macros_compose() {
        fn f(fail: bool) -> Result<u32> {
            ensure!(!fail, "failed with code {}", 7);
            Ok(1)
        }
        assert_eq!(f(false).unwrap(), 1);
        assert_eq!(format!("{}", f(true).unwrap_err()), "failed with code 7");
        fn g() -> Result<()> {
            bail!("bad {}", "state")
        }
        assert_eq!(format!("{}", g().unwrap_err()), "bad state");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<String> {
            let s = std::fs::read_to_string("/definitely/not/a/path")?;
            Ok(s)
        }
        assert!(f().is_err());
    }
}
