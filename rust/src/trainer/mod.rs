//! The 4D training coordinator (Layer 3 hot path).
//!
//! Each data-parallel group is a worker thread owning its own PJRT runtime
//! (the CPU client is not `Send`), executing the AOT train-step artifacts:
//!
//! * `dp = 1` — the **fused** path: one `train_step_*` executable performs
//!   forward, backward and Adam with donated state buffers; parameters stay
//!   device-side as literals between steps (Python is never involved).
//! * `dp > 1` — the **synchronous DP** path: `grad_step_*` produces raw
//!   gradients, the coordinator all-reduces them across groups
//!   (`comm::CommWorld`, §IV-A), and `adam_apply_*` applies the update —
//!   bitwise-identical replicas by construction.
//!
//! **Prefetch pipeline (§V-A):** a dedicated sampler thread per group runs
//! Algorithm 1 for step `t+1` while step `t` executes, handing batches over
//! a bounded channel (the CUDA-event synchronization of the paper maps to
//! the channel receive).  Disabling it (`prefetch = false`) reproduces the
//! Fig. 5 baseline where sampling sits on the critical path.

pub mod batch;
pub mod eval;

use std::path::PathBuf;
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};

use crate::checkpoint::{CheckpointManager, CheckpointPolicy, Snapshot};
use crate::comm::{CommWorld, Precision};
use crate::graph::store::OocGraph;
use crate::graph::{datasets, Dataset};
use crate::grid::{Axis, Grid4D};
use crate::model::GcnDims;
use crate::runtime::{lit_f32, lit_i32, lit_u32, scalar_f32, to_f32, ModelMeta, Runtime};
use crate::sampling::{SamplerKind, UniformVertexSampler};
use crate::tensor::Mat;
use crate::util::rng::splitmix64;
use batch::{BatchData, BatchMaker};

/// Training configuration.
#[derive(Clone, Debug)]
pub struct TrainConfig {
    /// Registry dataset name.
    pub dataset: String,
    /// Sampling algorithm (ScaleGNN uniform or a Table I baseline).
    pub sampler: SamplerKind,
    /// number of data-parallel groups (Gd)
    pub dp: usize,
    /// Adam learning rate.
    pub lr: f32,
    /// Sampling / parameter-init seed.
    pub seed: u64,
    /// overlap sampling with training (§V-A)
    pub prefetch: bool,
    /// Directory of the AOT PJRT artifacts.
    pub artifacts: PathBuf,
    /// hard step cap (0 = until target/max_epochs)
    pub max_steps: u64,
    /// Epoch cap when `max_steps` is 0.
    pub max_epochs: usize,
    /// stop once full-graph test accuracy reaches this (paper's E2E metric)
    pub target_acc: Option<f32>,
    /// evaluate every k epochs
    pub eval_every_epochs: usize,
    /// Row-chunk workers of the shared-memory full-graph evaluation.
    pub eval_threads: usize,
    /// Per-epoch stderr progress logging.
    pub verbose: bool,
    /// use BF16 payloads for the DP gradient all-reduce (§V-B)
    pub bf16_dp: bool,
    /// §V-D gradient bucketing: issue every per-tensor DP bucket through
    /// the nonblocking collective engine before draining (default), vs
    /// one blocking all-reduce per tensor
    pub overlap: bool,
    /// Periodic snapshot policy (`None` = no checkpointing); each group
    /// saves under tag `ref-g{group}`.
    pub checkpoint: Option<CheckpointPolicy>,
    /// Resume from the newest snapshot step every group has a valid
    /// snapshot for (requires `checkpoint`).
    pub resume: bool,
}

impl TrainConfig {
    /// Sensible defaults for a quick run on `dataset` with `sampler`.
    pub fn quick(dataset: &str, sampler: SamplerKind) -> TrainConfig {
        TrainConfig {
            dataset: dataset.to_string(),
            sampler,
            dp: 1,
            lr: 1e-2,
            seed: 42,
            prefetch: true,
            artifacts: PathBuf::from("artifacts"),
            max_steps: 0,
            max_epochs: 30,
            target_acc: None,
            eval_every_epochs: 1,
            eval_threads: std::thread::available_parallelism()
                .map(|p| p.get())
                .unwrap_or(4),
            verbose: false,
            bf16_dp: false,
            overlap: true,
            checkpoint: None,
            resume: false,
        }
    }
}

/// Per-step timing breakdown (averaged over the run).
#[derive(Clone, Copy, Debug, Default)]
pub struct StepBreakdown {
    /// waiting on the sampler (≈0 with prefetch; full sampling cost without)
    pub sample_wait_s: f64,
    /// literal packing
    pub pack_s: f64,
    /// PJRT execution (fwd+bwd+opt or grad)
    pub exec_s: f64,
    /// DP gradient all-reduce (+ adam_apply on the dp>1 path)
    pub dp_comm_s: f64,
}

/// Result of a training run.
#[derive(Clone, Debug, Default)]
pub struct TrainReport {
    /// Steps executed.
    pub steps: u64,
    /// Whole epochs completed.
    pub epochs: usize,
    /// training wall-clock, excluding evaluation (§VI-C methodology)
    pub train_time_s: f64,
    /// Wall-clock spent in periodic full-graph evaluation.
    pub eval_time_s: f64,
    /// Loss of the final step.
    pub final_loss: f32,
    /// Best full-graph test accuracy seen at any evaluation.
    pub best_test_acc: f32,
    /// Best full-graph validation accuracy seen at any evaluation.
    pub best_val_acc: f32,
    /// train time at which the target accuracy was first reached
    pub time_to_target_s: Option<f64>,
    /// (step, loss) once per epoch (plus the final step).
    pub loss_curve: Vec<(u64, f32)>,
    /// (step, val_acc, test_acc) at each evaluation
    pub acc_curve: Vec<(u64, f32, f32)>,
    /// Mean per-step timing breakdown.
    pub breakdown: StepBreakdown,
}

/// One streamed training-step event: the progress feed `session::run`
/// turns into `StepReport`s.  Streaming is observation-only — the worker
/// never blocks on (or reacts to) the receiver, so a run with a progress
/// sender is bitwise identical to one without.
#[derive(Clone, Copy, Debug)]
pub struct StepEvent {
    /// 0-based step index.
    pub step: u64,
    /// Loss of this step.
    pub loss: f32,
    /// Sampled train accuracy (NaN where the path does not measure it).
    pub acc: f32,
    /// Wall-clock of the step, excluding evaluation.
    pub wall_s: f64,
    /// Full-graph (val, test) accuracy when this step evaluated.
    pub eval: Option<(f32, f32)>,
    /// Edges dropped from this step's batch because it exceeded the
    /// artifact's `edge_cap` (0 on paths without an edge cap).  Non-zero
    /// values are surfaced as a trainer warning and a `truncated_edges`
    /// session detail instead of being silently dropped.
    pub truncated: usize,
    /// Whether this is the last step of the run.
    pub done: bool,
}

/// Sending half of a [`StepEvent`] stream.
pub type ProgressSender = std::sync::mpsc::Sender<StepEvent>;

/// Convert artifact-manifest model metadata into reference-model dims.
pub fn meta_to_dims(m: &ModelMeta) -> GcnDims {
    GcnDims {
        d_in: m.d_in,
        d_h: m.d_h,
        d_out: m.d_out,
        layers: m.layers,
        dropout: m.dropout,
        weight_decay: 0.0,
    }
}

/// Spawn the §V-A prefetch pipeline: a sampler thread feeding a bounded(2)
/// channel.  Returns the receiving end plus the recycle sender the trainer
/// uses to hand spent [`BatchData`] shells back — with the shells
/// circulating, the sampler thread's steady-state `make()` allocates
/// nothing (double buffering in both directions).
fn spawn_prefetcher(
    mut maker: BatchMaker,
    start: u64,
    max_steps: u64,
) -> (Receiver<BatchData>, SyncSender<BatchData>) {
    let (tx, rx) = sync_channel::<BatchData>(2);
    let (free_tx, free_rx) = sync_channel::<BatchData>(4);
    std::thread::spawn(move || {
        for step in start..max_steps {
            // drain recycled shells first so `make` reuses their buffers
            while let Ok(spent) = free_rx.try_recv() {
                maker.recycle(spent);
            }
            let b = maker.make(step);
            if tx.send(b).is_err() {
                break; // trainer finished / dropped
            }
        }
    });
    (rx, free_tx)
}

struct PackedState {
    params: Vec<Vec<f32>>,
    m: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
    t: f32,
}

fn init_state(meta: &ModelMeta, seed: u64) -> PackedState {
    let dims = meta_to_dims(meta);
    let params: Vec<Vec<f32>> = crate::model::init_params(&dims, seed)
        .into_iter()
        .map(|m| m.data)
        .collect();
    let zeros: Vec<Vec<f32>> = params.iter().map(|p| vec![0.0; p.len()]).collect();
    PackedState { params, m: zeros.clone(), v: zeros, t: 0.0 }
}

fn state_literals(meta: &ModelMeta, st: &PackedState) -> Result<Vec<xla::Literal>> {
    let mut lits = Vec::with_capacity(3 * meta.n_params);
    for group in [&st.params, &st.m, &st.v] {
        for (data, shape) in group.iter().zip(&meta.param_shapes) {
            lits.push(lit_f32(data, shape)?);
        }
    }
    Ok(lits)
}

fn batch_literals(meta: &ModelMeta, b: &BatchData, seed: u64) -> Result<Vec<xla::Literal>> {
    let bb = meta.batch;
    let e = meta.edge_cap;
    let key = [
        (splitmix64(seed ^ b.step) >> 32) as u32,
        splitmix64(seed ^ b.step) as u32,
    ];
    Ok(vec![
        lit_i32(&b.src, &[e])?,
        lit_i32(&b.dst, &[e])?,
        lit_f32(&b.val, &[e])?,
        lit_f32(&b.x, &[bb, meta.d_in])?,
        lit_i32(&b.y, &[bb])?,
        lit_f32(&b.wmask, &[bb])?,
        lit_u32(&key, &[2])?,
    ])
}

/// Shared per-worker training loop.  `world` carries the DP communicator
/// when `cfg.dp > 1`; `resume_from` is this group's snapshot when the run
/// resumes (all groups must resume from the same step).
#[allow(clippy::too_many_arguments)]
fn worker_loop(
    cfg: &TrainConfig,
    data: Arc<Dataset>,
    meta: &ModelMeta,
    group: usize,
    world: Option<&CommWorld>,
    report: &mut TrainReport,
    progress: Option<ProgressSender>,
    resume_from: Option<Snapshot>,
) -> Result<()> {
    let rt = Runtime::open(&cfg.artifacts)?;
    let dims = meta_to_dims(meta);
    let steps_per_epoch = ((data.n / meta.batch).max(1)) as u64;
    let total_steps = if cfg.max_steps > 0 {
        cfg.max_steps
    } else {
        steps_per_epoch * cfg.max_epochs as u64
    };
    let group_seed = splitmix64(cfg.seed ^ (0xD0 + group as u64));
    let spec_hash = crate::checkpoint::state_hash(&[
        0x5245_4600, // backend tag "REF"
        cfg.seed,
        dims.state_signature(),
        meta.batch as u64,
        cfg.lr.to_bits() as u64,
        cfg.dp as u64,
        group as u64,
    ]);
    let ckpt = cfg
        .checkpoint
        .as_ref()
        .map(|p| CheckpointManager::new(p.clone(), &format!("ref-g{group}")));
    let maker =
        BatchMaker::new(data.clone(), cfg.sampler, meta.batch, meta.edge_cap, meta.layers, group_seed);

    // fused path artifacts vs DP decomposition artifacts
    let fused = cfg.dp == 1;
    let (step_exe, adam_exe) = if fused {
        (rt.load(&format!("train_step_{}", meta.name))?, None)
    } else {
        (
            rt.load(&format!("grad_step_{}", meta.name))?,
            Some(rt.load(&format!("adam_apply_{}", meta.name))?),
        )
    };

    let mut st = init_state(meta, cfg.seed);
    let mut start: u64 = 0;
    if let Some(snap) = &resume_from {
        snap.check_hash(spec_hash, &format!("reference group {group}"))?;
        if snap.tensors.len() != st.params.len()
            || snap.tensors.iter().zip(&st.params).any(|(s, p)| s.len() != p.len())
        {
            bail!("group {group}: snapshot tensor shapes do not match this model");
        }
        st.params = snap.tensors.clone();
        st.m = snap.m.clone();
        st.v = snap.v.clone();
        st.t = snap.t;
        start = snap.step;
    }
    if start >= total_steps {
        bail!(
            "group {group}: the snapshot already covers step {start} of {total_steps}; \
             nothing left to resume (raise max_steps to continue training)"
        );
    }
    // §V-A double buffering: with prefetch on, the maker moves to a sampler
    // thread that builds batch t+1 while step t executes (spent shells are
    // recycled back over the second channel); otherwise it runs inline on
    // the critical path (the Fig. 5 baseline).
    let (mut rx, mut inline_maker) = if cfg.prefetch {
        (Some(spawn_prefetcher(maker, start, total_steps)), None)
    } else {
        (None, Some(maker))
    };

    let np = meta.n_params;
    let mut train_time = 0.0f64;
    let mut eval_time = 0.0f64;
    let mut bd = StepBreakdown::default();
    let mut best_test = 0.0f32;
    let mut best_val = 0.0f32;
    let mut time_to_target = None;
    let mut last_loss = f32::NAN;
    let mut warned_truncation = false;
    // evaluation parameter buffers, allocated once and refilled per eval
    let mut eval_params: Vec<crate::tensor::Mat> = meta
        .param_shapes
        .iter()
        .map(|s| {
            let (r, c) = if s.len() == 2 { (s[0], s[1]) } else { (1, s[0]) };
            crate::tensor::Mat::zeros(r, c)
        })
        .collect();

    for step in start..total_steps {
        let t_step = Instant::now();
        // --- sample (or wait on the prefetcher) ---
        let t0 = Instant::now();
        let bdat = match (&mut rx, &mut inline_maker) {
            (Some((rx, _)), _) => rx.recv().map_err(|_| anyhow!("prefetcher died"))?,
            (None, Some(mk)) => mk.make(step),
            _ => unreachable!(),
        };
        bd.sample_wait_s += t0.elapsed().as_secs_f64();
        let truncated = bdat.truncated;
        if truncated > 0 && !warned_truncation {
            warned_truncation = true;
            eprintln!(
                "warning: [group {group}] step {step}: {truncated} edges dropped past \
                 edge_cap {} — the batch is inexact; rebuild the artifacts with a larger \
                 edge_cap (further occurrences stream as `truncated` step events)",
                meta.edge_cap
            );
        }

        // --- pack ---
        let t0 = Instant::now();
        let mut inputs = batch_literals(meta, &bdat, group_seed)?;
        bd.pack_s += t0.elapsed().as_secs_f64();

        // hand the spent shell back for buffer reuse (never blocks; a
        // full/closed recycle channel or finished prefetcher just drops it)
        match (&rx, &mut inline_maker) {
            (Some((_, free_tx)), _) => {
                let _ = free_tx.try_send(bdat);
            }
            (None, Some(mk)) => mk.recycle(bdat),
            _ => unreachable!(),
        }

        if fused {
            let t0 = Instant::now();
            inputs.push(xla::Literal::scalar(cfg.lr));
            inputs.push(xla::Literal::scalar(st.t));
            inputs.extend(state_literals(meta, &st)?);
            let outs = step_exe.run(&inputs)?;
            last_loss = scalar_f32(&outs[0])?;
            st.t = scalar_f32(&outs[2])?;
            for i in 0..np {
                st.params[i] = to_f32(&outs[3 + i])?;
                st.m[i] = to_f32(&outs[3 + np + i])?;
                st.v[i] = to_f32(&outs[3 + 2 * np + i])?;
            }
            bd.exec_s += t0.elapsed().as_secs_f64();
        } else {
            // grad
            let t0 = Instant::now();
            for (p, shape) in st.params.iter().zip(&meta.param_shapes) {
                inputs.push(lit_f32(p, shape)?);
            }
            let outs = step_exe.run(&inputs)?;
            last_loss = scalar_f32(&outs[0])?;
            let mut grads: Vec<Vec<f32>> =
                (0..np).map(|i| to_f32(&outs[2 + i])).collect::<Result<_>>()?;
            bd.exec_s += t0.elapsed().as_secs_f64();

            // DP all-reduce + mean (Fig. 8's DP component)
            let t0 = Instant::now();
            if let Some(w) = world {
                let gd = cfg.dp as f32;
                let prec = if cfg.bf16_dp { Precision::Bf16 } else { Precision::Fp32 };
                if cfg.overlap {
                    // §V-D gradient bucketing: stage every per-tensor
                    // bucket into the nonblocking engine, then drain —
                    // chunk reductions of bucket k proceed while buckets
                    // k+1.. are still being issued, and no rank stalls at
                    // a per-tensor rendezvous
                    let pending: Vec<crate::comm::PendingOp<'_>> = grads
                        .iter()
                        .map(|g| w.issue_all_reduce(group, Axis::Dp, g, prec))
                        .collect();
                    for (op, g) in pending.into_iter().zip(grads.iter_mut()) {
                        op.wait_into(g);
                        for v in g.iter_mut() {
                            *v /= gd;
                        }
                    }
                } else {
                    for g in grads.iter_mut() {
                        w.all_reduce(group, Axis::Dp, g, prec);
                        for v in g.iter_mut() {
                            *v /= gd;
                        }
                    }
                }
                let mut loss_buf = [last_loss];
                w.all_reduce(group, Axis::Dp, &mut loss_buf, Precision::Fp32);
                last_loss = loss_buf[0] / gd;
            }
            // adam_apply
            let adam = adam_exe.as_ref().unwrap();
            let mut ain = vec![xla::Literal::scalar(cfg.lr), xla::Literal::scalar(st.t)];
            for group_vals in [&st.params, &grads, &st.m, &st.v] {
                for (p, shape) in group_vals.iter().zip(&meta.param_shapes) {
                    ain.push(lit_f32(p, shape)?);
                }
            }
            let aouts = adam.run(&ain)?;
            st.t = scalar_f32(&aouts[0])?;
            for i in 0..np {
                st.params[i] = to_f32(&aouts[1 + i])?;
                st.m[i] = to_f32(&aouts[1 + np + i])?;
                st.v[i] = to_f32(&aouts[1 + 2 * np + i])?;
            }
            bd.dp_comm_s += t0.elapsed().as_secs_f64();
        }
        let step_wall = t_step.elapsed().as_secs_f64();
        train_time += step_wall;

        if let Some(mgr) = &ckpt {
            if mgr.should_save(step) {
                let snap = Snapshot::from_flat(
                    step + 1,
                    cfg.seed,
                    spec_hash,
                    st.params.clone(),
                    st.m.clone(),
                    st.v.clone(),
                    st.t,
                );
                mgr.save(&snap)
                    .with_context(|| format!("group {group}: saving the step-{step} snapshot"))?;
            }
        }

        if step % steps_per_epoch == 0 || step == total_steps - 1 {
            report.loss_curve.push((step, last_loss));
        }

        // --- periodic full-graph evaluation (group 0 computes; others sync) ---
        let mut evaled = None;
        let mut target_stop = false;
        let epoch_done = (step + 1) % (steps_per_epoch * cfg.eval_every_epochs as u64) == 0
            || step == total_steps - 1;
        if epoch_done {
            let t0 = Instant::now();
            for (m, p) in eval_params.iter_mut().zip(&st.params) {
                m.data.copy_from_slice(p);
            }
            let (val, test) =
                eval::full_graph_accuracy(&data, &dims, &eval_params, cfg.eval_threads);
            eval_time += t0.elapsed().as_secs_f64();
            best_test = best_test.max(test);
            best_val = best_val.max(val);
            evaled = Some((val, test));
            report.acc_curve.push((step + 1, val, test));
            if cfg.verbose && group == 0 {
                eprintln!(
                    "[{}] step {:>6} epoch {:>3} loss {:.4} val {:.4} test {:.4} ({:.1}s train)",
                    cfg.dataset,
                    step + 1,
                    (step + 1) / steps_per_epoch,
                    last_loss,
                    val,
                    test,
                    train_time
                );
            }
            if let Some(target) = cfg.target_acc {
                if test >= target && time_to_target.is_none() {
                    time_to_target = Some(train_time);
                }
                if test >= target {
                    target_stop = true;
                }
            }
        }
        report.steps = step + 1;
        if let Some(tx) = &progress {
            // observation only: a gone receiver must not end the run
            let _ = tx.send(StepEvent {
                step,
                loss: last_loss,
                acc: f32::NAN,
                wall_s: step_wall,
                eval: evaled,
                truncated,
                done: target_stop || step == total_steps - 1,
            });
        }
        if target_stop {
            break;
        }
    }

    // breakdown averages are over the steps *this* invocation executed
    // (absolute indices `start..report.steps` after a resume)
    let steps = report.steps.saturating_sub(start).max(1) as f64;
    report.epochs = (report.steps / steps_per_epoch) as usize;
    report.train_time_s = train_time;
    report.eval_time_s = eval_time;
    report.final_loss = last_loss;
    report.best_test_acc = best_test;
    report.best_val_acc = best_val;
    report.time_to_target_s = time_to_target;
    report.breakdown = StepBreakdown {
        sample_wait_s: bd.sample_wait_s / steps,
        pack_s: bd.pack_s / steps,
        exec_s: bd.exec_s / steps,
        dp_comm_s: bd.dp_comm_s / steps,
    };
    Ok(())
}

/// Run a training job per `cfg`; returns group 0's report.
pub fn train(cfg: &TrainConfig) -> Result<TrainReport> {
    train_with_progress(cfg, None)
}

/// [`train`] with an optional [`StepEvent`] stream from group 0 — the
/// session-API internal (`session::run` receives the events and fans them
/// out to its observers).  `progress = None` is exactly [`train`].
pub fn train_with_progress(
    cfg: &TrainConfig,
    progress: Option<ProgressSender>,
) -> Result<TrainReport> {
    let data = Arc::new(
        datasets::load(&cfg.dataset)
            .ok_or_else(|| anyhow!("unknown dataset {}", cfg.dataset))?,
    );
    let spec = datasets::spec(&cfg.dataset).unwrap();
    let rt = Runtime::open(&cfg.artifacts).context("opening artifacts")?;
    let meta = rt.model(spec.model_config)?.clone();
    drop(rt);

    // resume every group from the newest step that *all* groups have a
    // valid snapshot for (a crash can leave the final save partial)
    let mut resume: Vec<Option<Snapshot>> = if cfg.resume {
        let policy = cfg
            .checkpoint
            .clone()
            .ok_or_else(|| anyhow!("resume requires a checkpoint directory (cfg.checkpoint)"))?;
        let mut common: Option<std::collections::BTreeSet<u64>> = None;
        for g in 0..cfg.dp {
            let (steps, warnings) =
                crate::checkpoint::valid_steps(&policy.dir, &format!("ref-g{g}"));
            for w in warnings {
                eprintln!("warning: {w}");
            }
            let set: std::collections::BTreeSet<u64> = steps.into_iter().collect();
            common = Some(match common {
                None => set,
                Some(c) => c.intersection(&set).copied().collect(),
            });
        }
        let step = common.and_then(|c| c.into_iter().next_back()).ok_or_else(|| {
            anyhow!(
                "resume requested but no valid snapshot covers all {} group(s) under {}",
                cfg.dp,
                policy.dir.display()
            )
        })?;
        (0..cfg.dp)
            .map(|g| {
                crate::checkpoint::load(&crate::checkpoint::path_for(
                    &policy.dir,
                    &format!("ref-g{g}"),
                    step,
                ))
                .map(Some)
            })
            .collect::<Result<_>>()?
    } else {
        vec![None; cfg.dp]
    };

    if cfg.dp == 1 {
        let mut report = TrainReport::default();
        worker_loop(cfg, data, &meta, 0, None, &mut report, progress, resume.pop().unwrap())?;
        Ok(report)
    } else {
        let world = Arc::new(CommWorld::new(Grid4D::new(cfg.dp, 1, 1, 1)));
        let mut handles = vec![];
        let mut progress = progress;
        for g in 0..cfg.dp {
            let cfg = cfg.clone();
            let data = data.clone();
            let meta = meta.clone();
            let world = world.clone();
            let tx = if g == 0 { progress.take() } else { None };
            let snap = resume[g].take();
            handles.push(std::thread::spawn(move || -> Result<TrainReport> {
                let mut report = TrainReport::default();
                worker_loop(&cfg, data, &meta, g, Some(&world), &mut report, tx, snap)?;
                Ok(report)
            }));
        }
        let mut first = None;
        for h in handles {
            let r = h.join().map_err(|_| anyhow!("worker panicked"))??;
            if first.is_none() {
                first = Some(r);
            }
        }
        Ok(first.unwrap())
    }
}

// ---------------------------------------------------------------------------
// Out-of-core training (`.pallas` store; see graph::store)
// ---------------------------------------------------------------------------

/// Configuration of the out-of-core training path (`train --from-store`):
/// mini-batches are constructed straight from a `.pallas` store through its
/// bounded block cache and trained with the pure-Rust reference GCN — the
/// graph and feature matrix never fully reside in RAM.
#[derive(Clone, Debug)]
pub struct OocTrainConfig {
    /// Path of the `.pallas` container.
    pub store: PathBuf,
    /// When set and `store` does not exist, pack this registry dataset into
    /// `store` first (the pack-once flow of `papers100m_ooc`).
    pub dataset: Option<String>,
    /// Cache budget in bytes for resident graph/feature blocks.
    pub cache_bytes: usize,
    /// Mini-batch size `B`.
    pub batch: usize,
    /// Hidden width of the reference GCN.
    pub d_h: usize,
    /// Number of GCN layers.
    pub layers: usize,
    /// Training steps to run.
    pub steps: u64,
    /// Adam learning rate.
    pub lr: f32,
    /// Sampling / parameter-init seed.
    pub seed: u64,
    /// Overlap disk-backed sampling with training (§V-A), as in the PJRT
    /// path: batch `t+1` is read while step `t` computes.
    pub prefetch: bool,
    /// Per-step stderr logging.
    pub verbose: bool,
    /// Periodic snapshot policy (`None` = no checkpointing); saves under
    /// tag `ooc`.
    pub checkpoint: Option<CheckpointPolicy>,
    /// Resume from the newest valid `ooc` snapshot (requires `checkpoint`).
    pub resume: bool,
}

impl OocTrainConfig {
    /// Defaults mirroring `TrainConfig::quick` at reference-model scale.
    pub fn quick(store: PathBuf) -> OocTrainConfig {
        OocTrainConfig {
            store,
            dataset: None,
            cache_bytes: 64 << 20,
            batch: 1024,
            d_h: 128,
            layers: 3,
            steps: 50,
            lr: 1e-2,
            seed: 42,
            prefetch: true,
            verbose: false,
            checkpoint: None,
            resume: false,
        }
    }
}

/// Result of an out-of-core training run, including the cache telemetry the
/// residency guarantee is asserted on (`tests/ooc_store.rs`).
#[derive(Clone, Debug, Default)]
pub struct OocTrainReport {
    /// Steps executed.
    pub steps: u64,
    /// (step, loss) at every step.
    pub loss_curve: Vec<(u64, f32)>,
    /// Loss of the final step.
    pub final_loss: f32,
    /// Sampled train-split accuracy of the final step.
    pub final_train_acc: f32,
    /// Training wall-clock.
    pub train_time_s: f64,
    /// Mean per-step wait on the (disk-backed) sampler; ≈0 with prefetch.
    pub sample_wait_s: f64,
    /// Store bytes resident in the block cache when the run finished.
    pub cache_resident_bytes: usize,
    /// Residency upper bound (`cache_bytes` rounded to whole blocks).
    pub cache_budget_bytes: usize,
    /// Block-cache hits over the whole run.
    pub cache_hits: u64,
    /// Block-cache misses over the whole run.
    pub cache_misses: u64,
    /// Total size of the `.pallas` container on disk.
    pub store_bytes: u64,
}

/// One out-of-core mini-batch: induced adjacency + gathered vertex data.
struct OocBatch {
    mb: crate::sampling::MiniBatch,
    x: Mat,
    y: Vec<u32>,
    w: Vec<f32>,
}

impl OocBatch {
    /// An empty shell for [`build_ooc_batch_into`] to fill/recycle.
    fn empty() -> OocBatch {
        OocBatch {
            mb: crate::sampling::MiniBatch::default(),
            x: Mat::zeros(0, 0),
            y: Vec::new(),
            w: Vec::new(),
        }
    }
}

/// Build the batch for `step` into a (possibly recycled) shell through the
/// sampling fast path: sort-free induction with the transpose kept (the
/// reference GCN backward needs `adj_t`), disk rows/features read through
/// the store's block cache, zero steady-state allocations.
fn build_ooc_batch_into(
    store: &OocGraph,
    sampler: &UniformVertexSampler,
    step: u64,
    ws: &mut crate::sampling::InduceWorkspace,
    out: &mut OocBatch,
) {
    use crate::graph::store::VertexData;
    crate::sampling::sample_and_induce_into(store, sampler, step, true, ws, &mut out.mb);
    let d_in = store.d_in;
    let b = out.mb.vertices.len();
    if out.x.rows != b || out.x.cols != d_in {
        out.x = Mat::zeros(b, d_in);
    }
    out.y.clear();
    out.w.clear();
    for (i, &v) in out.mb.vertices.iter().enumerate() {
        store.read_features(v as usize, &mut out.x.data[i * d_in..(i + 1) * d_in]);
        out.y.push(store.label_of(v as usize));
        out.w.push(if store.split_of(v as usize) == 0 { 1.0 } else { 0.0 });
    }
}

/// Train the pure-Rust reference GCN from a `.pallas` store: Algorithm 1
/// sampling, induced mini-batches read through the bounded block cache,
/// `model::train_step_ws` for the update.  Packs `cfg.dataset` into the
/// store file first when it is missing.  The full graph/feature matrix is
/// never materialized in RAM — peak store residency is reported in
/// `OocTrainReport::cache_resident_bytes` and bounded by the budget.
pub fn train_from_store(cfg: &OocTrainConfig) -> Result<OocTrainReport> {
    train_from_store_with_progress(cfg, None)
}

/// [`train_from_store`] with an optional [`StepEvent`] stream (the
/// session-API internal).  `progress = None` is exactly
/// [`train_from_store`].
pub fn train_from_store_with_progress(
    cfg: &OocTrainConfig,
    progress: Option<ProgressSender>,
) -> Result<OocTrainReport> {
    let store = Arc::new(match &cfg.dataset {
        Some(name) => crate::graph::store::open_or_pack(name, &cfg.store, cfg.cache_bytes)?,
        None => OocGraph::open(&cfg.store, cfg.cache_bytes)?,
    });
    if cfg.batch > store.n {
        bail!("batch {} exceeds store vertex count {}", cfg.batch, store.n);
    }
    let dims = GcnDims {
        d_in: store.d_in,
        d_h: cfg.d_h,
        d_out: store.classes,
        layers: cfg.layers,
        dropout: 0.0,
        weight_decay: 0.0,
    };
    let group_seed = splitmix64(cfg.seed ^ 0xD0);
    let sampler = UniformVertexSampler::new(store.n, cfg.batch, group_seed);

    let mut params = crate::model::init_params(&dims, cfg.seed);
    let mut opt = crate::model::AdamState::new(&dims);
    let spec_hash = crate::checkpoint::state_hash(&[
        0x4F4F_4300, // backend tag "OOC"
        cfg.seed,
        dims.state_signature(),
        cfg.batch as u64,
        cfg.lr.to_bits() as u64,
    ]);
    let ckpt = cfg.checkpoint.as_ref().map(|p| CheckpointManager::new(p.clone(), "ooc"));
    let mut start: u64 = 0;
    if cfg.resume {
        let mgr = ckpt
            .as_ref()
            .ok_or_else(|| anyhow!("resume requires a checkpoint directory (cfg.checkpoint)"))?;
        let (found, warnings) = mgr.latest();
        for w in warnings {
            eprintln!("warning: {w}");
        }
        let (path, snap) = found.ok_or_else(|| {
            anyhow!(
                "resume requested but no valid 'ooc' snapshot under {}",
                mgr.policy().dir.display()
            )
        })?;
        snap.check_hash(spec_hash, "the ooc trainer")?;
        snap.restore_model(&mut params, &mut opt)
            .with_context(|| format!("restoring {}", path.display()))?;
        start = snap.step;
        if cfg.verbose {
            eprintln!("[ooc] resuming from {} at step {start}", path.display());
        }
    }
    if start >= cfg.steps {
        bail!(
            "the snapshot already covers step {start} of {}; nothing left to resume \
             (raise steps to continue training)",
            cfg.steps
        );
    }

    // §V-A overlap: batch t+1 is read from disk while step t computes.
    // Spent shells circulate back over the recycle channel, so the sampler
    // thread's steady-state batch build allocates nothing.
    let (rx, free_tx) = if cfg.prefetch {
        let (tx, rx) = sync_channel::<OocBatch>(2);
        let (free_tx, free_rx) = sync_channel::<OocBatch>(4);
        let st = store.clone();
        let sm = sampler.clone();
        let steps = cfg.steps;
        let first = start;
        std::thread::spawn(move || {
            let mut ws = crate::sampling::InduceWorkspace::new();
            for step in first..steps {
                let mut shell = free_rx.try_recv().unwrap_or_else(|_| OocBatch::empty());
                build_ooc_batch_into(&st, &sm, step, &mut ws, &mut shell);
                if tx.send(shell).is_err() {
                    break; // trainer finished / dropped
                }
            }
        });
        (Some(rx), Some(free_tx))
    } else {
        (None, None)
    };

    let mut ws = crate::model::StepWorkspace::new();
    let masks = vec![Mat::filled(cfg.batch, dims.d_h, 1.0); dims.layers];
    let mut report = OocTrainReport { store_bytes: store.store_bytes(), ..Default::default() };
    let mut wait = 0.0f64;
    let mut last = (f32::NAN, 0.0f32);
    // inline-path (prefetch off) workspace + reused shell
    let mut inline_ws = crate::sampling::InduceWorkspace::new();
    let mut inline_shell = OocBatch::empty();
    let t_train = Instant::now();
    for step in start..cfg.steps {
        let t_step = Instant::now();
        let mut recvd: Option<OocBatch> = None;
        let b: &OocBatch = match &rx {
            Some(rx) => {
                recvd = Some(rx.recv().map_err(|_| anyhow!("ooc prefetcher died"))?);
                recvd.as_ref().expect("just set")
            }
            None => {
                build_ooc_batch_into(&store, &sampler, step, &mut inline_ws, &mut inline_shell);
                &inline_shell
            }
        };
        wait += t_step.elapsed().as_secs_f64();
        let (loss, acc) = crate::model::train_step_ws(
            &dims, &mut params, &mut opt, &b.mb.adj, &b.mb.adj_t, &b.x, &b.y, &b.w, &masks,
            cfg.lr, &mut ws,
        );
        // recycle the spent shell (never blocks; drops when the channel is
        // full or the prefetcher already exited)
        if let (Some(ftx), Some(spent)) = (&free_tx, recvd.take()) {
            let _ = ftx.try_send(spent);
        }
        last = (loss, acc);
        report.loss_curve.push((step, loss));
        if let Some(mgr) = &ckpt {
            if mgr.should_save(step) {
                let snap = Snapshot::from_model(step + 1, cfg.seed, spec_hash, &params, &opt);
                mgr.save(&snap)
                    .with_context(|| format!("saving the step-{step} ooc snapshot"))?;
            }
        }
        if cfg.verbose {
            eprintln!("[ooc] step {step} loss {loss:.4} train-acc {acc:.4}");
        }
        report.steps = step + 1;
        if let Some(tx) = &progress {
            let _ = tx.send(StepEvent {
                step,
                loss,
                acc,
                wall_s: t_step.elapsed().as_secs_f64(),
                eval: None,
                truncated: 0,
                done: step + 1 == cfg.steps,
            });
        }
    }
    drop(rx);
    report.train_time_s = t_train.elapsed().as_secs_f64();
    report.sample_wait_s = wait / report.steps.saturating_sub(start).max(1) as f64;
    report.final_loss = last.0;
    report.final_train_acc = last.1;
    let cs = store.cache_stats();
    report.cache_resident_bytes = cs.resident_bytes;
    report.cache_budget_bytes = cs.budget_bytes;
    report.cache_hits = cs.hits;
    report.cache_misses = cs.misses;
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg() -> TrainConfig {
        let mut c = TrainConfig::quick("tiny", SamplerKind::ScaleGnnUniform);
        c.artifacts = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        c.max_steps = 40;
        c.lr = 5e-3;
        c.eval_threads = 4;
        c
    }

    /// The PJRT training path needs the AOT artifacts (`make artifacts`)
    /// and a real xla backend; skip gracefully when either is absent so
    /// `cargo test` works in the offline/stub build.
    fn artifacts_available() -> bool {
        let ok = crate::runtime::pjrt_artifacts_available(&tiny_cfg().artifacts);
        if !ok {
            eprintln!("skipping: PJRT artifacts/backend not available");
        }
        ok
    }

    #[test]
    fn fused_training_reduces_loss_and_learns() {
        if !artifacts_available() {
            return;
        }
        let cfg = tiny_cfg();
        let r = train(&cfg).unwrap();
        assert_eq!(r.steps, 40);
        let first = r.loss_curve.first().unwrap().1;
        assert!(r.final_loss < first, "loss {first} -> {}", r.final_loss);
        assert!(r.best_test_acc > 0.5, "test acc {}", r.best_test_acc);
    }

    #[test]
    fn prefetch_and_inline_sampling_agree() {
        if !artifacts_available() {
            return;
        }
        let mut a = tiny_cfg();
        a.max_steps = 12;
        let mut b = a.clone();
        b.prefetch = false;
        let ra = train(&a).unwrap();
        let rb = train(&b).unwrap();
        // identical batches and state -> identical losses
        assert_eq!(ra.final_loss, rb.final_loss);
        // inline sampling pays the cost on the critical path
        assert!(rb.breakdown.sample_wait_s > 0.0);
    }

    #[test]
    fn dp2_path_runs_and_learns() {
        if !artifacts_available() {
            return;
        }
        let mut cfg = tiny_cfg();
        cfg.dp = 2;
        cfg.max_steps = 30;
        let r = train(&cfg).unwrap();
        assert!(r.final_loss.is_finite());
        assert!(r.best_test_acc > 0.4, "acc {}", r.best_test_acc);
    }

    #[test]
    fn target_accuracy_stops_early() {
        if !artifacts_available() {
            return;
        }
        let mut cfg = tiny_cfg();
        cfg.max_steps = 0;
        cfg.max_epochs = 50;
        cfg.target_acc = Some(0.6);
        let r = train(&cfg).unwrap();
        assert!(r.time_to_target_s.is_some(), "never reached 0.6: {:?}", r.acc_curve);
        assert!(r.best_test_acc >= 0.6);
    }

    #[test]
    fn baseline_samplers_train_too() {
        if !artifacts_available() {
            return;
        }
        for kind in [SamplerKind::GraphSage, SamplerKind::GraphSaintNode] {
            let mut cfg = tiny_cfg();
            cfg.sampler = kind;
            cfg.max_steps = 30;
            let r = train(&cfg).unwrap();
            assert!(r.final_loss.is_finite(), "{kind:?}");
            assert!(r.best_test_acc > 0.3, "{kind:?} acc {}", r.best_test_acc);
        }
    }
}
