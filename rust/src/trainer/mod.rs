//! The 4D training coordinator (Layer 3 hot path).
//!
//! Each data-parallel group is a worker thread owning its own PJRT runtime
//! (the CPU client is not `Send`), executing the AOT train-step artifacts:
//!
//! * `dp = 1` — the **fused** path: one `train_step_*` executable performs
//!   forward, backward and Adam with donated state buffers; parameters stay
//!   device-side as literals between steps (Python is never involved).
//! * `dp > 1` — the **synchronous DP** path: `grad_step_*` produces raw
//!   gradients, the coordinator all-reduces them across groups
//!   (`comm::CommWorld`, §IV-A), and `adam_apply_*` applies the update —
//!   bitwise-identical replicas by construction.
//!
//! **Prefetch pipeline (§V-A):** a dedicated sampler thread per group runs
//! Algorithm 1 for step `t+1` while step `t` executes, handing batches over
//! a bounded channel (the CUDA-event synchronization of the paper maps to
//! the channel receive).  Disabling it (`prefetch = false`) reproduces the
//! Fig. 5 baseline where sampling sits on the critical path.

pub mod batch;
pub mod eval;

use std::path::PathBuf;
use std::sync::mpsc::{sync_channel, Receiver};
use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, Context, Result};

use crate::comm::{CommWorld, Precision};
use crate::graph::{datasets, Dataset};
use crate::grid::{Axis, Grid4D};
use crate::model::GcnDims;
use crate::runtime::{lit_f32, lit_i32, lit_u32, scalar_f32, to_f32, ModelMeta, Runtime};
use crate::sampling::SamplerKind;
use crate::util::rng::splitmix64;
use batch::{BatchData, BatchMaker};

/// Training configuration.
#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub dataset: String,
    pub sampler: SamplerKind,
    /// number of data-parallel groups (Gd)
    pub dp: usize,
    pub lr: f32,
    pub seed: u64,
    /// overlap sampling with training (§V-A)
    pub prefetch: bool,
    pub artifacts: PathBuf,
    /// hard step cap (0 = until target/max_epochs)
    pub max_steps: u64,
    pub max_epochs: usize,
    /// stop once full-graph test accuracy reaches this (paper's E2E metric)
    pub target_acc: Option<f32>,
    /// evaluate every k epochs
    pub eval_every_epochs: usize,
    pub eval_threads: usize,
    pub verbose: bool,
    /// use BF16 payloads for the DP gradient all-reduce (§V-B)
    pub bf16_dp: bool,
}

impl TrainConfig {
    pub fn quick(dataset: &str, sampler: SamplerKind) -> TrainConfig {
        TrainConfig {
            dataset: dataset.to_string(),
            sampler,
            dp: 1,
            lr: 1e-2,
            seed: 42,
            prefetch: true,
            artifacts: PathBuf::from("artifacts"),
            max_steps: 0,
            max_epochs: 30,
            target_acc: None,
            eval_every_epochs: 1,
            eval_threads: std::thread::available_parallelism()
                .map(|p| p.get())
                .unwrap_or(4),
            verbose: false,
            bf16_dp: false,
        }
    }
}

/// Per-step timing breakdown (averaged over the run).
#[derive(Clone, Copy, Debug, Default)]
pub struct StepBreakdown {
    /// waiting on the sampler (≈0 with prefetch; full sampling cost without)
    pub sample_wait_s: f64,
    /// literal packing
    pub pack_s: f64,
    /// PJRT execution (fwd+bwd+opt or grad)
    pub exec_s: f64,
    /// DP gradient all-reduce (+ adam_apply on the dp>1 path)
    pub dp_comm_s: f64,
}

/// Result of a training run.
#[derive(Clone, Debug, Default)]
pub struct TrainReport {
    pub steps: u64,
    pub epochs: usize,
    /// training wall-clock, excluding evaluation (§VI-C methodology)
    pub train_time_s: f64,
    pub eval_time_s: f64,
    pub final_loss: f32,
    pub best_test_acc: f32,
    pub best_val_acc: f32,
    /// train time at which the target accuracy was first reached
    pub time_to_target_s: Option<f64>,
    pub loss_curve: Vec<(u64, f32)>,
    /// (step, val_acc, test_acc) at each evaluation
    pub acc_curve: Vec<(u64, f32, f32)>,
    pub breakdown: StepBreakdown,
}

pub fn meta_to_dims(m: &ModelMeta) -> GcnDims {
    GcnDims {
        d_in: m.d_in,
        d_h: m.d_h,
        d_out: m.d_out,
        layers: m.layers,
        dropout: m.dropout,
        weight_decay: 0.0,
    }
}

/// Spawn the §V-A prefetch pipeline: a sampler thread feeding a bounded(2)
/// channel.  Returns the receiving end.
fn spawn_prefetcher(mut maker: BatchMaker, max_steps: u64) -> Receiver<BatchData> {
    let (tx, rx) = sync_channel::<BatchData>(2);
    std::thread::spawn(move || {
        for step in 0..max_steps {
            let b = maker.make(step);
            if tx.send(b).is_err() {
                break; // trainer finished / dropped
            }
        }
    });
    rx
}

struct PackedState {
    params: Vec<Vec<f32>>,
    m: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
    t: f32,
}

fn init_state(meta: &ModelMeta, seed: u64) -> PackedState {
    let dims = meta_to_dims(meta);
    let params: Vec<Vec<f32>> = crate::model::init_params(&dims, seed)
        .into_iter()
        .map(|m| m.data)
        .collect();
    let zeros: Vec<Vec<f32>> = params.iter().map(|p| vec![0.0; p.len()]).collect();
    PackedState { params, m: zeros.clone(), v: zeros, t: 0.0 }
}

fn state_literals(meta: &ModelMeta, st: &PackedState) -> Result<Vec<xla::Literal>> {
    let mut lits = Vec::with_capacity(3 * meta.n_params);
    for group in [&st.params, &st.m, &st.v] {
        for (data, shape) in group.iter().zip(&meta.param_shapes) {
            lits.push(lit_f32(data, shape)?);
        }
    }
    Ok(lits)
}

fn batch_literals(meta: &ModelMeta, b: &BatchData, seed: u64) -> Result<Vec<xla::Literal>> {
    let bb = meta.batch;
    let e = meta.edge_cap;
    let key = [
        (splitmix64(seed ^ b.step) >> 32) as u32,
        splitmix64(seed ^ b.step) as u32,
    ];
    Ok(vec![
        lit_i32(&b.src, &[e])?,
        lit_i32(&b.dst, &[e])?,
        lit_f32(&b.val, &[e])?,
        lit_f32(&b.x, &[bb, meta.d_in])?,
        lit_i32(&b.y, &[bb])?,
        lit_f32(&b.wmask, &[bb])?,
        lit_u32(&key, &[2])?,
    ])
}

/// Shared per-worker training loop.  `world` carries the DP communicator
/// when `cfg.dp > 1`.
#[allow(clippy::too_many_arguments)]
fn worker_loop(
    cfg: &TrainConfig,
    data: Arc<Dataset>,
    meta: &ModelMeta,
    group: usize,
    world: Option<&CommWorld>,
    report: &mut TrainReport,
) -> Result<()> {
    let rt = Runtime::open(&cfg.artifacts)?;
    let dims = meta_to_dims(meta);
    let steps_per_epoch = ((data.n / meta.batch).max(1)) as u64;
    let total_steps = if cfg.max_steps > 0 {
        cfg.max_steps
    } else {
        steps_per_epoch * cfg.max_epochs as u64
    };
    let group_seed = splitmix64(cfg.seed ^ (0xD0 + group as u64));
    let maker =
        BatchMaker::new(data.clone(), cfg.sampler, meta.batch, meta.edge_cap, meta.layers, group_seed);

    // fused path artifacts vs DP decomposition artifacts
    let fused = cfg.dp == 1;
    let (step_exe, adam_exe) = if fused {
        (rt.load(&format!("train_step_{}", meta.name))?, None)
    } else {
        (
            rt.load(&format!("grad_step_{}", meta.name))?,
            Some(rt.load(&format!("adam_apply_{}", meta.name))?),
        )
    };

    let mut st = init_state(meta, cfg.seed);
    // §V-A double buffering: with prefetch on, the maker moves to a sampler
    // thread that builds batch t+1 while step t executes; otherwise it runs
    // inline on the critical path (the Fig. 5 baseline).
    let (mut rx, mut inline_maker) = if cfg.prefetch {
        (Some(spawn_prefetcher(maker, total_steps)), None)
    } else {
        (None, Some(maker))
    };

    let np = meta.n_params;
    let mut train_time = 0.0f64;
    let mut eval_time = 0.0f64;
    let mut bd = StepBreakdown::default();
    let mut best_test = 0.0f32;
    let mut best_val = 0.0f32;
    let mut time_to_target = None;
    let mut last_loss = f32::NAN;
    // evaluation parameter buffers, allocated once and refilled per eval
    let mut eval_params: Vec<crate::tensor::Mat> = meta
        .param_shapes
        .iter()
        .map(|s| {
            let (r, c) = if s.len() == 2 { (s[0], s[1]) } else { (1, s[0]) };
            crate::tensor::Mat::zeros(r, c)
        })
        .collect();

    for step in 0..total_steps {
        let t_step = Instant::now();
        // --- sample (or wait on the prefetcher) ---
        let t0 = Instant::now();
        let bdat = match (&mut rx, &mut inline_maker) {
            (Some(rx), _) => rx.recv().map_err(|_| anyhow!("prefetcher died"))?,
            (None, Some(mk)) => mk.make(step),
            _ => unreachable!(),
        };
        bd.sample_wait_s += t0.elapsed().as_secs_f64();

        // --- pack ---
        let t0 = Instant::now();
        let mut inputs = batch_literals(meta, &bdat, group_seed)?;
        bd.pack_s += t0.elapsed().as_secs_f64();

        if fused {
            let t0 = Instant::now();
            inputs.push(xla::Literal::scalar(cfg.lr));
            inputs.push(xla::Literal::scalar(st.t));
            inputs.extend(state_literals(meta, &st)?);
            let outs = step_exe.run(&inputs)?;
            last_loss = scalar_f32(&outs[0])?;
            st.t = scalar_f32(&outs[2])?;
            for i in 0..np {
                st.params[i] = to_f32(&outs[3 + i])?;
                st.m[i] = to_f32(&outs[3 + np + i])?;
                st.v[i] = to_f32(&outs[3 + 2 * np + i])?;
            }
            bd.exec_s += t0.elapsed().as_secs_f64();
        } else {
            // grad
            let t0 = Instant::now();
            for (p, shape) in st.params.iter().zip(&meta.param_shapes) {
                inputs.push(lit_f32(p, shape)?);
            }
            let outs = step_exe.run(&inputs)?;
            last_loss = scalar_f32(&outs[0])?;
            let mut grads: Vec<Vec<f32>> =
                (0..np).map(|i| to_f32(&outs[2 + i])).collect::<Result<_>>()?;
            bd.exec_s += t0.elapsed().as_secs_f64();

            // DP all-reduce + mean (Fig. 8's DP component)
            let t0 = Instant::now();
            if let Some(w) = world {
                let gd = cfg.dp as f32;
                let prec = if cfg.bf16_dp { Precision::Bf16 } else { Precision::Fp32 };
                for g in grads.iter_mut() {
                    w.all_reduce(group, Axis::Dp, g, prec);
                    for v in g.iter_mut() {
                        *v /= gd;
                    }
                }
                let mut loss_buf = [last_loss];
                w.all_reduce(group, Axis::Dp, &mut loss_buf, Precision::Fp32);
                last_loss = loss_buf[0] / gd;
            }
            // adam_apply
            let adam = adam_exe.as_ref().unwrap();
            let mut ain = vec![xla::Literal::scalar(cfg.lr), xla::Literal::scalar(st.t)];
            for group_vals in [&st.params, &grads, &st.m, &st.v] {
                for (p, shape) in group_vals.iter().zip(&meta.param_shapes) {
                    ain.push(lit_f32(p, shape)?);
                }
            }
            let aouts = adam.run(&ain)?;
            st.t = scalar_f32(&aouts[0])?;
            for i in 0..np {
                st.params[i] = to_f32(&aouts[1 + i])?;
                st.m[i] = to_f32(&aouts[1 + np + i])?;
                st.v[i] = to_f32(&aouts[1 + 2 * np + i])?;
            }
            bd.dp_comm_s += t0.elapsed().as_secs_f64();
        }
        train_time += t_step.elapsed().as_secs_f64();

        if step % steps_per_epoch == 0 || step == total_steps - 1 {
            report.loss_curve.push((step, last_loss));
        }

        // --- periodic full-graph evaluation (group 0 computes; others sync) ---
        let epoch_done = (step + 1) % (steps_per_epoch * cfg.eval_every_epochs as u64) == 0
            || step == total_steps - 1;
        if epoch_done {
            let t0 = Instant::now();
            for (m, p) in eval_params.iter_mut().zip(&st.params) {
                m.data.copy_from_slice(p);
            }
            let (val, test) =
                eval::full_graph_accuracy(&data, &dims, &eval_params, cfg.eval_threads);
            eval_time += t0.elapsed().as_secs_f64();
            best_test = best_test.max(test);
            best_val = best_val.max(val);
            report.acc_curve.push((step + 1, val, test));
            if cfg.verbose && group == 0 {
                eprintln!(
                    "[{}] step {:>6} epoch {:>3} loss {:.4} val {:.4} test {:.4} ({:.1}s train)",
                    cfg.dataset,
                    step + 1,
                    (step + 1) / steps_per_epoch,
                    last_loss,
                    val,
                    test,
                    train_time
                );
            }
            if let Some(target) = cfg.target_acc {
                if test >= target && time_to_target.is_none() {
                    time_to_target = Some(train_time);
                }
                if test >= target {
                    report.steps = step + 1;
                    break;
                }
            }
        }
        report.steps = step + 1;
    }

    let steps = report.steps.max(1) as f64;
    report.epochs = (report.steps / steps_per_epoch) as usize;
    report.train_time_s = train_time;
    report.eval_time_s = eval_time;
    report.final_loss = last_loss;
    report.best_test_acc = best_test;
    report.best_val_acc = best_val;
    report.time_to_target_s = time_to_target;
    report.breakdown = StepBreakdown {
        sample_wait_s: bd.sample_wait_s / steps,
        pack_s: bd.pack_s / steps,
        exec_s: bd.exec_s / steps,
        dp_comm_s: bd.dp_comm_s / steps,
    };
    Ok(())
}

/// Run a training job per `cfg`; returns group 0's report.
pub fn train(cfg: &TrainConfig) -> Result<TrainReport> {
    let data = Arc::new(
        datasets::load(&cfg.dataset)
            .ok_or_else(|| anyhow!("unknown dataset {}", cfg.dataset))?,
    );
    let spec = datasets::spec(&cfg.dataset).unwrap();
    let rt = Runtime::open(&cfg.artifacts).context("opening artifacts")?;
    let meta = rt.model(spec.model_config)?.clone();
    drop(rt);

    if cfg.dp == 1 {
        let mut report = TrainReport::default();
        worker_loop(cfg, data, &meta, 0, None, &mut report)?;
        Ok(report)
    } else {
        let world = Arc::new(CommWorld::new(Grid4D::new(cfg.dp, 1, 1, 1)));
        let mut handles = vec![];
        for g in 0..cfg.dp {
            let cfg = cfg.clone();
            let data = data.clone();
            let meta = meta.clone();
            let world = world.clone();
            handles.push(std::thread::spawn(move || -> Result<TrainReport> {
                let mut report = TrainReport::default();
                worker_loop(&cfg, data, &meta, g, Some(&world), &mut report)?;
                Ok(report)
            }));
        }
        let mut first = None;
        for h in handles {
            let r = h.join().map_err(|_| anyhow!("worker panicked"))??;
            if first.is_none() {
                first = Some(r);
            }
        }
        Ok(first.unwrap())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg() -> TrainConfig {
        let mut c = TrainConfig::quick("tiny", SamplerKind::ScaleGnnUniform);
        c.artifacts = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        c.max_steps = 40;
        c.lr = 5e-3;
        c.eval_threads = 4;
        c
    }

    /// The PJRT training path needs the AOT artifacts (`make artifacts`)
    /// and a real xla backend; skip gracefully when either is absent so
    /// `cargo test` works in the offline/stub build.
    fn artifacts_available() -> bool {
        let ok = crate::runtime::pjrt_artifacts_available(&tiny_cfg().artifacts);
        if !ok {
            eprintln!("skipping: PJRT artifacts/backend not available");
        }
        ok
    }

    #[test]
    fn fused_training_reduces_loss_and_learns() {
        if !artifacts_available() {
            return;
        }
        let cfg = tiny_cfg();
        let r = train(&cfg).unwrap();
        assert_eq!(r.steps, 40);
        let first = r.loss_curve.first().unwrap().1;
        assert!(r.final_loss < first, "loss {first} -> {}", r.final_loss);
        assert!(r.best_test_acc > 0.5, "test acc {}", r.best_test_acc);
    }

    #[test]
    fn prefetch_and_inline_sampling_agree() {
        if !artifacts_available() {
            return;
        }
        let mut a = tiny_cfg();
        a.max_steps = 12;
        let mut b = a.clone();
        b.prefetch = false;
        let ra = train(&a).unwrap();
        let rb = train(&b).unwrap();
        // identical batches and state -> identical losses
        assert_eq!(ra.final_loss, rb.final_loss);
        // inline sampling pays the cost on the critical path
        assert!(rb.breakdown.sample_wait_s > 0.0);
    }

    #[test]
    fn dp2_path_runs_and_learns() {
        if !artifacts_available() {
            return;
        }
        let mut cfg = tiny_cfg();
        cfg.dp = 2;
        cfg.max_steps = 30;
        let r = train(&cfg).unwrap();
        assert!(r.final_loss.is_finite());
        assert!(r.best_test_acc > 0.4, "acc {}", r.best_test_acc);
    }

    #[test]
    fn target_accuracy_stops_early() {
        if !artifacts_available() {
            return;
        }
        let mut cfg = tiny_cfg();
        cfg.max_steps = 0;
        cfg.max_epochs = 50;
        cfg.target_acc = Some(0.6);
        let r = train(&cfg).unwrap();
        assert!(r.time_to_target_s.is_some(), "never reached 0.6: {:?}", r.acc_curve);
        assert!(r.best_test_acc >= 0.6);
    }

    #[test]
    fn baseline_samplers_train_too() {
        if !artifacts_available() {
            return;
        }
        for kind in [SamplerKind::GraphSage, SamplerKind::GraphSaintNode] {
            let mut cfg = tiny_cfg();
            cfg.sampler = kind;
            cfg.max_steps = 30;
            let r = train(&cfg).unwrap();
            assert!(r.final_loss.is_finite(), "{kind:?}");
            assert!(r.best_test_acc > 0.3, "{kind:?} acc {}", r.best_test_acc);
        }
    }
}
