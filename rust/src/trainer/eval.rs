//! Multi-threaded full-graph evaluation used by the time-to-accuracy runner.
//!
//! Row-chunked version of `model::forward` with a barrier between layers
//! (each SpMM reads the full previous activation).  The Table II experiment
//! uses the genuinely distributed `pmm::PmmGcn::eval_full_graph` path; this
//! helper is the fast shared-memory equivalent for the training loop's
//! periodic accuracy checks.
//!
//! Safety model: the two activation buffers are shared via raw pointers; in
//! every phase each worker writes only its own row chunk and reads only the
//! buffer written in the *previous* phase, with a barrier between phases.

use std::sync::Barrier;

use crate::graph::Dataset;
use crate::model::{GcnDims, Params, RMS_EPS};
#[cfg(test)]
use crate::tensor::Mat;

/// Raw shared f32 buffer (see module safety note).
#[derive(Clone, Copy)]
struct SharedBuf {
    ptr: *mut f32,
    len: usize,
}
// SAFETY: SharedBuf is a raw view into a Vec<f32> that outlives the scoped
// workers; cross-thread access follows the module safety model (disjoint
// row chunks per phase, barrier between phases), so sending/sharing the
// pointer is sound.
unsafe impl Send for SharedBuf {}
// SAFETY: see the Send impl above — the phase discipline serializes every
// write against every read of the same element.
unsafe impl Sync for SharedBuf {}

impl SharedBuf {
    // SAFETY: caller must not hold any overlapping &mut from rows_mut for
    // the same phase (the barrier protocol guarantees readers only see the
    // buffer written in the previous phase); ptr/len come from a live Vec.
    unsafe fn all(&self) -> &[f32] {
        std::slice::from_raw_parts(self.ptr, self.len)
    }

    // SAFETY: caller must pass a row range disjoint from every other
    // worker's (r0..r1 chunks partition the rows) with r1*cols <= len, so
    // the &mut slices never alias.
    #[allow(clippy::mut_from_ref)]
    unsafe fn rows_mut(&self, r0: usize, r1: usize, cols: usize) -> &mut [f32] {
        std::slice::from_raw_parts_mut(self.ptr.add(r0 * cols), (r1 - r0) * cols)
    }
}

/// Full-graph (val_acc, test_acc) with `threads` row-chunk workers.
pub fn full_graph_accuracy(
    data: &Dataset,
    dims: &GcnDims,
    params: &Params,
    threads: usize,
) -> (f32, f32) {
    let n = data.n;
    let threads = threads.max(1).min(n);
    let bounds = crate::graph::block_bounds(n, threads);
    let barrier = Barrier::new(threads);
    let dh = dims.d_h;

    let mut h = vec![0.0f32; n * dh];
    let mut h_next = vec![0.0f32; n * dh];
    let buf_a = SharedBuf { ptr: h.as_mut_ptr(), len: h.len() };
    let buf_b = SharedBuf { ptr: h_next.as_mut_ptr(), len: h_next.len() };

    let counts: Vec<(u64, u64, u64, u64)> = std::thread::scope(|scope| {
        let mut handles = vec![];
        for t in 0..threads {
            let (r0, r1) = (bounds[t], bounds[t + 1]);
            let barrier = &barrier;
            handles.push(scope.spawn(move || {
                // input projection into my chunk of buf_a
                {
                    // SAFETY: each worker writes only its own disjoint
                    // r0..r1 chunk in this phase; nobody reads buf_a until
                    // the barrier below.
                    let dst = unsafe { buf_a.rows_mut(r0, r1, dh) };
                    for (k, r) in (r0..r1).enumerate() {
                        let xrow = &data.features.data[r * dims.d_in..(r + 1) * dims.d_in];
                        let orow = &mut dst[k * dh..(k + 1) * dh];
                        orow.fill(0.0);
                        for (i, &xv) in xrow.iter().enumerate() {
                            if xv == 0.0 {
                                continue;
                            }
                            let wrow = &params[0].data[i * dh..(i + 1) * dh];
                            for j in 0..dh {
                                orow[j] += xv * wrow[j];
                            }
                        }
                    }
                }
                barrier.wait();

                let mut read_a = true;
                let mut agg = vec![0.0f32; dh];
                for l in 0..dims.layers {
                    let w = &params[1 + 2 * l];
                    let g = &params[2 + 2 * l];
                    // SAFETY: src is the buffer fully written in the
                    // previous phase (sealed by the barrier) and dst is
                    // this worker's disjoint chunk of the *other* buffer,
                    // so no read aliases any concurrent write.
                    let (src, dst) = unsafe {
                        if read_a {
                            (buf_a.all(), buf_b.rows_mut(r0, r1, dh))
                        } else {
                            (buf_b.all(), buf_a.rows_mut(r0, r1, dh))
                        }
                    };
                    for (k, r) in (r0..r1).enumerate() {
                        agg.fill(0.0);
                        let (cs, vs) = data.adj.row(r);
                        for (&c, &v) in cs.iter().zip(vs) {
                            let srow = &src[c as usize * dh..(c as usize + 1) * dh];
                            for j in 0..dh {
                                agg[j] += v * srow[j];
                            }
                        }
                        let orow = &mut dst[k * dh..(k + 1) * dh];
                        orow.fill(0.0);
                        for (i, &av) in agg.iter().enumerate() {
                            if av == 0.0 {
                                continue;
                            }
                            let wrow = &w.data[i * dh..(i + 1) * dh];
                            for j in 0..dh {
                                orow[j] += av * wrow[j];
                            }
                        }
                        let ms: f32 = orow.iter().map(|v| v * v).sum::<f32>() / dh as f32;
                        let inv = 1.0 / (ms + RMS_EPS).sqrt();
                        let srow = &src[r * dh..(r + 1) * dh];
                        for j in 0..dh {
                            let v = (orow[j] * inv * g.data[j]).max(0.0);
                            orow[j] = v + srow[j];
                        }
                    }
                    read_a = !read_a;
                    barrier.wait();
                }

                // output head + accuracy for my rows
                // SAFETY: the final barrier of the layer loop sealed the
                // last-written buffer; every worker only reads from here on.
                let src = unsafe { if read_a { buf_a.all() } else { buf_b.all() } };
                let wout = &params[params.len() - 1];
                let dout = dims.d_out;
                let mut local = (0u64, 0u64, 0u64, 0u64);
                let mut logits = vec![0.0f32; dout];
                for r in r0..r1 {
                    let split = data.split[r];
                    if split == 0 {
                        continue;
                    }
                    let srow = &src[r * dh..(r + 1) * dh];
                    logits.fill(0.0);
                    for (i, &hv) in srow.iter().enumerate() {
                        if hv == 0.0 {
                            continue;
                        }
                        let wrow = &wout.data[i * dout..(i + 1) * dout];
                        for j in 0..dout {
                            logits[j] += hv * wrow[j];
                        }
                    }
                    let mut arg = 0usize;
                    for j in 1..dout {
                        if logits[j] > logits[arg] {
                            arg = j;
                        }
                    }
                    let ok = arg as u32 == data.labels[r];
                    if split == 1 {
                        local.1 += 1;
                        local.0 += ok as u64;
                    } else {
                        local.3 += 1;
                        local.2 += ok as u64;
                    }
                }
                local
            }));
        }
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });

    let (mut vo, mut vn, mut to, mut tn) = (0u64, 0u64, 0u64, 0u64);
    for &(a, b, c, d) in &counts {
        vo += a;
        vn += b;
        to += c;
        tn += d;
    }
    (vo as f32 / vn.max(1) as f32, to as f32 / tn.max(1) as f32)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::datasets;
    use crate::model;

    #[test]
    fn parallel_eval_matches_reference() {
        let data = datasets::load("tiny").unwrap();
        let dims = GcnDims {
            d_in: 16,
            d_h: 16,
            d_out: 4,
            layers: 2,
            dropout: 0.0,
            weight_decay: 0.0,
        };
        let params = model::init_params(&dims, 5);
        let (logits, _) = model::forward(&dims, &params, &data.adj, &data.features, None);
        let wtest: Vec<f32> = data
            .split
            .iter()
            .map(|&s| if s == 2 { 1.0 } else { 0.0 })
            .collect();
        let (_, want_test, _) = model::loss_and_grad(&logits, &data.labels, &wtest);
        for threads in [1, 2, 4, 7] {
            let (_val, test) = full_graph_accuracy(&data, &dims, &params, threads);
            assert!(
                (test - want_test).abs() < 1e-5,
                "threads={threads}: {test} vs {want_test}"
            );
        }
    }

    #[test]
    fn trained_model_beats_random_on_eval() {
        let data = std::sync::Arc::new(datasets::load("tiny").unwrap());
        let dims = GcnDims {
            d_in: 16,
            d_h: 16,
            d_out: 4,
            layers: 2,
            dropout: 0.0,
            weight_decay: 0.0,
        };
        let mut params = model::init_params(&dims, 1);
        let mut opt = model::AdamState::new(&dims);
        let sampler = crate::sampling::UniformVertexSampler::new(data.n, 128, 3);
        let (_, acc0) = full_graph_accuracy(&data, &dims, &params, 4);
        for step in 0..30 {
            let s = sampler.sample(step);
            let mb = crate::sampling::induce_rescaled(&data.adj, &s, sampler.inclusion_prob());
            let mut x = Mat::zeros(128, 16);
            for (i, &v) in s.iter().enumerate() {
                x.data[i * 16..(i + 1) * 16]
                    .copy_from_slice(&data.features.data[v as usize * 16..(v as usize + 1) * 16]);
            }
            let y: Vec<u32> = s.iter().map(|&v| data.labels[v as usize]).collect();
            let w: Vec<f32> = s
                .iter()
                .map(|&v| if data.split[v as usize] == 0 { 1.0 } else { 0.0 })
                .collect();
            let masks = vec![Mat::filled(128, 16, 1.0); 2];
            model::train_step(
                &dims, &mut params, &mut opt, &mb.adj, &mb.adj_t, &x, &y, &w, &masks, 5e-3,
            );
        }
        let (_, acc1) = full_graph_accuracy(&data, &dims, &params, 4);
        assert!(acc1 > acc0 + 0.1, "acc {acc0} -> {acc1}");
    }
}
