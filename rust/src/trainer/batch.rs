//! Mini-batch assembly for the PJRT train-step artifacts: every sampler
//! (ScaleGNN uniform, GraphSAGE, GraphSAINT) is reduced to the same
//! fixed-shape payload `(src[E], dst[E], val[E], X[B,d_in], y[B],
//! wmask[B])` — a padded edge list plus gathered features/labels.
//!
//! The maker reads its graph through the `graph::store` access traits, so
//! the ScaleGNN uniform path can be fed either by an in-memory [`Dataset`]
//! ([`BatchMaker::new`]) or by an on-disk `.pallas` store
//! ([`BatchMaker::from_store`]); same seed, same batch — bitwise — either
//! way.  The baseline samplers need `raw_adj`/degree statistics and remain
//! in-memory only.

use std::sync::Arc;

use crate::graph::store::{OocGraph, VertexData};
use crate::graph::Dataset;
use crate::sampling::{
    induce_rescaled, induce_rescaled_from, GraphSageSampler, GraphSaintNodeSampler, SamplerKind,
    UniformVertexSampler,
};

/// One step's packed inputs (ready to become literals).  The adjacency is
/// a padded edge list (`edge_cap` entries; padding has val = 0) — the
/// CPU-efficient sparse-SpMM lowering (EXPERIMENTS.md §Perf L2).
pub struct BatchData {
    /// Step index this batch was built for.
    pub step: u64,
    /// Edge sources in the compact `[0, B)` namespace (padded).
    pub src: Vec<i32>,
    /// Edge destinations in the compact namespace (padded).
    pub dst: Vec<i32>,
    /// Edge weights (0 for padding slots).
    pub val: Vec<f32>,
    /// Row-major `B x d_in` gathered features.
    pub x: Vec<f32>,
    /// Labels per batch slot.
    pub y: Vec<i32>,
    /// Per-slot loss weight (0 masks a slot out of the loss).
    pub wmask: Vec<f32>,
    /// edges dropped because the batch exceeded edge_cap (0 in practice)
    pub truncated: usize,
}

/// Where the maker reads graph + vertex data from.
enum Source {
    /// Fully in-memory generated dataset.
    Mem(Arc<Dataset>),
    /// Disk-backed `.pallas` store (ScaleGNN uniform sampling only).
    Ooc(Arc<OocGraph>),
}

/// Stateful batch factory for one DP group.
pub struct BatchMaker {
    /// Sampling algorithm this maker runs.  Fixed at construction: only the
    /// matching baseline sampler is built, so reassigning this afterwards
    /// panics on the next `make`.
    pub kind: SamplerKind,
    /// Mini-batch size `B`.
    pub batch: usize,
    /// Padded edge-list capacity of the target artifact.
    pub edge_cap: usize,
    d_in: usize,
    source: Source,
    uniform: UniformVertexSampler,
    sage: Option<GraphSageSampler>,
    saint: Option<GraphSaintNodeSampler>,
}

impl BatchMaker {
    /// Maker over an in-memory dataset (any [`SamplerKind`]).  Only the
    /// sampler matching `kind` is constructed — GraphSAINT in particular
    /// precomputes O(n) degree tables that would be dead weight otherwise.
    pub fn new(
        data: Arc<Dataset>,
        kind: SamplerKind,
        batch: usize,
        edge_cap: usize,
        layers: usize,
        group_seed: u64,
    ) -> BatchMaker {
        BatchMaker {
            kind,
            batch,
            edge_cap,
            d_in: data.features.cols,
            uniform: UniformVertexSampler::new(data.n, batch, group_seed),
            sage: (kind == SamplerKind::GraphSage)
                .then(|| GraphSageSampler::new(batch, layers, group_seed)),
            saint: (kind == SamplerKind::GraphSaintNode)
                .then(|| GraphSaintNodeSampler::new(&data, batch, group_seed)),
            source: Source::Mem(data),
        }
    }

    /// Maker over an out-of-core `.pallas` store.  Only ScaleGNN uniform
    /// sampling is supported out-of-core (the baselines need the raw
    /// adjacency and degree statistics, which the store does not carry).
    pub fn from_store(
        store: Arc<OocGraph>,
        batch: usize,
        edge_cap: usize,
        group_seed: u64,
    ) -> BatchMaker {
        BatchMaker {
            kind: SamplerKind::ScaleGnnUniform,
            batch,
            edge_cap,
            d_in: store.d_in,
            uniform: UniformVertexSampler::new(store.n, batch, group_seed),
            sage: None,
            saint: None,
            source: Source::Ooc(store),
        }
    }

    /// Build the batch for `step` (Algorithm 1 for ScaleGNN; the baselines'
    /// own pipelines otherwise).
    pub fn make(&mut self, step: u64) -> BatchData {
        let b = self.batch;
        let (vertices, adj, weights): (Vec<u32>, _, Vec<f32>) = match (&self.source, self.kind) {
            (Source::Mem(d), SamplerKind::ScaleGnnUniform) => {
                let s = self.uniform.sample(step);
                let mb = induce_rescaled(&d.adj, &s, self.uniform.inclusion_prob());
                // loss on sampled train-split vertices
                let w = s
                    .iter()
                    .map(|&v| if d.split[v as usize] == 0 { 1.0 } else { 0.0 })
                    .collect();
                (s, mb.adj, w)
            }
            (Source::Ooc(g), SamplerKind::ScaleGnnUniform) => {
                let s = self.uniform.sample(step);
                let mb = induce_rescaled_from(g.as_ref(), &s, self.uniform.inclusion_prob());
                let w = s
                    .iter()
                    .map(|&v| if g.split_of(v as usize) == 0 { 1.0 } else { 0.0 })
                    .collect();
                (s, mb.adj, w)
            }
            (Source::Mem(d), SamplerKind::GraphSage) => {
                let sb = self
                    .sage
                    .as_ref()
                    .expect("in-memory maker carries the GraphSAGE sampler")
                    .sample(d, step, true);
                (sb.vertices, sb.adj, sb.loss_weight)
            }
            (Source::Mem(d), SamplerKind::GraphSaintNode) => {
                let sb = self
                    .saint
                    .as_ref()
                    .expect("in-memory maker carries the GraphSAINT sampler")
                    .sample(d, step);
                let w = sb
                    .vertices
                    .iter()
                    .zip(&sb.loss_weight)
                    .map(|(&v, &lw)| if d.split[v as usize] == 0 { lw } else { 0.0 })
                    .collect();
                (sb.vertices, sb.adj, w)
            }
            (Source::Ooc(_), kind) => {
                panic!("sampler {kind:?} is not supported out-of-core (uniform only)")
            }
        };

        // flatten the induced CSR into the padded edge list
        let cap = self.edge_cap;
        let mut src = vec![0i32; cap];
        let mut dst = vec![0i32; cap];
        let mut val = vec![0.0f32; cap];
        let mut k = 0usize;
        let mut truncated = 0usize;
        for r in 0..adj.rows {
            let (cs, vs) = adj.row(r);
            for (&c, &w) in cs.iter().zip(vs) {
                if k < cap {
                    dst[k] = r as i32;
                    src[k] = c as i32;
                    val[k] = w;
                    k += 1;
                } else {
                    truncated += 1;
                }
            }
        }

        let mut x = vec![0.0f32; b * self.d_in];
        let mut y = vec![0i32; b];
        {
            let vd: &dyn VertexData = match &self.source {
                Source::Mem(d) => d.as_ref(),
                Source::Ooc(g) => g.as_ref(),
            };
            for (i, &v) in vertices.iter().enumerate() {
                vd.read_features(v as usize, &mut x[i * self.d_in..(i + 1) * self.d_in]);
                y[i] = vd.label_of(v as usize) as i32;
            }
        }
        BatchData { step, src, dst, val, x, y, wmask: weights, truncated }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::datasets;

    fn maker(kind: SamplerKind) -> BatchMaker {
        let d = Arc::new(datasets::load("tiny").unwrap());
        BatchMaker::new(d, kind, 32, 512, 2, 9)
    }

    #[test]
    fn shapes_are_fixed_for_all_samplers() {
        for kind in [
            SamplerKind::ScaleGnnUniform,
            SamplerKind::GraphSage,
            SamplerKind::GraphSaintNode,
        ] {
            let mut m = maker(kind);
            let b = m.make(0);
            assert_eq!(b.src.len(), 512, "{kind:?}");
            assert_eq!(b.val.len(), 512);
            assert_eq!(b.truncated, 0, "{kind:?}");
            assert!(b.val.iter().any(|&v| v != 0.0), "{kind:?} has edges");
            assert_eq!(b.x.len(), 32 * 16);
            assert_eq!(b.y.len(), 32);
            assert_eq!(b.wmask.len(), 32);
            assert!(b.wmask.iter().any(|&w| w > 0.0), "{kind:?} has loss rows");
        }
    }

    #[test]
    fn uniform_wmask_is_train_split() {
        let mut m = maker(SamplerKind::ScaleGnnUniform);
        let d = datasets::load("tiny").unwrap();
        let s = m.uniform.sample(3);
        let b = m.make(3);
        for (i, &v) in s.iter().enumerate() {
            assert_eq!(b.wmask[i] > 0.0, d.split[v as usize] == 0);
        }
    }

    #[test]
    fn batches_differ_across_steps() {
        let mut m = maker(SamplerKind::ScaleGnnUniform);
        let b0 = m.make(0);
        let b1 = m.make(1);
        assert_ne!(b0.y, b1.y);
    }
}
