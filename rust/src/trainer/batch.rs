//! Mini-batch assembly for the PJRT train-step artifacts: every sampler
//! (ScaleGNN uniform, GraphSAGE, GraphSAINT) is reduced to the same
//! fixed-shape payload `(src[E], dst[E], val[E], X[B,d_in], y[B],
//! wmask[B])` — a padded edge list plus gathered features/labels.
//!
//! The maker reads its graph through the `graph::store` access traits, so
//! the ScaleGNN uniform path can be fed either by an in-memory [`Dataset`]
//! ([`BatchMaker::new`]) or by an on-disk `.pallas` store
//! ([`BatchMaker::from_store`]); same seed, same batch — bitwise — either
//! way.  The baseline samplers need `raw_adj`/degree statistics and remain
//! in-memory only.
//!
//! The ScaleGNN path runs the sampling fast path (`sampling::uniform`):
//! sort-free induction into a reused [`MiniBatch`] slot (the transpose is
//! skipped — the edge-list payload never reads it), a row-parallel feature
//! gather, and recycled [`BatchData`] buffers ([`BatchMaker::recycle`]),
//! so the steady-state `make()` performs ~zero heap allocations
//! (asserted by `tests/alloc_batch.rs`).

use std::sync::Arc;

use crate::graph::store::{OocGraph, VertexData};
use crate::graph::Dataset;
use crate::sampling::{
    sample_and_induce_into, GraphSageSampler, GraphSaintNodeSampler, InduceWorkspace, MiniBatch,
    SamplerKind, UniformVertexSampler,
};

/// One step's packed inputs (ready to become literals).  The adjacency is
/// a padded edge list (`edge_cap` entries; padding has val = 0) — the
/// CPU-efficient sparse-SpMM lowering (EXPERIMENTS.md §Perf L2).
pub struct BatchData {
    /// Step index this batch was built for.
    pub step: u64,
    /// Edge sources in the compact `[0, B)` namespace (padded).
    pub src: Vec<i32>,
    /// Edge destinations in the compact namespace (padded).
    pub dst: Vec<i32>,
    /// Edge weights (0 for padding slots).
    pub val: Vec<f32>,
    /// Row-major `B x d_in` gathered features.
    pub x: Vec<f32>,
    /// Labels per batch slot.
    pub y: Vec<i32>,
    /// Per-slot loss weight (0 masks a slot out of the loss).
    pub wmask: Vec<f32>,
    /// edges dropped because the batch exceeded edge_cap (0 in practice;
    /// surfaced as a trainer warning + session `truncated_edges` detail)
    pub truncated: usize,
}

impl BatchData {
    /// An empty shell for [`BatchMaker::make_into`] to fill; buffers grow
    /// on first use and are reused when the shell is recycled.
    pub fn empty() -> BatchData {
        BatchData {
            step: 0,
            src: Vec::new(),
            dst: Vec::new(),
            val: Vec::new(),
            x: Vec::new(),
            y: Vec::new(),
            wmask: Vec::new(),
            truncated: 0,
        }
    }
}

/// Where the maker reads graph + vertex data from.
enum Source {
    /// Fully in-memory generated dataset.
    Mem(Arc<Dataset>),
    /// Disk-backed `.pallas` store (ScaleGNN uniform sampling only).
    Ooc(Arc<OocGraph>),
}

/// Stateful batch factory for one DP group.
pub struct BatchMaker {
    /// Sampling algorithm this maker runs.  Fixed at construction: only the
    /// matching baseline sampler is built, so reassigning this afterwards
    /// panics on the next `make`.
    pub kind: SamplerKind,
    /// Mini-batch size `B`.
    pub batch: usize,
    /// Padded edge-list capacity of the target artifact.
    pub edge_cap: usize,
    d_in: usize,
    source: Source,
    uniform: UniformVertexSampler,
    sage: Option<GraphSageSampler>,
    saint: Option<GraphSaintNodeSampler>,
    /// sampling fast-path scratch (RNG overlay, sample, induction segments)
    ws: InduceWorkspace,
    /// reused induced-subgraph slot (vertices + adjacency; no transpose)
    mb: MiniBatch,
    /// recycled output shells ([`BatchMaker::recycle`])
    free: Vec<BatchData>,
}

impl BatchMaker {
    /// Maker over an in-memory dataset (any [`SamplerKind`]).  Only the
    /// sampler matching `kind` is constructed — GraphSAINT in particular
    /// precomputes O(n) degree tables that would be dead weight otherwise.
    pub fn new(
        data: Arc<Dataset>,
        kind: SamplerKind,
        batch: usize,
        edge_cap: usize,
        layers: usize,
        group_seed: u64,
    ) -> BatchMaker {
        BatchMaker {
            kind,
            batch,
            edge_cap,
            d_in: data.features.cols,
            uniform: UniformVertexSampler::new(data.n, batch, group_seed),
            sage: (kind == SamplerKind::GraphSage)
                .then(|| GraphSageSampler::new(batch, layers, group_seed)),
            saint: (kind == SamplerKind::GraphSaintNode)
                .then(|| GraphSaintNodeSampler::new(&data, batch, group_seed)),
            source: Source::Mem(data),
            ws: InduceWorkspace::new(),
            mb: MiniBatch::default(),
            free: Vec::new(),
        }
    }

    /// Maker over an out-of-core `.pallas` store.  Only ScaleGNN uniform
    /// sampling is supported out-of-core (the baselines need the raw
    /// adjacency and degree statistics, which the store does not carry).
    pub fn from_store(
        store: Arc<OocGraph>,
        batch: usize,
        edge_cap: usize,
        group_seed: u64,
    ) -> BatchMaker {
        BatchMaker {
            kind: SamplerKind::ScaleGnnUniform,
            batch,
            edge_cap,
            d_in: store.d_in,
            uniform: UniformVertexSampler::new(store.n, batch, group_seed),
            sage: None,
            saint: None,
            source: Source::Ooc(store),
            ws: InduceWorkspace::new(),
            mb: MiniBatch::default(),
            free: Vec::new(),
        }
    }

    /// Build the batch for `step` (Algorithm 1 for ScaleGNN; the baselines'
    /// own pipelines otherwise).  Pops a recycled shell when one is
    /// available ([`BatchMaker::recycle`]), so the double-buffered
    /// steady state allocates nothing.
    pub fn make(&mut self, step: u64) -> BatchData {
        let mut out = self.free.pop().unwrap_or_else(BatchData::empty);
        self.make_into(step, &mut out);
        out
    }

    /// Return a spent batch's buffers for reuse by a later
    /// [`BatchMaker::make`] — the consumer half of the double-buffer
    /// recycle loop (`trainer`'s prefetcher sends shells back over a
    /// channel; the inline path recycles directly).
    pub fn recycle(&mut self, spent: BatchData) {
        // a couple of shells cover every double-buffering depth in use;
        // beyond that just drop (a burst would otherwise pin memory)
        if self.free.len() < 4 {
            self.free.push(spent);
        }
    }

    /// [`BatchMaker::make`] into a caller-owned shell: every output buffer
    /// is cleared and refilled, never reallocated once grown.
    pub fn make_into(&mut self, step: u64, out: &mut BatchData) {
        let b = self.batch;
        let cap = self.edge_cap;
        out.step = step;
        out.wmask.clear();

        // --- sample + induce into the reused `self.mb` slot ---
        match (&self.source, self.kind) {
            (Source::Mem(d), SamplerKind::ScaleGnnUniform) => {
                // fast path: sort-free induction, no transpose (the edge
                // list below never reads adj_t)
                sample_and_induce_into(
                    &d.adj,
                    &self.uniform,
                    step,
                    false,
                    &mut self.ws,
                    &mut self.mb,
                );
                // loss on sampled train-split vertices
                for &v in &self.mb.vertices {
                    out.wmask.push(if d.split[v as usize] == 0 { 1.0 } else { 0.0 });
                }
            }
            (Source::Ooc(g), SamplerKind::ScaleGnnUniform) => {
                sample_and_induce_into(
                    g.as_ref(),
                    &self.uniform,
                    step,
                    false,
                    &mut self.ws,
                    &mut self.mb,
                );
                for &v in &self.mb.vertices {
                    out.wmask.push(if g.split_of(v as usize) == 0 { 1.0 } else { 0.0 });
                }
            }
            (Source::Mem(d), SamplerKind::GraphSage) => {
                let sb = self
                    .sage
                    .as_ref()
                    .expect("in-memory maker carries the GraphSAGE sampler")
                    .sample(d, step, true);
                out.wmask.extend_from_slice(&sb.loss_weight);
                self.mb.vertices = sb.vertices;
                self.mb.adj = sb.adj;
            }
            (Source::Mem(d), SamplerKind::GraphSaintNode) => {
                let sb = self
                    .saint
                    .as_ref()
                    .expect("in-memory maker carries the GraphSAINT sampler")
                    .sample(d, step);
                for (&v, &lw) in sb.vertices.iter().zip(&sb.loss_weight) {
                    out.wmask.push(if d.split[v as usize] == 0 { lw } else { 0.0 });
                }
                self.mb.vertices = sb.vertices;
                self.mb.adj = sb.adj;
            }
            (Source::Ooc(_), kind) => {
                panic!("sampler {kind:?} is not supported out-of-core (uniform only)")
            }
        }

        // --- flatten the induced CSR into the padded edge list ---
        // (the zero-refill after clear is the padding contract)
        out.src.clear();
        out.src.resize(cap, 0);
        out.dst.clear();
        out.dst.resize(cap, 0);
        out.val.clear();
        out.val.resize(cap, 0.0);
        let adj = &self.mb.adj;
        let mut k = 0usize;
        let mut truncated = 0usize;
        for r in 0..adj.rows {
            let (cs, vs) = adj.row(r);
            for (&c, &w) in cs.iter().zip(vs) {
                if k < cap {
                    out.dst[k] = r as i32;
                    out.src[k] = c as i32;
                    out.val[k] = w;
                    k += 1;
                } else {
                    truncated += 1;
                }
            }
        }
        out.truncated = truncated;

        // --- gather features (row-parallel) and labels ---
        let d_in = self.d_in;
        out.x.clear();
        out.x.resize(b * d_in, 0.0);
        out.y.clear();
        out.y.resize(b, 0);
        let vertices = &self.mb.vertices;
        let rows = vertices.len().min(b);
        let vd: &dyn VertexData = match &self.source {
            Source::Mem(d) => d.as_ref(),
            Source::Ooc(g) => g.as_ref(),
        };
        if d_in > 0 && rows > 0 {
            crate::tensor::pool::par_row_blocks(
                &mut out.x[..rows * d_in],
                rows,
                d_in,
                crate::tensor::pool::num_threads(),
                4 * rows * d_in,
                |r0, chunk| {
                    let nr = chunk.len() / d_in;
                    for i in 0..nr {
                        vd.read_features(
                            vertices[r0 + i] as usize,
                            &mut chunk[i * d_in..(i + 1) * d_in],
                        );
                    }
                },
            );
        }
        for (i, &v) in vertices.iter().take(b).enumerate() {
            out.y[i] = vd.label_of(v as usize) as i32;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::datasets;

    fn maker(kind: SamplerKind) -> BatchMaker {
        let d = Arc::new(datasets::load("tiny").unwrap());
        BatchMaker::new(d, kind, 32, 512, 2, 9)
    }

    #[test]
    fn shapes_are_fixed_for_all_samplers() {
        for kind in [
            SamplerKind::ScaleGnnUniform,
            SamplerKind::GraphSage,
            SamplerKind::GraphSaintNode,
        ] {
            let mut m = maker(kind);
            let b = m.make(0);
            assert_eq!(b.src.len(), 512, "{kind:?}");
            assert_eq!(b.val.len(), 512);
            assert_eq!(b.truncated, 0, "{kind:?}");
            assert!(b.val.iter().any(|&v| v != 0.0), "{kind:?} has edges");
            assert_eq!(b.x.len(), 32 * 16);
            assert_eq!(b.y.len(), 32);
            assert_eq!(b.wmask.len(), 32);
            assert!(b.wmask.iter().any(|&w| w > 0.0), "{kind:?} has loss rows");
        }
    }

    #[test]
    fn uniform_wmask_is_train_split() {
        let mut m = maker(SamplerKind::ScaleGnnUniform);
        let d = datasets::load("tiny").unwrap();
        let s = m.uniform.sample(3);
        let b = m.make(3);
        for (i, &v) in s.iter().enumerate() {
            assert_eq!(b.wmask[i] > 0.0, d.split[v as usize] == 0);
        }
    }

    #[test]
    fn batches_differ_across_steps() {
        let mut m = maker(SamplerKind::ScaleGnnUniform);
        let b0 = m.make(0);
        let b1 = m.make(1);
        assert_ne!(b0.y, b1.y);
    }

    #[test]
    fn recycled_shells_rebuild_bitwise_identical_batches() {
        for kind in [
            SamplerKind::ScaleGnnUniform,
            SamplerKind::GraphSage,
            SamplerKind::GraphSaintNode,
        ] {
            let mut fresh = maker(kind);
            let mut recycled = maker(kind);
            for step in 0..5u64 {
                let want = fresh.make(step);
                let got = recycled.make(step);
                assert_eq!(got.step, want.step, "{kind:?} step {step}");
                assert_eq!(got.src, want.src, "{kind:?} step {step}");
                assert_eq!(got.dst, want.dst);
                assert_eq!(got.val, want.val);
                assert_eq!(got.x, want.x);
                assert_eq!(got.y, want.y);
                assert_eq!(got.wmask, want.wmask);
                assert_eq!(got.truncated, want.truncated);
                recycled.recycle(got); // reuse the same shell every step
            }
        }
    }

    #[test]
    fn tiny_edge_cap_reports_truncation() {
        let d = Arc::new(datasets::load("tiny").unwrap());
        let mut m = BatchMaker::new(d, SamplerKind::ScaleGnnUniform, 32, 1, 2, 9);
        let b = m.make(0);
        // a 32-vertex induced subgraph always carries > 1 edge (self loops)
        assert!(b.truncated > 0, "edge_cap 1 must truncate");
        assert_eq!(b.val.len(), 1);
    }
}
