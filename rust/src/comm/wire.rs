//! Wire format of the socket transports: length-prefixed, CRC-checked
//! frames with magic and version, following the checkpoint module's
//! validated-decode discipline (`checkpoint::mod` — magic, version,
//! exact lengths, CRC32 trailer, and a decoder that *returns* errors,
//! never panics).
//!
//! ```text
//!   offset  size  field
//!   0       4     magic  "PLSW"
//!   4       2     version (u16 LE)
//!   6       2     frame type (u16 LE, FrameType)
//!   8       4     payload length (u32 LE, <= MAX_FRAME_PAYLOAD)
//!   12      n     payload (per-type encoding, all integers LE)
//!   12+n    4     CRC32 (u32 LE) over header + payload
//! ```
//!
//! Every multi-byte integer is little-endian.  bf16 contributions —
//! reduce *and* gather — travel as the high 16 bits of the
//! already-rounded f32, and a bf16 gather's broadcast result ships the
//! same half-width bits back out, so both wire directions are lossless at
//! half the bytes, mirroring the §V-B byte accounting.  (Wire version 2
//! added the gather precision; version 3 added the [`FailureKind`] byte
//! on `Poison` frames and the `Rollback` frame that offers survivors a
//! rejoin instead of a teardown; older peers are rejected with
//! [`WireError::BadVersion`].)
//!
//! The decoder ([`read_msg`]) classifies every way a frame can be bad
//! (truncated, wrong magic, unsupported version, unknown type, oversized
//! length, CRC mismatch, malformed payload) into a descriptive
//! [`WireError`]; the adversarial battery in
//! `tests/transport_conformance.rs` feeds it each class and asserts the
//! message, and the live transports convert the error into a
//! [`CommError`](super::CommError) naming the peer that sent the bytes.

use std::io::{self, Read, Write};

use super::{CollKind, CommError, FailureKind, Precision};
use crate::checkpoint::crc32;
use crate::grid::Axis;
use crate::util::bf16_round;
use crate::util::bytes::{f32_le, u16_le, u32_le, u64_le};

/// Frame magic: "PLSW" (PaLlaS Wire).
pub const WIRE_MAGIC: [u8; 4] = *b"PLSW";
/// Wire protocol version; bumped on any frame-format change (2: bf16
/// gather contributions and half-width gather results; 3: failure-kind
/// byte on poison frames plus the `Rollback` re-form offer).
pub const WIRE_VERSION: u16 = 3;
/// Hard cap on a frame payload (64 MiB) — a corrupted length prefix must
/// fail fast, not trigger a giant allocation.
pub const MAX_FRAME_PAYLOAD: usize = 64 << 20;

const HEADER_BYTES: usize = 12;

/// Everything that can be wrong with bytes arriving on a transport
/// connection.  Every variant renders to a human-readable description
/// that the transports embed in the resulting [`CommError`].
#[derive(Debug)]
pub enum WireError {
    /// Clean EOF on a frame boundary (peer closed the connection).
    Closed,
    /// The stream ended mid-header or mid-payload.
    Truncated {
        /// Which part of the frame was being read.
        what: &'static str,
        /// Bytes actually read.
        got: usize,
        /// Bytes the frame promised.
        want: usize,
    },
    /// The 4 magic bytes were not [`WIRE_MAGIC`].
    BadMagic([u8; 4]),
    /// The version field names a protocol this build does not speak.
    BadVersion(u16),
    /// The frame-type field is not a known [`FrameType`].
    BadFrameType(u16),
    /// The length prefix exceeds [`MAX_FRAME_PAYLOAD`].
    Oversized(usize),
    /// The CRC32 trailer does not match the received header + payload.
    BadCrc {
        /// CRC computed over the received bytes.
        computed: u32,
        /// CRC the frame carried.
        carried: u32,
    },
    /// Header and CRC were fine but the payload does not decode as the
    /// frame type's encoding.
    Malformed(String),
    /// An I/O error below the framing layer.
    Io(String),
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::Closed => write!(f, "connection closed"),
            WireError::Truncated { what, got, want } => {
                write!(f, "truncated frame: {what} ended after {got} of {want} bytes")
            }
            WireError::BadMagic(m) => {
                write!(f, "bad frame magic {m:02x?} (want {WIRE_MAGIC:02x?} \"PLSW\")")
            }
            WireError::BadVersion(v) => {
                write!(f, "unsupported wire version {v} (this build speaks {WIRE_VERSION})")
            }
            WireError::BadFrameType(t) => write!(f, "unknown frame type {t}"),
            WireError::Oversized(n) => {
                write!(f, "frame payload of {n} bytes exceeds the {MAX_FRAME_PAYLOAD} B cap")
            }
            WireError::BadCrc { computed, carried } => {
                write!(f, "frame CRC mismatch: computed {computed:08x}, trailer {carried:08x}")
            }
            WireError::Malformed(s) => write!(f, "malformed frame payload: {s}"),
            WireError::Io(s) => write!(f, "wire i/o error: {s}"),
        }
    }
}

impl std::error::Error for WireError {}

/// Frame types (the u16 at header offset 6).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u16)]
pub enum FrameType {
    /// Rank → coordinator: registration (rank + expected grid).
    Hello = 1,
    /// Coordinator → rank: the world assembled.
    Welcome = 2,
    /// Rank → coordinator: a collective contribution.
    Contribute = 3,
    /// Coordinator → rank: a completed reduction's result.
    ReduceResult = 4,
    /// Coordinator → rank: a completed gather's payloads.
    GatherResult = 5,
    /// Rank → coordinator: barrier arrival.
    Barrier = 6,
    /// Coordinator → rank: barrier release.
    BarrierRelease = 7,
    /// Either direction: a structured failure origin.
    Poison = 8,
    /// Rank → coordinator: heartbeat.
    Ping = 9,
    /// Rank → coordinator: clean completion.
    Bye = 10,
    /// Coordinator → rank: the world is re-forming around the carried
    /// failure; the receiver may re-register (same payload as `Poison`).
    Rollback = 11,
}

impl FrameType {
    fn from_u16(t: u16) -> Option<FrameType> {
        match t {
            1 => Some(FrameType::Hello),
            2 => Some(FrameType::Welcome),
            3 => Some(FrameType::Contribute),
            4 => Some(FrameType::ReduceResult),
            5 => Some(FrameType::GatherResult),
            6 => Some(FrameType::Barrier),
            7 => Some(FrameType::BarrierRelease),
            8 => Some(FrameType::Poison),
            9 => Some(FrameType::Ping),
            10 => Some(FrameType::Bye),
            11 => Some(FrameType::Rollback),
            _ => None,
        }
    }
}

/// A decoded frame.
#[derive(Clone, Debug, PartialEq)]
pub enum Msg {
    /// Rank registration: global rank + the grid it was launched for
    /// (the coordinator rejects a rank whose grid disagrees).
    Hello {
        /// Global rank registering.
        rank: u32,
        /// Grid shape as `[gd, gx, gy, gz]`.
        grid: [u32; 4],
    },
    /// World assembly complete; collectives may start.
    Welcome {
        /// World size the coordinator assembled.
        world: u32,
        /// Heartbeat interval the coordinator expects (0 = no heartbeat).
        heartbeat_ms: u32,
    },
    /// One rank's contribution to the sequence-matched op at
    /// (`axis`, sender's group, `seq`).
    Contribute {
        /// Axis of the group.
        axis: Axis,
        /// Group sequence number.
        seq: u64,
        /// Collective kind (handshake-checked against the slot).
        kind: CollKind,
        /// The payload (bf16 reduces are already rounded).
        data: Vec<f32>,
    },
    /// Ordered-sum result of a completed reduce.
    ReduceResult {
        /// Axis of the group.
        axis: Axis,
        /// Group sequence number.
        seq: u64,
        /// The reduced payload.
        data: Vec<f32>,
    },
    /// Payloads of a completed gather, group-index order.
    GatherResult {
        /// Axis of the group.
        axis: Axis,
        /// Group sequence number.
        seq: u64,
        /// Gather precision: bf16 results ship as high-16-bit halves
        /// (the payloads are already rounded, so the transit is lossless).
        prec: Precision,
        /// Per-member payloads ordered by index in group.
        parts: Vec<Vec<f32>>,
    },
    /// Barrier arrival `bseq` on `axis` (per-axis barrier counter).
    Barrier {
        /// Axis of the barrier group.
        axis: Axis,
        /// Per-axis barrier sequence number.
        bseq: u64,
    },
    /// All members arrived at barrier `bseq` on `axis`.
    BarrierRelease {
        /// Axis of the barrier group.
        axis: Axis,
        /// Per-axis barrier sequence number.
        bseq: u64,
    },
    /// A structured failure origin (rank → coordinator on injected
    /// faults; coordinator → every rank on any world death).
    Poison {
        /// The failure origin, carried unchanged through the cascade.
        err: CommError,
    },
    /// Heartbeat.
    Ping,
    /// Clean completion; the sender will close its connection.
    Bye,
    /// The coordinator is re-forming the world around the carried
    /// failure instead of tearing it down: the receiving rank's current
    /// collectives die with this origin, and the process may reconnect
    /// and re-register into the same coordinator within the rejoin
    /// grace window.
    Rollback {
        /// The failure the world is re-forming around.
        err: CommError,
    },
}

// Op-name codes for CommError::op over the wire.  CommError.op is a
// &'static str, so decode maps back onto the canonical strings.
fn op_code(op: &str) -> u8 {
    match op {
        "all_reduce" => 0,
        "all_gather" => 1,
        "injected-fault" => 2,
        "rank-death" => 3,
        "coordinator-lost" => 4,
        "protocol" => 5,
        "barrier" => 6,
        _ => 255,
    }
}

fn op_from_code(c: u8) -> &'static str {
    match c {
        0 => "all_reduce",
        1 => "all_gather",
        2 => "injected-fault",
        3 => "rank-death",
        4 => "coordinator-lost",
        5 => "protocol",
        6 => "barrier",
        _ => "remote-failure",
    }
}

fn kind_code(k: FailureKind) -> u8 {
    match k {
        FailureKind::Fault => 0,
        FailureKind::Stalled => 1,
        FailureKind::Death => 2,
    }
}

fn kind_from_code(c: u8) -> Result<FailureKind, WireError> {
    match c {
        0 => Ok(FailureKind::Fault),
        1 => Ok(FailureKind::Stalled),
        2 => Ok(FailureKind::Death),
        k => Err(WireError::Malformed(format!("unknown failure kind {k}"))),
    }
}

struct Enc(Vec<u8>);

impl Enc {
    fn u8(&mut self, v: u8) {
        self.0.push(v);
    }
    fn u16(&mut self, v: u16) {
        self.0.extend_from_slice(&v.to_le_bytes());
    }
    fn u32(&mut self, v: u32) {
        self.0.extend_from_slice(&v.to_le_bytes());
    }
    fn u64(&mut self, v: u64) {
        self.0.extend_from_slice(&v.to_le_bytes());
    }
    fn f32s(&mut self, vs: &[f32]) {
        self.0.reserve(vs.len() * 4);
        for &v in vs {
            self.0.extend_from_slice(&v.to_le_bytes());
        }
    }
    /// bf16 payload: the high 16 bits of each (rounded) f32.  Rounding
    /// here is idempotent when the caller already rounded, so both the
    /// rank→coordinator and coordinator→rank legs use this one encoder.
    fn bf16s(&mut self, vs: &[f32]) {
        self.0.reserve(vs.len() * 2);
        for &v in vs {
            self.u16((bf16_round(v).to_bits() >> 16) as u16);
        }
    }
}

struct Dec<'a> {
    b: &'a [u8],
    at: usize,
}

impl<'a> Dec<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8], WireError> {
        if self.at + n > self.b.len() {
            return Err(WireError::Malformed(format!(
                "payload needs {} more bytes at offset {}, {} remain",
                n,
                self.at,
                self.b.len() - self.at
            )));
        }
        let s = &self.b[self.at..self.at + n];
        self.at += n;
        Ok(s)
    }
    fn u8(&mut self) -> Result<u8, WireError> {
        Ok(self.take(1)?[0])
    }
    fn u32(&mut self) -> Result<u32, WireError> {
        Ok(u32_le(self.take(4)?))
    }
    fn u64(&mut self) -> Result<u64, WireError> {
        Ok(u64_le(self.take(8)?))
    }
    fn f32s(&mut self, n: usize) -> Result<Vec<f32>, WireError> {
        let raw = self.take(n * 4)?;
        Ok(raw.chunks_exact(4).map(f32_le).collect())
    }
    /// Widen a bf16 payload (high-16-bit halves) back to f32.
    fn bf16s(&mut self, n: usize) -> Result<Vec<f32>, WireError> {
        let raw = self.take(n * 2)?;
        Ok(raw.chunks_exact(2).map(|c| f32::from_bits((u16_le(c) as u32) << 16)).collect())
    }
    fn axis(&mut self) -> Result<Axis, WireError> {
        let c = self.u8()?;
        Axis::from_code(c).ok_or_else(|| WireError::Malformed(format!("unknown axis code {c}")))
    }
    fn finished(&self) -> Result<(), WireError> {
        if self.at != self.b.len() {
            return Err(WireError::Malformed(format!(
                "{} trailing payload bytes after a complete message",
                self.b.len() - self.at
            )));
        }
        Ok(())
    }
}

fn encode(msg: &Msg) -> (FrameType, Vec<u8>) {
    let mut e = Enc(Vec::new());
    let ty = match msg {
        Msg::Hello { rank, grid } => {
            e.u32(*rank);
            for &g in grid {
                e.u32(g);
            }
            FrameType::Hello
        }
        Msg::Welcome { world, heartbeat_ms } => {
            e.u32(*world);
            e.u32(*heartbeat_ms);
            FrameType::Welcome
        }
        Msg::Contribute { axis, seq, kind, data } => {
            e.u8(axis.code());
            e.u8(match kind {
                CollKind::Reduce(Precision::Fp32) => 0,
                CollKind::Reduce(Precision::Bf16) => 1,
                CollKind::Gather(Precision::Fp32) => 2,
                CollKind::Gather(Precision::Bf16) => 3,
            });
            e.u64(*seq);
            e.u32(data.len() as u32);
            match kind.precision() {
                Precision::Bf16 => e.bf16s(data),
                Precision::Fp32 => e.f32s(data),
            }
            FrameType::Contribute
        }
        Msg::ReduceResult { axis, seq, data } => {
            e.u8(axis.code());
            e.u64(*seq);
            e.u32(data.len() as u32);
            e.f32s(data);
            FrameType::ReduceResult
        }
        Msg::GatherResult { axis, seq, prec, parts } => {
            e.u8(axis.code());
            e.u64(*seq);
            e.u8(match prec {
                Precision::Fp32 => 0,
                Precision::Bf16 => 1,
            });
            e.u32(parts.len() as u32);
            for p in parts {
                e.u32(p.len() as u32);
                match prec {
                    Precision::Bf16 => e.bf16s(p),
                    Precision::Fp32 => e.f32s(p),
                }
            }
            FrameType::GatherResult
        }
        Msg::Barrier { axis, bseq } => {
            e.u8(axis.code());
            e.u64(*bseq);
            FrameType::Barrier
        }
        Msg::BarrierRelease { axis, bseq } => {
            e.u8(axis.code());
            e.u64(*bseq);
            FrameType::BarrierRelease
        }
        Msg::Poison { err } => {
            encode_err(&mut e, err);
            FrameType::Poison
        }
        Msg::Rollback { err } => {
            encode_err(&mut e, err);
            FrameType::Rollback
        }
        Msg::Ping => FrameType::Ping,
        Msg::Bye => FrameType::Bye,
    };
    (ty, e.0)
}

// Poison and Rollback share one CommError payload encoding: rank, seq,
// op code, failure-kind code, axis code, then the length-prefixed
// message bytes.
fn encode_err(e: &mut Enc, err: &CommError) {
    e.u32(err.rank as u32);
    e.u64(err.seq);
    e.u8(op_code(err.op));
    e.u8(kind_code(err.kind));
    e.u8(err.axis.code());
    let m = err.msg.as_bytes();
    e.u32(m.len() as u32);
    e.0.extend_from_slice(m);
}

fn decode_err(d: &mut Dec<'_>) -> Result<CommError, WireError> {
    let rank = d.u32()? as usize;
    let seq = d.u64()?;
    let op = op_from_code(d.u8()?);
    let kind = kind_from_code(d.u8()?)?;
    let axis = d.axis()?;
    let ml = d.u32()? as usize;
    let msg = String::from_utf8(d.take(ml)?.to_vec())
        .map_err(|_| WireError::Malformed("poison message is not UTF-8".into()))?;
    let mut err = CommError::new(rank, seq, op, axis, msg);
    err.kind = kind;
    Ok(err)
}

fn decode(ty: FrameType, payload: &[u8]) -> Result<Msg, WireError> {
    let mut d = Dec { b: payload, at: 0 };
    let msg = match ty {
        FrameType::Hello => {
            let rank = d.u32()?;
            let grid = [d.u32()?, d.u32()?, d.u32()?, d.u32()?];
            Msg::Hello { rank, grid }
        }
        FrameType::Welcome => Msg::Welcome { world: d.u32()?, heartbeat_ms: d.u32()? },
        FrameType::Contribute => {
            let axis = d.axis()?;
            let kc = d.u8()?;
            let seq = d.u64()?;
            let n = d.u32()? as usize;
            let (kind, data) = match kc {
                0 => (CollKind::Reduce(Precision::Fp32), d.f32s(n)?),
                1 => (CollKind::Reduce(Precision::Bf16), d.bf16s(n)?),
                2 => (CollKind::Gather(Precision::Fp32), d.f32s(n)?),
                3 => (CollKind::Gather(Precision::Bf16), d.bf16s(n)?),
                k => return Err(WireError::Malformed(format!("unknown collective kind {k}"))),
            };
            Msg::Contribute { axis, seq, kind, data }
        }
        FrameType::ReduceResult => {
            let axis = d.axis()?;
            let seq = d.u64()?;
            let n = d.u32()? as usize;
            Msg::ReduceResult { axis, seq, data: d.f32s(n)? }
        }
        FrameType::GatherResult => {
            let axis = d.axis()?;
            let seq = d.u64()?;
            let prec = match d.u8()? {
                0 => Precision::Fp32,
                1 => Precision::Bf16,
                p => {
                    return Err(WireError::Malformed(format!("unknown gather precision {p}")));
                }
            };
            let np = d.u32()? as usize;
            let mut parts = Vec::with_capacity(np.min(1 << 16));
            for _ in 0..np {
                let n = d.u32()? as usize;
                parts.push(match prec {
                    Precision::Bf16 => d.bf16s(n)?,
                    Precision::Fp32 => d.f32s(n)?,
                });
            }
            Msg::GatherResult { axis, seq, prec, parts }
        }
        FrameType::Barrier => Msg::Barrier { axis: d.axis()?, bseq: d.u64()? },
        FrameType::BarrierRelease => Msg::BarrierRelease { axis: d.axis()?, bseq: d.u64()? },
        FrameType::Poison => Msg::Poison { err: decode_err(&mut d)? },
        FrameType::Rollback => Msg::Rollback { err: decode_err(&mut d)? },
        FrameType::Ping => Msg::Ping,
        FrameType::Bye => Msg::Bye,
    };
    d.finished()?;
    Ok(msg)
}

/// Encode `msg` as one frame and write it (single `write_all` + flush,
/// so a frame is never interleaved when callers serialize on a writer
/// lock).
pub fn write_msg<W: Write>(w: &mut W, msg: &Msg) -> io::Result<()> {
    let (ty, payload) = encode(msg);
    let mut buf = Vec::with_capacity(HEADER_BYTES + payload.len() + 4);
    buf.extend_from_slice(&WIRE_MAGIC);
    buf.extend_from_slice(&WIRE_VERSION.to_le_bytes());
    buf.extend_from_slice(&(ty as u16).to_le_bytes());
    buf.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    buf.extend_from_slice(&payload);
    let crc = crc32(&buf);
    buf.extend_from_slice(&crc.to_le_bytes());
    w.write_all(&buf)?;
    w.flush()
}

fn read_full<R: Read>(
    r: &mut R,
    buf: &mut [u8],
    what: &'static str,
    clean_eof: bool,
) -> Result<(), WireError> {
    let mut n = 0;
    while n < buf.len() {
        match r.read(&mut buf[n..]) {
            Ok(0) => {
                return Err(if n == 0 && clean_eof {
                    WireError::Closed
                } else {
                    WireError::Truncated { what, got: n, want: buf.len() }
                });
            }
            Ok(k) => n += k,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(WireError::Io(e.to_string())),
        }
    }
    Ok(())
}

/// Read and validate one frame: magic, version, known type, sane length,
/// CRC, then the per-type payload decode.  Returns [`WireError::Closed`]
/// on a clean EOF at a frame boundary; every other failure mode gets its
/// own descriptive variant.  Never panics on adversarial bytes.
pub fn read_msg<R: Read>(r: &mut R) -> Result<Msg, WireError> {
    let mut hdr = [0u8; HEADER_BYTES];
    read_full(r, &mut hdr, "header", true)?;
    if hdr[0..4] != WIRE_MAGIC {
        return Err(WireError::BadMagic([hdr[0], hdr[1], hdr[2], hdr[3]]));
    }
    let version = u16::from_le_bytes([hdr[4], hdr[5]]);
    if version != WIRE_VERSION {
        return Err(WireError::BadVersion(version));
    }
    let ty_raw = u16::from_le_bytes([hdr[6], hdr[7]]);
    let len = u32::from_le_bytes([hdr[8], hdr[9], hdr[10], hdr[11]]) as usize;
    if len > MAX_FRAME_PAYLOAD {
        return Err(WireError::Oversized(len));
    }
    let ty = FrameType::from_u16(ty_raw).ok_or(WireError::BadFrameType(ty_raw))?;
    let mut payload = vec![0u8; len];
    read_full(r, &mut payload, "payload", false)?;
    let mut trailer = [0u8; 4];
    read_full(r, &mut trailer, "crc trailer", false)?;
    let carried = u32::from_le_bytes(trailer);
    let mut whole = Vec::with_capacity(HEADER_BYTES + len);
    whole.extend_from_slice(&hdr);
    whole.extend_from_slice(&payload);
    let computed = crc32(&whole);
    if computed != carried {
        return Err(WireError::BadCrc { computed, carried });
    }
    decode(ty, &payload)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn round_trip(msg: Msg) -> Msg {
        let mut buf = Vec::new();
        write_msg(&mut buf, &msg).unwrap();
        read_msg(&mut &buf[..]).unwrap()
    }

    #[test]
    fn every_frame_kind_round_trips() {
        let msgs = vec![
            Msg::Hello { rank: 3, grid: [1, 2, 2, 1] },
            Msg::Welcome { world: 4, heartbeat_ms: 250 },
            Msg::Contribute {
                axis: Axis::Y,
                seq: 7,
                kind: CollKind::Reduce(Precision::Fp32),
                data: vec![1.5, -2.25, 0.0],
            },
            Msg::Contribute {
                axis: Axis::Dp,
                seq: 0,
                kind: CollKind::Gather(Precision::Fp32),
                data: vec![9.0],
            },
            Msg::Contribute {
                axis: Axis::Y,
                seq: 4,
                kind: CollKind::Gather(Precision::Bf16),
                data: vec![crate::util::bf16_round(3.141)],
            },
            Msg::ReduceResult { axis: Axis::X, seq: 2, data: vec![4.0; 5] },
            Msg::GatherResult {
                axis: Axis::Z,
                seq: 1,
                prec: Precision::Fp32,
                parts: vec![vec![1.0], vec![], vec![2.0, 3.0]],
            },
            Msg::GatherResult {
                axis: Axis::Y,
                seq: 6,
                prec: Precision::Bf16,
                parts: vec![vec![crate::util::bf16_round(-0.5)], vec![]],
            },
            Msg::Barrier { axis: Axis::X, bseq: 11 },
            Msg::BarrierRelease { axis: Axis::X, bseq: 11 },
            Msg::Poison {
                err: CommError::new(2, 5, "all_reduce", Axis::Y, "length mismatch".into()),
            },
            Msg::Rollback {
                err: CommError::stalled(1, 9, "all_gather", Axis::Z, "silent rank".into()),
            },
            Msg::Ping,
            Msg::Bye,
        ];
        for m in msgs {
            assert_eq!(round_trip(m.clone()), m, "frame failed to round-trip");
        }
    }

    #[test]
    fn bf16_contributions_round_trip_losslessly_at_half_width() {
        let vals: Vec<f32> = vec![1.0009765625, -3.75, 0.0, 1e-30, 6.5e4]
            .into_iter()
            .map(crate::util::bf16_round)
            .collect();
        let msg = Msg::Contribute {
            axis: Axis::X,
            seq: 0,
            kind: CollKind::Reduce(Precision::Bf16),
            data: vals.clone(),
        };
        let mut buf = Vec::new();
        write_msg(&mut buf, &msg).unwrap();
        let fp32 = {
            let mut b = Vec::new();
            write_msg(
                &mut b,
                &Msg::Contribute {
                    axis: Axis::X,
                    seq: 0,
                    kind: CollKind::Reduce(Precision::Fp32),
                    data: vals.clone(),
                },
            )
            .unwrap();
            b.len()
        };
        assert_eq!(fp32 - buf.len(), vals.len() * 2, "bf16 frames ship 2 bytes/elem");
        match read_msg(&mut &buf[..]).unwrap() {
            Msg::Contribute { data, .. } => {
                for (a, b) in data.iter().zip(&vals) {
                    assert_eq!(a.to_bits(), b.to_bits(), "bf16 wire transit must be lossless");
                }
            }
            m => panic!("decoded {m:?}"),
        }
    }

    #[test]
    fn bf16_gathers_ship_half_width_in_both_directions() {
        let vals: Vec<f32> = vec![1.0009765625, -3.75, 0.0, 1e-30, 6.5e4]
            .into_iter()
            .map(crate::util::bf16_round)
            .collect();
        // contribution leg
        let frame_len = |kind: CollKind| {
            let mut b = Vec::new();
            write_msg(
                &mut b,
                &Msg::Contribute { axis: Axis::X, seq: 0, kind, data: vals.clone() },
            )
            .unwrap();
            b.len()
        };
        assert_eq!(
            frame_len(CollKind::Gather(Precision::Fp32))
                - frame_len(CollKind::Gather(Precision::Bf16)),
            vals.len() * 2,
            "bf16 gather contributions ship 2 bytes/elem"
        );
        // result leg
        let result_len = |prec: Precision| {
            let mut b = Vec::new();
            write_msg(
                &mut b,
                &Msg::GatherResult {
                    axis: Axis::X,
                    seq: 0,
                    prec,
                    parts: vec![vals.clone(), vals.clone()],
                },
            )
            .unwrap();
            b.len()
        };
        assert_eq!(
            result_len(Precision::Fp32) - result_len(Precision::Bf16),
            2 * vals.len() * 2,
            "bf16 gather results ship 2 bytes/elem per part"
        );
        // both legs are lossless on already-rounded payloads
        let mut buf = Vec::new();
        write_msg(
            &mut buf,
            &Msg::GatherResult {
                axis: Axis::X,
                seq: 0,
                prec: Precision::Bf16,
                parts: vec![vals.clone()],
            },
        )
        .unwrap();
        match read_msg(&mut &buf[..]).unwrap() {
            Msg::GatherResult { parts, .. } => {
                for (a, b) in parts[0].iter().zip(&vals) {
                    assert_eq!(a.to_bits(), b.to_bits(), "bf16 wire transit must be lossless");
                }
            }
            m => panic!("decoded {m:?}"),
        }
    }

    #[test]
    fn poison_op_names_survive_the_wire() {
        for op in
            ["all_reduce", "all_gather", "injected-fault", "rank-death", "protocol", "barrier"]
        {
            let m = round_trip(Msg::Poison {
                err: CommError::new(1, 2, op, Axis::Dp, "why".into()),
            });
            match m {
                Msg::Poison { err } => assert_eq!(err.op, op),
                m => panic!("decoded {m:?}"),
            }
        }
    }

    #[test]
    fn failure_kinds_survive_the_wire() {
        // Stalled vs Fault vs Death must travel: the supervisor routes a
        // stall through the same recovery as a death, but reports it as
        // what it was.
        let mk = |kind: FailureKind| {
            let mut e = CommError::new(3, 7, "all_reduce", Axis::X, "k".into());
            e.kind = kind;
            e
        };
        for kind in [FailureKind::Fault, FailureKind::Stalled, FailureKind::Death] {
            match round_trip(Msg::Poison { err: mk(kind) }) {
                Msg::Poison { err } => assert_eq!(err.kind, kind),
                m => panic!("decoded {m:?}"),
            }
            match round_trip(Msg::Rollback { err: mk(kind) }) {
                Msg::Rollback { err } => assert_eq!(err.kind, kind),
                m => panic!("decoded {m:?}"),
            }
        }
        // rank-death defaults to the Death kind via CommError::new
        match round_trip(Msg::Poison {
            err: CommError::new(0, 0, "rank-death", Axis::X, "gone".into()),
        }) {
            Msg::Poison { err } => assert_eq!(err.kind, FailureKind::Death),
            m => panic!("decoded {m:?}"),
        }
    }
}
