//! The shared-memory [`Transport`]: every rank is a thread of this
//! process and op slots live behind per-group mutexes.
//!
//! This is the pre-trait collective engine — same op-slot protocol, same
//! ordered chunk reduction, same poison cascade — so the refactor is
//! bitwise-invisible to every existing caller (pinned by the `comm` unit
//! tests and `tests/comm_overlap.rs`).  Every blocking wait (collective
//! waits *and* the group barrier) runs against a configurable deadline:
//! expiry names the first missing contributor in a
//! [`FailureKind::Stalled`](super::FailureKind::Stalled) origin instead
//! of hanging the world on a silent rank.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

use super::{CollKind, CommError, Precision, Transport, TransportTuning};
use crate::grid::{Axis, Grid4D};

/// One in-flight collective of a process group, matched across members by
/// sequence number (every member issues its group's collectives in the same
/// program order, so equal seq = same logical op).
struct OpState {
    seq: u64,
    kind: CollKind,
    /// Reduce: payload elements (identical on every member; handshaked).
    len: usize,
    /// Per-member contributions, group-index order (freed after reduction).
    parts: Vec<Vec<f32>>,
    contributed: Vec<bool>,
    n_contributed: usize,
    /// Reduce: ordered-sum result, valid below `chunks_done * chunk_elems`.
    result: Vec<f32>,
    chunks_done: usize,
    total_chunks: usize,
    /// Set when the payload is fully reduced (Reduce) / gathered (Gather).
    completed_at: Option<Instant>,
    read: usize,
}

struct GroupState {
    /// Per-member sequence number of its next issued collective.
    next_seq: Vec<u64>,
    /// In-flight ops, ascending `seq`.
    ops: VecDeque<OpState>,
    /// Set on a mismatched collective (or injected fault); every member
    /// fails with this same structured origin.
    poison: Option<CommError>,
    /// Barrier generation (one per completed group barrier) — the
    /// condvar-based barrier is poison-aware and deadline-capable,
    /// unlike `std::sync::Barrier` which can never be woken early.
    bar_gen: u64,
    /// Members arrived at the current barrier generation, by group index
    /// (names the straggler when the barrier deadline expires).
    bar_arrived: Vec<bool>,
    bar_count: usize,
}

struct Group {
    size: usize,
    state: Mutex<GroupState>,
    cv: Condvar,
}

/// Contribute `data` to the op slot at `seq`, creating the slot on first
/// touch.  Returns a mismatch message (instead of contributing) when the
/// slot disagrees on kind or payload length — the length handshake that
/// turns a would-be deadlock into a clean error.
fn contribute(
    st: &mut GroupState,
    size: usize,
    chunk_elems: usize,
    me: usize,
    seq: u64,
    kind: CollKind,
    data: &[f32],
) -> Option<String> {
    if st.ops.iter().all(|o| o.seq != seq) {
        st.ops.push_back(OpState {
            seq,
            kind,
            len: data.len(),
            parts: vec![Vec::new(); size],
            contributed: vec![false; size],
            n_contributed: 0,
            result: match kind {
                CollKind::Reduce(_) => vec![0.0; data.len()],
                CollKind::Gather(_) => Vec::new(),
            },
            chunks_done: 0,
            total_chunks: match kind {
                CollKind::Reduce(_) => data.len().div_ceil(chunk_elems).max(1),
                CollKind::Gather(_) => 0,
            },
            completed_at: None,
            read: 0,
        });
    }
    let op = st.ops.iter_mut().find(|o| o.seq == seq).expect("just ensured");
    if op.kind != kind {
        return Some(format!(
            "collective kind mismatch at seq {seq}: slot holds {:?}, member {me} issued {:?}",
            op.kind, kind
        ));
    }
    if matches!(kind, CollKind::Reduce(_)) && op.len != data.len() {
        return Some(format!(
            "all_reduce length mismatch at seq {seq}: slot has {} elems, member {me} sent {}",
            op.len,
            data.len()
        ));
    }
    assert!(!op.contributed[me], "member {me} double-contributed seq {seq}");
    // bf16 contributions are rounded once at the source (§V-B) so every
    // receiver — and every transport — sees identical rounded payloads
    op.parts[me] = match kind.precision() {
        Precision::Bf16 => {
            let mut v = data.to_vec();
            crate::tensor::simd::round_bf16(&mut v);
            v
        }
        Precision::Fp32 => data.to_vec(),
    };
    op.contributed[me] = true;
    op.n_contributed += 1;
    if op.n_contributed == size && matches!(kind, CollKind::Gather(_)) {
        op.completed_at = Some(Instant::now());
    }
    None
}

/// Shared-memory transport: all process groups of the grid as in-memory
/// op slots (see the module docs).
pub struct InProcTransport {
    grid: Grid4D,
    groups: Vec<Vec<Group>>, // [axis][group_id]
    /// Elements per reduction chunk.
    chunk_elems: usize,
    /// Deadline on every blocking wait; expiry poisons the group with a
    /// `Stalled` origin naming the first missing contributor.
    wait_timeout: Duration,
}

impl InProcTransport {
    /// Allocate the op slots of every process group of `grid`, with the
    /// default wait deadline.
    pub fn new(grid: Grid4D, chunk_elems: usize) -> InProcTransport {
        InProcTransport::with_wait_timeout(
            grid,
            chunk_elems,
            TransportTuning::default().wait_timeout(),
        )
    }

    /// As [`InProcTransport::new`] with an explicit deadline on every
    /// blocking wait (tests use tiny deadlines to exercise the stall
    /// detection; `CommWorld::with_tuning` threads the spec knob here).
    pub fn with_wait_timeout(
        grid: Grid4D,
        chunk_elems: usize,
        wait_timeout: Duration,
    ) -> InProcTransport {
        assert!(chunk_elems > 0, "chunk_elems must be positive");
        assert!(!wait_timeout.is_zero(), "wait_timeout must be positive");
        let mk = |axis: Axis| -> Vec<Group> {
            (0..grid.num_groups(axis))
                .map(|_| Group {
                    size: grid.axis_size(axis),
                    state: Mutex::new(GroupState {
                        next_seq: vec![0; grid.axis_size(axis)],
                        ops: VecDeque::new(),
                        poison: None,
                        bar_gen: 0,
                        bar_arrived: vec![false; grid.axis_size(axis)],
                        bar_count: 0,
                    }),
                    cv: Condvar::new(),
                })
                .collect()
        };
        InProcTransport {
            grid,
            groups: vec![mk(Axis::X), mk(Axis::Y), mk(Axis::Z), mk(Axis::Dp)],
            chunk_elems,
            wait_timeout,
        }
    }

    fn group(&self, rank: usize, axis: Axis) -> &Group {
        &self.groups[axis.index()][self.grid.group_id(rank, axis)]
    }

    /// The `Stalled` origin for an expired wait on the op at `seq`: the
    /// first member (group-index order) that never contributed is the
    /// evidence — determinism matters so every waiter diagnoses the same
    /// straggler.
    fn stall_error(
        &self,
        st: &GroupState,
        rank: usize,
        axis: Axis,
        seq: u64,
        op_name: &'static str,
    ) -> CommError {
        let members = self.grid.group_ranks(rank, axis);
        let origin = st
            .ops
            .iter()
            .find(|o| o.seq == seq)
            .and_then(|o| o.contributed.iter().position(|c| !*c))
            .map(|i| members[i])
            .unwrap_or(rank);
        CommError::stalled(
            origin,
            seq,
            op_name,
            axis,
            format!(
                "rank {origin} silent on {op_name} seq {seq}: no contribution within {} ms",
                self.wait_timeout.as_millis()
            ),
        )
    }

    /// Advance ordered chunk reductions of every fully-contributed op of
    /// the group; `budget` caps the chunks reduced per call so `progress`
    /// stays cheap.  Returns whether any chunk was advanced.
    fn reduce_ready_locked(&self, st: &mut GroupState, size: usize, mut budget: usize) -> bool {
        let chunk = self.chunk_elems;
        let mut did = false;
        for op in st.ops.iter_mut() {
            if budget == 0 {
                break;
            }
            if !matches!(op.kind, CollKind::Reduce(_)) || op.n_contributed < size {
                continue;
            }
            while op.chunks_done < op.total_chunks && budget > 0 {
                let lo = (op.chunks_done * chunk).min(op.len);
                let hi = ((op.chunks_done + 1) * chunk).min(op.len);
                // ordered sum over members: deterministic regardless of
                // arrival order or of which rank drives the reduction
                let dst = &mut op.result[lo..hi];
                dst.copy_from_slice(&op.parts[0][lo..hi]);
                for p in op.parts.iter().skip(1) {
                    for (d, &v) in dst.iter_mut().zip(&p[lo..hi]) {
                        *d += v;
                    }
                }
                op.chunks_done += 1;
                budget -= 1;
                did = true;
            }
            if op.chunks_done == op.total_chunks && op.completed_at.is_none() {
                op.completed_at = Some(Instant::now());
                // contributions are no longer needed; free them eagerly
                for p in op.parts.iter_mut() {
                    *p = Vec::new();
                }
            }
        }
        did
    }
}

impl Transport for InProcTransport {
    fn name(&self) -> &'static str {
        "inproc"
    }

    fn issue(
        &self,
        rank: usize,
        axis: Axis,
        kind: CollKind,
        data: &[f32],
    ) -> Result<u64, CommError> {
        let g = self.group(rank, axis);
        let me = self.grid.index_in_group(rank, axis);
        let mut st = g.state.lock().unwrap();
        if let Some(e) = st.poison.clone() {
            return Err(e);
        }
        let seq = st.next_seq[me];
        st.next_seq[me] += 1;
        if let Some(msg) = contribute(&mut st, g.size, self.chunk_elems, me, seq, kind, data) {
            return Err(CommError::new(rank, seq, kind.op_name(), axis, msg));
        }
        drop(st);
        g.cv.notify_all();
        Ok(seq)
    }

    fn try_ready(&self, rank: usize, axis: Axis, seq: u64) -> bool {
        let g = self.group(rank, axis);
        match g.state.try_lock() {
            Ok(mut st) => {
                if st.poison.is_some() {
                    return true; // the wait surfaces the error
                }
                if self.reduce_ready_locked(&mut st, g.size, 8) {
                    g.cv.notify_all();
                }
                st.ops
                    .iter()
                    .find(|o| o.seq == seq)
                    .map(|o| o.chunks_done == o.total_chunks)
                    .unwrap_or(false)
            }
            Err(_) => false,
        }
    }

    fn wait_reduce(
        &self,
        rank: usize,
        axis: Axis,
        seq: u64,
        out: &mut [f32],
    ) -> Result<Instant, CommError> {
        let g = self.group(rank, axis);
        let deadline = Instant::now() + self.wait_timeout;
        let mut st = g.state.lock().unwrap();
        let completed_at = loop {
            if let Some(e) = st.poison.clone() {
                return Err(e);
            }
            if self.reduce_ready_locked(&mut st, g.size, usize::MAX) {
                g.cv.notify_all();
            }
            let done = {
                let op = st.ops.iter().find(|o| o.seq == seq).expect("pending op slot missing");
                if op.chunks_done == op.total_chunks {
                    op.completed_at
                } else {
                    None
                }
            };
            if let Some(t) = done {
                break t;
            }
            let now = Instant::now();
            if now >= deadline {
                return Err(self.stall_error(&st, rank, axis, seq, "all_reduce"));
            }
            st = g.cv.wait_timeout(st, deadline - now).unwrap().0;
        };
        let retire = {
            let op = st.ops.iter_mut().find(|o| o.seq == seq).unwrap();
            out.copy_from_slice(&op.result);
            op.read += 1;
            op.read == g.size
        };
        if retire {
            st.ops.retain(|o| o.seq != seq);
        }
        Ok(completed_at)
    }

    fn wait_gather(
        &self,
        rank: usize,
        axis: Axis,
        seq: u64,
    ) -> Result<(Vec<Vec<f32>>, Instant), CommError> {
        let g = self.group(rank, axis);
        let deadline = Instant::now() + self.wait_timeout;
        let mut st = g.state.lock().unwrap();
        let completed_at = loop {
            if let Some(e) = st.poison.clone() {
                return Err(e);
            }
            let done = {
                let op =
                    st.ops.iter().find(|o| o.seq == seq).expect("pending gather slot missing");
                if op.n_contributed == g.size {
                    op.completed_at
                } else {
                    None
                }
            };
            if let Some(t) = done {
                break t;
            }
            let now = Instant::now();
            if now >= deadline {
                return Err(self.stall_error(&st, rank, axis, seq, "all_gather"));
            }
            st = g.cv.wait_timeout(st, deadline - now).unwrap().0;
        };
        let (out, retire) = {
            let op = st.ops.iter_mut().find(|o| o.seq == seq).unwrap();
            let out = op.parts.clone();
            op.read += 1;
            (out, op.read == g.size)
        };
        if retire {
            st.ops.retain(|o| o.seq != seq);
        }
        Ok((out, completed_at))
    }

    fn progress(&self, rank: usize) -> bool {
        let mut did = false;
        for axis in Axis::ALL {
            let g = self.group(rank, axis);
            if g.size <= 1 {
                continue;
            }
            if let Ok(mut st) = g.state.try_lock() {
                if st.poison.is_some() {
                    continue; // surfaced by the owning wait
                }
                if self.reduce_ready_locked(&mut st, g.size, 8) {
                    did = true;
                    g.cv.notify_all();
                }
            }
        }
        did
    }

    fn barrier(&self, rank: usize, axis: Axis) -> Result<(), CommError> {
        let g = self.group(rank, axis);
        if g.size <= 1 {
            return Ok(());
        }
        let me = self.grid.index_in_group(rank, axis);
        let mut st = g.state.lock().unwrap();
        if let Some(e) = st.poison.clone() {
            return Err(e);
        }
        let gen = st.bar_gen;
        st.bar_arrived[me] = true;
        st.bar_count += 1;
        if st.bar_count == g.size {
            // last arrival releases the generation
            st.bar_count = 0;
            for a in st.bar_arrived.iter_mut() {
                *a = false;
            }
            st.bar_gen += 1;
            drop(st);
            g.cv.notify_all();
            return Ok(());
        }
        let deadline = Instant::now() + self.wait_timeout;
        loop {
            if let Some(e) = st.poison.clone() {
                return Err(e);
            }
            if st.bar_gen != gen {
                return Ok(());
            }
            let now = Instant::now();
            if now >= deadline {
                let members = self.grid.group_ranks(rank, axis);
                let origin = st
                    .bar_arrived
                    .iter()
                    .position(|a| !*a)
                    .map(|i| members[i])
                    .unwrap_or(rank);
                return Err(CommError::stalled(
                    origin,
                    gen,
                    "barrier",
                    axis,
                    format!(
                        "rank {origin} silent on barrier {gen}: no arrival within {} ms",
                        self.wait_timeout.as_millis()
                    ),
                ));
            }
            st = g.cv.wait_timeout(st, deadline - now).unwrap().0;
        }
    }

    fn fail(&self, rank: usize, err: &CommError) {
        for axis in Axis::ALL {
            let g = self.group(rank, axis);
            if g.size <= 1 {
                continue;
            }
            let mut st = g.state.lock().unwrap();
            if st.poison.is_none() {
                st.poison = Some(err.clone());
            }
            drop(st);
            g.cv.notify_all();
        }
    }

    fn poison_of(&self, rank: usize) -> Option<CommError> {
        for axis in Axis::ALL {
            let g = self.group(rank, axis);
            if g.size <= 1 {
                continue;
            }
            if let Some(e) = &g.state.lock().unwrap().poison {
                return Some(e.clone());
            }
        }
        None
    }
}
