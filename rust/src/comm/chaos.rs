//! Deterministic fault injection for the distributed runtime.
//!
//! Two composable wrappers inject faults from a reproducible schedule:
//!
//! * [`ChaosTransport`] composes over any [`Transport`] (the conformance
//!   battery runs the in-process engine under it) and injects
//!   issue-level faults: frame delay, stall-until-detected, and
//!   connection-drop-style kills.
//! * [`ChaosConn`] wraps the write side of a socket transport's wire
//!   connection and injects byte-level faults on data frames: delay,
//!   duplicate frame, CRC corruption, partial write, and connection drop
//!   at byte N.
//!
//! Both draw every decision from a pure hash of
//! `(seed, stream, event-counter)` — never from wall-clock time or
//! thread interleaving — so the *schedule* of injected faults is
//! byte-identical across runs of the same [`ChaosSpec`]: same event
//! index faults, same mode, same drop offset.  That is what makes the
//! seed-sweep test meaningful ("same spec ⇒ same failure origin and
//! diagnosis") and lets CI soak across seeds with reproducible
//! failures.
//!
//! The injected faults are *honest*: a `Drop` really poisons the world
//! through [`Transport::fail`], a `Stall` really goes silent and is
//! only unblocked by the deadline discipline detecting it (or a hard
//! cap, so a test can never hang), and the byte-level modes produce
//! exactly the wire damage a flaky network would.

use std::collections::HashMap;
use std::io::{self, Write};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use super::socket::Conn;
use super::{CollKind, CommError, Transport};
use crate::grid::Axis;

/// One injectable fault mode.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum ChaosMode {
    /// Delay the event a few milliseconds (adversarial timing; never
    /// corrupts results).
    Delay,
    /// Go silent: the rank (or its data frames) stall until the
    /// deadline discipline detects and poisons it.
    Stall,
    /// Kill the rank / drop the connection at a schedule-chosen byte.
    Drop,
    /// Flip a bit in the frame's CRC region (socket only).
    Corrupt,
    /// Send the data frame twice (socket only).
    Duplicate,
    /// Write half the frame, then fail the connection (socket only).
    Partial,
}

/// Every mode, in the canonical order used for schedule selection.
pub const ALL_CHAOS_MODES: [ChaosMode; 6] = [
    ChaosMode::Delay,
    ChaosMode::Stall,
    ChaosMode::Drop,
    ChaosMode::Corrupt,
    ChaosMode::Duplicate,
    ChaosMode::Partial,
];

impl ChaosMode {
    /// Spec / CLI name of the mode.
    pub fn tag(&self) -> &'static str {
        match self {
            ChaosMode::Delay => "delay",
            ChaosMode::Stall => "stall",
            ChaosMode::Drop => "drop",
            ChaosMode::Corrupt => "corrupt",
            ChaosMode::Duplicate => "duplicate",
            ChaosMode::Partial => "partial",
        }
    }

    /// Parse a spec / CLI mode name.
    pub fn parse(s: &str) -> Option<ChaosMode> {
        ALL_CHAOS_MODES.iter().copied().find(|m| m.tag() == s)
    }
}

/// A reproducible fault-injection schedule: `seed` fixes the schedule,
/// `rate` the per-event fault probability, `modes` the fault repertoire.
/// Threaded through `RunSpec` and the `--chaos seed=S,rate=R` CLI flag.
#[derive(Clone, Debug, PartialEq)]
pub struct ChaosSpec {
    /// Schedule seed: every decision hashes off it.
    pub seed: u64,
    /// Per-event fault probability in `(0, 1]`.
    pub rate: f64,
    /// Enabled fault modes (sorted, deduplicated).
    pub modes: Vec<ChaosMode>,
}

impl ChaosSpec {
    /// A schedule over every mode.
    pub fn new(seed: u64, rate: f64) -> ChaosSpec {
        ChaosSpec { seed, rate, modes: ALL_CHAOS_MODES.to_vec() }
    }

    /// A schedule restricted to `modes` (sorted + deduplicated).
    pub fn with_modes(seed: u64, rate: f64, mut modes: Vec<ChaosMode>) -> ChaosSpec {
        modes.sort();
        modes.dedup();
        ChaosSpec { seed, rate, modes }
    }

    /// Parse the `--chaos` flag value: `seed=S,rate=R[,modes=a+b+c]`.
    pub fn parse(s: &str) -> Result<ChaosSpec, String> {
        let mut seed = None;
        let mut rate = None;
        let mut modes = ALL_CHAOS_MODES.to_vec();
        for part in s.split(',') {
            let (k, v) = part
                .split_once('=')
                .ok_or_else(|| format!("chaos: expected key=value, got '{part}'"))?;
            match k {
                "seed" => {
                    seed = Some(
                        v.parse::<u64>().map_err(|_| format!("chaos: bad seed '{v}'"))?,
                    );
                }
                "rate" => {
                    rate = Some(
                        v.parse::<f64>().map_err(|_| format!("chaos: bad rate '{v}'"))?,
                    );
                }
                "modes" => {
                    modes = v
                        .split('+')
                        .map(|m| {
                            ChaosMode::parse(m).ok_or_else(|| format!("chaos: unknown mode '{m}'"))
                        })
                        .collect::<Result<Vec<_>, _>>()?;
                }
                _ => return Err(format!("chaos: unknown key '{k}' (seed/rate/modes)")),
            }
        }
        let seed = seed.ok_or("chaos: missing seed=".to_string())?;
        let rate = rate.ok_or("chaos: missing rate=".to_string())?;
        let spec = ChaosSpec::with_modes(seed, rate, modes);
        spec.check().map_err(|e| format!("chaos: {e}"))?;
        Ok(spec)
    }

    /// Validate rate and modes; the error text is embedded by the spec
    /// layer's `BadChaos`.
    pub fn check(&self) -> Result<(), &'static str> {
        if !(self.rate > 0.0 && self.rate <= 1.0) {
            return Err("rate must be in (0, 1]");
        }
        if self.modes.is_empty() {
            return Err("at least one mode is required");
        }
        Ok(())
    }

    /// The pure per-event decision: does event `n` of `stream` fault,
    /// and if so with which mode of `subset`?  Returns the mode plus a
    /// derived hash for mode parameters (drop offset, delay length).
    fn roll(&self, stream: u64, n: u64, subset: &[ChaosMode]) -> Option<(ChaosMode, u64)> {
        if subset.is_empty() {
            return None;
        }
        let h = mix(mix(self.seed ^ stream).wrapping_add(n));
        // 53 uniform bits -> [0, 1)
        let u = (h >> 11) as f64 / (1u64 << 53) as f64;
        if u >= self.rate {
            return None;
        }
        let h2 = mix(h);
        Some((subset[(h2 % subset.len() as u64) as usize], h2))
    }

    fn subset(&self, allowed: &[ChaosMode]) -> Vec<ChaosMode> {
        self.modes.iter().copied().filter(|m| allowed.contains(m)).collect()
    }
}

/// splitmix64 finalizer: the pure mixing function behind every schedule
/// decision.
fn mix(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9e37_79b9_7f4a_7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

/// Transport-level chaos: composes over any inner [`Transport`] and
/// injects a fault on schedule-chosen `issue` events.  Only the modes
/// meaningful without a wire apply here (`Delay`, `Stall`, `Drop`);
/// byte-level modes are exercised by [`ChaosConn`].
pub struct ChaosTransport {
    inner: Box<dyn Transport>,
    spec: ChaosSpec,
    issue_modes: Vec<ChaosMode>,
    /// Hard cap on a `Stall`'s silence so an undetected stall (nobody
    /// waiting on this rank) can never hang a run.
    stall_cap: Duration,
    /// Per-(rank, axis) issue counters — the event index `n` of the
    /// schedule.  Counted per logical stream, not per thread, so the
    /// schedule is independent of interleaving.
    counters: Mutex<HashMap<(usize, usize), u64>>,
}

impl ChaosTransport {
    /// Wrap `inner` under the schedule `spec`.
    pub fn new(inner: Box<dyn Transport>, spec: ChaosSpec) -> ChaosTransport {
        let issue_modes = spec.subset(&[ChaosMode::Delay, ChaosMode::Stall, ChaosMode::Drop]);
        ChaosTransport {
            inner,
            spec,
            issue_modes,
            stall_cap: Duration::from_secs(120),
            counters: Mutex::new(HashMap::new()),
        }
    }

    /// Override the hard cap on injected stalls (worlds with short wait
    /// deadlines shorten the cap so a dual-stall resolves quickly).
    pub fn with_stall_cap(mut self, cap: Duration) -> ChaosTransport {
        self.stall_cap = cap;
        self
    }

    fn next_event(&self, rank: usize, axis: Axis) -> u64 {
        let mut c = self.counters.lock().unwrap_or_else(|p| p.into_inner());
        let n = c.entry((rank, axis.index())).or_insert(0);
        let v = *n;
        *n += 1;
        v
    }
}

impl Transport for ChaosTransport {
    fn name(&self) -> &'static str {
        "chaos"
    }

    fn issue(
        &self,
        rank: usize,
        axis: Axis,
        kind: CollKind,
        data: &[f32],
    ) -> Result<u64, CommError> {
        let n = self.next_event(rank, axis);
        let stream = ((rank as u64) << 3) | axis.index() as u64;
        match self.spec.roll(stream, n, &self.issue_modes) {
            None => {}
            Some((ChaosMode::Delay, h)) => {
                std::thread::sleep(Duration::from_millis(1 + h % 4));
            }
            Some((ChaosMode::Stall, _)) => {
                // go silent: contribute nothing until the deadline
                // discipline poisons the group (naming this rank), or
                // the hard cap expires so nothing can hang
                let start = Instant::now();
                while self.inner.poison_of(rank).is_none() && start.elapsed() < self.stall_cap {
                    std::thread::sleep(Duration::from_millis(5));
                }
            }
            Some((mode, _)) => {
                // Drop (and any byte-level mode routed here): kill the
                // rank with a deterministic, schedule-stamped origin
                let err = CommError::new(
                    rank,
                    0,
                    "injected-fault",
                    axis,
                    format!("chaos {} (seed {}, event {n})", mode.tag(), self.spec.seed),
                );
                self.inner.fail(rank, &err);
                return Err(err);
            }
        }
        self.inner.issue(rank, axis, kind, data)
    }

    fn try_ready(&self, rank: usize, axis: Axis, seq: u64) -> bool {
        self.inner.try_ready(rank, axis, seq)
    }

    fn wait_reduce(
        &self,
        rank: usize,
        axis: Axis,
        seq: u64,
        out: &mut [f32],
    ) -> Result<Instant, CommError> {
        self.inner.wait_reduce(rank, axis, seq, out)
    }

    fn wait_gather(
        &self,
        rank: usize,
        axis: Axis,
        seq: u64,
    ) -> Result<(Vec<Vec<f32>>, Instant), CommError> {
        self.inner.wait_gather(rank, axis, seq)
    }

    fn progress(&self, rank: usize) -> bool {
        self.inner.progress(rank)
    }

    fn barrier(&self, rank: usize, axis: Axis) -> Result<(), CommError> {
        self.inner.barrier(rank, axis)
    }

    fn fail(&self, rank: usize, err: &CommError) {
        self.inner.fail(rank, err);
    }

    fn poison_of(&self, rank: usize) -> Option<CommError> {
        self.inner.poison_of(rank)
    }

    fn rejoin_offered(&self, rank: usize) -> bool {
        self.inner.rejoin_offered(rank)
    }
}

// Wire frame types carrying collective data (see `wire::FrameType`):
// only these consume schedule events; control frames (Hello, Ping,
// Poison, Bye) always pass through so the handshake and the failure
// cascade stay intact and the pinger thread cannot perturb the
// schedule.
const FT_CONTRIBUTE: u16 = 3;
const FT_BARRIER: u16 = 6;

/// Write-side chaos for a socket transport's wire connection: data
/// frames are delayed, duplicated, CRC-corrupted, half-written, dropped
/// mid-frame, or silenced per the schedule.  Reads are untouched — a
/// "silent" rank still hears the coordinator's poison / rollback, which
/// is exactly the semantics of a stalled-but-alive process.
pub struct ChaosConn {
    inner: Conn,
    spec: ChaosSpec,
    conn_modes: Vec<ChaosMode>,
    /// Schedule stream of this connection (derived from the rank).
    stream: u64,
    /// Data-frame counter — the schedule's event index.
    n: u64,
    /// Bytes left of a partially-forwarded frame (robustness against a
    /// caller splitting one frame over several writes).
    remaining: usize,
    /// Swallow the rest of the current frame.
    swallowing: bool,
    /// A `Stall` fired: every later data frame is swallowed, simulating
    /// a silent rank until the coordinator's deadline poisons it.
    mute: bool,
}

impl ChaosConn {
    pub(crate) fn new(inner: Conn, spec: ChaosSpec, rank: usize) -> ChaosConn {
        let conn_modes = spec.subset(&[
            ChaosMode::Delay,
            ChaosMode::Stall,
            ChaosMode::Drop,
            ChaosMode::Corrupt,
            ChaosMode::Duplicate,
            ChaosMode::Partial,
        ]);
        ChaosConn {
            inner,
            spec,
            conn_modes,
            stream: ((rank as u64) << 3) | 7,
            n: 0,
            remaining: 0,
            swallowing: false,
            mute: false,
        }
    }

    fn fail_conn(&mut self, what: &str) -> io::Error {
        let _ = self.inner.shutdown();
        io::Error::new(io::ErrorKind::BrokenPipe, format!("chaos: {what}"))
    }
}

impl Write for ChaosConn {
    fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
        // tail of a frame already dispatched
        if self.remaining > 0 {
            let n = buf.len().min(self.remaining);
            self.remaining -= n;
            if self.remaining == 0 {
                self.swallowing = false;
            }
            return if self.swallowing || self.mute {
                Ok(n)
            } else {
                self.inner.write(&buf[..n])
            };
        }
        if buf.len() < 12 {
            // not a frame header; pass through untouched
            return self.inner.write(buf);
        }
        let ty = u16::from_le_bytes([buf[6], buf[7]]);
        let payload = u32::from_le_bytes([buf[8], buf[9], buf[10], buf[11]]) as usize;
        let total = 12 + payload + 4;
        let data_frame = ty == FT_CONTRIBUTE || ty == FT_BARRIER;
        if !data_frame {
            // control frames pass through, no schedule event consumed
            if buf.len() < total {
                self.remaining = total - buf.len();
                self.swallowing = false;
            }
            return self.inner.write(buf);
        }
        if self.mute {
            // silent rank: swallow the whole data frame
            if buf.len() < total {
                self.remaining = total - buf.len();
                self.swallowing = true;
                return Ok(buf.len());
            }
            return Ok(total.min(buf.len()));
        }
        let n = self.n;
        self.n += 1;
        let roll = self.spec.roll(self.stream, n, &self.conn_modes);
        // whole-frame modes need the whole frame in this write (the
        // wire layer always sends one frame per write_all); fall back
        // to pass-through when it is split
        let whole = buf.len() >= total;
        match roll {
            Some((ChaosMode::Delay, h)) => {
                std::thread::sleep(Duration::from_millis(1 + h % 4));
            }
            Some((ChaosMode::Stall, _)) => {
                self.mute = true;
                if !whole {
                    self.remaining = total - buf.len();
                    self.swallowing = true;
                    return Ok(buf.len());
                }
                return Ok(total);
            }
            Some((ChaosMode::Drop, h)) if whole => {
                // connection drop at byte N of the frame
                let cut = (h % (total as u64 + 1)) as usize;
                let _ = self.inner.write_all(&buf[..cut]);
                let _ = self.inner.flush();
                return Err(self.fail_conn(&format!("connection dropped at byte {cut} of frame")));
            }
            Some((ChaosMode::Corrupt, _)) if whole => {
                // flip a bit in the CRC trailer: the receiver must
                // diagnose BadCrc, not act on the frame
                let mut bad = buf[..total].to_vec();
                bad[total - 1] ^= 0x01;
                self.inner.write_all(&bad)?;
                if buf.len() > total {
                    self.remaining = 0;
                    let k = self.inner.write(&buf[total..])?;
                    return Ok(total + k);
                }
                return Ok(total);
            }
            Some((ChaosMode::Duplicate, _)) if whole => {
                self.inner.write_all(&buf[..total])?;
                self.inner.write_all(&buf[..total])?;
                return Ok(total);
            }
            Some((ChaosMode::Partial, _)) if whole => {
                self.inner.write_all(&buf[..total / 2])?;
                let _ = self.inner.flush();
                return Err(
                    self.fail_conn(&format!("partial write: {} of {total} bytes", total / 2))
                );
            }
            _ => {}
        }
        if buf.len() < total {
            self.remaining = total - buf.len();
            self.swallowing = false;
        }
        self.inner.write(buf)
    }

    fn flush(&mut self) -> io::Result<()> {
        self.inner.flush()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn schedule_is_a_pure_function_of_seed_stream_event() {
        let spec = ChaosSpec::new(42, 0.3);
        for stream in 0..8u64 {
            for n in 0..200u64 {
                let a = spec.roll(stream, n, &spec.modes);
                let b = spec.roll(stream, n, &spec.modes);
                assert_eq!(a, b, "roll must be deterministic");
            }
        }
        // different seeds produce different schedules
        let other = ChaosSpec::new(43, 0.3);
        let fires =
            |s: &ChaosSpec| (0..200).filter(|&n| s.roll(0, n, &s.modes).is_some()).count();
        assert!(fires(&spec) > 0, "a 0.3 rate over 200 events must fire");
        let a: Vec<u64> = (0..200).filter(|&n| spec.roll(0, n, &spec.modes).is_some()).collect();
        let b: Vec<u64> = (0..200).filter(|&n| other.roll(0, n, &other.modes).is_some()).collect();
        assert_ne!(a, b, "different seeds must differ somewhere in 200 events");
    }

    #[test]
    fn rate_bounds_the_fire_fraction() {
        let spec = ChaosSpec::new(7, 0.05);
        let fired = (0..10_000).filter(|&n| spec.roll(1, n, &spec.modes).is_some()).count();
        // 500 expected; allow generous slack, but it must be in the
        // right ballpark for the soak job's budget math to hold
        assert!((200..=900).contains(&fired), "fired {fired} of 10000 at rate 0.05");
    }

    #[test]
    fn parse_round_trips_the_cli_flag() {
        let spec = ChaosSpec::parse("seed=9,rate=0.25,modes=delay+drop").unwrap();
        assert_eq!(spec.seed, 9);
        assert_eq!(spec.rate, 0.25);
        assert_eq!(spec.modes, vec![ChaosMode::Delay, ChaosMode::Drop]);
        assert!(ChaosSpec::parse("seed=1").is_err(), "rate is required");
        assert!(ChaosSpec::parse("rate=0.5").is_err(), "seed is required");
        assert!(ChaosSpec::parse("seed=1,rate=0").is_err(), "zero rate rejected");
        assert!(ChaosSpec::parse("seed=1,rate=1.5").is_err(), "rate > 1 rejected");
        assert!(ChaosSpec::parse("seed=1,rate=0.5,modes=fry").is_err(), "unknown mode");
        assert!(ChaosSpec::parse("seed=1,rate=0.5,bogus=2").is_err(), "unknown key");
    }
}
