//! The socket [`Transport`]: this process runs **one** rank; collectives
//! travel as [`wire`] frames over TCP or a Unix-domain socket to a
//! `scalegnn-coord` coordinator ([`super::coord`]) that matches
//! sequence-numbered contributions, reduces them in group-index member
//! order (bitwise identical to the in-process engine) and sends results
//! back.
//!
//! Connection anatomy: one stream per rank, writer behind a mutex (the
//! rank thread issues contributions / barriers / heartbeats), plus a
//! reader thread that dispatches results, barrier releases, poison and
//! rollback frames into shared state a waiting rank blocks on.  A lost
//! coordinator connection poisons the rank with a `"coordinator-lost"`
//! origin instead of hanging a wait forever.
//!
//! **No unbounded waits.**  Every blocking path carries a deadline from
//! [`TransportTuning`]: the handshake read times out at the connect
//! budget, and collective waits use `wait_timeout` loops whose expiry
//! poisons the rank with a [`FailureKind::Stalled`](super::FailureKind)
//! origin.  The rank-local deadline is *twice* the configured
//! `wait_timeout_ms` — the coordinator's op-stall watchdog (one
//! `wait_timeout_ms`) names the true straggler first; the local fallback
//! only fires when the coordinator itself went silent.
//!
//! **Rejoin.**  A coordinator re-forming the world (its
//! `rejoin_grace_ms` window) broadcasts `Rollback` instead of plain
//! poison; the reader records the offer and the supervisor can
//! reconnect this rank into the same coordinator instead of tearing the
//! run down ([`Transport::rejoin_offered`]).

use std::collections::HashMap;
use std::io::{self, Read, Write};
use std::net::{Shutdown, TcpStream};
use std::os::unix::net::UnixStream;
use std::path::PathBuf;
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};

use super::chaos::{ChaosConn, ChaosSpec};
use super::wire::{self, Msg};
use super::{CollKind, CommError, Transport, TransportTuning};
use crate::grid::{Axis, Grid4D};

/// Where a coordinator listens (and ranks connect).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Endpoint {
    /// TCP at `"host:port"` (port 0 = coordinator picks one and reports
    /// the resolved address).
    Tcp(String),
    /// Unix-domain socket at a filesystem path.
    Unix(PathBuf),
}

impl Endpoint {
    /// Parse `"tcp:HOST:PORT"` or `"unix:PATH"`.
    pub fn parse(s: &str) -> Result<Endpoint, String> {
        if let Some(addr) = s.strip_prefix("tcp:") {
            if addr.is_empty() {
                return Err("tcp endpoint needs an address: tcp:HOST:PORT".into());
            }
            Ok(Endpoint::Tcp(addr.to_string()))
        } else if let Some(path) = s.strip_prefix("unix:") {
            if path.is_empty() {
                return Err("unix endpoint needs a path: unix:/some/socket".into());
            }
            Ok(Endpoint::Unix(PathBuf::from(path)))
        } else {
            Err(format!("unknown endpoint '{s}' (want tcp:HOST:PORT or unix:PATH)"))
        }
    }
}

impl std::fmt::Display for Endpoint {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Endpoint::Tcp(a) => write!(f, "tcp:{a}"),
            Endpoint::Unix(p) => write!(f, "unix:{}", p.display()),
        }
    }
}

/// One coordinator connection, TCP or Unix-domain.
pub(crate) enum Conn {
    Tcp(TcpStream),
    Unix(UnixStream),
}

impl Conn {
    /// Connect with retries until `timeout` (the coordinator may still be
    /// binding when ranks launch).
    pub(crate) fn connect(ep: &Endpoint, timeout: Duration) -> io::Result<Conn> {
        let deadline = Instant::now() + timeout;
        loop {
            let r = match ep {
                Endpoint::Tcp(addr) => TcpStream::connect(addr.as_str()).map(|s| {
                    let _ = s.set_nodelay(true);
                    Conn::Tcp(s)
                }),
                Endpoint::Unix(path) => UnixStream::connect(path).map(Conn::Unix),
            };
            match r {
                Ok(c) => return Ok(c),
                Err(e) if Instant::now() >= deadline => return Err(e),
                Err(_) => std::thread::sleep(Duration::from_millis(30)),
            }
        }
    }

    pub(crate) fn try_clone(&self) -> io::Result<Conn> {
        match self {
            Conn::Tcp(s) => s.try_clone().map(Conn::Tcp),
            Conn::Unix(s) => s.try_clone().map(Conn::Unix),
        }
    }

    /// Shut both directions down, unblocking any reader; errors ignored
    /// (the peer may already be gone).
    pub(crate) fn shutdown(&self) {
        match self {
            Conn::Tcp(s) => {
                let _ = s.shutdown(Shutdown::Both);
            }
            Conn::Unix(s) => {
                let _ = s.shutdown(Shutdown::Both);
            }
        }
    }

    pub(crate) fn set_read_timeout(&self, t: Option<Duration>) -> io::Result<()> {
        match self {
            Conn::Tcp(s) => s.set_read_timeout(t),
            Conn::Unix(s) => s.set_read_timeout(t),
        }
    }
}

impl Read for Conn {
    fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
        match self {
            Conn::Tcp(s) => s.read(buf),
            Conn::Unix(s) => s.read(buf),
        }
    }
}

impl Write for Conn {
    fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
        match self {
            Conn::Tcp(s) => s.write(buf),
            Conn::Unix(s) => s.write(buf),
        }
    }
    fn flush(&mut self) -> io::Result<()> {
        match self {
            Conn::Tcp(s) => s.flush(),
            Conn::Unix(s) => s.flush(),
        }
    }
}

/// Lock that survives a poisoned mutex (a panicking rank thread must
/// still be able to close the connection in Drop).
fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|p| p.into_inner())
}

struct Tx {
    /// The write side of the coordinator connection — the raw [`Conn`],
    /// or a [`ChaosConn`] injecting wire faults from its schedule.
    w: Box<dyn Write + Send>,
    /// Per-axis sequence number of this rank's next collective (assigned
    /// under the writer lock so seq order equals wire order).
    next_seq: [u64; 4],
    /// Per-axis barrier sequence number.
    next_bseq: [u64; 4],
}

#[derive(Default)]
struct RxState {
    /// Completed reduces keyed by (axis index, seq), with arrival time.
    reduces: HashMap<(usize, u64), (Vec<f32>, Instant)>,
    /// Completed gathers keyed by (axis index, seq).
    gathers: HashMap<(usize, u64), (Vec<Vec<f32>>, Instant)>,
    /// Count of barrier releases received per axis.
    releases: [u64; 4],
    /// First failure origin seen (from the coordinator, a peer via the
    /// coordinator, or a lost connection).
    poison: Option<CommError>,
    /// The coordinator broadcast `Rollback`: the world is re-forming in
    /// place and this rank may reconnect into the same coordinator.
    rejoin: bool,
    /// Set by Drop so the reader thread exits silently on EOF.
    closing: bool,
}

struct Shared {
    state: Mutex<RxState>,
    cv: Condvar,
}

/// Socket transport for one rank of a multi-process world (see the
/// module docs); built by [`SocketTransport::connect`], normally via
/// [`super::CommWorld::connect`].
pub struct SocketTransport {
    rank: usize,
    tx: Arc<Mutex<Tx>>,
    sh: Arc<Shared>,
    /// Dedicated handle for Drop to unblock the reader thread.
    shutdown_conn: Conn,
    kind: &'static str,
    /// Rank-local fallback deadline on collective waits (twice the
    /// configured `wait_timeout_ms`; see the module docs).
    wait_deadline: Duration,
    reader: Option<JoinHandle<()>>,
    pinger: Option<JoinHandle<()>>,
}

impl SocketTransport {
    /// Register `rank` with the coordinator at `ep` under default
    /// [`TransportTuning`] and no chaos (see
    /// [`SocketTransport::connect_with`]).
    pub fn connect(grid: Grid4D, rank: usize, ep: &Endpoint) -> Result<SocketTransport> {
        SocketTransport::connect_with(grid, rank, ep, &TransportTuning::default(), None)
    }

    /// Register `rank` with the coordinator at `ep`, block until the
    /// whole world assembled (the coordinator's Welcome, bounded by the
    /// connect budget plus the rejoin grace), and start the reader (and,
    /// if the coordinator asked for heartbeats, pinger) threads.  With a
    /// [`ChaosSpec`], the write side of the connection goes through a
    /// [`ChaosConn`] injecting wire faults from the seeded schedule.
    pub fn connect_with(
        grid: Grid4D,
        rank: usize,
        ep: &Endpoint,
        tuning: &TransportTuning,
        chaos: Option<&ChaosSpec>,
    ) -> Result<SocketTransport> {
        if rank >= grid.world_size() {
            bail!("rank {rank} outside world of {} ranks", grid.world_size());
        }
        let mut conn = Conn::connect(ep, tuning.connect_timeout())
            .map_err(|e| anyhow!("rank {rank}: connecting to coordinator at {ep}: {e}"))?;
        wire::write_msg(
            &mut conn,
            &Msg::Hello {
                rank: rank as u32,
                grid: [grid.gd as u32, grid.gx as u32, grid.gy as u32, grid.gz as u32],
            },
        )
        .map_err(|e| anyhow!("rank {rank}: sending hello: {e}"))?;
        // the Welcome wait is bounded: peers get the connect budget to
        // assemble, plus the grace window if the world is re-forming
        // around a rejoining rank
        conn.set_read_timeout(Some(tuning.connect_timeout() + tuning.rejoin_grace()))
            .map_err(|e| anyhow!("rank {rank}: arming handshake deadline: {e}"))?;
        let heartbeat_ms = match wire::read_msg(&mut conn) {
            Ok(Msg::Welcome { world, heartbeat_ms }) => {
                if world as usize != grid.world_size() {
                    bail!(
                        "rank {rank}: coordinator assembled {world} ranks, this grid has {}",
                        grid.world_size()
                    );
                }
                heartbeat_ms
            }
            Ok(Msg::Poison { err }) | Ok(Msg::Rollback { err }) => {
                bail!("rank {rank}: world failed during assembly: {err}")
            }
            Ok(m) => bail!("rank {rank}: expected welcome, coordinator sent {m:?}"),
            Err(e) => bail!("rank {rank}: waiting for welcome: {e}"),
        };
        let shutdown_conn = conn.try_clone()?;
        let mut rconn = conn.try_clone()?;
        // The reader thread's blocking read carries no deadline of its
        // own: shutdown_conn.shutdown() on poison/Drop unblocks it, a dead
        // coordinator surfaces as an EOF/error poisoning the rank, and
        // every *collective* wait above it is deadline-bounded.
        // lint: allow(unbounded-wait) — reader-thread read; shutdown_conn.shutdown() unblocks it
        if let Err(e) = rconn.set_read_timeout(None) {
            bail!("rank {rank}: clearing handshake deadline: {e}");
        }
        let sh = Arc::new(Shared { state: Mutex::new(RxState::default()), cv: Condvar::new() });
        let sh_r = sh.clone();
        let reader = std::thread::spawn(move || reader_loop(&mut rconn, &sh_r, rank));
        let w: Box<dyn Write + Send> = match chaos {
            Some(spec) => Box::new(ChaosConn::new(conn, spec.clone(), rank)),
            None => Box::new(conn),
        };
        let tx = Arc::new(Mutex::new(Tx { w, next_seq: [0; 4], next_bseq: [0; 4] }));
        let pinger = (heartbeat_ms > 0).then(|| {
            let tx = tx.clone();
            let sh = sh.clone();
            std::thread::spawn(move || ping_loop(&tx, &sh, heartbeat_ms))
        });
        Ok(SocketTransport {
            rank,
            tx,
            sh,
            shutdown_conn,
            kind: match ep {
                Endpoint::Tcp(_) => "tcp",
                Endpoint::Unix(_) => "uds",
            },
            wait_deadline: tuning.wait_timeout() * 2,
            reader: Some(reader),
            pinger: Some(pinger).flatten(),
        })
    }

    fn poison(&self) -> Option<CommError> {
        lock(&self.sh.state).poison.clone()
    }

    /// A write failure usually means the world already died and the
    /// poison explains why; fall back to a send-failure origin.
    fn send_err(&self, seq: u64, op: &'static str, axis: Axis, e: io::Error) -> CommError {
        self.poison().unwrap_or_else(|| {
            CommError::new(self.rank, seq, op, axis, format!("sending to coordinator: {e}"))
        })
    }

    /// The rank-local deadline expired with no result and no poison: the
    /// coordinator itself went silent (its own op-stall watchdog, at half
    /// this deadline, would otherwise have named the straggler already).
    fn stall_err(&self, seq: u64, op: &'static str, axis: Axis) -> CommError {
        CommError::stalled(
            self.rank,
            seq,
            op,
            axis,
            format!(
                "no {op} result and no failure verdict within {} ms: coordinator silent",
                self.wait_deadline.as_millis()
            ),
        )
    }
}

fn reader_loop(conn: &mut Conn, sh: &Shared, rank: usize) {
    loop {
        match wire::read_msg(conn) {
            Ok(Msg::ReduceResult { axis, seq, data }) => {
                let mut st = lock(&sh.state);
                st.reduces.insert((axis.index(), seq), (data, Instant::now()));
                drop(st);
                sh.cv.notify_all();
            }
            Ok(Msg::GatherResult { axis, seq, parts, .. }) => {
                let mut st = lock(&sh.state);
                st.gathers.insert((axis.index(), seq), (parts, Instant::now()));
                drop(st);
                sh.cv.notify_all();
            }
            Ok(Msg::BarrierRelease { axis, .. }) => {
                let mut st = lock(&sh.state);
                st.releases[axis.index()] += 1;
                drop(st);
                sh.cv.notify_all();
            }
            Ok(Msg::Poison { err }) => {
                let mut st = lock(&sh.state);
                if st.poison.is_none() {
                    st.poison = Some(err);
                }
                drop(st);
                sh.cv.notify_all();
                // keep reading: the coordinator closes after the
                // broadcast and the EOF ends this loop cleanly
            }
            Ok(Msg::Rollback { err }) => {
                // like poison, but the coordinator is holding the world
                // open: record the rejoin offer so the supervisor
                // reconnects instead of tearing the run down
                let mut st = lock(&sh.state);
                st.rejoin = true;
                if st.poison.is_none() {
                    st.poison = Some(err);
                }
                drop(st);
                sh.cv.notify_all();
            }
            Ok(_) => {} // stray frame; harmless
            Err(e) => {
                let mut st = lock(&sh.state);
                if !st.closing && st.poison.is_none() {
                    st.poison = Some(CommError::new(
                        rank,
                        0,
                        "coordinator-lost",
                        Axis::X,
                        format!("coordinator connection lost: {e}"),
                    ));
                }
                drop(st);
                sh.cv.notify_all();
                return;
            }
        }
    }
}

fn ping_loop(tx: &Mutex<Tx>, sh: &Shared, heartbeat_ms: u32) {
    let interval = Duration::from_millis((heartbeat_ms as u64 / 3).max(10));
    loop {
        let deadline = Instant::now() + interval;
        while Instant::now() < deadline {
            {
                let st = lock(&sh.state);
                if st.closing || st.poison.is_some() {
                    return;
                }
            }
            std::thread::sleep(Duration::from_millis(20).min(interval));
        }
        let mut tx = lock(tx);
        if wire::write_msg(&mut tx.w, &Msg::Ping).is_err() {
            return;
        }
    }
}

impl Transport for SocketTransport {
    fn name(&self) -> &'static str {
        self.kind
    }

    fn issue(
        &self,
        rank: usize,
        axis: Axis,
        kind: CollKind,
        data: &[f32],
    ) -> Result<u64, CommError> {
        debug_assert_eq!(rank, self.rank, "a socket world carries exactly one rank");
        if let Some(e) = self.poison() {
            return Err(e);
        }
        let mut tx = lock(&self.tx);
        let seq = tx.next_seq[axis.index()];
        tx.next_seq[axis.index()] += 1;
        wire::write_msg(
            &mut tx.w,
            &Msg::Contribute { axis, seq, kind, data: data.to_vec() },
        )
        .map_err(|e| self.send_err(seq, kind.op_name(), axis, e))?;
        Ok(seq)
    }

    fn try_ready(&self, _rank: usize, axis: Axis, seq: u64) -> bool {
        let st = lock(&self.sh.state);
        st.poison.is_some() || st.reduces.contains_key(&(axis.index(), seq))
    }

    fn wait_reduce(
        &self,
        rank: usize,
        axis: Axis,
        seq: u64,
        out: &mut [f32],
    ) -> Result<Instant, CommError> {
        let key = (axis.index(), seq);
        let deadline = Instant::now() + self.wait_deadline;
        let mut st = lock(&self.sh.state);
        loop {
            if let Some(e) = st.poison.clone() {
                return Err(e);
            }
            if let Some((data, at)) = st.reduces.remove(&key) {
                if data.len() != out.len() {
                    return Err(CommError::new(
                        rank,
                        seq,
                        "protocol",
                        axis,
                        format!("result has {} elems, issued {}", data.len(), out.len()),
                    ));
                }
                out.copy_from_slice(&data);
                return Ok(at);
            }
            let now = Instant::now();
            if now >= deadline {
                return Err(self.stall_err(seq, "all_reduce", axis));
            }
            st = self
                .sh
                .cv
                .wait_timeout(st, deadline - now)
                .unwrap_or_else(|p| p.into_inner())
                .0;
        }
    }

    fn wait_gather(
        &self,
        _rank: usize,
        axis: Axis,
        seq: u64,
    ) -> Result<(Vec<Vec<f32>>, Instant), CommError> {
        let key = (axis.index(), seq);
        let deadline = Instant::now() + self.wait_deadline;
        let mut st = lock(&self.sh.state);
        loop {
            if let Some(e) = st.poison.clone() {
                return Err(e);
            }
            if let Some(r) = st.gathers.remove(&key) {
                return Ok(r);
            }
            let now = Instant::now();
            if now >= deadline {
                return Err(self.stall_err(seq, "all_gather", axis));
            }
            st = self
                .sh
                .cv
                .wait_timeout(st, deadline - now)
                .unwrap_or_else(|p| p.into_inner())
                .0;
        }
    }

    fn progress(&self, _rank: usize) -> bool {
        false // reductions complete at the coordinator; nothing to drive
    }

    fn barrier(&self, _rank: usize, axis: Axis) -> Result<(), CommError> {
        let bseq = {
            let mut tx = lock(&self.tx);
            let b = tx.next_bseq[axis.index()];
            tx.next_bseq[axis.index()] += 1;
            wire::write_msg(&mut tx.w, &Msg::Barrier { axis, bseq: b })
                .map_err(|e| self.send_err(b, "protocol", axis, e))?;
            b
        };
        let deadline = Instant::now() + self.wait_deadline;
        let mut st = lock(&self.sh.state);
        loop {
            if let Some(e) = st.poison.clone() {
                return Err(e);
            }
            if st.releases[axis.index()] > bseq {
                return Ok(());
            }
            let now = Instant::now();
            if now >= deadline {
                return Err(self.stall_err(bseq, "barrier", axis));
            }
            st = self
                .sh
                .cv
                .wait_timeout(st, deadline - now)
                .unwrap_or_else(|p| p.into_inner())
                .0;
        }
    }

    fn fail(&self, _rank: usize, err: &CommError) {
        {
            let mut st = lock(&self.sh.state);
            if st.poison.is_none() {
                st.poison = Some(err.clone());
            }
        }
        self.sh.cv.notify_all();
        // tell the coordinator so it broadcasts the origin world-wide
        let mut tx = lock(&self.tx);
        let _ = wire::write_msg(&mut tx.w, &Msg::Poison { err: err.clone() });
    }

    fn poison_of(&self, _rank: usize) -> Option<CommError> {
        self.poison()
    }

    fn rejoin_offered(&self, _rank: usize) -> bool {
        lock(&self.sh.state).rejoin
    }
}

impl Drop for SocketTransport {
    fn drop(&mut self) {
        let was_poisoned = {
            let mut st = lock(&self.sh.state);
            st.closing = true;
            st.poison.is_some()
        };
        self.sh.cv.notify_all();
        if !was_poisoned {
            // clean completion; a poisoned rank just closes
            let mut tx = lock(&self.tx);
            let _ = wire::write_msg(&mut tx.w, &Msg::Bye);
        }
        self.shutdown_conn.shutdown();
        if let Some(h) = self.reader.take() {
            let _ = h.join();
        }
        if let Some(h) = self.pinger.take() {
            let _ = h.join();
        }
    }
}
