//! The multi-process world coordinator behind the socket transports
//! (the `scalegnn-coord` binary wraps [`Coordinator`]).
//!
//! Handshake state machine (one world, one run):
//!
//! ```text
//!   BIND ──accept──► REGISTER: each connection must open with a valid
//!     Hello{rank, grid}; wrong grid / out-of-range rank / duplicate
//!     rank / undecodable bytes are rejected (logged, connection
//!     dropped) without disturbing registered ranks.
//!   REGISTER ──all ranks present──► RUN: Welcome{world, heartbeat_ms}
//!     is sent to every rank; per-rank handler threads serve
//!     Contribute / Barrier / Ping / Poison / Bye frames.
//!   RUN ──every rank sent Bye──► DONE (returns no failure), or
//!   RUN ──any failure──► POISONED: the first failure origin is
//!     recorded and broadcast to every rank — as a Poison frame
//!     (fatal), or, with `rejoin_grace_ms > 0`, as a Rollback frame:
//!   POISONED ──Rollback──► RE-REGISTER: the world's slots stay open
//!     for the grace window; every rank re-registers with a fresh
//!     Hello (survivors reconnect, the failed rank relaunches with
//!     `--rank R --resume`) into the *same* coordinator, which bumps
//!     the generation and serves the re-formed world.  Grace expiry
//!     (or too many re-forms) falls back to the fatal path with the
//!     original origin.
//! ```
//!
//! Failures that poison the world: a collective handshake mismatch
//! (kind/length/precision — same checks, same message text as the
//! in-process engine), a rank-sent Poison (injected fault), a peer
//! connection dying mid-run or sending undecodable bytes
//! (`"rank-death"`), a heartbeat timeout, an op-stall deadline expiry
//! (`FailureKind::Stalled` — a member opened a collective and some
//! other member stayed silent past `wait_timeout_ms`), or a protocol
//! violation.
//!
//! Determinism: a reduce completes when the last member contributes and
//! is summed **in group-index member order**, never arrival order — so
//! socket-transport results are bitwise identical to the in-process
//! engine's ordered chunk reduction.

use std::collections::HashMap;
use std::io;
use std::net::TcpListener;
use std::os::unix::net::UnixListener;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use super::socket::{Conn, Endpoint};
use super::wire::{self, Msg, WireError};
use super::{CollKind, CommError};
use crate::grid::{Axis, Grid4D};

/// Most world re-forms a coordinator serves before declaring the run
/// unrecoverable (mirrors the supervisor's checkpoint-restart cap).
pub const MAX_REFORMS: u64 = 3;

/// Coordinator tuning.
#[derive(Clone, Copy, Debug)]
pub struct CoordConfig {
    /// Heartbeat interval ranks are told to ping at; a rank silent for
    /// 4 intervals is declared dead.  0 disables the watchdog (tests,
    /// and runs where rank steps may legitimately take long).
    pub heartbeat_ms: u32,
    /// Deadline on an open collective op: once any member contributed,
    /// the rest must arrive within this window or the world is poisoned
    /// with a `Stalled` origin naming the first silent member.  0
    /// disables the op-stall watchdog.
    pub wait_timeout_ms: u32,
    /// After a failure, hold every rank's slot open this long for a
    /// re-registration (Rollback / rejoin) before tearing the world
    /// down.  0 = rejoin disabled, fail fast.
    pub rejoin_grace_ms: u32,
    /// Suppress progress logging on stderr.
    pub quiet: bool,
}

impl Default for CoordConfig {
    fn default() -> CoordConfig {
        CoordConfig { heartbeat_ms: 0, wait_timeout_ms: 30_000, rejoin_grace_ms: 0, quiet: true }
    }
}

enum Listener {
    Tcp(TcpListener),
    Unix(UnixListener),
}

/// One in-flight collective of one group: contributions keyed by member
/// index, completed (and answered) when the last member arrives.
struct CoordOp {
    kind: CollKind,
    /// Reduce payload length handshake (first contributor sets it).
    len: usize,
    parts: Vec<Option<Vec<f32>>>,
    n: usize,
    /// Global ranks of the group in member order (stall diagnosis).
    members: Vec<usize>,
    /// When the slot opened — the op-stall watchdog's reference point.
    born: Instant,
}

/// One in-flight barrier of one group, arrival-tracked per member so a
/// stall names the first silent member (and a duplicate arrival is a
/// protocol violation, not a silent double count).
struct CoordBarrier {
    arrived: Vec<bool>,
    n: usize,
    /// Global ranks of the group in member order (stall diagnosis).
    members: Vec<usize>,
    /// When the slot opened — the op-stall watchdog's reference point.
    born: Instant,
}

struct CoordState {
    /// Op slots keyed by (axis index, group id, seq).
    ops: HashMap<(usize, usize, u64), CoordOp>,
    /// Barrier slots keyed by (axis index, group id, bseq).
    barriers: HashMap<(usize, usize, u64), CoordBarrier>,
    /// First failure origin; sticky once set.
    failure: Option<CommError>,
    /// Ranks that sent Bye.
    done: Vec<bool>,
    /// Last frame seen per rank (heartbeat watchdog).
    last_seen: Vec<Instant>,
}

struct Shared {
    grid: Grid4D,
    cfg: CoordConfig,
    /// This generation broadcasts Rollback (world re-forms in place)
    /// instead of fatal Poison when a failure strikes.
    offer_rejoin: bool,
    state: Mutex<CoordState>,
    /// Per-rank write half, locked per frame (handlers of any rank may
    /// complete an op and answer every member).
    writers: Vec<Mutex<Conn>>,
    /// Per-rank shutdown handles (watchdog unblocks a dead rank's
    /// blocked reader).
    shutdowns: Vec<Conn>,
}

fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|p| p.into_inner())
}

impl Shared {
    fn log(&self, msg: &str) {
        if !self.cfg.quiet {
            eprintln!("coord: {msg}");
        }
    }

    fn send(&self, rank: usize, msg: &Msg) {
        let failed = {
            let mut w = lock(&self.writers[rank]);
            wire::write_msg(&mut *w, msg).is_err()
        };
        if failed && !matches!(msg, Msg::Poison { .. } | Msg::Rollback { .. }) {
            self.poison_world(CommError::new(
                rank,
                0,
                "rank-death",
                Axis::X,
                format!("rank {rank} unreachable (result delivery failed)"),
            ));
        }
    }

    /// Record the first failure origin and broadcast it to every rank —
    /// fatal Poison, or Rollback when this generation offers a rejoin.
    /// Idempotent: later failures are cascade effects of the first.
    fn poison_world(&self, err: CommError) {
        {
            let mut st = lock(&self.state);
            if st.failure.is_some() {
                return;
            }
            st.failure = Some(err.clone());
        }
        self.log(&format!(
            "failure origin rank {} op {} seq {} axis {}: {}",
            err.rank,
            err.op,
            err.seq,
            err.axis.tag(),
            err.msg
        ));
        for r in 0..self.grid.world_size() {
            let msg = if self.offer_rejoin {
                Msg::Rollback { err: err.clone() }
            } else {
                Msg::Poison { err: err.clone() }
            };
            self.send(r, &msg);
        }
    }

    fn touch(&self, rank: usize) {
        lock(&self.state).last_seen[rank] = Instant::now();
    }

    fn contribute(&self, rank: usize, axis: Axis, seq: u64, kind: CollKind, data: Vec<f32>) {
        let size = self.grid.axis_size(axis);
        if size <= 1 {
            // size-1 groups never reach a transport; a frame for one is a
            // protocol violation
            self.poison_world(CommError::new(
                rank,
                seq,
                "protocol",
                axis,
                format!("contribution to size-1 axis {axis:?}"),
            ));
            return;
        }
        let gid = self.grid.group_id(rank, axis);
        let me = self.grid.index_in_group(rank, axis);
        let key = (axis.index(), gid, seq);
        let completed = {
            let mut st = lock(&self.state);
            if st.failure.is_some() {
                return; // world is dying; ranks have the origin
            }
            let op = st.ops.entry(key).or_insert_with(|| CoordOp {
                kind,
                len: data.len(),
                parts: vec![None; size],
                n: 0,
                members: self.grid.group_ranks(rank, axis),
                born: Instant::now(),
            });
            if op.kind != kind {
                let msg = format!(
                    "collective kind mismatch at seq {seq}: slot holds {:?}, member {me} issued {:?}",
                    op.kind, kind
                );
                let err = CommError::new(rank, seq, kind.op_name(), axis, msg);
                drop(st);
                self.poison_world(err);
                return;
            }
            if matches!(kind, CollKind::Reduce(_)) && op.len != data.len() {
                let msg = format!(
                    "all_reduce length mismatch at seq {seq}: slot has {} elems, member {me} sent {}",
                    op.len,
                    data.len()
                );
                let err = CommError::new(rank, seq, kind.op_name(), axis, msg);
                drop(st);
                self.poison_world(err);
                return;
            }
            if op.parts[me].is_some() {
                let err = CommError::new(
                    rank,
                    seq,
                    "protocol",
                    axis,
                    format!("member {me} double-contributed seq {seq}"),
                );
                drop(st);
                self.poison_world(err);
                return;
            }
            op.parts[me] = Some(data);
            op.n += 1;
            if op.n == size {
                st.ops.remove(&key)
            } else {
                None
            }
        };
        if let Some(op) = completed {
            match op.kind {
                CollKind::Reduce(_) => {
                    // ordered sum in group-index member order: bitwise
                    // identical to the in-process chunked reduction
                    // lint: allow(panic-free-boundary) — op completed under the state lock with n == size, so every slot is Some; a flatten() would silently drop a member and corrupt the reduction
                    let mut parts = op.parts.into_iter().map(|p| p.unwrap());
                    // lint: allow(panic-free-boundary) — size >= 2 was enforced at op creation, so the first part exists
                    let mut result = parts.next().unwrap();
                    for p in parts {
                        for (d, v) in result.iter_mut().zip(p) {
                            *d += v;
                        }
                    }
                    for &m in &op.members {
                        self.send(m, &Msg::ReduceResult { axis, seq, data: result.clone() });
                    }
                }
                CollKind::Gather(prec) => {
                    // parts were rounded at the source for bf16, so the
                    // result leg re-narrows losslessly on the wire
                    let parts: Vec<Vec<f32>> =
                        // lint: allow(panic-free-boundary) — op completed under the state lock with n == size, so every slot is Some (see the Reduce arm)
                        op.parts.into_iter().map(|p| p.unwrap()).collect();
                    for &m in &op.members {
                        self.send(
                            m,
                            &Msg::GatherResult { axis, seq, prec, parts: parts.clone() },
                        );
                    }
                }
            }
        }
    }

    fn barrier(&self, rank: usize, axis: Axis, bseq: u64) {
        let size = self.grid.axis_size(axis);
        if size <= 1 {
            self.poison_world(CommError::new(
                rank,
                bseq,
                "protocol",
                axis,
                format!("barrier on size-1 axis {axis:?}"),
            ));
            return;
        }
        let gid = self.grid.group_id(rank, axis);
        let me = self.grid.index_in_group(rank, axis);
        let key = (axis.index(), gid, bseq);
        let release = {
            let mut st = lock(&self.state);
            if st.failure.is_some() {
                return;
            }
            let b = st.barriers.entry(key).or_insert_with(|| CoordBarrier {
                arrived: vec![false; size],
                n: 0,
                members: self.grid.group_ranks(rank, axis),
                born: Instant::now(),
            });
            if b.arrived[me] {
                let err = CommError::new(
                    rank,
                    bseq,
                    "protocol",
                    axis,
                    format!("member {me} double-arrived at barrier {bseq}"),
                );
                drop(st);
                self.poison_world(err);
                return;
            }
            b.arrived[me] = true;
            b.n += 1;
            if b.n == size {
                st.barriers.remove(&key)
            } else {
                None
            }
        };
        if let Some(b) = release {
            for &m in &b.members {
                self.send(m, &Msg::BarrierRelease { axis, bseq });
            }
        }
    }

    /// The op-stall scan: the oldest open slot past the deadline poisons
    /// the world with a `Stalled` origin naming the first silent member.
    fn check_op_stalls(&self, deadline: Duration) {
        let stalled = {
            let st = lock(&self.state);
            if st.failure.is_some() {
                return;
            }
            let from_ops = st.ops.iter().filter(|(_, op)| op.born.elapsed() > deadline).map(
                |(&(ax, _, seq), op)| {
                    let me = op.parts.iter().position(|p| p.is_none()).unwrap_or(0);
                    (op.born, op.members[me], seq, op.kind.op_name(), ax)
                },
            );
            let from_bars =
                st.barriers.iter().filter(|(_, b)| b.born.elapsed() > deadline).map(
                    |(&(ax, _, bseq), b)| {
                        let me = b.arrived.iter().position(|&a| !a).unwrap_or(0);
                        (b.born, b.members[me], bseq, "barrier", ax)
                    },
                );
            from_ops.chain(from_bars).min_by_key(|&(born, ..)| born)
        };
        if let Some((_, origin, seq, op, ax)) = stalled {
            let axis = Axis::ALL[ax];
            self.poison_world(CommError::stalled(
                origin,
                seq,
                op,
                axis,
                format!(
                    "rank {origin} silent on {op} seq {seq}: no contribution within {} ms",
                    deadline.as_millis()
                ),
            ));
        }
    }

    fn handle_rank(&self, rank: usize, conn: &mut Conn) {
        loop {
            match wire::read_msg(conn) {
                Ok(Msg::Contribute { axis, seq, kind, data }) => {
                    self.touch(rank);
                    self.contribute(rank, axis, seq, kind, data);
                }
                Ok(Msg::Barrier { axis, bseq }) => {
                    self.touch(rank);
                    self.barrier(rank, axis, bseq);
                }
                Ok(Msg::Ping) => self.touch(rank),
                Ok(Msg::Poison { err }) => {
                    // a rank announcing its own death (injected fault):
                    // broadcast the origin unchanged
                    self.poison_world(err);
                }
                Ok(Msg::Bye) => {
                    lock(&self.state).done[rank] = true;
                    self.log(&format!("rank {rank} completed"));
                    return;
                }
                Ok(m) => {
                    self.poison_world(CommError::new(
                        rank,
                        0,
                        "protocol",
                        Axis::X,
                        format!("unexpected frame {m:?} mid-run"),
                    ));
                    return;
                }
                Err(e) => {
                    let benign = {
                        let st = lock(&self.state);
                        st.done[rank] || st.failure.is_some()
                    };
                    if !benign {
                        let msg = match e {
                            WireError::Closed => {
                                format!("rank {rank} connection closed mid-run")
                            }
                            e => format!("undecodable frame from rank {rank}: {e}"),
                        };
                        self.poison_world(CommError::new(rank, 0, "rank-death", Axis::X, msg));
                    }
                    return;
                }
            }
        }
    }
}

/// One-shot world coordinator: bind, register `world_size` ranks, serve
/// the run — re-forming the world through Rollback / re-registration
/// cycles when `rejoin_grace_ms` allows — and return the failure origin
/// (if any).  See the module docs for the handshake state machine.
pub struct Coordinator {
    grid: Grid4D,
    cfg: CoordConfig,
    listener: Listener,
    endpoint: Endpoint,
}

impl Coordinator {
    /// Bind the listening socket.  For `tcp:host:0` the OS picks a port;
    /// [`Coordinator::endpoint`] reports the resolved address.  An
    /// existing file at a unix socket path is removed (a stale socket
    /// from a previous run).
    pub fn bind(grid: Grid4D, ep: &Endpoint, cfg: CoordConfig) -> Result<Coordinator> {
        let (listener, endpoint) = match ep {
            Endpoint::Tcp(addr) => {
                let l = TcpListener::bind(addr.as_str())
                    .map_err(|e| anyhow!("binding tcp {addr}: {e}"))?;
                let resolved = l.local_addr()?.to_string();
                (Listener::Tcp(l), Endpoint::Tcp(resolved))
            }
            Endpoint::Unix(path) => {
                let _ = std::fs::remove_file(path);
                let l = UnixListener::bind(path)
                    .map_err(|e| anyhow!("binding unix {}: {e}", path.display()))?;
                (Listener::Unix(l), Endpoint::Unix(path.clone()))
            }
        };
        Ok(Coordinator { grid, cfg, listener, endpoint })
    }

    /// The resolved endpoint ranks should connect to.
    pub fn endpoint(&self) -> &Endpoint {
        &self.endpoint
    }

    fn log(&self, m: &str) {
        if !self.cfg.quiet {
            eprintln!("coord: {m}");
        }
    }

    fn try_accept(&self) -> io::Result<Conn> {
        Ok(match &self.listener {
            Listener::Tcp(l) => {
                let (s, _) = l.accept()?;
                let _ = s.set_nodelay(true);
                Conn::Tcp(s)
            }
            Listener::Unix(l) => {
                let (s, _) = l.accept()?;
                Conn::Unix(s)
            }
        })
    }

    fn set_nonblocking(&self, on: bool) -> io::Result<()> {
        match &self.listener {
            Listener::Tcp(l) => l.set_nonblocking(on),
            Listener::Unix(l) => l.set_nonblocking(on),
        }
    }

    /// Accept one connection — blocking, or, with a deadline, polling
    /// nonblocking accepts until it expires (`Ok(None)`).
    fn accept_within(&self, deadline: Option<Instant>) -> Result<Option<Conn>> {
        let Some(d) = deadline else {
            return Ok(Some(self.try_accept()?));
        };
        self.set_nonblocking(true)?;
        let r = loop {
            match self.try_accept() {
                Ok(c) => break Ok(Some(c)),
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                    if Instant::now() >= d {
                        break Ok(None);
                    }
                    std::thread::sleep(Duration::from_millis(10));
                }
                Err(e) => break Err(e.into()),
            }
        };
        self.set_nonblocking(false)?;
        r
    }

    /// Accept `n` valid Hellos (invalid connections rejected).  With a
    /// deadline (the rejoin grace window) returns `Ok(None)` on expiry;
    /// without one, blocks until the world assembled.
    fn register(&self, n: usize, deadline: Option<Instant>) -> Result<Option<Vec<Conn>>> {
        let mut conns: Vec<Option<Conn>> = (0..n).map(|_| None).collect();
        let mut registered = 0;
        while registered < n {
            let Some(mut conn) = self.accept_within(deadline)? else {
                return Ok(None);
            };
            // a connection that never sends its Hello must not stall
            // world assembly forever
            let hello_budget = match deadline {
                Some(d) => d
                    .saturating_duration_since(Instant::now())
                    .max(Duration::from_millis(10)),
                None => Duration::from_secs(30),
            };
            let _ = conn.set_read_timeout(Some(hello_budget));
            match wire::read_msg(&mut conn) {
                Ok(Msg::Hello { rank, grid }) => {
                    let want = [
                        self.grid.gd as u32,
                        self.grid.gx as u32,
                        self.grid.gy as u32,
                        self.grid.gz as u32,
                    ];
                    let r = rank as usize;
                    if grid != want {
                        self.log(&format!(
                            "rejecting rank {rank}: grid {grid:?} does not match {want:?}"
                        ));
                    } else if r >= n {
                        self.log(&format!("rejecting rank {rank}: world has {n} ranks"));
                    } else if conns[r].is_some() {
                        self.log(&format!("rejecting duplicate registration for rank {rank}"));
                    } else {
                        // lint: allow(unbounded-wait) — the run-phase handler read this re-arms is unblocked by the watchdogs' per-rank shutdown handles and by rank closes; collective progress itself is bounded by the op-stall watchdog
                        let _ = conn.set_read_timeout(None);
                        conns[r] = Some(conn);
                        registered += 1;
                        self.log(&format!("rank {r} registered ({registered}/{n})"));
                    }
                }
                Ok(m) => self.log(&format!("rejecting connection: expected hello, got {m:?}")),
                Err(e) => self.log(&format!("rejecting connection: {e}")),
            }
        }
        // lint: allow(panic-free-boundary) — the loop above runs until registered == n, and registered only increments when conns[r] is filled, so every slot is Some here
        Ok(Some(conns.into_iter().map(|c| c.expect("registered")).collect()))
    }

    /// Serve one world generation: welcome every rank, run handlers and
    /// watchdogs, and return the failure origin (`None` = every rank
    /// sent Bye).  With `offer_rejoin`, a failure is broadcast as
    /// Rollback and this returns immediately so the caller can hold the
    /// re-registration window (handler threads of lingering connections
    /// drain on their own EOFs — they only touch this generation's
    /// abandoned state).
    fn serve_generation(
        &self,
        conns: Vec<Conn>,
        generation: u64,
        offer_rejoin: bool,
    ) -> Result<Option<CommError>> {
        let n = self.grid.world_size();
        let mut writers = Vec::with_capacity(n);
        let mut shutdowns = Vec::with_capacity(n);
        let mut readers = Vec::with_capacity(n);
        for c in conns {
            writers.push(Mutex::new(c.try_clone()?));
            shutdowns.push(c.try_clone()?);
            readers.push(c);
        }
        let shared = Arc::new(Shared {
            grid: self.grid,
            cfg: self.cfg,
            offer_rejoin,
            state: Mutex::new(CoordState {
                ops: HashMap::new(),
                barriers: HashMap::new(),
                failure: None,
                done: vec![false; n],
                last_seen: vec![Instant::now(); n],
            }),
            writers,
            shutdowns,
        });
        for r in 0..n {
            shared.send(
                r,
                &Msg::Welcome { world: n as u32, heartbeat_ms: self.cfg.heartbeat_ms },
            );
        }
        self.log(&format!(
            "world assembled: {n} ranks on {} (generation {generation})",
            self.endpoint
        ));
        let mut handles = Vec::with_capacity(n);
        for (r, mut conn) in readers.into_iter().enumerate() {
            let sh = shared.clone();
            handles.push(std::thread::spawn(move || sh.handle_rank(r, &mut conn)));
        }
        let stop = Arc::new(AtomicBool::new(false));
        let watchdog = (self.cfg.heartbeat_ms > 0 || self.cfg.wait_timeout_ms > 0).then(|| {
            let sh = shared.clone();
            let stop = stop.clone();
            std::thread::spawn(move || watchdog_loop(&sh, &stop))
        });
        // completion poll: all-done ends the generation cleanly; a
        // failure either ends the run (fatal) or hands control back for
        // the re-registration window (rejoin)
        let failure = loop {
            std::thread::sleep(Duration::from_millis(10));
            let (failed, all_done) = {
                let st = lock(&shared.state);
                (st.failure.clone(), st.done.iter().all(|&d| d))
            };
            if let Some(e) = failed {
                break Some(e);
            }
            if all_done {
                break None;
            }
        };
        stop.store(true, Ordering::Relaxed);
        if failure.is_none() || !offer_rejoin {
            // drain handlers: ranks got their verdict (or Bye'd) and
            // close, ending each handler's read
            for h in handles {
                let _ = h.join();
            }
        }
        if let Some(w) = watchdog {
            let _ = w.join();
        }
        Ok(failure)
    }

    /// Register every rank, serve the world — re-forming it through the
    /// rejoin window when configured — and return the failure origin
    /// (`None` = every rank completed cleanly).
    pub fn run(self) -> Result<Option<CommError>> {
        let res = self.run_inner();
        if let Endpoint::Unix(path) = &self.endpoint {
            let _ = std::fs::remove_file(path);
        }
        match &res {
            Ok(None) => self.log("world completed cleanly"),
            Ok(Some(e)) => self.log(&format!("world failed: {e}")),
            Err(_) => {}
        }
        res
    }

    fn run_inner(&self) -> Result<Option<CommError>> {
        let n = self.grid.world_size();
        let mut conns = match self.register(n, None)? {
            Some(c) => c,
            // deadline-free registration blocks until the world forms
            None => return Err(anyhow!("registration aborted")),
        };
        let mut generation: u64 = 0;
        loop {
            let offer_rejoin = self.cfg.rejoin_grace_ms > 0 && generation < MAX_REFORMS;
            let failure = self.serve_generation(conns, generation, offer_rejoin)?;
            let err = match failure {
                None => return Ok(None),
                Some(e) => e,
            };
            if !offer_rejoin {
                return Ok(Some(err));
            }
            let grace = Duration::from_millis(u64::from(self.cfg.rejoin_grace_ms));
            self.log(&format!(
                "holding rank slots open {} ms for rejoin after: {err}",
                grace.as_millis()
            ));
            match self.register(n, Some(Instant::now() + grace))? {
                Some(c) => {
                    generation += 1;
                    self.log(&format!("world re-formed (generation {generation})"));
                    conns = c;
                }
                None => {
                    self.log("rejoin grace expired; world torn down");
                    return Ok(Some(err));
                }
            }
        }
    }

    /// [`Coordinator::run`] on a background thread (in-process tests and
    /// benchmarks; child processes use the `scalegnn-coord` binary).
    pub fn spawn(self) -> std::thread::JoinHandle<Result<Option<CommError>>> {
        std::thread::spawn(move || self.run())
    }
}

fn watchdog_loop(sh: &Shared, stop: &AtomicBool) {
    let hb = Duration::from_millis(u64::from(sh.cfg.heartbeat_ms));
    let wait = Duration::from_millis(u64::from(sh.cfg.wait_timeout_ms));
    // scan at a quarter of the tightest enabled deadline, floored so the
    // loop never busy-spins
    let mut period = Duration::from_millis(250);
    if sh.cfg.heartbeat_ms > 0 {
        period = period.min(hb / 2);
    }
    if sh.cfg.wait_timeout_ms > 0 {
        period = period.min(wait / 4);
    }
    let period = period.max(Duration::from_millis(10));
    loop {
        std::thread::sleep(period);
        if stop.load(Ordering::Relaxed) {
            return;
        }
        if sh.cfg.wait_timeout_ms > 0 {
            sh.check_op_stalls(wait);
        }
        let dead = {
            let st = lock(&sh.state);
            if st.failure.is_some() {
                return;
            }
            if sh.cfg.heartbeat_ms == 0 {
                None
            } else {
                (0..sh.grid.world_size())
                    .find(|&r| !st.done[r] && st.last_seen[r].elapsed() > hb * 4)
            }
        };
        if let Some(r) = dead {
            sh.poison_world(CommError::new(
                r,
                0,
                "rank-death",
                Axis::X,
                format!("rank {r} heartbeat timeout (> {} ms silent)", (hb * 4).as_millis()),
            ));
            // the dead rank's handler may be blocked in read; unblock it
            sh.shutdowns[r].shutdown();
            return;
        }
    }
}
