//! The multi-process world coordinator behind the socket transports
//! (the `scalegnn-coord` binary wraps [`Coordinator`]).
//!
//! Handshake state machine (one world, one run):
//!
//! ```text
//!   BIND ──accept──► REGISTER: each connection must open with a valid
//!     Hello{rank, grid}; wrong grid / out-of-range rank / duplicate
//!     rank / undecodable bytes are rejected (logged, connection
//!     dropped) without disturbing registered ranks.
//!   REGISTER ──all ranks present──► RUN: Welcome{world, heartbeat_ms}
//!     is sent to every rank; per-rank handler threads serve
//!     Contribute / Barrier / Ping / Poison / Bye frames.
//!   RUN ──every rank sent Bye──► DONE (returns no failure), or
//!   RUN ──any failure──► POISONED: the first failure origin is
//!     recorded and broadcast to every rank as a Poison frame; ranks
//!     panic with that origin, close, and the coordinator drains the
//!     remaining connections and returns the failure.
//! ```
//!
//! Failures that poison the world: a collective handshake mismatch
//! (kind/length/precision — same checks, same message text as the
//! in-process engine), a rank-sent Poison (injected fault), a peer
//! connection dying mid-run or sending undecodable bytes
//! (`"rank-death"`), a heartbeat timeout, or a protocol violation.
//!
//! Determinism: a reduce completes when the last member contributes and
//! is summed **in group-index member order**, never arrival order — so
//! socket-transport results are bitwise identical to the in-process
//! engine's ordered chunk reduction.

use std::collections::HashMap;
use std::net::TcpListener;
use std::os::unix::net::UnixListener;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use super::socket::{Conn, Endpoint};
use super::wire::{self, Msg, WireError};
use super::{CollKind, CommError};
use crate::grid::{Axis, Grid4D};

/// Coordinator tuning.
#[derive(Clone, Copy, Debug)]
pub struct CoordConfig {
    /// Heartbeat interval ranks are told to ping at; a rank silent for
    /// 4 intervals is declared dead.  0 disables the watchdog (tests,
    /// and runs where rank steps may legitimately take long).
    pub heartbeat_ms: u32,
    /// Suppress progress logging on stderr.
    pub quiet: bool,
}

impl Default for CoordConfig {
    fn default() -> CoordConfig {
        CoordConfig { heartbeat_ms: 0, quiet: true }
    }
}

enum Listener {
    Tcp(TcpListener),
    Unix(UnixListener),
}

/// One in-flight collective of one group: contributions keyed by member
/// index, completed (and answered) when the last member arrives.
struct CoordOp {
    kind: CollKind,
    /// Reduce payload length handshake (first contributor sets it).
    len: usize,
    parts: Vec<Option<Vec<f32>>>,
    n: usize,
}

struct CoordState {
    /// Op slots keyed by (axis index, group id, seq).
    ops: HashMap<(usize, usize, u64), CoordOp>,
    /// Barrier arrival counts keyed by (axis index, group id, bseq).
    barriers: HashMap<(usize, usize, u64), usize>,
    /// First failure origin; sticky once set.
    failure: Option<CommError>,
    /// Ranks that sent Bye.
    done: Vec<bool>,
    /// Last frame seen per rank (heartbeat watchdog).
    last_seen: Vec<Instant>,
}

struct Shared {
    grid: Grid4D,
    cfg: CoordConfig,
    state: Mutex<CoordState>,
    /// Per-rank write half, locked per frame (handlers of any rank may
    /// complete an op and answer every member).
    writers: Vec<Mutex<Conn>>,
    /// Per-rank shutdown handles (watchdog unblocks a dead rank's
    /// blocked reader).
    shutdowns: Vec<Conn>,
}

fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|p| p.into_inner())
}

impl Shared {
    fn log(&self, msg: &str) {
        if !self.cfg.quiet {
            eprintln!("coord: {msg}");
        }
    }

    fn send(&self, rank: usize, msg: &Msg) {
        let failed = {
            let mut w = lock(&self.writers[rank]);
            wire::write_msg(&mut *w, msg).is_err()
        };
        if failed && !matches!(msg, Msg::Poison { .. }) {
            self.poison_world(CommError::new(
                rank,
                0,
                "rank-death",
                Axis::X,
                format!("rank {rank} unreachable (result delivery failed)"),
            ));
        }
    }

    /// Record the first failure origin and broadcast it to every rank.
    /// Idempotent: later failures are cascade effects of the first.
    fn poison_world(&self, err: CommError) {
        {
            let mut st = lock(&self.state);
            if st.failure.is_some() {
                return;
            }
            st.failure = Some(err.clone());
        }
        self.log(&format!(
            "failure origin rank {} op {} seq {} axis {}: {}",
            err.rank,
            err.op,
            err.seq,
            err.axis.tag(),
            err.msg
        ));
        for r in 0..self.grid.world_size() {
            self.send(r, &Msg::Poison { err: err.clone() });
        }
    }

    fn touch(&self, rank: usize) {
        lock(&self.state).last_seen[rank] = Instant::now();
    }

    fn contribute(&self, rank: usize, axis: Axis, seq: u64, kind: CollKind, data: Vec<f32>) {
        let size = self.grid.axis_size(axis);
        if size <= 1 {
            // size-1 groups never reach a transport; a frame for one is a
            // protocol violation
            self.poison_world(CommError::new(
                rank,
                seq,
                "protocol",
                axis,
                format!("contribution to size-1 axis {axis:?}"),
            ));
            return;
        }
        let gid = self.grid.group_id(rank, axis);
        let me = self.grid.index_in_group(rank, axis);
        let key = (axis.index(), gid, seq);
        let completed = {
            let mut st = lock(&self.state);
            if st.failure.is_some() {
                return; // world is dying; ranks have the origin
            }
            let op = st.ops.entry(key).or_insert_with(|| CoordOp {
                kind,
                len: data.len(),
                parts: vec![None; size],
                n: 0,
            });
            if op.kind != kind {
                let msg = format!(
                    "collective kind mismatch at seq {seq}: slot holds {:?}, member {me} issued {:?}",
                    op.kind, kind
                );
                let err = CommError::new(rank, seq, kind.op_name(), axis, msg);
                drop(st);
                self.poison_world(err);
                return;
            }
            if matches!(kind, CollKind::Reduce(_)) && op.len != data.len() {
                let msg = format!(
                    "all_reduce length mismatch at seq {seq}: slot has {} elems, member {me} sent {}",
                    op.len,
                    data.len()
                );
                let err = CommError::new(rank, seq, kind.op_name(), axis, msg);
                drop(st);
                self.poison_world(err);
                return;
            }
            if op.parts[me].is_some() {
                let err = CommError::new(
                    rank,
                    seq,
                    "protocol",
                    axis,
                    format!("member {me} double-contributed seq {seq}"),
                );
                drop(st);
                self.poison_world(err);
                return;
            }
            op.parts[me] = Some(data);
            op.n += 1;
            if op.n == size {
                st.ops.remove(&key)
            } else {
                None
            }
        };
        if let Some(op) = completed {
            let members = self.grid.group_ranks(rank, axis);
            match op.kind {
                CollKind::Reduce(_) => {
                    // ordered sum in group-index member order: bitwise
                    // identical to the in-process chunked reduction
                    // lint: allow(panic-free-boundary) — op completed under the state lock with n == size, so every slot is Some; a flatten() would silently drop a member and corrupt the reduction
                    let mut parts = op.parts.into_iter().map(|p| p.unwrap());
                    // lint: allow(panic-free-boundary) — size >= 2 was enforced at op creation, so the first part exists
                    let mut result = parts.next().unwrap();
                    for p in parts {
                        for (d, v) in result.iter_mut().zip(p) {
                            *d += v;
                        }
                    }
                    for &m in &members {
                        self.send(m, &Msg::ReduceResult { axis, seq, data: result.clone() });
                    }
                }
                CollKind::Gather(prec) => {
                    // parts were rounded at the source for bf16, so the
                    // result leg re-narrows losslessly on the wire
                    let parts: Vec<Vec<f32>> =
                        // lint: allow(panic-free-boundary) — op completed under the state lock with n == size, so every slot is Some (see the Reduce arm)
                        op.parts.into_iter().map(|p| p.unwrap()).collect();
                    for &m in &members {
                        self.send(
                            m,
                            &Msg::GatherResult { axis, seq, prec, parts: parts.clone() },
                        );
                    }
                }
            }
        }
    }

    fn barrier(&self, rank: usize, axis: Axis, bseq: u64) {
        let size = self.grid.axis_size(axis);
        if size <= 1 {
            self.poison_world(CommError::new(
                rank,
                bseq,
                "protocol",
                axis,
                format!("barrier on size-1 axis {axis:?}"),
            ));
            return;
        }
        let gid = self.grid.group_id(rank, axis);
        let key = (axis.index(), gid, bseq);
        let release = {
            let mut st = lock(&self.state);
            if st.failure.is_some() {
                return;
            }
            let n = st.barriers.entry(key).or_insert(0);
            *n += 1;
            if *n == size {
                st.barriers.remove(&key);
                true
            } else {
                false
            }
        };
        if release {
            for &m in &self.grid.group_ranks(rank, axis) {
                self.send(m, &Msg::BarrierRelease { axis, bseq });
            }
        }
    }

    fn handle_rank(&self, rank: usize, conn: &mut Conn) {
        loop {
            match wire::read_msg(conn) {
                Ok(Msg::Contribute { axis, seq, kind, data }) => {
                    self.touch(rank);
                    self.contribute(rank, axis, seq, kind, data);
                }
                Ok(Msg::Barrier { axis, bseq }) => {
                    self.touch(rank);
                    self.barrier(rank, axis, bseq);
                }
                Ok(Msg::Ping) => self.touch(rank),
                Ok(Msg::Poison { err }) => {
                    // a rank announcing its own death (injected fault):
                    // broadcast the origin unchanged
                    self.poison_world(err);
                }
                Ok(Msg::Bye) => {
                    lock(&self.state).done[rank] = true;
                    self.log(&format!("rank {rank} completed"));
                    return;
                }
                Ok(m) => {
                    self.poison_world(CommError::new(
                        rank,
                        0,
                        "protocol",
                        Axis::X,
                        format!("unexpected frame {m:?} mid-run"),
                    ));
                    return;
                }
                Err(e) => {
                    let benign = {
                        let st = lock(&self.state);
                        st.done[rank] || st.failure.is_some()
                    };
                    if !benign {
                        let msg = match e {
                            WireError::Closed => {
                                format!("rank {rank} connection closed mid-run")
                            }
                            e => format!("undecodable frame from rank {rank}: {e}"),
                        };
                        self.poison_world(CommError::new(rank, 0, "rank-death", Axis::X, msg));
                    }
                    return;
                }
            }
        }
    }
}

/// One-shot world coordinator: bind, register `world_size` ranks, serve
/// the run, return the failure origin (if any).  See the module docs for
/// the handshake state machine.
pub struct Coordinator {
    grid: Grid4D,
    cfg: CoordConfig,
    listener: Listener,
    endpoint: Endpoint,
}

impl Coordinator {
    /// Bind the listening socket.  For `tcp:host:0` the OS picks a port;
    /// [`Coordinator::endpoint`] reports the resolved address.  An
    /// existing file at a unix socket path is removed (a stale socket
    /// from a previous run).
    pub fn bind(grid: Grid4D, ep: &Endpoint, cfg: CoordConfig) -> Result<Coordinator> {
        let (listener, endpoint) = match ep {
            Endpoint::Tcp(addr) => {
                let l = TcpListener::bind(addr.as_str())
                    .map_err(|e| anyhow!("binding tcp {addr}: {e}"))?;
                let resolved = l.local_addr()?.to_string();
                (Listener::Tcp(l), Endpoint::Tcp(resolved))
            }
            Endpoint::Unix(path) => {
                let _ = std::fs::remove_file(path);
                let l = UnixListener::bind(path)
                    .map_err(|e| anyhow!("binding unix {}: {e}", path.display()))?;
                (Listener::Unix(l), Endpoint::Unix(path.clone()))
            }
        };
        Ok(Coordinator { grid, cfg, listener, endpoint })
    }

    /// The resolved endpoint ranks should connect to.
    pub fn endpoint(&self) -> &Endpoint {
        &self.endpoint
    }

    fn accept(&self) -> Result<Conn> {
        Ok(match &self.listener {
            Listener::Tcp(l) => {
                let (s, _) = l.accept()?;
                let _ = s.set_nodelay(true);
                Conn::Tcp(s)
            }
            Listener::Unix(l) => {
                let (s, _) = l.accept()?;
                Conn::Unix(s)
            }
        })
    }

    /// Register every rank, serve the world, and return the failure
    /// origin (`None` = every rank completed cleanly).
    pub fn run(self) -> Result<Option<CommError>> {
        let n = self.grid.world_size();
        let quiet = self.cfg.quiet;
        let log = |m: &str| {
            if !quiet {
                eprintln!("coord: {m}");
            }
        };
        // --- REGISTER: n valid Hellos, invalid connections rejected ---
        let mut conns: Vec<Option<Conn>> = (0..n).map(|_| None).collect();
        let mut registered = 0;
        while registered < n {
            let mut conn = self.accept()?;
            // a connection that never sends its Hello must not stall
            // world assembly forever
            let _ = conn.set_read_timeout(Some(Duration::from_secs(30)));
            match wire::read_msg(&mut conn) {
                Ok(Msg::Hello { rank, grid }) => {
                    let want = [
                        self.grid.gd as u32,
                        self.grid.gx as u32,
                        self.grid.gy as u32,
                        self.grid.gz as u32,
                    ];
                    let r = rank as usize;
                    if grid != want {
                        log(&format!(
                            "rejecting rank {rank}: grid {grid:?} does not match {want:?}"
                        ));
                    } else if r >= n {
                        log(&format!("rejecting rank {rank}: world has {n} ranks"));
                    } else if conns[r].is_some() {
                        log(&format!("rejecting duplicate registration for rank {rank}"));
                    } else {
                        let _ = conn.set_read_timeout(None);
                        conns[r] = Some(conn);
                        registered += 1;
                        log(&format!("rank {r} registered ({registered}/{n})"));
                    }
                }
                Ok(m) => log(&format!("rejecting connection: expected hello, got {m:?}")),
                Err(e) => log(&format!("rejecting connection: {e}")),
            }
        }
        // --- RUN: welcome everyone, then serve per-rank handlers ---
        let mut writers = Vec::with_capacity(n);
        let mut shutdowns = Vec::with_capacity(n);
        let mut readers = Vec::with_capacity(n);
        // lint: allow(panic-free-boundary) — the accept loop above runs until registered == n, and registered only increments when conns[r] is filled, so every slot is Some here
        for c in conns.into_iter().map(|c| c.expect("registered")) {
            writers.push(Mutex::new(c.try_clone()?));
            shutdowns.push(c.try_clone()?);
            readers.push(c);
        }
        let shared = Arc::new(Shared {
            grid: self.grid,
            cfg: self.cfg,
            state: Mutex::new(CoordState {
                ops: HashMap::new(),
                barriers: HashMap::new(),
                failure: None,
                done: vec![false; n],
                last_seen: vec![Instant::now(); n],
            }),
            writers,
            shutdowns,
        });
        for r in 0..n {
            shared.send(
                r,
                &Msg::Welcome { world: n as u32, heartbeat_ms: self.cfg.heartbeat_ms },
            );
        }
        log(&format!("world assembled: {n} ranks on {}", self.endpoint));
        let mut handles = Vec::with_capacity(n);
        for (r, mut conn) in readers.into_iter().enumerate() {
            let sh = shared.clone();
            handles.push(std::thread::spawn(move || sh.handle_rank(r, &mut conn)));
        }
        let stop = Arc::new(AtomicBool::new(false));
        let watchdog = (self.cfg.heartbeat_ms > 0).then(|| {
            let sh = shared.clone();
            let stop = stop.clone();
            let hb = self.cfg.heartbeat_ms;
            std::thread::spawn(move || watchdog_loop(&sh, &stop, hb))
        });
        for h in handles {
            let _ = h.join();
        }
        stop.store(true, Ordering::Relaxed);
        if let Some(w) = watchdog {
            let _ = w.join();
        }
        if let Endpoint::Unix(path) = &self.endpoint {
            let _ = std::fs::remove_file(path);
        }
        let failure = lock(&shared.state).failure.clone();
        match &failure {
            None => log("world completed cleanly"),
            Some(e) => log(&format!("world failed: {e}")),
        }
        Ok(failure)
    }

    /// [`Coordinator::run`] on a background thread (in-process tests and
    /// benchmarks; child processes use the `scalegnn-coord` binary).
    pub fn spawn(self) -> std::thread::JoinHandle<Result<Option<CommError>>> {
        std::thread::spawn(move || self.run())
    }
}

fn watchdog_loop(sh: &Shared, stop: &AtomicBool, heartbeat_ms: u32) {
    let timeout = Duration::from_millis(heartbeat_ms as u64 * 4);
    loop {
        std::thread::sleep(Duration::from_millis((heartbeat_ms as u64 / 2).max(10)));
        if stop.load(Ordering::Relaxed) {
            return;
        }
        let dead = {
            let st = lock(&sh.state);
            if st.failure.is_some() {
                return;
            }
            (0..sh.grid.world_size())
                .find(|&r| !st.done[r] && st.last_seen[r].elapsed() > timeout)
        };
        if let Some(r) = dead {
            sh.poison_world(CommError::new(
                r,
                0,
                "rank-death",
                Axis::X,
                format!("rank {r} heartbeat timeout (> {} ms silent)", timeout.as_millis()),
            ));
            // the dead rank's handler may be blocked in read; unblock it
            sh.shutdowns[r].shutdown();
            return;
        }
    }
}
