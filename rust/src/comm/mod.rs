//! Shared-memory collectives over rank threads.
//!
//! A "GPU" in this reproduction is an OS thread with private shard state;
//! collectives move real data through per-group rendezvous slots, so the 3D
//! PMM algebra and the DP gradient synchronization are *executed*, not
//! mocked.  Wall-clock at paper scale is projected separately by
//! `sim::` — these collectives are for correctness and for measuring the
//! coordinator's real overheads at <= 64 ranks.
//!
//! BF16 mode reproduces §V-B numerically: each rank's contribution is
//! rounded to bf16 before the reduction (results stay f32), and the byte
//! accounting halves the payload — exactly what casting before an NCCL
//! all-reduce does.

use std::sync::{Barrier, Mutex};
use std::sync::atomic::{AtomicU64, Ordering};

use crate::grid::{Axis, Grid4D};
use crate::util::bf16_round;

/// Payload precision for collectives (§V-B low-precision communication).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Precision {
    /// Full-precision f32 payloads.
    Fp32,
    /// Contributions rounded to bf16 before the reduction (§V-B); results
    /// stay f32, byte accounting halves the payload.
    Bf16,
}

impl Precision {
    /// Payload bytes per element for the byte accounting.
    pub fn bytes_per_elem(&self) -> u64 {
        match self {
            Precision::Fp32 => 4,
            Precision::Bf16 => 2,
        }
    }
}

struct Slot {
    buf: Vec<f32>,
    gathered: Vec<Vec<f32>>,
    contributed: usize,
    read: usize,
}

struct Group {
    size: usize,
    barrier: Barrier,
    slot: Mutex<Slot>,
}

/// Per-axis traffic counters (feeds the epoch-time breakdown metrics).
#[derive(Default)]
pub struct AxisCounters {
    /// Collective operations accounted on this axis.
    pub ops: AtomicU64,
    /// Logical payload bytes moved on this axis.
    pub bytes: AtomicU64,
}

/// All process groups of a 4D grid.
pub struct CommWorld {
    /// The grid this world was built for.
    pub grid: Grid4D,
    groups: Vec<Vec<Group>>, // [axis][group_id]
    /// Traffic counters indexed by axis (X, Y, Z, Dp).
    pub counters: [AxisCounters; 4],
}

fn axis_idx(a: Axis) -> usize {
    match a {
        Axis::X => 0,
        Axis::Y => 1,
        Axis::Z => 2,
        Axis::Dp => 3,
    }
}

impl CommWorld {
    /// Allocate the rendezvous slots of every process group of `grid`.
    ///
    /// Slot protocol (per group): contributors accumulate into the shared
    /// buffer under the mutex, a barrier separates the write phase from the
    /// read phase, and the last reader resets the slot for the next
    /// collective — so back-to-back collectives on the same group never
    /// alias.
    pub fn new(grid: Grid4D) -> CommWorld {
        let mk = |axis: Axis| -> Vec<Group> {
            (0..grid.num_groups(axis))
                .map(|_| Group {
                    size: grid.axis_size(axis),
                    barrier: Barrier::new(grid.axis_size(axis)),
                    slot: Mutex::new(Slot {
                        buf: Vec::new(),
                        gathered: vec![Vec::new(); grid.axis_size(axis)],
                        contributed: 0,
                        read: 0,
                    }),
                })
                .collect()
        };
        CommWorld {
            grid,
            groups: vec![mk(Axis::X), mk(Axis::Y), mk(Axis::Z), mk(Axis::Dp)],
            counters: Default::default(),
        }
    }

    fn group(&self, rank: usize, axis: Axis) -> &Group {
        &self.groups[axis_idx(axis)][self.grid.group_id(rank, axis)]
    }

    fn account(&self, axis: Axis, elems: u64, prec: Precision, group_size: usize) {
        if group_size <= 1 {
            return;
        }
        let c = &self.counters[axis_idx(axis)];
        c.ops.fetch_add(1, Ordering::Relaxed);
        // ring all-reduce moves ~2 n bytes per rank; we account the logical
        // payload volume (n * wordsize) — the cost model applies the 2(p-1)/p
        c.bytes.fetch_add(elems * prec.bytes_per_elem(), Ordering::Relaxed);
    }

    /// Sum-all-reduce `data` across the rank's `axis` group, in place.
    pub fn all_reduce(&self, rank: usize, axis: Axis, data: &mut [f32], prec: Precision) {
        let g = self.group(rank, axis);
        if g.size == 1 {
            return;
        }
        self.account(axis, data.len() as u64, prec, g.size);
        {
            let mut s = g.slot.lock().unwrap();
            if s.contributed == 0 {
                s.buf.clear();
                s.buf.resize(data.len(), 0.0);
            }
            debug_assert_eq!(s.buf.len(), data.len(), "mismatched all_reduce sizes");
            match prec {
                Precision::Fp32 => {
                    for (b, &d) in s.buf.iter_mut().zip(data.iter()) {
                        *b += d;
                    }
                }
                Precision::Bf16 => {
                    for (b, &d) in s.buf.iter_mut().zip(data.iter()) {
                        *b += bf16_round(d);
                    }
                }
            }
            s.contributed += 1;
        }
        g.barrier.wait();
        {
            let mut s = g.slot.lock().unwrap();
            data.copy_from_slice(&s.buf);
            s.read += 1;
            if s.read == g.size {
                s.contributed = 0;
                s.read = 0;
            }
        }
        g.barrier.wait();
    }

    /// Gather each member's payload; returns the payloads ordered by the
    /// member's index within the group.  Payload lengths may differ.
    pub fn all_gather(&self, rank: usize, axis: Axis, payload: &[f32]) -> Vec<Vec<f32>> {
        let g = self.group(rank, axis);
        if g.size == 1 {
            return vec![payload.to_vec()];
        }
        self.account(axis, payload.len() as u64, Precision::Fp32, g.size);
        let me = self.grid.index_in_group(rank, axis);
        {
            let mut s = g.slot.lock().unwrap();
            s.gathered[me] = payload.to_vec();
            s.contributed += 1;
        }
        g.barrier.wait();
        let out;
        {
            let mut s = g.slot.lock().unwrap();
            out = s.gathered.clone();
            s.read += 1;
            if s.read == g.size {
                s.contributed = 0;
                s.read = 0;
                for v in s.gathered.iter_mut() {
                    v.clear();
                }
            }
        }
        g.barrier.wait();
        out
    }

    /// Barrier across the rank's `axis` group.
    pub fn barrier(&self, rank: usize, axis: Axis) {
        let g = self.group(rank, axis);
        if g.size > 1 {
            g.barrier.wait();
        }
    }

    /// Snapshot (ops, bytes) for an axis.
    pub fn stats(&self, axis: Axis) -> (u64, u64) {
        let c = &self.counters[axis_idx(axis)];
        (c.ops.load(Ordering::Relaxed), c.bytes.load(Ordering::Relaxed))
    }

    /// Zero all per-axis traffic counters.
    pub fn reset_stats(&self) {
        for c in &self.counters {
            c.ops.store(0, Ordering::Relaxed);
            c.bytes.store(0, Ordering::Relaxed);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn run_ranks<F>(grid: Grid4D, f: F) -> Vec<Vec<f32>>
    where
        F: Fn(usize, &CommWorld) -> Vec<f32> + Send + Sync + 'static,
    {
        let world = Arc::new(CommWorld::new(grid));
        let f = Arc::new(f);
        let mut handles = vec![];
        for r in 0..grid.world_size() {
            let w = world.clone();
            let f = f.clone();
            handles.push(std::thread::spawn(move || f(r, &w)));
        }
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    }

    #[test]
    fn all_reduce_sums_across_x_groups_only() {
        let grid = Grid4D::new(1, 2, 2, 1);
        let outs = run_ranks(grid, |rank, w| {
            let mut v = vec![rank as f32 + 1.0; 3];
            w.all_reduce(rank, Axis::X, &mut v, Precision::Fp32);
            v
        });
        // X groups: {0,1} (y=0) and {2,3} (y=1)
        assert_eq!(outs[0], vec![3.0; 3]);
        assert_eq!(outs[1], vec![3.0; 3]);
        assert_eq!(outs[2], vec![7.0; 3]);
        assert_eq!(outs[3], vec![7.0; 3]);
    }

    #[test]
    fn repeated_all_reduce_reuses_slots_correctly() {
        let grid = Grid4D::new(1, 4, 1, 1);
        let outs = run_ranks(grid, |rank, w| {
            let mut acc = vec![];
            for round in 0..10 {
                let mut v = vec![(rank + round) as f32];
                w.all_reduce(rank, Axis::X, &mut v, Precision::Fp32);
                acc.push(v[0]);
            }
            acc
        });
        for o in outs {
            for (round, &v) in o.iter().enumerate() {
                // sum over ranks of (rank + round) = 6 + 4*round
                assert_eq!(v, 6.0 + 4.0 * round as f32);
            }
        }
    }

    #[test]
    fn bf16_mode_rounds_contributions() {
        let grid = Grid4D::new(1, 2, 1, 1);
        let outs = run_ranks(grid, |rank, w| {
            // a value with bits below bf16 precision
            let x = if rank == 0 { 1.0009765625f32 } else { 0.0 };
            let mut v = vec![x];
            w.all_reduce(rank, Axis::X, &mut v, Precision::Bf16);
            v
        });
        let expect = bf16_round(1.0009765625);
        assert_eq!(outs[0][0], expect);
        assert_ne!(outs[0][0], 1.0009765625);
    }

    #[test]
    fn all_gather_orders_by_group_index() {
        let grid = Grid4D::new(1, 1, 3, 1);
        let outs = run_ranks(grid, |rank, w| {
            let mine = vec![rank as f32; rank + 1]; // variable lengths
            let all = w.all_gather(rank, Axis::Y, &mine);
            all.into_iter().flatten().collect()
        });
        for o in outs {
            assert_eq!(o, vec![0.0, 1.0, 1.0, 2.0, 2.0, 2.0]);
        }
    }

    #[test]
    fn dp_axis_reduces_across_groups() {
        let grid = Grid4D::new(2, 2, 1, 1);
        let outs = run_ranks(grid, |rank, w| {
            let mut v = vec![if w.grid.coord(rank).d == 0 { 1.0 } else { 10.0 }];
            w.all_reduce(rank, Axis::Dp, &mut v, Precision::Fp32);
            v
        });
        for o in outs {
            assert_eq!(o, vec![11.0]);
        }
    }

    #[test]
    fn size_one_group_is_noop_and_unaccounted() {
        let grid = Grid4D::new(1, 1, 1, 1);
        let world = CommWorld::new(grid);
        let mut v = vec![5.0];
        world.all_reduce(0, Axis::X, &mut v, Precision::Fp32);
        assert_eq!(v, vec![5.0]);
        assert_eq!(world.stats(Axis::X), (0, 0));
    }

    #[test]
    fn byte_accounting_tracks_precision() {
        let grid = Grid4D::new(1, 2, 1, 1);
        let outs = run_ranks(grid, |rank, w| {
            let mut v = vec![1.0; 8];
            w.all_reduce(rank, Axis::X, &mut v, Precision::Fp32);
            w.all_reduce(rank, Axis::X, &mut v, Precision::Bf16);
            vec![]
        });
        drop(outs);
        // can't reach into the moved world; re-run with a shared one
        let world = Arc::new(CommWorld::new(grid));
        let w1 = world.clone();
        let w2 = world.clone();
        let t1 = std::thread::spawn(move || {
            let mut v = vec![1.0; 8];
            w1.all_reduce(0, Axis::X, &mut v, Precision::Fp32);
            w1.all_reduce(0, Axis::X, &mut v, Precision::Bf16);
        });
        let t2 = std::thread::spawn(move || {
            let mut v = vec![1.0; 8];
            w2.all_reduce(1, Axis::X, &mut v, Precision::Fp32);
            w2.all_reduce(1, Axis::X, &mut v, Precision::Bf16);
        });
        t1.join().unwrap();
        t2.join().unwrap();
        let (ops, bytes) = world.stats(Axis::X);
        assert_eq!(ops, 4); // 2 collectives x 2 ranks accounted
        assert_eq!(bytes, 2 * (8 * 4) + 2 * (8 * 2));
    }
}
