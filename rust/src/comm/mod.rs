//! Collectives over rank threads or rank processes — a nonblocking,
//! chunked collective engine (§V-D) behind a pluggable [`Transport`].
//!
//! A "GPU" in this reproduction is an OS thread (or, over the socket
//! transports, an OS process) with private shard state; collectives move
//! real data through per-group, sequence-matched op slots, so the 3D PMM
//! algebra and the DP gradient synchronization are *executed*, not
//! mocked.  Wall-clock at paper scale is projected separately by `sim::`
//! — these collectives are for correctness and for measuring the
//! coordinator's real overheads at <= 64 ranks.
//!
//! **Transports.**  [`CommWorld`] owns a boxed [`Transport`] that moves
//! the payloads; everything above it (accounting, overlap timing, the
//! poison-cascade contract, the pending-handle API) is shared:
//!
//! * [`InProcTransport`] — every rank is a thread of this process and op
//!   slots live in shared memory.  The default ([`CommWorld::new`]) and
//!   bit-for-bit the pre-trait engine.
//! * [`SocketTransport`] — this process runs *one* rank; contributions
//!   travel as CRC-checked [`wire`] frames over TCP or a Unix-domain
//!   socket to a `scalegnn-coord` coordinator ([`coord::Coordinator`])
//!   that reduces in group-index member order, so results are bitwise
//!   identical to the in-process engine.  Built by [`CommWorld::connect`].
//!
//! **Nonblocking issue (§V-D).**  [`CommWorld::issue_all_reduce`] stages the
//! caller's contribution and returns a [`PendingOp`] handle immediately;
//! the reduction proceeds while the caller computes, and
//! [`PendingOp::wait_into`] blocks only at the true data dependency.  The
//! blocking [`CommWorld::all_reduce`] / [`CommWorld::all_gather`] entry
//! points are thin `issue(..).wait(..)` wrappers, so call sites opt into
//! overlap mechanically.
//!
//! **Determinism.**  Reductions are order-deterministic: once every member
//! has contributed, payloads are summed in group-index order, never in
//! arrival order — so overlap-on and overlap-off schedules, repeated
//! runs, *and different transports* produce bitwise-identical results
//! (`tests/transport_conformance.rs` pins this).
//!
//! **Mismatch safety.**  Collectives that disagree across members at the
//! same sequence number (different kind, payload length or precision)
//! poison the group and panic on *every* member instead of deadlocking in
//! the rendezvous slot.  The panic payload is a structured [`CommError`]
//! naming the originating rank, sequence number, op kind and axis; the
//! *same* origin is carried unchanged through the cascade into every group
//! a dying rank belongs to — over sockets the coordinator broadcasts it to
//! every live rank — so bystanders fail fast and a supervisor can
//! downcast the payload and report exactly which rank/seq/op died
//! (the elastic-recovery path in `session::backends`).
//!
//! **BF16 mode** reproduces §V-B numerically: each rank's contribution is
//! rounded to bf16 before the reduction (results stay f32), and the byte
//! accounting halves the payload — exactly what casting before an NCCL
//! all-reduce does.  The socket transports ship bf16 contributions as the
//! high 16 bits of the rounded f32, which is lossless.  All-gathers take
//! the same [`Precision`]: a bf16 gather rounds every member's payload
//! before distribution (each receiver sees identical rounded rows on any
//! transport), and both wire directions — the contribution *and* the
//! broadcast gather result — ship the half-width bits.
//!
//! **Measured overlap.**  Per-axis counters record logical traffic (ops,
//! bytes) plus per-op timings: issue→fully-reduced (`comm`) vs time spent
//! blocked inside `wait` (`blocked`), counted only for collectives issued
//! through the nonblocking API (blocking wrappers are true dependencies,
//! not hideable even in principle).  Their ratio is the measured
//! hidden-communication fraction ([`CommWorld::hidden_fraction`],
//! [`CommWorld::tp_hidden_fraction`]) that calibrates the hideable share
//! of the §V-D term in `sim::model` in place of a guessed constant.
//! Counters live on the world handle: with `InProc` all ranks share one
//! world, over sockets each rank process owns its own.

pub mod chaos;
pub mod coord;
mod inproc;
mod socket;
pub mod wire;

pub use chaos::{ChaosMode, ChaosSpec, ChaosTransport};
pub use coord::{CoordConfig, Coordinator};
pub use inproc::InProcTransport;
pub use socket::{Endpoint, SocketTransport};

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, Instant};

use crate::grid::{Axis, Grid4D};

/// Payload precision for collectives (§V-B low-precision communication).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Precision {
    /// Full-precision f32 payloads.
    Fp32,
    /// Contributions rounded to bf16 before the reduction (§V-B); results
    /// stay f32, byte accounting halves the payload.
    Bf16,
}

impl Precision {
    /// Payload bytes per element for the byte accounting.
    pub fn bytes_per_elem(&self) -> u64 {
        match self {
            Precision::Fp32 => 4,
            Precision::Bf16 => 2,
        }
    }

    /// Spec / CLI name of the precision (`"fp32"` / `"bf16"`).
    pub fn name(&self) -> &'static str {
        match self {
            Precision::Fp32 => "fp32",
            Precision::Bf16 => "bf16",
        }
    }

    /// Parse a spec / CLI precision name; `None` for anything but
    /// `"fp32"` / `"bf16"`.
    pub fn parse(s: &str) -> Option<Precision> {
        match s {
            "fp32" => Some(Precision::Fp32),
            "bf16" => Some(Precision::Bf16),
            _ => None,
        }
    }
}

/// Default elements per chunk (16 KiB of f32 payload per chunk).
pub const DEFAULT_CHUNK_ELEMS: usize = 4096;

/// How a collective failure came about — a real fault, a deadline expiry
/// on a silent-but-alive rank, or a peer process death.  Supervisors
/// route all three through the same re-form-and-replay recovery; reports
/// keep them apart so a straggler is diagnosed as a straggler.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FailureKind {
    /// A protocol violation, mismatch handshake, or injected fault.
    Fault,
    /// A configured `wait_timeout_ms` deadline expired: some member is
    /// silent (not provably dead) and the group was poisoned instead of
    /// hanging forever.
    Stalled,
    /// A peer process died or its connection dropped.
    Death,
}

impl FailureKind {
    /// Report tag for this kind (`"fault"` / `"stalled"` / `"death"`).
    pub fn tag(&self) -> &'static str {
        match self {
            FailureKind::Fault => "fault",
            FailureKind::Stalled => "stalled",
            FailureKind::Death => "death",
        }
    }
}

/// Timing knobs of the distributed runtime, spec-visible on
/// `RunSpec.transport` and threaded to every blocking wait.  `None`
/// fields resolve to the engine defaults via the accessor methods; the
/// spec layer validates that provided values are nonzero and within a
/// day (`session::spec`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TransportTuning {
    /// Socket connect + registration handshake budget (default 10 000).
    pub connect_timeout_ms: Option<u32>,
    /// Heartbeat interval the coordinator demands of ranks; rank side
    /// this is advisory (the coordinator's `Welcome` carries the actual
    /// interval).  Default 0 = no heartbeat.
    pub heartbeat_ms: Option<u32>,
    /// Deadline on every blocking collective wait; expiry poisons the
    /// group with a [`FailureKind::Stalled`] origin (default 30 000).
    pub wait_timeout_ms: Option<u32>,
    /// How long the coordinator holds a failed rank's slot open for a
    /// re-registration before tearing the world down (default 0 =
    /// rejoin disabled, fail fast).
    pub rejoin_grace_ms: Option<u32>,
}

impl TransportTuning {
    /// Socket connect + handshake budget.
    pub fn connect_timeout(&self) -> Duration {
        Duration::from_millis(u64::from(self.connect_timeout_ms.unwrap_or(10_000)))
    }

    /// Heartbeat interval in ms (0 = disabled).
    pub fn heartbeat(&self) -> u32 {
        self.heartbeat_ms.unwrap_or(0)
    }

    /// Deadline on every blocking collective wait.
    pub fn wait_timeout(&self) -> Duration {
        Duration::from_millis(u64::from(self.wait_timeout_ms.unwrap_or(30_000)))
    }

    /// Rejoin grace window (zero = rejoin disabled).
    pub fn rejoin_grace(&self) -> Duration {
        Duration::from_millis(u64::from(self.rejoin_grace_ms.unwrap_or(0)))
    }
}

/// Structured origin of a collective failure: which rank died, at which
/// group sequence number, issuing which op on which axis, and why.
///
/// This is the panic payload of every comm-engine death (mismatch
/// handshake, poison cascade, injected fault, peer process death),
/// carried *unchanged* from the originating rank through the cascade so
/// a bystander's panic still names the true origin.  Rank supervisors
/// downcast the payload (`Box<dyn Any>::downcast::<CommError>`) to
/// report the failure in the `RunReport` and drive checkpoint-based
/// recovery.
#[derive(Clone, Debug, PartialEq)]
pub struct CommError {
    /// Global rank where the failure originated.
    pub rank: usize,
    /// Group sequence number of the failing collective (0 for injected
    /// faults, which are not tied to an op slot).
    pub seq: u64,
    /// Op kind at the origin: `"all_reduce"`, `"all_gather"`,
    /// `"barrier"`, `"injected-fault"`, or — over socket transports —
    /// `"rank-death"` (a peer process died or sent an undecodable frame)
    /// / `"coordinator-lost"` (the coordinator connection dropped).
    pub op: &'static str,
    /// Axis of the group where the failure originated.
    pub axis: Axis,
    /// How the failure came about; a [`FailureKind::Stalled`] origin
    /// means a deadline expired on a silent member, not that anything
    /// provably died.
    pub kind: FailureKind,
    /// Human-readable cause (the handshake mismatch text, the injected
    /// fault description, or the wire decode error).
    pub msg: String,
}

impl CommError {
    /// Build a failure origin (transports construct these; everything
    /// downstream only clones and reports them).  The kind is inferred
    /// from the op — `"rank-death"` / `"coordinator-lost"` are deaths,
    /// everything else a fault; use [`CommError::stalled`] for deadline
    /// expiries.
    pub fn new(rank: usize, seq: u64, op: &'static str, axis: Axis, msg: String) -> CommError {
        let kind = match op {
            "rank-death" | "coordinator-lost" => FailureKind::Death,
            _ => FailureKind::Fault,
        };
        CommError { rank, seq, op, axis, kind, msg }
    }

    /// Build a [`FailureKind::Stalled`] origin: the deadline on `op`
    /// expired and `rank` is the member the evidence points at (the
    /// first missing contributor, or the silent waiter itself).
    pub fn stalled(rank: usize, seq: u64, op: &'static str, axis: Axis, msg: String) -> CommError {
        CommError { rank, seq, op, axis, kind: FailureKind::Stalled, msg }
    }
}

impl std::fmt::Display for CommError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "comm: rank {} {} seq {} on axis {:?}: {}",
            self.rank, self.op, self.seq, self.axis, self.msg
        )
    }
}

impl std::error::Error for CommError {}

/// Collective kind carried by an op slot (handshake-checked across
/// members, and across the wire by the socket transports).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CollKind {
    /// Sum all-reduce at a payload precision.
    Reduce(Precision),
    /// All-gather at a payload precision (variable payload lengths
    /// allowed; bf16 rounds every member's payload before distribution).
    Gather(Precision),
}

impl CollKind {
    /// The op name reported in [`CommError::op`] for this kind.
    pub fn op_name(self) -> &'static str {
        match self {
            CollKind::Reduce(_) => "all_reduce",
            CollKind::Gather(_) => "all_gather",
        }
    }

    /// The payload precision carried by this kind.
    pub fn precision(self) -> Precision {
        match self {
            CollKind::Reduce(p) | CollKind::Gather(p) => p,
        }
    }
}

pub(crate) fn axis_idx(a: Axis) -> usize {
    a.index()
}

/// Per-axis traffic + overlap counters (feeds the epoch-time breakdown
/// metrics and the measured §V-D hide fraction).
#[derive(Default)]
pub struct AxisCounters {
    /// Collective operations accounted on this axis.
    pub ops: AtomicU64,
    /// Logical payload bytes moved on this axis.
    pub bytes: AtomicU64,
    /// Nanoseconds from a rank's issue until the op was fully reduced /
    /// gathered, summed over ranks and ops — counted only for collectives
    /// issued through the nonblocking API (`issue_*`); the blocking
    /// wrappers are excluded so the ratio measures how much of the
    /// *deferrable* communication was actually hidden.
    pub comm_ns: AtomicU64,
    /// Nanoseconds ranks spent blocked inside `wait` on nonblocking-issued
    /// collectives; `1 - blocked/comm` is the hidden-comm fraction.
    pub blocked_ns: AtomicU64,
}

/// What a collective backend must provide for [`CommWorld`] to run the
/// sequence-matched op protocol over it.
///
/// The contract (pinned for every implementation by
/// `tests/transport_conformance.rs`):
///
/// * **Sequencing** — [`Transport::issue`] assigns the rank's next
///   per-axis sequence number and stages its contribution; equal seq on
///   an axis group = same logical op on every member.
/// * **Determinism** — reductions sum contributions in group-index
///   member order, so every transport yields bitwise-identical results.
/// * **Errors, never deadlocks** — kind/length/precision mismatches,
///   injected faults and peer deaths surface as a [`CommError`] from
///   `issue`/`wait_*`/`barrier` (the *same* origin on every member);
///   implementations never panic and never hang a waiter forever.
/// * **Poison is sticky** — after [`Transport::fail`] (or any internal
///   failure) every subsequent call of this rank returns the recorded
///   origin via [`Transport::poison_of`].
///
/// Size-1 groups never reach the transport: [`CommWorld`] short-circuits
/// them (the reduction is the identity, the barrier a no-op).
pub trait Transport: Send + Sync {
    /// Short name for reports and benchmarks (`"inproc"`, `"tcp"`, …).
    fn name(&self) -> &'static str;

    /// Stage `rank`'s contribution to its next collective on `axis` and
    /// return the op's sequence number.
    fn issue(&self, rank: usize, axis: Axis, kind: CollKind, data: &[f32])
        -> Result<u64, CommError>;

    /// Nonblocking: has the reduce issued at `seq` on `axis` completed
    /// (or failed — the subsequent wait surfaces the error)?
    fn try_ready(&self, rank: usize, axis: Axis, seq: u64) -> bool;

    /// Block until the reduce at `seq` completes; write the result into
    /// `out` and return the completion instant (for the overlap timing).
    fn wait_reduce(
        &self,
        rank: usize,
        axis: Axis,
        seq: u64,
        out: &mut [f32],
    ) -> Result<Instant, CommError>;

    /// Block until the gather at `seq` completes; returns the payloads in
    /// group-index order plus the completion instant.
    fn wait_gather(
        &self,
        rank: usize,
        axis: Axis,
        seq: u64,
    ) -> Result<(Vec<Vec<f32>>, Instant), CommError>;

    /// Opportunistically advance pending work without blocking; returns
    /// whether anything moved (socket transports complete remotely and
    /// return `false`).
    fn progress(&self, rank: usize) -> bool;

    /// Barrier across `rank`'s `axis` group.
    fn barrier(&self, rank: usize, axis: Axis) -> Result<(), CommError>;

    /// Record `err` as the failure origin of `rank`'s groups and wake /
    /// notify every peer that could block on this rank (does **not**
    /// panic; [`CommWorld`] layers the panic-with-payload on top).
    fn fail(&self, rank: usize, err: &CommError);

    /// The recorded failure origin visible to `rank`, if any of its
    /// groups was poisoned.
    fn poison_of(&self, rank: usize) -> Option<CommError>;

    /// Whether the coordinator offered this (poisoned) rank a rejoin:
    /// the world is re-forming in place and the supervisor may
    /// reconnect into the same coordinator instead of tearing the run
    /// down.  Transports without a coordinator never offer one.
    fn rejoin_offered(&self, _rank: usize) -> bool {
        false
    }
}

/// All process groups of a 4D grid, over a pluggable [`Transport`].
pub struct CommWorld {
    /// The grid this world was built for.
    pub grid: Grid4D,
    /// Traffic counters indexed by axis (X, Y, Z, Dp).
    pub counters: [AxisCounters; 4],
    transport: Box<dyn Transport>,
}

impl CommWorld {
    /// In-process world: every rank is a thread sharing op slots in
    /// memory, with the default reduction chunk size.
    pub fn new(grid: Grid4D) -> CommWorld {
        CommWorld::with_chunk_elems(grid, DEFAULT_CHUNK_ELEMS)
    }

    /// As [`CommWorld::new`] with an explicit reduction chunk size in
    /// elements (tests use tiny chunks to exercise the chunk pipeline).
    pub fn with_chunk_elems(grid: Grid4D, chunk_elems: usize) -> CommWorld {
        CommWorld::with_transport(grid, Box::new(InProcTransport::new(grid, chunk_elems)))
    }

    /// A world over an explicit transport (the conformance suite builds
    /// every backend through this one constructor).
    pub fn with_transport(grid: Grid4D, transport: Box<dyn Transport>) -> CommWorld {
        CommWorld { grid, counters: Default::default(), transport }
    }

    /// In-process world with explicit [`TransportTuning`] and an
    /// optional deterministic chaos schedule wrapped around the
    /// transport (`session::backends` builds PMM worlds through this).
    pub fn with_tuning(
        grid: Grid4D,
        chunk_elems: usize,
        tuning: &TransportTuning,
        chaos: Option<&ChaosSpec>,
    ) -> CommWorld {
        let inner = InProcTransport::with_wait_timeout(grid, chunk_elems, tuning.wait_timeout());
        let transport: Box<dyn Transport> = match chaos {
            Some(spec) => Box::new(
                ChaosTransport::new(Box::new(inner), spec.clone())
                    .with_stall_cap(tuning.wait_timeout() * 4),
            ),
            None => Box::new(inner),
        };
        CommWorld::with_transport(grid, transport)
    }

    /// Socket world for **one** rank of a multi-process run: register
    /// with the `scalegnn-coord` coordinator at `endpoint`, block until
    /// the full world assembled, and return a world whose collectives
    /// travel as [`wire`] frames.  Counters on this handle account this
    /// rank's traffic only.
    pub fn connect(grid: Grid4D, rank: usize, endpoint: &Endpoint) -> anyhow::Result<CommWorld> {
        CommWorld::connect_with(grid, rank, endpoint, &TransportTuning::default(), None)
    }

    /// As [`CommWorld::connect`] with explicit [`TransportTuning`] and an
    /// optional deterministic chaos schedule on the write side of the
    /// connection.
    pub fn connect_with(
        grid: Grid4D,
        rank: usize,
        endpoint: &Endpoint,
        tuning: &TransportTuning,
        chaos: Option<&ChaosSpec>,
    ) -> anyhow::Result<CommWorld> {
        let t = SocketTransport::connect_with(grid, rank, endpoint, tuning, chaos)?;
        Ok(CommWorld::with_transport(grid, Box::new(t)))
    }

    /// Short name of the transport moving this world's payloads.
    pub fn transport_name(&self) -> &'static str {
        self.transport.name()
    }

    fn account(&self, axis: Axis, elems: u64, prec: Precision, group_size: usize) {
        if group_size <= 1 {
            return;
        }
        let c = &self.counters[axis_idx(axis)];
        c.ops.fetch_add(1, Ordering::Relaxed);
        // ring all-reduce moves ~2 n bytes per rank; we account the logical
        // payload volume (n * wordsize) — the cost model applies the 2(p-1)/p
        c.bytes.fetch_add(elems * prec.bytes_per_elem(), Ordering::Relaxed);
    }

    /// Poison every group `rank` belongs to with `err` (waking their
    /// waiters), then panic with `err` as the structured payload.  A
    /// member that dies inside one collective must not leave peers in its
    /// *other* groups waiting on a contribution that will never come, so
    /// the poison cascades rank-by-rank through shared groups — over
    /// sockets the coordinator broadcasts it world-wide — and each awoken
    /// member re-panics with the *original* origin.
    fn poison_and_panic(&self, rank: usize, err: CommError) -> ! {
        self.transport.fail(rank, &err);
        std::panic::panic_any(err);
    }

    /// Deterministic fault injection: kill the calling rank *now*,
    /// poisoning all its groups exactly like a real collective failure so
    /// peers fail fast and a supervisor can recover from the last
    /// checkpoint.  Drives the `FaultSpec::KillRank` crash-recovery path.
    pub fn fail(&self, rank: usize, msg: &str) -> ! {
        self.poison_and_panic(
            rank,
            CommError::new(rank, 0, "injected-fault", Axis::X, msg.to_string()),
        );
    }

    /// The failure origin poisoning any of `rank`'s groups, if one was
    /// recorded.  Engines call this at step boundaries so a rank whose
    /// next collective is far away still learns of a dead peer promptly.
    pub fn poison_of(&self, rank: usize) -> Option<CommError> {
        self.transport.poison_of(rank)
    }

    /// Whether the coordinator offered this (poisoned) rank a rejoin —
    /// the world is re-forming in place, and the supervisor should
    /// reconnect and replay from the newest consistent snapshot rather
    /// than exit.
    pub fn rejoin_offered(&self, rank: usize) -> bool {
        self.transport.rejoin_offered(rank)
    }

    /// `Ok(())` while `rank`'s groups are healthy; the recorded failure
    /// origin as the error once any of them was poisoned.  The checked
    /// entry point for report/stats queries after a run — a poisoned
    /// world must answer with the origin, not with misleading numbers.
    pub fn check_healthy(&self, rank: usize) -> Result<(), CommError> {
        match self.transport.poison_of(rank) {
            None => Ok(()),
            Some(e) => Err(e),
        }
    }

    /// Issue a sum-all-reduce of `data` across the rank's `axis` group;
    /// returns a [`PendingOp`] handle.  The caller's contribution is
    /// staged immediately (the borrow ends at return); the reduction
    /// proceeds while the caller computes, and [`PendingOp::wait_into`]
    /// blocks only on the true dependency.
    pub fn issue_all_reduce(
        &self,
        rank: usize,
        axis: Axis,
        data: &[f32],
        prec: Precision,
    ) -> PendingOp<'_> {
        self.issue_reduce_inner(rank, axis, data, prec, true)
    }

    fn issue_reduce_inner(
        &self,
        rank: usize,
        axis: Axis,
        data: &[f32],
        prec: Precision,
        deferred: bool,
    ) -> PendingOp<'_> {
        let issued_at = Instant::now();
        if self.grid.axis_size(axis) == 1 {
            // a size-1 "reduction" is the identity; keep the payload so
            // wait_into honors its write-into-`out` contract
            return PendingOp {
                world: self,
                axis,
                rank,
                seq: 0,
                len: data.len(),
                trivial: Some(data.to_vec()),
                deferred,
                issued_at,
            };
        }
        self.account(axis, data.len() as u64, prec, self.grid.axis_size(axis));
        match self.transport.issue(rank, axis, CollKind::Reduce(prec), data) {
            Ok(seq) => PendingOp {
                world: self,
                axis,
                rank,
                seq,
                len: data.len(),
                trivial: None,
                deferred,
                issued_at,
            },
            Err(e) => self.poison_and_panic(rank, e),
        }
    }

    /// Issue a gather of `payload` across the rank's `axis` group; returns
    /// a [`PendingGather`] resolved by [`PendingGather::wait`].  Payload
    /// lengths may differ across members.  With [`Precision::Bf16`] every
    /// member's payload is rounded to bf16 before distribution (§V-B) and
    /// the byte accounting halves.
    pub fn issue_all_gather(
        &self,
        rank: usize,
        axis: Axis,
        payload: &[f32],
        prec: Precision,
    ) -> PendingGather<'_> {
        self.issue_gather_inner(rank, axis, payload, prec, true)
    }

    fn issue_gather_inner(
        &self,
        rank: usize,
        axis: Axis,
        payload: &[f32],
        prec: Precision,
        deferred: bool,
    ) -> PendingGather<'_> {
        let issued_at = Instant::now();
        if self.grid.axis_size(axis) == 1 {
            return PendingGather {
                world: self,
                axis,
                rank,
                seq: 0,
                trivial: Some(payload.to_vec()),
                deferred,
                issued_at,
            };
        }
        self.account(axis, payload.len() as u64, prec, self.grid.axis_size(axis));
        match self.transport.issue(rank, axis, CollKind::Gather(prec), payload) {
            Ok(seq) => {
                PendingGather { world: self, axis, rank, seq, trivial: None, deferred, issued_at }
            }
            Err(e) => self.poison_and_panic(rank, e),
        }
    }

    /// Drive pending work of this rank's groups without blocking — the
    /// per-rank progress engine of the nonblocking API.  Cheap (bounded
    /// work, `try_lock` only); returns whether anything advanced.
    pub fn progress(&self, rank: usize) -> bool {
        self.transport.progress(rank)
    }

    /// Sum-all-reduce `data` across the rank's `axis` group, in place
    /// (blocking wrapper over issue + wait; excluded from the hidden-comm
    /// timing so the measured fraction covers only deferrable ops).
    pub fn all_reduce(&self, rank: usize, axis: Axis, data: &mut [f32], prec: Precision) {
        if self.grid.axis_size(axis) == 1 {
            return; // identity in place, no payload copy
        }
        let op = self.issue_reduce_inner(rank, axis, data, prec, false);
        op.wait_into(data);
    }

    /// Gather each member's payload; returns the payloads ordered by the
    /// member's index within the group.  Payload lengths may differ
    /// (blocking wrapper over issue + wait; excluded from the hidden-comm
    /// timing).  With [`Precision::Bf16`] payloads are rounded before
    /// distribution (§V-B) and the byte accounting halves.
    pub fn all_gather(
        &self,
        rank: usize,
        axis: Axis,
        payload: &[f32],
        prec: Precision,
    ) -> Vec<Vec<f32>> {
        if self.grid.axis_size(axis) == 1 {
            return vec![payload.to_vec()];
        }
        self.issue_gather_inner(rank, axis, payload, prec, false).wait()
    }

    /// Barrier across the rank's `axis` group.  Panics with the
    /// originating [`CommError`] if the group was poisoned while waiting
    /// (a dead peer can never arrive).
    pub fn barrier(&self, rank: usize, axis: Axis) {
        if self.grid.axis_size(axis) == 1 {
            return;
        }
        if let Err(e) = self.transport.barrier(rank, axis) {
            self.poison_and_panic(rank, e);
        }
    }

    /// Snapshot (ops, bytes) for an axis.
    pub fn stats(&self, axis: Axis) -> (u64, u64) {
        let c = &self.counters[axis_idx(axis)];
        (c.ops.load(Ordering::Relaxed), c.bytes.load(Ordering::Relaxed))
    }

    /// [`CommWorld::stats`] that refuses to answer on a poisoned world:
    /// returns the failure origin instead of counters that stopped
    /// moving when the world died.
    pub fn stats_checked(&self, rank: usize, axis: Axis) -> Result<(u64, u64), CommError> {
        self.check_healthy(rank)?;
        Ok(self.stats(axis))
    }

    /// Snapshot (comm seconds, blocked seconds) measured on an axis: total
    /// issue→completion time vs time ranks actually stalled in `wait`.
    pub fn timing(&self, axis: Axis) -> (f64, f64) {
        let c = &self.counters[axis_idx(axis)];
        (
            c.comm_ns.load(Ordering::Relaxed) as f64 * 1e-9,
            c.blocked_ns.load(Ordering::Relaxed) as f64 * 1e-9,
        )
    }

    /// [`CommWorld::timing`] guarded like [`CommWorld::stats_checked`].
    pub fn timing_checked(&self, rank: usize, axis: Axis) -> Result<(f64, f64), CommError> {
        self.check_healthy(rank)?;
        Ok(self.timing(axis))
    }

    /// Measured fraction of this axis's *deferrable* collective time
    /// hidden behind compute: `1 - blocked/comm` over collectives issued
    /// through the nonblocking API, clamped to `[0, 1]` (0 when none
    /// ran).  Blocking-wrapper collectives (true data dependencies) are
    /// excluded — they are not hideable even in principle, and counting
    /// them would bias the §V-D calibration low.
    pub fn hidden_fraction(&self, axis: Axis) -> f64 {
        let c = &self.counters[axis_idx(axis)];
        let comm = c.comm_ns.load(Ordering::Relaxed) as f64;
        if comm <= 0.0 {
            return 0.0;
        }
        let blocked = c.blocked_ns.load(Ordering::Relaxed) as f64;
        (1.0 - blocked / comm).clamp(0.0, 1.0)
    }

    /// [`CommWorld::hidden_fraction`] that returns the failure origin on
    /// a poisoned world instead of an overlap number cut short by the
    /// death (ops in flight when a world dies never accrue their
    /// blocked time, so the unchecked fraction would read as optimistic).
    pub fn hidden_fraction_checked(&self, rank: usize, axis: Axis) -> Result<f64, CommError> {
        self.check_healthy(rank)?;
        Ok(self.hidden_fraction(axis))
    }

    /// Aggregate hidden fraction over the tensor-parallel axes (X, Y, Z):
    /// the executed counterpart of the §V-D hide fraction consumed by
    /// `sim::model` in place of a guessed constant.
    pub fn tp_hidden_fraction(&self) -> f64 {
        let (mut comm, mut blocked) = (0u64, 0u64);
        for axis in [Axis::X, Axis::Y, Axis::Z] {
            let c = &self.counters[axis_idx(axis)];
            comm += c.comm_ns.load(Ordering::Relaxed);
            blocked += c.blocked_ns.load(Ordering::Relaxed);
        }
        if comm == 0 {
            return 0.0;
        }
        (1.0 - blocked as f64 / comm as f64).clamp(0.0, 1.0)
    }

    /// Zero all per-axis traffic and timing counters.
    pub fn reset_stats(&self) {
        for c in &self.counters {
            c.ops.store(0, Ordering::Relaxed);
            c.bytes.store(0, Ordering::Relaxed);
            c.comm_ns.store(0, Ordering::Relaxed);
            c.blocked_ns.store(0, Ordering::Relaxed);
        }
    }
}

/// Handle of an in-flight chunked all-reduce.  Resolve with
/// [`PendingOp::wait_into`]; poll with [`PendingOp::try_ready`].  Dropping
/// a handle without waiting leaks its op slot (the engine always waits).
#[must_use = "a pending collective must be awaited (PendingOp::wait_into)"]
pub struct PendingOp<'w> {
    world: &'w CommWorld,
    axis: Axis,
    rank: usize,
    seq: u64,
    len: usize,
    /// Size-1 groups complete at issue: the "reduction" is the identity,
    /// kept here so `wait_into` still writes the promised result.
    trivial: Option<Vec<f32>>,
    /// Issued through the nonblocking API (counted in the overlap timing)
    /// vs through a blocking wrapper (excluded).
    deferred: bool,
    issued_at: Instant,
}

impl PendingOp<'_> {
    /// Payload length of the issued op.
    pub fn len(&self) -> usize {
        self.len
    }

    /// True when the issued payload is empty.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Nonblocking readiness check; in-process it opportunistically
    /// drives a bounded number of chunk reductions while it holds the
    /// group lock (bounded like `progress` so a poll never stalls peers
    /// queueing on the lock; a subsequent blocking wait finishes any
    /// remainder).
    pub fn try_ready(&self) -> bool {
        self.trivial.is_some() || self.world.transport.try_ready(self.rank, self.axis, self.seq)
    }

    /// Block until every chunk is reduced and write the result into `out`
    /// (same length as the issued payload).  Waiters drive the remaining
    /// reductions themselves, so completion never depends on a third
    /// party.  Panics with the originating [`CommError`] as payload if the
    /// group was poisoned by a mismatched collective or injected fault.
    pub fn wait_into(self, out: &mut [f32]) {
        assert_eq!(out.len(), self.len, "wait_into buffer length mismatch");
        if let Some(p) = self.trivial {
            out.copy_from_slice(&p);
            return;
        }
        let w = self.world;
        let t_wait = Instant::now();
        let completed_at = match w.transport.wait_reduce(self.rank, self.axis, self.seq, out) {
            Ok(t) => t,
            Err(e) => w.poison_and_panic(self.rank, e),
        };
        if self.deferred {
            let blocked = t_wait.elapsed();
            let total = completed_at.saturating_duration_since(self.issued_at);
            let c = &w.counters[axis_idx(self.axis)];
            c.comm_ns.fetch_add(total.as_nanos() as u64, Ordering::Relaxed);
            c.blocked_ns
                .fetch_add(blocked.min(total).as_nanos() as u64, Ordering::Relaxed);
        }
    }
}

/// Handle of an in-flight all-gather; resolve with [`PendingGather::wait`].
#[must_use = "a pending collective must be awaited (PendingGather::wait)"]
pub struct PendingGather<'w> {
    world: &'w CommWorld,
    axis: Axis,
    rank: usize,
    seq: u64,
    /// Size-1 groups complete at issue with the caller's own payload.
    trivial: Option<Vec<f32>>,
    /// Issued through the nonblocking API (counted in the overlap timing)
    /// vs through a blocking wrapper (excluded).
    deferred: bool,
    issued_at: Instant,
}

impl PendingGather<'_> {
    /// Block until every member's payload arrived; returns the payloads in
    /// group-index order.  Panics with the originating [`CommError`] as
    /// payload if the group was poisoned by a mismatched collective or
    /// injected fault.
    pub fn wait(self) -> Vec<Vec<f32>> {
        if let Some(p) = self.trivial {
            return vec![p];
        }
        let w = self.world;
        let t_wait = Instant::now();
        let (out, completed_at) = match w.transport.wait_gather(self.rank, self.axis, self.seq) {
            Ok(r) => r,
            Err(e) => w.poison_and_panic(self.rank, e),
        };
        if self.deferred {
            let blocked = t_wait.elapsed();
            let total = completed_at.saturating_duration_since(self.issued_at);
            let c = &w.counters[axis_idx(self.axis)];
            c.comm_ns.fetch_add(total.as_nanos() as u64, Ordering::Relaxed);
            c.blocked_ns
                .fetch_add(blocked.min(total).as_nanos() as u64, Ordering::Relaxed);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::bf16_round;
    use std::sync::Arc;

    fn run_ranks<F>(grid: Grid4D, f: F) -> Vec<Vec<f32>>
    where
        F: Fn(usize, &CommWorld) -> Vec<f32> + Send + Sync + 'static,
    {
        let world = Arc::new(CommWorld::new(grid));
        let f = Arc::new(f);
        let mut handles = vec![];
        for r in 0..grid.world_size() {
            let w = world.clone();
            let f = f.clone();
            handles.push(std::thread::spawn(move || f(r, &w)));
        }
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    }

    #[test]
    fn all_reduce_sums_across_x_groups_only() {
        let grid = Grid4D::new(1, 2, 2, 1);
        let outs = run_ranks(grid, |rank, w| {
            let mut v = vec![rank as f32 + 1.0; 3];
            w.all_reduce(rank, Axis::X, &mut v, Precision::Fp32);
            v
        });
        // X groups: {0,1} (y=0) and {2,3} (y=1)
        assert_eq!(outs[0], vec![3.0; 3]);
        assert_eq!(outs[1], vec![3.0; 3]);
        assert_eq!(outs[2], vec![7.0; 3]);
        assert_eq!(outs[3], vec![7.0; 3]);
    }

    #[test]
    fn repeated_all_reduce_reuses_slots_correctly() {
        let grid = Grid4D::new(1, 4, 1, 1);
        let outs = run_ranks(grid, |rank, w| {
            let mut acc = vec![];
            for round in 0..10 {
                let mut v = vec![(rank + round) as f32];
                w.all_reduce(rank, Axis::X, &mut v, Precision::Fp32);
                acc.push(v[0]);
            }
            acc
        });
        for o in outs {
            for (round, &v) in o.iter().enumerate() {
                // sum over ranks of (rank + round) = 6 + 4*round
                assert_eq!(v, 6.0 + 4.0 * round as f32);
            }
        }
    }

    #[test]
    fn bf16_mode_rounds_contributions() {
        let grid = Grid4D::new(1, 2, 1, 1);
        let outs = run_ranks(grid, |rank, w| {
            // a value with bits below bf16 precision
            let x = if rank == 0 { 1.0009765625f32 } else { 0.0 };
            let mut v = vec![x];
            w.all_reduce(rank, Axis::X, &mut v, Precision::Bf16);
            v
        });
        let expect = bf16_round(1.0009765625);
        assert_eq!(outs[0][0], expect);
        assert_ne!(outs[0][0], 1.0009765625);
    }

    #[test]
    fn all_gather_orders_by_group_index() {
        let grid = Grid4D::new(1, 1, 3, 1);
        let outs = run_ranks(grid, |rank, w| {
            let mine = vec![rank as f32; rank + 1]; // variable lengths
            let all = w.all_gather(rank, Axis::Y, &mine, Precision::Fp32);
            all.into_iter().flatten().collect()
        });
        for o in outs {
            assert_eq!(o, vec![0.0, 1.0, 1.0, 2.0, 2.0, 2.0]);
        }
    }

    #[test]
    fn bf16_gather_rounds_payloads_and_halves_bytes() {
        let grid = Grid4D::new(1, 2, 1, 1);
        let world = Arc::new(CommWorld::new(grid));
        let mut hs = vec![];
        for rank in 0..2usize {
            let w = world.clone();
            hs.push(std::thread::spawn(move || {
                // a value with bits below bf16 precision
                let mine = vec![1.0009765625f32 + rank as f32];
                w.all_gather(rank, Axis::X, &mine, Precision::Bf16)
            }));
        }
        for h in hs {
            let parts = h.join().unwrap();
            assert_eq!(parts.len(), 2);
            for (r, p) in parts.iter().enumerate() {
                let want = bf16_round(1.0009765625 + r as f32);
                assert_eq!(p[0], want, "member {r} payload must be rounded");
                assert_ne!(p[0], 1.0009765625 + r as f32);
            }
        }
        // 1 elem x 2 bytes x 2 ranks accounted
        let (ops, bytes) = world.stats(Axis::X);
        assert_eq!(ops, 2);
        assert_eq!(bytes, 2 * 2);
    }

    #[test]
    fn dp_axis_reduces_across_groups() {
        let grid = Grid4D::new(2, 2, 1, 1);
        let outs = run_ranks(grid, |rank, w| {
            let mut v = vec![if w.grid.coord(rank).d == 0 { 1.0 } else { 10.0 }];
            w.all_reduce(rank, Axis::Dp, &mut v, Precision::Fp32);
            v
        });
        for o in outs {
            assert_eq!(o, vec![11.0]);
        }
    }

    #[test]
    fn size_one_group_is_noop_and_unaccounted() {
        let grid = Grid4D::new(1, 1, 1, 1);
        let world = CommWorld::new(grid);
        let mut v = vec![5.0];
        world.all_reduce(0, Axis::X, &mut v, Precision::Fp32);
        assert_eq!(v, vec![5.0]);
        assert_eq!(world.stats(Axis::X), (0, 0));
    }

    #[test]
    fn byte_accounting_tracks_precision() {
        let grid = Grid4D::new(1, 2, 1, 1);
        let world = Arc::new(CommWorld::new(grid));
        let w1 = world.clone();
        let w2 = world.clone();
        let t1 = std::thread::spawn(move || {
            let mut v = vec![1.0; 8];
            w1.all_reduce(0, Axis::X, &mut v, Precision::Fp32);
            w1.all_reduce(0, Axis::X, &mut v, Precision::Bf16);
        });
        let t2 = std::thread::spawn(move || {
            let mut v = vec![1.0; 8];
            w2.all_reduce(1, Axis::X, &mut v, Precision::Fp32);
            w2.all_reduce(1, Axis::X, &mut v, Precision::Bf16);
        });
        t1.join().unwrap();
        t2.join().unwrap();
        let (ops, bytes) = world.stats(Axis::X);
        assert_eq!(ops, 4); // 2 collectives x 2 ranks accounted
        assert_eq!(bytes, 2 * (8 * 4) + 2 * (8 * 2));
    }

    #[test]
    fn nonblocking_issue_allows_out_of_order_waits() {
        // two ops in flight per rank on the same group, waited in reverse
        let grid = Grid4D::new(1, 2, 1, 1);
        let outs = run_ranks(grid, |rank, w| {
            let a = vec![rank as f32 + 1.0; 5];
            let b = vec![10.0 * (rank as f32 + 1.0); 7];
            let pa = w.issue_all_reduce(rank, Axis::X, &a, Precision::Fp32);
            let pb = w.issue_all_reduce(rank, Axis::X, &b, Precision::Fp32);
            let mut rb = vec![0.0; 7];
            pb.wait_into(&mut rb);
            let mut ra = vec![0.0; 5];
            pa.wait_into(&mut ra);
            ra.extend_from_slice(&rb);
            ra
        });
        for o in outs {
            assert_eq!(&o[..5], &[3.0; 5]);
            assert_eq!(&o[5..], &[30.0; 7]);
        }
    }

    #[test]
    fn chunked_reduction_matches_unchunked() {
        // payload of 10 elems with 3-elem chunks: 4 chunks, same sums
        let grid = Grid4D::new(1, 3, 1, 1);
        let world = Arc::new(CommWorld::with_chunk_elems(grid, 3));
        let mut hs = vec![];
        for rank in 0..3 {
            let w = world.clone();
            hs.push(std::thread::spawn(move || {
                let mut v: Vec<f32> = (0..10).map(|i| (rank * 10 + i) as f32).collect();
                w.all_reduce(rank, Axis::X, &mut v, Precision::Fp32);
                v
            }));
        }
        for h in hs {
            let v = h.join().unwrap();
            for (i, &x) in v.iter().enumerate() {
                // sum over ranks r of (10 r + i) = 30 + 3 i
                assert_eq!(x, (30 + 3 * i) as f32);
            }
        }
    }

    #[test]
    fn try_ready_becomes_true_after_peers_issue() {
        let grid = Grid4D::new(1, 2, 1, 1);
        let world = Arc::new(CommWorld::new(grid));
        let w1 = world.clone();
        let t = std::thread::spawn(move || {
            let v = vec![2.0; 4];
            let p = w1.issue_all_reduce(1, Axis::X, &v, Precision::Fp32);
            let mut out = vec![0.0; 4];
            p.wait_into(&mut out);
            out
        });
        let v = vec![1.0; 4];
        let p = world.issue_all_reduce(0, Axis::X, &v, Precision::Fp32);
        // the peer will issue eventually; poll until ready
        while !p.try_ready() {
            std::thread::yield_now();
        }
        let mut out = vec![0.0; 4];
        p.wait_into(&mut out);
        assert_eq!(out, vec![3.0; 4]);
        assert_eq!(t.join().unwrap(), vec![3.0; 4]);
    }

    #[test]
    fn mismatch_panic_payload_is_structured() {
        // both the originating member and the poisoned peer must die with
        // the SAME CommError origin, downcastable from the join payload
        let grid = Grid4D::new(1, 2, 1, 1);
        let world = Arc::new(CommWorld::new(grid));
        let mut hs = vec![];
        for rank in 0..2usize {
            let w = world.clone();
            hs.push(std::thread::spawn(move || {
                let mut v = vec![1.0f32; if rank == 0 { 4 } else { 8 }];
                w.all_reduce(rank, Axis::X, &mut v, Precision::Fp32);
            }));
        }
        for h in hs {
            let payload = h.join().expect_err("mismatch must panic");
            let e = payload.downcast::<CommError>().expect("structured payload");
            assert_eq!(e.op, "all_reduce");
            assert_eq!(e.axis, Axis::X);
            assert_eq!(e.seq, 0);
            assert!(e.rank < 2);
            assert!(e.msg.contains("length mismatch"), "{}", e.msg);
        }
    }

    #[test]
    fn injected_fault_poisons_peers_with_its_origin() {
        let grid = Grid4D::new(1, 2, 1, 1);
        let world = Arc::new(CommWorld::new(grid));
        let w0 = world.clone();
        let killer = std::thread::spawn(move || {
            w0.fail(0, "scripted kill");
        });
        let w1 = world.clone();
        let victim = std::thread::spawn(move || {
            let mut v = vec![1.0f32; 4];
            // peer never contributes; the poison must wake and kill this wait
            w1.all_reduce(1, Axis::X, &mut v, Precision::Fp32);
        });
        for h in [killer, victim] {
            let payload = h.join().expect_err("both sides must die");
            let e = payload.downcast::<CommError>().expect("structured payload");
            assert_eq!(e.rank, 0, "bystander panic must name the true origin");
            assert_eq!(e.op, "injected-fault");
            assert_eq!(e.msg, "scripted kill");
        }
    }

    #[test]
    fn hidden_fraction_counts_deferred_ops_only() {
        let grid = Grid4D::new(1, 2, 1, 1);
        let world = Arc::new(CommWorld::new(grid));
        // blocking wrappers are excluded from the overlap timing ...
        let mut hs = vec![];
        for rank in 0..2 {
            let w = world.clone();
            hs.push(std::thread::spawn(move || {
                let mut v = vec![1.0; 1 << 18];
                w.all_reduce(rank, Axis::X, &mut v, Precision::Fp32);
            }));
        }
        for h in hs {
            h.join().unwrap();
        }
        assert_eq!(world.timing(Axis::X), (0.0, 0.0));
        assert_eq!(world.hidden_fraction(Axis::X), 0.0);
        // ... while nonblocking issues are measured
        let mut hs = vec![];
        for rank in 0..2 {
            let w = world.clone();
            hs.push(std::thread::spawn(move || {
                let v = vec![1.0; 1 << 18];
                let op = w.issue_all_reduce(rank, Axis::X, &v, Precision::Fp32);
                let mut out = vec![0.0; 1 << 18];
                op.wait_into(&mut out);
            }));
        }
        for h in hs {
            h.join().unwrap();
        }
        let f = world.hidden_fraction(Axis::X);
        assert!((0.0..=1.0).contains(&f), "hidden fraction {f}");
        let (comm_s, blocked_s) = world.timing(Axis::X);
        assert!(comm_s > 0.0, "deferred ops must be timed");
        assert!(blocked_s >= 0.0);
        world.reset_stats();
        assert_eq!(world.timing(Axis::X), (0.0, 0.0));
        assert_eq!(world.hidden_fraction(Axis::X), 0.0);
    }
}
