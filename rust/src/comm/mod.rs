//! Shared-memory collectives over rank threads — a nonblocking, chunked
//! collective engine (§V-D).
//!
//! A "GPU" in this reproduction is an OS thread with private shard state;
//! collectives move real data through per-group, sequence-matched op slots,
//! so the 3D PMM algebra and the DP gradient synchronization are *executed*,
//! not mocked.  Wall-clock at paper scale is projected separately by
//! `sim::` — these collectives are for correctness and for measuring the
//! coordinator's real overheads at <= 64 ranks.
//!
//! **Nonblocking issue (§V-D).**  [`CommWorld::issue_all_reduce`] copies the
//! caller's contribution into the op slot in fixed-size chunks and returns a
//! [`PendingOp`] handle immediately; the ordered reduction of chunk *k*
//! proceeds — driven by any member's [`CommWorld::progress`] call or by a
//! waiter — while the caller computes, and [`PendingOp::wait_into`] blocks
//! only at the true data dependency.  The blocking
//! [`CommWorld::all_reduce`] / [`CommWorld::all_gather`] entry points are
//! thin `issue(..).wait(..)` wrappers, so call sites opt into overlap
//! mechanically.
//!
//! **Determinism.**  Reductions are order-deterministic: once every member
//! has contributed, chunks are summed in group-index order, never in
//! arrival order — so overlap-on and overlap-off schedules (and repeated
//! runs) produce bitwise-identical results.
//!
//! **Mismatch safety.**  Collectives that disagree across members at the
//! same sequence number (different kind, payload length or precision)
//! poison the group and panic on *every* member instead of deadlocking in
//! the rendezvous slot.  The panic payload is a structured [`CommError`]
//! naming the originating rank, sequence number, op kind and axis; the
//! *same* origin is carried unchanged through the cascade into every group
//! a dying rank belongs to, so bystanders waiting on the dead rank in
//! *other* groups fail fast — and a supervisor joining the rank threads
//! can downcast the payload and report exactly which rank/seq/op died
//! (the elastic-recovery path in `session::backends`).
//!
//! **BF16 mode** reproduces §V-B numerically: each rank's contribution is
//! rounded to bf16 before the reduction (results stay f32), and the byte
//! accounting halves the payload — exactly what casting before an NCCL
//! all-reduce does.
//!
//! **Measured overlap.**  Per-axis counters record logical traffic (ops,
//! bytes) plus per-op timings: issue→fully-reduced (`comm`) vs time spent
//! blocked inside `wait` (`blocked`), counted only for collectives issued
//! through the nonblocking API (blocking wrappers are true dependencies,
//! not hideable even in principle).  Their ratio is the measured
//! hidden-communication fraction ([`CommWorld::hidden_fraction`],
//! [`CommWorld::tp_hidden_fraction`]) that calibrates the hideable share
//! of the §V-D term in `sim::model` in place of a guessed constant.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Barrier, Condvar, Mutex};
use std::time::Instant;

use crate::grid::{Axis, Grid4D};
use crate::util::bf16_round;

/// Payload precision for collectives (§V-B low-precision communication).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Precision {
    /// Full-precision f32 payloads.
    Fp32,
    /// Contributions rounded to bf16 before the reduction (§V-B); results
    /// stay f32, byte accounting halves the payload.
    Bf16,
}

impl Precision {
    /// Payload bytes per element for the byte accounting.
    pub fn bytes_per_elem(&self) -> u64 {
        match self {
            Precision::Fp32 => 4,
            Precision::Bf16 => 2,
        }
    }
}

/// Default elements per chunk (16 KiB of f32 payload per chunk).
pub const DEFAULT_CHUNK_ELEMS: usize = 4096;

/// Structured origin of a collective failure: which rank died, at which
/// group sequence number, issuing which op on which axis, and why.
///
/// This is the panic payload of every comm-engine death (mismatch
/// handshake, poison cascade, injected fault), carried *unchanged* from
/// the originating rank through the cascade so a bystander's panic still
/// names the true origin.  Rank-thread supervisors downcast the payload
/// (`Box<dyn Any>::downcast::<CommError>`) to report the failure in the
/// `RunReport` and drive checkpoint-based recovery.
#[derive(Clone, Debug)]
pub struct CommError {
    /// Global rank where the failure originated.
    pub rank: usize,
    /// Group sequence number of the failing collective (0 for injected
    /// faults, which are not tied to an op slot).
    pub seq: u64,
    /// Op kind at the origin: `"all_reduce"`, `"all_gather"` or
    /// `"injected-fault"`.
    pub op: &'static str,
    /// Axis of the group where the failure originated.
    pub axis: Axis,
    /// Human-readable cause (the handshake mismatch text, or the injected
    /// fault description).
    pub msg: String,
}

impl CommError {
    fn new(rank: usize, seq: u64, op: &'static str, axis: Axis, msg: String) -> CommError {
        CommError { rank, seq, op, axis, msg }
    }
}

impl std::fmt::Display for CommError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "comm: rank {} {} seq {} on axis {:?}: {}",
            self.rank, self.op, self.seq, self.axis, self.msg
        )
    }
}

impl std::error::Error for CommError {}

/// Collective kind carried by an op slot (handshake-checked across members).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum OpKind {
    Reduce(Precision),
    Gather,
}

/// One in-flight collective of a process group, matched across members by
/// sequence number (every member issues its group's collectives in the same
/// program order, so equal seq = same logical op).
struct OpState {
    seq: u64,
    kind: OpKind,
    /// Reduce: payload elements (identical on every member; handshaked).
    len: usize,
    /// Per-member contributions, group-index order (freed after reduction).
    parts: Vec<Vec<f32>>,
    contributed: Vec<bool>,
    n_contributed: usize,
    /// Reduce: ordered-sum result, valid below `chunks_done * chunk_elems`.
    result: Vec<f32>,
    chunks_done: usize,
    total_chunks: usize,
    /// Set when the payload is fully reduced (Reduce) / gathered (Gather).
    completed_at: Option<Instant>,
    read: usize,
}

struct GroupState {
    /// Per-member sequence number of its next issued collective.
    next_seq: Vec<u64>,
    /// In-flight ops, ascending `seq`.
    ops: VecDeque<OpState>,
    /// Set on a mismatched collective (or injected fault); every member
    /// panics with this same structured origin.
    poison: Option<CommError>,
}

struct Group {
    size: usize,
    barrier: Barrier,
    state: Mutex<GroupState>,
    cv: Condvar,
}

/// Per-axis traffic + overlap counters (feeds the epoch-time breakdown
/// metrics and the measured §V-D hide fraction).
#[derive(Default)]
pub struct AxisCounters {
    /// Collective operations accounted on this axis.
    pub ops: AtomicU64,
    /// Logical payload bytes moved on this axis.
    pub bytes: AtomicU64,
    /// Nanoseconds from a rank's issue until the op was fully reduced /
    /// gathered, summed over ranks and ops — counted only for collectives
    /// issued through the nonblocking API (`issue_*`); the blocking
    /// wrappers are excluded so the ratio measures how much of the
    /// *deferrable* communication was actually hidden.
    pub comm_ns: AtomicU64,
    /// Nanoseconds ranks spent blocked inside `wait` on nonblocking-issued
    /// collectives; `1 - blocked/comm` is the hidden-comm fraction.
    pub blocked_ns: AtomicU64,
}

/// All process groups of a 4D grid.
pub struct CommWorld {
    /// The grid this world was built for.
    pub grid: Grid4D,
    groups: Vec<Vec<Group>>, // [axis][group_id]
    /// Traffic counters indexed by axis (X, Y, Z, Dp).
    pub counters: [AxisCounters; 4],
    /// Elements per reduction chunk.
    chunk_elems: usize,
}

fn axis_idx(a: Axis) -> usize {
    match a {
        Axis::X => 0,
        Axis::Y => 1,
        Axis::Z => 2,
        Axis::Dp => 3,
    }
}

/// Contribute `data` to the op slot at `seq`, creating the slot on first
/// touch.  Returns a mismatch message (instead of contributing) when the
/// slot disagrees on kind or payload length — the length handshake that
/// turns a would-be deadlock into a clean error.
fn contribute(
    st: &mut GroupState,
    size: usize,
    chunk_elems: usize,
    me: usize,
    seq: u64,
    kind: OpKind,
    data: &[f32],
) -> Option<String> {
    if st.ops.iter().all(|o| o.seq != seq) {
        st.ops.push_back(OpState {
            seq,
            kind,
            len: data.len(),
            parts: vec![Vec::new(); size],
            contributed: vec![false; size],
            n_contributed: 0,
            result: match kind {
                OpKind::Reduce(_) => vec![0.0; data.len()],
                OpKind::Gather => Vec::new(),
            },
            chunks_done: 0,
            total_chunks: match kind {
                OpKind::Reduce(_) => data.len().div_ceil(chunk_elems).max(1),
                OpKind::Gather => 0,
            },
            completed_at: None,
            read: 0,
        });
    }
    let op = st.ops.iter_mut().find(|o| o.seq == seq).expect("just ensured");
    if op.kind != kind {
        return Some(format!(
            "collective kind mismatch at seq {seq}: slot holds {:?}, member {me} issued {:?}",
            op.kind, kind
        ));
    }
    if matches!(kind, OpKind::Reduce(_)) && op.len != data.len() {
        return Some(format!(
            "all_reduce length mismatch at seq {seq}: slot has {} elems, member {me} sent {}",
            op.len,
            data.len()
        ));
    }
    assert!(!op.contributed[me], "member {me} double-contributed seq {seq}");
    op.parts[me] = match kind {
        OpKind::Reduce(Precision::Bf16) => data.iter().map(|&v| bf16_round(v)).collect(),
        _ => data.to_vec(),
    };
    op.contributed[me] = true;
    op.n_contributed += 1;
    if op.n_contributed == size && matches!(kind, OpKind::Gather) {
        op.completed_at = Some(Instant::now());
    }
    None
}

impl CommWorld {
    /// Allocate the op slots of every process group of `grid` with the
    /// default reduction chunk size.
    pub fn new(grid: Grid4D) -> CommWorld {
        CommWorld::with_chunk_elems(grid, DEFAULT_CHUNK_ELEMS)
    }

    /// As [`CommWorld::new`] with an explicit reduction chunk size in
    /// elements (tests use tiny chunks to exercise the chunk pipeline).
    pub fn with_chunk_elems(grid: Grid4D, chunk_elems: usize) -> CommWorld {
        assert!(chunk_elems > 0, "chunk_elems must be positive");
        let mk = |axis: Axis| -> Vec<Group> {
            (0..grid.num_groups(axis))
                .map(|_| Group {
                    size: grid.axis_size(axis),
                    barrier: Barrier::new(grid.axis_size(axis)),
                    state: Mutex::new(GroupState {
                        next_seq: vec![0; grid.axis_size(axis)],
                        ops: VecDeque::new(),
                        poison: None,
                    }),
                    cv: Condvar::new(),
                })
                .collect()
        };
        CommWorld {
            grid,
            groups: vec![mk(Axis::X), mk(Axis::Y), mk(Axis::Z), mk(Axis::Dp)],
            counters: Default::default(),
            chunk_elems,
        }
    }

    fn group(&self, rank: usize, axis: Axis) -> &Group {
        &self.groups[axis_idx(axis)][self.grid.group_id(rank, axis)]
    }

    fn account(&self, axis: Axis, elems: u64, prec: Precision, group_size: usize) {
        if group_size <= 1 {
            return;
        }
        let c = &self.counters[axis_idx(axis)];
        c.ops.fetch_add(1, Ordering::Relaxed);
        // ring all-reduce moves ~2 n bytes per rank; we account the logical
        // payload volume (n * wordsize) — the cost model applies the 2(p-1)/p
        c.bytes.fetch_add(elems * prec.bytes_per_elem(), Ordering::Relaxed);
    }

    /// Advance ordered chunk reductions of every fully-contributed op of
    /// the group; `budget` caps the chunks reduced per call so `progress`
    /// stays cheap.  Returns whether any chunk was advanced.
    fn reduce_ready_locked(&self, st: &mut GroupState, size: usize, mut budget: usize) -> bool {
        let chunk = self.chunk_elems;
        let mut did = false;
        for op in st.ops.iter_mut() {
            if budget == 0 {
                break;
            }
            if !matches!(op.kind, OpKind::Reduce(_)) || op.n_contributed < size {
                continue;
            }
            while op.chunks_done < op.total_chunks && budget > 0 {
                let lo = (op.chunks_done * chunk).min(op.len);
                let hi = ((op.chunks_done + 1) * chunk).min(op.len);
                // ordered sum over members: deterministic regardless of
                // arrival order or of which rank drives the reduction
                let dst = &mut op.result[lo..hi];
                dst.copy_from_slice(&op.parts[0][lo..hi]);
                for p in op.parts.iter().skip(1) {
                    for (d, &v) in dst.iter_mut().zip(&p[lo..hi]) {
                        *d += v;
                    }
                }
                op.chunks_done += 1;
                budget -= 1;
                did = true;
            }
            if op.chunks_done == op.total_chunks && op.completed_at.is_none() {
                op.completed_at = Some(Instant::now());
                // contributions are no longer needed; free them eagerly
                for p in op.parts.iter_mut() {
                    *p = Vec::new();
                }
            }
        }
        did
    }

    /// Poison every group `rank` belongs to with `err`, wake their
    /// waiters, then panic with `err` as the structured payload.  A member
    /// that dies inside one collective must not leave peers in its *other*
    /// groups waiting on a contribution that will never come, so the
    /// poison cascades rank-by-rank through shared groups (each awoken
    /// member re-panics with the *original* origin and cascades in turn —
    /// a bystander's panic still names the rank/seq/op that truly died).
    fn poison_and_panic(&self, rank: usize, err: CommError) -> ! {
        for axis in [Axis::X, Axis::Y, Axis::Z, Axis::Dp] {
            let g = self.group(rank, axis);
            if g.size <= 1 {
                continue;
            }
            let mut st = g.state.lock().unwrap();
            if st.poison.is_none() {
                st.poison = Some(err.clone());
            }
            drop(st);
            g.cv.notify_all();
        }
        std::panic::panic_any(err);
    }

    /// Deterministic fault injection: kill the calling rank *now*,
    /// poisoning all its groups exactly like a real collective failure so
    /// peers fail fast and a supervisor can recover from the last
    /// checkpoint.  Drives the `FaultSpec::KillRank` crash-recovery path.
    pub fn fail(&self, rank: usize, msg: &str) -> ! {
        self.poison_and_panic(
            rank,
            CommError::new(rank, 0, "injected-fault", Axis::X, msg.to_string()),
        );
    }

    /// Issue a sum-all-reduce of `data` across the rank's `axis` group in
    /// fixed-size chunks; returns a [`PendingOp`] handle.  The caller's
    /// contribution is staged immediately (the borrow ends at return);
    /// chunk reductions proceed while the caller computes, and
    /// [`PendingOp::wait_into`] blocks only on the true dependency.
    pub fn issue_all_reduce(
        &self,
        rank: usize,
        axis: Axis,
        data: &[f32],
        prec: Precision,
    ) -> PendingOp<'_> {
        self.issue_reduce_inner(rank, axis, data, prec, true)
    }

    fn issue_reduce_inner(
        &self,
        rank: usize,
        axis: Axis,
        data: &[f32],
        prec: Precision,
        deferred: bool,
    ) -> PendingOp<'_> {
        let issued_at = Instant::now();
        let g = self.group(rank, axis);
        if g.size == 1 {
            // a size-1 "reduction" is the identity; keep the payload so
            // wait_into honors its write-into-`out` contract
            return PendingOp {
                world: self,
                axis,
                rank,
                seq: 0,
                len: data.len(),
                trivial: Some(data.to_vec()),
                deferred,
                issued_at,
            };
        }
        self.account(axis, data.len() as u64, prec, g.size);
        let me = self.grid.index_in_group(rank, axis);
        let mut st = g.state.lock().unwrap();
        if let Some(e) = st.poison.clone() {
            drop(st);
            self.poison_and_panic(rank, e);
        }
        let seq = st.next_seq[me];
        st.next_seq[me] += 1;
        if let Some(msg) =
            contribute(&mut st, g.size, self.chunk_elems, me, seq, OpKind::Reduce(prec), data)
        {
            drop(st);
            self.poison_and_panic(rank, CommError::new(rank, seq, "all_reduce", axis, msg));
        }
        g.cv.notify_all();
        drop(st);
        PendingOp {
            world: self,
            axis,
            rank,
            seq,
            len: data.len(),
            trivial: None,
            deferred,
            issued_at,
        }
    }

    /// Issue a gather of `payload` across the rank's `axis` group; returns
    /// a [`PendingGather`] resolved by [`PendingGather::wait`].  Payload
    /// lengths may differ across members.
    pub fn issue_all_gather(
        &self,
        rank: usize,
        axis: Axis,
        payload: &[f32],
    ) -> PendingGather<'_> {
        self.issue_gather_inner(rank, axis, payload, true)
    }

    fn issue_gather_inner(
        &self,
        rank: usize,
        axis: Axis,
        payload: &[f32],
        deferred: bool,
    ) -> PendingGather<'_> {
        let issued_at = Instant::now();
        let g = self.group(rank, axis);
        if g.size == 1 {
            return PendingGather {
                world: self,
                axis,
                rank,
                seq: 0,
                trivial: Some(payload.to_vec()),
                deferred,
                issued_at,
            };
        }
        self.account(axis, payload.len() as u64, Precision::Fp32, g.size);
        let me = self.grid.index_in_group(rank, axis);
        let mut st = g.state.lock().unwrap();
        if let Some(e) = st.poison.clone() {
            drop(st);
            self.poison_and_panic(rank, e);
        }
        let seq = st.next_seq[me];
        st.next_seq[me] += 1;
        if let Some(msg) =
            contribute(&mut st, g.size, self.chunk_elems, me, seq, OpKind::Gather, payload)
        {
            drop(st);
            self.poison_and_panic(rank, CommError::new(rank, seq, "all_gather", axis, msg));
        }
        g.cv.notify_all();
        drop(st);
        PendingGather { world: self, axis, rank, seq, trivial: None, deferred, issued_at }
    }

    /// Drive pending chunk reductions of this rank's groups without
    /// blocking — the per-rank progress engine of the nonblocking API.
    /// Cheap (bounded work, `try_lock` only); returns whether any chunk
    /// was advanced.
    pub fn progress(&self, rank: usize) -> bool {
        let mut did = false;
        for axis in [Axis::X, Axis::Y, Axis::Z, Axis::Dp] {
            let g = self.group(rank, axis);
            if g.size <= 1 {
                continue;
            }
            if let Ok(mut st) = g.state.try_lock() {
                if st.poison.is_some() {
                    continue; // surfaced by the owning wait
                }
                if self.reduce_ready_locked(&mut st, g.size, 8) {
                    did = true;
                    g.cv.notify_all();
                }
            }
        }
        did
    }

    /// Sum-all-reduce `data` across the rank's `axis` group, in place
    /// (blocking wrapper over issue + wait; excluded from the hidden-comm
    /// timing so the measured fraction covers only deferrable ops).
    pub fn all_reduce(&self, rank: usize, axis: Axis, data: &mut [f32], prec: Precision) {
        if self.group(rank, axis).size == 1 {
            return; // identity in place, no payload copy
        }
        let op = self.issue_reduce_inner(rank, axis, data, prec, false);
        op.wait_into(data);
    }

    /// Gather each member's payload; returns the payloads ordered by the
    /// member's index within the group.  Payload lengths may differ
    /// (blocking wrapper over issue + wait; excluded from the hidden-comm
    /// timing).
    pub fn all_gather(&self, rank: usize, axis: Axis, payload: &[f32]) -> Vec<Vec<f32>> {
        if self.group(rank, axis).size == 1 {
            return vec![payload.to_vec()];
        }
        self.issue_gather_inner(rank, axis, payload, false).wait()
    }

    /// Barrier across the rank's `axis` group.
    pub fn barrier(&self, rank: usize, axis: Axis) {
        let g = self.group(rank, axis);
        if g.size > 1 {
            g.barrier.wait();
        }
    }

    /// Snapshot (ops, bytes) for an axis.
    pub fn stats(&self, axis: Axis) -> (u64, u64) {
        let c = &self.counters[axis_idx(axis)];
        (c.ops.load(Ordering::Relaxed), c.bytes.load(Ordering::Relaxed))
    }

    /// Snapshot (comm seconds, blocked seconds) measured on an axis: total
    /// issue→completion time vs time ranks actually stalled in `wait`.
    pub fn timing(&self, axis: Axis) -> (f64, f64) {
        let c = &self.counters[axis_idx(axis)];
        (
            c.comm_ns.load(Ordering::Relaxed) as f64 * 1e-9,
            c.blocked_ns.load(Ordering::Relaxed) as f64 * 1e-9,
        )
    }

    /// Measured fraction of this axis's *deferrable* collective time
    /// hidden behind compute: `1 - blocked/comm` over collectives issued
    /// through the nonblocking API, clamped to `[0, 1]` (0 when none
    /// ran).  Blocking-wrapper collectives (true data dependencies) are
    /// excluded — they are not hideable even in principle, and counting
    /// them would bias the §V-D calibration low.
    pub fn hidden_fraction(&self, axis: Axis) -> f64 {
        let c = &self.counters[axis_idx(axis)];
        let comm = c.comm_ns.load(Ordering::Relaxed) as f64;
        if comm <= 0.0 {
            return 0.0;
        }
        let blocked = c.blocked_ns.load(Ordering::Relaxed) as f64;
        (1.0 - blocked / comm).clamp(0.0, 1.0)
    }

    /// Aggregate hidden fraction over the tensor-parallel axes (X, Y, Z):
    /// the executed counterpart of the §V-D hide fraction consumed by
    /// `sim::model` in place of a guessed constant.
    pub fn tp_hidden_fraction(&self) -> f64 {
        let (mut comm, mut blocked) = (0u64, 0u64);
        for axis in [Axis::X, Axis::Y, Axis::Z] {
            let c = &self.counters[axis_idx(axis)];
            comm += c.comm_ns.load(Ordering::Relaxed);
            blocked += c.blocked_ns.load(Ordering::Relaxed);
        }
        if comm == 0 {
            return 0.0;
        }
        (1.0 - blocked as f64 / comm as f64).clamp(0.0, 1.0)
    }

    /// Zero all per-axis traffic and timing counters.
    pub fn reset_stats(&self) {
        for c in &self.counters {
            c.ops.store(0, Ordering::Relaxed);
            c.bytes.store(0, Ordering::Relaxed);
            c.comm_ns.store(0, Ordering::Relaxed);
            c.blocked_ns.store(0, Ordering::Relaxed);
        }
    }
}

/// Handle of an in-flight chunked all-reduce.  Resolve with
/// [`PendingOp::wait_into`]; poll with [`PendingOp::try_ready`].  Dropping
/// a handle without waiting leaks its op slot (the engine always waits).
#[must_use = "a pending collective must be awaited (PendingOp::wait_into)"]
pub struct PendingOp<'w> {
    world: &'w CommWorld,
    axis: Axis,
    rank: usize,
    seq: u64,
    len: usize,
    /// Size-1 groups complete at issue: the "reduction" is the identity,
    /// kept here so `wait_into` still writes the promised result.
    trivial: Option<Vec<f32>>,
    /// Issued through the nonblocking API (counted in the overlap timing)
    /// vs through a blocking wrapper (excluded).
    deferred: bool,
    issued_at: Instant,
}

impl PendingOp<'_> {
    /// Payload length of the issued op.
    pub fn len(&self) -> usize {
        self.len
    }

    /// True when the issued payload is empty.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Nonblocking readiness check; opportunistically drives a bounded
    /// number of chunk reductions while it holds the group lock (bounded
    /// like `progress` so a poll never stalls peers queueing on the lock;
    /// a subsequent blocking wait finishes any remainder).
    pub fn try_ready(&self) -> bool {
        if self.trivial.is_some() {
            return true;
        }
        let g = self.world.group(self.rank, self.axis);
        match g.state.try_lock() {
            Ok(mut st) => {
                if st.poison.is_some() {
                    return true; // wait_into surfaces the error
                }
                if self.world.reduce_ready_locked(&mut st, g.size, 8) {
                    g.cv.notify_all();
                }
                st.ops
                    .iter()
                    .find(|o| o.seq == self.seq)
                    .map(|o| o.chunks_done == o.total_chunks)
                    .unwrap_or(false)
            }
            Err(_) => false,
        }
    }

    /// Block until every chunk is reduced and write the result into `out`
    /// (same length as the issued payload).  Waiters drive the remaining
    /// reductions themselves, so completion never depends on a third
    /// party.  Panics with the originating [`CommError`] as payload if the
    /// group was poisoned by a mismatched collective or injected fault.
    pub fn wait_into(self, out: &mut [f32]) {
        assert_eq!(out.len(), self.len, "wait_into buffer length mismatch");
        if let Some(p) = self.trivial {
            out.copy_from_slice(&p);
            return;
        }
        let w = self.world;
        let g = w.group(self.rank, self.axis);
        let t_wait = Instant::now();
        let mut st = g.state.lock().unwrap();
        let completed_at = loop {
            if let Some(e) = st.poison.clone() {
                drop(st);
                w.poison_and_panic(self.rank, e);
            }
            if w.reduce_ready_locked(&mut st, g.size, usize::MAX) {
                g.cv.notify_all();
            }
            let done = {
                let op = st
                    .ops
                    .iter()
                    .find(|o| o.seq == self.seq)
                    .expect("pending op slot missing");
                if op.chunks_done == op.total_chunks {
                    op.completed_at
                } else {
                    None
                }
            };
            if let Some(t) = done {
                break t;
            }
            st = g.cv.wait(st).unwrap();
        };
        let retire = {
            let op = st.ops.iter_mut().find(|o| o.seq == self.seq).unwrap();
            out.copy_from_slice(&op.result);
            op.read += 1;
            op.read == g.size
        };
        if retire {
            st.ops.retain(|o| o.seq != self.seq);
        }
        drop(st);
        if self.deferred {
            let blocked = t_wait.elapsed();
            let total = completed_at.saturating_duration_since(self.issued_at);
            let c = &w.counters[axis_idx(self.axis)];
            c.comm_ns.fetch_add(total.as_nanos() as u64, Ordering::Relaxed);
            c.blocked_ns
                .fetch_add(blocked.min(total).as_nanos() as u64, Ordering::Relaxed);
        }
    }
}

/// Handle of an in-flight all-gather; resolve with [`PendingGather::wait`].
#[must_use = "a pending collective must be awaited (PendingGather::wait)"]
pub struct PendingGather<'w> {
    world: &'w CommWorld,
    axis: Axis,
    rank: usize,
    seq: u64,
    /// Size-1 groups complete at issue with the caller's own payload.
    trivial: Option<Vec<f32>>,
    /// Issued through the nonblocking API (counted in the overlap timing)
    /// vs through a blocking wrapper (excluded).
    deferred: bool,
    issued_at: Instant,
}

impl PendingGather<'_> {
    /// Block until every member's payload arrived; returns the payloads in
    /// group-index order.  Panics with the originating [`CommError`] as
    /// payload if the group was poisoned by a mismatched collective or
    /// injected fault.
    pub fn wait(self) -> Vec<Vec<f32>> {
        if let Some(p) = self.trivial {
            return vec![p];
        }
        let w = self.world;
        let g = w.group(self.rank, self.axis);
        let t_wait = Instant::now();
        let mut st = g.state.lock().unwrap();
        let completed_at = loop {
            if let Some(e) = st.poison.clone() {
                drop(st);
                w.poison_and_panic(self.rank, e);
            }
            let done = {
                let op = st
                    .ops
                    .iter()
                    .find(|o| o.seq == self.seq)
                    .expect("pending gather slot missing");
                if op.n_contributed == g.size {
                    op.completed_at
                } else {
                    None
                }
            };
            if let Some(t) = done {
                break t;
            }
            st = g.cv.wait(st).unwrap();
        };
        let (out, retire) = {
            let op = st.ops.iter_mut().find(|o| o.seq == self.seq).unwrap();
            let out = op.parts.clone();
            op.read += 1;
            (out, op.read == g.size)
        };
        if retire {
            st.ops.retain(|o| o.seq != self.seq);
        }
        drop(st);
        if self.deferred {
            let blocked = t_wait.elapsed();
            let total = completed_at.saturating_duration_since(self.issued_at);
            let c = &w.counters[axis_idx(self.axis)];
            c.comm_ns.fetch_add(total.as_nanos() as u64, Ordering::Relaxed);
            c.blocked_ns
                .fetch_add(blocked.min(total).as_nanos() as u64, Ordering::Relaxed);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn run_ranks<F>(grid: Grid4D, f: F) -> Vec<Vec<f32>>
    where
        F: Fn(usize, &CommWorld) -> Vec<f32> + Send + Sync + 'static,
    {
        let world = Arc::new(CommWorld::new(grid));
        let f = Arc::new(f);
        let mut handles = vec![];
        for r in 0..grid.world_size() {
            let w = world.clone();
            let f = f.clone();
            handles.push(std::thread::spawn(move || f(r, &w)));
        }
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    }

    #[test]
    fn all_reduce_sums_across_x_groups_only() {
        let grid = Grid4D::new(1, 2, 2, 1);
        let outs = run_ranks(grid, |rank, w| {
            let mut v = vec![rank as f32 + 1.0; 3];
            w.all_reduce(rank, Axis::X, &mut v, Precision::Fp32);
            v
        });
        // X groups: {0,1} (y=0) and {2,3} (y=1)
        assert_eq!(outs[0], vec![3.0; 3]);
        assert_eq!(outs[1], vec![3.0; 3]);
        assert_eq!(outs[2], vec![7.0; 3]);
        assert_eq!(outs[3], vec![7.0; 3]);
    }

    #[test]
    fn repeated_all_reduce_reuses_slots_correctly() {
        let grid = Grid4D::new(1, 4, 1, 1);
        let outs = run_ranks(grid, |rank, w| {
            let mut acc = vec![];
            for round in 0..10 {
                let mut v = vec![(rank + round) as f32];
                w.all_reduce(rank, Axis::X, &mut v, Precision::Fp32);
                acc.push(v[0]);
            }
            acc
        });
        for o in outs {
            for (round, &v) in o.iter().enumerate() {
                // sum over ranks of (rank + round) = 6 + 4*round
                assert_eq!(v, 6.0 + 4.0 * round as f32);
            }
        }
    }

    #[test]
    fn bf16_mode_rounds_contributions() {
        let grid = Grid4D::new(1, 2, 1, 1);
        let outs = run_ranks(grid, |rank, w| {
            // a value with bits below bf16 precision
            let x = if rank == 0 { 1.0009765625f32 } else { 0.0 };
            let mut v = vec![x];
            w.all_reduce(rank, Axis::X, &mut v, Precision::Bf16);
            v
        });
        let expect = bf16_round(1.0009765625);
        assert_eq!(outs[0][0], expect);
        assert_ne!(outs[0][0], 1.0009765625);
    }

    #[test]
    fn all_gather_orders_by_group_index() {
        let grid = Grid4D::new(1, 1, 3, 1);
        let outs = run_ranks(grid, |rank, w| {
            let mine = vec![rank as f32; rank + 1]; // variable lengths
            let all = w.all_gather(rank, Axis::Y, &mine);
            all.into_iter().flatten().collect()
        });
        for o in outs {
            assert_eq!(o, vec![0.0, 1.0, 1.0, 2.0, 2.0, 2.0]);
        }
    }

    #[test]
    fn dp_axis_reduces_across_groups() {
        let grid = Grid4D::new(2, 2, 1, 1);
        let outs = run_ranks(grid, |rank, w| {
            let mut v = vec![if w.grid.coord(rank).d == 0 { 1.0 } else { 10.0 }];
            w.all_reduce(rank, Axis::Dp, &mut v, Precision::Fp32);
            v
        });
        for o in outs {
            assert_eq!(o, vec![11.0]);
        }
    }

    #[test]
    fn size_one_group_is_noop_and_unaccounted() {
        let grid = Grid4D::new(1, 1, 1, 1);
        let world = CommWorld::new(grid);
        let mut v = vec![5.0];
        world.all_reduce(0, Axis::X, &mut v, Precision::Fp32);
        assert_eq!(v, vec![5.0]);
        assert_eq!(world.stats(Axis::X), (0, 0));
    }

    #[test]
    fn byte_accounting_tracks_precision() {
        let grid = Grid4D::new(1, 2, 1, 1);
        let world = Arc::new(CommWorld::new(grid));
        let w1 = world.clone();
        let w2 = world.clone();
        let t1 = std::thread::spawn(move || {
            let mut v = vec![1.0; 8];
            w1.all_reduce(0, Axis::X, &mut v, Precision::Fp32);
            w1.all_reduce(0, Axis::X, &mut v, Precision::Bf16);
        });
        let t2 = std::thread::spawn(move || {
            let mut v = vec![1.0; 8];
            w2.all_reduce(1, Axis::X, &mut v, Precision::Fp32);
            w2.all_reduce(1, Axis::X, &mut v, Precision::Bf16);
        });
        t1.join().unwrap();
        t2.join().unwrap();
        let (ops, bytes) = world.stats(Axis::X);
        assert_eq!(ops, 4); // 2 collectives x 2 ranks accounted
        assert_eq!(bytes, 2 * (8 * 4) + 2 * (8 * 2));
    }

    #[test]
    fn nonblocking_issue_allows_out_of_order_waits() {
        // two ops in flight per rank on the same group, waited in reverse
        let grid = Grid4D::new(1, 2, 1, 1);
        let outs = run_ranks(grid, |rank, w| {
            let a = vec![rank as f32 + 1.0; 5];
            let b = vec![10.0 * (rank as f32 + 1.0); 7];
            let pa = w.issue_all_reduce(rank, Axis::X, &a, Precision::Fp32);
            let pb = w.issue_all_reduce(rank, Axis::X, &b, Precision::Fp32);
            let mut rb = vec![0.0; 7];
            pb.wait_into(&mut rb);
            let mut ra = vec![0.0; 5];
            pa.wait_into(&mut ra);
            ra.extend_from_slice(&rb);
            ra
        });
        for o in outs {
            assert_eq!(&o[..5], &[3.0; 5]);
            assert_eq!(&o[5..], &[30.0; 7]);
        }
    }

    #[test]
    fn chunked_reduction_matches_unchunked() {
        // payload of 10 elems with 3-elem chunks: 4 chunks, same sums
        let grid = Grid4D::new(1, 3, 1, 1);
        let world = Arc::new(CommWorld::with_chunk_elems(grid, 3));
        let mut hs = vec![];
        for rank in 0..3 {
            let w = world.clone();
            hs.push(std::thread::spawn(move || {
                let mut v: Vec<f32> = (0..10).map(|i| (rank * 10 + i) as f32).collect();
                w.all_reduce(rank, Axis::X, &mut v, Precision::Fp32);
                v
            }));
        }
        for h in hs {
            let v = h.join().unwrap();
            for (i, &x) in v.iter().enumerate() {
                // sum over ranks r of (10 r + i) = 30 + 3 i
                assert_eq!(x, (30 + 3 * i) as f32);
            }
        }
    }

    #[test]
    fn try_ready_becomes_true_after_peers_issue() {
        let grid = Grid4D::new(1, 2, 1, 1);
        let world = Arc::new(CommWorld::new(grid));
        let w1 = world.clone();
        let t = std::thread::spawn(move || {
            let v = vec![2.0; 4];
            let p = w1.issue_all_reduce(1, Axis::X, &v, Precision::Fp32);
            let mut out = vec![0.0; 4];
            p.wait_into(&mut out);
            out
        });
        let v = vec![1.0; 4];
        let p = world.issue_all_reduce(0, Axis::X, &v, Precision::Fp32);
        // the peer will issue eventually; poll until ready
        while !p.try_ready() {
            std::thread::yield_now();
        }
        let mut out = vec![0.0; 4];
        p.wait_into(&mut out);
        assert_eq!(out, vec![3.0; 4]);
        assert_eq!(t.join().unwrap(), vec![3.0; 4]);
    }

    #[test]
    fn mismatch_panic_payload_is_structured() {
        // both the originating member and the poisoned peer must die with
        // the SAME CommError origin, downcastable from the join payload
        let grid = Grid4D::new(1, 2, 1, 1);
        let world = Arc::new(CommWorld::new(grid));
        let mut hs = vec![];
        for rank in 0..2usize {
            let w = world.clone();
            hs.push(std::thread::spawn(move || {
                let mut v = vec![1.0f32; if rank == 0 { 4 } else { 8 }];
                w.all_reduce(rank, Axis::X, &mut v, Precision::Fp32);
            }));
        }
        for h in hs {
            let payload = h.join().expect_err("mismatch must panic");
            let e = payload.downcast::<CommError>().expect("structured payload");
            assert_eq!(e.op, "all_reduce");
            assert_eq!(e.axis, Axis::X);
            assert_eq!(e.seq, 0);
            assert!(e.rank < 2);
            assert!(e.msg.contains("length mismatch"), "{}", e.msg);
        }
    }

    #[test]
    fn injected_fault_poisons_peers_with_its_origin() {
        let grid = Grid4D::new(1, 2, 1, 1);
        let world = Arc::new(CommWorld::new(grid));
        let w0 = world.clone();
        let killer = std::thread::spawn(move || {
            w0.fail(0, "scripted kill");
        });
        let w1 = world.clone();
        let victim = std::thread::spawn(move || {
            let mut v = vec![1.0f32; 4];
            // peer never contributes; the poison must wake and kill this wait
            w1.all_reduce(1, Axis::X, &mut v, Precision::Fp32);
        });
        for h in [killer, victim] {
            let payload = h.join().expect_err("both sides must die");
            let e = payload.downcast::<CommError>().expect("structured payload");
            assert_eq!(e.rank, 0, "bystander panic must name the true origin");
            assert_eq!(e.op, "injected-fault");
            assert_eq!(e.msg, "scripted kill");
        }
    }

    #[test]
    fn hidden_fraction_counts_deferred_ops_only() {
        let grid = Grid4D::new(1, 2, 1, 1);
        let world = Arc::new(CommWorld::new(grid));
        // blocking wrappers are excluded from the overlap timing ...
        let mut hs = vec![];
        for rank in 0..2 {
            let w = world.clone();
            hs.push(std::thread::spawn(move || {
                let mut v = vec![1.0; 1 << 18];
                w.all_reduce(rank, Axis::X, &mut v, Precision::Fp32);
            }));
        }
        for h in hs {
            h.join().unwrap();
        }
        assert_eq!(world.timing(Axis::X), (0.0, 0.0));
        assert_eq!(world.hidden_fraction(Axis::X), 0.0);
        // ... while nonblocking issues are measured
        let mut hs = vec![];
        for rank in 0..2 {
            let w = world.clone();
            hs.push(std::thread::spawn(move || {
                let v = vec![1.0; 1 << 18];
                let op = w.issue_all_reduce(rank, Axis::X, &v, Precision::Fp32);
                let mut out = vec![0.0; 1 << 18];
                op.wait_into(&mut out);
            }));
        }
        for h in hs {
            h.join().unwrap();
        }
        let f = world.hidden_fraction(Axis::X);
        assert!((0.0..=1.0).contains(&f), "hidden fraction {f}");
        let (comm_s, blocked_s) = world.timing(Axis::X);
        assert!(comm_s > 0.0, "deferred ops must be timed");
        assert!(blocked_s >= 0.0);
        world.reset_stats();
        assert_eq!(world.timing(Axis::X), (0.0, 0.0));
        assert_eq!(world.hidden_fraction(Axis::X), 0.0);
    }
}
