//! ScaleGNN launcher: the Layer-3 leader entrypoint.
//!
//! Every subcommand is a thin flag-to-[`RunSpec`] mapping over the unified
//! session API (`session::run`); `scalegnn run --spec FILE.json` is the
//! canonical entry point.
//!
//! ```text
//! scalegnn info
//! scalegnn run        --spec FILE.json [--stats-json F] [--jsonl F]
//!                     [--precision fp32|bf16] [--log-every N] [--quiet]
//! scalegnn train      --dataset products_sim [--sampler scalegnn|sage|saint]
//!                     [--dp N] [--epochs E | --steps S] [--target-acc A]
//!                     [--lr F] [--precision fp32|bf16] [--no-prefetch]
//!                     [--overlap on|off] [--verbose]
//! scalegnn train      --from-store graph.pallas [--dataset papers100m_ooc]
//!                     [--cache-mb M] [--steps S] [--batch B] [--lr F]
//!                     [--checkpoint-dir D [--checkpoint-every N]
//!                      [--checkpoint-keep K] [--resume]]
//! scalegnn pack       --dataset papers100m_ooc [--out graph.pallas]
//!                     [--feat-precision fp32|bf16]
//! scalegnn pmm-train  --dataset tiny --grid 1x2x2x2 [--steps S]
//!                     [--precision fp32|bf16]
//!                     [--overlap on|off] [--stats-json FILE]
//!                     [--checkpoint-dir D [--checkpoint-every N]
//!                      [--checkpoint-keep K] [--resume]
//!                      [--kill-rank R --kill-step S]
//!                      [--stall-rank R --stall-step S [--stall-ms MS]]]
//!                     [--chaos seed=S,rate=R[,modes=a+b]]
//!                     [--wait-timeout-ms N] [--rejoin-grace-ms N]
//!                     [--connect-timeout-ms N] [--heartbeat-ms N]
//! scalegnn eval       --dataset tiny --grid 2x2x2
//! scalegnn sample     --dataset products_sim [--grid 2x2] [--steps S]
//!                     [--from-store graph.pallas] [--cache-mb M]
//! scalegnn scaling    --dataset papers100m_sim --machine perlmutter
//!                     [--overlap on|off] [--hide-frac F | --calibrate-overlap]
//! scalegnn breakdown  --dataset products14m_sim [--machine M]
//!                     [--overlap on|off] [--hide-frac F | --calibrate-overlap]
//! scalegnn e2e        --dataset products_sim --machine perlmutter
//!                     [--overlap on|off] [--hide-frac F | --calibrate-overlap]
//! ```

use std::path::PathBuf;

use anyhow::{anyhow, bail, Result};

use scalegnn::comm::{ChaosSpec, Precision};
use scalegnn::graph::{datasets, partition_2d};
use scalegnn::sampling::{DistributedSubgraphBuilder, SamplerKind, UniformVertexSampler};
use scalegnn::session::{
    self, BackendKind, CheckpointPolicy, FaultSpec, GridSpec, JsonlObserver, LogObserver,
    ModelSpec, RunReport, RunSpec, StepObserver, TransportSpec,
};
use scalegnn::sim;
use scalegnn::util::cli::Args;
use scalegnn::util::stats::fmt_time;

fn main() {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    let sub = args.subcommand.clone().unwrap_or_else(|| "help".into());
    let r = match sub.as_str() {
        "info" => cmd_info(&args),
        "run" => cmd_run(&args),
        "train" => cmd_train(&args),
        "pack" => cmd_pack(&args),
        "pmm-train" => cmd_pmm_train(&args),
        "eval" => cmd_eval(&args),
        "sample" => cmd_sample(&args),
        "scaling" => cmd_scaling(&args),
        "breakdown" => cmd_breakdown(&args),
        "e2e" => cmd_e2e(&args),
        _ => {
            print!("{}", HELP);
            Ok(())
        }
    };
    if let Err(e) = r {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

const HELP: &str = "\
ScaleGNN: communication-free sampling + 4D hybrid parallel GNN training

USAGE: scalegnn <command> [options]

COMMANDS:
  info        show artifacts, models and datasets
  run         execute a RunSpec JSON file through the unified session API
              (the canonical entry point; see examples/specs/)
  train       mini-batch training via the PJRT artifacts (fused or DP);
              with --from-store FILE.pallas: out-of-core pure-Rust training
  pack        serialize a dataset into a .pallas out-of-core container
  pmm-train   4D training on the rank-thread 3D PMM engine
  eval        distributed full-graph evaluation (Table II mechanism)
  sample      communication-free distributed sampling microbench
              (--from-store FILE.pallas extracts shards out-of-core)
  scaling     projected strong scaling at paper scale (Fig. 7)
  breakdown   projected epoch-time breakdown (Figs. 5/8)
  e2e         projected end-to-end time-to-accuracy vs baselines (Fig. 6)

Every command maps its flags onto a session::RunSpec and calls
session::run.  `scalegnn run --spec FILE.json` executes a saved spec
directly: --jsonl F streams one JSON object per step, --stats-json F
writes {"spec", "report"} (self-identifying), --log-every N / --quiet
control stderr logging.

§V-B low precision: run/train/pmm-train accept --precision fp32|bf16
(bf16 collective payloads: gathers and reduces ship half the bytes;
pmm-train's old --bf16 flag remains as a deprecated alias).  pack accepts
--feat-precision fp32|bf16 to store .pallas features at half width (reads
widen back to f32 through the SIMD batch conversion).

§V-D overlap: train/pmm-train accept --overlap on|off (nonblocking chunked
collectives; pmm-train reports the measured hidden-comm fraction per axis,
--stats-json FILE writes it).  The sim commands accept --overlap on|off and
--hide-frac F or --calibrate-overlap (measure the hide fraction on an
executed 8-rank engine run instead of the default constant).

Fault tolerance: pmm-train and train --from-store accept --checkpoint-dir D
[--checkpoint-every N] [--checkpoint-keep K] (versioned CRC-checked
snapshots, atomic writes, keep-last-K) and --resume (replay from the newest
snapshot valid on every rank — bitwise-identical to the uninterrupted run).
pmm-train also accepts --kill-rank R --kill-step S (a deterministic death)
and --stall-rank R --stall-step S [--stall-ms MS] (a silent, not-dead rank
the deadline discipline must detect and poison as Stalled): fault
injections the supervisor must recover from by re-forming the world and
replaying from the last checkpoint.

Chaos testing: run and pmm-train accept --chaos seed=S,rate=R[,modes=a+b]
(modes: delay, stall, drop, corrupt, duplicate, partial) — a reproducible
fault-injection schedule on the transport.  The same seed yields the same
failure origin; chaos is disarmed on recovery so the replayed run matches
the clean loss curve bitwise.

Deadlines: run and pmm-train accept --wait-timeout-ms N (every blocking
collective wait; expiry poisons the world with a structured Stalled
origin), --rejoin-grace-ms N (coordinator holds a failed rank's slot open
for a relaunched --rank R --resume process), --connect-timeout-ms N and
--heartbeat-ms N.  The same values ride on RunSpec.transport in a spec
file.

Multi-process worlds: run and pmm-train accept --transport tcp:HOST:PORT |
unix:PATH --rank R to join a world assembled by `scalegnn-coord --grid G
(--tcp HOST:PORT | --unix PATH)` — one OS process per rank, same chunked
sequence-matched collectives over length-prefixed CRC-checked frames,
bitwise identical to the in-process run (see EXPERIMENTS.md for the
launch recipe).

Run `cargo bench` to regenerate every paper table/figure.
";

/// Parse `--precision fp32|bf16` (§V-B); `None` when the flag was not
/// given, a descriptive error on any other value.
fn precision_opt(args: &Args, key: &str) -> Result<Option<Precision>> {
    match args.str_opt(key) {
        Some(p) => match Precision::parse(&p) {
            Some(v) => Ok(Some(v)),
            None => bail!("--{key} must be fp32|bf16, got '{p}'"),
        },
        None => Ok(None),
    }
}

/// Map `--checkpoint-dir D [--checkpoint-every N] [--checkpoint-keep K]`
/// and `--resume` onto the spec's checkpoint section.
fn apply_checkpoint_flags(args: &Args, spec: &mut RunSpec) -> Result<()> {
    if let Some(dir) = args.path_opt("checkpoint-dir") {
        let every = args.get_or("checkpoint-every", 10u64).map_err(|e| anyhow!(e))?;
        let keep = args.get_or("checkpoint-keep", 4usize).map_err(|e| anyhow!(e))?;
        spec.checkpoint = Some(CheckpointPolicy::new(dir, every, keep));
    }
    spec.resume = args.flag("resume");
    Ok(())
}

/// Map `--transport inproc|tcp:HOST:PORT|unix:PATH` and `--rank R` onto
/// the spec's transport section, the deadline/heartbeat tuning flags onto
/// `spec.tuning`, and `--chaos seed=S,rate=R[,modes=a+b]` onto
/// `spec.chaos`.  The same spec file can be shared by every rank process,
/// with `--rank` supplying the per-process member.
fn apply_transport_flags(args: &Args, spec: &mut RunSpec) -> Result<()> {
    if let Some(t) = args.str_opt("transport") {
        spec.transport = TransportSpec::parse(&t).map_err(|e| anyhow!(e))?;
    }
    if let Some(r) = args.get::<usize>("rank").map_err(|e| anyhow!(e))? {
        if !matches!(spec.transport, TransportSpec::Socket { .. }) {
            bail!("--rank only applies to socket transports (give --transport tcp:… or unix:…)");
        }
        *spec = spec.clone().with_rank(r);
    }
    if let Some(v) = args.get::<u32>("connect-timeout-ms").map_err(|e| anyhow!(e))? {
        spec.tuning.connect_timeout_ms = Some(v);
    }
    if let Some(v) = args.get::<u32>("heartbeat-ms").map_err(|e| anyhow!(e))? {
        spec.tuning.heartbeat_ms = Some(v);
    }
    if let Some(v) = args.get::<u32>("wait-timeout-ms").map_err(|e| anyhow!(e))? {
        spec.tuning.wait_timeout_ms = Some(v);
    }
    if let Some(v) = args.get::<u32>("rejoin-grace-ms").map_err(|e| anyhow!(e))? {
        spec.tuning.rejoin_grace_ms = Some(v);
    }
    if let Some(c) = args.str_opt("chaos") {
        spec.chaos = Some(ChaosSpec::parse(&c).map_err(|e| anyhow!(e))?);
    }
    Ok(())
}

/// Stderr observers for a subcommand: a `LogObserver` printing every
/// `every`-th step (0 = eval/final only) when `--verbose` was given,
/// nothing otherwise.
fn flag_observers(args: &Args, every: u64) -> Vec<Box<dyn StepObserver>> {
    if args.flag("verbose") || args.flag("v") {
        vec![Box::new(LogObserver::every(every))]
    } else {
        Vec::new()
    }
}

/// Write `{"spec": ..., "report": ...}` when `--stats-json FILE` was
/// given — the spec makes the file self-identifying (dataset, grid,
/// overlap, precision, ...).
fn write_stats_json(args: &Args, spec: &RunSpec, report: &RunReport) -> Result<()> {
    if let Some(path) = args.path_opt("stats-json") {
        let doc = scalegnn::util::json::obj(vec![
            ("spec", spec.to_json()),
            ("report", report.to_json()),
        ]);
        std::fs::write(&path, doc.to_string() + "\n")
            .map_err(|e| anyhow!("writing {}: {e}", path.display()))?;
        println!("wrote {}", path.display());
    }
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    args.check_known("info", &["artifacts"], &[]).map_err(|e| anyhow!(e))?;
    println!("== datasets ==");
    for s in datasets::registry() {
        println!(
            "  {:<16} n={:<9} classes={:<3} d_in={:<4} batch={:<5} (paper N={:.1e})",
            s.name, s.planted.n, s.planted.classes, s.planted.d_in, s.batch, s.paper.n
        );
    }
    match scalegnn::runtime::Runtime::open(&PathBuf::from(args.str_or("artifacts", "artifacts")))
    {
        Ok(rt) => {
            println!("== artifacts ({}) ==", rt.platform());
            let mut names: Vec<_> = rt.manifest.artifacts.keys().collect();
            names.sort();
            for n in names {
                let a = &rt.manifest.artifacts[n];
                println!("  {:<28} {} in / {} out", n, a.inputs.len(), a.outputs.len());
            }
        }
        Err(e) => println!("(artifacts not built: {e})"),
    }
    Ok(())
}

/// `scalegnn run --spec FILE.json`: execute a saved spec.
fn cmd_run(args: &Args) -> Result<()> {
    args.check_known(
        "run",
        &[
            "spec", "stats-json", "jsonl", "log-every", "transport", "rank", "precision",
            "chaos", "connect-timeout-ms", "heartbeat-ms", "wait-timeout-ms", "rejoin-grace-ms",
        ],
        &["quiet"],
    )
    .map_err(|e| anyhow!(e))?;
    let path = args
        .path_opt("spec")
        .ok_or_else(|| anyhow!("run requires --spec FILE.json (see examples/specs/)"))?;
    let text = std::fs::read_to_string(&path)
        .map_err(|e| anyhow!("reading {}: {e}", path.display()))?;
    let mut spec =
        RunSpec::from_json_str(&text).map_err(|e| anyhow!("{}: {e}", path.display()))?;
    apply_transport_flags(args, &mut spec)?;
    if let Some(p) = precision_opt(args, "precision")? {
        spec.precision = p;
    }
    let mut obs: Vec<Box<dyn StepObserver>> = Vec::new();
    if !args.flag("quiet") {
        let every = args.get_or("log-every", 1u64).map_err(|e| anyhow!(e))?;
        obs.push(Box::new(LogObserver::every(every)));
    }
    if let Some(p) = args.path_opt("jsonl") {
        obs.push(Box::new(
            JsonlObserver::create(&p).map_err(|e| anyhow!("creating {}: {e}", p.display()))?,
        ));
    }
    let report = session::run(&spec, &mut obs)?;
    write_stats_json(args, &spec, &report)?;
    print_summary(&report);
    Ok(())
}

/// Human-readable end-of-run summary of any backend's report.
fn print_summary(report: &RunReport) {
    for f in &report.failures {
        if let Some(s) = f.resumed_from_step {
            println!(
                "recovered: rank {} died in {} (seq {}, axis '{}'); replayed from step {s}",
                f.rank, f.op, f.seq, f.axis
            );
        }
    }
    if let Some(t) = &report.trainer {
        println!(
            "steps={} epochs={} train={} eval={} loss={:.4} best_val={:.4} best_test={:.4}",
            t.steps,
            t.epochs,
            fmt_time(t.train_time_s),
            fmt_time(t.eval_time_s),
            t.final_loss,
            t.best_val_acc,
            t.best_test_acc
        );
        if let Some(tt) = t.time_to_target_s {
            println!("time-to-target: {}", fmt_time(tt));
        }
    }
    if let Some(o) = &report.ooc {
        println!(
            "steps={} train={} loss={:.4} train-acc={:.4} sample-wait {}",
            o.steps,
            fmt_time(o.train_time_s),
            o.final_loss,
            o.final_train_acc,
            fmt_time(o.sample_wait_s)
        );
    }
    if let Some(p) = &report.pmm {
        println!(
            "final loss {:.4} acc {:.4}  ({} steps in {})",
            report.final_loss,
            p.final_acc,
            report.steps,
            fmt_time(report.wall_s)
        );
        if let Some((val, test)) = p.eval {
            println!("full-graph eval: val {val:.4} test {test:.4}");
        }
    }
    if let Some(s) = &report.sim {
        println!(
            "projected on {} (hide={:.2}): {} points",
            s.machine,
            s.hide_frac,
            s.points.len()
        );
        for pt in &s.points {
            println!(
                "  Gd={:<3} devices={:<5} epoch {:.1} ms",
                pt.gd,
                pt.devices,
                pt.breakdown.total() * 1e3
            );
        }
    }
}

fn cmd_pack(args: &Args) -> Result<()> {
    args.check_known("pack", &["dataset", "out", "feat-precision"], &[])
        .map_err(|e| anyhow!(e))?;
    let dataset = args.str_or("dataset", "papers100m_ooc");
    let out = args
        .path_opt("out")
        .unwrap_or_else(|| PathBuf::from(format!("{dataset}.pallas")));
    let feat = precision_opt(args, "feat-precision")?.unwrap_or(Precision::Fp32);
    let t0 = std::time::Instant::now();
    println!("generating {dataset}...");
    let data = datasets::load(&dataset).ok_or_else(|| anyhow!("unknown dataset {dataset}"))?;
    println!(
        "packing {} vertices / {} edges into {} ({} features)",
        data.n,
        data.adj.nnz(),
        out.display(),
        feat.name()
    );
    let stats = scalegnn::graph::store::pack_with(&data, &out, feat)?;
    println!(
        "wrote {} ({} bytes = {:.1} MiB) in {}",
        out.display(),
        stats.bytes,
        stats.bytes as f64 / (1 << 20) as f64,
        fmt_time(t0.elapsed().as_secs_f64())
    );
    Ok(())
}

/// Out-of-core training (`train --from-store`): OOC backend of the
/// session API (pure-Rust reference model fed through the store's bounded
/// block cache).
fn cmd_train_ooc(args: &Args, store: PathBuf) -> Result<()> {
    // the OOC path trains the pure-Rust reference GCN with uniform
    // sampling only; PJRT-trainer options are rejected by check_known
    args.check_known(
        "train --from-store",
        &[
            "from-store", "dataset", "cache-mb", "batch", "d-h", "layers", "steps", "lr", "seed",
            "checkpoint-dir", "checkpoint-every", "checkpoint-keep",
        ],
        &["no-prefetch", "resume", "verbose", "v"],
    )
    .map_err(|e| anyhow!(e))?;
    let dataset = match args.str_opt("dataset") {
        Some(d) => d.to_string(),
        None => {
            // resolve the registry dataset from the store's source tag;
            // this extra open is header-only cost (the block cache reads
            // lazily), and the backend re-opens through open_or_pack
            let g = scalegnn::graph::store::OocGraph::open(&store, 1 << 20)?;
            datasets::registry()
                .iter()
                .find(|s| scalegnn::graph::store::name_tag(s.name) == g.source_tag)
                .map(|s| s.name.to_string())
                .ok_or_else(|| {
                    anyhow!(
                        "store {} was not packed from a registry dataset; pass --dataset",
                        store.display()
                    )
                })?
        }
    };
    let store_display = store.display().to_string();
    let mut spec = RunSpec::new(BackendKind::Ooc, &dataset).store(store);
    spec.cache_mb = args.get_or("cache-mb", 64usize).map_err(|e| anyhow!(e))?;
    spec.batch = Some(args.get_or("batch", 1024).map_err(|e| anyhow!(e))?);
    spec.model.d_h = args.get_or("d-h", 128).map_err(|e| anyhow!(e))?;
    spec.model.layers = args.get_or("layers", 3).map_err(|e| anyhow!(e))?;
    spec.model.dropout = 0.0;
    spec.steps = args.get_or("steps", 50).map_err(|e| anyhow!(e))?;
    spec.lr = args.get_or("lr", 1e-2).map_err(|e| anyhow!(e))?;
    spec.seed = args.get_or("seed", 42).map_err(|e| anyhow!(e))?;
    spec.prefetch = !args.flag("no-prefetch");
    apply_checkpoint_flags(args, &mut spec)?;
    println!(
        "out-of-core training from {store_display} (cache budget {} MiB, prefetch={})",
        spec.cache_mb, spec.prefetch
    );
    let mut obs = flag_observers(args, 1); // OOC has no eval steps: log each step
    let report = session::run(&spec, &mut obs)?;
    print_summary(&report);
    let r = report.ooc.as_ref().expect("ooc backend returns an ooc report");
    println!(
        "store {} bytes; cache resident {} / budget {} bytes ({} hits / {} misses)",
        r.store_bytes, r.cache_resident_bytes, r.cache_budget_bytes, r.cache_hits, r.cache_misses
    );
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    if let Some(store) = args.path_opt("from-store") {
        return cmd_train_ooc(args, store);
    }
    args.check_known(
        "train",
        &[
            "dataset", "sampler", "dp", "epochs", "steps", "target-acc", "lr", "seed", "overlap",
            "artifacts", "eval-every-epochs", "precision",
        ],
        &["no-prefetch", "verbose", "v"],
    )
    .map_err(|e| anyhow!(e))?;
    let dataset = args.str_or("dataset", "products_sim");
    let sampler_name = args.str_or("sampler", "scalegnn");
    let sampler = SamplerKind::parse(&sampler_name).ok_or_else(|| {
        anyhow!("--sampler must be scalegnn|graphsage|graphsaint, got '{sampler_name}'")
    })?;
    let mut spec = RunSpec::new(BackendKind::Reference, &dataset).sampler(sampler);
    spec.artifacts = PathBuf::from(args.str_or("artifacts", "artifacts"));
    spec.grid.gd = args.get_or("dp", 1).map_err(|e| anyhow!(e))?;
    spec.lr = args.get_or("lr", 1e-2).map_err(|e| anyhow!(e))?;
    spec.seed = args.get_or("seed", 42).map_err(|e| anyhow!(e))?;
    spec.steps = args.get_or("steps", 0).map_err(|e| anyhow!(e))?;
    spec.epochs = args.get_or("epochs", 20).map_err(|e| anyhow!(e))?;
    spec.prefetch = !args.flag("no-prefetch");
    spec.overlap = args.on_off("overlap", true).map_err(|e| anyhow!(e))?;
    if let Some(p) = precision_opt(args, "precision")? {
        spec.precision = p;
    }
    spec.eval_every_epochs = args.get_or("eval-every-epochs", 1).map_err(|e| anyhow!(e))?;
    if let Some(t) = args.get::<f32>("target-acc").map_err(|e| anyhow!(e))? {
        spec.target_acc = Some(t);
    }
    println!(
        "training {dataset} with {} sampling, dp={}, prefetch={}",
        sampler.name(),
        spec.grid.gd,
        spec.prefetch
    );
    let mut obs = flag_observers(args, 0); // per-epoch eval lines, as before
    let report = session::run(&spec, &mut obs)?;
    print_summary(&report);
    let r = report.trainer.as_ref().expect("reference backend returns a trainer report");
    println!(
        "per-step: sample-wait {} pack {} exec {} dp {}",
        fmt_time(r.breakdown.sample_wait_s),
        fmt_time(r.breakdown.pack_s),
        fmt_time(r.breakdown.exec_s),
        fmt_time(r.breakdown.dp_comm_s)
    );
    Ok(())
}

fn cmd_pmm_train(args: &Args) -> Result<()> {
    args.check_known(
        "pmm-train",
        &[
            "dataset", "grid", "steps", "lr", "seed", "batch", "d-h", "layers", "dropout",
            "overlap", "stats-json", "checkpoint-dir", "checkpoint-every", "checkpoint-keep",
            "kill-rank", "kill-step", "stall-rank", "stall-step", "stall-ms", "transport",
            "rank", "precision", "chaos", "connect-timeout-ms", "heartbeat-ms",
            "wait-timeout-ms", "rejoin-grace-ms",
        ],
        &["bf16", "resume", "verbose", "v"],
    )
    .map_err(|e| anyhow!(e))?;
    let dataset = args.str_or("dataset", "tiny");
    let mut spec = RunSpec::new(BackendKind::Pmm, &dataset);
    spec.grid = GridSpec::parse(&args.str_or("grid", "1x2x2x2")).map_err(|e| anyhow!(e))?;
    spec.model = ModelSpec::for_dataset(&dataset, 0.5);
    spec.model.d_h = args.get_or("d-h", spec.model.d_h).map_err(|e| anyhow!(e))?;
    spec.model.layers = args.get_or("layers", spec.model.layers).map_err(|e| anyhow!(e))?;
    spec.model.dropout = args.get_or("dropout", spec.model.dropout).map_err(|e| anyhow!(e))?;
    spec.steps = args.get_or("steps", 20).map_err(|e| anyhow!(e))?;
    spec.lr = args.get_or("lr", 5e-3).map_err(|e| anyhow!(e))?;
    spec.seed = args.get_or("seed", 42).map_err(|e| anyhow!(e))?;
    if let Some(b) = args.get::<usize>("batch").map_err(|e| anyhow!(e))? {
        spec.batch = Some(b);
    }
    spec.precision = match precision_opt(args, "precision")? {
        Some(p) => {
            if args.flag("bf16") && p != Precision::Bf16 {
                bail!("--bf16 and --precision {} conflict", p.name());
            }
            p
        }
        None if args.flag("bf16") => {
            eprintln!("warning: --bf16 is deprecated, use --precision bf16");
            Precision::Bf16
        }
        None => Precision::Fp32,
    };
    spec.overlap = args.on_off("overlap", true).map_err(|e| anyhow!(e))?;
    apply_checkpoint_flags(args, &mut spec)?;
    match (
        args.get::<usize>("kill-rank").map_err(|e| anyhow!(e))?,
        args.get::<u64>("kill-step").map_err(|e| anyhow!(e))?,
    ) {
        (Some(rank), Some(step)) => spec.fault = Some(FaultSpec::KillRank { rank, step }),
        (None, None) => {}
        _ => bail!("--kill-rank and --kill-step must be given together"),
    }
    match (
        args.get::<usize>("stall-rank").map_err(|e| anyhow!(e))?,
        args.get::<u64>("stall-step").map_err(|e| anyhow!(e))?,
    ) {
        (Some(rank), Some(step)) => {
            if spec.fault.is_some() {
                bail!("--kill-rank and --stall-rank conflict (one scripted fault per run)");
            }
            let ms = args.get_or("stall-ms", 60_000u64).map_err(|e| anyhow!(e))?;
            spec.fault = Some(FaultSpec::StallRank { rank, step, ms });
        }
        (None, None) => {
            if args.get::<u64>("stall-ms").map_err(|e| anyhow!(e))?.is_some() {
                bail!("--stall-ms needs --stall-rank and --stall-step");
            }
        }
        _ => bail!("--stall-rank and --stall-step must be given together"),
    }
    apply_transport_flags(args, &mut spec)?;
    println!(
        "4D PMM training {dataset} on grid {} ({} ranks, {}), {:?}, overlap={}",
        spec.grid.to_string(),
        spec.grid.world_size(),
        spec.transport.endpoint_tag(),
        spec.precision,
        if spec.overlap { "on" } else { "off" }
    );
    let mut obs = flag_observers(args, 1);
    let report = session::run(&spec, &mut obs)?;
    print_summary(&report);
    let p = report.pmm.as_ref().expect("pmm backend returns a pmm report");
    let t = &p.timers_mean;
    println!(
        "per-rank mean: sampling {} spmm {} gemm {} elementwise {} tp_comm {} dp_comm {} reshard {}",
        fmt_time(t.sampling),
        fmt_time(t.spmm),
        fmt_time(t.gemm),
        fmt_time(t.elementwise),
        fmt_time(t.tp_comm),
        fmt_time(t.dp_comm),
        fmt_time(t.reshard),
    );
    print!("measured hidden-comm fraction (§V-D):");
    for ax in &p.axes {
        print!(" {}={:.2}", ax.axis, ax.hidden_frac);
    }
    println!("  (tp aggregate {:.3})", p.tp_hidden_frac);
    write_stats_json(args, &spec, &report)?;
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    args.check_known("eval", &["dataset", "grid"], &[]).map_err(|e| anyhow!(e))?;
    let dataset = args.str_or("dataset", "tiny");
    let mut spec = RunSpec::new(BackendKind::Pmm, &dataset);
    spec.grid = GridSpec::parse(&args.str_or("grid", "2x2x2")).map_err(|e| anyhow!(e))?;
    spec.model = ModelSpec::for_dataset(&dataset, 0.0);
    spec.steps = 0;
    spec.final_eval = true;
    let report = session::run_silent(&spec)?;
    let (val, test) = report
        .pmm
        .as_ref()
        .and_then(|p| p.eval)
        .ok_or_else(|| anyhow!("evaluation produced no result"))?;
    println!(
        "distributed full-graph eval on {} ranks: val {:.4} test {:.4} in {}",
        spec.grid.world_size(),
        val,
        test,
        fmt_time(report.wall_s)
    );
    Ok(())
}

fn cmd_sample(args: &Args) -> Result<()> {
    args.check_known(
        "sample",
        &["dataset", "grid", "steps", "from-store", "cache-mb", "batch"],
        &[],
    )
    .map_err(|e| anyhow!(e))?;
    let dataset = args.str_or("dataset", "products_sim");
    let steps: u64 = args.get_or("steps", 50).map_err(|e| anyhow!(e))?;
    let gridspec = args.str_or("grid", "2x2");
    let parts: Vec<usize> = gridspec.split('x').filter_map(|p| p.parse().ok()).collect();
    if parts.len() != 2 {
        bail!("--grid must be RxC, e.g. 2x2 (got '{gridspec}')");
    }
    // From a .pallas store each shard is extracted independently through
    // GraphAccess — a real rank would materialize only its own block.  This
    // single-process demo hosts every rank, so all blocks coexist here.
    let from_store = args.path_opt("from-store");
    let source = match &from_store {
        Some(p) => format!("store {}", p.display()),
        None => dataset.clone(),
    };
    let (n, batch, shards) = if let Some(path) = from_store {
        let cache = args.get_or("cache-mb", 64usize).map_err(|e| anyhow!(e))? << 20;
        let store = scalegnn::graph::store::OocGraph::open(&path, cache)?;
        let batch = args.get_or("batch", 1024).map_err(|e| anyhow!(e))?;
        if batch > store.n {
            bail!("--batch {} exceeds store vertex count {}", batch, store.n);
        }
        let rb = scalegnn::graph::block_bounds(store.n, parts[0]);
        let cb = scalegnn::graph::block_bounds(store.n, parts[1]);
        let mut shards = Vec::with_capacity(parts[0] * parts[1]);
        for i in 0..parts[0] {
            for j in 0..parts[1] {
                shards.push(scalegnn::graph::extract_shard_from(
                    &store,
                    rb[i],
                    rb[i + 1],
                    cb[j],
                    cb[j + 1],
                ));
            }
        }
        (store.n, batch, shards)
    } else {
        let data = datasets::load(&dataset).ok_or_else(|| anyhow!("unknown dataset"))?;
        let spec = datasets::spec(&dataset).unwrap();
        (data.n, spec.batch, partition_2d(&data.adj, parts[0], parts[1]))
    };
    let sampler = UniformVertexSampler::new(n, batch, 42);
    println!(
        "Algorithm 2 on {}: n={} batch={} shard grid {}x{}",
        source, n, batch, parts[0], parts[1]
    );
    let mut builders: Vec<_> = shards
        .into_iter()
        .map(|sh| DistributedSubgraphBuilder::new(sampler.clone(), sh))
        .collect();
    let t0 = std::time::Instant::now();
    let mut nnz = 0usize;
    for step in 0..steps {
        for b in builders.iter_mut() {
            nnz += b.build(step).adj.nnz();
        }
    }
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "{} steps x {} ranks: {} per rank-step, induced nnz/step {:.0} (p={:.2e})",
        steps,
        builders.len(),
        fmt_time(dt / (steps as f64 * builders.len() as f64)),
        nnz as f64 / steps as f64,
        sampler.inclusion_prob(),
    );
    Ok(())
}

/// Measured §V-D hide fraction: execute a short 8-rank PMM run (tiny
/// dataset, 1x2x2x2 grid, overlap on) through the session API and return
/// the TP hidden-communication fraction — the executed calibration feeding
/// the sim backend in place of the guessed constant.
fn calibrated_hide_frac() -> Result<f64> {
    let mut spec = RunSpec::new(BackendKind::Pmm, "tiny");
    spec.grid = GridSpec { gd: 1, gx: 2, gy: 2, gz: 2 };
    spec.model = ModelSpec::for_dataset("tiny", 0.0);
    spec.steps = 8;
    spec.lr = 5e-3;
    let report = session::run_silent(&spec)?;
    Ok(report.pmm.expect("pmm backend returns a pmm report").tp_hidden_frac)
}

/// Map the shared sim-command flags (`--machine`, `--overlap`,
/// `--hide-frac` / `--calibrate-overlap`) onto a sim-backend spec over
/// `gd_sweep`.
fn sim_spec(args: &Args, dataset: &str, gd_sweep: Vec<usize>) -> Result<RunSpec> {
    let machine = args.str_or("machine", "perlmutter");
    let hide = match args.get::<f64>("hide-frac").map_err(|e| anyhow!(e))? {
        Some(f) => Some(f),
        None if args.flag("calibrate-overlap") => {
            let f = calibrated_hide_frac()?;
            println!(
                "calibrated §V-D hide fraction from an executed 8-rank engine run: {f:.3}"
            );
            Some(f)
        }
        None => None,
    };
    let (x, y, z) = sim::base_grid_for(dataset);
    let mut spec = RunSpec::new(BackendKind::Sim, dataset).sim(&machine, hide, gd_sweep);
    spec.grid = GridSpec { gd: 1, gx: x, gy: y, gz: z };
    spec.model = ModelSpec { d_h: 128, layers: 3, dropout: 0.0 };
    spec.precision = Precision::Bf16; // §V-B is on in the paper projections
    spec.overlap = args.on_off("overlap", true).map_err(|e| anyhow!(e))?;
    Ok(spec)
}

const SIM_OPTS: [&str; 4] = ["dataset", "machine", "overlap", "hide-frac"];
const SIM_FLAGS: [&str; 1] = ["calibrate-overlap"];

fn cmd_scaling(args: &Args) -> Result<()> {
    args.check_known("scaling", &SIM_OPTS, &SIM_FLAGS).map_err(|e| anyhow!(e))?;
    let dataset = args.str_or("dataset", "papers100m_sim");
    let (x, y, z) = sim::base_grid_for(&dataset);
    let base = x * y * z;
    let sweep: Vec<usize> =
        [1usize, 2, 4, 8, 16, 32, 64].into_iter().filter(|gd| base * gd <= 2048).collect();
    let spec = sim_spec(args, &dataset, sweep)?;
    let report = session::run_silent(&spec)?;
    let s = report.sim.as_ref().expect("sim backend returns a sim report");
    println!(
        "strong scaling: {dataset} on {} (3D grid {x}x{y}x{z}, growing Gd, overlap={} hide={:.2})",
        s.machine,
        if spec.overlap { "on" } else { "off" },
        s.hide_frac
    );
    println!("{:>8} {:>6} {:>14} {:>9}", "devices", "Gd", "epoch (ms)", "speedup");
    let first = s.points.first().map(|p| p.breakdown.total()).unwrap_or(f64::NAN);
    for p in &s.points {
        let t = p.breakdown.total();
        println!("{:>8} {:>6} {:>14.1} {:>8.1}x", p.devices, p.gd, t * 1e3, first / t);
    }
    Ok(())
}

fn cmd_breakdown(args: &Args) -> Result<()> {
    args.check_known("breakdown", &SIM_OPTS, &SIM_FLAGS).map_err(|e| anyhow!(e))?;
    let dataset = args.str_or("dataset", "products14m_sim");
    let (x, y, z) = sim::base_grid_for(&dataset);
    let spec = sim_spec(args, &dataset, vec![1, 2, 4, 8, 16, 32])?;
    let report = session::run_silent(&spec)?;
    let s = report.sim.as_ref().expect("sim backend returns a sim report");
    println!(
        "epoch breakdown: {dataset} on {} ({x}x{y}x{z} per group, overlap={} hide={:.2})",
        s.machine,
        if spec.overlap { "on" } else { "off" },
        s.hide_frac
    );
    println!(
        "{:>4} {:>10} {:>10} {:>10} {:>10} {:>10} {:>10} {:>10}",
        "Gd", "total ms", "sampling", "spmm+gemm", "elemwise", "tp_comm", "dp_comm", "other"
    );
    for p in &s.points {
        let b = &p.breakdown;
        println!(
            "{:>4} {:>10.1} {:>10.1} {:>10.1} {:>10.1} {:>10.1} {:>10.1} {:>10.1}",
            p.gd,
            b.total() * 1e3,
            b.sampling * 1e3,
            (b.spmm + b.gemm) * 1e3,
            b.elementwise * 1e3,
            b.tp_comm * 1e3,
            b.dp_comm * 1e3,
            b.other * 1e3
        );
    }
    Ok(())
}

fn cmd_e2e(args: &Args) -> Result<()> {
    args.check_known("e2e", &SIM_OPTS, &SIM_FLAGS).map_err(|e| anyhow!(e))?;
    let dataset = args.str_or("dataset", "products_sim");
    let machine_name = args.str_or("machine", "perlmutter");
    let m = sim::by_name(&machine_name).ok_or_else(|| {
        anyhow!("unknown machine '{machine_name}' (accepted: perlmutter, frontier, tuolumne)")
    })?;
    let spec_ds = datasets::spec(&dataset).ok_or_else(|| anyhow!("unknown dataset {dataset}"))?;
    let w = sim::Workload::from_spec(&spec_ds, 128.0, 3.0);
    let gpus_list = [4usize, 8, 16, 32, 64];
    // ScaleGNN's column comes from one sim-backend session over the
    // device counts the dataset's fixed 3D base divides
    let valid: Vec<(usize, usize)> = gpus_list
        .iter()
        .filter_map(|&g| sim::grid_for(&dataset, g).map(|gr| (g, gr.gd)))
        .collect();
    let mut scalegnn_s: std::collections::BTreeMap<usize, f64> = Default::default();
    if !valid.is_empty() {
        let spec = sim_spec(args, &dataset, valid.iter().map(|v| v.1).collect())?;
        let report = session::run_silent(&spec)?;
        let s = report.sim.as_ref().expect("sim backend returns a sim report");
        for (&(gpus, _), p) in valid.iter().zip(&s.points) {
            scalegnn_s.insert(gpus, p.breakdown.total());
        }
    }
    println!(
        "end-to-end time-to-accuracy: {dataset} on {} (log-scale in the paper)",
        m.name
    );
    print!("{:>8}", "devices");
    for fw in sim::Framework::all() {
        print!(" {:>12}", fw.name());
    }
    println!();
    for gpus in gpus_list {
        print!("{:>8}", gpus);
        for fw in sim::Framework::all() {
            let t = if fw == sim::Framework::ScaleGnn {
                match scalegnn_s.get(&gpus) {
                    Some(&epoch) => epoch * sim::epochs_to_target(fw, &dataset, gpus),
                    None => f64::NAN,
                }
            } else if m.name != "Perlmutter" && !fw.supports_rocm() {
                f64::NAN
            } else {
                sim::baseline_epoch(fw, &w, &m, gpus) * sim::epochs_to_target(fw, &dataset, gpus)
            };
            if t.is_nan() {
                print!(" {:>12}", "-");
            } else {
                print!(" {:>11.2}s", t);
            }
        }
        println!();
    }
    Ok(())
}
