//! ScaleGNN launcher: the Layer-3 leader entrypoint.
//!
//! ```text
//! scalegnn info
//! scalegnn train      --dataset products_sim [--sampler scalegnn|sage|saint]
//!                     [--dp N] [--epochs E | --steps S] [--target-acc A]
//!                     [--lr F] [--no-prefetch] [--overlap on|off] [--verbose]
//! scalegnn train      --from-store graph.pallas [--dataset papers100m_ooc]
//!                     [--cache-mb M] [--steps S] [--batch B] [--lr F]
//! scalegnn pack       --dataset papers100m_ooc [--out graph.pallas]
//! scalegnn pmm-train  --dataset tiny --grid 1x2x2x2 [--steps S] [--bf16]
//!                     [--overlap on|off] [--stats-json FILE]
//! scalegnn eval       --dataset tiny --grid 2x2x2
//! scalegnn sample     --dataset products_sim [--grid 2x2] [--steps S]
//!                     [--from-store graph.pallas] [--cache-mb M]
//! scalegnn scaling    --dataset papers100m_sim --machine perlmutter
//!                     [--overlap on|off] [--hide-frac F | --calibrate-overlap]
//! scalegnn breakdown  --dataset products14m_sim [--machine M]
//!                     [--overlap on|off] [--hide-frac F | --calibrate-overlap]
//! scalegnn e2e        --dataset products_sim --machine perlmutter
//!                     [--overlap on|off] [--hide-frac F | --calibrate-overlap]
//! ```

use std::path::PathBuf;
use std::sync::Arc;

use anyhow::{anyhow, bail, Result};

use scalegnn::comm::{CommWorld, Precision};
use scalegnn::graph::{datasets, partition_2d};
use scalegnn::grid::{Axis, Grid4D};
use scalegnn::pmm::{PmmCtx, PmmGcn};
use scalegnn::sampling::{DistributedSubgraphBuilder, SamplerKind, UniformVertexSampler};
use scalegnn::sim;
use scalegnn::trainer::{self, TrainConfig};
use scalegnn::util::cli::Args;
use scalegnn::util::json::{obj, Json};
use scalegnn::util::stats::fmt_time;

fn main() {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    let sub = args.subcommand.clone().unwrap_or_else(|| "help".into());
    let r = match sub.as_str() {
        "info" => cmd_info(&args),
        "train" => cmd_train(&args),
        "pack" => cmd_pack(&args),
        "pmm-train" => cmd_pmm_train(&args),
        "eval" => cmd_eval(&args),
        "sample" => cmd_sample(&args),
        "scaling" => cmd_scaling(&args),
        "breakdown" => cmd_breakdown(&args),
        "e2e" => cmd_e2e(&args),
        _ => {
            print!("{}", HELP);
            Ok(())
        }
    };
    if let Err(e) = r {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

const HELP: &str = "\
ScaleGNN: communication-free sampling + 4D hybrid parallel GNN training

USAGE: scalegnn <command> [options]

COMMANDS:
  info        show artifacts, models and datasets
  train       mini-batch training via the PJRT artifacts (fused or DP);
              with --from-store FILE.pallas: out-of-core pure-Rust training
  pack        serialize a dataset into a .pallas out-of-core container
  pmm-train   4D training on the rank-thread 3D PMM engine
  eval        distributed full-graph evaluation (Table II mechanism)
  sample      communication-free distributed sampling microbench
              (--from-store FILE.pallas extracts shards out-of-core)
  scaling     projected strong scaling at paper scale (Fig. 7)
  breakdown   projected epoch-time breakdown (Figs. 5/8)
  e2e         projected end-to-end time-to-accuracy vs baselines (Fig. 6)

§V-D overlap: train/pmm-train accept --overlap on|off (nonblocking chunked
collectives; pmm-train reports the measured hidden-comm fraction per axis,
--stats-json FILE writes it).  The sim commands accept --overlap on|off and
--hide-frac F or --calibrate-overlap (measure the hide fraction on an
executed 8-rank engine run instead of the default constant).

Run `cargo bench` to regenerate every paper table/figure.
";

fn artifacts_dir(args: &Args) -> PathBuf {
    PathBuf::from(args.str_or("artifacts", "artifacts"))
}

/// Parse `--overlap on|off` (§V-D communication/computation overlap;
/// default on).
fn overlap_of(args: &Args) -> Result<bool> {
    match args.str_or("overlap", "on").as_str() {
        "on" | "true" | "1" => Ok(true),
        "off" | "false" | "0" => Ok(false),
        other => Err(anyhow!("--overlap must be on|off, got '{other}'")),
    }
}

/// §V-D hide fraction for the sim commands: `--hide-frac F` overrides,
/// `--calibrate-overlap` measures it by executing a short multi-rank run
/// on the rank-thread engine, otherwise the calibration default is used.
fn hide_frac_of(args: &Args) -> Result<f64> {
    if let Some(f) = args.get::<f64>("hide-frac").map_err(|e| anyhow!(e))? {
        if !(0.0..=1.0).contains(&f) {
            bail!("--hide-frac must be in [0, 1], got {f}");
        }
        return Ok(f);
    }
    if args.flag("calibrate-overlap") {
        let f = measure_overlap_hide_frac(8)?;
        println!("calibrated §V-D hide fraction from an executed 8-rank engine run: {f:.3}");
        return Ok(f);
    }
    Ok(sim::DEFAULT_OVERLAP_HIDE_FRAC)
}

/// Execute a short 8-rank PMM training run (tiny dataset, 1x2x2x2 grid)
/// with overlap on and return the measured TP hidden-communication
/// fraction — the executed calibration feeding `sim::scalegnn_epoch_with`
/// in place of the guessed constant.
fn measure_overlap_hide_frac(steps: u64) -> Result<f64> {
    let grid = Grid4D::new(1, 2, 2, 2);
    let data = Arc::new(datasets::load("tiny").ok_or_else(|| anyhow!("tiny dataset missing"))?);
    let spec = datasets::spec("tiny").unwrap();
    let batch = spec.batch;
    let dims = dims_for("tiny", 0.0);
    let world = Arc::new(CommWorld::new(grid));
    let mut handles = vec![];
    for r in 0..grid.world_size() {
        let w = world.clone();
        let d = data.clone();
        handles.push(std::thread::spawn(move || {
            let ctx = PmmCtx::new(grid, r, &w, Precision::Fp32);
            let mut eng = PmmGcn::new(ctx, dims, batch, d, 42);
            for s in 0..steps {
                eng.train_step(s, 5e-3);
            }
        }));
    }
    for h in handles {
        h.join().map_err(|_| anyhow!("calibration rank panicked"))?;
    }
    Ok(world.tp_hidden_fraction())
}

/// Model dims for a dataset (mirrors the artifact configurations).
fn dims_for(dataset: &str, dropout: f32) -> scalegnn::model::GcnDims {
    let spec = datasets::spec(dataset).expect("known dataset");
    let (d_h, layers) = match dataset {
        "tiny" => (16, 2),
        "e2e_big" => (512, 4),
        _ => (128, 3),
    };
    scalegnn::model::GcnDims {
        d_in: spec.planted.d_in,
        d_h,
        d_out: spec.planted.classes,
        layers,
        dropout,
        weight_decay: 0.0,
    }
}

fn cmd_info(args: &Args) -> Result<()> {
    println!("== datasets ==");
    for s in datasets::registry() {
        println!(
            "  {:<16} n={:<9} classes={:<3} d_in={:<4} batch={:<5} (paper N={:.1e})",
            s.name, s.planted.n, s.planted.classes, s.planted.d_in, s.batch, s.paper.n
        );
    }
    match scalegnn::runtime::Runtime::open(&artifacts_dir(args)) {
        Ok(rt) => {
            println!("== artifacts ({}) ==", rt.platform());
            let mut names: Vec<_> = rt.manifest.artifacts.keys().collect();
            names.sort();
            for n in names {
                let a = &rt.manifest.artifacts[n];
                println!("  {:<28} {} in / {} out", n, a.inputs.len(), a.outputs.len());
            }
        }
        Err(e) => println!("(artifacts not built: {e})"),
    }
    Ok(())
}

fn cmd_pack(args: &Args) -> Result<()> {
    let dataset = args.str_or("dataset", "papers100m_ooc");
    let out = args
        .path_opt("out")
        .unwrap_or_else(|| PathBuf::from(format!("{dataset}.pallas")));
    let t0 = std::time::Instant::now();
    println!("generating {dataset}...");
    let data = datasets::load(&dataset).ok_or_else(|| anyhow!("unknown dataset {dataset}"))?;
    println!("packing {} vertices / {} edges into {}", data.n, data.adj.nnz(), out.display());
    let stats = scalegnn::graph::store::pack(&data, &out)?;
    println!(
        "wrote {} ({} bytes = {:.1} MiB) in {}",
        out.display(),
        stats.bytes,
        stats.bytes as f64 / (1 << 20) as f64,
        fmt_time(t0.elapsed().as_secs_f64())
    );
    Ok(())
}

/// Out-of-core training (`train --from-store`): pure-Rust reference model
/// fed by mini-batches read through the store's bounded block cache.
fn cmd_train_ooc(args: &Args, store: PathBuf) -> Result<()> {
    // the OOC path trains the pure-Rust reference GCN with uniform
    // sampling only; reject PJRT-trainer options instead of ignoring them
    for opt in ["sampler", "dp", "epochs", "target-acc", "eval-every-epochs"] {
        if args.str_opt(opt).is_some() {
            bail!("--{opt} is not supported with --from-store (see `scalegnn help`)");
        }
    }
    if args.flag("bf16") {
        bail!("--bf16 is not supported with --from-store");
    }
    let mut cfg = trainer::OocTrainConfig::quick(store);
    cfg.dataset = args.str_opt("dataset").map(str::to_string);
    cfg.cache_bytes = args.get_or("cache-mb", 64usize).map_err(|e| anyhow!(e))? << 20;
    cfg.batch = args.get_or("batch", 1024).map_err(|e| anyhow!(e))?;
    cfg.d_h = args.get_or("d-h", 128).map_err(|e| anyhow!(e))?;
    cfg.layers = args.get_or("layers", 3).map_err(|e| anyhow!(e))?;
    cfg.steps = args.get_or("steps", 50).map_err(|e| anyhow!(e))?;
    cfg.lr = args.get_or("lr", 1e-2).map_err(|e| anyhow!(e))?;
    cfg.seed = args.get_or("seed", 42).map_err(|e| anyhow!(e))?;
    cfg.prefetch = !args.flag("no-prefetch");
    cfg.verbose = args.flag("verbose") || args.flag("v");
    println!(
        "out-of-core training from {} (cache budget {} MiB, prefetch={})",
        cfg.store.display(),
        cfg.cache_bytes >> 20,
        cfg.prefetch
    );
    let r = trainer::train_from_store(&cfg)?;
    println!(
        "steps={} train={} loss={:.4} train-acc={:.4} sample-wait {}",
        r.steps,
        fmt_time(r.train_time_s),
        r.final_loss,
        r.final_train_acc,
        fmt_time(r.sample_wait_s)
    );
    println!(
        "store {} bytes; cache resident {} / budget {} bytes ({} hits / {} misses)",
        r.store_bytes, r.cache_resident_bytes, r.cache_budget_bytes, r.cache_hits, r.cache_misses
    );
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    if let Some(store) = args.path_opt("from-store") {
        return cmd_train_ooc(args, store);
    }
    let dataset = args.str_or("dataset", "products_sim");
    let sampler = SamplerKind::parse(&args.str_or("sampler", "scalegnn"))
        .ok_or_else(|| anyhow!("unknown sampler"))?;
    let mut cfg = TrainConfig::quick(&dataset, sampler);
    cfg.artifacts = artifacts_dir(args);
    cfg.dp = args.get_or("dp", 1).map_err(|e| anyhow!(e))?;
    cfg.lr = args.get_or("lr", 1e-2).map_err(|e| anyhow!(e))?;
    cfg.seed = args.get_or("seed", 42).map_err(|e| anyhow!(e))?;
    cfg.max_steps = args.get_or("steps", 0).map_err(|e| anyhow!(e))?;
    cfg.max_epochs = args.get_or("epochs", 20).map_err(|e| anyhow!(e))?;
    cfg.prefetch = !args.flag("no-prefetch");
    cfg.overlap = overlap_of(args)?;
    cfg.verbose = args.flag("verbose") || args.flag("v");
    if let Some(t) = args.get::<f32>("target-acc").map_err(|e| anyhow!(e))? {
        cfg.target_acc = Some(t);
    }
    println!(
        "training {dataset} with {} sampling, dp={}, prefetch={}",
        sampler.name(),
        cfg.dp,
        cfg.prefetch
    );
    let r = trainer::train(&cfg)?;
    println!(
        "steps={} epochs={} train={} eval={} loss={:.4} best_val={:.4} best_test={:.4}",
        r.steps,
        r.epochs,
        fmt_time(r.train_time_s),
        fmt_time(r.eval_time_s),
        r.final_loss,
        r.best_val_acc,
        r.best_test_acc
    );
    if let Some(t) = r.time_to_target_s {
        println!("time-to-target: {}", fmt_time(t));
    }
    println!(
        "per-step: sample-wait {} pack {} exec {} dp {}",
        fmt_time(r.breakdown.sample_wait_s),
        fmt_time(r.breakdown.pack_s),
        fmt_time(r.breakdown.exec_s),
        fmt_time(r.breakdown.dp_comm_s)
    );
    Ok(())
}

fn cmd_pmm_train(args: &Args) -> Result<()> {
    let dataset = args.str_or("dataset", "tiny");
    let grid = Grid4D::parse(&args.str_or("grid", "1x2x2x2"))
        .ok_or_else(|| anyhow!("bad --grid"))?;
    let steps: u64 = args.get_or("steps", 20).map_err(|e| anyhow!(e))?;
    let lr: f32 = args.get_or("lr", 5e-3).map_err(|e| anyhow!(e))?;
    let prec = if args.flag("bf16") { Precision::Bf16 } else { Precision::Fp32 };
    let overlap = overlap_of(args)?;
    let data = Arc::new(datasets::load(&dataset).ok_or_else(|| anyhow!("unknown dataset"))?);
    let spec = datasets::spec(&dataset).unwrap();
    let dims = dims_for(&dataset, 0.5);
    let batch = spec.batch;
    println!(
        "4D PMM training {dataset} on grid {}x{}x{}x{} ({} rank threads), {prec:?}, overlap={}",
        grid.gd,
        grid.gx,
        grid.gy,
        grid.gz,
        grid.world_size(),
        if overlap { "on" } else { "off" }
    );
    let world = Arc::new(CommWorld::new(grid));
    let t0 = std::time::Instant::now();
    let mut handles = vec![];
    for r in 0..grid.world_size() {
        let w = world.clone();
        let d = data.clone();
        handles.push(std::thread::spawn(move || {
            let ctx = PmmCtx::new(grid, r, &w, prec);
            let mut eng = PmmGcn::new(ctx, dims, batch, d, 42);
            eng.set_overlap(overlap);
            let mut out = (0.0, 0.0);
            for s in 0..steps {
                let o = eng.train_step(s, lr);
                out = (o.loss, o.acc);
            }
            (out, eng.timers)
        }));
    }
    let mut timers = scalegnn::pmm::PmmTimers::default();
    let mut last = (0.0, 0.0);
    for h in handles {
        let ((l, a), t) = h.join().unwrap();
        timers.add(&t);
        last = (l, a);
    }
    let wall = t0.elapsed().as_secs_f64();
    let n = grid.world_size() as f64;
    println!(
        "final loss {:.4} acc {:.4}  ({} steps in {})",
        last.0,
        last.1,
        steps,
        fmt_time(wall)
    );
    println!(
        "per-rank mean: sampling {} spmm {} gemm {} elementwise {} tp_comm {} dp_comm {} reshard {}",
        fmt_time(timers.sampling / n),
        fmt_time(timers.spmm / n),
        fmt_time(timers.gemm / n),
        fmt_time(timers.elementwise / n),
        fmt_time(timers.tp_comm / n),
        fmt_time(timers.dp_comm / n),
        fmt_time(timers.reshard / n),
    );
    let axes = [(Axis::X, "x"), (Axis::Y, "y"), (Axis::Z, "z"), (Axis::Dp, "dp")];
    print!("measured hidden-comm fraction (§V-D):");
    for (ax, name) in axes {
        print!(" {name}={:.2}", world.hidden_fraction(ax));
    }
    println!("  (tp aggregate {:.3})", world.tp_hidden_fraction());
    if let Some(path) = args.path_opt("stats-json") {
        let mut ax_objs = Vec::new();
        for (ax, name) in axes {
            let (ops, bytes) = world.stats(ax);
            let (comm_s, blocked_s) = world.timing(ax);
            ax_objs.push(obj(vec![
                ("axis", Json::from(name)),
                ("ops", Json::from(ops as usize)),
                ("bytes", Json::from(bytes as usize)),
                ("comm_s", Json::from(comm_s)),
                ("blocked_s", Json::from(blocked_s)),
                ("hidden_frac", Json::from(world.hidden_fraction(ax))),
            ]));
        }
        let gridspec = format!("{}x{}x{}x{}", grid.gd, grid.gx, grid.gy, grid.gz);
        let doc = obj(vec![
            ("dataset", Json::from(dataset.as_str())),
            ("grid", Json::from(gridspec.as_str())),
            ("steps", Json::from(steps as usize)),
            ("overlap", Json::Bool(overlap)),
            ("precision", Json::from(if args.flag("bf16") { "bf16" } else { "fp32" })),
            ("wall_s", Json::from(wall)),
            ("final_loss", Json::from(last.0 as f64)),
            ("final_acc", Json::from(last.1 as f64)),
            ("tp_hidden_frac", Json::from(world.tp_hidden_fraction())),
            ("axes", Json::Arr(ax_objs)),
            (
                "per_rank_mean_s",
                obj(vec![
                    ("sampling", Json::from(timers.sampling / n)),
                    ("spmm", Json::from(timers.spmm / n)),
                    ("gemm", Json::from(timers.gemm / n)),
                    ("elementwise", Json::from(timers.elementwise / n)),
                    ("tp_comm", Json::from(timers.tp_comm / n)),
                    ("dp_comm", Json::from(timers.dp_comm / n)),
                    ("reshard", Json::from(timers.reshard / n)),
                ]),
            ),
        ]);
        std::fs::write(&path, doc.to_string() + "\n")
            .map_err(|e| anyhow!("writing {}: {e}", path.display()))?;
        println!("wrote {}", path.display());
    }
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    let dataset = args.str_or("dataset", "tiny");
    let grid =
        Grid4D::parse(&args.str_or("grid", "2x2x2")).ok_or_else(|| anyhow!("bad --grid"))?;
    let data = Arc::new(datasets::load(&dataset).ok_or_else(|| anyhow!("unknown dataset"))?);
    let spec = datasets::spec(&dataset).unwrap();
    let dims = dims_for(&dataset, 0.0);
    let world = Arc::new(CommWorld::new(grid));
    let t0 = std::time::Instant::now();
    let mut handles = vec![];
    for r in 0..grid.world_size() {
        let w = world.clone();
        let d = data.clone();
        handles.push(std::thread::spawn(move || {
            let ctx = PmmCtx::new(grid, r, &w, Precision::Fp32);
            let mut eng = PmmGcn::new(ctx, dims, spec.batch, d, 42);
            eng.eval_full_graph()
        }));
    }
    let mut accs = (0.0, 0.0);
    for h in handles {
        accs = h.join().unwrap();
    }
    println!(
        "distributed full-graph eval on {} ranks: val {:.4} test {:.4} in {}",
        grid.world_size(),
        accs.0,
        accs.1,
        fmt_time(t0.elapsed().as_secs_f64())
    );
    Ok(())
}

fn cmd_sample(args: &Args) -> Result<()> {
    let dataset = args.str_or("dataset", "products_sim");
    let steps: u64 = args.get_or("steps", 50).map_err(|e| anyhow!(e))?;
    let gridspec = args.str_or("grid", "2x2");
    let parts: Vec<usize> = gridspec.split('x').filter_map(|p| p.parse().ok()).collect();
    if parts.len() != 2 {
        bail!("--grid must be RxC, e.g. 2x2");
    }
    // From a .pallas store each shard is extracted independently through
    // GraphAccess — a real rank would materialize only its own block.  This
    // single-process demo hosts every rank, so all blocks coexist here.
    let from_store = args.path_opt("from-store");
    let source = match &from_store {
        Some(p) => format!("store {}", p.display()),
        None => dataset.clone(),
    };
    let (n, batch, shards) = if let Some(path) = from_store {
        let cache = args.get_or("cache-mb", 64usize).map_err(|e| anyhow!(e))? << 20;
        let store = scalegnn::graph::store::OocGraph::open(&path, cache)?;
        let batch = args.get_or("batch", 1024).map_err(|e| anyhow!(e))?;
        if batch > store.n {
            bail!("--batch {} exceeds store vertex count {}", batch, store.n);
        }
        let rb = scalegnn::graph::block_bounds(store.n, parts[0]);
        let cb = scalegnn::graph::block_bounds(store.n, parts[1]);
        let mut shards = Vec::with_capacity(parts[0] * parts[1]);
        for i in 0..parts[0] {
            for j in 0..parts[1] {
                shards.push(scalegnn::graph::extract_shard_from(
                    &store,
                    rb[i],
                    rb[i + 1],
                    cb[j],
                    cb[j + 1],
                ));
            }
        }
        (store.n, batch, shards)
    } else {
        let data = datasets::load(&dataset).ok_or_else(|| anyhow!("unknown dataset"))?;
        let spec = datasets::spec(&dataset).unwrap();
        (data.n, spec.batch, partition_2d(&data.adj, parts[0], parts[1]))
    };
    let sampler = UniformVertexSampler::new(n, batch, 42);
    println!(
        "Algorithm 2 on {}: n={} batch={} shard grid {}x{}",
        source, n, batch, parts[0], parts[1]
    );
    let mut builders: Vec<_> = shards
        .into_iter()
        .map(|sh| DistributedSubgraphBuilder::new(sampler.clone(), sh))
        .collect();
    let t0 = std::time::Instant::now();
    let mut nnz = 0usize;
    for step in 0..steps {
        for b in builders.iter_mut() {
            nnz += b.build(step).adj.nnz();
        }
    }
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "{} steps x {} ranks: {} per rank-step, induced nnz/step {:.0} (p={:.2e})",
        steps,
        builders.len(),
        fmt_time(dt / (steps as f64 * builders.len() as f64)),
        nnz as f64 / steps as f64,
        sampler.inclusion_prob(),
    );
    Ok(())
}

fn machine_of(args: &Args) -> Result<sim::Machine> {
    sim::by_name(&args.str_or("machine", "perlmutter"))
        .ok_or_else(|| anyhow!("unknown machine"))
}

fn cmd_scaling(args: &Args) -> Result<()> {
    let dataset = args.str_or("dataset", "papers100m_sim");
    let m = machine_of(args)?;
    let spec = datasets::spec(&dataset).ok_or_else(|| anyhow!("unknown dataset"))?;
    let w = sim::Workload::from_spec(&spec, 128.0, 3.0);
    let opts = sim::OptFlags { overlap: overlap_of(args)?, ..sim::OptFlags::ALL };
    let hide = hide_frac_of(args)?;
    let (x, y, z) = sim::base_grid_for(&dataset);
    let base = x * y * z;
    println!(
        "strong scaling: {dataset} on {} (3D grid {x}x{y}x{z}, growing Gd, overlap={} hide={hide:.2})",
        m.name,
        if opts.overlap { "on" } else { "off" }
    );
    println!("{:>8} {:>6} {:>14} {:>9}", "devices", "Gd", "epoch (ms)", "speedup");
    let mut first = None;
    for gd in [1usize, 2, 4, 8, 16, 32, 64] {
        let gpus = base * gd;
        if gpus > 2048 {
            break;
        }
        let t = sim::scalegnn_epoch_with(&w, &m, Grid4D::new(gd, x, y, z), opts, hide).total();
        let f = *first.get_or_insert(t);
        println!("{:>8} {:>6} {:>14.1} {:>8.1}x", gpus, gd, t * 1e3, f / t);
    }
    Ok(())
}

fn cmd_breakdown(args: &Args) -> Result<()> {
    let dataset = args.str_or("dataset", "products14m_sim");
    let m = machine_of(args)?;
    let spec = datasets::spec(&dataset).ok_or_else(|| anyhow!("unknown dataset"))?;
    let w = sim::Workload::from_spec(&spec, 128.0, 3.0);
    let opts = sim::OptFlags { overlap: overlap_of(args)?, ..sim::OptFlags::ALL };
    let hide = hide_frac_of(args)?;
    let (x, y, z) = sim::base_grid_for(&dataset);
    println!(
        "epoch breakdown: {dataset} on {} ({x}x{y}x{z} per group, overlap={} hide={hide:.2})",
        m.name,
        if opts.overlap { "on" } else { "off" }
    );
    println!(
        "{:>4} {:>10} {:>10} {:>10} {:>10} {:>10} {:>10} {:>10}",
        "Gd", "total ms", "sampling", "spmm+gemm", "elemwise", "tp_comm", "dp_comm", "other"
    );
    for gd in [1usize, 2, 4, 8, 16, 32] {
        let b = sim::scalegnn_epoch_with(&w, &m, Grid4D::new(gd, x, y, z), opts, hide);
        println!(
            "{:>4} {:>10.1} {:>10.1} {:>10.1} {:>10.1} {:>10.1} {:>10.1} {:>10.1}",
            gd,
            b.total() * 1e3,
            b.sampling * 1e3,
            (b.spmm + b.gemm) * 1e3,
            b.elementwise * 1e3,
            b.tp_comm * 1e3,
            b.dp_comm * 1e3,
            b.other * 1e3
        );
    }
    Ok(())
}

fn cmd_e2e(args: &Args) -> Result<()> {
    let dataset = args.str_or("dataset", "products_sim");
    let m = machine_of(args)?;
    let spec = datasets::spec(&dataset).ok_or_else(|| anyhow!("unknown dataset"))?;
    let w = sim::Workload::from_spec(&spec, 128.0, 3.0);
    let opts = sim::OptFlags { overlap: overlap_of(args)?, ..sim::OptFlags::ALL };
    let hide = hide_frac_of(args)?;
    println!(
        "end-to-end time-to-accuracy: {dataset} on {} (log-scale in the paper)",
        m.name
    );
    print!("{:>8}", "devices");
    for fw in sim::Framework::all() {
        print!(" {:>12}", fw.name());
    }
    println!();
    for gpus in [4usize, 8, 16, 32, 64] {
        print!("{:>8}", gpus);
        for fw in sim::Framework::all() {
            let t = if fw == sim::Framework::ScaleGnn {
                match sim::grid_for(&dataset, gpus) {
                    Some(g) => {
                        sim::scalegnn_epoch_with(&w, &m, g, opts, hide).total()
                            * sim::epochs_to_target(fw, &dataset, gpus)
                    }
                    None => f64::NAN,
                }
            } else if m.name != "Perlmutter" && !fw.supports_rocm() {
                f64::NAN
            } else {
                sim::baseline_epoch(fw, &w, &m, gpus) * sim::epochs_to_target(fw, &dataset, gpus)
            };
            if t.is_nan() {
                print!(" {:>12}", "-");
            } else {
                print!(" {:>11.2}s", t);
            }
        }
        println!();
    }
    Ok(())
}
