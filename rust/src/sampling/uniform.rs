//! Communication-free uniform vertex sampling (paper §III-D, Algorithm 1).
//!
//! Every rank derives the *same* sorted sample `S` from the shared
//! `(seed, step)` pair — no inter-rank communication — then extracts its
//! local portion of the induced subgraph (Algorithm 2, `distributed.rs`).
//!
//! # The sampling fast path
//!
//! Mini-batch induction is the last stage of the per-step pipeline, and
//! with the compute and communication paths parallelized (PR 1/PR 3) it is
//! the one §V-A overlap can only hide, not shrink.  The fast path applies
//! the same discipline as the kernels: **bitwise-identical output, zero
//! steady-state allocations, row-parallel execution.**
//!
//! * **Sort-free assembly** — sampled rows are visited in ascending
//!   compact-row order and each CSR row stores its columns sorted, so the
//!   induced `(row, col, weight)` stream is emitted already CSR-ordered.
//!   The triple list + `Csr::from_triples` `O(E log E)` sort of the old
//!   path is pure waste; the fast path appends straight into the output
//!   CSR.  Induction cannot produce duplicate coordinates (each source
//!   row is visited once and a sorted row holds each column once), so the
//!   duplicate-sum pass is dead weight too.
//! * **Workspace reuse** — [`InduceWorkspace`] owns every scratch buffer
//!   (RNG overlay, sample, per-chunk segments, transpose cursor) and the
//!   caller owns the output [`MiniBatch`]; after warmup a step allocates
//!   nothing (asserted by `tests/alloc_batch.rs`).
//! * **Strategy-switching intersection** — per row, the sorted
//!   row-columns × sorted-sample intersection runs as a linear merge when
//!   the sizes are comparable and as a binary-search probe of the larger
//!   side when they are lopsided (`deg(v) ≫ B` or `B ≫ deg(v)`).  All
//!   strategies emit the identical pair stream in the identical order
//!   with the identical float ops, so the switch is bitwise-invisible.
//! * **Row-range parallelism** — chunks of sample rows are induced
//!   concurrently into per-chunk segments (`tensor::pool::par_chunks`)
//!   and concatenated in chunk order; induction is row-local, so the
//!   concatenated stream is bitwise identical for any thread count.
//!
//! The pre-fast-path implementation is kept as
//! [`induce_rescaled_reference`] — the oracle `tests/induction.rs` and the
//! `BENCH_sampling.json` sweep compare against.

use crate::graph::{Csr, GraphAccess};
use crate::util::rng::{Rng, SampleScratch};

/// Sampler state shared (by value — it is tiny) by every rank of a DP group.
#[derive(Clone, Debug)]
pub struct UniformVertexSampler {
    /// Number of vertices in the full graph.
    pub n: usize,
    /// Mini-batch size `B`.
    pub batch: usize,
    /// Shared sampling seed (identical across the ranks of a DP group).
    pub seed: u64,
}

impl UniformVertexSampler {
    /// Build a sampler drawing `batch` of `n` vertices per step.
    pub fn new(n: usize, batch: usize, seed: u64) -> Self {
        assert!(batch <= n, "batch {batch} > n {n}");
        UniformVertexSampler { n, batch, seed }
    }

    /// Eq. 20: `S ~ Uniform(C(V, B))`, sorted.  Deterministic in
    /// `(seed, step)` — the communication-free contract.
    pub fn sample(&self, step: u64) -> Vec<u32> {
        let mut rng = Rng::for_step(self.seed, step);
        rng.sample_k_of_n_sorted(self.batch, self.n)
    }

    /// Workspace variant of [`UniformVertexSampler::sample`]: identical
    /// output for the same `(seed, step)`, zero steady-state allocations
    /// (the permutation overlay lives in `scratch`, the sample in `out`).
    pub fn sample_into(&self, step: u64, scratch: &mut SampleScratch, out: &mut Vec<u32>) {
        let mut rng = Rng::for_step(self.seed, step);
        rng.sample_k_of_n_sorted_into(self.batch, self.n, scratch, out);
    }

    /// Eq. 23: conditional inclusion probability of a *neighbor* given the
    /// target is in the sample, `(B - 1) / (n - 1)`.
    ///
    /// Degenerate sizes are handled explicitly:
    /// * `n == 1` — Eq. 23 is `0/0`; the single vertex is always sampled
    ///   and has no neighbors to condition on, so `1.0` is returned (any
    ///   finite value is unused, and `1.0` keeps a hypothetical `1/p`
    ///   rescale a no-op) instead of the `NaN` this used to produce.
    /// * `batch == 1` — the numerator is zero and `p = 0.0` is *correct*
    ///   (no second vertex is ever co-sampled) and safe: induction divides
    ///   by `p` only for an off-diagonal edge between two distinct sampled
    ///   vertices, which cannot exist in a one-vertex sample (regression
    ///   test `batch_of_one_induces_finite_weights`).
    pub fn inclusion_prob(&self) -> f32 {
        if self.n <= 1 {
            return 1.0;
        }
        (self.batch as f32 - 1.0) / (self.n as f32 - 1.0)
    }
}

/// A fully assembled mini-batch (single-rank / per-DP-group view).
pub struct MiniBatch {
    /// sorted sampled vertex ids (global)
    pub vertices: Vec<u32>,
    /// induced, rescaled adjacency in the compact [0,B) namespace
    pub adj: Csr,
    /// its transpose (for backward SpMM, Eq. 17); left structurally empty
    /// when induction is asked to skip it (the padded-edge-list trainer
    /// path never reads it)
    pub adj_t: Csr,
}

impl Default for MiniBatch {
    /// An empty shell for the workspace constructors to fill; buffers grow
    /// on first use and are reused afterwards.
    fn default() -> MiniBatch {
        MiniBatch { vertices: Vec::new(), adj: Csr::empty(0, 0), adj_t: Csr::empty(0, 0) }
    }
}

/// One row-range's induction output plus its private row-read scratch:
/// what each parallel worker fills.  Segments concatenate in chunk order
/// into the output CSR.
#[derive(Default)]
struct InduceSeg {
    /// nnz of each induced row in this chunk's range, in row order
    row_nnz: Vec<usize>,
    /// compact column ids, concatenated across the chunk's rows
    indices: Vec<u32>,
    /// rescaled weights, aligned with `indices`
    values: Vec<f32>,
    /// row-read scratch of the [`GraphAccess::with_row`] default impl
    rcols: Vec<u32>,
    /// row-read scratch (values half)
    rvals: Vec<f32>,
}

/// Every scratch buffer mini-batch construction needs, owned by the call
/// site and reused across steps so the steady-state `make()` allocates
/// nothing.  One workspace serves one construction stream (a trainer
/// worker, a prefetch thread, a per-rank builder); it is `Send` but not
/// shared.
pub struct InduceWorkspace {
    /// per-chunk segments of the parallel induction
    segs: Vec<InduceSeg>,
    /// transpose column-cursor scratch
    cursor: Vec<usize>,
    /// RNG permutation overlay of [`UniformVertexSampler::sample_into`]
    pub scratch: SampleScratch,
    /// the current step's sorted sample (filled by
    /// [`sample_and_induce_into`])
    pub sample: Vec<u32>,
}

impl InduceWorkspace {
    /// An empty workspace; buffers grow on first use.
    pub fn new() -> InduceWorkspace {
        InduceWorkspace {
            segs: Vec::new(),
            cursor: Vec::new(),
            scratch: SampleScratch::default(),
            sample: Vec::new(),
        }
    }
}

impl Default for InduceWorkspace {
    fn default() -> InduceWorkspace {
        InduceWorkspace::new()
    }
}

/// Size ratio beyond which the per-row intersection switches from the
/// linear merge to a binary-search probe of the larger side.
const GALLOP_RATIO: usize = 16;

/// Intersect one sampled row with the sorted sample and rescale
/// off-diagonal weights by `1/p` (Eq. 24), appending compact columns and
/// weights for the row.  Three strategies — probe-the-sample for short
/// rows, probe-the-row for small samples, linear merge otherwise — that
/// emit the identical `(compact col, weight)` stream: the same matches in
/// the same ascending order with the same float ops, so the switch is
/// exact, not approximate.
#[inline]
fn induce_row_into(
    s: &[u32],
    v: u32,
    cs: &[u32],
    vs: &[f32],
    p: f32,
    cols: &mut Vec<u32>,
    vals: &mut Vec<f32>,
) {
    let b = s.len();
    let deg = cs.len();
    if deg.saturating_mul(GALLOP_RATIO) < b {
        // short row, large sample: binary-search each column in `s`
        for (&c, &w) in cs.iter().zip(vs) {
            if let Ok(ci) = s.binary_search(&c) {
                cols.push(ci as u32);
                vals.push(if c == v { w } else { w / p });
            }
        }
    } else if b.saturating_mul(GALLOP_RATIO) < deg {
        // long row, small sample: binary-search each sampled id in the row
        for (ci, &c) in s.iter().enumerate() {
            if let Ok(k) = cs.binary_search(&c) {
                let w = vs[k];
                cols.push(ci as u32);
                vals.push(if c == v { w } else { w / p });
            }
        }
    } else {
        // comparable sizes: linear merge (the reference strategy)
        let mut ci = 0usize;
        for (&c, &w) in cs.iter().zip(vs) {
            while ci < b && s[ci] < c {
                ci += 1;
            }
            if ci < b && s[ci] == c {
                cols.push(ci as u32);
                vals.push(if c == v { w } else { w / p });
            }
        }
    }
}

/// Induce sample rows `[r0, r1)` into one segment.  Row-local: the output
/// depends only on the graph, the sample and the row range, never on the
/// chunking — the property that makes chunk-order concatenation bitwise
/// deterministic.
fn induce_chunk<G: GraphAccess + ?Sized>(
    a: &G,
    s: &[u32],
    p: f32,
    r0: usize,
    r1: usize,
    seg: &mut InduceSeg,
) {
    let InduceSeg { row_nnz, indices, values, rcols, rvals } = seg;
    row_nnz.clear();
    indices.clear();
    values.clear();
    for si in r0..r1 {
        let v = s[si];
        let before = indices.len();
        a.with_row(v as usize, rcols, rvals, &mut |cs, vs| {
            induce_row_into(s, v, cs, vs, p, indices, values);
        });
        row_nnz.push(indices.len() - before);
    }
}

/// The shared body of the workspace fast path, over split-borrowed
/// workspace parts (so the sample may live in the same workspace).
fn induce_into_parts<G: GraphAccess + ?Sized>(
    a: &G,
    s: &[u32],
    p: f32,
    transpose: bool,
    threads: usize,
    segs: &mut Vec<InduceSeg>,
    cursor: &mut Vec<usize>,
    out: &mut MiniBatch,
) {
    let b = s.len();
    let nseg = threads.max(1).min(b.max(1));
    if segs.len() < nseg {
        segs.resize_with(nseg, InduceSeg::default);
    }
    // rough per-row cost estimate; small batches run inline (identical
    // result either way — induction is row-local)
    let work = b.saturating_mul(512);
    let used = crate::tensor::pool::par_chunks(&mut segs[..nseg], b, work, |_, r0, r1, seg| {
        induce_chunk(a, s, p, r0, r1, seg)
    });

    out.vertices.clear();
    out.vertices.extend_from_slice(s);
    let adj = &mut out.adj;
    adj.rows = b;
    adj.cols = b;
    adj.indptr.clear();
    adj.indptr.push(0);
    adj.indices.clear();
    adj.values.clear();
    let mut nnz = 0usize;
    for seg in &segs[..used] {
        for &rn in &seg.row_nnz {
            nnz += rn;
            adj.indptr.push(nnz);
        }
        adj.indices.extend_from_slice(&seg.indices);
        adj.values.extend_from_slice(&seg.values);
    }
    debug_assert_eq!(adj.indptr.len(), b + 1);
    debug_assert_eq!(adj.indices.len(), nnz);

    if transpose {
        adj.transpose_into(&mut out.adj_t, cursor);
    } else {
        out.adj_t.rows = b;
        out.adj_t.cols = b;
        out.adj_t.indptr.clear();
        out.adj_t.indptr.resize(b + 1, 0);
        out.adj_t.indices.clear();
        out.adj_t.values.clear();
    }
}

/// Workspace fast path of [`induce_rescaled`]: induce the subgraph on
/// sorted `s` (off-diagonal weights rescaled by `1/p`, Eq. 24) into
/// `out`, reusing every buffer of `ws` and `out`.  `transpose` skips the
/// `adj_t` build when the caller never reads it (the padded-edge-list
/// trainer path); `adj_t` is then left structurally empty.  Output is
/// byte-identical to [`induce_rescaled_reference`] — asserted across edge
/// cases and thread counts by `tests/induction.rs`.
pub fn induce_rescaled_into<G: GraphAccess + ?Sized>(
    a: &G,
    s: &[u32],
    p: f32,
    transpose: bool,
    ws: &mut InduceWorkspace,
    out: &mut MiniBatch,
) {
    induce_rescaled_into_threads(a, s, p, transpose, crate::tensor::pool::num_threads(), ws, out)
}

/// [`induce_rescaled_into`] with an explicit thread count (1 = serial
/// reference) — what the bitwise-equality tests and the bench sweep use.
pub fn induce_rescaled_into_threads<G: GraphAccess + ?Sized>(
    a: &G,
    s: &[u32],
    p: f32,
    transpose: bool,
    threads: usize,
    ws: &mut InduceWorkspace,
    out: &mut MiniBatch,
) {
    induce_into_parts(a, s, p, transpose, threads, &mut ws.segs, &mut ws.cursor, out)
}

/// Algorithm 1 + induction for `step`, entirely inside the workspace: the
/// sample is drawn into `ws.sample` (zero-allocation overlay) and the
/// induced mini-batch lands in `out`.  The one-call hot path of
/// `trainer::batch`, the OOC prefetcher and the microbench sweep.
pub fn sample_and_induce_into<G: GraphAccess + ?Sized>(
    a: &G,
    sampler: &UniformVertexSampler,
    step: u64,
    transpose: bool,
    ws: &mut InduceWorkspace,
    out: &mut MiniBatch,
) {
    sampler.sample_into(step, &mut ws.scratch, &mut ws.sample);
    let p = sampler.inclusion_prob();
    // split borrows: the sample is read while segs/cursor are written
    let InduceWorkspace { segs, cursor, sample, .. } = ws;
    let threads = crate::tensor::pool::num_threads();
    induce_into_parts(a, sample, p, transpose, threads, segs, cursor, out)
}

/// Induce the subgraph on sorted `s` and rescale off-diagonal entries by
/// `1/p` (Eq. 24).  Single-rank convenience wrapper over the workspace
/// fast path ([`induce_rescaled_into`]); the oracle the distributed
/// builder is tested against.
pub fn induce_rescaled(a: &Csr, s: &[u32], p: f32) -> MiniBatch {
    induce_rescaled_from(a, s, p)
}

/// As [`induce_rescaled`], but generic over [`GraphAccess`] so the same
/// mini-batch construction serves out-of-core graphs.  For the same
/// stored bytes, sample and probability the output is bitwise identical
/// regardless of where the graph lives — the per-row intersection is the
/// very same code.
pub fn induce_rescaled_from<G: GraphAccess + ?Sized>(a: &G, s: &[u32], p: f32) -> MiniBatch {
    let mut ws = InduceWorkspace::new();
    let mut out = MiniBatch::default();
    induce_rescaled_into(a, s, p, true, &mut ws, &mut out);
    out
}

/// The row merge of the pre-fast-path implementation: intersect the row's
/// (sorted) columns with the (sorted) sample by linear merge and push
/// `(row, col, weight)` triples.
#[inline]
fn induce_row_reference(
    s: &[u32],
    si: usize,
    v: u32,
    cs: &[u32],
    vs: &[f32],
    p: f32,
    triples: &mut Vec<(u32, u32, f32)>,
) {
    let b = s.len();
    let mut ci = 0usize;
    for (&c, &w) in cs.iter().zip(vs) {
        // advance ci while s[ci] < c
        while ci < b && s[ci] < c {
            ci += 1;
        }
        if ci < b && s[ci] == c {
            let w = if c == v { w } else { w / p };
            triples.push((si as u32, ci as u32, w));
        }
    }
}

/// The pre-fast-path induction, kept verbatim as the bitwise oracle:
/// triple list -> sorting [`Csr::from_triples`] -> allocating transpose,
/// single-threaded.  `tests/induction.rs` asserts the fast path matches
/// it byte-for-byte and the `BENCH_sampling.json` sweep measures the
/// speedup against it.
pub fn induce_rescaled_reference<G: GraphAccess + ?Sized>(a: &G, s: &[u32], p: f32) -> MiniBatch {
    let mut triples = Vec::new();
    let (mut rcols, mut rvals) = (Vec::new(), Vec::new());
    for (si, &v) in s.iter().enumerate() {
        a.read_row(v as usize, &mut rcols, &mut rvals);
        induce_row_reference(s, si, v, &rcols, &rvals, p, &mut triples);
    }
    let b = s.len();
    let adj = Csr::from_triples(b, b, triples);
    let adj_t = adj.transpose();
    MiniBatch { vertices: s.to_vec(), adj, adj_t }
}

/// Dense-ified `B x B` adjacency (row-major) for the PJRT train-step
/// artifact, written into a caller-provided buffer (zero-alloc hot path).
pub fn densify_into(adj: &Csr, out: &mut [f32]) {
    let b = adj.rows;
    assert_eq!(out.len(), b * b);
    out.fill(0.0);
    for r in 0..b {
        let (cs, vs) = adj.row(r);
        let row = &mut out[r * b..(r + 1) * b];
        for (&c, &v) in cs.iter().zip(vs) {
            row[c as usize] = v;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generate::rmat;

    #[test]
    fn sample_is_deterministic_and_sorted() {
        let s = UniformVertexSampler::new(1000, 64, 42);
        let a = s.sample(7);
        let b = s.sample(7);
        assert_eq!(a, b);
        assert!(a.windows(2).all(|w| w[0] < w[1]));
        assert_ne!(s.sample(8), a);
    }

    #[test]
    fn sample_into_matches_sample() {
        let s = UniformVertexSampler::new(777, 50, 3);
        let mut scratch = SampleScratch::default();
        let mut out = Vec::new();
        for step in 0..6u64 {
            s.sample_into(step, &mut scratch, &mut out);
            assert_eq!(out, s.sample(step), "step {step}");
        }
    }

    #[test]
    fn inclusion_prob_matches_eq23() {
        let s = UniformVertexSampler::new(101, 11, 0);
        assert!((s.inclusion_prob() - 0.1).abs() < 1e-6);
    }

    #[test]
    fn inclusion_prob_is_finite_for_degenerate_sizes() {
        // n == 1 used to evaluate 0/0 = NaN
        let s = UniformVertexSampler::new(1, 1, 0);
        assert_eq!(s.inclusion_prob(), 1.0);
        // batch == 1 legitimately yields p = 0 (never divided by)
        let s = UniformVertexSampler::new(10, 1, 0);
        assert_eq!(s.inclusion_prob(), 0.0);
        // full batch: every off-diagonal neighbor is certainly included
        let s = UniformVertexSampler::new(10, 10, 0);
        assert_eq!(s.inclusion_prob(), 1.0);
    }

    #[test]
    fn batch_of_one_induces_finite_weights() {
        let g = rmat(5, 6, 4).gcn_normalize();
        let sampler = UniformVertexSampler::new(g.rows, 1, 9);
        for step in 0..8u64 {
            let s = sampler.sample(step);
            let mb = induce_rescaled(&g, &s, sampler.inclusion_prob());
            // only a self loop can survive; its weight is untouched
            assert!(mb.adj.nnz() <= 1);
            assert!(mb.adj.values.iter().all(|v| v.is_finite()));
            let want = induce_rescaled_reference(&g, &s, sampler.inclusion_prob());
            assert_eq!(mb.adj.values, want.adj.values);
        }
    }

    #[test]
    fn induced_subgraph_contains_exactly_the_sampled_edges() {
        let g = rmat(7, 8, 5).gcn_normalize();
        let sampler = UniformVertexSampler::new(g.rows, 40, 1);
        let s = sampler.sample(0);
        let mb = induce_rescaled(&g, &s, sampler.inclusion_prob());
        assert_eq!(mb.adj.rows, 40);
        // every kept edge exists in g between the mapped endpoints
        for r in 0..40 {
            let (cs, _) = mb.adj.row(r);
            for &c in cs {
                assert!(g.has_edge(s[r] as usize, s[c as usize]));
            }
        }
        // and every g-edge with both endpoints sampled is kept
        let mut count = 0;
        for (i, &v) in s.iter().enumerate() {
            let (cs, _) = g.row(v as usize);
            for &c in cs {
                if s.binary_search(&c).is_ok() {
                    count += 1;
                    let j = s.binary_search(&c).unwrap();
                    assert!(mb.adj.has_edge(i, j as u32));
                }
            }
        }
        assert_eq!(count, mb.adj.nnz());
    }

    #[test]
    fn rescaling_leaves_self_loops_untouched() {
        let g = rmat(6, 6, 2).gcn_normalize();
        let sampler = UniformVertexSampler::new(g.rows, 24, 3);
        let s = sampler.sample(1);
        let p = sampler.inclusion_prob();
        let mb = induce_rescaled(&g, &s, p);
        for (i, &v) in s.iter().enumerate() {
            let gd = g.to_dense();
            let (cs, vs) = mb.adj.row(i);
            for (&c, &w) in cs.iter().zip(vs) {
                let orig = gd.at(v as usize, s[c as usize] as usize);
                if c as usize == i {
                    assert!((w - orig).abs() < 1e-6, "self loop rescaled");
                } else {
                    assert!((w - orig / p).abs() < 1e-5, "off-diagonal not 1/p");
                }
            }
        }
    }

    #[test]
    fn rescaled_aggregation_is_unbiased() {
        // Eq. 25: E_S[sum_{u in N(v) cap S} a~_{vu} x_u | v in S] = h_v.
        // Monte-Carlo over many samples, scalar features x_u = u + 1.
        let g = rmat(6, 8, 9).gcn_normalize();
        let n = g.rows;
        let bsize = 24;
        let sampler = UniformVertexSampler::new(n, bsize, 77);
        let p = sampler.inclusion_prob();
        let x: Vec<f64> = (0..n).map(|u| (u + 1) as f64).collect();

        // pick a target vertex with decent degree
        let v = (0..n).max_by_key(|&r| g.row_nnz(r)).unwrap();
        let full: f64 = {
            let (cs, vs) = g.row(v);
            cs.iter().zip(vs).map(|(&c, &w)| w as f64 * x[c as usize]).sum()
        };

        let trials = 4000u64;
        let mut acc = 0.0f64;
        let mut hits = 0u64;
        for t in 0..trials {
            let s = sampler.sample(t);
            if let Ok(i) = s.binary_search(&(v as u32)) {
                hits += 1;
                let mb = induce_rescaled(&g, &s, p);
                let (cs, vs) = mb.adj.row(i);
                acc += cs
                    .iter()
                    .zip(vs)
                    .map(|(&c, &w)| w as f64 * x[s[c as usize] as usize])
                    .sum::<f64>();
            }
        }
        let est = acc / hits as f64;
        let rel = (est - full).abs() / full.abs();
        assert!(rel < 0.05, "estimator {est} vs full {full} (rel {rel})");
    }

    #[test]
    fn fast_path_matches_reference_bitwise() {
        let g = rmat(8, 10, 21).gcn_normalize();
        let sampler = UniformVertexSampler::new(g.rows, 96, 5);
        let mut ws = InduceWorkspace::new();
        let mut out = MiniBatch::default();
        for step in 0..6u64 {
            let s = sampler.sample(step);
            let p = sampler.inclusion_prob();
            let want = induce_rescaled_reference(&g, &s, p);
            induce_rescaled_into(&g, &s, p, true, &mut ws, &mut out);
            assert_eq!(out.vertices, want.vertices, "step {step}");
            assert_eq!(out.adj.indptr, want.adj.indptr);
            assert_eq!(out.adj.indices, want.adj.indices);
            assert_eq!(out.adj.values, want.adj.values);
            assert_eq!(out.adj_t.indptr, want.adj_t.indptr);
            assert_eq!(out.adj_t.indices, want.adj_t.indices);
            assert_eq!(out.adj_t.values, want.adj_t.values);
        }
    }

    #[test]
    fn skipped_transpose_leaves_adj_t_structurally_empty() {
        let g = rmat(6, 6, 1).gcn_normalize();
        let sampler = UniformVertexSampler::new(g.rows, 16, 2);
        let s = sampler.sample(0);
        let mut ws = InduceWorkspace::new();
        let mut out = MiniBatch::default();
        induce_rescaled_into(&g, &s, sampler.inclusion_prob(), false, &mut ws, &mut out);
        assert_eq!(out.adj_t.nnz(), 0);
        assert_eq!(out.adj_t.indptr, vec![0; 17]);
        assert!(out.adj.nnz() > 0);
    }

    #[test]
    fn densify_matches_to_dense() {
        let g = rmat(5, 4, 2).gcn_normalize();
        let sampler = UniformVertexSampler::new(g.rows, 16, 5);
        let mb = induce_rescaled(&g, &sampler.sample(0), sampler.inclusion_prob());
        let mut buf = vec![0.0f32; 16 * 16];
        densify_into(&mb.adj, &mut buf);
        assert_eq!(buf, mb.adj.to_dense().data);
    }

    #[test]
    fn transpose_is_consistent() {
        let g = rmat(6, 4, 8).gcn_normalize();
        let sampler = UniformVertexSampler::new(g.rows, 32, 9);
        let mb = induce_rescaled(&g, &sampler.sample(4), sampler.inclusion_prob());
        assert!(mb
            .adj_t
            .to_dense()
            .allclose(&mb.adj.to_dense().transpose(), 1e-6, 0.0));
    }
}
