//! Communication-free uniform vertex sampling (paper §III-D, Algorithm 1).
//!
//! Every rank derives the *same* sorted sample `S` from the shared
//! `(seed, step)` pair — no inter-rank communication — then extracts its
//! local portion of the induced subgraph (Algorithm 2, `distributed.rs`).

use crate::graph::{Csr, GraphAccess};
use crate::util::rng::Rng;

/// Sampler state shared (by value — it is tiny) by every rank of a DP group.
#[derive(Clone, Debug)]
pub struct UniformVertexSampler {
    /// Number of vertices in the full graph.
    pub n: usize,
    /// Mini-batch size `B`.
    pub batch: usize,
    /// Shared sampling seed (identical across the ranks of a DP group).
    pub seed: u64,
}

impl UniformVertexSampler {
    /// Build a sampler drawing `batch` of `n` vertices per step.
    pub fn new(n: usize, batch: usize, seed: u64) -> Self {
        assert!(batch <= n, "batch {batch} > n {n}");
        UniformVertexSampler { n, batch, seed }
    }

    /// Eq. 20: `S ~ Uniform(C(V, B))`, sorted.  Deterministic in
    /// `(seed, step)` — the communication-free contract.
    pub fn sample(&self, step: u64) -> Vec<u32> {
        let mut rng = Rng::for_step(self.seed, step);
        rng.sample_k_of_n_sorted(self.batch, self.n)
    }

    /// Eq. 23: conditional inclusion probability of a *neighbor* given the
    /// target is in the sample.
    pub fn inclusion_prob(&self) -> f32 {
        (self.batch as f32 - 1.0) / (self.n as f32 - 1.0)
    }
}

/// A fully assembled mini-batch (single-rank / per-DP-group view).
pub struct MiniBatch {
    /// sorted sampled vertex ids (global)
    pub vertices: Vec<u32>,
    /// induced, rescaled adjacency in the compact [0,B) namespace
    pub adj: Csr,
    /// its transpose (for backward SpMM, Eq. 17)
    pub adj_t: Csr,
}

/// Merge one sampled row into the induced triple list: intersect the row's
/// (sorted) columns with the (sorted) sample and rescale off-diagonal
/// weights by `1/p` (Eq. 24).  Shared by the zero-copy in-memory path and
/// the scratch-buffer out-of-core path, so the two cannot drift.
#[inline]
fn induce_row(
    s: &[u32],
    si: usize,
    v: u32,
    cs: &[u32],
    vs: &[f32],
    p: f32,
    triples: &mut Vec<(u32, u32, f32)>,
) {
    let b = s.len();
    let mut ci = 0usize;
    for (&c, &w) in cs.iter().zip(vs) {
        // advance ci while s[ci] < c
        while ci < b && s[ci] < c {
            ci += 1;
        }
        if ci < b && s[ci] == c {
            let w = if c == v { w } else { w / p };
            triples.push((si as u32, ci as u32, w));
        }
    }
}

fn assemble_minibatch(s: &[u32], triples: Vec<(u32, u32, f32)>) -> MiniBatch {
    let b = s.len();
    let adj = Csr::from_triples(b, b, triples);
    let adj_t = adj.transpose();
    MiniBatch { vertices: s.to_vec(), adj, adj_t }
}

/// Induce the subgraph on sorted `s` and rescale off-diagonal entries by
/// `1/p` (Eq. 24).  Single-rank reference used by the per-group trainer and
/// as the oracle the distributed builder is tested against.  Rows are
/// borrowed zero-copy; the out-of-core variant is [`induce_rescaled_from`].
pub fn induce_rescaled(a: &Csr, s: &[u32], p: f32) -> MiniBatch {
    let mut triples = Vec::new();
    for (si, &v) in s.iter().enumerate() {
        let (cs, vs) = a.row(v as usize);
        induce_row(s, si, v, cs, vs, p, &mut triples);
    }
    assemble_minibatch(s, triples)
}

/// As [`induce_rescaled`], but generic over [`GraphAccess`] so the same
/// mini-batch construction serves out-of-core graphs.  Rows are read into
/// reused scratch buffers; the per-row merge (`induce_row`) is the very
/// function the in-memory path runs, so for the same stored bytes, sample
/// and probability the output is bitwise identical regardless of where the
/// graph lives.
pub fn induce_rescaled_from<G: GraphAccess + ?Sized>(a: &G, s: &[u32], p: f32) -> MiniBatch {
    let mut triples = Vec::new();
    let (mut rcols, mut rvals) = (Vec::new(), Vec::new());
    for (si, &v) in s.iter().enumerate() {
        a.read_row(v as usize, &mut rcols, &mut rvals);
        induce_row(s, si, v, &rcols, &rvals, p, &mut triples);
    }
    assemble_minibatch(s, triples)
}

/// Dense-ified `B x B` adjacency (row-major) for the PJRT train-step
/// artifact, written into a caller-provided buffer (zero-alloc hot path).
pub fn densify_into(adj: &Csr, out: &mut [f32]) {
    let b = adj.rows;
    assert_eq!(out.len(), b * b);
    out.fill(0.0);
    for r in 0..b {
        let (cs, vs) = adj.row(r);
        let row = &mut out[r * b..(r + 1) * b];
        for (&c, &v) in cs.iter().zip(vs) {
            row[c as usize] = v;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generate::rmat;

    #[test]
    fn sample_is_deterministic_and_sorted() {
        let s = UniformVertexSampler::new(1000, 64, 42);
        let a = s.sample(7);
        let b = s.sample(7);
        assert_eq!(a, b);
        assert!(a.windows(2).all(|w| w[0] < w[1]));
        assert_ne!(s.sample(8), a);
    }

    #[test]
    fn inclusion_prob_matches_eq23() {
        let s = UniformVertexSampler::new(101, 11, 0);
        assert!((s.inclusion_prob() - 0.1).abs() < 1e-6);
    }

    #[test]
    fn induced_subgraph_contains_exactly_the_sampled_edges() {
        let g = rmat(7, 8, 5).gcn_normalize();
        let sampler = UniformVertexSampler::new(g.rows, 40, 1);
        let s = sampler.sample(0);
        let mb = induce_rescaled(&g, &s, sampler.inclusion_prob());
        assert_eq!(mb.adj.rows, 40);
        // every kept edge exists in g between the mapped endpoints
        for r in 0..40 {
            let (cs, _) = mb.adj.row(r);
            for &c in cs {
                assert!(g.has_edge(s[r] as usize, s[c as usize]));
            }
        }
        // and every g-edge with both endpoints sampled is kept
        let mut count = 0;
        for (i, &v) in s.iter().enumerate() {
            let (cs, _) = g.row(v as usize);
            for &c in cs {
                if s.binary_search(&c).is_ok() {
                    count += 1;
                    let j = s.binary_search(&c).unwrap();
                    assert!(mb.adj.has_edge(i, j as u32));
                }
            }
        }
        assert_eq!(count, mb.adj.nnz());
    }

    #[test]
    fn rescaling_leaves_self_loops_untouched() {
        let g = rmat(6, 6, 2).gcn_normalize();
        let sampler = UniformVertexSampler::new(g.rows, 24, 3);
        let s = sampler.sample(1);
        let p = sampler.inclusion_prob();
        let mb = induce_rescaled(&g, &s, p);
        for (i, &v) in s.iter().enumerate() {
            let gd = g.to_dense();
            let (cs, vs) = mb.adj.row(i);
            for (&c, &w) in cs.iter().zip(vs) {
                let orig = gd.at(v as usize, s[c as usize] as usize);
                if c as usize == i {
                    assert!((w - orig).abs() < 1e-6, "self loop rescaled");
                } else {
                    assert!((w - orig / p).abs() < 1e-5, "off-diagonal not 1/p");
                }
            }
        }
    }

    #[test]
    fn rescaled_aggregation_is_unbiased() {
        // Eq. 25: E_S[sum_{u in N(v) cap S} a~_{vu} x_u | v in S] = h_v.
        // Monte-Carlo over many samples, scalar features x_u = u + 1.
        let g = rmat(6, 8, 9).gcn_normalize();
        let n = g.rows;
        let bsize = 24;
        let sampler = UniformVertexSampler::new(n, bsize, 77);
        let p = sampler.inclusion_prob();
        let x: Vec<f64> = (0..n).map(|u| (u + 1) as f64).collect();

        // pick a target vertex with decent degree
        let v = (0..n).max_by_key(|&r| g.row_nnz(r)).unwrap();
        let full: f64 = {
            let (cs, vs) = g.row(v);
            cs.iter().zip(vs).map(|(&c, &w)| w as f64 * x[c as usize]).sum()
        };

        let trials = 4000u64;
        let mut acc = 0.0f64;
        let mut hits = 0u64;
        for t in 0..trials {
            let s = sampler.sample(t);
            if let Ok(i) = s.binary_search(&(v as u32)) {
                hits += 1;
                let mb = induce_rescaled(&g, &s, p);
                let (cs, vs) = mb.adj.row(i);
                acc += cs
                    .iter()
                    .zip(vs)
                    .map(|(&c, &w)| w as f64 * x[s[c as usize] as usize])
                    .sum::<f64>();
            }
        }
        let est = acc / hits as f64;
        let rel = (est - full).abs() / full.abs();
        assert!(rel < 0.05, "estimator {est} vs full {full} (rel {rel})");
    }

    #[test]
    fn densify_matches_to_dense() {
        let g = rmat(5, 4, 2).gcn_normalize();
        let sampler = UniformVertexSampler::new(g.rows, 16, 5);
        let mb = induce_rescaled(&g, &sampler.sample(0), sampler.inclusion_prob());
        let mut buf = vec![0.0f32; 16 * 16];
        densify_into(&mb.adj, &mut buf);
        assert_eq!(buf, mb.adj.to_dense().data);
    }

    #[test]
    fn transpose_is_consistent() {
        let g = rmat(6, 4, 8).gcn_normalize();
        let sampler = UniformVertexSampler::new(g.rows, 32, 9);
        let mb = induce_rescaled(&g, &sampler.sample(4), sampler.inclusion_prob());
        assert!(mb
            .adj_t
            .to_dense()
            .allclose(&mb.adj.to_dense().transpose(), 1e-6, 0.0));
    }
}
