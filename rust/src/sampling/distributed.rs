//! Algorithm 2: distributed subgraph construction, per rank, with zero
//! inter-rank communication.
//!
//! Each rank owns a 2D CSR shard (rows `[R0,R1)`, cols `[C0,C1)` of the
//! global adjacency).  At every step it independently
//!   1. derives the shared sorted sample `S` from `(seed, step)` and locates
//!      its local row/column sub-ranges by binary search,
//!   2. extracts the sampled CSR rows through a prefix-sum flat-index gather,
//!   3. filters columns by membership and remaps survivors to the compact
//!      `[0,B)` namespace via a **step-tagged persistent map** (O(B) updates
//!      per step instead of an O(N) clear),
//!   4. rescales off-diagonal weights by `1/p` (Eq. 24) and assembles the
//!      local CSR block (and, on request, its transpose for Eq. 17).

use crate::graph::{Csr, CsrShard};
use crate::sampling::uniform::UniformVertexSampler;

/// Per-rank output of Algorithm 2: a block of the compact `B x B`
/// mini-batch adjacency.
#[derive(Debug)]
pub struct LocalSubgraph {
    /// the full sorted sample (identical on every rank)
    pub sample: Vec<u32>,
    /// compact row range [row_lo, row_hi): rows of the B x B matrix owned
    /// by this rank (S[row_lo..row_hi] fall in the shard's [R0,R1))
    pub row_lo: usize,
    /// End (exclusive) of the compact row range.
    pub row_hi: usize,
    /// compact column range [col_lo, col_hi)
    pub col_lo: usize,
    /// End (exclusive) of the compact column range.
    pub col_hi: usize,
    /// local rows (row_hi-row_lo) x B CSR with compact column ids in
    /// [col_lo, col_hi)
    pub adj: Csr,
    /// inclusion probability used for rescaling
    pub p: f32,
}

impl LocalSubgraph {
    /// An empty slot for [`DistributedSubgraphBuilder::build_into`] to
    /// fill — what buffer-recycling call sites (the PMM engine's
    /// `SubgraphPrefetcher`) hand back for reuse.
    pub fn empty() -> LocalSubgraph {
        LocalSubgraph {
            sample: Vec::new(),
            row_lo: 0,
            row_hi: 0,
            col_lo: 0,
            col_hi: 0,
            adj: Csr::empty(0, 0),
            p: 0.0,
        }
    }

    /// Number of compact rows owned by this rank.
    pub fn local_rows(&self) -> usize {
        self.row_hi - self.row_lo
    }

    /// Transpose of the local block: (B x local_rows) CSR whose rows are
    /// compact column ids — the backward-SpMM operand (Eq. 17).
    pub fn transpose(&self) -> Csr {
        self.adj.transpose()
    }

    /// Workspace variant of [`LocalSubgraph::transpose`]: reuses `out`'s
    /// buffers with `cursor` as insertion scratch, byte-identical output.
    pub fn transpose_into(&self, out: &mut Csr, cursor: &mut Vec<usize>) {
        self.adj.transpose_into(out, cursor)
    }
}

/// Persistent step-tagged remap (Algorithm 2, line 14).
struct TagMap {
    tag: Vec<u64>,
    compact: Vec<u32>,
    cur: u64,
}

impl TagMap {
    fn new(n: usize) -> TagMap {
        TagMap { tag: vec![0; n], compact: vec![0; n], cur: 0 }
    }

    /// Start a new step: O(|ids|) updates, no O(N) clear.
    fn set_epoch(&mut self, ids: &[u32], compact_base: usize) {
        self.cur += 1;
        for (k, &v) in ids.iter().enumerate() {
            self.tag[v as usize] = self.cur;
            self.compact[v as usize] = (compact_base + k) as u32;
        }
    }

    #[inline]
    fn lookup(&self, v: u32) -> Option<u32> {
        if self.tag[v as usize] == self.cur {
            Some(self.compact[v as usize])
        } else {
            None
        }
    }
}

/// Per-rank builder. Owns scratch buffers so the steady-state hot path does
/// not allocate.
pub struct DistributedSubgraphBuilder {
    /// The shared communication-free sampler (identical on every rank).
    pub sampler: UniformVertexSampler,
    /// This rank's 2D adjacency shard.
    pub shard: CsrShard,
    tags: TagMap,
    // scratch reused across steps
    row_nnz: Vec<usize>,
    prefix: Vec<usize>,
    sample_scratch: crate::util::rng::SampleScratch,
}

impl DistributedSubgraphBuilder {
    /// Builder for one rank: the shared sampler plus the rank's shard.
    pub fn new(sampler: UniformVertexSampler, shard: CsrShard) -> Self {
        let n = sampler.n;
        DistributedSubgraphBuilder {
            sampler,
            shard,
            tags: TagMap::new(n),
            row_nnz: Vec::new(),
            prefix: Vec::new(),
            sample_scratch: crate::util::rng::SampleScratch::default(),
        }
    }

    /// Run Algorithm 2 for `step` (allocating wrapper over
    /// [`DistributedSubgraphBuilder::build_into`]).
    pub fn build(&mut self, step: u64) -> LocalSubgraph {
        let mut out = LocalSubgraph::empty();
        self.build_into(step, &mut out);
        out
    }

    /// Run Algorithm 2 for `step`, reusing `out`'s sample and adjacency
    /// buffers (zero steady-state allocations; content is identical to
    /// [`DistributedSubgraphBuilder::build`]).
    pub fn build_into(&mut self, step: u64, out: &mut LocalSubgraph) {
        let b = self.sampler.batch;
        let p = self.sampler.inclusion_prob();
        // Line 1: shared sample (communication-free), drawn through the
        // reusable overlay straight into the output slot
        self.sampler.sample_into(step, &mut self.sample_scratch, &mut out.sample);
        let sample = &out.sample;

        // Phase 1: binary-search local ranges (lines 3-5)
        let row_lo = sample.partition_point(|&v| (v as usize) < self.shard.r0);
        let row_hi = sample.partition_point(|&v| (v as usize) < self.shard.r1);
        let col_lo = sample.partition_point(|&v| (v as usize) < self.shard.c0);
        let col_hi = sample.partition_point(|&v| (v as usize) < self.shard.c1);
        let s_r = &sample[row_lo..row_hi];
        let s_c = &sample[col_lo..col_hi];

        // Phase 3 prep: tag the sampled columns (O(B) map update, line 14)
        self.tags.set_epoch(s_c, col_lo);

        // Phase 2: vectorized CSR row extraction (lines 6-10):
        // nnz per sampled row -> prefix sum -> flat gather
        self.row_nnz.clear();
        self.row_nnz.extend(
            s_r.iter()
                .map(|&v| self.shard.csr.row_nnz(v as usize - self.shard.r0)),
        );
        self.prefix.clear();
        self.prefix.push(0);
        for &c in &self.row_nnz {
            self.prefix.push(self.prefix.last().unwrap() + c);
        }
        let total = *self.prefix.last().unwrap();

        // Phases 3+4 fused with assembly: columns within each CSR row are
        // sorted and the compact map is monotonic, so the output CSR can be
        // built directly without a sort.  The output buffers are reused
        // (the reserves are no-ops once warm).
        let adj = &mut out.adj;
        adj.indptr.clear();
        adj.indptr.reserve(s_r.len() + 1);
        adj.indices.clear();
        adj.values.clear();
        adj.indices.reserve(total / 4 + 1);
        adj.values.reserve(total / 4 + 1);
        adj.indptr.push(0);
        for (k, &v) in s_r.iter().enumerate() {
            let lr = v as usize - self.shard.r0;
            let (cs, vs) = self.shard.csr.row(lr);
            let gi = (row_lo + k) as u32; // compact row id (global namespace)
            for (&c, &w) in cs.iter().zip(vs) {
                if let Some(j) = self.tags.lookup(c) {
                    // Phase 4: unbiased rescale (Eq. 24) — self loops kept
                    let w = if j == gi { w } else { w / p };
                    adj.indices.push(j);
                    adj.values.push(w);
                }
            }
            adj.indptr.push(adj.indices.len());
        }
        adj.rows = s_r.len();
        adj.cols = b;

        out.row_lo = row_lo;
        out.row_hi = row_hi;
        out.col_lo = col_lo;
        out.col_hi = col_hi;
        out.p = p;
    }
}

/// Assemble the global compact B x B matrix from a full grid of local
/// blocks (test/eval helper — production ranks never do this).
pub fn assemble_global(blocks: &[LocalSubgraph], b: usize) -> Csr {
    let mut triples = Vec::new();
    for blk in blocks {
        for lr in 0..blk.adj.rows {
            let (cs, vs) = blk.adj.row(lr);
            for (&c, &v) in cs.iter().zip(vs) {
                triples.push(((blk.row_lo + lr) as u32, c, v));
            }
        }
    }
    Csr::from_triples(b, b, triples)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generate::rmat;
    use crate::graph::partition_2d;
    use crate::sampling::uniform::induce_rescaled;

    fn setup(pr: usize, pc: usize) -> (Csr, Vec<DistributedSubgraphBuilder>, UniformVertexSampler) {
        let g = rmat(8, 8, 11).gcn_normalize();
        let sampler = UniformVertexSampler::new(g.rows, 48, 99);
        let builders = partition_2d(&g, pr, pc)
            .into_iter()
            .map(|sh| DistributedSubgraphBuilder::new(sampler.clone(), sh))
            .collect();
        (g, builders, sampler)
    }

    #[test]
    fn all_ranks_derive_identical_sample() {
        let (_, mut builders, _) = setup(2, 3);
        let outs: Vec<_> = builders.iter_mut().map(|b| b.build(5)).collect();
        for o in &outs[1..] {
            assert_eq!(o.sample, outs[0].sample);
        }
    }

    #[test]
    fn distributed_blocks_reassemble_to_oracle() {
        for &(pr, pc) in &[(1usize, 1usize), (2, 2), (3, 2), (4, 1), (1, 4)] {
            let (g, mut builders, sampler) = setup(pr, pc);
            for step in [0u64, 3, 17] {
                let blocks: Vec<_> = builders.iter_mut().map(|b| b.build(step)).collect();
                let got = assemble_global(&blocks, sampler.batch);
                let want =
                    induce_rescaled(&g, &sampler.sample(step), sampler.inclusion_prob());
                assert!(
                    got.to_dense().allclose(&want.adj.to_dense(), 1e-6, 0.0),
                    "grid {pr}x{pc} step {step}"
                );
            }
        }
    }

    #[test]
    fn ranges_partition_the_sample() {
        let (_, mut builders, sampler) = setup(2, 2);
        let blocks: Vec<_> = builders.iter_mut().map(|b| b.build(1)).collect();
        // row ranges of the first column of ranks tile [0, B)
        let mut row_cover = vec![0u8; sampler.batch];
        for blk in blocks.iter().filter(|b| b.col_lo == 0) {
            for i in blk.row_lo..blk.row_hi {
                row_cover[i] += 1;
            }
        }
        assert!(row_cover.iter().all(|&c| c == 1), "{row_cover:?}");
    }

    #[test]
    fn tag_map_reuse_matches_fresh_builder() {
        let (g, _, sampler) = setup(1, 1);
        let shard = partition_2d(&g, 1, 1).remove(0);
        let mut reused = DistributedSubgraphBuilder::new(sampler.clone(), shard.clone());
        for step in 0..6u64 {
            let got = reused.build(step);
            let mut fresh = DistributedSubgraphBuilder::new(sampler.clone(), shard.clone());
            let want = fresh.build(step);
            assert_eq!(got.adj.indptr, want.adj.indptr, "step {step}");
            assert_eq!(got.adj.indices, want.adj.indices);
            assert_eq!(got.adj.values, want.adj.values);
        }
    }

    #[test]
    fn build_into_recycled_slot_matches_fresh_build() {
        let (_, mut builders, _) = setup(2, 2);
        let mut slot = LocalSubgraph::empty();
        for b in builders.iter_mut() {
            for step in 0..5u64 {
                b.build_into(step, &mut slot); // slot reused across steps
                let want = b.build(step);
                assert_eq!(slot.sample, want.sample, "step {step}");
                assert_eq!(
                    (slot.row_lo, slot.row_hi, slot.col_lo, slot.col_hi),
                    (want.row_lo, want.row_hi, want.col_lo, want.col_hi)
                );
                assert_eq!((slot.adj.rows, slot.adj.cols), (want.adj.rows, want.adj.cols));
                assert_eq!(slot.adj.indptr, want.adj.indptr);
                assert_eq!(slot.adj.indices, want.adj.indices);
                assert_eq!(slot.adj.values, want.adj.values);
                assert_eq!(slot.p, want.p);
            }
        }
    }

    #[test]
    fn column_filter_keeps_only_local_columns() {
        let (_, mut builders, _) = setup(2, 2);
        for b in builders.iter_mut() {
            let o = b.build(2);
            for lr in 0..o.adj.rows {
                let (cs, _) = o.adj.row(lr);
                for &c in cs {
                    assert!((c as usize) >= o.col_lo && (c as usize) < o.col_hi);
                }
            }
        }
    }

    #[test]
    fn local_transpose_matches_block_transpose() {
        let (_, mut builders, _) = setup(2, 2);
        let o = builders[0].build(3);
        let t = o.transpose();
        assert!(t.to_dense().allclose(&o.adj.to_dense().transpose(), 1e-6, 0.0));
    }
}
