//! Baseline sampling algorithms for the Table I accuracy comparison:
//! GraphSAGE-style node-wise neighbor sampling [8] and GraphSAINT node
//! sampling [12].
//!
//! Both are adapted to the shared fixed-shape execution engine (a `B x B`
//! adjacency + per-vertex loss weights), which is how they differ
//! *algorithmically* from ScaleGNN's uniform vertex sampling while sharing
//! the model, optimizer, and artifacts:
//!
//! * **GraphSAGE** samples target vertices plus up to `fanout` neighbors per
//!   layer; the union (truncated/padded to `B`) forms the batch, aggregation
//!   uses only sampled edges (mean-normalized), and ONLY targets carry loss.
//!   Neighbor truncation at fixed `B` is exactly the neighborhood-explosion
//!   pathology the paper describes (§II-B).
//! * **GraphSAINT (node variant)** samples vertices with probability
//!   proportional to degree, keeps the induced subgraph, and corrects bias
//!   through edge normalization `a_uv / (q_u B)` and loss normalization
//!   `1 / (q_v B)` as in the GraphSAINT estimators.
//!
//! ScaleGNN's sampler is in `uniform.rs`/`distributed.rs`; all three emit
//! the same `SampledBatch` consumed by the trainer.

use crate::graph::{Csr, Dataset};
use crate::util::rng::Rng;

/// A mini-batch in the execution engine's fixed shape.
pub struct SampledBatch {
    /// exactly B vertex ids (padding repeats an arbitrary vertex with zero
    /// loss weight and no edges)
    pub vertices: Vec<u32>,
    /// B x B adjacency in the compact namespace
    pub adj: Csr,
    /// per-slot loss weight (0 for padding / non-targets; GraphSAINT uses
    /// continuous importance weights)
    pub loss_weight: Vec<f32>,
}

/// Strategy selector shared by the trainer and the benches.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SamplerKind {
    /// ScaleGNN's communication-free uniform vertex sampling (Algorithm 1).
    ScaleGnnUniform,
    /// GraphSAGE node-wise neighbor sampling (Table I baseline).
    GraphSage,
    /// GraphSAINT node sampling (Table I baseline).
    GraphSaintNode,
}

impl SamplerKind {
    /// Parse a CLI name (`scalegnn`/`uniform`, `graphsage`/`sage`,
    /// `graphsaint`/`saint`).
    pub fn parse(s: &str) -> Option<SamplerKind> {
        match s {
            "scalegnn" | "uniform" => Some(SamplerKind::ScaleGnnUniform),
            "graphsage" | "sage" => Some(SamplerKind::GraphSage),
            "graphsaint" | "saint" => Some(SamplerKind::GraphSaintNode),
            _ => None,
        }
    }

    /// Human-readable name (Table I row label).
    pub fn name(&self) -> &'static str {
        match self {
            SamplerKind::ScaleGnnUniform => "ScaleGNN",
            SamplerKind::GraphSage => "GraphSAGE",
            SamplerKind::GraphSaintNode => "GraphSAINT (node)",
        }
    }
}

/// GraphSAGE node-wise neighbor sampling.
pub struct GraphSageSampler {
    /// Fixed batch capacity `B` (union truncated/padded to this).
    pub batch: usize,
    /// Loss-carrying target vertices drawn per batch.
    pub targets_per_batch: usize,
    /// Neighbors sampled per vertex per layer.
    pub fanout: usize,
    /// Hops of neighborhood expansion.
    pub layers: usize,
    /// Sampling seed.
    pub seed: u64,
}

impl GraphSageSampler {
    /// Pick `targets_per_batch`/`fanout` so the expected L-hop union
    /// roughly fills `batch`.
    pub fn new(batch: usize, layers: usize, seed: u64) -> Self {
        // pick targets/fanout so the expected L-hop union roughly fills B
        let fanout = 5usize;
        let mut expansion = 1usize;
        let mut per_target = 1usize;
        for _ in 0..layers {
            expansion *= fanout;
            per_target += expansion;
        }
        GraphSageSampler {
            batch,
            targets_per_batch: (batch / per_target).max(1),
            fanout,
            layers,
            seed,
        }
    }

    /// Draw the step's batch: targets, frontier-wise neighbor expansion,
    /// mean-normalized sampled adjacency.  `train_only` restricts targets
    /// to the train split.
    pub fn sample(&self, data: &Dataset, step: u64, train_only: bool) -> SampledBatch {
        let mut rng = Rng::for_step(self.seed ^ 0x5A6E, step);
        let n = data.n;
        let b = self.batch;

        // frontier-wise expansion: targets, then sampled neighbors per layer
        let mut chosen: Vec<u32> = Vec::with_capacity(b);
        let mut in_batch = std::collections::HashMap::<u32, u32>::new();
        let push = |v: u32, chosen: &mut Vec<u32>, in_batch: &mut std::collections::HashMap<u32, u32>| -> Option<u32> {
            if let Some(&i) = in_batch.get(&v) {
                return Some(i);
            }
            if chosen.len() >= b {
                return None; // truncation: neighborhood explosion hits the cap
            }
            let idx = chosen.len() as u32;
            chosen.push(v);
            in_batch.insert(v, idx);
            Some(idx)
        };

        let mut targets = Vec::with_capacity(self.targets_per_batch);
        let mut guard = 0;
        while targets.len() < self.targets_per_batch && guard < 50 * self.targets_per_batch {
            guard += 1;
            let v = rng.below(n as u64) as u32;
            if train_only && data.split[v as usize] != 0 {
                continue;
            }
            if push(v, &mut chosen, &mut in_batch).is_some() {
                targets.push(v);
            }
        }
        let n_targets = targets.len();

        // sampled edges (directed v <- u aggregation)
        let mut edges: Vec<(u32, u32)> = Vec::new();
        let mut frontier: Vec<u32> = targets.clone();
        for _ in 0..self.layers {
            let mut next = Vec::new();
            for &v in &frontier {
                let (nbrs, _) = data.raw_adj.row(v as usize);
                if nbrs.is_empty() {
                    continue;
                }
                for _ in 0..self.fanout.min(nbrs.len()) {
                    let u = nbrs[rng.below(nbrs.len() as u64) as usize];
                    let vi = in_batch[&v];
                    if let Some(ui) = push(u, &mut chosen, &mut in_batch) {
                        edges.push((vi, ui));
                        next.push(u);
                    }
                }
            }
            frontier = next;
            if frontier.is_empty() {
                break;
            }
        }

        // pad to exactly B with loss-less, edge-less slots
        let pad_v = chosen.first().copied().unwrap_or(0);
        while chosen.len() < b {
            chosen.push(pad_v);
        }

        // mean-normalized sampled adjacency + self loops
        edges.sort_unstable();
        edges.dedup();
        let mut deg = vec![0usize; b];
        for &(v, _) in &edges {
            deg[v as usize] += 1;
        }
        let mut triples: Vec<(u32, u32, f32)> = Vec::with_capacity(edges.len() + b);
        for &(v, u) in &edges {
            triples.push((v, u, 1.0 / (deg[v as usize] + 1) as f32));
        }
        for v in 0..b as u32 {
            triples.push((v, v, 1.0 / (deg[v as usize] + 1) as f32));
        }

        let mut loss_weight = vec![0.0f32; b];
        for t in 0..n_targets {
            loss_weight[t] = 1.0; // targets were pushed first
        }

        SampledBatch {
            vertices: chosen,
            adj: Csr::from_triples(b, b, triples),
            loss_weight,
        }
    }
}

/// GraphSAINT node sampling with the standard bias-correcting estimators.
pub struct GraphSaintNodeSampler {
    /// Fixed batch capacity `B` (draws with replacement, deduped, padded).
    pub batch: usize,
    /// Sampling seed.
    pub seed: u64,
    /// per-vertex sampling probability q_v (prop. to degree), precomputed
    q: Vec<f32>,
    /// alias-free cumulative distribution for O(log n) draws
    cdf: Vec<f64>,
}

impl GraphSaintNodeSampler {
    /// Precompute the degree-proportional sampling distribution of `data`.
    pub fn new(data: &Dataset, batch: usize, seed: u64) -> Self {
        let deg: Vec<f64> = data.raw_adj.degrees().iter().map(|&d| (d + 1) as f64).collect();
        let total: f64 = deg.iter().sum();
        let mut cdf = Vec::with_capacity(deg.len());
        let mut acc = 0.0;
        for &d in &deg {
            acc += d;
            cdf.push(acc / total);
        }
        // q_v = P[v in batch] ~= 1 - (1 - d_v/total)^B ~ B d_v / total (small)
        let q: Vec<f32> = deg
            .iter()
            .map(|&d| {
                let per_draw = d / total;
                (1.0 - (1.0 - per_draw).powi(batch as i32)).max(1e-9) as f32
            })
            .collect();
        GraphSaintNodeSampler { batch, seed, q, cdf }
    }

    /// Draw the step's batch: degree-biased vertices, induced subgraph with
    /// the GraphSAINT edge/loss normalizations.
    pub fn sample(&self, data: &Dataset, step: u64) -> SampledBatch {
        let mut rng = Rng::for_step(self.seed ^ 0x5417, step);
        let b = self.batch;
        // B draws with replacement, prob ~ degree; dedupe
        let mut s: Vec<u32> = (0..b)
            .map(|_| {
                let u = rng.f64();
                self.cdf.partition_point(|&c| c < u) as u32
            })
            .collect();
        s.sort_unstable();
        s.dedup();

        // induced subgraph with GraphSAINT normalizations
        let mut triples = Vec::new();
        for (si, &v) in s.iter().enumerate() {
            let (cs, vs) = data.adj.row(v as usize);
            let mut ci = 0usize;
            for (&c, &w) in cs.iter().zip(vs) {
                while ci < s.len() && s[ci] < c {
                    ci += 1;
                }
                if ci < s.len() && s[ci] == c {
                    // edge estimator: divide by the neighbor's inclusion prob
                    let corr = if c == v { 1.0 } else { self.q[c as usize] };
                    triples.push((si as u32, ci as u32, w / corr));
                }
            }
        }

        // loss normalization 1/q_v (then mean-normalized by the trainer's
        // weighted loss denominator)
        let mut loss_weight = vec![0.0f32; b];
        for (si, &v) in s.iter().enumerate() {
            loss_weight[si] = 1.0 / self.q[v as usize];
        }
        // normalize weights to mean ~1 over real slots for stable LR
        let mean: f32 = loss_weight.iter().sum::<f32>() / s.len() as f32;
        for w in loss_weight.iter_mut() {
            *w /= mean;
        }

        let pad_v = s.first().copied().unwrap_or(0);
        let mut vertices = s.clone();
        while vertices.len() < b {
            vertices.push(pad_v);
        }

        SampledBatch {
            vertices,
            adj: Csr::from_triples(b, b, triples),
            loss_weight,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::datasets;

    fn tiny() -> Dataset {
        datasets::load("tiny").unwrap()
    }

    #[test]
    fn sage_batch_has_fixed_shape_and_targets_first() {
        let d = tiny();
        let s = GraphSageSampler::new(32, 2, 1);
        let mb = s.sample(&d, 0, false);
        assert_eq!(mb.vertices.len(), 32);
        assert_eq!(mb.adj.rows, 32);
        let targets: usize = mb.loss_weight.iter().filter(|&&w| w > 0.0).count();
        assert!(targets >= 1 && targets <= s.targets_per_batch);
        // targets occupy the leading slots
        for i in 0..targets {
            assert!(mb.loss_weight[i] > 0.0);
        }
    }

    #[test]
    fn sage_edges_connect_true_neighbors() {
        let d = tiny();
        let s = GraphSageSampler::new(32, 2, 2);
        let mb = s.sample(&d, 1, false);
        for r in 0..32 {
            let (cs, _) = mb.adj.row(r);
            for &c in cs {
                if c as usize == r {
                    continue; // self loop
                }
                let (v, u) = (mb.vertices[r], mb.vertices[c as usize]);
                assert!(
                    d.raw_adj.has_edge(v as usize, u),
                    "sampled edge {v}->{u} not in graph"
                );
            }
        }
    }

    #[test]
    fn sage_rows_are_mean_normalized() {
        let d = tiny();
        let s = GraphSageSampler::new(32, 2, 3);
        let mb = s.sample(&d, 2, false);
        for r in 0..32 {
            let sum: f32 = mb.adj.row(r).1.iter().sum();
            if mb.adj.row_nnz(r) > 0 {
                assert!((sum - 1.0).abs() < 1e-5, "row {r} sums to {sum}");
            }
        }
    }

    #[test]
    fn sage_train_only_targets_are_train_split() {
        let d = tiny();
        let s = GraphSageSampler::new(32, 2, 4);
        let mb = s.sample(&d, 3, true);
        for (i, &w) in mb.loss_weight.iter().enumerate() {
            if w > 0.0 {
                assert_eq!(d.split[mb.vertices[i] as usize], 0);
            }
        }
    }

    #[test]
    fn saint_prefers_high_degree_vertices() {
        let d = tiny();
        let s = GraphSaintNodeSampler::new(&d, 64, 5);
        let deg = d.raw_adj.degrees();
        let mut sampled_deg = 0.0f64;
        let mut count = 0usize;
        for step in 0..50 {
            let mb = s.sample(&d, step);
            for (i, &w) in mb.loss_weight.iter().enumerate() {
                if w > 0.0 {
                    sampled_deg += deg[mb.vertices[i] as usize] as f64;
                    count += 1;
                }
            }
        }
        let avg_all: f64 = deg.iter().sum::<usize>() as f64 / d.n as f64;
        assert!(sampled_deg / count as f64 > avg_all, "degree-biased sampling");
    }

    #[test]
    fn saint_loss_weights_mean_one() {
        let d = tiny();
        let s = GraphSaintNodeSampler::new(&d, 64, 6);
        let mb = s.sample(&d, 0);
        let real: Vec<f32> = mb.loss_weight.iter().copied().filter(|&w| w > 0.0).collect();
        let mean: f32 = real.iter().sum::<f32>() / real.len() as f32;
        assert!((mean - 1.0).abs() < 1e-3);
    }

    #[test]
    fn saint_edges_are_induced_and_corrected() {
        let d = tiny();
        let s = GraphSaintNodeSampler::new(&d, 64, 7);
        let mb = s.sample(&d, 1);
        let dense = d.adj.to_dense();
        for r in 0..mb.adj.rows {
            let (cs, vs) = mb.adj.row(r);
            for (&c, &w) in cs.iter().zip(vs) {
                let (v, u) = (mb.vertices[r] as usize, mb.vertices[c as usize] as usize);
                let orig = dense.at(v, u);
                assert!(orig > 0.0, "induced edge must exist");
                assert!(w >= orig, "correction only scales up: {w} vs {orig}");
            }
        }
    }

    #[test]
    fn samplers_are_deterministic_per_step() {
        let d = tiny();
        let s = GraphSageSampler::new(32, 2, 8);
        assert_eq!(s.sample(&d, 4, false).vertices, s.sample(&d, 4, false).vertices);
        let gs = GraphSaintNodeSampler::new(&d, 64, 9);
        assert_eq!(gs.sample(&d, 4).vertices, gs.sample(&d, 4).vertices);
    }

    #[test]
    fn kind_parse_roundtrip() {
        assert_eq!(SamplerKind::parse("scalegnn"), Some(SamplerKind::ScaleGnnUniform));
        assert_eq!(SamplerKind::parse("sage"), Some(SamplerKind::GraphSage));
        assert_eq!(SamplerKind::parse("saint"), Some(SamplerKind::GraphSaintNode));
        assert_eq!(SamplerKind::parse("x"), None);
    }
}
