//! Sampling: ScaleGNN's communication-free uniform vertex sampling
//! (Algorithm 1), its distributed per-rank subgraph construction
//! (Algorithm 2), and the baseline samplers used in Table I.

pub mod baselines;
pub mod distributed;
pub mod uniform;

pub use baselines::{GraphSageSampler, GraphSaintNodeSampler, SampledBatch, SamplerKind};
pub use distributed::{assemble_global, DistributedSubgraphBuilder, LocalSubgraph};
pub use uniform::{
    densify_into, induce_rescaled, induce_rescaled_from, induce_rescaled_into,
    induce_rescaled_into_threads, induce_rescaled_reference, sample_and_induce_into,
    InduceWorkspace, MiniBatch, UniformVertexSampler,
};
