//! Analytical performance simulator: projects ScaleGNN (and the baseline
//! frameworks) to the paper's machine scale (Perlmutter / Frontier /
//! Tuolumne, up to 2048 devices) from first-principles cost models
//! calibrated at the paper's reference points.  See DESIGN.md §2
//! (substitutions) — the rank-thread runtime executes the algorithms for
//! real at <= 64 ranks; this module supplies the wall-clock projections the
//! figures need.

pub mod baselines;
pub mod machines;
pub mod model;

pub use baselines::{baseline_epoch, baseline_eval_round, epochs_to_target, Framework};
pub use machines::{by_name, Machine, FRONTIER, PERLMUTTER, TUOLUMNE};
pub use model::{
    scalegnn_epoch, scalegnn_epoch_with, scalegnn_eval_round, EpochBreakdown, OptFlags,
    Workload, DEFAULT_OVERLAP_HIDE_FRAC,
};

use crate::grid::{near_cubic, Grid4D};

/// The paper's per-dataset base 3D PMM grid (leftmost scaling point,
/// §VII-C: "as close to a cube as possible").
pub fn base_grid_for(dataset: &str) -> (usize, usize, usize) {
    match dataset {
        "products_sim" => (2, 2, 2),       // starts at 8 GPUs
        "reddit_sim" => (2, 2, 1),         // starts at 4
        "isolate_sim" => (4, 2, 2),        // starts at 16
        "products14m_sim" => (4, 4, 2),    // starts at 32
        "papers100m_sim" => (4, 4, 4),     // starts at 64
        _ => near_cubic(4),
    }
}

/// Build the 4D grid for `gpus` total devices with the dataset's fixed 3D
/// base (scaling = growing `Gd`, exactly the paper's methodology).
pub fn grid_for(dataset: &str, gpus: usize) -> Option<Grid4D> {
    let (x, y, z) = base_grid_for(dataset);
    let g3 = x * y * z;
    if gpus % g3 != 0 || gpus < g3 {
        return None;
    }
    Some(Grid4D::new(gpus / g3, x, y, z))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_for_respects_base_and_scale() {
        let g = grid_for("papers100m_sim", 2048).unwrap();
        assert_eq!((g.gx, g.gy, g.gz), (4, 4, 4));
        assert_eq!(g.gd, 32);
        assert!(grid_for("papers100m_sim", 96).is_none());
        let g8 = grid_for("products_sim", 8).unwrap();
        assert_eq!(g8.gd, 1);
    }
}
