//! Analytical epoch-time model for ScaleGNN at paper scale.
//!
//! Every component is derived from first principles (FLOP counts, bytes
//! moved, α-β collective costs on the machine profiles); a small set of
//! per-term efficiency constants is calibrated ONCE against the paper's
//! reference breakdown (Fig. 5: ogbn-products, 2x2x2 grid on Perlmutter —
//! TP collectives 47 %, sampling 26 % of the unoptimized epoch) and then
//! held fixed for all datasets, machines and scales.  The §V optimizations
//! are explicit toggles so the Fig. 5 ablation and the optimized scaling
//! runs (Figs. 7-8) come from the same model.

use super::machines::Machine;
use crate::grid::Grid4D;

/// Bytes of one element-wise pass over a B x d_h activation.
fn passes_bytes(b: f64, dh: f64) -> f64 {
    2.0 * b * dh * 4.0 // read + write
}

/// Paper-scale workload description (real dataset sizes).
#[derive(Clone, Copy, Debug)]
pub struct Workload {
    /// Vertices.
    pub n: f64,
    /// Edges.
    pub edges: f64,
    /// Input feature dimensionality.
    pub d_in: f64,
    /// Hidden width.
    pub d_h: f64,
    /// Output classes.
    pub d_out: f64,
    /// GCN layers.
    pub layers: f64,
    /// Per-group mini-batch size.
    pub batch: f64,
}

impl Workload {
    /// Workload from a registry spec's paper-scale shadow, with the model
    /// width/depth the projections assume.
    pub fn from_spec(spec: &crate::graph::DatasetSpec, d_h: f64, layers: f64) -> Workload {
        Workload {
            n: spec.paper.n,
            edges: spec.paper.edges,
            d_in: spec.paper.d_in,
            d_h,
            d_out: spec.paper.classes,
            layers,
            batch: spec.paper.batch,
        }
    }

    /// Expected nnz of the induced rescaled mini-batch adjacency
    /// (off-diagonals + self loops).
    pub fn nnz_batch(&self) -> f64 {
        self.edges * (self.batch / self.n).powi(2) + self.batch
    }

    /// Trainable parameter count.
    pub fn params(&self) -> f64 {
        self.d_in * self.d_h
            + self.layers * (self.d_h * self.d_h + self.d_h)
            + self.d_h * self.d_out
    }
}

/// §V optimization toggles.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct OptFlags {
    /// §V-A sampling/training overlap (prefetch)
    pub prefetch: bool,
    /// §V-B BF16 PMM collectives
    pub bf16: bool,
    /// §V-C fused element-wise kernels
    pub fusion: bool,
    /// §V-D backward comm/compute overlap
    pub overlap: bool,
}

impl OptFlags {
    /// Every §V optimization disabled (the Fig. 5 baseline).
    pub const NONE: OptFlags =
        OptFlags { prefetch: false, bf16: false, fusion: false, overlap: false };
    /// Every §V optimization enabled.
    pub const ALL: OptFlags =
        OptFlags { prefetch: true, bf16: true, fusion: true, overlap: true };
}

/// Per-epoch component times in seconds.
#[derive(Clone, Copy, Debug, Default)]
pub struct EpochBreakdown {
    /// Algorithm-2 sampling (visible share after prefetch).
    pub sampling: f64,
    /// Sparse aggregation kernels.
    pub spmm: f64,
    /// Dense matmul kernels.
    pub gemm: f64,
    /// Element-wise kernels (RMSNorm/ReLU/dropout/residual).
    pub elementwise: f64,
    /// Tensor-parallel collectives.
    pub tp_comm: f64,
    /// Data-parallel gradient all-reduce.
    pub dp_comm: f64,
    /// Fixed per-step launch/bookkeeping overhead.
    pub other: f64,
}

impl EpochBreakdown {
    /// Sum of all components.
    pub fn total(&self) -> f64 {
        self.sampling
            + self.spmm
            + self.gemm
            + self.elementwise
            + self.tp_comm
            + self.dp_comm
            + self.other
    }

    /// Every component multiplied by `f`.
    pub fn scale(&self, f: f64) -> EpochBreakdown {
        EpochBreakdown {
            sampling: self.sampling * f,
            spmm: self.spmm * f,
            gemm: self.gemm * f,
            elementwise: self.elementwise * f,
            tp_comm: self.tp_comm * f,
            dp_comm: self.dp_comm * f,
            other: self.other * f,
        }
    }
}

// ---------------------------------------------------------------------------
// Calibration constants (fit once at the Fig. 5 reference; see DESIGN.md §7)
// ---------------------------------------------------------------------------

/// Effective-bandwidth derating of Alg. 2's irregular gathers vs streaming
/// HBM bandwidth (random CSR row access, binary searches, compaction).
const SAMPLING_INEFFICIENCY: f64 = 40.0;
/// Algorithm 2 launches per layer-shard (range location, prefix sum,
/// gather, membership filter, remap, rescale, assembly x fwd/T) ...
const SAMPLE_KERNELS_PER_LAYER: f64 = 12.0;
/// ... each costing one GPU kernel launch + sync.
const KERNEL_LAUNCH: f64 = 40e-6;
/// Element-wise kernels per layer (fwd+bwd), unfused vs fused (§V-C).
const EW_KERNELS_UNFUSED: f64 = 6.0;
const EW_KERNELS_FUSED: f64 = 2.0;
const EW_LAUNCH: f64 = 20e-6;
/// SpMM achieves a fraction of streaming HBM bandwidth (gather-heavy).
const SPMM_BW_FRAC: f64 = 0.35;
/// GEMM sustained efficiency on mini-batch-sized tiles.
const GEMM_EFF: f64 = 0.55;
/// Default fraction of backward TP communication hidden by §V-D overlap —
/// used when no *measured* value is supplied.  The executed collective
/// engine records the real per-op issue→completion vs blocked timings
/// (`comm::CommWorld::tp_hidden_fraction`); feed that measurement through
/// [`scalegnn_epoch_with`] (CLI: `scalegnn breakdown --calibrate-overlap`)
/// to calibrate the 2048-GPU projections from the executed ≤64-rank runs.
pub const DEFAULT_OVERLAP_HIDE_FRAC: f64 = 0.15;
/// Fixed per-step launch/bookkeeping overhead (s) per device.
const STEP_OVERHEAD: f64 = 400e-6;

/// Epoch time for ScaleGNN on `machine` with the 4D `grid`, with the
/// default §V-D hide fraction.
pub fn scalegnn_epoch(
    w: &Workload,
    machine: &Machine,
    grid: Grid4D,
    opts: OptFlags,
) -> EpochBreakdown {
    scalegnn_epoch_with(w, machine, grid, opts, DEFAULT_OVERLAP_HIDE_FRAC)
}

/// Epoch time for ScaleGNN with an explicit §V-D hide fraction — pass the
/// measured `comm::CommWorld::tp_hidden_fraction()` of an executed run to
/// calibrate the projection (clamped to `[0, 1]`).  The fraction applies
/// to the *hideable* share of backward TP communication (the
/// parameter-gradient all-reduces), matching the population of
/// nonblocking-issued collectives the engine actually measures.
pub fn scalegnn_epoch_with(
    w: &Workload,
    machine: &Machine,
    grid: Grid4D,
    opts: OptFlags,
    overlap_hide_frac: f64,
) -> EpochBreakdown {
    let g3 = grid.group_size() as f64;
    let gd = grid.gd as f64;
    let steps = (w.n / (w.batch * gd)).max(1.0);
    let b = w.batch;
    let dh = w.d_h;
    let nnz_s = w.nnz_batch();

    // ---- per-step compute (per device, work / g3) ----
    // GEMM flops: input proj + L layer GEMMs + head, x3 for fwd + 2 bwd
    let gemm_flops = 3.0 * 2.0 * (b * w.d_in * dh + w.layers * b * dh * dh + b * dh * w.d_out);
    let gemm_t = gemm_flops / g3 / (machine.flops * GEMM_EFF);

    // SpMM: memory-bound CSR gathers, fwd + bwd per layer
    let spmm_bytes = 2.0 * w.layers * nnz_s * (dh * 8.0 + 16.0);
    let spmm_t = spmm_bytes / g3 / (machine.hbm_bw * SPMM_BW_FRAC);

    // element-wise: RMSNorm/ReLU/dropout/residual kernels over B x d_h
    // (launch-bound at mini-batch sizes, which is why §V-C fusion pays)
    let kernels = if opts.fusion { EW_KERNELS_FUSED } else { EW_KERNELS_UNFUSED };
    let ew_t = w.layers
        * kernels
        * (EW_LAUNCH + passes_bytes(b, dh) / g3 / machine.hbm_bw);

    // ---- sampling (Algorithm 2, per device) ----
    // per-layer shard extraction: a chain of small launch-bound kernels
    // (the paper's 26 % sampling share at B~16k) + irregular gather bytes
    let samp_bytes = w.layers * (w.edges * b / w.n * 12.0 + b * 96.0);
    let samp_t = w.layers * SAMPLE_KERNELS_PER_LAYER * KERNEL_LAUNCH
        + samp_bytes / g3 / machine.hbm_bw * SAMPLING_INEFFICIENCY;

    // ---- TP collectives (per step) ----
    // group strides: X contiguous, Y stride gx, Z stride gx*gy
    let tp_bytes4 = |rows_div: f64, cols_div: f64| b / rows_div * dh / cols_div * 4.0;
    let scale_bytes = if opts.bf16 { 0.5 } else { 1.0 };
    let (gx, gy, gz) = (grid.gx as f64, grid.gy as f64, grid.gz as f64);
    let ar = |bytes: f64, p: usize, stride: usize| {
        machine.all_reduce_time(bytes * scale_bytes, p, machine.spans_nodes(p, stride))
    };
    let ag = |bytes: f64, p: usize, stride: usize| {
        machine.all_gather_time(bytes, p, machine.spans_nodes(p, stride))
    };
    // forward per layer: AR over R (spmm partials), AR over C (gemm),
    // rmsnorm AR (small, fp32), residual reshard (2 all-gathers);
    // backward: 3 more matmul ARs + reshard.  Use the period-3 rotation's
    // average axis sizes; approximate with the X/Y/Z roles of layer 0.
    let strides = [1usize, grid.gx, grid.gx * grid.gy];
    let sizes = [grid.gx, grid.gy, grid.gz];
    let mut tp_fwd = 0.0;
    let mut tp_bwd = 0.0;
    // the §V-D-hideable share of tp_bwd: the parameter-gradient (dW)
    // all-reduces, which have no downstream consumer until the optimizer
    // — exactly the ops the executed engine issues nonblocking and whose
    // measured hidden fraction calibrates `overlap_hide_frac`.  The
    // activation gradients (dH, dF) and reshards are true dependencies
    // and can never be hidden.
    let mut tp_bwd_hideable = 0.0;
    for l in 0..(w.layers as usize) {
        // rotate which axis plays R/C/T per layer
        let r = l % 3;
        let c = (l + 1) % 3;
        let t = (l + 2) % 3;
        let (pr, pc, pt) = (sizes[r], sizes[c], sizes[t]);
        let (sr, sc, _st) = (strides[r], strides[c], strides[t]);
        // spmm AR over R: payload (B/pt)*(dh/pc)
        tp_fwd += ar(tp_bytes4(pt as f64, pc as f64), pr, sr);
        // gemm AR over C: payload (B/pt)*(dh/pr)
        tp_fwd += ar(tp_bytes4(pt as f64, pr as f64), pc, sc);
        // rmsnorm AR (B/pt rows, fp32, never bf16)
        tp_fwd += machine.all_reduce_time(b / pt as f64 * 4.0, pc, machine.spans_nodes(pc, sc));
        // residual reshard: two all-gathers growing to full B x dh strip
        tp_fwd += ag(tp_bytes4(pr as f64, pc as f64), pr, sr)
            + ag(tp_bytes4(1.0, pc as f64), pc, sc);
        // backward: dW (over T), dH (over R), dF (over T) + reshard
        let dw_ar = ar(dh / pc as f64 * dh / pr as f64 * 4.0 * scale_bytes, pt, strides[t]);
        tp_bwd += dw_ar;
        tp_bwd_hideable += dw_ar;
        tp_bwd += ar(tp_bytes4(pt as f64, pc as f64), pr, sr);
        tp_bwd += ar(tp_bytes4(pr as f64, pc as f64), pt, strides[t]);
        tp_bwd += ag(tp_bytes4(pt as f64, pr as f64), pr, sr)
            + ag(tp_bytes4(1.0, pr as f64), pc, sc);
    }
    // projections: AR over Z fwd + bwd weight grads
    tp_fwd += ar(b / gx * dh / gy * 4.0, grid.gz, grid.gx * grid.gy);
    let dwin_ar = ar(w.d_in / gz * dh / gy * 4.0, grid.gx, 1);
    tp_bwd += dwin_ar + ar(b / gx * w.d_out / gy * 4.0, grid.gz, grid.gx * grid.gy);
    tp_bwd_hideable += dwin_ar;
    let tp_bwd_hidden =
        if opts.overlap { tp_bwd_hideable * overlap_hide_frac.clamp(0.0, 1.0) } else { 0.0 };
    let tp_t = tp_fwd + tp_bwd - tp_bwd_hidden;

    // ---- DP gradient all-reduce (per step) ----
    // each rank reduces its parameter shard across the gd groups; gradients
    // are flushed in buckets (4 here), so the latency term multiplies
    const DP_BUCKETS: f64 = 4.0;
    let dp_bytes = w.params() * 4.0 / g3;
    let dp_t = DP_BUCKETS
        * machine.all_reduce_time(
            dp_bytes / DP_BUCKETS,
            grid.gd,
            machine.spans_nodes(grid.gd, grid.group_size()),
        );

    // ---- assemble epoch ----
    let compute = spmm_t + gemm_t + ew_t + STEP_OVERHEAD;
    let per_step_rest = compute + tp_t + dp_t;
    let (samp_eff, other) = if opts.prefetch {
        // §V-A: sampling runs on its own stream; only the excess beyond the
        // training step remains visible
        ((samp_t - per_step_rest).max(0.0), STEP_OVERHEAD)
    } else {
        (samp_t, STEP_OVERHEAD)
    };

    EpochBreakdown {
        sampling: samp_eff * steps,
        spmm: spmm_t * steps,
        gemm: gemm_t * steps,
        elementwise: ew_t * steps,
        tp_comm: tp_t * steps,
        dp_comm: dp_t * steps,
        other: other * steps,
    }
}

/// Full-graph distributed evaluation round (Table II, ScaleGNN row): one 3D
/// PMM forward over the entire graph on a single group.
pub fn scalegnn_eval_round(w: &Workload, machine: &Machine, grid: Grid4D) -> f64 {
    let g3 = grid.group_size() as f64;
    let gemm_flops = 2.0 * (w.n * w.d_in * w.d_h
        + w.layers * w.n * w.d_h * w.d_h
        + w.n * w.d_h * w.d_out);
    let gemm_t = gemm_flops / g3 / (machine.flops * GEMM_EFF);
    let spmm_bytes = w.layers * w.edges * (w.d_h * 8.0 + 16.0);
    let spmm_t = spmm_bytes / g3 / (machine.hbm_bw * SPMM_BW_FRAC);
    // per-layer ARs over full activations N x d_h
    let (gx, gy) = (grid.gx as f64, grid.gy as f64);
    let act_bytes = w.n / gx * w.d_h / gy * 4.0;
    let comm = (w.layers + 1.0)
        * 2.0
        * machine.all_reduce_time(act_bytes, grid.gx.max(grid.gy).max(grid.gz), true);
    gemm_t + spmm_t + comm + 5.0 * STEP_OVERHEAD
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::datasets;
    use crate::sim::machines::PERLMUTTER;

    fn products() -> Workload {
        Workload::from_spec(&datasets::spec("products_sim").unwrap(), 128.0, 3.0)
    }

    #[test]
    fn fig5_reference_fractions_are_close_to_paper() {
        // Fig. 5 leftmost bar: 2x2x2, DP1, unoptimized: TP ~47 %, sampling
        // ~26 % of epoch time.
        let bd = scalegnn_epoch(&products(), &PERLMUTTER, Grid4D::new(1, 2, 2, 2), OptFlags::NONE);
        let total = bd.total();
        let tp = bd.tp_comm / total;
        let sa = bd.sampling / total;
        assert!((tp - 0.47).abs() < 0.08, "TP fraction {tp:.3} (want ~0.47)");
        assert!((sa - 0.26).abs() < 0.06, "sampling fraction {sa:.3} (want ~0.26)");
    }

    #[test]
    fn cumulative_optimizations_match_paper_magnitude() {
        // §V: cumulative speedup 1.75x (DP1) over the unoptimized baseline.
        let w = products();
        let g = Grid4D::new(1, 2, 2, 2);
        let base = scalegnn_epoch(&w, &PERLMUTTER, g, OptFlags::NONE).total();
        let opt = scalegnn_epoch(&w, &PERLMUTTER, g, OptFlags::ALL).total();
        let speedup = base / opt;
        assert!(
            (1.4..2.2).contains(&speedup),
            "cumulative speedup {speedup:.2} (paper: 1.75)"
        );
    }

    #[test]
    fn each_optimization_helps() {
        let w = products();
        let g = Grid4D::new(4, 2, 2, 2);
        let mut prev = scalegnn_epoch(&w, &PERLMUTTER, g, OptFlags::NONE).total();
        let seq = [
            OptFlags { prefetch: true, ..OptFlags::NONE },
            OptFlags { prefetch: true, bf16: true, ..OptFlags::NONE },
            OptFlags { prefetch: true, bf16: true, fusion: true, overlap: false },
            OptFlags::ALL,
        ];
        for (i, o) in seq.iter().enumerate() {
            let t = scalegnn_epoch(&w, &PERLMUTTER, g, *o).total();
            assert!(t < prev, "opt stage {i} regressed: {t} vs {prev}");
            prev = t;
        }
    }

    #[test]
    fn dp_scaling_reduces_epoch_time() {
        let w = Workload::from_spec(&datasets::spec("products14m_sim").unwrap(), 128.0, 3.0);
        let mut prev = f64::MAX;
        for gd in [1usize, 2, 4, 8, 16, 32] {
            let t = scalegnn_epoch(&w, &PERLMUTTER, Grid4D::new(gd, 2, 2, 2), OptFlags::ALL)
                .total();
            assert!(t < prev, "gd={gd}: {t} vs {prev}");
            prev = t;
        }
    }

    #[test]
    fn papers100m_strong_scaling_matches_paper_shape() {
        // Paper: 64 -> 2048 GPUs gives 21.7x (4095 ms -> 189 ms).
        let w = Workload::from_spec(&datasets::spec("papers100m_sim").unwrap(), 128.0, 3.0);
        let t64 =
            scalegnn_epoch(&w, &PERLMUTTER, Grid4D::new(1, 4, 4, 4), OptFlags::ALL).total();
        let t2048 =
            scalegnn_epoch(&w, &PERLMUTTER, Grid4D::new(32, 4, 4, 4), OptFlags::ALL).total();
        let speedup = t64 / t2048;
        assert!(
            (10.0..32.0).contains(&speedup),
            "64->2048 speedup {speedup:.1} (paper: 21.7)"
        );
    }

    #[test]
    fn dp_allreduce_fraction_grows_with_gd() {
        // Fig. 8 shape: DP all-reduce grows, sampling + TP stay ~constant.
        let w = Workload::from_spec(&datasets::spec("products14m_sim").unwrap(), 128.0, 3.0);
        let b1 = scalegnn_epoch(&w, &PERLMUTTER, Grid4D::new(1, 2, 2, 2), OptFlags::ALL);
        let b16 = scalegnn_epoch(&w, &PERLMUTTER, Grid4D::new(16, 2, 2, 2), OptFlags::ALL);
        assert_eq!(b1.dp_comm, 0.0);
        let f16 = b16.dp_comm / b16.total();
        assert!(f16 > 0.05, "dp fraction at gd=16: {f16:.3}");
        // per-step TP time constant => epoch TP shrinks with gd (fewer steps)
        let tp_per_step_1 = b1.tp_comm / (w.n / (w.batch * 1.0));
        let tp_per_step_16 = b16.tp_comm / (w.n / (w.batch * 16.0));
        assert!((tp_per_step_1 - tp_per_step_16).abs() / tp_per_step_1 < 1e-6);
    }

    #[test]
    fn measured_hide_fraction_calibrates_the_overlap_term() {
        let w = products();
        let g = Grid4D::new(4, 2, 2, 2);
        let default = scalegnn_epoch(&w, &PERLMUTTER, g, OptFlags::ALL).total();
        assert_eq!(
            default,
            scalegnn_epoch_with(&w, &PERLMUTTER, g, OptFlags::ALL, DEFAULT_OVERLAP_HIDE_FRAC)
                .total()
        );
        // a larger measured hide fraction hides more backward TP time
        let lo = scalegnn_epoch_with(&w, &PERLMUTTER, g, OptFlags::ALL, 0.05).total();
        let hi = scalegnn_epoch_with(&w, &PERLMUTTER, g, OptFlags::ALL, 0.60).total();
        assert!(hi < default && default < lo, "{hi} < {default} < {lo}");
        // with overlap off, the hide fraction is irrelevant
        let off = OptFlags { overlap: false, ..OptFlags::ALL };
        assert_eq!(
            scalegnn_epoch_with(&w, &PERLMUTTER, g, off, 0.9).total(),
            scalegnn_epoch_with(&w, &PERLMUTTER, g, off, 0.0).total()
        );
        // out-of-range measurements are clamped, not amplified
        let clamped = scalegnn_epoch_with(&w, &PERLMUTTER, g, OptFlags::ALL, 2.0);
        assert!(clamped.total() > 0.0 && clamped.tp_comm >= 0.0);
    }

    #[test]
    fn eval_round_is_subsecond_at_paper_scale() {
        // Table II: products eval 0.19 s on 8 GPUs.
        let t = scalegnn_eval_round(&products(), &PERLMUTTER, Grid4D::new(1, 2, 2, 2));
        assert!((0.02..1.0).contains(&t), "eval round {t:.3}s (paper: 0.19)");
    }
}
