//! Machine profiles for the analytical performance model (§VI-A).
//!
//! Numbers are from public spec sheets; per-term efficiency factors are
//! calibrated once against the paper's reference breakdown (Fig. 5:
//! ogbn-products on eight A100s, 2x2x2 grid) and then held fixed for every
//! projection.  RCCL's lower collective throughput on Frontier (§VII-C,
//! [60]) enters as `collective_efficiency`.

/// One GPU/GCD/APU model + its node-level fabric.
#[derive(Clone, Copy, Debug)]
pub struct Machine {
    /// Machine name as it appears in the paper.
    pub name: &'static str,
    /// sustained f32 matmul throughput per device (FLOP/s)
    pub flops: f64,
    /// HBM bandwidth per device (B/s)
    pub hbm_bw: f64,
    /// intra-node link bandwidth per device (B/s) — NVLink / xGMI
    pub intra_bw: f64,
    /// inter-node injection bandwidth per device (B/s) — Slingshot-11,
    /// 100 GB/s per node / 4 devices
    pub inter_bw: f64,
    /// per-message latency (s) intra / inter node
    pub alpha_intra: f64,
    /// Per-message latency (s) across nodes.
    pub alpha_inter: f64,
    /// devices per node (GCDs on Frontier)
    pub devices_per_node: usize,
    /// NCCL=1.0; RCCL lower at scale [60]
    pub collective_efficiency: f64,
}

/// NERSC Perlmutter: 4x NVIDIA A100 40GB per node, Slingshot-11.
pub const PERLMUTTER: Machine = Machine {
    name: "Perlmutter",
    flops: 15.0e12, // sustained TF32/FP32 tensor GEMM
    hbm_bw: 1.4e12,
    intra_bw: 200.0e9, // NVLink3 per-direction share
    inter_bw: 25.0e9,  // 100 GB/s node injection / 4
    alpha_intra: 6.0e-6,
    alpha_inter: 12.0e-6,
    devices_per_node: 4,
    collective_efficiency: 1.0,
};

/// OLCF Frontier: 4x MI250X per node = 8 GCDs, Slingshot-11.
pub const FRONTIER: Machine = Machine {
    name: "Frontier",
    flops: 14.0e12, // per GCD, sustained
    hbm_bw: 1.3e12,
    intra_bw: 150.0e9, // Infinity Fabric share per GCD
    inter_bw: 12.5e9,  // 100 GB/s node injection / 8 GCDs
    alpha_intra: 7.0e-6,
    alpha_inter: 14.0e-6,
    devices_per_node: 8,
    collective_efficiency: 0.55, // RCCL vs NCCL at scale [60]
};

/// LLNL Tuolumne: 4x MI300A APU per node, Slingshot-11.
pub const TUOLUMNE: Machine = Machine {
    name: "Tuolumne",
    flops: 30.0e12, // MI300A sustained f32 matrix
    hbm_bw: 3.0e12,
    intra_bw: 180.0e9,
    inter_bw: 25.0e9,
    alpha_intra: 6.0e-6,
    alpha_inter: 12.0e-6,
    devices_per_node: 4,
    collective_efficiency: 0.7,
};

/// Look up a machine profile by (case-insensitive) name.
pub fn by_name(name: &str) -> Option<Machine> {
    match name.to_ascii_lowercase().as_str() {
        "perlmutter" => Some(PERLMUTTER),
        "frontier" => Some(FRONTIER),
        "tuolumne" => Some(TUOLUMNE),
        _ => None,
    }
}

impl Machine {
    /// Ring all-reduce time for `bytes` payload across a group of `p`
    /// devices; `spans_nodes` decides which link/latency applies.
    pub fn all_reduce_time(&self, bytes: f64, p: usize, spans_nodes: bool) -> f64 {
        if p <= 1 || bytes <= 0.0 {
            return 0.0;
        }
        let (bw, alpha) = if spans_nodes {
            (self.inter_bw, self.alpha_inter)
        } else {
            (self.intra_bw, self.alpha_intra)
        };
        let eff = self.collective_efficiency;
        let pf = p as f64;
        2.0 * (pf - 1.0) / pf * bytes / (bw * eff) + 2.0 * (pf - 1.0) * alpha
    }

    /// All-gather time for `bytes` contributed per member.
    pub fn all_gather_time(&self, bytes: f64, p: usize, spans_nodes: bool) -> f64 {
        if p <= 1 || bytes <= 0.0 {
            return 0.0;
        }
        let (bw, alpha) = if spans_nodes {
            (self.inter_bw, self.alpha_inter)
        } else {
            (self.intra_bw, self.alpha_intra)
        };
        let pf = p as f64;
        (pf - 1.0) * bytes / (bw * self.collective_efficiency) + (pf - 1.0) * alpha
    }

    /// Whether a process group of `p` consecutive devices crosses nodes,
    /// given `group_stride` devices between members.
    pub fn spans_nodes(&self, p: usize, group_stride: usize) -> bool {
        p * group_stride > self.devices_per_node
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn profiles_resolve_by_name() {
        for n in ["perlmutter", "Frontier", "TUOLUMNE"] {
            assert!(by_name(n).is_some());
        }
        assert!(by_name("summit").is_none());
    }

    #[test]
    fn all_reduce_scales_with_payload_and_group() {
        let m = PERLMUTTER;
        let t1 = m.all_reduce_time(1e6, 4, false);
        let t2 = m.all_reduce_time(2e6, 4, false);
        assert!(t2 > t1);
        // bandwidth term roughly doubles
        assert!(t2 < 2.2 * t1);
        // inter-node slower than intra
        assert!(m.all_reduce_time(1e6, 4, true) > t1);
        // single member is free
        assert_eq!(m.all_reduce_time(1e6, 1, false), 0.0);
    }

    #[test]
    fn frontier_collectives_slower_than_perlmutter() {
        let b = 8e6;
        assert!(
            FRONTIER.all_reduce_time(b, 8, true) > PERLMUTTER.all_reduce_time(b, 8, true),
            "RCCL efficiency factor"
        );
    }

    #[test]
    fn spans_nodes_logic() {
        assert!(!PERLMUTTER.spans_nodes(4, 1));
        assert!(PERLMUTTER.spans_nodes(8, 1));
        assert!(PERLMUTTER.spans_nodes(4, 2));
        assert!(!FRONTIER.spans_nodes(8, 1));
    }
}
