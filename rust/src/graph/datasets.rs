//! Dataset registry: named synthetic stand-ins for the paper's five graphs
//! (§VI-C), scaled so they run on one machine.  Shapes mirror the model
//! configurations baked into the AOT artifacts (`python/compile/aot.py`).
//!
//! | name            | paper dataset    | paper N / this N | d_in | classes |
//! |-----------------|------------------|------------------|------|---------|
//! | tiny            | (tests)          | — / 512          | 16   | 4       |
//! | reddit_sim      | Reddit           | 233 k / 65 k     | 128  | 40      |
//! | products_sim    | ogbn-products    | 2.4 M / 131 k    | 128  | 48      |
//! | isolate_sim     | Isolate-3-8M     | 3.8 M / 262 k    | 128  | 32      |
//! | products14m_sim | Products-14M     | 14 M / 524 k     | 128  | 32      |
//! | papers100m_sim  | ogbn-papers100M  | 111 M / 1.05 M   | 64   | 32      |
//!
//! The three scaling datasets are used for epoch-time / scaling experiments
//! only (as in the paper, which gives them random features + synthetic
//! degree-proportional classes); the accuracy datasets carry a planted
//! community structure so test accuracy is meaningful.

use super::generate::{planted_partition, Dataset, PlantedConfig};

/// Paper-scale metadata used by the analytical simulator (`sim::`): the
/// *real* dataset sizes, so projected epoch times use the paper's workload
/// volumes, not the scaled-down local stand-ins.
#[derive(Clone, Copy, Debug)]
pub struct PaperScale {
    pub n: f64,
    pub edges: f64,
    pub d_in: f64,
    pub classes: f64,
    pub batch: f64,
}

#[derive(Clone, Debug)]
pub struct DatasetSpec {
    pub name: &'static str,
    pub model_config: &'static str, // artifact family suffix
    pub planted: PlantedConfig,
    pub batch: usize,
    pub paper: PaperScale,
}

pub fn registry() -> Vec<DatasetSpec> {
    vec![
        DatasetSpec {
            name: "tiny",
            model_config: "tiny",
            planted: PlantedConfig {
                n: 512,
                classes: 4,
                avg_degree: 12,
                d_in: 16,
                intra_frac: 0.85,
                feature_noise: 0.4,
                label_noise: 0.0,
                seed: 0xC0FFEE,
            },
            batch: 32,
            paper: PaperScale { n: 512.0, edges: 6e3, d_in: 16.0, classes: 4.0, batch: 32.0 },
        },
        DatasetSpec {
            name: "reddit_sim",
            model_config: "reddit_sim",
            planted: PlantedConfig {
                n: 65_536,
                classes: 40,
                avg_degree: 32,
                d_in: 128,
                intra_frac: 0.82,
                feature_noise: 0.55,
                label_noise: 0.02,
                seed: 0x5EDD17,
            },
            batch: 1024,
            paper: PaperScale {
                n: 232_965.0,
                edges: 114.6e6,
                d_in: 602.0,
                classes: 41.0,
                batch: 8192.0,
            },
        },
        DatasetSpec {
            name: "products_sim",
            model_config: "products_sim",
            planted: PlantedConfig {
                n: 131_072,
                classes: 48,
                avg_degree: 16,
                d_in: 128,
                intra_frac: 0.80,
                feature_noise: 0.6,
                label_noise: 0.05,
                seed: 0x9A0D,
            },
            batch: 1024,
            paper: PaperScale {
                n: 2_449_029.0,
                edges: 61.9e6,
                d_in: 100.0,
                classes: 47.0,
                batch: 32768.0,
            },
        },
        DatasetSpec {
            name: "isolate_sim",
            model_config: "products_sim", // shares the artifact shape family
            planted: PlantedConfig {
                n: 262_144,
                classes: 32,
                avg_degree: 16,
                d_in: 128,
                intra_frac: 0.8,
                feature_noise: 0.6,
                label_noise: 0.05,
                seed: 0x150,
            },
            batch: 1024,
            paper: PaperScale {
                n: 3.8e6,
                edges: 68.0e6,
                d_in: 128.0,
                classes: 32.0,
                batch: 32768.0,
            },
        },
        DatasetSpec {
            name: "products14m_sim",
            model_config: "products_sim",
            planted: PlantedConfig {
                n: 524_288,
                classes: 32,
                avg_degree: 16,
                d_in: 128,
                intra_frac: 0.8,
                feature_noise: 0.6,
                label_noise: 0.05,
                seed: 0x14D,
            },
            batch: 1024,
            paper: PaperScale {
                n: 14.0e6,
                edges: 115.0e6,
                d_in: 128.0,
                classes: 32.0,
                batch: 32768.0,
            },
        },
        DatasetSpec {
            // end-to-end driver workload (examples/train_e2e.rs): larger
            // model (d_h=512, L=4) on a mid-size graph
            name: "e2e_big",
            model_config: "e2e_big",
            planted: PlantedConfig {
                n: 65_536,
                classes: 32,
                avg_degree: 16,
                d_in: 256,
                intra_frac: 0.8,
                feature_noise: 0.6,
                label_noise: 0.05,
                seed: 0xE2E,
            },
            batch: 1024,
            paper: PaperScale {
                n: 65_536.0,
                edges: 1.0e6,
                d_in: 256.0,
                classes: 32.0,
                batch: 1024.0,
            },
        },
        DatasetSpec {
            name: "papers100m_sim",
            model_config: "products_sim",
            planted: PlantedConfig {
                n: 1_048_576,
                classes: 32,
                avg_degree: 8,
                d_in: 128,
                intra_frac: 0.8,
                feature_noise: 0.6,
                label_noise: 0.05,
                seed: 0x100A11,
            },
            batch: 1024,
            paper: PaperScale {
                n: 111.0e6,
                edges: 1.6e9,
                d_in: 128.0,
                classes: 172.0,
                batch: 32768.0,
            },
        },
    ]
}

pub fn spec(name: &str) -> Option<DatasetSpec> {
    registry().into_iter().find(|s| s.name == name)
}

/// Generate (deterministically) the named dataset.
pub fn load(name: &str) -> Option<Dataset> {
    let s = spec(name)?;
    let mut d = planted_partition(&s.planted);
    d.name = s.name.to_string();
    Some(d)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_names_unique_and_resolvable() {
        let r = registry();
        for s in &r {
            assert!(spec(s.name).is_some());
        }
        let mut names: Vec<_> = r.iter().map(|s| s.name).collect();
        names.sort();
        names.dedup();
        assert_eq!(names.len(), r.len());
    }

    #[test]
    fn tiny_loads_and_matches_spec() {
        let d = load("tiny").unwrap();
        assert_eq!(d.n, 512);
        assert_eq!(d.classes, 4);
        assert_eq!(d.features.cols, 16);
    }

    #[test]
    fn unknown_dataset_is_none() {
        assert!(load("nope").is_none());
    }
}
