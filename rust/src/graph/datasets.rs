//! Dataset registry: named synthetic stand-ins for the paper's five graphs
//! (§VI-C), scaled so they run on one machine.  Shapes mirror the model
//! configurations baked into the AOT artifacts (`python/compile/aot.py`).
//!
//! The table below is RENDERED FROM CODE by [`doc_table`] and asserted
//! against this comment by the `doc_table_matches_module_docs` test — edit
//! `registry()` and paste the regenerated lines here, or the build's test
//! suite will tell you the docs rotted:
//!
//! | name            | paper dataset         |     paper N |   local N | d_in | classes | batch |
//! |-----------------|-----------------------|-------------|-----------|------|---------|-------|
//! | tiny            | (tests)               |         512 |       512 |   16 |       4 |    32 |
//! | reddit_sim      | Reddit                |     232,965 |    65,536 |  128 |      40 |  1024 |
//! | products_sim    | ogbn-products         |   2,449,029 |   131,072 |  128 |      48 |  1024 |
//! | isolate_sim     | Isolate-3-8M          |   3,800,000 |   262,144 |  128 |      32 |  1024 |
//! | products14m_sim | Products-14M          |  14,000,000 |   524,288 |  128 |      32 |  1024 |
//! | e2e_big         | (e2e driver)          |      65,536 |    65,536 |  256 |      32 |  1024 |
//! | papers100m_sim  | ogbn-papers100M       | 111,000,000 | 1,048,576 |  128 |      32 |  1024 |
//! | papers100m_ooc  | ogbn-papers100M (OOC) | 111,000,000 | 1,048,576 |  128 |      32 |  1024 |
//!
//! The three scaling datasets are used for epoch-time / scaling experiments
//! only (as in the paper, which gives them random features + synthetic
//! degree-proportional classes); the accuracy datasets carry a planted
//! community structure so test accuracy is meaningful.  `papers100m_ooc` is
//! the same graph as `papers100m_sim` but is meant to be packed into a
//! `.pallas` container once (`scalegnn pack`) and trained **out-of-core**
//! (`scalegnn train --from-store`), reproducing the larger-than-RAM
//! scenario of the paper's headline dataset; see `graph::store`.

use super::generate::{planted_partition, Dataset, PlantedConfig};

/// Paper-scale metadata used by the analytical simulator (`sim::`): the
/// *real* dataset sizes, so projected epoch times use the paper's workload
/// volumes, not the scaled-down local stand-ins.
#[derive(Clone, Copy, Debug)]
pub struct PaperScale {
    /// Vertices of the real dataset.
    pub n: f64,
    /// Edges of the real dataset.
    pub edges: f64,
    /// Input feature dimensionality of the real dataset.
    pub d_in: f64,
    /// Label classes of the real dataset.
    pub classes: f64,
    /// Per-group mini-batch size the paper trains with.
    pub batch: f64,
}

/// One registry entry: a named local stand-in plus its paper-scale shadow.
#[derive(Clone, Debug)]
pub struct DatasetSpec {
    /// Registry name (what the CLI's `--dataset` accepts).
    pub name: &'static str,
    /// Human-readable name of the paper dataset this stands in for.
    pub paper_name: &'static str,
    /// Artifact family suffix of the matching AOT model configuration.
    pub model_config: &'static str,
    /// Generator parameters of the local stand-in.
    pub planted: PlantedConfig,
    /// Local mini-batch size.
    pub batch: usize,
    /// Real-dataset sizes for the analytical projections.
    pub paper: PaperScale,
}

/// All registered datasets, in documentation order.
pub fn registry() -> Vec<DatasetSpec> {
    vec![
        DatasetSpec {
            name: "tiny",
            paper_name: "(tests)",
            model_config: "tiny",
            planted: PlantedConfig {
                n: 512,
                classes: 4,
                avg_degree: 12,
                d_in: 16,
                intra_frac: 0.85,
                feature_noise: 0.4,
                label_noise: 0.0,
                seed: 0xC0FFEE,
            },
            batch: 32,
            paper: PaperScale { n: 512.0, edges: 6e3, d_in: 16.0, classes: 4.0, batch: 32.0 },
        },
        DatasetSpec {
            name: "reddit_sim",
            paper_name: "Reddit",
            model_config: "reddit_sim",
            planted: PlantedConfig {
                n: 65_536,
                classes: 40,
                avg_degree: 32,
                d_in: 128,
                intra_frac: 0.82,
                feature_noise: 0.55,
                label_noise: 0.02,
                seed: 0x5EDD17,
            },
            batch: 1024,
            paper: PaperScale {
                n: 232_965.0,
                edges: 114.6e6,
                d_in: 602.0,
                classes: 41.0,
                batch: 8192.0,
            },
        },
        DatasetSpec {
            name: "products_sim",
            paper_name: "ogbn-products",
            model_config: "products_sim",
            planted: PlantedConfig {
                n: 131_072,
                classes: 48,
                avg_degree: 16,
                d_in: 128,
                intra_frac: 0.80,
                feature_noise: 0.6,
                label_noise: 0.05,
                seed: 0x9A0D,
            },
            batch: 1024,
            paper: PaperScale {
                n: 2_449_029.0,
                edges: 61.9e6,
                d_in: 100.0,
                classes: 47.0,
                batch: 32768.0,
            },
        },
        DatasetSpec {
            name: "isolate_sim",
            paper_name: "Isolate-3-8M",
            model_config: "products_sim", // shares the artifact shape family
            planted: PlantedConfig {
                n: 262_144,
                classes: 32,
                avg_degree: 16,
                d_in: 128,
                intra_frac: 0.8,
                feature_noise: 0.6,
                label_noise: 0.05,
                seed: 0x150,
            },
            batch: 1024,
            paper: PaperScale {
                n: 3.8e6,
                edges: 68.0e6,
                d_in: 128.0,
                classes: 32.0,
                batch: 32768.0,
            },
        },
        DatasetSpec {
            name: "products14m_sim",
            paper_name: "Products-14M",
            model_config: "products_sim",
            planted: PlantedConfig {
                n: 524_288,
                classes: 32,
                avg_degree: 16,
                d_in: 128,
                intra_frac: 0.8,
                feature_noise: 0.6,
                label_noise: 0.05,
                seed: 0x14D,
            },
            batch: 1024,
            paper: PaperScale {
                n: 14.0e6,
                edges: 115.0e6,
                d_in: 128.0,
                classes: 32.0,
                batch: 32768.0,
            },
        },
        DatasetSpec {
            // end-to-end driver workload (examples/train_e2e.rs): larger
            // model (d_h=512, L=4) on a mid-size graph
            name: "e2e_big",
            paper_name: "(e2e driver)",
            model_config: "e2e_big",
            planted: PlantedConfig {
                n: 65_536,
                classes: 32,
                avg_degree: 16,
                d_in: 256,
                intra_frac: 0.8,
                feature_noise: 0.6,
                label_noise: 0.05,
                seed: 0xE2E,
            },
            batch: 1024,
            paper: PaperScale {
                n: 65_536.0,
                edges: 1.0e6,
                d_in: 256.0,
                classes: 32.0,
                batch: 1024.0,
            },
        },
        DatasetSpec {
            name: "papers100m_sim",
            paper_name: "ogbn-papers100M",
            model_config: "products_sim",
            planted: PlantedConfig {
                n: 1_048_576,
                classes: 32,
                avg_degree: 8,
                d_in: 128,
                intra_frac: 0.8,
                feature_noise: 0.6,
                label_noise: 0.05,
                seed: 0x100A11,
            },
            batch: 1024,
            paper: PaperScale {
                n: 111.0e6,
                edges: 1.6e9,
                d_in: 128.0,
                classes: 172.0,
                batch: 32768.0,
            },
        },
        DatasetSpec {
            // identical graph to papers100m_sim (same generator seed) but
            // registered as the out-of-core workload: pack once into a
            // .pallas container, then train with a bounded cache budget
            name: "papers100m_ooc",
            paper_name: "ogbn-papers100M (OOC)",
            model_config: "products_sim",
            planted: PlantedConfig {
                n: 1_048_576,
                classes: 32,
                avg_degree: 8,
                d_in: 128,
                intra_frac: 0.8,
                feature_noise: 0.6,
                label_noise: 0.05,
                seed: 0x100A11,
            },
            batch: 1024,
            paper: PaperScale {
                n: 111.0e6,
                edges: 1.6e9,
                d_in: 128.0,
                classes: 172.0,
                batch: 32768.0,
            },
        },
    ]
}

/// Look up a dataset spec by registry name.
pub fn spec(name: &str) -> Option<DatasetSpec> {
    registry().into_iter().find(|s| s.name == name)
}

/// Generate (deterministically) the named dataset.
pub fn load(name: &str) -> Option<Dataset> {
    let s = spec(name)?;
    let mut d = planted_partition(&s.planted);
    d.name = s.name.to_string();
    Some(d)
}

/// `232965` -> `"232,965"`.
fn group_digits(v: u64) -> String {
    let s = v.to_string();
    let mut out = String::with_capacity(s.len() + s.len() / 3);
    for (i, ch) in s.chars().enumerate() {
        if i > 0 && (s.len() - i) % 3 == 0 {
            out.push(',');
        }
        out.push(ch);
    }
    out
}

/// Render the module-doc dataset table from [`registry`] — header, separator
/// and one row per dataset.  The `doc_table_matches_module_docs` test
/// asserts these exact lines appear in this module's doc comment, so the
/// hand-pasted table can never drift from the code.
pub fn doc_table() -> Vec<String> {
    let mut out = vec![
        format!(
            "| {:<15} | {:<21} | {:>11} | {:>9} | {:>4} | {:>7} | {:>5} |",
            "name", "paper dataset", "paper N", "local N", "d_in", "classes", "batch"
        ),
        format!(
            "|{}|{}|{}|{}|{}|{}|{}|",
            "-".repeat(17),
            "-".repeat(23),
            "-".repeat(13),
            "-".repeat(11),
            "-".repeat(6),
            "-".repeat(9),
            "-".repeat(7)
        ),
    ];
    for s in registry() {
        out.push(format!(
            "| {:<15} | {:<21} | {:>11} | {:>9} | {:>4} | {:>7} | {:>5} |",
            s.name,
            s.paper_name,
            group_digits(s.paper.n as u64),
            group_digits(s.planted.n as u64),
            s.planted.d_in,
            s.planted.classes,
            s.batch
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_names_unique_and_resolvable() {
        let r = registry();
        for s in &r {
            assert!(spec(s.name).is_some());
        }
        let mut names: Vec<_> = r.iter().map(|s| s.name).collect();
        names.sort();
        names.dedup();
        assert_eq!(names.len(), r.len());
    }

    #[test]
    fn tiny_loads_and_matches_spec() {
        let d = load("tiny").unwrap();
        assert_eq!(d.n, 512);
        assert_eq!(d.classes, 4);
        assert_eq!(d.features.cols, 16);
    }

    #[test]
    fn unknown_dataset_is_none() {
        assert!(load("nope").is_none());
    }

    #[test]
    fn papers100m_ooc_mirrors_papers100m_sim() {
        let sim = spec("papers100m_sim").unwrap();
        let ooc = spec("papers100m_ooc").unwrap();
        // same generator config (seed included): identical graph bytes, so
        // packing either name produces the same .pallas content
        assert_eq!(format!("{:?}", sim.planted), format!("{:?}", ooc.planted));
        assert_eq!(ooc.paper_name, "ogbn-papers100M (OOC)");
    }

    #[test]
    fn group_digits_formats() {
        assert_eq!(group_digits(0), "0");
        assert_eq!(group_digits(512), "512");
        assert_eq!(group_digits(65_536), "65,536");
        assert_eq!(group_digits(111_000_000), "111,000,000");
    }

    #[test]
    fn doc_table_matches_module_docs() {
        let src = include_str!("datasets.rs");
        let table = doc_table();
        for line in &table {
            assert!(
                src.contains(&format!("//! {line}")),
                "dataset doc table drifted from registry(); regenerate this line:\n{line}"
            );
        }
        // and no stale rows: the doc comment has exactly the rendered lines
        let doc_rows = src
            .lines()
            .filter(|l| l.trim_start().starts_with("//! |"))
            .count();
        assert_eq!(doc_rows, table.len(), "doc table has extra/stale rows");
    }
}
