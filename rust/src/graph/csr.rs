//! Compressed sparse row graphs with the normalizations GCN training needs.

/// CSR sparse matrix / graph adjacency.  `indptr.len() == rows + 1`,
/// column indices are global vertex ids, values are edge weights.
#[derive(Clone, Debug)]
pub struct Csr {
    /// Number of rows.
    pub rows: usize,
    /// Number of columns.
    pub cols: usize,
    /// Row offsets into `indices`/`values` (`rows + 1` entries).
    pub indptr: Vec<usize>,
    /// Column ids per stored entry, sorted within each row.
    pub indices: Vec<u32>,
    /// Edge weights per stored entry.
    pub values: Vec<f32>,
}

impl Csr {
    /// Matrix with no stored entries.
    pub fn empty(rows: usize, cols: usize) -> Csr {
        Csr { rows, cols, indptr: vec![0; rows + 1], indices: vec![], values: vec![] }
    }

    /// Number of stored entries.
    pub fn nnz(&self) -> usize {
        self.indices.len()
    }

    /// Row `r` as `(column ids, values)` slices.
    pub fn row(&self, r: usize) -> (&[u32], &[f32]) {
        let (a, b) = (self.indptr[r], self.indptr[r + 1]);
        (&self.indices[a..b], &self.values[a..b])
    }

    /// Number of stored entries in row `r`.
    pub fn row_nnz(&self, r: usize) -> usize {
        self.indptr[r + 1] - self.indptr[r]
    }

    /// Build from (row, col, val) triples (unsorted ok; duplicates summed).
    pub fn from_triples(rows: usize, cols: usize, mut t: Vec<(u32, u32, f32)>) -> Csr {
        t.sort_unstable_by_key(|&(r, c, _)| ((r as u64) << 32) | c as u64);
        let mut indptr = vec![0usize; rows + 1];
        let mut indices = Vec::with_capacity(t.len());
        let mut values: Vec<f32> = Vec::with_capacity(t.len());
        let mut last: Option<(u32, u32)> = None;
        for (r, c, v) in t {
            debug_assert!((r as usize) < rows && (c as usize) < cols);
            if last == Some((r, c)) {
                *values.last_mut().unwrap() += v;
            } else {
                indptr[r as usize + 1] += 1;
                indices.push(c);
                values.push(v);
                last = Some((r, c));
            }
        }
        for i in 0..rows {
            indptr[i + 1] += indptr[i];
        }
        Csr { rows, cols, indptr, indices, values }
    }

    /// Build from triples that are already sorted by `(row, col)` with no
    /// duplicate coordinates — the stream in-order subgraph induction
    /// emits.  Skips `from_triples`' `O(E log E)` sort and duplicate-sum
    /// pass; on such input the output is byte-identical to
    /// [`Csr::from_triples`] (asserted here and, against the induction
    /// fast path's directly-assembled CSR, in `tests/induction.rs`).
    pub fn from_sorted_triples(rows: usize, cols: usize, t: &[(u32, u32, f32)]) -> Csr {
        let mut out = Csr::empty(0, 0);
        Csr::from_sorted_triples_into(rows, cols, t, &mut out);
        out
    }

    /// Workspace variant of [`Csr::from_sorted_triples`]: emits into
    /// `out`, reusing its buffers (zero allocations once their capacities
    /// have grown to fit).
    pub fn from_sorted_triples_into(
        rows: usize,
        cols: usize,
        t: &[(u32, u32, f32)],
        out: &mut Csr,
    ) {
        out.rows = rows;
        out.cols = cols;
        out.indptr.clear();
        out.indptr.resize(rows + 1, 0);
        out.indices.clear();
        out.values.clear();
        out.indices.reserve(t.len());
        out.values.reserve(t.len());
        #[cfg(debug_assertions)]
        let mut last: Option<(u32, u32)> = None;
        for &(r, c, v) in t {
            debug_assert!((r as usize) < rows && (c as usize) < cols);
            #[cfg(debug_assertions)]
            {
                debug_assert!(
                    last.is_none() || last.unwrap() < (r, c),
                    "triples must be strictly (row, col)-sorted with no duplicates"
                );
                last = Some((r, c));
            }
            out.indptr[r as usize + 1] += 1;
            out.indices.push(c);
            out.values.push(v);
        }
        for i in 0..rows {
            out.indptr[i + 1] += out.indptr[i];
        }
    }

    /// Transpose (CSC view materialized as CSR).
    pub fn transpose(&self) -> Csr {
        let mut out = Csr::empty(0, 0);
        self.transpose_into(&mut out, &mut Vec::new());
        out
    }

    /// Workspace variant of [`Csr::transpose`]: writes the transpose into
    /// `out` reusing its buffers, with `cursor` as the per-column
    /// insertion scratch.  Byte-identical to [`Csr::transpose`] (which
    /// delegates here with fresh buffers).
    pub fn transpose_into(&self, out: &mut Csr, cursor: &mut Vec<usize>) {
        out.rows = self.cols;
        out.cols = self.rows;
        out.indptr.clear();
        out.indptr.resize(self.cols + 1, 0);
        for &c in &self.indices {
            out.indptr[c as usize + 1] += 1;
        }
        for i in 0..self.cols {
            out.indptr[i + 1] += out.indptr[i];
        }
        cursor.clear();
        cursor.extend_from_slice(&out.indptr[..self.cols]);
        out.indices.clear();
        out.indices.resize(self.nnz(), 0);
        out.values.clear();
        out.values.resize(self.nnz(), 0.0);
        for r in 0..self.rows {
            let (cs, vs) = self.row(r);
            for (&c, &v) in cs.iter().zip(vs) {
                let slot = cursor[c as usize];
                out.indices[slot] = r as u32;
                out.values[slot] = v;
                cursor[c as usize] += 1;
            }
        }
    }

    /// y = self @ x  (SpMM into a dense matrix).
    pub fn spmm(&self, x: &crate::tensor::Mat) -> crate::tensor::Mat {
        let mut y = crate::tensor::Mat::zeros(self.rows, x.cols);
        self.spmm_into(x, &mut y);
        y
    }

    /// Workspace SpMM: `y = self @ x` into a caller-provided `self.rows x
    /// x.cols` buffer, row-block parallel.  Each worker owns a disjoint
    /// block of output rows and runs the identical per-row accumulation, so
    /// the result is bitwise identical for any thread count.
    pub fn spmm_into(&self, x: &crate::tensor::Mat, y: &mut crate::tensor::Mat) {
        self.spmm_into_threads(x, y, crate::tensor::pool::num_threads());
    }

    /// `spmm_into` with an explicit thread count (1 = serial reference).
    pub fn spmm_into_threads(
        &self,
        x: &crate::tensor::Mat,
        y: &mut crate::tensor::Mat,
        threads: usize,
    ) {
        assert_eq!(self.cols, x.rows, "spmm shape");
        assert_eq!((y.rows, y.cols), (self.rows, x.cols));
        y.data.fill(0.0);
        let d = x.cols;
        // 2 flops per nnz per column, plus a row of x streamed per nnz
        let work = 2 * self.nnz() * d;
        let x_data = &x.data;
        crate::tensor::pool::par_row_blocks(&mut y.data, self.rows, d, threads, work, |r0, yb| {
            let rows = if d == 0 { 0 } else { yb.len() / d };
            for i in 0..rows {
                let (cs, vs) = self.row(r0 + i);
                let yrow = &mut yb[i * d..(i + 1) * d];
                for (&c, &v) in cs.iter().zip(vs) {
                    let xrow = &x_data[c as usize * d..(c as usize + 1) * d];
                    crate::tensor::simd::axpy(yrow, v, xrow);
                }
            }
        });
    }

    /// Fused aggregate + transform (the paper's kernel-fusion optimization):
    /// `out = (self @ x) @ w` in a single pass over the rows, never
    /// materializing the aggregated `self @ x`.  Optionally stores the
    /// aggregation into `agg_out` (the backward pass needs it) at no extra
    /// traversal cost.  Each output row is produced by the same per-row
    /// aggregation followed by the same GEMM row kernel as the unfused
    /// pair, so results are bitwise identical to `spmm` + `matmul`.
    pub fn spmm_matmul_into(
        &self,
        x: &crate::tensor::Mat,
        w: &crate::tensor::Mat,
        mut agg_out: Option<&mut crate::tensor::Mat>,
        out: &mut crate::tensor::Mat,
    ) {
        self.spmm_matmul_into_threads(x, w, agg_out.take(), out, crate::tensor::pool::num_threads())
    }

    /// `spmm_matmul_into` with an explicit thread count.
    pub fn spmm_matmul_into_threads(
        &self,
        x: &crate::tensor::Mat,
        w: &crate::tensor::Mat,
        agg_out: Option<&mut crate::tensor::Mat>,
        out: &mut crate::tensor::Mat,
        threads: usize,
    ) {
        assert_eq!(self.cols, x.rows, "spmm_matmul shape (adj/x)");
        assert_eq!(x.cols, w.rows, "spmm_matmul shape (x/w)");
        assert_eq!((out.rows, out.cols), (self.rows, w.cols));
        let d = x.cols;
        let p = w.cols;
        let agg = match agg_out {
            Some(a) => {
                assert_eq!((a.rows, a.cols), (self.rows, d));
                a.data.fill(0.0);
                Some(&mut a.data)
            }
            None => None,
        };
        out.data.fill(0.0);
        let work = 2 * self.nnz() * d + 2 * self.rows * d * p;
        let x_data = &x.data;
        let w_data = &w.data;
        match agg {
            None => {
                crate::tensor::pool::par_row_blocks(
                    &mut out.data,
                    self.rows,
                    p,
                    threads,
                    work,
                    |r0, ob| {
                        let rows = if p == 0 { 0 } else { ob.len() / p };
                        let mut aggrow = vec![0.0f32; d];
                        for i in 0..rows {
                            let (cs, vs) = self.row(r0 + i);
                            aggrow.fill(0.0);
                            for (&c, &v) in cs.iter().zip(vs) {
                                let xrow = &x_data[c as usize * d..(c as usize + 1) * d];
                                crate::tensor::simd::axpy(&mut aggrow, v, xrow);
                            }
                            gemm_row(&aggrow, w_data, p, &mut ob[i * p..(i + 1) * p]);
                        }
                    },
                );
            }
            Some(agg_data) => {
                crate::tensor::pool::par_row_blocks_pair(
                    agg_data,
                    d,
                    &mut out.data,
                    p,
                    self.rows,
                    threads,
                    work,
                    |r0, r1, ab, ob| fused_rows(self, r0, r1, x_data, w_data, d, p, ab, ob),
                );
            }
        }
    }

    /// Dense-ify into a Mat (only for small matrices / tests).
    pub fn to_dense(&self) -> crate::tensor::Mat {
        let mut m = crate::tensor::Mat::zeros(self.rows, self.cols);
        for r in 0..self.rows {
            let (cs, vs) = self.row(r);
            for (&c, &v) in cs.iter().zip(vs) {
                m.data[r * self.cols + c as usize] += v;
            }
        }
        m
    }

    /// Out-degrees including weights = row sums.
    pub fn row_sums(&self) -> Vec<f32> {
        (0..self.rows)
            .map(|r| self.row(r).1.iter().sum::<f32>())
            .collect()
    }

    /// Structural degree (nnz per row).
    pub fn degrees(&self) -> Vec<usize> {
        (0..self.rows).map(|r| self.row_nnz(r)).collect()
    }

    /// Whether (r, c) is present (binary search within the row).
    pub fn has_edge(&self, r: usize, c: u32) -> bool {
        self.row(r).0.binary_search(&c).is_ok()
    }

    /// GCN normalization with self-loops (Eq. 3):
    /// `Â = A + I`, `D̂ = deg(Â)`, returns `D̂^-1/2 Â D̂^-1/2`.
    pub fn gcn_normalize(&self) -> Csr {
        assert_eq!(self.rows, self.cols, "adjacency must be square");
        let n = self.rows;
        // structural degree of A + I (values treated as existence)
        let mut deg = vec![1.0f32; n]; // self loop
        for r in 0..n {
            let (cs, _) = self.row(r);
            for &c in cs {
                if c as usize != r {
                    deg[r] += 1.0;
                }
            }
        }
        let dinv: Vec<f32> = deg.iter().map(|&d| 1.0 / d.sqrt()).collect();
        let mut triples = Vec::with_capacity(self.nnz() + n);
        for r in 0..n {
            let (cs, _) = self.row(r);
            for &c in cs {
                if c as usize != r {
                    triples.push((r as u32, c, dinv[r] * dinv[c as usize]));
                }
            }
            triples.push((r as u32, r as u32, dinv[r] * dinv[r]));
        }
        Csr::from_triples(n, n, triples)
    }

    /// Make structurally symmetric (max of both directions), no values dup.
    pub fn symmetrize(&self) -> Csr {
        assert_eq!(self.rows, self.cols);
        let mut triples: Vec<(u32, u32, f32)> = Vec::with_capacity(self.nnz() * 2);
        for r in 0..self.rows {
            let (cs, vs) = self.row(r);
            for (&c, &v) in cs.iter().zip(vs) {
                triples.push((r as u32, c, v));
                triples.push((c, r as u32, v));
            }
        }
        // dedupe by keeping max
        triples.sort_unstable_by_key(|&(r, c, _)| ((r as u64) << 32) | c as u64);
        triples.dedup_by(|a, b| {
            if a.0 == b.0 && a.1 == b.1 {
                b.2 = b.2.max(a.2);
                true
            } else {
                false
            }
        });
        Csr::from_triples(self.rows, self.cols, triples)
    }
}

/// One GEMM output row: `crow += arow @ b` through the SAME inner kernel
/// as `tensor::matmul_into` (a one-row block), so fused and unfused paths
/// agree bitwise by construction rather than by parallel maintenance.
#[inline]
fn gemm_row(arow: &[f32], b: &[f32], n: usize, crow: &mut [f32]) {
    crate::tensor::gemm_rows(arow, arow.len(), b, n, crow);
}

/// Fused aggregate+transform over rows [r0, r1): aggregation lands in
/// `agg_block` (pre-zeroed), transformed rows in `out_block` (pre-zeroed).
#[allow(clippy::too_many_arguments)]
fn fused_rows(
    a: &Csr,
    r0: usize,
    r1: usize,
    x: &[f32],
    w: &[f32],
    d: usize,
    p: usize,
    agg_block: &mut [f32],
    out_block: &mut [f32],
) {
    for (i, r) in (r0..r1).enumerate() {
        let (cs, vs) = a.row(r);
        let arow = &mut agg_block[i * d..(i + 1) * d];
        for (&c, &v) in cs.iter().zip(vs) {
            let xrow = &x[c as usize * d..(c as usize + 1) * d];
            crate::tensor::simd::axpy(arow, v, xrow);
        }
        gemm_row(arow, w, p, &mut out_block[i * p..(i + 1) * p]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Mat;
    use crate::util::rng::Rng;

    fn random_csr(rows: usize, cols: usize, density: f64, seed: u64) -> Csr {
        let mut rng = Rng::new(seed);
        let mut t = vec![];
        for r in 0..rows {
            for c in 0..cols {
                if rng.f64() < density {
                    t.push((r as u32, c as u32, rng.f32() + 0.1));
                }
            }
        }
        Csr::from_triples(rows, cols, t)
    }

    #[test]
    fn from_triples_sorts_and_sums_duplicates() {
        let c = Csr::from_triples(
            2,
            3,
            vec![(1, 2, 1.0), (0, 1, 2.0), (1, 2, 3.0), (0, 0, 1.0)],
        );
        assert_eq!(c.nnz(), 3);
        assert_eq!(c.row(0).0, &[0, 1]);
        assert_eq!(c.row(1), (&[2u32][..], &[4.0f32][..]));
    }

    #[test]
    fn from_sorted_triples_matches_from_triples() {
        // sorted, duplicate-free triple stream (what induction emits)
        let t = vec![(0u32, 0u32, 1.0f32), (0, 2, 2.0), (1, 1, 3.0), (3, 0, 4.0)];
        let want = Csr::from_triples(4, 3, t.clone());
        let got = Csr::from_sorted_triples(4, 3, &t);
        assert_eq!(got.indptr, want.indptr);
        assert_eq!(got.indices, want.indices);
        assert_eq!(got.values, want.values);
        assert_eq!((got.rows, got.cols), (want.rows, want.cols));
    }

    #[test]
    fn from_sorted_triples_into_reuses_buffers() {
        let mut out = Csr::empty(0, 0);
        let mut cursor = Vec::new();
        for seed in 0..4u64 {
            let a = random_csr(17, 11, 0.3, seed);
            let mut t: Vec<(u32, u32, f32)> = Vec::new();
            for r in 0..a.rows {
                let (cs, vs) = a.row(r);
                for (&c, &v) in cs.iter().zip(vs) {
                    t.push((r as u32, c, v));
                }
            }
            Csr::from_sorted_triples_into(17, 11, &t, &mut out);
            assert_eq!(out.indptr, a.indptr, "seed {seed}");
            assert_eq!(out.indices, a.indices);
            assert_eq!(out.values, a.values);
            // transpose through the reused-buffer variant too
            let mut tr = Csr::empty(0, 0);
            a.transpose_into(&mut tr, &mut cursor);
            let want = a.transpose();
            assert_eq!(tr.indptr, want.indptr, "seed {seed}");
            assert_eq!(tr.indices, want.indices);
            assert_eq!(tr.values, want.values);
        }
    }

    #[test]
    fn transpose_roundtrip() {
        let a = random_csr(13, 7, 0.3, 1);
        let tt = a.transpose().transpose();
        assert_eq!(a.indptr, tt.indptr);
        assert_eq!(a.indices, tt.indices);
        assert_eq!(a.values, tt.values);
    }

    #[test]
    fn transpose_matches_dense() {
        let a = random_csr(9, 5, 0.4, 2);
        assert!(a.transpose().to_dense().allclose(&a.to_dense().transpose(), 1e-6, 0.0));
    }

    #[test]
    fn spmm_matches_dense_matmul() {
        let a = random_csr(11, 8, 0.35, 3);
        let mut rng = Rng::new(4);
        let x = Mat::randn(8, 6, &mut rng, 1.0);
        assert!(a.spmm(&x).allclose(&a.to_dense().matmul(&x), 1e-4, 1e-4));
    }

    #[test]
    fn parallel_spmm_bitwise_matches_serial() {
        let a = random_csr(257, 120, 0.2, 8);
        let mut rng = Rng::new(9);
        let x = Mat::randn(120, 33, &mut rng, 1.0);
        let mut serial = Mat::zeros(257, 33);
        a.spmm_into_threads(&x, &mut serial, 1);
        for threads in [2, 3, 4, 8] {
            let mut par = Mat::zeros(257, 33);
            a.spmm_into_threads(&x, &mut par, threads);
            assert_eq!(serial.data, par.data, "spmm t={threads}");
        }
    }

    #[test]
    fn fused_spmm_matmul_bitwise_matches_unfused() {
        let a = random_csr(190, 90, 0.25, 10);
        let mut rng = Rng::new(11);
        let x = Mat::randn(90, 40, &mut rng, 1.0);
        let w = Mat::randn(40, 24, &mut rng, 1.0);
        let want_agg = a.spmm(&x);
        let mut want = Mat::zeros(190, 24);
        crate::tensor::matmul_into_threads(&want_agg, &w, &mut want, false, 1);
        for threads in [1usize, 2, 4, 8] {
            let mut out = Mat::zeros(190, 24);
            let mut agg = Mat::zeros(190, 40);
            a.spmm_matmul_into_threads(&x, &w, Some(&mut agg), &mut out, threads);
            assert_eq!(out.data, want.data, "fused (agg) t={threads}");
            assert_eq!(agg.data, want_agg.data, "agg t={threads}");
            let mut out2 = Mat::zeros(190, 24);
            a.spmm_matmul_into_threads(&x, &w, None, &mut out2, threads);
            assert_eq!(out2.data, want.data, "fused (no agg) t={threads}");
        }
    }

    #[test]
    fn spmm_handles_empty_rows_and_one_column() {
        // rows 1 and 3 are empty; x has a single column
        let a = Csr::from_triples(5, 4, vec![(0, 1, 2.0), (2, 0, 1.0), (2, 3, 0.5), (4, 2, 3.0)]);
        let x = Mat::from_vec(4, 1, vec![1.0, 2.0, 3.0, 4.0]);
        let y = a.spmm(&x);
        assert_eq!(y.data, vec![4.0, 0.0, 3.0, 0.0, 9.0]);
        let mut fused = Mat::zeros(5, 1);
        let w = Mat::eye(1);
        a.spmm_matmul_into(&x, &w, None, &mut fused);
        assert_eq!(fused.data, y.data);
    }

    #[test]
    fn gcn_normalize_rows_bounded_and_symmetric() {
        let a = random_csr(20, 20, 0.15, 5).symmetrize();
        let n = a.gcn_normalize();
        // normalized matrix of a symmetric graph is symmetric
        assert!(n.to_dense().allclose(&n.to_dense().transpose(), 1e-5, 0.0));
        // self loops present with positive weight
        for r in 0..20 {
            assert!(n.has_edge(r, r as u32));
        }
        // spectral-ish sanity: all values in (0, 1]
        assert!(n.values.iter().all(|&v| v > 0.0 && v <= 1.0 + 1e-6));
    }

    #[test]
    fn symmetrize_makes_symmetric() {
        let a = random_csr(15, 15, 0.1, 6);
        let s = a.symmetrize();
        for r in 0..15 {
            let (cs, _) = s.row(r);
            for &c in cs {
                assert!(s.has_edge(c as usize, r as u32));
            }
        }
    }

    #[test]
    fn degrees_and_row_sums() {
        let c = Csr::from_triples(3, 3, vec![(0, 1, 2.0), (0, 2, 3.0), (2, 0, 1.0)]);
        assert_eq!(c.degrees(), vec![2, 0, 1]);
        assert_eq!(c.row_sums(), vec![5.0, 0.0, 1.0]);
    }
}
