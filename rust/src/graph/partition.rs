//! 2D block partitioning of the adjacency matrix across a process grid.
//!
//! Within one data-parallel group each rank owns a contiguous row range
//! `[R0, R1)` and column range `[C0, C1)` of the global N x N adjacency
//! (paper §IV-B): the CSR shard keeps *local* row indexing and *global*
//! column ids, exactly what Algorithm 2 consumes.

use super::csr::Csr;
use super::store::GraphAccess;

/// Split `n` into `parts` contiguous ranges, remainder spread over the
/// leading parts. Returns the boundaries (len = parts + 1).
pub fn block_bounds(n: usize, parts: usize) -> Vec<usize> {
    assert!(parts > 0);
    let base = n / parts;
    let rem = n % parts;
    let mut b = Vec::with_capacity(parts + 1);
    let mut acc = 0;
    b.push(0);
    for i in 0..parts {
        acc += base + usize::from(i < rem);
        b.push(acc);
    }
    b
}

/// One rank's shard of the adjacency.
#[derive(Clone, Debug)]
pub struct CsrShard {
    /// Start of the global row range `[r0, r1)`.
    pub r0: usize,
    /// End (exclusive) of the global row range.
    pub r1: usize,
    /// Start of the global column range `[c0, c1)`.
    pub c0: usize,
    /// End (exclusive) of the global column range.
    pub c1: usize,
    /// Shard contents: rows indexed locally (`0..r1-r0`), columns remain
    /// GLOBAL ids.
    pub csr: Csr,
}

impl CsrShard {
    /// Number of rows owned by this shard.
    pub fn local_rows(&self) -> usize {
        self.r1 - self.r0
    }
}

/// Extract a single shard (rows [r0,r1), cols [c0,c1)) without building the
/// full partition — used by PMM ranks, which each need only their own block.
/// Rows are borrowed zero-copy (this runs per layer in the engine's
/// full-graph eval); the out-of-core variant is [`extract_shard_from`], and
/// `extract_shard_matches_direct_row_filter` pins both to the same oracle.
pub fn extract_shard(a: &Csr, r0: usize, r1: usize, c0: usize, c1: usize) -> CsrShard {
    let mut indptr = Vec::with_capacity(r1 - r0 + 1);
    let mut indices = Vec::new();
    let mut values = Vec::new();
    indptr.push(0);
    for r in r0..r1 {
        let (cs, vs) = a.row(r);
        let lo = cs.partition_point(|&c| (c as usize) < c0);
        let hi = cs.partition_point(|&c| (c as usize) < c1);
        indices.extend_from_slice(&cs[lo..hi]);
        values.extend_from_slice(&vs[lo..hi]);
        indptr.push(indices.len());
    }
    CsrShard {
        r0,
        r1,
        c0,
        c1,
        csr: Csr { rows: r1 - r0, cols: a.cols, indptr, indices, values },
    }
}

/// As `extract_shard`, but generic over [`GraphAccess`] — so a PMM/sampler
/// rank can materialize its own block of an out-of-core graph without the
/// full adjacency ever residing in RAM.  For an in-memory `Csr` source the
/// output is identical (bitwise) to `extract_shard`.
pub fn extract_shard_from<G: GraphAccess + ?Sized>(
    a: &G,
    r0: usize,
    r1: usize,
    c0: usize,
    c1: usize,
) -> CsrShard {
    let mut indptr = Vec::with_capacity(r1 - r0 + 1);
    let mut indices = Vec::new();
    let mut values = Vec::new();
    let (mut rbuf_c, mut rbuf_v) = (Vec::new(), Vec::new());
    indptr.push(0);
    for r in r0..r1 {
        a.read_row(r, &mut rbuf_c, &mut rbuf_v);
        let lo = rbuf_c.partition_point(|&c| (c as usize) < c0);
        let hi = rbuf_c.partition_point(|&c| (c as usize) < c1);
        indices.extend_from_slice(&rbuf_c[lo..hi]);
        values.extend_from_slice(&rbuf_v[lo..hi]);
        indptr.push(indices.len());
    }
    CsrShard {
        r0,
        r1,
        c0,
        c1,
        csr: Csr { rows: r1 - r0, cols: a.cols(), indptr, indices, values },
    }
}

/// Partition `a` into an `pr x pc` grid of shards (row-major order).
pub fn partition_2d(a: &Csr, pr: usize, pc: usize) -> Vec<CsrShard> {
    assert_eq!(a.rows, a.cols);
    let rb = block_bounds(a.rows, pr);
    let cb = block_bounds(a.cols, pc);
    let mut shards = Vec::with_capacity(pr * pc);
    for i in 0..pr {
        for j in 0..pc {
            let (r0, r1) = (rb[i], rb[i + 1]);
            let (c0, c1) = (cb[j], cb[j + 1]);
            let mut indptr = Vec::with_capacity(r1 - r0 + 1);
            let mut indices = Vec::new();
            let mut values = Vec::new();
            indptr.push(0);
            for r in r0..r1 {
                let (cs, vs) = a.row(r);
                // columns are sorted: binary search the [c0, c1) window
                let lo = cs.partition_point(|&c| (c as usize) < c0);
                let hi = cs.partition_point(|&c| (c as usize) < c1);
                indices.extend_from_slice(&cs[lo..hi]);
                values.extend_from_slice(&vs[lo..hi]);
                indptr.push(indices.len());
            }
            shards.push(CsrShard {
                r0,
                r1,
                c0,
                c1,
                csr: Csr { rows: r1 - r0, cols: a.cols, indptr, indices, values },
            });
        }
    }
    shards
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generate::rmat;

    #[test]
    fn block_bounds_cover_exactly() {
        for &(n, p) in &[(10usize, 3usize), (7, 7), (100, 8), (5, 1), (3, 5)] {
            let b = block_bounds(n, p);
            assert_eq!(b.len(), p + 1);
            assert_eq!(b[0], 0);
            assert_eq!(*b.last().unwrap(), n);
            for w in b.windows(2) {
                assert!(w[0] <= w[1]);
                assert!(w[1] - w[0] <= n / p + 1);
            }
        }
    }

    #[test]
    fn partition_preserves_all_edges() {
        let g = rmat(7, 6, 1).gcn_normalize();
        for &(pr, pc) in &[(1usize, 1usize), (2, 2), (3, 2), (4, 4)] {
            let shards = partition_2d(&g, pr, pc);
            assert_eq!(shards.len(), pr * pc);
            let total: usize = shards.iter().map(|s| s.csr.nnz()).sum();
            assert_eq!(total, g.nnz(), "grid {pr}x{pc}");
            // every edge in its shard is within the shard's ranges
            for s in &shards {
                for lr in 0..s.csr.rows {
                    let (cs, _) = s.csr.row(lr);
                    for &c in cs {
                        assert!((c as usize) >= s.c0 && (c as usize) < s.c1);
                        assert!(g.has_edge(s.r0 + lr, c));
                    }
                }
            }
        }
    }

    #[test]
    fn shard_values_match_source() {
        let g = rmat(6, 4, 2).gcn_normalize();
        let shards = partition_2d(&g, 2, 3);
        let dense = g.to_dense();
        for s in &shards {
            for lr in 0..s.csr.rows {
                let (cs, vs) = s.csr.row(lr);
                for (&c, &v) in cs.iter().zip(vs) {
                    assert_eq!(dense.at(s.r0 + lr, c as usize), v);
                }
            }
        }
    }

    #[test]
    fn extract_shard_matches_direct_row_filter() {
        // independent oracle: filter each source row by the column window;
        // both the zero-copy and the GraphAccess-generic extractor must
        // match it (and hence each other, bitwise)
        let g = rmat(7, 6, 4).gcn_normalize();
        for s in [extract_shard(&g, 10, 50, 20, 90), extract_shard_from(&g, 10, 50, 20, 90)] {
            assert_eq!((s.r0, s.r1, s.c0, s.c1), (10, 50, 20, 90));
            assert_eq!(s.csr.cols, g.cols);
            assert_eq!(s.csr.rows, 40);
            for lr in 0..s.csr.rows {
                let (cs, vs) = s.csr.row(lr);
                let (gcs, gvs) = g.row(10 + lr);
                let want: Vec<(u32, f32)> = gcs
                    .iter()
                    .zip(gvs)
                    .filter(|&(&c, _)| (20..90).contains(&(c as usize)))
                    .map(|(&c, &v)| (c, v))
                    .collect();
                let got: Vec<(u32, f32)> = cs.iter().zip(vs).map(|(&c, &v)| (c, v)).collect();
                assert_eq!(got, want, "row {lr}");
            }
        }
    }

    #[test]
    fn one_by_one_partition_is_identity() {
        let g = rmat(6, 4, 3).gcn_normalize();
        let s = &partition_2d(&g, 1, 1)[0];
        assert_eq!(s.csr.indptr, g.indptr);
        assert_eq!(s.csr.indices, g.indices);
    }
}
