//! Graph substrate: CSR storage, synthetic dataset generators, and the 2D
//! block partitioner that feeds the distributed sampler (Algorithm 2).

pub mod csr;
pub mod datasets;
pub mod generate;
pub mod partition;

pub use csr::Csr;
pub use datasets::{load, registry, spec, DatasetSpec};
pub use generate::{planted_partition, rmat, Dataset, PlantedConfig};
pub use partition::{block_bounds, partition_2d, CsrShard};
