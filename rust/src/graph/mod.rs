//! Graph substrate: CSR storage, synthetic dataset generators, the 2D block
//! partitioner that feeds the distributed sampler (Algorithm 2), and the
//! out-of-core `.pallas` binary store for larger-than-RAM training.

pub mod csr;
pub mod datasets;
pub mod generate;
pub mod partition;
pub mod store;

pub use csr::Csr;
pub use datasets::{load, registry, spec, DatasetSpec};
pub use generate::{planted_partition, rmat, Dataset, PlantedConfig};
pub use partition::{block_bounds, extract_shard_from, partition_2d, CsrShard};
pub use store::{open_or_pack, pack, pack_with, GraphAccess, OocGraph, VertexData};
