//! Out-of-core binary graph store: the `.pallas` on-disk container and its
//! bounded-memory reader, which let the mini-batch pipeline train on graphs
//! that never fully reside in RAM (the papers100M scenario of §VI-C).
//!
//! # Container format (version 2, little-endian)
//!
//! ```text
//! header (64 B): magic "PALLASG1" | version u32 | flags u32
//!                | n u64 | nnz u64 | d_in u64 | classes u64
//!                | source-tag u64 (hash of the dataset name) | 8 B reserved
//! sections:      indptr   (n+1) x u64     CSR row offsets (normalized adj)
//!                indices  nnz x u32       column ids, sorted per row
//!                values   nnz x f32       GCN-normalized edge weights
//!                features n x d_in x f32|bf16  row-major vertex features
//!                labels   n x u32
//!                split    n x u8          0 = train, 1 = val, 2 = test
//!                crcs     6 x u32         per-section CRC32, section order
//! ```
//!
//! Flags bit 0 selects the on-disk feature element (§V-B low precision):
//! 0 = f32, 1 = bf16 (`scalegnn pack --feat-precision bf16`).  A bf16 store
//! moves half the feature bytes per batch and the pinned-block cache holds
//! twice the features per byte of budget; reads widen back to f32 through
//! the SIMD batch conversion (`tensor::simd::widen_bf16`).
//!
//! Section offsets are a pure function of the header counts, so the expected
//! file size is known up front: `OocGraph::open` validates magic, version,
//! exact length, every section's CRC32 (streamed with a bounded buffer; a
//! flipped byte anywhere is reported as a *named* corrupt section) AND the
//! full indptr table (monotone from 0 to nnz), returning a clean error —
//! never a panic — on truncated or corrupt files; every later row read is
//! guaranteed in-bounds.  `pack` writes through a `.tmp` sibling and renames
//! into place, so an interrupted pack never leaves a half-written container
//! at the target path.
//!
//! # Reader
//!
//! [`OocGraph`] serves CSR row slices and feature rows through
//! `std::os::unix::fs::FileExt::read_at` (std-only, no mmap, no new
//! dependencies) behind a small pinned-block LRU cache: every graph, feature,
//! label and split byte is read through fixed-size cache blocks, so the
//! resident footprint of the store is bounded by the configured cache budget
//! regardless of graph size (asserted by `tests/ooc_store.rs`).  Only the
//! 64-byte header is kept outside the cache.
//!
//! # Access traits
//!
//! [`GraphAccess`] abstracts a CSR adjacency that may live in RAM
//! ([`Csr`]) or on disk ([`OocGraph`]); [`VertexData`] does the same for
//! per-vertex features/labels/splits ([`Dataset`] or [`OocGraph`]).  The
//! uniform sampler's induced-subgraph builder
//! (`sampling::uniform::induce_rescaled_from`), the distributed shard
//! extractor (`graph::partition::extract_shard_from`) and the trainer's
//! `BatchMaker` are generic over them, which is what makes the in-memory and
//! out-of-core mini-batch paths bitwise identical for the same seed.

use std::collections::HashMap;
use std::fs::File;
use std::io::Write;
use std::os::unix::fs::FileExt;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use anyhow::{anyhow, bail, Context, Result};

use super::csr::Csr;
use super::generate::Dataset;
use crate::checkpoint::Crc32;
use crate::comm::Precision;
use crate::util::bytes::{f32_le, u16_le, u32_le, u64_le};
use crate::util::lock_unpoisoned;

/// File magic: "PALLASG1" (pallas graph container, generation 1).
pub const MAGIC: [u8; 8] = *b"PALLASG1";
/// Current container format version (2 added bf16 features + section CRCs).
pub const VERSION: u32 = 2;
/// Fixed header size in bytes (magic + version + flags + 4 counts + pad).
pub const HEADER_BYTES: u64 = 64;
/// Cache block size: one `read_at` unit of the pinned-block LRU cache.
/// Even, so 2-byte bf16 feature elements never straddle a block boundary.
pub const BLOCK_BYTES: usize = 64 * 1024;
/// Header flags bit 0: features are stored as bf16 (high half of the f32).
const FLAG_FEAT_BF16: u32 = 1;
/// Number of checksummed sections (indptr through split, in file order).
const SECTION_COUNT: usize = 6;

/// Uniform read access to a CSR adjacency that may live in RAM or on disk.
///
/// Implementors must return, for any row, exactly the bytes a [`Csr`] holding
/// the same matrix would: sorted column ids and bit-identical f32 values.
/// That contract is what keeps sampler outputs bitwise identical between the
/// in-memory and out-of-core paths (see `tests/ooc_store.rs`).
///
/// Disk-backed implementations panic on I/O errors *after* a validated open
/// (a mid-training read failure is unrecoverable); all validation errors are
/// surfaced as clean `Result`s at open time.
pub trait GraphAccess: Send + Sync {
    /// Number of rows (vertices) of the adjacency.
    fn rows(&self) -> usize;

    /// Number of columns of the adjacency (equals `rows` for a square graph).
    fn cols(&self) -> usize;

    /// Number of stored entries in row `r`.
    fn row_nnz(&self, r: usize) -> usize;

    /// Copy row `r` into the buffers (cleared first): sorted column ids into
    /// `cols`, matching edge weights into `vals`.
    fn read_row(&self, r: usize, cols: &mut Vec<u32>, vals: &mut Vec<f32>);

    /// Visit row `r` as slices, zero-copy where the implementation can
    /// (in-memory [`Csr`] borrows the row in place; the default reads
    /// through [`GraphAccess::read_row`] into the caller's scratch).  The
    /// sampling fast path uses this so the hot induction loop never pays
    /// a row copy for in-memory graphs.
    fn with_row(
        &self,
        r: usize,
        cols: &mut Vec<u32>,
        vals: &mut Vec<f32>,
        f: &mut dyn FnMut(&[u32], &[f32]),
    ) {
        self.read_row(r, cols, vals);
        f(cols, vals);
    }
}

impl GraphAccess for Csr {
    fn rows(&self) -> usize {
        self.rows
    }

    fn cols(&self) -> usize {
        self.cols
    }

    fn row_nnz(&self, r: usize) -> usize {
        Csr::row_nnz(self, r)
    }

    fn read_row(&self, r: usize, cols: &mut Vec<u32>, vals: &mut Vec<f32>) {
        let (cs, vs) = self.row(r);
        cols.clear();
        vals.clear();
        cols.extend_from_slice(cs);
        vals.extend_from_slice(vs);
    }

    fn with_row(
        &self,
        r: usize,
        _cols: &mut Vec<u32>,
        _vals: &mut Vec<f32>,
        f: &mut dyn FnMut(&[u32], &[f32]),
    ) {
        let (cs, vs) = self.row(r);
        f(cs, vs);
    }
}

/// Uniform read access to per-vertex training data (features, labels,
/// train/val/test split) that may live in RAM or on disk.
pub trait VertexData: Send + Sync {
    /// Number of vertices.
    fn num_vertices(&self) -> usize;

    /// Feature dimensionality `d_in`.
    fn feature_dim(&self) -> usize;

    /// Number of label classes.
    fn num_classes(&self) -> usize;

    /// Copy vertex `v`'s feature row into `out` (`out.len() == d_in`).
    fn read_features(&self, v: usize, out: &mut [f32]);

    /// Class label of vertex `v`.
    fn label_of(&self, v: usize) -> u32;

    /// Split of vertex `v`: 0 = train, 1 = val, 2 = test.
    fn split_of(&self, v: usize) -> u8;
}

impl VertexData for Dataset {
    fn num_vertices(&self) -> usize {
        self.n
    }

    fn feature_dim(&self) -> usize {
        self.features.cols
    }

    fn num_classes(&self) -> usize {
        self.classes
    }

    fn read_features(&self, v: usize, out: &mut [f32]) {
        let d = self.features.cols;
        out.copy_from_slice(&self.features.data[v * d..(v + 1) * d]);
    }

    fn label_of(&self, v: usize) -> u32 {
        self.labels[v]
    }

    fn split_of(&self, v: usize) -> u8 {
        self.split[v]
    }
}

/// Byte offsets of every section, derived purely from the header counts.
#[derive(Clone, Copy, Debug)]
struct SectionLayout {
    indptr: u64,
    indices: u64,
    values: u64,
    features: u64,
    labels: u64,
    split: u64,
    crcs: u64,
    total: u64,
}

/// Section offsets for the given counts and on-disk feature element size
/// (4 for f32, 2 for bf16); `None` when the sizes overflow u64 (only
/// reachable through a corrupt header — rejecting it here keeps
/// `OocGraph::open`'s never-panic contract).
fn layout(n: u64, nnz: u64, d_in: u64, feat_elem: u64) -> Option<SectionLayout> {
    let indptr = HEADER_BYTES;
    let indices = indptr.checked_add(n.checked_add(1)?.checked_mul(8)?)?;
    let values = indices.checked_add(nnz.checked_mul(4)?)?;
    let features = values.checked_add(nnz.checked_mul(4)?)?;
    let labels = features.checked_add(n.checked_mul(d_in)?.checked_mul(feat_elem)?)?;
    let split = labels.checked_add(n.checked_mul(4)?)?;
    let crcs = split.checked_add(n)?;
    let total = crcs.checked_add(4 * SECTION_COUNT as u64)?;
    Some(SectionLayout { indptr, indices, values, features, labels, split, crcs, total })
}

/// Buffered little-endian serialization of a slice; `enc` encodes one
/// element (the single writer all sections go through).  Returns the
/// CRC32 of the bytes written — the section checksum stored in the
/// container's crc table.
fn write_le<W: Write, T: Copy, const N: usize>(
    w: &mut W,
    xs: &[T],
    enc: impl Fn(T) -> [u8; N],
) -> std::io::Result<u32> {
    let mut crc = Crc32::new();
    let mut buf = Vec::with_capacity(N * 8192);
    for chunk in xs.chunks(8192) {
        buf.clear();
        for &x in chunk {
            buf.extend_from_slice(&enc(x));
        }
        crc.update(&buf);
        w.write_all(&buf)?;
    }
    Ok(crc.finish())
}

/// Deterministic identity tag of a dataset name, stored in the container
/// header so `open_or_pack` can refuse a store packed from a different
/// dataset than the one requested.
pub fn name_tag(name: &str) -> u64 {
    name.bytes()
        .fold(0xA5A5_5A5A_0F0F_F0F0, |h, b| crate::util::rng::splitmix64(h ^ b as u64))
}

/// Summary of one `pack` run.
#[derive(Clone, Copy, Debug)]
pub struct PackStats {
    /// Vertices written.
    pub n: usize,
    /// Stored adjacency entries written.
    pub nnz: usize,
    /// Total file size in bytes.
    pub bytes: u64,
}

/// Serialize an in-memory [`Dataset`] into a `.pallas` container at `path`
/// (overwriting any existing file) with f32 features — see [`pack_with`].
pub fn pack(data: &Dataset, path: &Path) -> Result<PackStats> {
    pack_with(data, path, Precision::Fp32)
}

/// Serialize an in-memory [`Dataset`] into a `.pallas` container at `path`
/// (overwriting any existing file).  The normalized adjacency (`data.adj`),
/// features, labels and split are stored; see the module docs for the exact
/// layout.  `feat` selects the on-disk feature element: [`Precision::Bf16`]
/// rounds each feature once (round-to-nearest-even, via the SIMD batch
/// narrow) and halves the feature section.  The bytes go to a `.tmp`
/// sibling first and are renamed into place, so a crash mid-pack never
/// leaves a truncated container at `path`.
pub fn pack_with(data: &Dataset, path: &Path, feat: Precision) -> Result<PackStats> {
    let n = data.n;
    if data.adj.rows != n || data.adj.cols != n {
        bail!("pack: adjacency must be square n x n (got {}x{})", data.adj.rows, data.adj.cols);
    }
    if data.features.rows != n || data.labels.len() != n || data.split.len() != n {
        bail!("pack: features/labels/split must all have n = {n} rows");
    }
    let nnz = data.adj.nnz();
    let d_in = data.features.cols;
    let lay = layout(n as u64, nnz as u64, d_in as u64, feat.bytes_per_elem())
        .ok_or_else(|| anyhow!("pack: dataset sizes overflow the container format"))?;

    // pid-unique tmp sibling: concurrent packs of the same destination each
    // write their own file and atomically rename a complete container
    let tmp = {
        let mut os = path.as_os_str().to_os_string();
        os.push(format!(".tmp.{}", std::process::id()));
        PathBuf::from(os)
    };
    {
        let f = File::create(&tmp).with_context(|| format!("creating {}", tmp.display()))?;
        let mut w = std::io::BufWriter::new(f);
        w.write_all(&MAGIC)?;
        w.write_all(&VERSION.to_le_bytes())?;
        let flags = match feat {
            Precision::Fp32 => 0u32,
            Precision::Bf16 => FLAG_FEAT_BF16,
        };
        w.write_all(&flags.to_le_bytes())?;
        for v in [n as u64, nnz as u64, d_in as u64, data.classes as u64] {
            w.write_all(&v.to_le_bytes())?;
        }
        w.write_all(&name_tag(&data.name).to_le_bytes())?;
        w.write_all(&[0u8; 8])?; // reserved padding up to HEADER_BYTES

        let mut crcs = [0u32; SECTION_COUNT];
        crcs[0] = write_le(&mut w, &data.adj.indptr, |p| (p as u64).to_le_bytes())?;
        crcs[1] = write_le(&mut w, &data.adj.indices, |x| x.to_le_bytes())?;
        crcs[2] = write_le(&mut w, &data.adj.values, |x| x.to_le_bytes())?;
        crcs[3] = match feat {
            Precision::Fp32 => write_le(&mut w, &data.features.data, |x| x.to_le_bytes())?,
            Precision::Bf16 => {
                // narrow in bounded chunks through the SIMD batch kernel
                let mut crc = Crc32::new();
                let mut bits = [0u16; 8192];
                let mut buf = Vec::with_capacity(2 * 8192);
                for chunk in data.features.data.chunks(8192) {
                    let bs = &mut bits[..chunk.len()];
                    crate::tensor::simd::narrow_bf16(chunk, bs);
                    buf.clear();
                    for b in bs.iter() {
                        buf.extend_from_slice(&b.to_le_bytes());
                    }
                    crc.update(&buf);
                    w.write_all(&buf)?;
                }
                crc.finish()
            }
        };
        crcs[4] = write_le(&mut w, &data.labels, |x| x.to_le_bytes())?;
        crcs[5] = crate::checkpoint::crc32(&data.split);
        w.write_all(&data.split)?;
        for c in crcs {
            w.write_all(&c.to_le_bytes())?;
        }
        w.flush()?;
        // data must be durable BEFORE the rename is journaled, or a crash
        // could leave a correct-length file with zeroed sections in place
        w.get_ref().sync_all()?;
    }
    std::fs::rename(&tmp, path)
        .with_context(|| format!("renaming {} into place", tmp.display()))?;
    // best-effort: persist the directory entry too
    if let Some(parent) = path.parent() {
        if let Ok(dir) = File::open(parent) {
            let _ = dir.sync_all();
        }
    }
    Ok(PackStats { n, nnz, bytes: lay.total })
}

/// One resident cache block.
struct Slot {
    id: u64,
    stamp: u64,
    data: Vec<u8>,
}

/// Pinned-block LRU cache: at most `max_blocks` blocks of [`BLOCK_BYTES`]
/// resident at once, evicting the least-recently-used block on overflow.
struct BlockCache {
    max_blocks: usize,
    slots: Vec<Slot>,
    map: HashMap<u64, usize>,
    tick: u64,
    hits: u64,
    misses: u64,
}

impl BlockCache {
    fn new(budget_bytes: usize) -> BlockCache {
        BlockCache {
            max_blocks: (budget_bytes / BLOCK_BYTES).max(1),
            slots: Vec::new(),
            map: HashMap::new(),
            tick: 0,
            hits: 0,
            misses: 0,
        }
    }

    /// Resolve block `id`, loading (and possibly evicting) on a miss.
    fn block(&mut self, file: &File, file_len: u64, id: u64) -> &[u8] {
        self.tick += 1;
        if let Some(&slot) = self.map.get(&id) {
            self.hits += 1;
            self.slots[slot].stamp = self.tick;
            return &self.slots[slot].data;
        }
        self.misses += 1;
        let start = id * BLOCK_BYTES as u64;
        let end = (start + BLOCK_BYTES as u64).min(file_len);
        let mut data = vec![0u8; (end - start) as usize];
        file.read_exact_at(&mut data, start)
            // lint: allow(panic-free-boundary) — open() validated length and CRCs; losing the device mid-run is unrecoverable, and block() returning &[u8] keeps GraphAccess infallible
            .expect("pallas store: read failed after validated open");
        let slot = if self.slots.len() < self.max_blocks {
            self.slots.push(Slot { id, stamp: self.tick, data });
            self.slots.len() - 1
        } else {
            let victim = (0..self.slots.len())
                .min_by_key(|&i| self.slots[i].stamp)
                // lint: allow(panic-free-boundary) — max_blocks >= 1 by construction (BlockCache::new clamps), so the eviction scan is never empty
                .expect("cache has at least one slot");
            self.map.remove(&self.slots[victim].id);
            self.slots[victim] = Slot { id, stamp: self.tick, data };
            victim
        };
        self.map.insert(id, slot);
        &self.slots[slot].data
    }

    fn resident_bytes(&self) -> usize {
        self.slots.iter().map(|s| s.data.len()).sum()
    }
}

/// Cache counters of an [`OocGraph`] (see [`OocGraph::cache_stats`]).
#[derive(Clone, Copy, Debug, Default)]
pub struct CacheStats {
    /// Block lookups served from a resident block.
    pub hits: u64,
    /// Block lookups that required a disk read.
    pub misses: u64,
    /// Bytes currently resident in cache blocks.
    pub resident_bytes: usize,
    /// Upper bound on resident bytes (`max_blocks * BLOCK_BYTES`).
    pub budget_bytes: usize,
}

/// Disk-backed graph: a validated `.pallas` container served through the
/// pinned-block LRU cache.  Implements [`GraphAccess`] (adjacency rows) and
/// [`VertexData`] (features/labels/split); see the module docs for the
/// residency guarantee.
pub struct OocGraph {
    file: File,
    file_len: u64,
    lay: SectionLayout,
    /// Number of vertices.
    pub n: usize,
    /// Stored adjacency entries.
    pub nnz: usize,
    /// Feature dimensionality.
    pub d_in: usize,
    /// Number of label classes.
    pub classes: usize,
    /// Identity tag written by `pack` ([`name_tag`] of the dataset name).
    pub source_tag: u64,
    /// On-disk feature element precision (header flags bit 0): reads always
    /// return f32, widening from bf16 when the store was packed that way.
    pub feat_precision: Precision,
    cache: Mutex<BlockCache>,
}

impl OocGraph {
    /// Open and validate a `.pallas` container, with at most `cache_bytes`
    /// of file content resident at any time (rounded down to whole
    /// [`BLOCK_BYTES`] blocks, minimum one block).
    ///
    /// Returns a clean error — never panics — on a missing file, bad magic,
    /// unsupported version, a file whose length does not match the header's
    /// section layout (truncation), or a structurally corrupt indptr table.
    /// The indptr scan (sequential, not cached) is what guarantees every
    /// later row read stays inside the indices/values sections.
    pub fn open(path: &Path, cache_bytes: usize) -> Result<OocGraph> {
        let file =
            File::open(path).with_context(|| format!("opening pallas store {}", path.display()))?;
        let file_len = file.metadata()?.len();
        if file_len < HEADER_BYTES {
            bail!(
                "pallas store {}: truncated header ({file_len} bytes, need {HEADER_BYTES})",
                path.display()
            );
        }
        let mut hdr = [0u8; HEADER_BYTES as usize];
        file.read_exact_at(&mut hdr, 0)?;
        if hdr[..8] != MAGIC {
            bail!("pallas store {}: bad magic (not a .pallas file)", path.display());
        }
        let version = u32_le(&hdr[8..12]);
        if version != VERSION {
            bail!(
                "pallas store {}: unsupported version {version} (this build reads {VERSION})",
                path.display()
            );
        }
        let flags = u32_le(&hdr[12..16]);
        if flags & !FLAG_FEAT_BF16 != 0 {
            bail!(
                "pallas store {}: unknown header flags {flags:#x} (this build understands {:#x})",
                path.display(),
                FLAG_FEAT_BF16
            );
        }
        let feat_precision =
            if flags & FLAG_FEAT_BF16 != 0 { Precision::Bf16 } else { Precision::Fp32 };
        let field = |o: usize| u64_le(&hdr[o..o + 8]);
        let (n, nnz, d_in, classes) = (field(16), field(24), field(32), field(40));
        let source_tag = field(48);
        let lay = layout(n, nnz, d_in, feat_precision.bytes_per_elem()).ok_or_else(|| {
            anyhow!("pallas store {}: corrupt header counts (sizes overflow)", path.display())
        })?;
        if file_len != lay.total {
            bail!(
                "pallas store {}: truncated or corrupt ({file_len} bytes, header implies {})",
                path.display(),
                lay.total
            );
        }
        // verify every section checksum, streaming with a bounded buffer:
        // a flipped byte anywhere in the payload is reported as a *named*
        // corrupt section instead of surfacing later as wrong numbers
        let mut crc_table = [0u8; 4 * SECTION_COUNT];
        file.read_exact_at(&mut crc_table, lay.crcs)?;
        let sections: [(&str, u64, u64); SECTION_COUNT] = [
            ("indptr", lay.indptr, lay.indices),
            ("indices", lay.indices, lay.values),
            ("values", lay.values, lay.features),
            ("features", lay.features, lay.labels),
            ("labels", lay.labels, lay.split),
            ("split", lay.split, lay.crcs),
        ];
        let mut buf = vec![0u8; 64 * 1024];
        for (i, &(name, start, end)) in sections.iter().enumerate() {
            let stored = u32_le(&crc_table[4 * i..4 * i + 4]);
            let mut crc = Crc32::new();
            let mut off = start;
            while off < end {
                let take = ((end - off) as usize).min(buf.len());
                file.read_exact_at(&mut buf[..take], off)?;
                crc.update(&buf[..take]);
                off += take as u64;
            }
            let computed = crc.finish();
            if computed != stored {
                bail!(
                    "pallas store {}: corrupt {name} section \
                     (crc mismatch: stored {stored:08x}, computed {computed:08x})",
                    path.display()
                );
            }
        }
        // stream-validate the indptr table: starts at 0, monotone, ends at
        // nnz — the invariant every row_range/read_row relies on
        let mut prev = 0u64;
        let mut seen_first = false;
        let mut off = lay.indptr;
        let mut buf = vec![0u8; 64 * 1024];
        while off < lay.indices {
            let take = ((lay.indices - off) as usize).min(buf.len());
            file.read_exact_at(&mut buf[..take], off)?;
            for ch in buf[..take].chunks_exact(8) {
                let v = u64_le(ch);
                if !seen_first {
                    if v != 0 {
                        bail!(
                            "pallas store {}: corrupt indptr (does not start at 0)",
                            path.display()
                        );
                    }
                    seen_first = true;
                } else if v < prev {
                    bail!("pallas store {}: corrupt indptr (not monotone)", path.display());
                }
                prev = v;
            }
            off += take as u64;
        }
        if prev != nnz {
            bail!(
                "pallas store {}: corrupt indptr (last offset {prev} != nnz {nnz})",
                path.display()
            );
        }
        Ok(OocGraph {
            file,
            file_len,
            lay,
            n: n as usize,
            nnz: nnz as usize,
            d_in: d_in as usize,
            classes: classes as usize,
            source_tag,
            feat_precision,
            cache: Mutex::new(BlockCache::new(cache_bytes)),
        })
    }

    /// Copy `out.len()` bytes starting at absolute file offset `off`,
    /// through the block cache.
    fn read_at_cached(&self, mut off: u64, out: &mut [u8]) {
        debug_assert!(off + out.len() as u64 <= self.file_len);
        let mut cache = lock_unpoisoned(&self.cache);
        let mut done = 0usize;
        while done < out.len() {
            let id = off / BLOCK_BYTES as u64;
            let in_off = (off % BLOCK_BYTES as u64) as usize;
            let blk = cache.block(&self.file, self.file_len, id);
            let take = (out.len() - done).min(blk.len() - in_off);
            out[done..done + take].copy_from_slice(&blk[in_off..in_off + take]);
            done += take;
            off += take as u64;
        }
    }

    /// Walk `n_elems` `elem`-byte elements starting at `elem`-aligned `off`,
    /// handing `f` one contiguous little-endian byte run (a whole number of
    /// elements) per block visit, straight out of the cache blocks.
    /// Sections start `elem`-aligned and [`BLOCK_BYTES`] is a multiple of
    /// every element size (4 for the graph sections, 2 for bf16 features),
    /// so an element never straddles a block boundary and the hot path
    /// performs no heap allocation.  The single block-walk all typed
    /// readers go through; callers bulk-decode each run, so the indirect
    /// call is per block, not per element.
    fn walk_runs_cached(&self, mut off: u64, n_elems: usize, elem: usize, f: &mut dyn FnMut(&[u8])) {
        debug_assert_eq!(off % elem as u64, 0);
        let mut cache = lock_unpoisoned(&self.cache);
        let mut remaining = n_elems;
        while remaining > 0 {
            let id = off / BLOCK_BYTES as u64;
            let in_off = (off % BLOCK_BYTES as u64) as usize;
            let blk = cache.block(&self.file, self.file_len, id);
            let take = remaining.min((blk.len() - in_off) / elem);
            debug_assert!(take > 0);
            f(&blk[in_off..in_off + elem * take]);
            remaining -= take;
            off += (elem * take) as u64;
        }
    }

    /// Decode f32s from `off` into the fixed-size buffer `out`.
    fn read_f32s_slice_cached(&self, off: u64, out: &mut [f32]) {
        let n = out.len();
        let mut done = 0usize;
        self.walk_runs_cached(off, n, 4, &mut |run| {
            for ch in run.chunks_exact(4) {
                out[done] = f32_le(ch);
                done += 1;
            }
        });
    }

    /// Decode bf16 feature elements from `off`, widening into `out`
    /// through the SIMD batch conversion (a fixed stack scratch per block
    /// run, no heap allocation).
    fn read_bf16s_slice_cached(&self, off: u64, out: &mut [f32]) {
        let n = out.len();
        let mut done = 0usize;
        let mut bits = [0u16; 256];
        self.walk_runs_cached(off, n, 2, &mut |run| {
            for bytes in run.chunks(2 * 256) {
                let m = bytes.len() / 2;
                for (b, ch) in bits[..m].iter_mut().zip(bytes.chunks_exact(2)) {
                    *b = u16_le(ch);
                }
                crate::tensor::simd::widen_bf16(&bits[..m], &mut out[done..done + m]);
                done += m;
            }
        });
    }

    /// Decode `n_elems` f32s from `off`, appending to `out`.
    fn read_f32s_vec_cached(&self, off: u64, n_elems: usize, out: &mut Vec<f32>) {
        out.reserve(n_elems);
        self.walk_runs_cached(off, n_elems, 4, &mut |run| {
            for ch in run.chunks_exact(4) {
                out.push(f32_le(ch));
            }
        });
    }

    /// Decode `n_elems` u32s from `off`, appending to `out`.
    fn read_u32s_vec_cached(&self, off: u64, n_elems: usize, out: &mut Vec<u32>) {
        out.reserve(n_elems);
        self.walk_runs_cached(off, n_elems, 4, &mut |run| {
            for ch in run.chunks_exact(4) {
                out.push(u32_le(ch));
            }
        });
    }

    /// CSR range `(indptr[r], indptr[r+1])` of row `r`.
    pub fn row_range(&self, r: usize) -> (usize, usize) {
        assert!(r < self.n, "row {r} out of range (n = {})", self.n);
        let mut b = [0u8; 16];
        self.read_at_cached(self.lay.indptr + 8 * r as u64, &mut b);
        let lo = u64_le(&b[..8]) as usize;
        let hi = u64_le(&b[8..]) as usize;
        (lo, hi)
    }

    /// Snapshot of the cache counters and the residency bound.
    pub fn cache_stats(&self) -> CacheStats {
        let c = lock_unpoisoned(&self.cache);
        CacheStats {
            hits: c.hits,
            misses: c.misses,
            resident_bytes: c.resident_bytes(),
            budget_bytes: c.max_blocks * BLOCK_BYTES,
        }
    }

    /// Total container size in bytes (header + all sections).
    pub fn store_bytes(&self) -> u64 {
        self.file_len
    }

    /// Materialize the full adjacency as an in-memory [`Csr`]
    /// (tests/tooling only — defeats the purpose on big graphs).
    pub fn read_csr(&self) -> Csr {
        let mut indptr = Vec::with_capacity(self.n + 1);
        let mut indices: Vec<u32> = Vec::with_capacity(self.nnz);
        let mut values: Vec<f32> = Vec::with_capacity(self.nnz);
        indptr.push(0usize);
        let (mut cols, mut vals) = (Vec::new(), Vec::new());
        for r in 0..self.n {
            GraphAccess::read_row(self, r, &mut cols, &mut vals);
            indices.extend_from_slice(&cols);
            values.extend_from_slice(&vals);
            indptr.push(indices.len());
        }
        Csr { rows: self.n, cols: self.n, indptr, indices, values }
    }
}

impl std::fmt::Debug for OocGraph {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("OocGraph")
            .field("n", &self.n)
            .field("nnz", &self.nnz)
            .field("d_in", &self.d_in)
            .field("classes", &self.classes)
            .field("feat_precision", &self.feat_precision)
            .field("file_len", &self.file_len)
            .finish()
    }
}

impl GraphAccess for OocGraph {
    fn rows(&self) -> usize {
        self.n
    }

    fn cols(&self) -> usize {
        self.n
    }

    fn row_nnz(&self, r: usize) -> usize {
        let (lo, hi) = self.row_range(r);
        hi - lo
    }

    fn read_row(&self, r: usize, cols: &mut Vec<u32>, vals: &mut Vec<f32>) {
        let (lo, hi) = self.row_range(r);
        let k = hi - lo;
        cols.clear();
        vals.clear();
        if k == 0 {
            return;
        }
        self.read_u32s_vec_cached(self.lay.indices + 4 * lo as u64, k, cols);
        self.read_f32s_vec_cached(self.lay.values + 4 * lo as u64, k, vals);
    }
}

impl VertexData for OocGraph {
    fn num_vertices(&self) -> usize {
        self.n
    }

    fn feature_dim(&self) -> usize {
        self.d_in
    }

    fn num_classes(&self) -> usize {
        self.classes
    }

    fn read_features(&self, v: usize, out: &mut [f32]) {
        assert!(v < self.n, "vertex {v} out of range (n = {})", self.n);
        assert_eq!(out.len(), self.d_in, "feature buffer must be d_in long");
        let row = (v as u64) * self.d_in as u64;
        match self.feat_precision {
            Precision::Fp32 => {
                self.read_f32s_slice_cached(self.lay.features + 4 * row, out);
            }
            Precision::Bf16 => {
                self.read_bf16s_slice_cached(self.lay.features + 2 * row, out);
            }
        }
    }

    fn label_of(&self, v: usize) -> u32 {
        assert!(v < self.n, "vertex {v} out of range (n = {})", self.n);
        let mut b = [0u8; 4];
        self.read_at_cached(self.lay.labels + 4 * v as u64, &mut b);
        u32::from_le_bytes(b)
    }

    fn split_of(&self, v: usize) -> u8 {
        assert!(v < self.n, "vertex {v} out of range (n = {})", self.n);
        let mut b = [0u8; 1];
        self.read_at_cached(self.lay.split + v as u64, &mut b);
        b[0]
    }
}

/// Open `path` as an [`OocGraph`], packing the named registry dataset into
/// it first if the file does not exist yet — the "pack once, train
/// out-of-core forever" flow used by `scalegnn train --from-store`.
///
/// An existing file must carry the [`name_tag`] of `dataset` in its header;
/// a store packed from a different dataset is refused instead of silently
/// training on the wrong graph.
pub fn open_or_pack(dataset: &str, path: &Path, cache_bytes: usize) -> Result<OocGraph> {
    if !path.exists() {
        let d = super::datasets::load(dataset)
            .ok_or_else(|| anyhow!("unknown dataset '{dataset}' (see `scalegnn info`)"))?;
        pack(&d, path)?;
    }
    let g = OocGraph::open(path, cache_bytes)?;
    if g.source_tag != name_tag(dataset) {
        bail!(
            "pallas store {} was packed from a different dataset than '{dataset}' \
             (source tag mismatch); delete it or drop --dataset",
            path.display()
        );
    }
    Ok(g)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::datasets;
    use std::path::PathBuf;

    fn tmp(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("pallas_unit_{name}_{}.pallas", std::process::id()))
    }

    #[test]
    fn layout_is_contiguous_and_sized() {
        let l = layout(10, 33, 4, 4).unwrap();
        assert_eq!(l.indptr, HEADER_BYTES);
        assert_eq!(l.indices, l.indptr + 8 * 11);
        assert_eq!(l.values, l.indices + 4 * 33);
        assert_eq!(l.features, l.values + 4 * 33);
        assert_eq!(l.labels, l.features + 4 * 40);
        assert_eq!(l.split, l.labels + 4 * 10);
        assert_eq!(l.crcs, l.split + 10);
        assert_eq!(l.total, l.crcs + 4 * SECTION_COUNT as u64);
        // bf16 features halve exactly the feature section
        let h = layout(10, 33, 4, 2).unwrap();
        assert_eq!(h.labels, h.features + 2 * 40);
        assert_eq!(l.total - h.total, 2 * 40);
    }

    #[test]
    fn overflowing_header_counts_are_rejected() {
        assert!(layout(u64::MAX, 1, 1, 4).is_none());
        assert!(layout(1, u64::MAX, 1, 4).is_none());
        assert!(layout(1 << 40, 1, 1 << 40, 4).is_none());
    }

    #[test]
    fn source_tag_roundtrips_and_gates_open_or_pack() {
        let d = datasets::load("tiny").unwrap();
        let p = tmp("tag");
        pack(&d, &p).unwrap();
        let g = OocGraph::open(&p, 1 << 20).unwrap();
        assert_eq!(g.source_tag, name_tag("tiny"));
        assert_ne!(name_tag("tiny"), name_tag("papers100m_ooc"));
        // same name -> accepted; different dataset -> refused (no repack)
        assert!(open_or_pack("tiny", &p, 1 << 20).is_ok());
        let e = open_or_pack("reddit_sim", &p, 1 << 20).unwrap_err();
        assert!(format!("{e:#}").contains("different dataset"), "{e:#}");
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn pack_reports_exact_file_size() {
        let d = datasets::load("tiny").unwrap();
        let p = tmp("size");
        let stats = pack(&d, &p).unwrap();
        assert_eq!(stats.bytes, std::fs::metadata(&p).unwrap().len());
        assert_eq!(stats.n, d.n);
        assert_eq!(stats.nnz, d.adj.nnz());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn graph_access_on_csr_matches_direct_rows() {
        let d = datasets::load("tiny").unwrap();
        let (mut cols, mut vals) = (Vec::new(), Vec::new());
        for r in [0usize, 1, 100, d.n - 1] {
            d.adj.read_row(r, &mut cols, &mut vals);
            let (cs, vs) = d.adj.row(r);
            assert_eq!(cols, cs);
            assert_eq!(vals, vs);
            assert_eq!(GraphAccess::row_nnz(&d.adj, r), cs.len());
        }
        assert_eq!(GraphAccess::rows(&d.adj), d.n);
        assert_eq!(GraphAccess::cols(&d.adj), d.n);
    }

    #[test]
    fn lru_evicts_oldest_block() {
        // one-block cache: touching the first and last block of the file
        // must evict, and residency stays within the single-block budget
        let d = datasets::load("tiny").unwrap();
        let p = tmp("lru");
        pack(&d, &p).unwrap();
        let g = OocGraph::open(&p, BLOCK_BYTES).unwrap();
        assert!(
            g.store_bytes() > BLOCK_BYTES as u64,
            "tiny store should span multiple blocks ({} bytes)",
            g.store_bytes()
        );
        let _ = g.row_range(0); // first block (indptr starts at byte 64)
        let _ = g.split_of(g.n - 1); // last byte of the file -> last block
        let _ = g.row_range(0); // must re-read: the one slot was evicted
        let s = g.cache_stats();
        assert!(s.resident_bytes <= BLOCK_BYTES, "resident {}", s.resident_bytes);
        assert_eq!(s.misses, 3, "hits {} misses {}", s.hits, s.misses);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn bf16_store_halves_features_and_widens_rounded_values() {
        let d = datasets::load("tiny").unwrap();
        let pf = tmp("bf16_f32");
        let ph = tmp("bf16_half");
        let f32_stats = pack_with(&d, &pf, Precision::Fp32).unwrap();
        let bf16_stats = pack_with(&d, &ph, Precision::Bf16).unwrap();
        // exactly the feature section shrinks, by 2 bytes per element
        assert_eq!(f32_stats.bytes - bf16_stats.bytes, 2 * (d.n * d.features.cols) as u64);

        let g = OocGraph::open(&ph, 4 << 20).unwrap();
        assert_eq!(g.feat_precision, Precision::Bf16);
        // adjacency is untouched by the feature precision
        let csr = g.read_csr();
        assert_eq!(csr.indices, d.adj.indices);
        // features come back as exactly the bf16 rounding of the originals
        let dcols = d.features.cols;
        let mut feat = vec![0.0f32; dcols];
        for v in [0usize, 1, d.n / 2, d.n - 1] {
            g.read_features(v, &mut feat);
            for (j, (a, b)) in feat.iter().zip(&d.features.data[v * dcols..(v + 1) * dcols]).enumerate()
            {
                assert_eq!(
                    a.to_bits(),
                    crate::util::bf16_round(*b).to_bits(),
                    "vertex {v} feature {j}"
                );
            }
            assert_eq!(g.label_of(v), d.labels[v]);
            assert_eq!(g.split_of(v), d.split[v]);
        }
        std::fs::remove_file(&pf).ok();
        std::fs::remove_file(&ph).ok();
    }

    #[test]
    fn corrupt_sections_are_reported_by_name() {
        let d = datasets::load("tiny").unwrap();
        let p = tmp("crc");
        pack(&d, &p).unwrap();
        let full = std::fs::read(&p).unwrap();
        let lay = layout(d.n as u64, d.adj.nnz() as u64, d.features.cols as u64, 4).unwrap();
        for (name, off) in [
            ("values", lay.values + 5),
            ("features", lay.features + 7),
            ("labels", lay.labels + 2),
            ("split", lay.split),
        ] {
            let mut bad = full.clone();
            bad[off as usize] ^= 0x55;
            std::fs::write(&p, &bad).unwrap();
            let e = OocGraph::open(&p, 1 << 20).unwrap_err();
            let msg = format!("{e:#}");
            assert!(
                msg.contains(&format!("corrupt {name} section")),
                "flip in {name} at {off}: {msg}"
            );
        }
        // untouched file still opens
        std::fs::write(&p, &full).unwrap();
        assert!(OocGraph::open(&p, 1 << 20).is_ok());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn unknown_flags_are_rejected() {
        let d = datasets::load("tiny").unwrap();
        let p = tmp("flags");
        pack(&d, &p).unwrap();
        let mut bytes = std::fs::read(&p).unwrap();
        bytes[13] = 0x80; // set a flag bit this build does not understand
        std::fs::write(&p, &bytes).unwrap();
        let e = OocGraph::open(&p, 1 << 20).unwrap_err();
        assert!(format!("{e:#}").contains("unknown header flags"), "{e:#}");
        std::fs::remove_file(&p).ok();
    }
}
