//! Synthetic graph generators standing in for the paper's datasets.
//!
//! The paper itself uses random features + degree-proportional synthetic
//! classes for its two scaling datasets (§VI-C); we extend the same recipe
//! with a planted community structure so *accuracy* experiments (Table I,
//! Fig. 6) remain meaningful: labels are communities, features are noisy
//! community indicators, and edges prefer intra-community endpoints — a
//! stochastic block model with a power-law-ish degree profile.

use super::csr::Csr;
use crate::tensor::Mat;
use crate::util::rng::Rng;

/// A generated dataset: normalized adjacency + features + labels + splits.
pub struct Dataset {
    /// Registry name (filled in by `datasets::load`).
    pub name: String,
    /// Number of vertices.
    pub n: usize,
    /// GCN-normalized adjacency: symmetric, with self-loops.
    pub adj: Csr,
    /// Unnormalized symmetric structure (used by the baseline samplers).
    pub raw_adj: Csr,
    /// `n x d_in` vertex features.
    pub features: Mat,
    /// Class label per vertex.
    pub labels: Vec<u32>,
    /// Number of label classes.
    pub classes: usize,
    /// 0 = train, 1 = val, 2 = test per vertex
    pub split: Vec<u8>,
}

impl Dataset {
    /// 1.0 for train-split vertices, 0.0 otherwise (loss mask).
    pub fn train_mask_f32(&self) -> Vec<f32> {
        self.split.iter().map(|&s| if s == 0 { 1.0 } else { 0.0 }).collect()
    }

    /// Number of vertices in split `which` (0 train / 1 val / 2 test).
    pub fn count_split(&self, which: u8) -> usize {
        self.split.iter().filter(|&&s| s == which).count()
    }
}

/// Parameters for the planted-partition generator.
#[derive(Clone, Debug)]
pub struct PlantedConfig {
    /// Number of vertices.
    pub n: usize,
    /// Number of communities (= label classes).
    pub classes: usize,
    /// Target mean degree of the lognormal degree profile.
    pub avg_degree: usize,
    /// Feature dimensionality.
    pub d_in: usize,
    /// fraction of a vertex's edges that stay inside its community
    pub intra_frac: f64,
    /// feature noise stddev relative to the unit community centroid
    pub feature_noise: f32,
    /// fraction of labels flipped to a random class (caps attainable acc)
    pub label_noise: f64,
    /// Generator seed (the whole dataset is a pure function of it).
    pub seed: u64,
}

/// Generate a planted-partition graph with community-correlated features.
pub fn planted_partition(cfg: &PlantedConfig) -> Dataset {
    let mut rng = Rng::new(cfg.seed);
    let n = cfg.n;
    let k = cfg.classes;

    // community assignment (round-robin-ish sizes, shuffled membership)
    let mut comm: Vec<u32> = (0..n).map(|i| (i % k) as u32).collect();
    rng.shuffle(&mut comm);

    // community member lists for intra-edge sampling
    let mut members: Vec<Vec<u32>> = vec![Vec::new(); k];
    for (v, &c) in comm.iter().enumerate() {
        members[c as usize].push(v as u32);
    }

    // degree profile: lognormal-ish around avg_degree (heavy-ish tail)
    let mut triples: Vec<(u32, u32, f32)> = Vec::with_capacity(n * cfg.avg_degree);
    for v in 0..n {
        let mult = (rng.normal() * 0.6).exp(); // lognormal(0, 0.6)
        let deg = ((cfg.avg_degree as f32 * mult).round() as usize).clamp(1, 16 * cfg.avg_degree);
        let c = comm[v] as usize;
        for _ in 0..deg {
            let u = if rng.f64() < cfg.intra_frac {
                let m = &members[c];
                m[rng.below(m.len() as u64) as usize]
            } else {
                rng.below(n as u64) as u32
            };
            if u as usize != v {
                triples.push((v as u32, u, 1.0));
            }
        }
    }
    let raw = Csr::from_triples(n, n, triples).symmetrize();
    let adj = raw.gcn_normalize();

    // features: unit-norm community centroid + iid noise
    let mut centroids = Mat::zeros(k, cfg.d_in);
    for c in 0..k {
        let mut norm = 0.0f32;
        for j in 0..cfg.d_in {
            let v = rng.normal();
            centroids.data[c * cfg.d_in + j] = v;
            norm += v * v;
        }
        let inv = 1.0 / norm.sqrt().max(1e-6);
        for j in 0..cfg.d_in {
            centroids.data[c * cfg.d_in + j] *= inv;
        }
    }
    let mut features = Mat::zeros(n, cfg.d_in);
    for v in 0..n {
        let c = comm[v] as usize;
        for j in 0..cfg.d_in {
            features.data[v * cfg.d_in + j] =
                centroids.data[c * cfg.d_in + j] + rng.normal() * cfg.feature_noise;
        }
    }

    // labels = community, with optional flip noise
    let mut labels = comm.clone();
    for l in labels.iter_mut() {
        if rng.f64() < cfg.label_noise {
            *l = rng.below(k as u64) as u32;
        }
    }

    // split 80/10/10 by per-vertex hash
    let split: Vec<u8> = (0..n)
        .map(|v| {
            let h = crate::util::rng::splitmix64(cfg.seed ^ (v as u64).wrapping_mul(0x9E3779B1));
            match h % 10 {
                0 => 1,      // val
                1 => 2,      // test
                _ => 0,      // train
            }
        })
        .collect();

    Dataset {
        name: String::new(),
        n,
        adj,
        raw_adj: raw,
        features,
        labels,
        classes: k,
        split,
    }
}

/// R-MAT generator (Graph500 style) for structure-only scaling datasets.
pub fn rmat(scale: u32, edge_factor: usize, seed: u64) -> Csr {
    let n = 1usize << scale;
    let m = n * edge_factor;
    let (a, b, c) = (0.57, 0.19, 0.19);
    let mut rng = Rng::new(seed);
    let mut triples = Vec::with_capacity(m);
    for _ in 0..m {
        let (mut r, mut cidx) = (0usize, 0usize);
        for lvl in (0..scale).rev() {
            let p = rng.f64();
            let (ri, ci) = if p < a {
                (0, 0)
            } else if p < a + b {
                (0, 1)
            } else if p < a + b + c {
                (1, 0)
            } else {
                (1, 1)
            };
            r |= ri << lvl;
            cidx |= ci << lvl;
        }
        if r != cidx {
            triples.push((r as u32, cidx as u32, 1.0));
        }
    }
    Csr::from_triples(n, n, triples).symmetrize()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_cfg() -> PlantedConfig {
        PlantedConfig {
            n: 400,
            classes: 4,
            avg_degree: 10,
            d_in: 16,
            intra_frac: 0.8,
            feature_noise: 0.3,
            label_noise: 0.0,
            seed: 7,
        }
    }

    #[test]
    fn planted_partition_basic_shape() {
        let d = planted_partition(&small_cfg());
        assert_eq!(d.n, 400);
        assert_eq!(d.adj.rows, 400);
        assert_eq!(d.features.rows, 400);
        assert_eq!(d.features.cols, 16);
        assert_eq!(d.labels.len(), 400);
        assert!(d.labels.iter().all(|&l| l < 4));
        // splits roughly 80/10/10
        assert!(d.count_split(0) > 280);
        assert!(d.count_split(1) > 10);
        assert!(d.count_split(2) > 10);
        assert_eq!(d.count_split(0) + d.count_split(1) + d.count_split(2), 400);
    }

    #[test]
    fn planted_partition_is_deterministic() {
        let a = planted_partition(&small_cfg());
        let b = planted_partition(&small_cfg());
        assert_eq!(a.labels, b.labels);
        assert_eq!(a.adj.indices, b.adj.indices);
        assert_eq!(a.features.data, b.features.data);
    }

    #[test]
    fn planted_partition_is_assortative() {
        // most edges should connect same-community endpoints
        let d = planted_partition(&small_cfg());
        let mut intra = 0usize;
        let mut total = 0usize;
        for r in 0..d.n {
            let (cs, _) = d.raw_adj.row(r);
            for &c in cs {
                total += 1;
                if d.labels[r] == d.labels[c as usize] {
                    intra += 1;
                }
            }
        }
        assert!(intra as f64 / total as f64 > 0.6, "{intra}/{total}");
    }

    #[test]
    fn planted_adj_is_gcn_normalized() {
        let d = planted_partition(&small_cfg());
        for r in 0..d.n {
            assert!(d.adj.has_edge(r, r as u32), "self loop at {r}");
        }
        assert!(d.adj.values.iter().all(|&v| v > 0.0 && v <= 1.0));
    }

    #[test]
    fn features_correlate_with_community() {
        let d = planted_partition(&small_cfg());
        // same-community feature dot products should exceed cross-community
        let dot = |a: usize, b: usize| -> f32 {
            d.features.row(a).iter().zip(d.features.row(b)).map(|(x, y)| x * y).sum()
        };
        let mut same = 0.0f32;
        let mut cross = 0.0f32;
        let mut ns = 0;
        let mut nc = 0;
        for v in 0..200 {
            for u in 200..400 {
                if d.labels[v] == d.labels[u] {
                    same += dot(v, u);
                    ns += 1;
                } else {
                    cross += dot(v, u);
                    nc += 1;
                }
            }
        }
        assert!(same / ns as f32 > cross / nc as f32 + 0.3);
    }

    #[test]
    fn rmat_generates_connected_ish_graph() {
        let g = rmat(8, 8, 3);
        assert_eq!(g.rows, 256);
        assert!(g.nnz() > 256 * 4);
        // symmetric
        for r in 0..g.rows {
            let (cs, _) = g.row(r);
            for &c in cs {
                assert!(g.has_edge(c as usize, r as u32));
            }
        }
    }
}
