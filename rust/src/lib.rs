//! ScaleGNN: communication-free sampling and 4D hybrid parallelism for
//! scalable mini-batch GNN training.
//!
//! Reproduction of the ScaleGNN paper as a three-layer Rust + JAX + Pallas
//! stack: Pallas kernels (L1) inside a JAX GCN model (L2) are AOT-lowered to
//! HLO text at build time; the Rust coordinator (L3) loads the artifacts via
//! PJRT and owns sampling, the 4D process grid, collectives, the training
//! loop and all experiment harnesses.  See ARCHITECTURE.md for the
//! paper-section ↔ module map and DESIGN.md for the system inventory and
//! the per-experiment index.

#![warn(missing_docs)]

pub mod checkpoint;
pub mod comm;
pub mod model;
pub mod pmm;
pub mod runtime;
pub mod graph;
pub mod grid;
pub mod sampling;
pub mod session;
pub mod sim;
pub mod trainer;
pub mod tensor;
pub mod util;
