//! `scalegnn-coord`: the multi-process world coordinator.
//!
//! ```text
//! scalegnn-coord --grid 1x2x1x1 (--tcp HOST:PORT | --unix PATH)
//!                [--heartbeat-ms N] [--wait-timeout-ms N]
//!                [--rejoin-grace-ms N] [--quiet]
//! ```
//!
//! Binds the endpoint, prints `listening <endpoint>` on stdout (launch
//! scripts parse this line — with `--tcp HOST:0` it carries the
//! OS-assigned port), registers `world_size` ranks, serves the run, and
//! exits 0 on a clean world.  If the world fails, the structured origin
//! is printed as
//! `failure origin rank R op OP seq S axis A: MSG` and the exit code
//! is 1.

use std::io::Write;

use anyhow::{anyhow, bail, Result};

use scalegnn::comm::{CoordConfig, Coordinator, Endpoint};
use scalegnn::grid::Grid4D;
use scalegnn::util::cli::Args;

fn main() {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    match run(&args) {
        Ok(true) => {}
        Ok(false) => std::process::exit(1),
        Err(e) => {
            eprintln!("error: {e:#}");
            std::process::exit(2);
        }
    }
}

/// `Ok(true)` = clean world, `Ok(false)` = world failed (origin printed),
/// `Err` = the coordinator itself could not run.
fn run(args: &Args) -> Result<bool> {
    args.check_known(
        "scalegnn-coord",
        &["grid", "tcp", "unix", "heartbeat-ms", "wait-timeout-ms", "rejoin-grace-ms"],
        &["quiet"],
    )
    .map_err(|e| anyhow!(e))?;
    let grid_s = args
        .str_opt("grid")
        .ok_or_else(|| anyhow!("--grid DxXxYxZ is required"))?;
    let grid = Grid4D::parse(grid_s).ok_or_else(|| anyhow!("invalid --grid '{grid_s}'"))?;
    let ep = match (args.str_opt("tcp"), args.str_opt("unix")) {
        (Some(addr), None) => Endpoint::Tcp(addr.to_string()),
        (None, Some(path)) => Endpoint::Unix(path.into()),
        _ => bail!("exactly one of --tcp HOST:PORT or --unix PATH is required"),
    };
    let defaults = CoordConfig::default();
    let cfg = CoordConfig {
        heartbeat_ms: args.get_or("heartbeat-ms", 0).map_err(|e| anyhow!(e))?,
        wait_timeout_ms: args
            .get_or("wait-timeout-ms", defaults.wait_timeout_ms)
            .map_err(|e| anyhow!(e))?,
        rejoin_grace_ms: args
            .get_or("rejoin-grace-ms", defaults.rejoin_grace_ms)
            .map_err(|e| anyhow!(e))?,
        quiet: args.flag("quiet"),
    };
    let coord = Coordinator::bind(grid, &ep, cfg)?;
    println!("listening {}", coord.endpoint());
    std::io::stdout().flush().ok();
    match coord.run()? {
        None => Ok(true),
        Some(err) => {
            println!(
                "failure origin rank {} op {} seq {} axis {}: {}",
                err.rank,
                err.op,
                err.seq,
                err.axis.tag(),
                err.msg
            );
            std::io::stdout().flush().ok();
            Ok(false)
        }
    }
}
