//! Minimal JSON parser/writer (the offline crate set has no `serde` facade).
//!
//! Covers the full JSON grammar; used to read `artifacts/manifest.json` and
//! `artifacts/golden.json` and to write experiment result files.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any JSON number (always carried as f64).
    Num(f64),
    /// String value.
    Str(String),
    /// Array value.
    Arr(Vec<Json>),
    /// Object value (keys sorted).
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a complete JSON document (rejects trailing data).
    pub fn parse(s: &str) -> Result<Json, String> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }

    /// Object member by key (`None` for non-objects / missing keys).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Array element by index (`None` for non-arrays / out of range).
    pub fn idx(&self, i: usize) -> Option<&Json> {
        match self {
            Json::Arr(v) => v.get(i),
            _ => None,
        }
    }

    /// Numeric value, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// Numeric value truncated to usize, if this is a number.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    /// String value, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Elements, if this is an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Members, if this is an object.
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Flatten a numeric array into `Vec<f32>`.
    pub fn as_f32_vec(&self) -> Option<Vec<f32>> {
        self.as_arr()
            .map(|v| v.iter().filter_map(|x| x.as_f64()).map(|f| f as f32).collect())
    }

    /// Flatten a numeric array into `Vec<usize>`.
    pub fn as_usize_vec(&self) -> Option<Vec<usize>> {
        self.as_arr()
            .map(|v| v.iter().filter_map(|x| x.as_f64()).map(|f| f as usize).collect())
    }

    /// Compact serialization.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{}", n);
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

impl From<f64> for Json {
    fn from(v: f64) -> Json {
        Json::Num(v)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Json {
        Json::Num(v as f64)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Json {
        Json::Str(v.to_string())
    }
}
impl From<Vec<Json>> for Json {
    fn from(v: Vec<Json>) -> Json {
        Json::Arr(v)
    }
}

/// Convenience builder for result files.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

/// Numeric array from a slice.
pub fn arr_f64(v: &[f64]) -> Json {
    Json::Arr(v.iter().map(|&x| Json::Num(x)).collect())
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek().ok_or("unexpected eof")? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek().ok_or("eof in string")? {
                b'"' => {
                    self.i += 1;
                    return Ok(s);
                }
                b'\\' => {
                    self.i += 1;
                    match self.peek().ok_or("eof in escape")? {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            let hex = std::str::from_utf8(
                                self.b.get(self.i + 1..self.i + 5).ok_or("eof in \\u")?,
                            )
                            .map_err(|e| e.to_string())?;
                            let cp =
                                u32::from_str_radix(hex, 16).map_err(|e| e.to_string())?;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        c => return Err(format!("bad escape '{}'", c as char)),
                    }
                    self.i += 1;
                }
                _ => {
                    // consume one utf8 char
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|e| e.to_string())?;
                    let c = rest.chars().next().ok_or("eof")?;
                    s.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a": [1, 2.5, -3e2], "b": "x\ny", "c": true, "d": null, "e": {"f": 0}}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("a").unwrap().idx(1).unwrap().as_f64(), Some(2.5));
        assert_eq!(v.get("a").unwrap().idx(2).unwrap().as_f64(), Some(-300.0));
        assert_eq!(v.get("b").unwrap().as_str(), Some("x\ny"));
        assert_eq!(v.get("c"), Some(&Json::Bool(true)));
        assert_eq!(v.get("d"), Some(&Json::Null));
        // reparse what we serialize
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn parses_unicode_escapes() {
        let v = Json::parse(r#""Aé""#).unwrap();
        assert_eq!(v.as_str(), Some("Aé"));
    }

    #[test]
    fn f32_vec_helper() {
        let v = Json::parse("[1, 2, 3.5]").unwrap();
        assert_eq!(v.as_f32_vec(), Some(vec![1.0, 2.0, 3.5]));
    }

    #[test]
    fn big_array_parses() {
        let big: String =
            format!("[{}]", (0..10000).map(|i| i.to_string()).collect::<Vec<_>>().join(","));
        let v = Json::parse(&big).unwrap();
        assert_eq!(v.as_arr().unwrap().len(), 10000);
    }
}
