//! Shared utilities: PRNG, JSON, CLI parsing, statistics/benching.

pub mod bytes;
pub mod cli;
pub mod json;
pub mod rng;
pub mod stats;

/// Lock a mutex, recovering the guard if a previous holder panicked.
///
/// This is the sanctioned poison-recovery idiom for the panic-free
/// boundary modules: the shared state the repo guards with mutexes
/// (block caches, pending-op tables) stays structurally valid even if a
/// holder unwound, so recovering the guard is strictly better than
/// propagating a second panic out of a decode or I/O path.  pallas-lint's
/// `lock-order` rule recognizes this helper as an acquisition site.
#[inline]
pub fn lock_unpoisoned<T>(m: &std::sync::Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|p| p.into_inner())
}

/// bf16 round-to-nearest-even of an f32 (the paper's low-precision
/// collective payload format, §V-B).
#[inline]
pub fn f32_to_bf16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    if x.is_nan() {
        // keep NaN a NaN (bias rounding could carry into the exponent)
        return ((bits >> 16) as u16) | 0x0040;
    }
    // round-to-nearest-even on the truncated 16 bits
    let rounding_bias = 0x7fff + ((bits >> 16) & 1);
    ((bits.wrapping_add(rounding_bias)) >> 16) as u16
}

/// Widen bf16 bits back to the f32 they represent exactly.
#[inline]
pub fn bf16_bits_to_f32(b: u16) -> f32 {
    f32::from_bits((b as u32) << 16)
}

/// Round-trip an f32 through bf16 (what a bf16 all-reduce does to each
/// rank's contribution).
#[inline]
pub fn bf16_round(x: f32) -> f32 {
    bf16_bits_to_f32(f32_to_bf16_bits(x))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bf16_roundtrip_exact_values() {
        for &v in &[0.0f32, 1.0, -2.0, 0.5, 256.0, -0.25] {
            assert_eq!(bf16_round(v), v, "{v} should be bf16-exact");
        }
    }

    #[test]
    fn bf16_relative_error_bounded() {
        let mut r = rng::Rng::new(1);
        for _ in 0..1000 {
            let v = (r.f32() - 0.5) * 100.0;
            if v.abs() < 1e-3 {
                continue;
            }
            let e = (bf16_round(v) - v).abs() / v.abs();
            assert!(e < 0.01, "relative error {e} too big for {v}");
        }
    }

    #[test]
    fn bf16_handles_specials() {
        assert!(bf16_round(f32::NAN).is_nan());
        assert_eq!(bf16_round(f32::INFINITY), f32::INFINITY);
        assert_eq!(bf16_round(f32::NEG_INFINITY), f32::NEG_INFINITY);
    }
}
