//! Tiny CLI argument parser (the offline crate set has no `clap`).
//!
//! Grammar: `scalegnn <subcommand> [--key value]... [--flag]...`.
//! Values are parsed on demand with helpful error messages.

use std::collections::BTreeMap;

/// Parsed command line: one optional subcommand, `--key value` options and
/// bare `--flag`s.
#[derive(Debug, Clone, Default)]
pub struct Args {
    /// First positional argument, if any.
    pub subcommand: Option<String>,
    /// `--key value` / `--key=value` options.
    pub opts: BTreeMap<String, String>,
    /// Bare `--flag`s in order of appearance.
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (excluding `argv[0]`).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Result<Args, String> {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                if key.is_empty() {
                    return Err("bare '--' not supported".into());
                }
                if let Some((k, v)) = key.split_once('=') {
                    out.opts.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    out.opts.insert(key.to_string(), it.next().unwrap());
                } else {
                    out.flags.push(key.to_string());
                }
            } else if out.subcommand.is_none() {
                out.subcommand = Some(a);
            } else {
                return Err(format!("unexpected positional argument '{a}'"));
            }
        }
        Ok(out)
    }

    /// Parse the process arguments (skipping `argv[0]`).
    pub fn from_env() -> Result<Args, String> {
        Args::parse(std::env::args().skip(1))
    }

    /// Whether the bare flag `--name` was given.
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// Raw value of `--name`, if given.
    pub fn str_opt(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    /// Value of `--name`, or `default` when absent.
    pub fn str_or(&self, name: &str, default: &str) -> String {
        self.str_opt(name).unwrap_or(default).to_string()
    }

    /// Value of `--name` as a filesystem path, if given.
    pub fn path_opt(&self, name: &str) -> Option<std::path::PathBuf> {
        self.str_opt(name).map(std::path::PathBuf::from)
    }

    /// Parsed value of `--name` (`Ok(None)` when absent, `Err` on a value
    /// that does not parse as `T`).
    pub fn get<T: std::str::FromStr>(&self, name: &str) -> Result<Option<T>, String> {
        match self.opts.get(name) {
            None => Ok(None),
            Some(v) => v
                .parse::<T>()
                .map(Some)
                .map_err(|_| format!("invalid value '{v}' for --{name}")),
        }
    }

    /// Parsed value of `--name`, or `default` when absent.
    pub fn get_or<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T, String> {
        Ok(self.get(name)?.unwrap_or(default))
    }

    /// Reject any option/flag the subcommand does not accept; the error
    /// names the offending flag and lists every accepted one.
    pub fn check_known(&self, sub: &str, opts: &[&str], flags: &[&str]) -> Result<(), String> {
        let describe = |kind: &str, got: &str| {
            let mut accepted: Vec<String> = opts.iter().map(|o| format!("--{o} V")).collect();
            accepted.extend(flags.iter().map(|f| format!("--{f}")));
            format!(
                "unknown {kind} '--{got}' for '{sub}' (accepted: {})",
                if accepted.is_empty() { "none".to_string() } else { accepted.join(", ") }
            )
        };
        for k in self.opts.keys() {
            if !opts.contains(&k.as_str()) {
                return Err(describe("option", k));
            }
        }
        for f in &self.flags {
            if !flags.contains(&f.as_str()) {
                return Err(describe("flag", f));
            }
        }
        Ok(())
    }

    /// Parse an `on|off` option (also accepts `true/false/1/0`), or
    /// `default` when absent; the error names the flag and the accepted
    /// values.
    pub fn on_off(&self, name: &str, default: bool) -> Result<bool, String> {
        match self.str_opt(name) {
            None => Ok(default),
            Some("on") | Some("true") | Some("1") => Ok(true),
            Some("off") | Some("false") | Some("0") => Ok(false),
            Some(other) => Err(format!("--{name} must be on|off, got '{other}'")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn parses_subcommand_opts_flags() {
        let a = parse("train --dataset products_sim --steps 100 --verbose");
        assert_eq!(a.subcommand.as_deref(), Some("train"));
        assert_eq!(a.str_opt("dataset"), Some("products_sim"));
        assert_eq!(a.get_or::<usize>("steps", 0).unwrap(), 100);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn equals_syntax() {
        let a = parse("bench --grid=2x2x2 --lr=0.01");
        assert_eq!(a.str_opt("grid"), Some("2x2x2"));
        assert_eq!(a.get_or::<f64>("lr", 0.0).unwrap(), 0.01);
    }

    #[test]
    fn trailing_flag_is_flag() {
        let a = parse("run --fast");
        assert!(a.flag("fast"));
    }

    #[test]
    fn numeric_value_not_eaten_as_flag() {
        let a = parse("run --count 5 --dry");
        assert_eq!(a.get_or::<i32>("count", 0).unwrap(), 5);
        assert!(a.flag("dry"));
    }

    #[test]
    fn path_opt_builds_pathbuf() {
        let a = parse("train --from-store /tmp/g.pallas");
        assert_eq!(a.path_opt("from-store"), Some(std::path::PathBuf::from("/tmp/g.pallas")));
        assert_eq!(a.path_opt("missing"), None);
    }

    #[test]
    fn bad_value_errors() {
        let a = parse("x --n abc");
        assert!(a.get::<usize>("n").is_err());
    }

    #[test]
    fn double_positional_rejected() {
        assert!(Args::parse(["a".to_string(), "b".to_string()]).is_err());
    }

    #[test]
    fn check_known_names_flag_and_accepted_values() {
        let a = parse("train --dataset tiny --vrbose");
        let err = a.check_known("train", &["dataset"], &["verbose"]).unwrap_err();
        assert!(err.contains("--vrbose"), "{err}");
        assert!(err.contains("--dataset") && err.contains("--verbose"), "{err}");
        let a = parse("train --datset tiny");
        let err = a.check_known("train", &["dataset"], &[]).unwrap_err();
        assert!(err.contains("--datset") && err.contains("train"), "{err}");
        let a = parse("train --dataset tiny --verbose");
        assert!(a.check_known("train", &["dataset"], &["verbose"]).is_ok());
    }

    #[test]
    fn on_off_parses_and_names_accepted_values() {
        let a = parse("x --overlap off");
        assert!(!a.on_off("overlap", true).unwrap());
        assert!(a.on_off("missing", true).unwrap());
        let a = parse("x --overlap sideways");
        let err = a.on_off("overlap", true).unwrap_err();
        assert!(err.contains("--overlap") && err.contains("on|off"), "{err}");
    }
}
