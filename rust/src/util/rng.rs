//! Deterministic PRNG utilities (no external `rand` in the offline build).
//!
//! `SplitMix64` seeds `Xoshiro256++`; both are well-studied, tiny, and —
//! crucially for the paper's communication-free sampling contract — fully
//! reproducible from a `(seed, step)` pair on every rank.

/// SplitMix64: used to expand a user seed into generator state and to mix
/// `(seed, step)` into an independent stream key.
#[inline]
pub fn splitmix64(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Reusable scratch of [`Rng::sample_k_of_n_sorted_into`]: the sparse
/// Fisher–Yates permutation overlay, retained (cleared, capacity kept)
/// across steps so the steady-state sample draw allocates nothing.
#[derive(Clone, Debug, Default)]
pub struct SampleScratch {
    overlay: std::collections::HashMap<u64, u64>,
}

/// Xoshiro256++ PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seeded via four SplitMix64 expansions (never the all-zero state).
    pub fn new(seed: u64) -> Self {
        let mut x = seed;
        let mut s = [0u64; 4];
        for v in s.iter_mut() {
            x = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
            *v = splitmix64(x);
        }
        Rng { s }
    }

    /// Independent stream for a `(seed, step)` pair — the paper's shared
    /// seed + step-index contract (§IV-B).
    pub fn for_step(seed: u64, step: u64) -> Self {
        Rng::new(splitmix64(seed ^ step.wrapping_mul(0xA076_1D64_78BD_642F)))
    }

    /// The full xoshiro256++ state, for checkpointing.
    pub fn state(&self) -> [u64; 4] {
        self.s
    }

    /// Rebuild a generator from a checkpointed [`Rng::state`] — the
    /// restored stream continues bit-for-bit where the saved one stopped.
    pub fn from_state(s: [u64; 4]) -> Self {
        Rng { s }
    }

    /// Next 64 random bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Next 32 random bits (the high half of `next_u64`).
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, bound)` via Lemire's multiply-shift (unbiased).
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(bound as u128);
        let mut l = m as u64;
        if l < bound {
            let t = bound.wrapping_neg() % bound;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(bound as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1 << 24) as f32)
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = (self.f64()).max(1e-300);
        let u2 = self.f64();
        ((-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()) as f32
    }

    /// Uniform f32 in `[lo, hi)`.
    #[inline]
    pub fn uniform(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.f32()
    }

    /// Sample `k` distinct values from `0..n` uniformly without replacement,
    /// returned **sorted** — Eq. 20's `S ~ Uniform(C(V, B))`.
    ///
    /// Partial Fisher–Yates over a sparse (hash-map overlay) permutation:
    /// `O(k)` time and space regardless of `n`.  Allocating convenience
    /// wrapper over [`Rng::sample_k_of_n_sorted_into`].
    pub fn sample_k_of_n_sorted(&mut self, k: usize, n: usize) -> Vec<u32> {
        let mut out = Vec::with_capacity(k);
        self.sample_k_of_n_sorted_into(k, n, &mut SampleScratch::default(), &mut out);
        out
    }

    /// Workspace variant of [`Rng::sample_k_of_n_sorted`]: the sparse
    /// permutation overlay lives in `scratch` and the sample lands in
    /// `out`, so the steady-state per-step draw performs zero heap
    /// allocations once both have warmed up.  The overlay is an
    /// implementation detail of the partial Fisher–Yates walk — clearing a
    /// retained map is observationally identical to building a fresh one,
    /// so the draw sequence (and therefore the sample) is identical to the
    /// allocating wrapper for the same RNG state.
    pub fn sample_k_of_n_sorted_into(
        &mut self,
        k: usize,
        n: usize,
        scratch: &mut SampleScratch,
        out: &mut Vec<u32>,
    ) {
        assert!(k <= n, "cannot sample {k} of {n}");
        let overlay = &mut scratch.overlay;
        overlay.clear();
        overlay.reserve(k * 2); // no-op once the scratch has warmed up
        out.clear();
        out.reserve(k);
        for i in 0..k as u64 {
            let j = i + self.below(n as u64 - i);
            let vj = *overlay.get(&j).unwrap_or(&j);
            let vi = *overlay.get(&i).unwrap_or(&i);
            overlay.insert(j, vi);
            out.push(vj as u32);
        }
        out.sort_unstable();
    }

    /// Fisher–Yates shuffle of a slice.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// `k` draws from `0..n` *with* replacement.
    pub fn sample_with_replacement(&mut self, k: usize, n: usize) -> Vec<u32> {
        (0..k).map(|_| self.below(n as u64) as u32).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn for_step_streams_are_independent() {
        let a: Vec<u64> = (0..8).map(|_| Rng::for_step(7, 0).next_u64()).collect();
        let b: Vec<u64> = (0..8).map(|_| Rng::for_step(7, 1).next_u64()).collect();
        assert_ne!(a, b);
        // and reproducible
        assert_eq!(Rng::for_step(7, 3).next_u64(), Rng::for_step(7, 3).next_u64());
    }

    #[test]
    fn state_roundtrip_resumes_bitwise() {
        let mut a = Rng::for_step(42, 9);
        for _ in 0..17 {
            a.next_u64();
        }
        let mut b = Rng::from_state(a.state());
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(1);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn sample_k_of_n_sorted_properties() {
        let mut r = Rng::new(3);
        for &(k, n) in &[(0usize, 5usize), (5, 5), (100, 1000), (1, 1)] {
            let s = r.sample_k_of_n_sorted(k, n);
            assert_eq!(s.len(), k);
            for w in s.windows(2) {
                assert!(w[0] < w[1], "sorted + distinct");
            }
            assert!(s.iter().all(|&v| (v as usize) < n));
        }
    }

    #[test]
    fn sample_into_reused_scratch_matches_allocating_wrapper() {
        let mut scratch = SampleScratch::default();
        let mut out = Vec::new();
        for step in 0..8u64 {
            let mut a = Rng::for_step(13, step);
            let mut b = Rng::for_step(13, step);
            let want = a.sample_k_of_n_sorted(33, 500);
            b.sample_k_of_n_sorted_into(33, 500, &mut scratch, &mut out);
            assert_eq!(out, want, "step {step}");
        }
    }

    #[test]
    fn sample_marginals_are_uniform() {
        // property: P[v in S] = B/N for every vertex (Eq. 20)
        let n = 200;
        let k = 20;
        let trials = 3000;
        let mut counts = vec![0u32; n];
        for t in 0..trials {
            let mut r = Rng::for_step(99, t);
            for v in r.sample_k_of_n_sorted(k, n) {
                counts[v as usize] += 1;
            }
        }
        let expect = trials as f64 * k as f64 / n as f64; // = 300
        for &c in &counts {
            // ~5.5 sigma of binomial(3000, 0.1)
            assert!(
                (c as f64 - expect).abs() < 5.5 * (expect * (1.0 - 0.1)).sqrt(),
                "count {c} vs expected {expect}"
            );
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 20000;
        let (mut s, mut s2) = (0.0f64, 0.0f64);
        for _ in 0..n {
            let x = r.normal() as f64;
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.08, "var {var}");
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut r = Rng::new(5);
        for _ in 0..1000 {
            let v = r.f32();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(8);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
