//! Panic-free little-endian decode helpers for the wire, checkpoint and
//! block-store boundaries.
//!
//! Every decoder in those layers validates lengths up front (`Dec::take`,
//! header-size checks, `chunks_exact`), which made the subsequent
//! `try_into().unwrap()` conversions infallible — but the panic-free
//! boundary discipline bans `unwrap` outright so a future refactor that
//! breaks the validation cannot turn into a panic.  These helpers read a
//! fixed-width value from the *front* of a slice with zero-extension:
//! given the callers' pre-validated lengths the padding never triggers,
//! and if a caller ever regresses, the result is a value that fails the
//! decoder's own semantic checks (checksums, length tables) instead of a
//! process abort mid-collective.

/// `u16` from the first 2 bytes of `b`, little endian.
#[inline]
pub fn u16_le(b: &[u8]) -> u16 {
    let mut a = [0u8; 2];
    let n = b.len().min(2);
    a[..n].copy_from_slice(&b[..n]);
    u16::from_le_bytes(a)
}

/// `u32` from the first 4 bytes of `b`, little endian.
#[inline]
pub fn u32_le(b: &[u8]) -> u32 {
    let mut a = [0u8; 4];
    let n = b.len().min(4);
    a[..n].copy_from_slice(&b[..n]);
    u32::from_le_bytes(a)
}

/// `u64` from the first 8 bytes of `b`, little endian.
#[inline]
pub fn u64_le(b: &[u8]) -> u64 {
    let mut a = [0u8; 8];
    let n = b.len().min(8);
    a[..n].copy_from_slice(&b[..n]);
    u64::from_le_bytes(a)
}

/// `f32` from the first 4 bytes of `b`, little endian.
#[inline]
pub fn f32_le(b: &[u8]) -> f32 {
    f32::from_bits(u32_le(b))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_exact_widths() {
        assert_eq!(u16_le(&0xbeefu16.to_le_bytes()), 0xbeef);
        assert_eq!(u32_le(&0xdead_beefu32.to_le_bytes()), 0xdead_beef);
        assert_eq!(u64_le(&0x0123_4567_89ab_cdefu64.to_le_bytes()), 0x0123_4567_89ab_cdef);
        assert_eq!(f32_le(&1.5f32.to_le_bytes()), 1.5);
    }

    #[test]
    fn longer_slices_read_the_prefix() {
        let b = [0x01, 0x02, 0x03, 0x04, 0x05, 0x06, 0x07, 0x08, 0xff];
        assert_eq!(u16_le(&b), 0x0201);
        assert_eq!(u32_le(&b), 0x0403_0201);
        assert_eq!(u64_le(&b), 0x0807_0605_0403_0201);
    }

    #[test]
    fn short_slices_zero_extend_instead_of_panicking() {
        assert_eq!(u32_le(&[0x01]), 0x01);
        assert_eq!(u64_le(&[]), 0);
        assert_eq!(u16_le(&[0xff]), 0xff);
    }
}
