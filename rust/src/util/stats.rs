//! Small statistics + timing helpers used by benches and metrics.

use std::time::Instant;

/// Mean of a slice (0.0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Population standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Percentile by nearest-rank on a copy (p in [0,100]).
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = ((p / 100.0) * (v.len() as f64 - 1.0)).round() as usize;
    v[rank.min(v.len() - 1)]
}

/// Median by nearest-rank.
pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Benchmark runner in the criterion spirit: warmup then timed iterations,
/// reporting mean/median/p95 in seconds.  Used by `rust/benches/*`
/// (`harness = false`; the offline crate set has no criterion).
pub struct BenchResult {
    /// Benchmark label.
    pub name: String,
    /// Recorded iterations.
    pub iters: usize,
    /// Mean seconds per iteration.
    pub mean_s: f64,
    /// Median seconds per iteration.
    pub median_s: f64,
    /// 95th-percentile seconds per iteration.
    pub p95_s: f64,
    /// Population standard deviation in seconds.
    pub stddev_s: f64,
    /// Raw per-iteration samples in seconds, in measurement order — lets
    /// callers compute their own robust statistics (e.g. the overlap
    /// on/off medians of `BENCH_e2e.json`).
    pub samples: Vec<f64>,
}

impl BenchResult {
    /// One-line human-readable summary.
    pub fn report(&self) -> String {
        format!(
            "{:<44} {:>10.3} ms/iter (median {:.3}, p95 {:.3}, sd {:.3}, n={})",
            self.name,
            self.mean_s * 1e3,
            self.median_s * 1e3,
            self.p95_s * 1e3,
            self.stddev_s * 1e3,
            self.iters
        )
    }
}

/// Run `f` for `warmup` unrecorded + `iters` recorded iterations.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64());
    }
    BenchResult {
        name: name.to_string(),
        iters,
        mean_s: mean(&samples),
        median_s: median(&samples),
        p95_s: percentile(&samples, 95.0),
        stddev_s: stddev(&samples),
        samples,
    }
}

/// Format seconds with an adaptive unit.
pub fn fmt_time(s: f64) -> String {
    if s >= 1.0 {
        format!("{:.2} s", s)
    } else if s >= 1e-3 {
        format!("{:.2} ms", s * 1e3)
    } else {
        format!("{:.1} us", s * 1e6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_basics() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&xs), 2.5);
        assert!((stddev(&xs) - 1.118).abs() < 1e-3);
        assert_eq!(median(&xs), 3.0); // nearest-rank (round half up) on even length
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
    }

    #[test]
    fn bench_runs_and_counts() {
        let mut n = 0;
        let r = bench("noop", 2, 5, || n += 1);
        assert_eq!(n, 7);
        assert_eq!(r.iters, 5);
        assert!(r.mean_s >= 0.0);
        assert_eq!(r.samples.len(), 5);
    }

    #[test]
    fn fmt_time_units() {
        assert!(fmt_time(2.0).ends_with(" s"));
        assert!(fmt_time(2e-3).ends_with(" ms"));
        assert!(fmt_time(2e-6).ends_with(" us"));
    }
}
