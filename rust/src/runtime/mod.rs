//! PJRT runtime: load the AOT HLO-text artifacts and execute them.
//!
//! Wraps the `xla` crate (`PjRtClient::cpu()` ->
//! `HloModuleProto::from_text_file` -> `compile` -> `execute`).  The
//! manifest written by `python/compile/aot.py` provides shapes/dtypes and
//! the model parameter layout, so the coordinator can pack inputs and
//! unpack the returned tuple without any Python at run time.
//!
//! `PjRtClient` is `Rc`-based (not `Send`): every worker thread constructs
//! its own `Runtime`.  The CPU PJRT backend itself is thread-safe; the
//! per-thread wrapper only costs one client handle and one compile per
//! artifact per thread.

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;

/// dtype of an artifact input/output.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    /// 32-bit float.
    F32,
    /// 32-bit signed integer.
    I32,
    /// 32-bit unsigned integer.
    U32,
}

impl DType {
    fn parse(s: &str) -> Result<DType> {
        match s {
            "float32" => Ok(DType::F32),
            "int32" => Ok(DType::I32),
            "uint32" => Ok(DType::U32),
            other => bail!("unsupported dtype {other}"),
        }
    }
}

/// Shape+dtype of one artifact input or output.
#[derive(Clone, Debug)]
pub struct TensorSpec {
    /// Tensor dimensions.
    pub shape: Vec<usize>,
    /// Element type.
    pub dtype: DType,
}

impl TensorSpec {
    /// Total element count.
    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }
}

/// Manifest entry for one artifact.
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    /// Manifest key / artifact name.
    pub name: String,
    /// HLO text file relative to the artifacts dir.
    pub file: String,
    /// Artifact family (e.g. `train_step`).
    pub family: String,
    /// Model configuration this artifact was lowered for, if any.
    pub model: Option<String>,
    /// Input tensor specs in call order.
    pub inputs: Vec<TensorSpec>,
    /// Output tuple specs in return order.
    pub outputs: Vec<TensorSpec>,
}

/// Model metadata mirrored from `python/compile/model.py::ModelConfig`.
#[derive(Clone, Debug)]
pub struct ModelMeta {
    /// Model configuration name.
    pub name: String,
    /// Fixed mini-batch size the artifact was lowered with.
    pub batch: usize,
    /// Input feature dimensionality.
    pub d_in: usize,
    /// Hidden width.
    pub d_h: usize,
    /// Output classes.
    pub d_out: usize,
    /// Number of GCN layers.
    pub layers: usize,
    /// Dropout probability baked into the training artifact.
    pub dropout: f32,
    /// padded edge-list capacity of the sparse-SpMM artifacts (0 = dense)
    pub edge_cap: usize,
    /// Number of parameter tensors.
    pub n_params: usize,
    /// Parameter shapes in artifact order.
    pub param_shapes: Vec<Vec<usize>>,
    /// Parameter names in artifact order.
    pub param_names: Vec<String>,
}

impl ModelMeta {
    /// Total trainable scalar count.
    pub fn param_elems(&self) -> usize {
        self.param_shapes.iter().map(|s| s.iter().product::<usize>()).sum()
    }
}

/// Parsed artifacts/manifest.json.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    /// Artifacts by name.
    pub artifacts: HashMap<String, ArtifactSpec>,
    /// Model configurations by name.
    pub models: HashMap<String, ModelMeta>,
}

fn spec_from_json(j: &Json) -> Result<TensorSpec> {
    Ok(TensorSpec {
        shape: j
            .get("shape")
            .and_then(|s| s.as_usize_vec())
            .ok_or_else(|| anyhow!("bad shape"))?,
        dtype: DType::parse(j.get("dtype").and_then(|d| d.as_str()).unwrap_or("?"))?,
    })
}

impl Manifest {
    /// Parse `dir/manifest.json` (written by `python/compile/aot.py`).
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("manifest parse: {e}"))?;
        let mut m = Manifest::default();
        for a in j.get("artifacts").and_then(|a| a.as_arr()).unwrap_or(&[]) {
            let name = a
                .get("name")
                .and_then(|n| n.as_str())
                .ok_or_else(|| anyhow!("artifact without name"))?
                .to_string();
            let inputs = a
                .get("inputs")
                .and_then(|i| i.as_arr())
                .ok_or_else(|| anyhow!("artifact {name}: no inputs"))?
                .iter()
                .map(spec_from_json)
                .collect::<Result<Vec<_>>>()?;
            let outputs = a
                .get("outputs")
                .and_then(|o| o.as_arr())
                .ok_or_else(|| anyhow!("artifact {name}: no outputs"))?
                .iter()
                .map(spec_from_json)
                .collect::<Result<Vec<_>>>()?;
            m.artifacts.insert(
                name.clone(),
                ArtifactSpec {
                    file: a
                        .get("file")
                        .and_then(|f| f.as_str())
                        .unwrap_or(&format!("{name}.hlo.txt"))
                        .to_string(),
                    family: a.get("family").and_then(|f| f.as_str()).unwrap_or("").to_string(),
                    model: a.get("model").and_then(|f| f.as_str()).map(str::to_string),
                    name,
                    inputs,
                    outputs,
                },
            );
        }
        if let Some(models) = j.get("models").and_then(|m| m.as_obj()) {
            for (name, mj) in models {
                let get = |k: &str| -> usize {
                    mj.get(k).and_then(|v| v.as_usize()).unwrap_or(0)
                };
                let param_shapes: Vec<Vec<usize>> = mj
                    .get("param_shapes")
                    .and_then(|s| s.as_arr())
                    .map(|arr| arr.iter().filter_map(|x| x.as_usize_vec()).collect())
                    .unwrap_or_default();
                let param_names: Vec<String> = mj
                    .get("param_names")
                    .and_then(|s| s.as_arr())
                    .map(|arr| {
                        arr.iter().filter_map(|x| x.as_str().map(str::to_string)).collect()
                    })
                    .unwrap_or_default();
                m.models.insert(
                    name.clone(),
                    ModelMeta {
                        name: name.clone(),
                        batch: get("batch"),
                        d_in: get("d_in"),
                        d_h: get("d_h"),
                        d_out: get("d_out"),
                        layers: get("layers"),
                        edge_cap: get("edge_cap"),
                        dropout: mj
                            .get("dropout")
                            .and_then(|v| v.as_f64())
                            .unwrap_or(0.0) as f32,
                        n_params: param_shapes.len(),
                        param_shapes,
                        param_names,
                    },
                );
            }
        }
        Ok(m)
    }
}

/// A compiled artifact ready to execute.
pub struct Executable {
    /// The manifest entry this executable was compiled from.
    pub spec: ArtifactSpec,
    exe: xla::PjRtLoadedExecutable,
}

impl Executable {
    /// Execute with packed literals; returns the decomposed output tuple.
    pub fn run(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        if inputs.len() != self.spec.inputs.len() {
            bail!(
                "{}: {} inputs given, {} expected",
                self.spec.name,
                inputs.len(),
                self.spec.inputs.len()
            );
        }
        let bufs = self.exe.execute::<xla::Literal>(inputs)?;
        let lit = bufs[0][0].to_literal_sync()?;
        // artifacts are lowered with return_tuple=True
        Ok(lit.to_tuple()?)
    }
}

/// Per-thread PJRT runtime with an executable cache.
pub struct Runtime {
    /// Artifacts directory this runtime reads from.
    pub dir: PathBuf,
    /// Parsed manifest.
    pub manifest: Manifest,
    client: xla::PjRtClient,
    cache: RefCell<HashMap<String, Rc<Executable>>>,
}

impl Runtime {
    /// Open `dir` (default `artifacts/`), parse the manifest, create the
    /// CPU PJRT client.
    pub fn open(dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Runtime { dir: dir.to_path_buf(), manifest, client, cache: RefCell::new(HashMap::new()) })
    }

    /// PJRT platform name (e.g. `cpu`).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an artifact by manifest name (cached).
    pub fn load(&self, name: &str) -> Result<Rc<Executable>> {
        if let Some(e) = self.cache.borrow().get(name) {
            return Ok(e.clone());
        }
        let spec = self
            .manifest
            .artifacts
            .get(name)
            .ok_or_else(|| anyhow!("artifact '{name}' not in manifest"))?
            .clone();
        let path = self.dir.join(&spec.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("bad path"))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        let e = Rc::new(Executable { spec, exe });
        self.cache.borrow_mut().insert(name.to_string(), e.clone());
        Ok(e)
    }

    /// Model metadata by configuration name.
    pub fn model(&self, name: &str) -> Result<&ModelMeta> {
        self.manifest
            .models
            .get(name)
            .ok_or_else(|| anyhow!("model '{name}' not in manifest"))
    }
}

/// True when the AOT artifacts exist under `dir` AND a real PJRT backend is
/// linked.  Tests, benches and examples use this single gate to skip
/// artifact-dependent paths in the offline/stub build (`make artifacts`
/// plus a real `xla` crate enable them).
pub fn pjrt_artifacts_available(dir: &Path) -> bool {
    dir.join("manifest.json").exists() && xla::PjRtClient::cpu().is_ok()
}

// ---------------------------------------------------------------------------
// Literal pack/unpack helpers
// ---------------------------------------------------------------------------

/// f32 tensor literal of the given shape.
pub fn lit_f32(data: &[f32], shape: &[usize]) -> Result<xla::Literal> {
    debug_assert_eq!(data.len(), shape.iter().product::<usize>());
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Ok(xla::Literal::vec1(data).reshape(&dims)?)
}

/// i32 tensor literal of the given shape.
pub fn lit_i32(data: &[i32], shape: &[usize]) -> Result<xla::Literal> {
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Ok(xla::Literal::vec1(data).reshape(&dims)?)
}

/// u32 tensor literal of the given shape.
pub fn lit_u32(data: &[u32], shape: &[usize]) -> Result<xla::Literal> {
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Ok(xla::Literal::vec1(data).reshape(&dims)?)
}

/// Rank-0 f32 literal.
pub fn lit_scalar_f32(v: f32) -> xla::Literal {
    xla::Literal::scalar(v)
}

/// Extract an f32 tensor.
pub fn to_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    Ok(lit.to_vec::<f32>()?)
}

/// Extract the scalar f32 value of a rank-0 literal.
pub fn scalar_f32(lit: &xla::Literal) -> Result<f32> {
    Ok(lit.get_first_element::<f32>()?)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> PathBuf {
        // tests run from the workspace root
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    /// Skip PJRT tests when the artifacts have not been built (offline/stub
    /// environments); see `make artifacts`.
    fn artifacts_available() -> bool {
        let ok = pjrt_artifacts_available(&artifacts_dir());
        if !ok {
            eprintln!("skipping: PJRT artifacts/backend not available");
        }
        ok
    }

    #[test]
    fn manifest_parses_and_has_models() {
        if !artifacts_available() {
            return;
        }
        let m = Manifest::load(&artifacts_dir()).expect("make artifacts first");
        assert!(m.artifacts.contains_key("train_step_tiny"));
        let tiny = &m.models["tiny"];
        assert_eq!(tiny.batch, 32);
        assert_eq!(tiny.n_params, 2 + 2 * tiny.layers);
        assert_eq!(tiny.param_shapes[0], vec![tiny.d_in, tiny.d_h]);
        assert_eq!(tiny.param_names[0], "w_in");
    }

    #[test]
    fn train_step_spec_shapes_are_consistent() {
        if !artifacts_available() {
            return;
        }
        let m = Manifest::load(&artifacts_dir()).unwrap();
        let a = &m.artifacts["train_step_tiny"];
        let mm = &m.models["tiny"];
        // src, dst, val, x, y, wmask, key, lr, t + 3 x n_params state
        assert_eq!(a.inputs.len(), 9 + 3 * mm.n_params);
        assert!(mm.edge_cap > 0);
        assert_eq!(a.inputs[0].shape, vec![mm.edge_cap]);
        assert_eq!(a.inputs[0].dtype, DType::I32);
        assert_eq!(a.inputs[2].dtype, DType::F32);
        assert_eq!(a.inputs[3].shape, vec![mm.batch, mm.d_in]);
        assert_eq!(a.inputs[6].dtype, DType::U32);
        // loss, acc, t, then params/m/v
        assert_eq!(a.outputs.len(), 3 + 3 * mm.n_params);
        // the dense variant keeps the B x B adjacency (TPU schedule)
        let ad = &m.artifacts["train_step_tiny_dense"];
        let md = &m.models["tiny_dense"];
        assert_eq!(ad.inputs[0].shape, vec![md.batch, md.batch]);
    }

    #[test]
    fn unknown_artifact_errors() {
        if !artifacts_available() {
            return;
        }
        let rt = Runtime::open(&artifacts_dir()).unwrap();
        assert!(rt.load("nope").is_err());
    }

    #[test]
    fn local_gemm_executes_correctly() {
        if !artifacts_available() {
            return;
        }
        let rt = Runtime::open(&artifacts_dir()).unwrap();
        let exe = rt.load("local_gemm_256x64x64").unwrap();
        let mut rng = crate::util::rng::Rng::new(1);
        let a = crate::tensor::Mat::randn(256, 64, &mut rng, 1.0);
        let b = crate::tensor::Mat::randn(64, 64, &mut rng, 1.0);
        let out = exe
            .run(&[
                lit_f32(&a.data, &[256, 64]).unwrap(),
                lit_f32(&b.data, &[64, 64]).unwrap(),
            ])
            .unwrap();
        let got = crate::tensor::Mat::from_vec(256, 64, to_f32(&out[0]).unwrap());
        let want = a.matmul(&b);
        assert!(got.allclose(&want, 1e-3, 1e-3), "max diff {}", got.max_abs_diff(&want));
    }

    #[test]
    fn executable_cache_returns_same_instance() {
        if !artifacts_available() {
            return;
        }
        let rt = Runtime::open(&artifacts_dir()).unwrap();
        let a = rt.load("local_gemm_256x64x64").unwrap();
        let b = rt.load("local_gemm_256x64x64").unwrap();
        assert!(Rc::ptr_eq(&a, &b));
    }

    #[test]
    fn wrong_arity_is_rejected() {
        if !artifacts_available() {
            return;
        }
        let rt = Runtime::open(&artifacts_dir()).unwrap();
        let exe = rt.load("local_gemm_256x64x64").unwrap();
        assert!(exe.run(&[]).is_err());
    }
}
