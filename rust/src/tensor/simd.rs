//! Runtime-dispatched SIMD kernels for the hot inner loops (§V-B makes
//! low precision free only if widen/narrow is; the same discipline makes
//! the f32 inner loops vectorize).
//!
//! Every kernel here has a retained scalar reference (`*_scalar`) and the
//! dispatched entry point is **bitwise identical** to it on every input —
//! the invariant the whole oracle-test discipline of this crate rests on:
//!
//! * The f32 kernel ([`axpy`]) vectorizes across *independent output
//!   columns* only, so the per-scalar accumulation order over `k` never
//!   changes, and the vector lanes use separate multiply and add (never
//!   FMA) — packed IEEE-754 `mul`/`add` round exactly like their scalar
//!   counterparts, and Rust never enables FTZ/DAZ, so denormal and NaN
//!   lanes match too.
//! * The bf16 conversions ([`widen_bf16`], [`narrow_bf16`],
//!   [`round_bf16`]) are pure integer bit manipulation, replicating
//!   `util::f32_to_bf16_bits` (round-to-nearest-even, NaN quieting) lane
//!   for lane.
//!
//! Dispatch resolves once per process ([`level`]): AVX2 on `x86_64` when
//! the CPU reports it, NEON on `aarch64` (baseline), scalar otherwise.
//! Setting `PALLAS_SIMD=0` forces the scalar path — CI runs the test
//! suite both ways so each dispatch path stays covered.

use std::sync::OnceLock;

use crate::util::{bf16_bits_to_f32, f32_to_bf16_bits, bf16_round};

/// Vector path the dispatched kernels take for this process.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SimdLevel {
    /// Portable scalar reference path (also the `PALLAS_SIMD=0` escape
    /// hatch and the oracle every vector path is pinned against).
    Scalar,
    /// 256-bit AVX2 path (`x86_64`, detected at runtime).
    Avx2,
    /// 128-bit NEON path (`aarch64`, baseline feature).
    Neon,
}

/// The vector path selected for this process, resolved once on first use:
/// `PALLAS_SIMD=0` forces [`SimdLevel::Scalar`], otherwise the best path
/// the CPU supports.  Mirrors `pool::num_threads`' `PALLAS_THREADS`
/// resolution so the per-call hot path is a cached load.
pub fn level() -> SimdLevel {
    static LEVEL: OnceLock<SimdLevel> = OnceLock::new();
    *LEVEL.get_or_init(|| {
        if let Ok(v) = std::env::var("PALLAS_SIMD") {
            if v.trim() == "0" {
                return SimdLevel::Scalar;
            }
        }
        detect()
    })
}

#[cfg(target_arch = "x86_64")]
fn detect() -> SimdLevel {
    if is_x86_feature_detected!("avx2") {
        SimdLevel::Avx2
    } else {
        SimdLevel::Scalar
    }
}

#[cfg(target_arch = "aarch64")]
fn detect() -> SimdLevel {
    SimdLevel::Neon
}

#[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
fn detect() -> SimdLevel {
    SimdLevel::Scalar
}

// ---------------------------------------------------------------------------
// axpy: the shared inner loop of GEMM / SpMM / fused spmm_matmul
// ---------------------------------------------------------------------------

/// `acc[j] += a * b[j]` over independent output columns — the one inner
/// loop shared by the j-tiled GEMM microkernel, SpMM row accumulation and
/// the fused `spmm_matmul`.  Bitwise identical to [`axpy_scalar`] on
/// every input (see the module docs for why).
#[inline]
pub fn axpy(acc: &mut [f32], a: f32, b: &[f32]) {
    debug_assert_eq!(acc.len(), b.len());
    match level() {
        // SAFETY: level() returns Avx2 only when is_x86_feature_detected!
        // confirmed AVX2 at runtime, which is the target_feature
        // precondition of axpy_avx2; slice bounds are checked inside.
        #[cfg(target_arch = "x86_64")]
        SimdLevel::Avx2 => unsafe { axpy_avx2(acc, a, b) },
        // SAFETY: NEON is a baseline aarch64 feature, always present when
        // this arm compiles; slice bounds are checked inside.
        #[cfg(target_arch = "aarch64")]
        SimdLevel::Neon => unsafe { axpy_neon(acc, a, b) },
        _ => axpy_scalar(acc, a, b),
    }
}

/// Scalar reference for [`axpy`], retained as the oracle the vector paths
/// are pinned against (and the `PALLAS_SIMD=0` path).
#[inline]
pub fn axpy_scalar(acc: &mut [f32], a: f32, b: &[f32]) {
    for (cj, &bj) in acc.iter_mut().zip(b) {
        *cj += a * bj;
    }
}

// SAFETY: caller must ensure the CPU supports AVX2 (the dispatcher checks
// via level()).  Every unaligned load/store stays in bounds: j + 8 <= n
// with n = min(acc.len(), b.len()), and loadu/storeu have no alignment
// requirement.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn axpy_avx2(acc: &mut [f32], a: f32, b: &[f32]) {
    use std::arch::x86_64::*;
    let n = acc.len().min(b.len());
    let va = _mm256_set1_ps(a);
    let mut j = 0;
    while j + 8 <= n {
        let vb = _mm256_loadu_ps(b.as_ptr().add(j));
        let vc = _mm256_loadu_ps(acc.as_ptr().add(j));
        // separate mul then add, operand order as in the scalar kernel
        // (`acc + a * b`): packed IEEE semantics equal scalar mulss/addss
        // per lane, so no FMA and no reassociation — bitwise identical
        let prod = _mm256_mul_ps(va, vb);
        _mm256_storeu_ps(acc.as_mut_ptr().add(j), _mm256_add_ps(vc, prod));
        j += 8;
    }
    axpy_scalar(&mut acc[j..n], a, &b[j..n]);
}

// SAFETY: NEON is baseline on aarch64, so the intrinsics are always
// available; every vld1q/vst1q stays in bounds because j + 4 <= n with
// n = min(acc.len(), b.len()).
#[cfg(target_arch = "aarch64")]
unsafe fn axpy_neon(acc: &mut [f32], a: f32, b: &[f32]) {
    use std::arch::aarch64::*;
    let n = acc.len().min(b.len());
    let va = vdupq_n_f32(a);
    let mut j = 0;
    while j + 4 <= n {
        let vb = vld1q_f32(b.as_ptr().add(j));
        let vc = vld1q_f32(acc.as_ptr().add(j));
        let prod = vmulq_f32(va, vb);
        vst1q_f32(acc.as_mut_ptr().add(j), vaddq_f32(vc, prod));
        j += 4;
    }
    axpy_scalar(&mut acc[j..n], a, &b[j..n]);
}

// ---------------------------------------------------------------------------
// bf16 widen / narrow / round batch conversion
// ---------------------------------------------------------------------------

/// Widen packed bf16 bits to f32: `dst[i] = bits[i] << 16` reinterpreted.
/// Exact by construction (bf16 is the high half of an f32); bitwise
/// identical to [`widen_bf16_scalar`].
pub fn widen_bf16(src: &[u16], dst: &mut [f32]) {
    assert_eq!(src.len(), dst.len(), "widen_bf16 length mismatch");
    match level() {
        // SAFETY: level() returns Avx2 only after runtime AVX2 detection
        // (the target_feature precondition); src.len() == dst.len() was
        // asserted above and all loads/stores are bounded by it.
        #[cfg(target_arch = "x86_64")]
        SimdLevel::Avx2 => unsafe { widen_bf16_avx2(src, dst) },
        // SAFETY: NEON is baseline on aarch64; lengths asserted equal
        // above bound every access.
        #[cfg(target_arch = "aarch64")]
        SimdLevel::Neon => unsafe { widen_bf16_neon(src, dst) },
        _ => widen_bf16_scalar(src, dst),
    }
}

/// Scalar reference for [`widen_bf16`].
pub fn widen_bf16_scalar(src: &[u16], dst: &mut [f32]) {
    for (d, &s) in dst.iter_mut().zip(src) {
        *d = bf16_bits_to_f32(s);
    }
}

// SAFETY: caller must ensure AVX2 support (dispatcher-checked) and
// src.len() == dst.len() (asserted by the public wrapper); i + 8 <= n
// bounds each 128-bit load and 256-bit store, both unaligned-safe.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn widen_bf16_avx2(src: &[u16], dst: &mut [f32]) {
    use std::arch::x86_64::*;
    let n = src.len();
    let mut i = 0;
    while i + 8 <= n {
        let h = _mm_loadu_si128(src.as_ptr().add(i) as *const __m128i);
        let w = _mm256_slli_epi32::<16>(_mm256_cvtepu16_epi32(h));
        _mm256_storeu_si256(dst.as_mut_ptr().add(i) as *mut __m256i, w);
        i += 8;
    }
    widen_bf16_scalar(&src[i..], &mut dst[i..]);
}

// SAFETY: NEON is baseline on aarch64; caller guarantees src.len() ==
// dst.len() (asserted by the public wrapper) and i + 4 <= n bounds every
// access.  Storing u32 bit patterns through the *mut u32 cast is sound:
// f32 and u32 have identical size/alignment and any bit pattern is a
// valid f32.
#[cfg(target_arch = "aarch64")]
unsafe fn widen_bf16_neon(src: &[u16], dst: &mut [f32]) {
    use std::arch::aarch64::*;
    let n = src.len();
    let mut i = 0;
    while i + 4 <= n {
        let h = vld1_u16(src.as_ptr().add(i));
        let w = vshlq_n_u32::<16>(vmovl_u16(h));
        vst1q_u32(dst.as_mut_ptr().add(i) as *mut u32, w);
        i += 4;
    }
    widen_bf16_scalar(&src[i..], &mut dst[i..]);
}

/// Narrow f32s to packed bf16 bits with round-to-nearest-even and NaN
/// quieting — lane-for-lane the integer algorithm of
/// `util::f32_to_bf16_bits`, so bitwise identical to
/// [`narrow_bf16_scalar`] including NaN and denormal lanes.
pub fn narrow_bf16(src: &[f32], dst: &mut [u16]) {
    assert_eq!(src.len(), dst.len(), "narrow_bf16 length mismatch");
    match level() {
        // SAFETY: level() returns Avx2 only after runtime AVX2 detection;
        // src.len() == dst.len() was asserted above.
        #[cfg(target_arch = "x86_64")]
        SimdLevel::Avx2 => unsafe { narrow_bf16_avx2(src, dst) },
        // SAFETY: NEON is baseline on aarch64; lengths asserted equal
        // above bound every access.
        #[cfg(target_arch = "aarch64")]
        SimdLevel::Neon => unsafe { narrow_bf16_neon(src, dst) },
        _ => narrow_bf16_scalar(src, dst),
    }
}

/// Scalar reference for [`narrow_bf16`].
pub fn narrow_bf16_scalar(src: &[f32], dst: &mut [u16]) {
    for (d, &s) in dst.iter_mut().zip(src) {
        *d = f32_to_bf16_bits(s);
    }
}

// SAFETY: caller must ensure AVX2 support (dispatcher-checked) and
// src.len() == dst.len() (asserted by the public wrapper); i + 16 <= n
// bounds the two 8-lane loads and the packed 16-lane store, all
// unaligned-safe.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn narrow_bf16_avx2(src: &[f32], dst: &mut [u16]) {
    use std::arch::x86_64::*;
    let n = src.len();
    let mut i = 0;
    // 8 f32 -> 8 u32 lanes, each holding the bf16 bits in its low half
    // SAFETY: caller must pass a pointer with 8 readable f32s (the outer
    // loop guarantees i + 16 <= n for both 8-lane halves) on an
    // AVX2-capable CPU (inherited from the enclosing target_feature fn).
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn narrow8(p: *const f32) -> __m256i {
        let bits = _mm256_loadu_si256(p as *const __m256i);
        let magnitude = _mm256_and_si256(bits, _mm256_set1_epi32(0x7fff_ffff));
        // NaN <=> magnitude > 0x7f80_0000; both sides are non-negative
        // i32s after the mask, so the signed compare is exact
        let is_nan = _mm256_cmpgt_epi32(magnitude, _mm256_set1_epi32(0x7f80_0000));
        let hi = _mm256_srli_epi32::<16>(bits);
        let quieted = _mm256_or_si256(hi, _mm256_set1_epi32(0x0040));
        // round-to-nearest-even: bits + 0x7fff + lsb-of-result, then >> 16
        let lsb = _mm256_and_si256(hi, _mm256_set1_epi32(1));
        let biased = _mm256_add_epi32(_mm256_add_epi32(bits, _mm256_set1_epi32(0x7fff)), lsb);
        let rounded = _mm256_srli_epi32::<16>(biased);
        _mm256_blendv_epi8(rounded, quieted, is_nan)
    }
    while i + 16 <= n {
        let a = narrow8(src.as_ptr().add(i));
        let b = narrow8(src.as_ptr().add(i + 8));
        // packus interleaves the two 128-bit lanes; permute restores
        // element order.  Values fit in u16 so the saturation is exact.
        let packed = _mm256_permute4x64_epi64::<0b1101_1000>(_mm256_packus_epi32(a, b));
        _mm256_storeu_si256(dst.as_mut_ptr().add(i) as *mut __m256i, packed);
        i += 16;
    }
    narrow_bf16_scalar(&src[i..], &mut dst[i..]);
}

// SAFETY: NEON is baseline on aarch64; caller guarantees src.len() ==
// dst.len() (asserted by the public wrapper) and i + 4 <= n bounds every
// load and the narrowing store.
#[cfg(target_arch = "aarch64")]
unsafe fn narrow_bf16_neon(src: &[f32], dst: &mut [u16]) {
    use std::arch::aarch64::*;
    let n = src.len();
    let mut i = 0;
    while i + 4 <= n {
        let bits = vreinterpretq_u32_f32(vld1q_f32(src.as_ptr().add(i)));
        let magnitude = vandq_u32(bits, vdupq_n_u32(0x7fff_ffff));
        let is_nan = vcgtq_u32(magnitude, vdupq_n_u32(0x7f80_0000));
        let hi = vshrq_n_u32::<16>(bits);
        let quieted = vorrq_u32(hi, vdupq_n_u32(0x0040));
        let lsb = vandq_u32(hi, vdupq_n_u32(1));
        let biased = vaddq_u32(vaddq_u32(bits, vdupq_n_u32(0x7fff)), lsb);
        let rounded = vshrq_n_u32::<16>(biased);
        let sel = vbslq_u32(is_nan, quieted, rounded);
        vst1_u16(dst.as_mut_ptr().add(i), vmovn_u32(sel));
        i += 4;
    }
    narrow_bf16_scalar(&src[i..], &mut dst[i..]);
}

/// In-place bf16 round of an f32 slice (fused narrow + widen): exactly
/// what a bf16 collective does to each contribution before it moves
/// (`util::bf16_round` per lane).  Bitwise identical to
/// [`round_bf16_scalar`].
pub fn round_bf16(xs: &mut [f32]) {
    match level() {
        // SAFETY: level() returns Avx2 only after runtime AVX2 detection;
        // the kernel bounds every access by xs.len().
        #[cfg(target_arch = "x86_64")]
        SimdLevel::Avx2 => unsafe { round_bf16_avx2(xs) },
        // SAFETY: NEON is baseline on aarch64; the kernel bounds every
        // access by xs.len().
        #[cfg(target_arch = "aarch64")]
        SimdLevel::Neon => unsafe { round_bf16_neon(xs) },
        _ => round_bf16_scalar(xs),
    }
}

/// Scalar reference for [`round_bf16`].
pub fn round_bf16_scalar(xs: &mut [f32]) {
    for x in xs.iter_mut() {
        *x = bf16_round(*x);
    }
}

// SAFETY: caller must ensure AVX2 support (dispatcher-checked); i + 8 <= n
// bounds every in-place load/store, and writing integer bit patterns into
// the f32 slice is sound because any u32 pattern is a valid f32.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn round_bf16_avx2(xs: &mut [f32]) {
    use std::arch::x86_64::*;
    let n = xs.len();
    let mut i = 0;
    while i + 8 <= n {
        let bits = _mm256_loadu_si256(xs.as_ptr().add(i) as *const __m256i);
        let magnitude = _mm256_and_si256(bits, _mm256_set1_epi32(0x7fff_ffff));
        let is_nan = _mm256_cmpgt_epi32(magnitude, _mm256_set1_epi32(0x7f80_0000));
        let hi = _mm256_srli_epi32::<16>(bits);
        let quieted = _mm256_or_si256(hi, _mm256_set1_epi32(0x0040));
        let lsb = _mm256_and_si256(hi, _mm256_set1_epi32(1));
        let biased = _mm256_add_epi32(_mm256_add_epi32(bits, _mm256_set1_epi32(0x7fff)), lsb);
        let rounded = _mm256_srli_epi32::<16>(biased);
        let sel = _mm256_blendv_epi8(rounded, quieted, is_nan);
        // widen back: the selected low 16 bits become the f32 high half
        let widened = _mm256_slli_epi32::<16>(sel);
        _mm256_storeu_si256(xs.as_mut_ptr().add(i) as *mut __m256i, widened);
        i += 8;
    }
    round_bf16_scalar(&mut xs[i..]);
}

// SAFETY: NEON is baseline on aarch64; i + 4 <= n bounds every in-place
// load/store, and the *mut u32 store is sound because f32 and u32 share
// size/alignment and any bit pattern is a valid f32.
#[cfg(target_arch = "aarch64")]
unsafe fn round_bf16_neon(xs: &mut [f32]) {
    use std::arch::aarch64::*;
    let n = xs.len();
    let mut i = 0;
    while i + 4 <= n {
        let bits = vreinterpretq_u32_f32(vld1q_f32(xs.as_ptr().add(i)));
        let magnitude = vandq_u32(bits, vdupq_n_u32(0x7fff_ffff));
        let is_nan = vcgtq_u32(magnitude, vdupq_n_u32(0x7f80_0000));
        let hi = vshrq_n_u32::<16>(bits);
        let quieted = vorrq_u32(hi, vdupq_n_u32(0x0040));
        let lsb = vandq_u32(hi, vdupq_n_u32(1));
        let biased = vaddq_u32(vaddq_u32(bits, vdupq_n_u32(0x7fff)), lsb);
        let rounded = vshrq_n_u32::<16>(biased);
        let sel = vbslq_u32(is_nan, quieted, rounded);
        vst1q_u32(xs.as_mut_ptr().add(i) as *mut u32, vshlq_n_u32::<16>(sel));
        i += 4;
    }
    round_bf16_scalar(&mut xs[i..]);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// Adversarial lane values: bf16-exact, needs-rounding, carries into
    /// the exponent, ±0, ±inf, NaNs (payload bits), denormals (f32 and
    /// below-bf16), and huge/tiny magnitudes.
    fn adversarial_values() -> Vec<f32> {
        let mut v = vec![
            0.0,
            -0.0,
            1.0,
            -1.0,
            1.0009765625, // needs mantissa rounding
            -1.0009765625,
            1.00390625, // bf16-exact
            f32::from_bits(0x3f80_7fff), // rounds up with carry
            f32::from_bits(0x3f80_8000), // round-to-even boundary
            f32::from_bits(0x3f81_8000), // round-to-even boundary, odd lsb
            f32::INFINITY,
            f32::NEG_INFINITY,
            f32::NAN,
            f32::from_bits(0x7f80_0001), // signaling-ish NaN, tiny payload
            f32::from_bits(0xffc1_2345), // negative NaN with payload
            f32::MIN_POSITIVE,           // smallest normal
            f32::MIN_POSITIVE / 4.0,     // denormal
            f32::from_bits(1),           // smallest denormal
            f32::MAX,
            -f32::MAX,
            3.4e38,
            1e-40,
            -1e-40,
            65504.0,
        ];
        let mut r = Rng::new(77);
        for _ in 0..200 {
            v.push((r.f32() - 0.5) * 1e6);
            v.push(r.normal() * 1e-3);
        }
        v
    }

    #[test]
    fn axpy_bitwise_matches_scalar_on_adversarial_shapes() {
        let vals = adversarial_values();
        let mut r = Rng::new(5);
        // non-multiple-of-lane lengths on both sides of every lane width
        for n in [0usize, 1, 2, 3, 4, 5, 7, 8, 9, 15, 16, 17, 31, 33, 63, 100] {
            for &a in &[0.0f32, 1.0, -2.5, f32::NAN, f32::MIN_POSITIVE / 2.0, 1e30] {
                let b: Vec<f32> = (0..n).map(|i| vals[(i * 7 + 3) % vals.len()]).collect();
                let init: Vec<f32> = (0..n).map(|_| r.normal()).collect();
                let mut simd_acc = init.clone();
                let mut scalar_acc = init.clone();
                axpy(&mut simd_acc, a, &b);
                axpy_scalar(&mut scalar_acc, a, &b);
                let sb: Vec<u32> = simd_acc.iter().map(|x| x.to_bits()).collect();
                let rb: Vec<u32> = scalar_acc.iter().map(|x| x.to_bits()).collect();
                assert_eq!(sb, rb, "axpy n={n} a={a}");
            }
        }
    }

    #[test]
    fn narrow_bf16_bitwise_matches_scalar_on_specials() {
        let vals = adversarial_values();
        for n in [0usize, 1, 3, 4, 7, 8, 15, 16, 17, 24, 31, 32, 33, 100] {
            let src: Vec<f32> = (0..n).map(|i| vals[(i * 5 + 1) % vals.len()]).collect();
            let mut simd_dst = vec![0u16; n];
            let mut scalar_dst = vec![0u16; n];
            narrow_bf16(&src, &mut simd_dst);
            narrow_bf16_scalar(&src, &mut scalar_dst);
            assert_eq!(simd_dst, scalar_dst, "narrow n={n}");
        }
    }

    #[test]
    fn widen_bf16_bitwise_matches_scalar() {
        let mut r = Rng::new(6);
        for n in [0usize, 1, 3, 4, 7, 8, 9, 16, 17, 33, 100] {
            let src: Vec<u16> = (0..n).map(|_| (r.f64() * 65536.0) as u16).collect();
            let mut simd_dst = vec![0f32; n];
            let mut scalar_dst = vec![0f32; n];
            widen_bf16(&src, &mut simd_dst);
            widen_bf16_scalar(&src, &mut scalar_dst);
            let sb: Vec<u32> = simd_dst.iter().map(|x| x.to_bits()).collect();
            let rb: Vec<u32> = scalar_dst.iter().map(|x| x.to_bits()).collect();
            assert_eq!(sb, rb, "widen n={n}");
        }
    }

    #[test]
    fn round_bf16_bitwise_matches_scalar_and_util() {
        let vals = adversarial_values();
        for n in [0usize, 1, 3, 4, 5, 7, 8, 9, 16, 17, 100] {
            let src: Vec<f32> = (0..n).map(|i| vals[(i * 11 + 2) % vals.len()]).collect();
            let mut simd_xs = src.clone();
            let mut scalar_xs = src.clone();
            round_bf16(&mut simd_xs);
            round_bf16_scalar(&mut scalar_xs);
            for (i, (a, b)) in simd_xs.iter().zip(&scalar_xs).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "round n={n} lane {i} ({:?})", src[i]);
                assert_eq!(b.to_bits(), bf16_round(src[i]).to_bits(), "util mismatch lane {i}");
            }
        }
    }

    #[test]
    fn narrow_then_widen_is_round() {
        let vals = adversarial_values();
        let mut bits = vec![0u16; vals.len()];
        narrow_bf16(&vals, &mut bits);
        let mut back = vec![0f32; vals.len()];
        widen_bf16(&bits, &mut back);
        for (i, (&w, &v)) in back.iter().zip(&vals).enumerate() {
            assert_eq!(w.to_bits(), bf16_round(v).to_bits(), "lane {i} ({v:?})");
        }
    }

    #[test]
    fn narrow_quiets_nans_and_keeps_infinities() {
        let src = [f32::NAN, f32::from_bits(0x7f80_0001), f32::INFINITY, f32::NEG_INFINITY];
        let mut dst = [0u16; 4];
        narrow_bf16(&src, &mut dst);
        assert!(bf16_bits_to_f32(dst[0]).is_nan());
        assert!(bf16_bits_to_f32(dst[1]).is_nan(), "signaling NaN must stay a NaN");
        assert_eq!(bf16_bits_to_f32(dst[2]), f32::INFINITY);
        assert_eq!(bf16_bits_to_f32(dst[3]), f32::NEG_INFINITY);
    }

    #[test]
    fn level_is_stable_and_scalar_gate_is_respected() {
        // the level resolves once and stays fixed for the process
        assert_eq!(level(), level());
        if std::env::var("PALLAS_SIMD").map(|v| v.trim() == "0").unwrap_or(false) {
            assert_eq!(level(), SimdLevel::Scalar, "PALLAS_SIMD=0 must force scalar");
        }
    }
}
