//! Std-only scoped parallelism for the dense/sparse kernels.
//!
//! The hot kernels (`matmul*`, `spmm*`) are parallelized over disjoint
//! *row blocks* of the output: each worker owns a contiguous `&mut` slice
//! of the output buffer and runs the identical serial inner kernel over it,
//! so the per-element accumulation order — and therefore the result — is
//! bitwise identical to the single-thread path for any thread count.
//!
//! Thread count resolution, in priority order:
//!   1. the explicit `threads` argument of the `*_threads` kernel variants
//!      (what benches and bitwise-equality tests use),
//!   2. the `PALLAS_THREADS` environment variable, resolved once per
//!      process (`1` forces the serial path),
//!   3. `std::thread::available_parallelism()`.
//!
//! Workers are `std::thread::scope` spawns per kernel call: spawn cost
//! (~tens of microseconds) is negligible against the mini-batch-shaped
//! kernels this backs (hundreds of microseconds to tens of milliseconds),
//! and scoped borrows keep the API allocation-free for the caller.

/// Work below this many flops (or bytes moved) is not worth a spawn.
pub const MIN_PARALLEL_WORK: usize = 1 << 18;

/// Resolve the effective thread count from `PALLAS_THREADS` or the
/// machine's available parallelism.  Always at least 1.  The value is
/// resolved once per process on first use (so the per-kernel hot path is
/// allocation-free); set the variable before the first kernel call.
pub fn num_threads() -> usize {
    static THREADS: std::sync::OnceLock<usize> = std::sync::OnceLock::new();
    *THREADS.get_or_init(|| match std::env::var("PALLAS_THREADS") {
        Ok(v) => match v.trim().parse::<usize>() {
            Ok(n) if n >= 1 => n,
            _ => 1,
        },
        Err(_) => std::thread::available_parallelism()
            .map(|p| p.get())
            .unwrap_or(1),
    })
}

/// Split `[0, rows)` into up to `threads` contiguous row blocks and run
/// `f(row0, rows_mut_chunk)` on each, where `rows_mut_chunk` is the
/// corresponding disjoint `&mut` window of `out` (`cols` f32 per row).
///
/// `work` is an estimate of total flops/bytes; small jobs and `threads <= 1`
/// run inline on the caller thread with zero spawns (and zero allocations).
pub fn par_row_blocks<F>(out: &mut [f32], rows: usize, cols: usize, threads: usize, work: usize, f: F)
where
    F: Fn(usize, &mut [f32]) + Sync,
{
    debug_assert!(out.len() >= rows * cols);
    if rows == 0 {
        return;
    }
    let t = threads.min(rows).max(1);
    if t <= 1 || work < MIN_PARALLEL_WORK {
        f(0, &mut out[..rows * cols]);
        return;
    }
    let per = (rows + t - 1) / t;
    std::thread::scope(|s| {
        let fr = &f;
        let mut rest: &mut [f32] = &mut out[..rows * cols];
        // spawn workers for every block after the first; the caller thread
        // takes block 0 so a 2-thread run spawns only once.
        let first_take = per.min(rows);
        let (first, tail) = std::mem::take(&mut rest).split_at_mut(first_take * cols);
        rest = tail;
        let mut r0 = first_take;
        while r0 < rows {
            let take = per.min(rows - r0);
            let (head, tail) = std::mem::take(&mut rest).split_at_mut(take * cols);
            rest = tail;
            let start = r0;
            s.spawn(move || fr(start, head));
            r0 += take;
        }
        fr(0, first);
    });
}

/// Two-buffer variant of `par_row_blocks`: split `a` (`acols` per row) and
/// `b` (`bcols` per row) into the SAME contiguous row blocks and run
/// `f(row0, row1, a_block, b_block)` on each.  Used by the fused SpMM+GEMM
/// kernel, whose aggregate and output buffers have different widths.
#[allow(clippy::too_many_arguments)]
pub fn par_row_blocks_pair<F>(
    a: &mut [f32],
    acols: usize,
    b: &mut [f32],
    bcols: usize,
    rows: usize,
    threads: usize,
    work: usize,
    f: F,
) where
    F: Fn(usize, usize, &mut [f32], &mut [f32]) + Sync,
{
    debug_assert!(a.len() >= rows * acols && b.len() >= rows * bcols);
    if rows == 0 {
        return;
    }
    let t = threads.min(rows).max(1);
    if t <= 1 || work < MIN_PARALLEL_WORK {
        f(0, rows, &mut a[..rows * acols], &mut b[..rows * bcols]);
        return;
    }
    let per = (rows + t - 1) / t;
    std::thread::scope(|s| {
        let fr = &f;
        let mut arest: &mut [f32] = &mut a[..rows * acols];
        let mut brest: &mut [f32] = &mut b[..rows * bcols];
        let first_take = per.min(rows);
        let (afirst, atail) = std::mem::take(&mut arest).split_at_mut(first_take * acols);
        let (bfirst, btail) = std::mem::take(&mut brest).split_at_mut(first_take * bcols);
        arest = atail;
        brest = btail;
        let mut r0 = first_take;
        while r0 < rows {
            let take = per.min(rows - r0);
            let (ahead, atail) = std::mem::take(&mut arest).split_at_mut(take * acols);
            let (bhead, btail) = std::mem::take(&mut brest).split_at_mut(take * bcols);
            arest = atail;
            brest = btail;
            let start = r0;
            s.spawn(move || fr(start, start + take, ahead, bhead));
            r0 += take;
        }
        fr(0, first_take, afirst, bfirst);
    });
}

/// Split `[0, rows)` into `outs.len()` contiguous row ranges and run
/// `f(chunk_index, r0, r1, out_chunk)` on each, one worker per chunk.
/// Returns the number of chunks actually used: `1` when the job ran
/// inline on the caller thread (`outs.len() <= 1`, tiny `work`, or fewer
/// than two rows), `outs.len()` otherwise (trailing ranges may be empty).
///
/// Unlike [`par_row_blocks`] the per-chunk output is an arbitrary `T`
/// (e.g. a growable segment buffer), so producers whose per-row output
/// size is not known up front — subgraph induction — can run row-ranges
/// in parallel and concatenate the segments in chunk order afterwards.
/// When the per-row computation is row-local (no row reads another row's
/// output), the concatenated stream is bitwise identical for any chunk
/// count, including the inline path.
pub fn par_chunks<T, F>(outs: &mut [T], rows: usize, work: usize, f: F) -> usize
where
    T: Send,
    F: Fn(usize, usize, usize, &mut T) + Sync,
{
    let nc = outs.len();
    if nc <= 1 || rows < 2 || work < MIN_PARALLEL_WORK {
        if let Some(first) = outs.first_mut() {
            f(0, 0, rows, first);
            return 1;
        }
        return 0;
    }
    let per = (rows + nc - 1) / nc;
    std::thread::scope(|s| {
        let fr = &f;
        let mut iter = outs.iter_mut().enumerate();
        let (_, first) = iter.next().expect("nc >= 2");
        for (i, o) in iter {
            let r0 = (i * per).min(rows);
            let r1 = ((i + 1) * per).min(rows);
            s.spawn(move || fr(i, r0, r1, o));
        }
        // the caller thread takes chunk 0
        fr(0, 0, per.min(rows), first);
    });
    nc
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn num_threads_is_positive() {
        assert!(num_threads() >= 1);
    }

    #[test]
    fn par_row_blocks_covers_all_rows_once() {
        for &(rows, cols, threads) in
            &[(1usize, 3usize, 4usize), (7, 2, 3), (16, 1, 16), (5, 4, 1), (100, 3, 7)]
        {
            let mut out = vec![0.0f32; rows * cols];
            // force the parallel path with a huge work estimate
            par_row_blocks(&mut out, rows, cols, threads, usize::MAX, |r0, chunk| {
                for (k, v) in chunk.iter_mut().enumerate() {
                    *v += (r0 * cols + k) as f32 + 1.0;
                }
            });
            for (k, v) in out.iter().enumerate() {
                assert_eq!(*v, k as f32 + 1.0, "rows={rows} cols={cols} t={threads} k={k}");
            }
        }
    }

    #[test]
    fn small_work_runs_inline() {
        let mut out = vec![0.0f32; 8];
        par_row_blocks(&mut out, 8, 1, 8, 10, |r0, chunk| {
            assert_eq!(r0, 0);
            assert_eq!(chunk.len(), 8);
        });
    }

    #[test]
    fn pair_blocks_partition_both_buffers_consistently() {
        let (rows, ac, bc) = (23usize, 3usize, 2usize);
        let mut a = vec![0.0f32; rows * ac];
        let mut b = vec![0.0f32; rows * bc];
        par_row_blocks_pair(&mut a, ac, &mut b, bc, rows, 4, usize::MAX, |r0, r1, ab, bb| {
            assert_eq!(ab.len(), (r1 - r0) * ac);
            assert_eq!(bb.len(), (r1 - r0) * bc);
            for v in ab.iter_mut() {
                *v += 1.0;
            }
            for v in bb.iter_mut() {
                *v += 1.0;
            }
        });
        assert!(a.iter().chain(b.iter()).all(|&v| v == 1.0));
    }

    #[test]
    fn par_chunks_covers_rows_in_order() {
        for &(rows, nseg) in &[(1usize, 4usize), (7, 3), (23, 4), (100, 7), (5, 8)] {
            let mut segs: Vec<Vec<usize>> = vec![Vec::new(); nseg];
            let used = par_chunks(&mut segs, rows, usize::MAX, |_, r0, r1, seg| {
                seg.clear();
                seg.extend(r0..r1);
            });
            let flat: Vec<usize> = segs[..used].iter().flatten().copied().collect();
            assert_eq!(flat, (0..rows).collect::<Vec<_>>(), "rows={rows} nseg={nseg}");
        }
    }

    #[test]
    fn par_chunks_small_work_runs_inline() {
        let mut segs: Vec<Vec<usize>> = vec![Vec::new(); 4];
        let used = par_chunks(&mut segs, 10, 10, |i, r0, r1, seg| {
            assert_eq!((i, r0, r1), (0, 0, 10));
            seg.push(r1);
        });
        assert_eq!(used, 1);
        assert!(segs[1].is_empty());
    }

    #[test]
    fn zero_rows_is_a_noop() {
        let mut out: Vec<f32> = vec![];
        par_row_blocks(&mut out, 0, 4, 8, usize::MAX, |_, _| panic!("no rows"));
        let mut b: Vec<f32> = vec![];
        par_row_blocks_pair(&mut out, 4, &mut b, 2, 0, 8, usize::MAX, |_, _, _, _| {
            panic!("no rows")
        });
    }
}
