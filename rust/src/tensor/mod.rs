//! Dense row-major f32 matrices with the BLAS-ish kernels the coordinator
//! needs: blocked GEMM (plain / transposed operands), element-wise ops and
//! reductions.  This backs the in-Rust reference model (`model::`), the
//! rank-local compute of the 3D-PMM engine, and test oracles.
//!
//! The hot GEMM uses i-k-j loop order with an 8-wide j unroll so LLVM
//! auto-vectorizes; see EXPERIMENTS.md §Perf for measured numbers.

#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Mat {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Mat {
        assert_eq!(data.len(), rows * cols, "shape mismatch");
        Mat { rows, cols, data }
    }

    pub fn filled(rows: usize, cols: usize, v: f32) -> Mat {
        Mat { rows, cols, data: vec![v; rows * cols] }
    }

    pub fn eye(n: usize) -> Mat {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m.data[i * n + i] = 1.0;
        }
        m
    }

    pub fn randn(rows: usize, cols: usize, rng: &mut crate::util::rng::Rng, scale: f32) -> Mat {
        Mat {
            rows,
            cols,
            data: (0..rows * cols).map(|_| rng.normal() * scale).collect(),
        }
    }

    /// Glorot-uniform init (matches `model.init_params` in python).
    pub fn glorot(rows: usize, cols: usize, rng: &mut crate::util::rng::Rng) -> Mat {
        let lim = (6.0 / (rows + cols) as f32).sqrt();
        Mat {
            rows,
            cols,
            data: (0..rows * cols).map(|_| rng.uniform(-lim, lim)).collect(),
        }
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn at_mut(&mut self, r: usize, c: usize) -> &mut f32 {
        &mut self.data[r * self.cols + c]
    }

    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// C = A @ B (blocked i-k-j).
    pub fn matmul(&self, b: &Mat) -> Mat {
        assert_eq!(self.cols, b.rows, "matmul {}x{} @ {}x{}", self.rows, self.cols, b.rows, b.cols);
        let mut c = Mat::zeros(self.rows, b.cols);
        matmul_into(self, b, &mut c, false);
        c
    }

    /// C = A^T @ B without materializing A^T.
    pub fn t_matmul(&self, b: &Mat) -> Mat {
        assert_eq!(self.rows, b.rows, "t_matmul");
        let (k, m, n) = (self.rows, self.cols, b.cols);
        let mut c = Mat::zeros(m, n);
        // c[i,j] = sum_k a[k,i] * b[k,j]  -> k-i-j order, rows of b stream
        for kk in 0..k {
            let brow = &b.data[kk * n..(kk + 1) * n];
            let arow = &self.data[kk * m..(kk + 1) * m];
            for i in 0..m {
                let a = arow[i];
                if a == 0.0 {
                    continue;
                }
                let crow = &mut c.data[i * n..(i + 1) * n];
                for j in 0..n {
                    crow[j] += a * brow[j];
                }
            }
        }
        c
    }

    /// C = A @ B^T without materializing B^T.
    pub fn matmul_t(&self, b: &Mat) -> Mat {
        assert_eq!(self.cols, b.cols, "matmul_t");
        let (m, k, n) = (self.rows, self.cols, b.rows);
        let mut c = Mat::zeros(m, n);
        for i in 0..m {
            let arow = &self.data[i * k..(i + 1) * k];
            let crow = &mut c.data[i * n..(i + 1) * n];
            for j in 0..n {
                let brow = &b.data[j * k..(j + 1) * k];
                let mut acc = 0.0f32;
                for kk in 0..k {
                    acc += arow[kk] * brow[kk];
                }
                crow[j] = acc;
            }
        }
        c
    }

    pub fn transpose(&self) -> Mat {
        let mut t = Mat::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                t.data[c * self.rows + r] = self.data[r * self.cols + c];
            }
        }
        t
    }

    pub fn add(&self, b: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (b.rows, b.cols));
        let mut out = self.clone();
        for (o, &x) in out.data.iter_mut().zip(&b.data) {
            *o += x;
        }
        out
    }

    pub fn add_assign(&mut self, b: &Mat) {
        assert_eq!((self.rows, self.cols), (b.rows, b.cols));
        for (o, &x) in self.data.iter_mut().zip(&b.data) {
            *o += x;
        }
    }

    pub fn sub(&self, b: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (b.rows, b.cols));
        let mut out = self.clone();
        for (o, &x) in out.data.iter_mut().zip(&b.data) {
            *o -= x;
        }
        out
    }

    pub fn hadamard(&self, b: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (b.rows, b.cols));
        let mut out = self.clone();
        for (o, &x) in out.data.iter_mut().zip(&b.data) {
            *o *= x;
        }
        out
    }

    pub fn scale(&self, s: f32) -> Mat {
        let mut out = self.clone();
        for o in out.data.iter_mut() {
            *o *= s;
        }
        out
    }

    pub fn relu(&self) -> Mat {
        let mut out = self.clone();
        for o in out.data.iter_mut() {
            *o = o.max(0.0);
        }
        out
    }

    /// Submatrix copy: rows [r0,r1), cols [c0,c1).
    pub fn slice(&self, r0: usize, r1: usize, c0: usize, c1: usize) -> Mat {
        assert!(r1 <= self.rows && c1 <= self.cols && r0 <= r1 && c0 <= c1);
        let mut out = Mat::zeros(r1 - r0, c1 - c0);
        for r in r0..r1 {
            out.data[(r - r0) * (c1 - c0)..(r - r0 + 1) * (c1 - c0)]
                .copy_from_slice(&self.data[r * self.cols + c0..r * self.cols + c1]);
        }
        out
    }

    /// Write `src` into this matrix at offset (r0, c0).
    pub fn set_slice(&mut self, r0: usize, c0: usize, src: &Mat) {
        assert!(r0 + src.rows <= self.rows && c0 + src.cols <= self.cols);
        for r in 0..src.rows {
            let dst = (r0 + r) * self.cols + c0;
            self.data[dst..dst + src.cols]
                .copy_from_slice(&src.data[r * src.cols..(r + 1) * src.cols]);
        }
    }

    pub fn frob_norm(&self) -> f32 {
        self.data.iter().map(|x| x * x).sum::<f32>().sqrt()
    }

    pub fn max_abs_diff(&self, b: &Mat) -> f32 {
        assert_eq!((self.rows, self.cols), (b.rows, b.cols));
        self.data
            .iter()
            .zip(&b.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    pub fn allclose(&self, b: &Mat, atol: f32, rtol: f32) -> bool {
        if (self.rows, self.cols) != (b.rows, b.cols) {
            return false;
        }
        self.data
            .iter()
            .zip(&b.data)
            .all(|(a, b)| (a - b).abs() <= atol + rtol * b.abs())
    }
}

/// `c += a @ b` (or `c = a @ b` if `accumulate` is false over a zeroed c).
/// i-k-j ordering: the inner loop streams rows of `b` and `c`.
pub fn matmul_into(a: &Mat, b: &Mat, c: &mut Mat, accumulate: bool) {
    assert_eq!(a.cols, b.rows);
    assert_eq!((c.rows, c.cols), (a.rows, b.cols));
    if !accumulate {
        c.data.fill(0.0);
    }
    let n = b.cols;
    for i in 0..a.rows {
        let arow = &a.data[i * a.cols..(i + 1) * a.cols];
        let crow = &mut c.data[i * n..(i + 1) * n];
        for (kk, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue; // pays off on dense-ified sparse adjacencies
            }
            let brow = &b.data[kk * n..(kk + 1) * n];
            for j in 0..n {
                crow[j] += av * brow[j];
            }
        }
    }
}

/// RMSNorm over rows with learned scale g (Eq. 7); returns (out, inv_rms).
pub fn rmsnorm(x: &Mat, g: &[f32], eps: f32) -> (Mat, Vec<f32>) {
    assert_eq!(g.len(), x.cols);
    let mut out = Mat::zeros(x.rows, x.cols);
    let mut inv = vec![0.0f32; x.rows];
    for r in 0..x.rows {
        let row = x.row(r);
        let ms = row.iter().map(|v| v * v).sum::<f32>() / x.cols as f32;
        let iv = 1.0 / (ms + eps).sqrt();
        inv[r] = iv;
        let orow = &mut out.data[r * x.cols..(r + 1) * x.cols];
        for j in 0..x.cols {
            orow[j] = row[j] * iv * g[j];
        }
    }
    (out, inv)
}

/// Row-wise log-softmax.
pub fn log_softmax(x: &Mat) -> Mat {
    let mut out = Mat::zeros(x.rows, x.cols);
    for r in 0..x.rows {
        let row = x.row(r);
        let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let lse = row.iter().map(|v| (v - mx).exp()).sum::<f32>().ln() + mx;
        let orow = &mut out.data[r * x.cols..(r + 1) * x.cols];
        for j in 0..x.cols {
            orow[j] = row[j] - lse;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn naive_matmul(a: &Mat, b: &Mat) -> Mat {
        let mut c = Mat::zeros(a.rows, b.cols);
        for i in 0..a.rows {
            for j in 0..b.cols {
                let mut s = 0.0;
                for k in 0..a.cols {
                    s += a.at(i, k) * b.at(k, j);
                }
                *c.at_mut(i, j) = s;
            }
        }
        c
    }

    #[test]
    fn matmul_matches_naive_random_shapes() {
        let mut r = Rng::new(2);
        for &(m, k, n) in &[(1, 1, 1), (3, 5, 7), (16, 16, 16), (33, 17, 9)] {
            let a = Mat::randn(m, k, &mut r, 1.0);
            let b = Mat::randn(k, n, &mut r, 1.0);
            assert!(a.matmul(&b).allclose(&naive_matmul(&a, &b), 1e-4, 1e-4));
        }
    }

    #[test]
    fn transposed_variants_match() {
        let mut r = Rng::new(3);
        let a = Mat::randn(9, 13, &mut r, 1.0);
        let b = Mat::randn(9, 7, &mut r, 1.0);
        assert!(a.t_matmul(&b).allclose(&a.transpose().matmul(&b), 1e-4, 1e-4));
        let c = Mat::randn(5, 13, &mut r, 1.0);
        assert!(a.matmul_t(&c).allclose(&a.matmul(&c.transpose()), 1e-4, 1e-4));
    }

    #[test]
    fn eye_is_identity_for_matmul() {
        let mut r = Rng::new(4);
        let a = Mat::randn(6, 6, &mut r, 1.0);
        assert!(a.matmul(&Mat::eye(6)).allclose(&a, 1e-6, 0.0));
        assert!(Mat::eye(6).matmul(&a).allclose(&a, 1e-6, 0.0));
    }

    #[test]
    fn slice_set_slice_roundtrip() {
        let mut r = Rng::new(5);
        let a = Mat::randn(8, 10, &mut r, 1.0);
        let s = a.slice(2, 6, 3, 9);
        assert_eq!((s.rows, s.cols), (4, 6));
        assert_eq!(s.at(0, 0), a.at(2, 3));
        let mut b = Mat::zeros(8, 10);
        b.set_slice(2, 3, &s);
        assert_eq!(b.at(5, 8), a.at(5, 8));
        assert_eq!(b.at(0, 0), 0.0);
    }

    #[test]
    fn rmsnorm_unit_rows() {
        let x = Mat::from_vec(2, 4, vec![1.0, 1.0, 1.0, 1.0, 2.0, 2.0, 2.0, 2.0]);
        let g = vec![1.0; 4];
        let (out, _) = rmsnorm(&x, &g, 0.0);
        for v in &out.data {
            assert!((v - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn log_softmax_rows_sum_to_one() {
        let mut r = Rng::new(6);
        let x = Mat::randn(4, 9, &mut r, 3.0);
        let ls = log_softmax(&x);
        for i in 0..4 {
            let s: f32 = ls.row(i).iter().map(|v| v.exp()).sum();
            assert!((s - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn elementwise_ops() {
        let a = Mat::from_vec(1, 3, vec![1.0, -2.0, 3.0]);
        let b = Mat::from_vec(1, 3, vec![2.0, 2.0, 2.0]);
        assert_eq!(a.add(&b).data, vec![3.0, 0.0, 5.0]);
        assert_eq!(a.sub(&b).data, vec![-1.0, -4.0, 1.0]);
        assert_eq!(a.hadamard(&b).data, vec![2.0, -4.0, 6.0]);
        assert_eq!(a.relu().data, vec![1.0, 0.0, 3.0]);
        assert_eq!(a.scale(2.0).data, vec![2.0, -4.0, 6.0]);
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        let a = Mat::zeros(2, 3);
        let b = Mat::zeros(2, 3);
        let _ = a.matmul(&b);
    }
}
