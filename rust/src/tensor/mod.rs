//! Dense row-major f32 matrices with the BLAS-ish kernels the coordinator
//! needs: blocked GEMM (plain / transposed operands), element-wise ops and
//! reductions.  This backs the in-Rust reference model (`model::`), the
//! rank-local compute of the 3D-PMM engine, and test oracles.
//!
//! The hot GEMMs are **row-block parallel** (see `tensor::pool`) and
//! j/k-tiled so the streamed B panel stays cache-resident; every worker
//! runs the identical serial inner kernel over a disjoint block of output
//! rows, so results are bitwise identical for any thread count (the
//! per-element accumulation order over k never changes).  Thread count
//! comes from `PALLAS_THREADS` (1 = serial) or the machine's available
//! parallelism; see EXPERIMENTS.md §Perf for measured numbers.

pub mod pool;
pub mod simd;

/// Column tile of the GEMM inner loops: the B panel touched by one tile is
/// `k x JT` floats, sized to stay L2-resident across an entire row block.
const GEMM_JT: usize = 256;

/// Dense row-major f32 matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    /// Number of rows.
    pub rows: usize,
    /// Number of columns.
    pub cols: usize,
    /// Row-major element storage (`rows * cols` values).
    pub data: Vec<f32>,
}

/// The default matrix is the 0 x 0 placeholder used by workspaces before
/// their first sizing (`Mat::reset` grows it in place).
impl Default for Mat {
    fn default() -> Mat {
        Mat { rows: 0, cols: 0, data: Vec::new() }
    }
}

impl Mat {
    /// All-zero `rows x cols` matrix.
    pub fn zeros(rows: usize, cols: usize) -> Mat {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Wrap a row-major buffer (must hold exactly `rows * cols` values).
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Mat {
        assert_eq!(data.len(), rows * cols, "shape mismatch");
        Mat { rows, cols, data }
    }

    /// Constant-filled `rows x cols` matrix.
    pub fn filled(rows: usize, cols: usize, v: f32) -> Mat {
        Mat { rows, cols, data: vec![v; rows * cols] }
    }

    /// `n x n` identity matrix.
    pub fn eye(n: usize) -> Mat {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m.data[i * n + i] = 1.0;
        }
        m
    }

    /// I.i.d. normal entries with standard deviation `scale`.
    pub fn randn(rows: usize, cols: usize, rng: &mut crate::util::rng::Rng, scale: f32) -> Mat {
        Mat {
            rows,
            cols,
            data: (0..rows * cols).map(|_| rng.normal() * scale).collect(),
        }
    }

    /// Glorot-uniform init (matches `model.init_params` in python).
    pub fn glorot(rows: usize, cols: usize, rng: &mut crate::util::rng::Rng) -> Mat {
        let lim = (6.0 / (rows + cols) as f32).sqrt();
        Mat {
            rows,
            cols,
            data: (0..rows * cols).map(|_| rng.uniform(-lim, lim)).collect(),
        }
    }

    /// Reshape in place to `rows x cols`, reusing the allocation when
    /// capacity suffices; contents are reset to zero.  The workspace
    /// primitive behind the zero-allocation training step.
    pub fn reset(&mut self, rows: usize, cols: usize) {
        self.rows = rows;
        self.cols = cols;
        self.data.clear();
        self.data.resize(rows * cols, 0.0);
    }

    /// As `reset` but without zeroing surviving contents — for buffers the
    /// next kernel fully overwrites (saves a memset per buffer per step).
    /// Newly grown elements are still zeroed.
    pub fn reset_for_overwrite(&mut self, rows: usize, cols: usize) {
        self.rows = rows;
        self.cols = cols;
        self.data.resize(rows * cols, 0.0);
    }

    /// Element at `(r, c)`.
    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        self.data[r * self.cols + c]
    }

    /// Mutable element at `(r, c)`.
    #[inline]
    pub fn at_mut(&mut self, r: usize, c: usize) -> &mut f32 {
        &mut self.data[r * self.cols + c]
    }

    /// Row `r` as a slice.
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// C = A @ B (row-block parallel, j-tiled i-k-j).
    pub fn matmul(&self, b: &Mat) -> Mat {
        assert_eq!(self.cols, b.rows, "matmul {}x{} @ {}x{}", self.rows, self.cols, b.rows, b.cols);
        let mut c = Mat::zeros(self.rows, b.cols);
        // accumulate over the freshly zeroed buffer: bitwise identical to
        // the non-accumulate path, minus its redundant second memset
        matmul_into(self, b, &mut c, true);
        c
    }

    /// C = A^T @ B without materializing A^T.
    pub fn t_matmul(&self, b: &Mat) -> Mat {
        let mut c = Mat::zeros(self.cols, b.cols);
        t_matmul_into(self, b, &mut c);
        c
    }

    /// C = A @ B^T without materializing B^T.
    pub fn matmul_t(&self, b: &Mat) -> Mat {
        let mut c = Mat::zeros(self.rows, b.rows);
        matmul_t_into(self, b, &mut c);
        c
    }

    /// Materialized transpose.
    pub fn transpose(&self) -> Mat {
        let mut t = Mat::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                t.data[c * self.rows + r] = self.data[r * self.cols + c];
            }
        }
        t
    }

    /// Element-wise sum `self + b`.
    pub fn add(&self, b: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (b.rows, b.cols));
        let mut out = self.clone();
        for (o, &x) in out.data.iter_mut().zip(&b.data) {
            *o += x;
        }
        out
    }

    /// In-place element-wise sum `self += b`.
    pub fn add_assign(&mut self, b: &Mat) {
        assert_eq!((self.rows, self.cols), (b.rows, b.cols));
        for (o, &x) in self.data.iter_mut().zip(&b.data) {
            *o += x;
        }
    }

    /// Element-wise difference `self - b`.
    pub fn sub(&self, b: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (b.rows, b.cols));
        let mut out = self.clone();
        for (o, &x) in out.data.iter_mut().zip(&b.data) {
            *o -= x;
        }
        out
    }

    /// Element-wise (Hadamard) product `self ⊙ b`.
    pub fn hadamard(&self, b: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (b.rows, b.cols));
        let mut out = self.clone();
        for (o, &x) in out.data.iter_mut().zip(&b.data) {
            *o *= x;
        }
        out
    }

    /// Scalar multiple `s * self`.
    pub fn scale(&self, s: f32) -> Mat {
        let mut out = self.clone();
        for o in out.data.iter_mut() {
            *o *= s;
        }
        out
    }

    /// Element-wise `max(x, 0)`.
    pub fn relu(&self) -> Mat {
        let mut out = self.clone();
        for o in out.data.iter_mut() {
            *o = o.max(0.0);
        }
        out
    }

    /// Submatrix copy: rows [r0,r1), cols [c0,c1).
    pub fn slice(&self, r0: usize, r1: usize, c0: usize, c1: usize) -> Mat {
        assert!(r1 <= self.rows && c1 <= self.cols && r0 <= r1 && c0 <= c1);
        let mut out = Mat::zeros(r1 - r0, c1 - c0);
        for r in r0..r1 {
            out.data[(r - r0) * (c1 - c0)..(r - r0 + 1) * (c1 - c0)]
                .copy_from_slice(&self.data[r * self.cols + c0..r * self.cols + c1]);
        }
        out
    }

    /// Write `src` into this matrix at offset (r0, c0).
    pub fn set_slice(&mut self, r0: usize, c0: usize, src: &Mat) {
        assert!(r0 + src.rows <= self.rows && c0 + src.cols <= self.cols);
        for r in 0..src.rows {
            let dst = (r0 + r) * self.cols + c0;
            self.data[dst..dst + src.cols]
                .copy_from_slice(&src.data[r * src.cols..(r + 1) * src.cols]);
        }
    }

    /// Frobenius norm.
    pub fn frob_norm(&self) -> f32 {
        self.data.iter().map(|x| x * x).sum::<f32>().sqrt()
    }

    /// Largest absolute element-wise difference to `b` (same shape).
    pub fn max_abs_diff(&self, b: &Mat) -> f32 {
        assert_eq!((self.rows, self.cols), (b.rows, b.cols));
        self.data
            .iter()
            .zip(&b.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    /// NumPy-style tolerance comparison: `|a - b| <= atol + rtol * |b|`
    /// element-wise (false on any shape mismatch).
    pub fn allclose(&self, b: &Mat, atol: f32, rtol: f32) -> bool {
        if (self.rows, self.cols) != (b.rows, b.cols) {
            return false;
        }
        self.data
            .iter()
            .zip(&b.data)
            .all(|(a, b)| (a - b).abs() <= atol + rtol * b.abs())
    }
}

/// Serial inner kernel shared by every `matmul` path: accumulate
/// `c_block += a_block @ b` over one block of rows, i-k-j order with a
/// zero-skip on A entries (pays off on dense-ified sparse adjacencies) and
/// a j-tile so the touched B panel stays cache-resident.  For every output
/// element the accumulation order over k is ascending — the invariant that
/// makes serial and row-parallel execution bitwise identical.
#[inline]
pub(crate) fn gemm_rows(a_block: &[f32], k: usize, b: &[f32], n: usize, c_block: &mut [f32]) {
    let rows = if n == 0 { 0 } else { c_block.len() / n };
    let mut j0 = 0usize;
    while j0 < n {
        let j1 = (j0 + GEMM_JT).min(n);
        for i in 0..rows {
            let arow = &a_block[i * k..(i + 1) * k];
            let crow = &mut c_block[i * n + j0..i * n + j1];
            for (kk, &av) in arow.iter().enumerate() {
                if av == 0.0 {
                    continue;
                }
                let brow = &b[kk * n + j0..kk * n + j1];
                simd::axpy(crow, av, brow);
            }
        }
        j0 = j1;
    }
}

/// `c += a @ b` (or `c = a @ b` if `accumulate` is false) with an explicit
/// thread count (1 = serial reference path).
pub fn matmul_into_threads(a: &Mat, b: &Mat, c: &mut Mat, accumulate: bool, threads: usize) {
    assert_eq!(a.cols, b.rows);
    assert_eq!((c.rows, c.cols), (a.rows, b.cols));
    if !accumulate {
        c.data.fill(0.0);
    }
    let (k, n) = (a.cols, b.cols);
    let work = 2 * a.rows * k * n;
    let (a_data, b_data) = (&a.data, &b.data);
    pool::par_row_blocks(&mut c.data, a.rows, n, threads, work, |r0, c_block| {
        let rows = if n == 0 { 0 } else { c_block.len() / n };
        gemm_rows(&a_data[r0 * k..(r0 + rows) * k], k, b_data, n, c_block);
    });
}

/// `c += a @ b` (or `c = a @ b` if `accumulate` is false over a zeroed c),
/// parallel over row blocks; bitwise identical to the serial path.
pub fn matmul_into(a: &Mat, b: &Mat, c: &mut Mat, accumulate: bool) {
    matmul_into_threads(a, b, c, accumulate, pool::num_threads());
}

/// `c = a^T @ b` without materializing `a^T`, explicit thread count.
/// Parallel over blocks of output rows (columns of `a`); within a block the
/// k loop stays outermost so contiguous segments of `a`'s rows stream, and
/// the per-element accumulation order over k is unchanged.
pub fn t_matmul_into_threads(a: &Mat, b: &Mat, c: &mut Mat, threads: usize) {
    assert_eq!(a.rows, b.rows, "t_matmul");
    let (k, m, n) = (a.rows, a.cols, b.cols);
    assert_eq!((c.rows, c.cols), (m, n));
    c.data.fill(0.0);
    let work = 2 * k * m * n;
    let (a_data, b_data) = (&a.data, &b.data);
    pool::par_row_blocks(&mut c.data, m, n, threads, work, |i0, c_block| {
        let rows = if n == 0 { 0 } else { c_block.len() / n };
        for kk in 0..k {
            let brow = &b_data[kk * n..(kk + 1) * n];
            let aseg = &a_data[kk * m + i0..kk * m + i0 + rows];
            for (ii, &av) in aseg.iter().enumerate() {
                if av == 0.0 {
                    continue;
                }
                let crow = &mut c_block[ii * n..(ii + 1) * n];
                simd::axpy(crow, av, brow);
            }
        }
    });
}

/// `c = a^T @ b` without materializing `a^T`.
pub fn t_matmul_into(a: &Mat, b: &Mat, c: &mut Mat) {
    t_matmul_into_threads(a, b, c, pool::num_threads());
}

/// `c = a @ b^T` without materializing `b^T`, explicit thread count.
/// Row-parallel dot-product form; each output element is one dot product,
/// so parallelism cannot change any accumulation order.
pub fn matmul_t_into_threads(a: &Mat, b: &Mat, c: &mut Mat, threads: usize) {
    assert_eq!(a.cols, b.cols, "matmul_t");
    let (m, k, n) = (a.rows, a.cols, b.rows);
    assert_eq!((c.rows, c.cols), (m, n));
    let work = 2 * m * k * n;
    let (a_data, b_data) = (&a.data, &b.data);
    pool::par_row_blocks(&mut c.data, m, n, threads, work, |r0, c_block| {
        let rows = if n == 0 { 0 } else { c_block.len() / n };
        for ii in 0..rows {
            let arow = &a_data[(r0 + ii) * k..(r0 + ii + 1) * k];
            let crow = &mut c_block[ii * n..(ii + 1) * n];
            for j in 0..n {
                let brow = &b_data[j * k..(j + 1) * k];
                let mut acc = 0.0f32;
                for kk in 0..k {
                    acc += arow[kk] * brow[kk];
                }
                crow[j] = acc;
            }
        }
    });
}

/// `c = a @ b^T` without materializing `b^T`.
pub fn matmul_t_into(a: &Mat, b: &Mat, c: &mut Mat) {
    matmul_t_into_threads(a, b, c, pool::num_threads());
}

/// RMSNorm over rows with learned scale g (Eq. 7); returns (out, inv_rms).
pub fn rmsnorm(x: &Mat, g: &[f32], eps: f32) -> (Mat, Vec<f32>) {
    let mut out = Mat::zeros(x.rows, x.cols);
    let mut inv = vec![0.0f32; x.rows];
    rmsnorm_into(x, g, eps, &mut out, &mut inv);
    (out, inv)
}

/// Workspace variant of `rmsnorm`: writes into caller-provided `out`
/// (already `x.rows x x.cols`) and `inv` (len `x.rows`).
pub fn rmsnorm_into(x: &Mat, g: &[f32], eps: f32, out: &mut Mat, inv: &mut [f32]) {
    assert_eq!(g.len(), x.cols);
    assert_eq!((out.rows, out.cols), (x.rows, x.cols));
    assert_eq!(inv.len(), x.rows);
    for r in 0..x.rows {
        let row = x.row(r);
        let ms = row.iter().map(|v| v * v).sum::<f32>() / x.cols as f32;
        let iv = 1.0 / (ms + eps).sqrt();
        inv[r] = iv;
        let orow = &mut out.data[r * x.cols..(r + 1) * x.cols];
        for j in 0..x.cols {
            orow[j] = row[j] * iv * g[j];
        }
    }
}

/// Row-wise log-softmax.
pub fn log_softmax(x: &Mat) -> Mat {
    let mut out = Mat::zeros(x.rows, x.cols);
    for r in 0..x.rows {
        let row = x.row(r);
        let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let lse = row.iter().map(|v| (v - mx).exp()).sum::<f32>().ln() + mx;
        let orow = &mut out.data[r * x.cols..(r + 1) * x.cols];
        for j in 0..x.cols {
            orow[j] = row[j] - lse;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn naive_matmul(a: &Mat, b: &Mat) -> Mat {
        let mut c = Mat::zeros(a.rows, b.cols);
        for i in 0..a.rows {
            for j in 0..b.cols {
                let mut s = 0.0;
                for k in 0..a.cols {
                    s += a.at(i, k) * b.at(k, j);
                }
                *c.at_mut(i, j) = s;
            }
        }
        c
    }

    #[test]
    fn matmul_matches_naive_random_shapes() {
        let mut r = Rng::new(2);
        for &(m, k, n) in &[(1, 1, 1), (3, 5, 7), (16, 16, 16), (33, 17, 9)] {
            let a = Mat::randn(m, k, &mut r, 1.0);
            let b = Mat::randn(k, n, &mut r, 1.0);
            assert!(a.matmul(&b).allclose(&naive_matmul(&a, &b), 1e-4, 1e-4));
        }
    }

    #[test]
    fn parallel_matmul_bitwise_matches_serial() {
        let mut r = Rng::new(12);
        for &(m, k, n) in &[(1, 9, 5), (7, 3, 1), (65, 33, 129), (300, 17, 260)] {
            let a = Mat::randn(m, k, &mut r, 1.0);
            let b = Mat::randn(k, n, &mut r, 1.0);
            let mut serial = Mat::zeros(m, n);
            matmul_into_threads(&a, &b, &mut serial, false, 1);
            for threads in [2, 3, 4, 8] {
                let mut par = Mat::zeros(m, n);
                // MIN_PARALLEL_WORK may route small shapes serially, which
                // is trivially identical; larger ones genuinely fan out.
                matmul_into_threads(&a, &b, &mut par, false, threads);
                assert_eq!(serial.data, par.data, "{m}x{k}x{n} t={threads}");
            }
        }
    }

    #[test]
    fn transposed_variants_match() {
        let mut r = Rng::new(3);
        let a = Mat::randn(9, 13, &mut r, 1.0);
        let b = Mat::randn(9, 7, &mut r, 1.0);
        assert!(a.t_matmul(&b).allclose(&a.transpose().matmul(&b), 1e-4, 1e-4));
        let c = Mat::randn(5, 13, &mut r, 1.0);
        assert!(a.matmul_t(&c).allclose(&a.matmul(&c.transpose()), 1e-4, 1e-4));
    }

    #[test]
    fn transposed_variants_bitwise_match_serial() {
        let mut r = Rng::new(13);
        let a = Mat::randn(130, 70, &mut r, 1.0);
        let b = Mat::randn(130, 90, &mut r, 1.0);
        let mut serial = Mat::zeros(70, 90);
        t_matmul_into_threads(&a, &b, &mut serial, 1);
        for threads in [2, 5, 8] {
            let mut par = Mat::zeros(70, 90);
            t_matmul_into_threads(&a, &b, &mut par, threads);
            assert_eq!(serial.data, par.data, "t_matmul t={threads}");
        }
        let c = Mat::randn(110, 70, &mut r, 1.0);
        let mut serial_t = Mat::zeros(130, 110);
        matmul_t_into_threads(&a, &c, &mut serial_t, 1);
        for threads in [2, 5, 8] {
            let mut par = Mat::zeros(130, 110);
            matmul_t_into_threads(&a, &c, &mut par, threads);
            assert_eq!(serial_t.data, par.data, "matmul_t t={threads}");
        }
    }

    #[test]
    fn accumulate_matmul_adds_on_top() {
        let mut r = Rng::new(21);
        let a = Mat::randn(6, 4, &mut r, 1.0);
        let b = Mat::randn(4, 5, &mut r, 1.0);
        let mut c = Mat::filled(6, 5, 1.0);
        matmul_into(&a, &b, &mut c, true);
        let want = naive_matmul(&a, &b).add(&Mat::filled(6, 5, 1.0));
        assert!(c.allclose(&want, 1e-4, 1e-4));
    }

    #[test]
    fn reset_reshapes_and_zeroes() {
        let mut m = Mat::filled(3, 4, 7.0);
        let cap = m.data.capacity();
        m.reset(2, 5);
        assert_eq!((m.rows, m.cols), (2, 5));
        assert!(m.data.iter().all(|&v| v == 0.0));
        assert!(m.data.capacity() >= cap.min(10));
        m.reset(3, 4);
        assert_eq!(m.data.len(), 12);
    }

    #[test]
    fn eye_is_identity_for_matmul() {
        let mut r = Rng::new(4);
        let a = Mat::randn(6, 6, &mut r, 1.0);
        assert!(a.matmul(&Mat::eye(6)).allclose(&a, 1e-6, 0.0));
        assert!(Mat::eye(6).matmul(&a).allclose(&a, 1e-6, 0.0));
    }

    #[test]
    fn slice_set_slice_roundtrip() {
        let mut r = Rng::new(5);
        let a = Mat::randn(8, 10, &mut r, 1.0);
        let s = a.slice(2, 6, 3, 9);
        assert_eq!((s.rows, s.cols), (4, 6));
        assert_eq!(s.at(0, 0), a.at(2, 3));
        let mut b = Mat::zeros(8, 10);
        b.set_slice(2, 3, &s);
        assert_eq!(b.at(5, 8), a.at(5, 8));
        assert_eq!(b.at(0, 0), 0.0);
    }

    #[test]
    fn rmsnorm_unit_rows() {
        let x = Mat::from_vec(2, 4, vec![1.0, 1.0, 1.0, 1.0, 2.0, 2.0, 2.0, 2.0]);
        let g = vec![1.0; 4];
        let (out, _) = rmsnorm(&x, &g, 0.0);
        for v in &out.data {
            assert!((v - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn log_softmax_rows_sum_to_one() {
        let mut r = Rng::new(6);
        let x = Mat::randn(4, 9, &mut r, 3.0);
        let ls = log_softmax(&x);
        for i in 0..4 {
            let s: f32 = ls.row(i).iter().map(|v| v.exp()).sum();
            assert!((s - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn elementwise_ops() {
        let a = Mat::from_vec(1, 3, vec![1.0, -2.0, 3.0]);
        let b = Mat::from_vec(1, 3, vec![2.0, 2.0, 2.0]);
        assert_eq!(a.add(&b).data, vec![3.0, 0.0, 5.0]);
        assert_eq!(a.sub(&b).data, vec![-1.0, -4.0, 1.0]);
        assert_eq!(a.hadamard(&b).data, vec![2.0, -4.0, 6.0]);
        assert_eq!(a.relu().data, vec![1.0, 0.0, 3.0]);
        assert_eq!(a.scale(2.0).data, vec![2.0, -4.0, 6.0]);
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        let a = Mat::zeros(2, 3);
        let b = Mat::zeros(2, 3);
        let _ = a.matmul(&b);
    }
}
