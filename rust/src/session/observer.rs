//! `StepObserver`: streaming run telemetry.
//!
//! Observers replace the scattered `verbose` printing and ad-hoc
//! `--stats-json` plumbing the entry points used to carry: every backend
//! emits one [`StepReport`] per step through [`super::run`], and the
//! observers decide what to do with it — log it ([`LogObserver`]), append
//! it to a JSONL file ([`JsonlObserver`]) or drop it ([`NullObserver`]).

use std::io::Write as _;
use std::path::Path;

use crate::util::json::Json;

use super::report::{RunReport, StepReport};
use super::spec::RunSpec;

/// Streaming callbacks over one session run.  All methods default to
/// no-ops so an observer implements only what it needs.
pub trait StepObserver {
    /// The spec was validated and the backend prepared.
    fn on_start(&mut self, _spec: &RunSpec) {}
    /// One step (or one projected point on the sim backend) finished.
    fn on_step(&mut self, _report: &StepReport) {}
    /// The session finished; `report` is the final aggregate.
    fn on_finish(&mut self, _report: &RunReport) {}
}

/// Drops every event.
pub struct NullObserver;

impl StepObserver for NullObserver {}

/// Human-readable stderr logging (the old `--verbose` behaviour).
pub struct LogObserver {
    /// Print one line every `every` steps.  Steps that evaluated and the
    /// final step always print; `0` prints **only** those (plus
    /// start/finish).
    pub every: u64,
}

impl LogObserver {
    /// Log every step.
    pub fn every_step() -> LogObserver {
        LogObserver { every: 1 }
    }

    /// Log every `every`-th step (eval and final steps always print;
    /// 0 = only those).
    pub fn every(every: u64) -> LogObserver {
        LogObserver { every }
    }
}

impl StepObserver for LogObserver {
    fn on_start(&mut self, spec: &RunSpec) {
        let work = if let Some(s) = &spec.sim {
            format!("{} sweep points", s.gd_sweep.len())
        } else if spec.steps > 0 {
            format!("{} steps", spec.steps)
        } else if spec.final_eval {
            "evaluation only".to_string()
        } else {
            format!("{} epochs", spec.epochs)
        };
        eprintln!(
            "[session] {} backend, dataset {}, grid {}, {work}",
            spec.backend.tag(),
            spec.dataset,
            spec.grid.to_string(),
        );
    }

    fn on_step(&mut self, r: &StepReport) {
        let eval = r.detail.get("val").is_some();
        // an edge_cap overflow is an anomaly, never rate-limited away
        let truncated = r.detail.get("truncated_edges").and_then(Json::as_f64);
        let periodic = self.every > 0 && (r.step + 1) % self.every == 0;
        if !(periodic || eval || truncated.is_some() || r.done) {
            return;
        }
        let mut line = format!("[session] step {:>6}", r.step + 1);
        if r.loss.is_finite() {
            line.push_str(&format!(" loss {:.4}", r.loss));
        }
        if r.acc.is_finite() {
            line.push_str(&format!(" acc {:.4}", r.acc));
        }
        if let (Some(v), Some(t)) = (
            r.detail.get("val").and_then(Json::as_f64),
            r.detail.get("test").and_then(Json::as_f64),
        ) {
            line.push_str(&format!(" val {v:.4} test {t:.4}"));
        }
        if let Some(t) = truncated {
            line.push_str(&format!(" WARNING: {t:.0} edges dropped past edge_cap"));
        }
        line.push_str(&format!(" ({:.1} ms)", r.wall_s * 1e3));
        eprintln!("{line}");
    }

    fn on_finish(&mut self, r: &RunReport) {
        for f in &r.failures {
            match f.resumed_from_step {
                Some(s) => eprintln!(
                    "[session] recovered: rank {} died in {} (seq {}, axis '{}'): {}; \
                     replayed from step {s}",
                    f.rank, f.op, f.seq, f.axis, f.message
                ),
                None => eprintln!(
                    "[session] rank {} died in {} (seq {}, axis '{}'): {}",
                    f.rank, f.op, f.seq, f.axis, f.message
                ),
            }
        }
        eprintln!(
            "[session] finished: {} steps in {:.2}s, final loss {:.4}",
            r.steps, r.wall_s, r.final_loss
        );
        // a panicking rank unwinds through arbitrary frames; make sure
        // the summary is on the terminal before anything re-raises
        let _ = std::io::stderr().flush();
    }
}

/// Machine-readable JSONL stream: one `{"event": "start" | "step" |
/// "finish", ...}` object per line (replaces the ad-hoc `--stats-json`
/// plumbing; the `finish` line carries the whole [`RunReport`]).
///
/// Write failures (full disk, revoked path) do not abort the run — the
/// first one is reported on stderr and the stream stops.
pub struct JsonlObserver {
    out: std::io::BufWriter<std::fs::File>,
    path: std::path::PathBuf,
    failed: bool,
}

impl JsonlObserver {
    /// Create/truncate `path` and stream events into it.
    pub fn create(path: &Path) -> std::io::Result<JsonlObserver> {
        Ok(JsonlObserver {
            out: std::io::BufWriter::new(std::fs::File::create(path)?),
            path: path.to_path_buf(),
            failed: false,
        })
    }

    fn emit(&mut self, event: &str, mut fields: Vec<(&str, Json)>) {
        if self.failed {
            return;
        }
        let mut all = vec![("event", Json::from(event))];
        all.append(&mut fields);
        if let Err(e) = writeln!(self.out, "{}", crate::util::json::obj(all).to_string()) {
            self.fail(&e);
        }
    }

    fn fail(&mut self, e: &std::io::Error) {
        self.failed = true;
        eprintln!(
            "warning: jsonl stream {} failed ({e}); the event log is incomplete",
            self.path.display()
        );
    }
}

impl Drop for JsonlObserver {
    /// A run that aborts mid-way (rank panic, unrecoverable fault) drops
    /// the observer without `on_finish`; flush here so the steps that DID
    /// stream survive in the file instead of dying in the buffer.
    fn drop(&mut self) {
        if !self.failed {
            if let Err(e) = self.out.flush() {
                eprintln!(
                    "warning: jsonl stream {} lost buffered events on drop ({e})",
                    self.path.display()
                );
            }
        }
    }
}

impl StepObserver for JsonlObserver {
    fn on_start(&mut self, spec: &RunSpec) {
        self.emit("start", vec![("spec", spec.to_json())]);
    }

    fn on_step(&mut self, r: &StepReport) {
        self.emit("step", vec![("report", r.to_json())]);
    }

    fn on_finish(&mut self, r: &RunReport) {
        self.emit("finish", vec![("report", r.to_json())]);
        if !self.failed {
            if let Err(e) = self.out.flush() {
                self.fail(&e);
            }
        }
    }
}
