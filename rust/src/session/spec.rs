//! `RunSpec`: the single typed description of a ScaleGNN run.
//!
//! A spec names a backend (reference trainer / out-of-core trainer / 4D
//! PMM engine / analytical simulator), a dataset source, the sampler, the
//! model dimensions, the 4D grid, precision and the §V toggles.  It
//! cross-validates ([`RunSpec::validate`] returns every violation as a
//! structured [`SpecError`]) and round-trips losslessly through
//! `util::json` ([`RunSpec::to_json`] / [`RunSpec::from_json`]) so runs
//! are shareable, diffable artifacts (`scalegnn run --spec FILE.json`).

use std::path::PathBuf;

use crate::checkpoint::CheckpointPolicy;
use crate::comm::{ChaosMode, ChaosSpec, Endpoint, Precision, TransportTuning};
use crate::graph::datasets;
use crate::grid::Grid4D;
use crate::sampling::SamplerKind;
use crate::sim;
use crate::util::json::{obj, Json};

/// Which engine executes the spec.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendKind {
    /// PJRT reference trainer (`trainer::train`): fused or DP artifacts,
    /// in-memory dataset.
    Reference,
    /// Out-of-core pure-Rust trainer (`trainer::train_from_store`): the
    /// graph/features stay on disk behind the `.pallas` block cache.
    Ooc,
    /// Rank-thread 4D PMM engine (`pmm::PmmGcn`): pure Rust, executes the
    /// sharded collectives for real.
    Pmm,
    /// Analytical projection (`sim::scalegnn_epoch_with`) at paper scale.
    Sim,
}

impl BackendKind {
    /// Parse a backend tag; the error names the accepted values.
    pub fn parse(s: &str) -> Result<BackendKind, String> {
        match s {
            "reference" | "ref" => Ok(BackendKind::Reference),
            "ooc" => Ok(BackendKind::Ooc),
            "pmm" => Ok(BackendKind::Pmm),
            "sim" => Ok(BackendKind::Sim),
            other => Err(format!(
                "unknown backend '{other}' (accepted: reference, ooc, pmm, sim)"
            )),
        }
    }

    /// Canonical tag used by the JSON encoding and error messages.
    pub fn tag(&self) -> &'static str {
        match self {
            BackendKind::Reference => "reference",
            BackendKind::Ooc => "ooc",
            BackendKind::Pmm => "pmm",
            BackendKind::Sim => "sim",
        }
    }
}

/// Where the graph + vertex data come from.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum DataSource {
    /// Generated in memory from the dataset registry.
    Mem,
    /// Served out-of-core from a `.pallas` container (packed from the
    /// registry dataset on first use).
    Ooc {
        /// Path of the `.pallas` store.
        store: PathBuf,
    },
}

/// The 4D process-grid axes `Gd x Gx x Gy x Gz` (§IV-A).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct GridSpec {
    /// Data-parallel groups.
    pub gd: usize,
    /// PMM x axis.
    pub gx: usize,
    /// PMM y axis.
    pub gy: usize,
    /// PMM z axis.
    pub gz: usize,
}

impl GridSpec {
    /// 1x1x1x1 (single rank).
    pub const SOLO: GridSpec = GridSpec { gd: 1, gx: 1, gy: 1, gz: 1 };

    /// Parse `"GdxGxxGyxGz"` (4 fields) or `"GxxGyxGz"` (3 fields, Gd=1);
    /// the error names the accepted form.  Zero axes parse and are
    /// rejected later by [`RunSpec::validate`] (never a panic).
    pub fn parse(s: &str) -> Result<GridSpec, String> {
        let bad = || format!("bad grid '{s}' (accepted: AxBxCxD or AxBxC, e.g. 2x2x2x2)");
        let parts: Vec<usize> = s
            .split('x')
            .map(|p| p.parse::<usize>())
            .collect::<Result<_, _>>()
            .map_err(|_| bad())?;
        match parts[..] {
            [gx, gy, gz] => Ok(GridSpec { gd: 1, gx, gy, gz }),
            [gd, gx, gy, gz] => Ok(GridSpec { gd, gx, gy, gz }),
            _ => Err(bad()),
        }
    }

    /// Total rank count (saturating, so absurd JSON values fail
    /// validation instead of overflowing).
    pub fn world_size(&self) -> usize {
        self.gd
            .saturating_mul(self.gx)
            .saturating_mul(self.gy)
            .saturating_mul(self.gz)
    }

    /// Canonical `GdxGxxGyxGz` form.
    pub fn to_string(&self) -> String {
        format!("{}x{}x{}x{}", self.gd, self.gx, self.gy, self.gz)
    }
}

impl From<Grid4D> for GridSpec {
    fn from(g: Grid4D) -> GridSpec {
        GridSpec { gd: g.gd, gx: g.gx, gy: g.gy, gz: g.gz }
    }
}

impl From<GridSpec> for Grid4D {
    fn from(g: GridSpec) -> Grid4D {
        Grid4D::new(g.gd, g.gx, g.gy, g.gz)
    }
}

/// Model dimensions carried by the spec (`d_in`/`d_out` always come from
/// the dataset registry).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ModelSpec {
    /// Hidden width.
    pub d_h: usize,
    /// GCN layers.
    pub layers: usize,
    /// Dropout probability.
    pub dropout: f32,
}

impl ModelSpec {
    /// The per-dataset defaults the artifact configurations use
    /// (tiny: 16x2, e2e_big: 512x4, otherwise 128x3).
    pub fn for_dataset(dataset: &str, dropout: f32) -> ModelSpec {
        let (d_h, layers) = match dataset {
            "tiny" => (16, 2),
            "e2e_big" => (512, 4),
            _ => (128, 3),
        };
        ModelSpec { d_h, layers, dropout }
    }
}

/// Simulator-only parameters (`backend == Sim`).
#[derive(Clone, Debug, PartialEq)]
pub struct SimSpec {
    /// Machine profile name (`perlmutter` / `frontier` / `tuolumne`).
    pub machine: String,
    /// §V-D hide fraction override; `None` uses the calibration default.
    pub hide_frac: Option<f64>,
    /// `Gd` values to project, one per session step (the 3D base comes
    /// from `RunSpec::grid`).
    pub gd_sweep: Vec<usize>,
}

/// How the ranks of a PMM run communicate (the comm transport behind
/// `CommWorld`).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum TransportSpec {
    /// Rank threads of this process over shared-memory op slots (the
    /// default; every rank of the grid runs in-process).
    InProc,
    /// This process runs **one** rank; collectives travel as wire frames
    /// to a `scalegnn-coord` coordinator.  The same spec file is shared
    /// by every rank process — only `rank` differs per process (usually
    /// supplied as a per-process `--rank` override rather than baked into
    /// the file).
    Socket {
        /// The coordinator endpoint every rank connects to.
        endpoint: Endpoint,
        /// Which rank this process runs; `None` in a shared spec file
        /// (each launch must then supply it) — rejected at prepare time,
        /// not by `validate`, so one spec artifact serves all ranks.
        rank: Option<usize>,
    },
}

impl TransportSpec {
    /// Parse `"inproc"`, `"tcp:HOST:PORT"` or `"unix:PATH"` (socket forms
    /// leave `rank` unset for a per-process override).
    pub fn parse(s: &str) -> Result<TransportSpec, String> {
        if s == "inproc" {
            return Ok(TransportSpec::InProc);
        }
        let endpoint = Endpoint::parse(s)
            .map_err(|e| format!("bad transport '{s}': {e} (or use 'inproc')"))?;
        Ok(TransportSpec::Socket { endpoint, rank: None })
    }

    /// The endpoint string (`"inproc"`, `"tcp:…"`, `"unix:…"`) without
    /// the rank.
    pub fn endpoint_tag(&self) -> String {
        match self {
            TransportSpec::InProc => "inproc".to_string(),
            TransportSpec::Socket { endpoint, .. } => endpoint.to_string(),
        }
    }
}

/// A deterministic fault the session layer injects to drive the
/// crash-recovery path end to end.  Faults require a `checkpoint`
/// section: recovery replays from the newest common snapshot.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultSpec {
    /// Kill rank `rank` when it reaches step `step` (PMM backend only):
    /// the rank poisons its collectives and unwinds, peers drain with the
    /// same structured origin, and the session supervisor re-forms the
    /// world and resumes from the newest common checkpoint.
    KillRank {
        /// Rank thread to kill.
        rank: usize,
        /// Step index at which the kill fires (at step entry, before the
        /// step's collectives).
        step: u64,
    },
    /// Stall rank `rank` for `ms` milliseconds when it reaches step
    /// `step` (PMM backend only): the rank goes silent without dying, so
    /// the deadline discipline — not a death notification — must detect
    /// it, poison the world with a `Stalled` origin, and recover from the
    /// newest common snapshot.
    StallRank {
        /// Rank thread to stall.
        rank: usize,
        /// Step index at which the stall fires (at step entry, before the
        /// step's collectives).
        step: u64,
        /// How long the rank sleeps; must exceed the world's
        /// `wait_timeout_ms` for the stall to be detected.
        ms: u64,
    },
    /// Flip a payload bit in the newest snapshot before the run starts,
    /// so restore must detect the bad checksum and fall back to the
    /// previous valid snapshot.
    CorruptNewest,
    /// Truncate the newest snapshot to half its length before the run
    /// starts (a torn write), forcing the same fallback.
    TruncateNewest,
}

/// One structured violation found by [`RunSpec::validate`].
#[derive(Clone, Debug, PartialEq)]
pub enum SpecError {
    /// `dataset` is not in the registry.
    UnknownDataset(String),
    /// A grid axis is zero.
    ZeroGridAxis(GridSpec),
    /// The grid volume exceeds what the rank-thread runtime executes.
    WorldTooLarge {
        /// Requested rank count.
        ranks: usize,
        /// Executable maximum.
        max: usize,
    },
    /// The backend cannot consume the given data source (e.g. OOC + PMM).
    SourceMismatch {
        /// Offending backend.
        backend: BackendKind,
        /// What that backend requires.
        need: &'static str,
    },
    /// The backend only trains with ScaleGNN uniform sampling.
    SamplerUnsupported(BackendKind),
    /// The backend uses the `Gd` axis only; `Gx/Gy/Gz` must be 1.
    GridUnsupported(BackendKind),
    /// `hide_frac` outside `[0, 1]`.
    HideFracRange(f64),
    /// Unknown simulator machine profile.
    UnknownMachine(String),
    /// `sim` section present iff `backend == Sim` was violated.
    SimSectionMismatch {
        /// The spec's backend.
        backend: BackendKind,
        /// Whether the `sim` section was present.
        present: bool,
    },
    /// The sim `gd_sweep` is empty.
    EmptySweep,
    /// A training backend was given zero steps (and zero epochs).
    NoWork(BackendKind),
    /// `batch` is zero or exceeds the dataset's vertex count.
    BatchTooLarge {
        /// Requested batch.
        batch: usize,
        /// Dataset vertices.
        n: usize,
    },
    /// The backend takes the mini-batch size from the artifact manifest;
    /// a spec override cannot be honored.
    BatchUnsupported(BackendKind),
    /// A spec field the backend would silently ignore.
    FieldUnsupported {
        /// Offending backend.
        backend: BackendKind,
        /// The field that would not apply.
        field: &'static str,
    },
    /// `d_h` or `layers` is zero.
    BadModel(ModelSpec),
    /// Learning rate is not finite-positive.
    BadLr(f32),
    /// The `checkpoint` section (or `resume` flag) is malformed.
    BadCheckpoint(&'static str),
    /// The `fault` section is malformed or not executable on this spec.
    BadFault(&'static str),
    /// The `transport` section is malformed or not executable on this
    /// spec.
    BadTransport(&'static str),
    /// The `chaos` section is malformed or not executable on this spec.
    BadChaos(&'static str),
}

impl std::fmt::Display for SpecError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SpecError::UnknownDataset(d) => {
                write!(f, "unknown dataset '{d}' (see `scalegnn info`)")
            }
            SpecError::ZeroGridAxis(g) => {
                write!(f, "grid {} has a zero axis", g.to_string())
            }
            SpecError::WorldTooLarge { ranks, max } => write!(
                f,
                "grid volume {ranks} exceeds the {max} rank threads the runtime executes"
            ),
            SpecError::SourceMismatch { backend, need } => {
                write!(f, "backend '{}' requires {need}", backend.tag())
            }
            SpecError::SamplerUnsupported(b) => write!(
                f,
                "backend '{}' only supports the scalegnn uniform sampler",
                b.tag()
            ),
            SpecError::GridUnsupported(b) => match b {
                BackendKind::Ooc => {
                    write!(f, "backend 'ooc' is single-rank (grid must be 1x1x1x1)")
                }
                _ => write!(
                    f,
                    "backend '{}' parallelizes over Gd only (grid must be Dx1x1x1)",
                    b.tag()
                ),
            },
            SpecError::HideFracRange(v) => {
                write!(f, "hide_frac must be in [0, 1], got {v}")
            }
            SpecError::UnknownMachine(m) => write!(
                f,
                "unknown machine '{m}' (accepted: perlmutter, frontier, tuolumne)"
            ),
            SpecError::SimSectionMismatch { backend, present } => {
                if *present {
                    write!(f, "'sim' section given but backend is '{}'", backend.tag())
                } else {
                    write!(f, "backend 'sim' needs a 'sim' section (machine, gd_sweep)")
                }
            }
            SpecError::EmptySweep => {
                write!(f, "sim.gd_sweep must list at least one nonzero Gd")
            }
            SpecError::NoWork(b) => match b {
                BackendKind::Reference => {
                    write!(f, "backend 'reference' needs steps > 0 or epochs > 0")
                }
                BackendKind::Pmm => write!(
                    f,
                    "backend 'pmm' needs steps > 0 (or final_eval for an evaluation-only run)"
                ),
                _ => write!(f, "backend '{}' needs steps > 0", b.tag()),
            },
            SpecError::BatchTooLarge { batch, n } => {
                write!(f, "batch {batch} must be in [1, {n}] (the dataset's vertex count)")
            }
            SpecError::BatchUnsupported(b) => write!(
                f,
                "backend '{}' takes the mini-batch size from the artifact manifest; omit 'batch'",
                b.tag()
            ),
            SpecError::FieldUnsupported { backend, field } => write!(
                f,
                "backend '{}' does not support '{field}' (it would silently not apply)",
                backend.tag()
            ),
            SpecError::BadModel(m) => write!(
                f,
                "model must have d_h > 0 and layers > 0 (got d_h={}, layers={})",
                m.d_h, m.layers
            ),
            SpecError::BadLr(lr) => write!(f, "lr must be finite and positive, got {lr}"),
            SpecError::BadCheckpoint(why) => write!(f, "bad checkpoint section: {why}"),
            SpecError::BadFault(why) => write!(f, "bad fault section: {why}"),
            SpecError::BadTransport(why) => write!(f, "bad transport section: {why}"),
            SpecError::BadChaos(why) => write!(f, "bad chaos section: {why}"),
        }
    }
}

/// Maximum rank threads the in-process runtime will spawn for one run.
pub const MAX_RANK_THREADS: usize = 256;

/// The single typed description of a run: dataset source, backend, model,
/// grid, precision, §V toggles and the training hyper-parameters.  Build
/// one with [`RunSpec::new`] + the chainable setters, validate with
/// [`RunSpec::validate`], execute with [`super::run`].
#[derive(Clone, Debug, PartialEq)]
pub struct RunSpec {
    /// Executing backend.
    pub backend: BackendKind,
    /// Registry dataset name.
    pub dataset: String,
    /// In-memory vs out-of-core source.
    pub source: DataSource,
    /// Sampling algorithm.
    pub sampler: SamplerKind,
    /// Model dimensions (`d_in`/`d_out` come from the dataset).  The
    /// reference backend reads its dims from the artifact manifest —
    /// [`ModelSpec::for_dataset`] mirrors those configurations.
    pub model: ModelSpec,
    /// 4D grid axes.
    pub grid: GridSpec,
    /// Collective payload precision (§V-B).
    pub precision: Precision,
    /// §V-D communication/computation overlap.
    pub overlap: bool,
    /// §V-A sampling/training prefetch overlap.
    pub prefetch: bool,
    /// Step cap (0 = derive from `epochs` on the reference backend).
    pub steps: u64,
    /// Epoch cap used by the reference backend when `steps == 0`.
    pub epochs: usize,
    /// Mini-batch size override (`None` = backend default; rejected on
    /// the reference backend, whose batch is fixed by the artifact).
    pub batch: Option<usize>,
    /// Adam learning rate.
    pub lr: f32,
    /// Sampling / parameter-init seed.
    pub seed: u64,
    /// Stop once full-graph test accuracy reaches this (reference backend).
    pub target_acc: Option<f32>,
    /// Evaluate every k epochs (reference backend).
    pub eval_every_epochs: usize,
    /// Block-cache budget (MiB) of the OOC source.
    pub cache_mb: usize,
    /// PJRT artifact directory of the reference backend.
    pub artifacts: PathBuf,
    /// Run a distributed full-graph evaluation at the end (PMM backend).
    pub final_eval: bool,
    /// Periodic snapshot policy (`None` = no checkpointing).
    pub checkpoint: Option<CheckpointPolicy>,
    /// Resume from the newest valid snapshot in `checkpoint.dir` before
    /// training (requires a `checkpoint` section).
    pub resume: bool,
    /// Deterministic fault injection for the crash-recovery tests.
    pub fault: Option<FaultSpec>,
    /// Comm transport of the PMM ranks (in-process rank threads vs one
    /// rank per OS process over a socket).
    pub transport: TransportSpec,
    /// Transport deadlines and heartbeat tuning (`None` fields keep the
    /// built-in defaults).  Rides on the `transport` JSON object.
    pub tuning: TransportTuning,
    /// Deterministic fault-injection schedule for chaos testing (PMM
    /// backend only; `None` = no chaos).
    pub chaos: Option<ChaosSpec>,
    /// Simulator section (`backend == Sim` only).
    pub sim: Option<SimSpec>,
}

impl RunSpec {
    /// A spec with the backend's defaults: solo grid, scalegnn sampling,
    /// the dataset's default model dims, fp32, overlap + prefetch on.
    pub fn new(backend: BackendKind, dataset: &str) -> RunSpec {
        RunSpec {
            backend,
            dataset: dataset.to_string(),
            source: DataSource::Mem,
            sampler: SamplerKind::ScaleGnnUniform,
            model: ModelSpec::for_dataset(dataset, 0.0),
            grid: GridSpec::SOLO,
            precision: Precision::Fp32,
            overlap: true,
            prefetch: true,
            steps: 0,
            epochs: 20,
            batch: None,
            lr: 1e-2,
            seed: 42,
            target_acc: None,
            eval_every_epochs: 1,
            cache_mb: 64,
            artifacts: PathBuf::from("artifacts"),
            final_eval: false,
            checkpoint: None,
            resume: false,
            fault: None,
            transport: TransportSpec::InProc,
            tuning: TransportTuning::default(),
            chaos: None,
            sim: None,
        }
    }

    /// Set the 4D grid.
    pub fn grid(mut self, gd: usize, gx: usize, gy: usize, gz: usize) -> Self {
        self.grid = GridSpec { gd, gx, gy, gz };
        self
    }

    /// Set the sampler.
    pub fn sampler(mut self, s: SamplerKind) -> Self {
        self.sampler = s;
        self
    }

    /// Set the model dims.
    pub fn model(mut self, d_h: usize, layers: usize, dropout: f32) -> Self {
        self.model = ModelSpec { d_h, layers, dropout };
        self
    }

    /// Set the step cap.
    pub fn steps(mut self, steps: u64) -> Self {
        self.steps = steps;
        self
    }

    /// Set the epoch cap (reference backend, `steps == 0`).
    pub fn epochs(mut self, epochs: usize) -> Self {
        self.epochs = epochs;
        self
    }

    /// Set the learning rate.
    pub fn lr(mut self, lr: f32) -> Self {
        self.lr = lr;
        self
    }

    /// Set the seed.
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Toggle §V-D overlap.
    pub fn overlap(mut self, on: bool) -> Self {
        self.overlap = on;
        self
    }

    /// Toggle §V-A prefetch.
    pub fn prefetch(mut self, on: bool) -> Self {
        self.prefetch = on;
        self
    }

    /// Set the collective precision.
    pub fn precision(mut self, p: Precision) -> Self {
        self.precision = p;
        self
    }

    /// Override the mini-batch size.
    pub fn batch(mut self, b: usize) -> Self {
        self.batch = Some(b);
        self
    }

    /// Set the target accuracy (reference backend stops when reached).
    pub fn target_acc(mut self, acc: f32) -> Self {
        self.target_acc = Some(acc);
        self
    }

    /// Evaluate every `k` epochs (reference backend).
    pub fn eval_every(mut self, k: usize) -> Self {
        self.eval_every_epochs = k;
        self
    }

    /// Serve the dataset out-of-core from `store` (packs on first use).
    pub fn store(mut self, store: PathBuf) -> Self {
        self.source = DataSource::Ooc { store };
        self
    }

    /// Set the OOC block-cache budget in MiB.
    pub fn cache_mb(mut self, mb: usize) -> Self {
        self.cache_mb = mb;
        self
    }

    /// Set the PJRT artifact directory.
    pub fn artifacts(mut self, dir: PathBuf) -> Self {
        self.artifacts = dir;
        self
    }

    /// Request a final distributed full-graph evaluation (PMM backend).
    pub fn final_eval(mut self, on: bool) -> Self {
        self.final_eval = on;
        self
    }

    /// Snapshot to `dir` every `every_steps` steps, keeping the newest
    /// `keep` snapshots per rank tag.
    pub fn checkpoint(mut self, dir: PathBuf, every_steps: u64, keep: usize) -> Self {
        self.checkpoint = Some(CheckpointPolicy::new(dir, every_steps, keep));
        self
    }

    /// Resume from the newest valid snapshot before training.
    pub fn resume(mut self, on: bool) -> Self {
        self.resume = on;
        self
    }

    /// Inject a deterministic fault (drives the crash-recovery tests).
    pub fn fault(mut self, fault: FaultSpec) -> Self {
        self.fault = Some(fault);
        self
    }

    /// Set the comm transport (PMM backend; default [`TransportSpec::InProc`]).
    pub fn transport(mut self, t: TransportSpec) -> Self {
        self.transport = t;
        self
    }

    /// Set which rank this process runs on a socket transport (no-op on
    /// `InProc`, which runs every rank).
    pub fn with_rank(mut self, r: usize) -> Self {
        if let TransportSpec::Socket { rank, .. } = &mut self.transport {
            *rank = Some(r);
        }
        self
    }

    /// Set the transport deadline / heartbeat tuning (`None` fields keep
    /// the built-in defaults).
    pub fn tuning(mut self, t: TransportTuning) -> Self {
        self.tuning = t;
        self
    }

    /// Enable deterministic chaos fault injection (PMM backend).
    pub fn chaos(mut self, c: ChaosSpec) -> Self {
        self.chaos = Some(c);
        self
    }

    /// Attach the simulator section (`backend == Sim`).
    pub fn sim(mut self, machine: &str, hide_frac: Option<f64>, gd_sweep: Vec<usize>) -> Self {
        self.sim = Some(SimSpec { machine: machine.to_string(), hide_frac, gd_sweep });
        self
    }

    /// Cross-field validation; returns **every** violation.
    pub fn validate(&self) -> Result<(), Vec<SpecError>> {
        let mut errs = Vec::new();
        let spec = datasets::spec(&self.dataset);
        if spec.is_none() {
            errs.push(SpecError::UnknownDataset(self.dataset.clone()));
        }
        let g = self.grid;
        if g.gd == 0 || g.gx == 0 || g.gy == 0 || g.gz == 0 {
            errs.push(SpecError::ZeroGridAxis(g));
        } else if self.backend != BackendKind::Sim && g.world_size() > MAX_RANK_THREADS {
            errs.push(SpecError::WorldTooLarge {
                ranks: g.world_size(),
                max: MAX_RANK_THREADS,
            });
        }
        if self.model.d_h == 0 || self.model.layers == 0 {
            errs.push(SpecError::BadModel(self.model));
        }
        if !(self.lr.is_finite() && self.lr > 0.0) {
            errs.push(SpecError::BadLr(self.lr));
        }
        if let Some(s) = spec.as_ref() {
            // the OOC backend defaults to OocTrainConfig::quick's batch of
            // 1024 when no override is given — check the effective value
            // so a batch-less spec on a small dataset fails here, not at
            // run time
            let eff = match (self.batch, self.backend) {
                (Some(b), _) => Some(b),
                (None, BackendKind::Ooc) => Some(1024),
                (None, _) => None,
            };
            if let Some(b) = eff {
                if b == 0 || b > s.planted.n {
                    errs.push(SpecError::BatchTooLarge { batch: b, n: s.planted.n });
                }
            }
        }
        match self.backend {
            BackendKind::Reference => {
                // batch and model dims come from the AOT artifact manifest
                // on this backend; a spec override would silently not apply
                if self.batch.is_some() {
                    errs.push(SpecError::BatchUnsupported(self.backend));
                }
                if self.source != DataSource::Mem {
                    errs.push(SpecError::SourceMismatch {
                        backend: self.backend,
                        need: "an in-memory source (source.kind = \"mem\")",
                    });
                }
                if g.gx != 1 || g.gy != 1 || g.gz != 1 {
                    errs.push(SpecError::GridUnsupported(self.backend));
                }
                if self.steps == 0 && self.epochs == 0 {
                    errs.push(SpecError::NoWork(self.backend));
                }
                // this backend evaluates periodically on its own; the
                // pmm-only final_eval knob would silently not apply
                if self.final_eval {
                    errs.push(SpecError::FieldUnsupported {
                        backend: self.backend,
                        field: "final_eval",
                    });
                }
                // model dims AND dropout come from the artifact manifest;
                // anything but the spec default (which mirrors the
                // artifact configurations) would silently not apply
                if self.model != ModelSpec::for_dataset(&self.dataset, 0.0) {
                    errs.push(SpecError::FieldUnsupported {
                        backend: self.backend,
                        field: "model",
                    });
                }
                // 0 would be silently clamped to "every epoch"
                if self.eval_every_epochs == 0 {
                    errs.push(SpecError::FieldUnsupported {
                        backend: self.backend,
                        field: "eval_every_epochs = 0",
                    });
                }
            }
            BackendKind::Ooc => {
                if !matches!(self.source, DataSource::Ooc { .. }) {
                    errs.push(SpecError::SourceMismatch {
                        backend: self.backend,
                        need: "an out-of-core source (source.kind = \"ooc\" with a store path)",
                    });
                }
                if self.sampler != SamplerKind::ScaleGnnUniform {
                    errs.push(SpecError::SamplerUnsupported(self.backend));
                }
                if g.world_size() != 1 {
                    errs.push(SpecError::GridUnsupported(self.backend));
                }
                if self.steps == 0 {
                    errs.push(SpecError::NoWork(self.backend));
                }
                // fields this backend would silently ignore.  `overlap`
                // and `precision` stay accepted: they toggle collectives
                // and this single-rank path has none, so they are
                // vacuously honored (the session-vs-legacy identity tests
                // exercise overlap on/off here by design).
                if self.target_acc.is_some() {
                    errs.push(SpecError::FieldUnsupported {
                        backend: self.backend,
                        field: "target_acc",
                    });
                }
                if self.final_eval {
                    errs.push(SpecError::FieldUnsupported {
                        backend: self.backend,
                        field: "final_eval",
                    });
                }
            }
            BackendKind::Pmm => {
                // the PMM engine shards the in-memory dataset per rank; an
                // out-of-core source would need per-rank shard extraction
                // (`scalegnn sample --from-store`) — not a training path
                if self.source != DataSource::Mem {
                    errs.push(SpecError::SourceMismatch {
                        backend: self.backend,
                        need: "an in-memory source (OOC + PMM is not a training combination)",
                    });
                }
                if self.sampler != SamplerKind::ScaleGnnUniform {
                    errs.push(SpecError::SamplerUnsupported(self.backend));
                }
                // steps == 0 is allowed for an evaluation-only session
                if self.steps == 0 && !self.final_eval {
                    errs.push(SpecError::NoWork(self.backend));
                }
                // fields this backend would silently ignore: it has no
                // early-stopping eval, and its Algorithm-2 subgraph
                // prefetcher cannot be disabled
                if self.target_acc.is_some() {
                    errs.push(SpecError::FieldUnsupported {
                        backend: self.backend,
                        field: "target_acc",
                    });
                }
                if !self.prefetch {
                    errs.push(SpecError::FieldUnsupported {
                        backend: self.backend,
                        field: "prefetch",
                    });
                }
            }
            BackendKind::Sim => {
                if self.target_acc.is_some() {
                    errs.push(SpecError::FieldUnsupported {
                        backend: self.backend,
                        field: "target_acc",
                    });
                }
                if self.batch.is_some() {
                    errs.push(SpecError::FieldUnsupported {
                        backend: self.backend,
                        field: "batch",
                    });
                }
                if self.final_eval {
                    errs.push(SpecError::FieldUnsupported {
                        backend: self.backend,
                        field: "final_eval",
                    });
                }
                // the analytical projection holds no trainable state; a
                // snapshot section would silently not apply
                if self.checkpoint.is_some() {
                    errs.push(SpecError::FieldUnsupported {
                        backend: self.backend,
                        field: "checkpoint",
                    });
                }
                if self.resume {
                    errs.push(SpecError::FieldUnsupported {
                        backend: self.backend,
                        field: "resume",
                    });
                }
                if self.fault.is_some() {
                    errs.push(SpecError::FieldUnsupported {
                        backend: self.backend,
                        field: "fault",
                    });
                }
            }
        }
        if let Some(cp) = &self.checkpoint {
            if cp.every_steps == 0 {
                errs.push(SpecError::BadCheckpoint("checkpoint.every_steps must be > 0"));
            }
            if cp.keep == 0 {
                errs.push(SpecError::BadCheckpoint("checkpoint.keep must be > 0"));
            }
        }
        if self.resume && self.checkpoint.is_none() {
            errs.push(SpecError::BadCheckpoint(
                "resume requires a 'checkpoint' section naming the snapshot dir",
            ));
        }
        if let Some(fault) = self.fault {
            if self.checkpoint.is_none() {
                errs.push(SpecError::BadFault(
                    "faults require a 'checkpoint' section (recovery replays from snapshots)",
                ));
            }
            match fault {
                FaultSpec::KillRank { rank, step } => {
                    if self.backend != BackendKind::Pmm {
                        errs.push(SpecError::BadFault(
                            "kill_rank faults only run on the pmm backend",
                        ));
                    }
                    if rank >= g.world_size() {
                        errs.push(SpecError::BadFault(
                            "fault.rank must be below the grid's world size",
                        ));
                    }
                    if step >= self.steps {
                        errs.push(SpecError::BadFault(
                            "fault.step must be below 'steps' (the kill must fire mid-run)",
                        ));
                    }
                }
                FaultSpec::StallRank { rank, step, ms } => {
                    if self.backend != BackendKind::Pmm {
                        errs.push(SpecError::BadFault(
                            "stall_rank faults only run on the pmm backend",
                        ));
                    }
                    if rank >= g.world_size() {
                        errs.push(SpecError::BadFault(
                            "fault.rank must be below the grid's world size",
                        ));
                    }
                    if step >= self.steps {
                        errs.push(SpecError::BadFault(
                            "fault.step must be below 'steps' (the stall must fire mid-run)",
                        ));
                    }
                    if ms == 0 {
                        errs.push(SpecError::BadFault(
                            "fault.ms must be > 0 (the stall duration)",
                        ));
                    }
                }
                FaultSpec::CorruptNewest | FaultSpec::TruncateNewest => {}
            }
        }
        if let TransportSpec::Socket { rank, .. } = &self.transport {
            if self.backend != BackendKind::Pmm {
                errs.push(SpecError::BadTransport(
                    "socket transports only run on the pmm backend",
                ));
            }
            if let Some(r) = rank {
                if *r >= g.world_size() {
                    errs.push(SpecError::BadTransport(
                        "transport.rank must be below the grid's world size",
                    ));
                }
            }
            // every rank process would mangle the shared snapshot dir
            // once; these faults only make sense in-process
            if matches!(self.fault, Some(FaultSpec::CorruptNewest | FaultSpec::TruncateNewest)) {
                errs.push(SpecError::BadTransport(
                    "corrupt/truncate faults run in-process only (each rank process would mutate the shared snapshot dir)",
                ));
            }
        }
        // tuning values are milliseconds; zero would silently disable the
        // deadline (use `null`/omit for the default instead) and anything
        // above a day is certainly a unit mistake
        const MS_DAY: u32 = 86_400_000;
        if matches!(self.tuning.connect_timeout_ms, Some(v) if v == 0 || v > MS_DAY) {
            errs.push(SpecError::BadTransport(
                "transport.connect_timeout_ms must be in [1, 86400000] (one day)",
            ));
        }
        if matches!(self.tuning.heartbeat_ms, Some(v) if v == 0 || v > MS_DAY) {
            errs.push(SpecError::BadTransport(
                "transport.heartbeat_ms must be in [1, 86400000] (omit it to disable heartbeats)",
            ));
        }
        if matches!(self.tuning.wait_timeout_ms, Some(v) if v == 0 || v > MS_DAY) {
            errs.push(SpecError::BadTransport(
                "transport.wait_timeout_ms must be in [1, 86400000] (one day)",
            ));
        }
        if matches!(self.tuning.rejoin_grace_ms, Some(v) if v == 0 || v > MS_DAY) {
            errs.push(SpecError::BadTransport(
                "transport.rejoin_grace_ms must be in [1, 86400000] (omit it to disable rejoin)",
            ));
        }
        if let Some(chaos) = &self.chaos {
            if self.backend != BackendKind::Pmm {
                errs.push(SpecError::BadChaos("chaos injection only runs on the pmm backend"));
            }
            if let Err(why) = chaos.check() {
                errs.push(SpecError::BadChaos(why));
            }
        }
        match (&self.sim, self.backend) {
            (Some(s), BackendKind::Sim) => {
                if sim::by_name(&s.machine).is_none() {
                    errs.push(SpecError::UnknownMachine(s.machine.clone()));
                }
                if let Some(h) = s.hide_frac {
                    if !(0.0..=1.0).contains(&h) {
                        errs.push(SpecError::HideFracRange(h));
                    }
                }
                if s.gd_sweep.is_empty() || s.gd_sweep.contains(&0) {
                    errs.push(SpecError::EmptySweep);
                }
            }
            (None, BackendKind::Sim) => {
                errs.push(SpecError::SimSectionMismatch { backend: self.backend, present: false })
            }
            (Some(_), b) => {
                errs.push(SpecError::SimSectionMismatch { backend: b, present: true })
            }
            (None, _) => {}
        }
        if errs.is_empty() {
            Ok(())
        } else {
            Err(errs)
        }
    }

    /// Lossless JSON encoding (the inverse of [`RunSpec::from_json`]).
    pub fn to_json(&self) -> Json {
        let source = match &self.source {
            DataSource::Mem => obj(vec![("kind", Json::from("mem"))]),
            DataSource::Ooc { store } => obj(vec![
                ("kind", Json::from("ooc")),
                ("store", Json::from(store.to_string_lossy().as_ref())),
            ]),
        };
        let sim = match &self.sim {
            None => Json::Null,
            Some(s) => obj(vec![
                ("machine", Json::from(s.machine.as_str())),
                (
                    "hide_frac",
                    s.hide_frac.map(Json::from).unwrap_or(Json::Null),
                ),
                (
                    "gd_sweep",
                    Json::Arr(s.gd_sweep.iter().map(|&g| Json::from(g)).collect()),
                ),
            ]),
        };
        obj(vec![
            ("backend", Json::from(self.backend.tag())),
            ("dataset", Json::from(self.dataset.as_str())),
            ("source", source),
            ("sampler", Json::from(sampler_tag(self.sampler))),
            (
                "model",
                obj(vec![
                    ("d_h", Json::from(self.model.d_h)),
                    ("layers", Json::from(self.model.layers)),
                    ("dropout", Json::from(self.model.dropout as f64)),
                ]),
            ),
            ("grid", Json::from(self.grid.to_string().as_str())),
            (
                "precision",
                Json::from(match self.precision {
                    Precision::Fp32 => "fp32",
                    Precision::Bf16 => "bf16",
                }),
            ),
            ("overlap", Json::Bool(self.overlap)),
            ("prefetch", Json::Bool(self.prefetch)),
            ("steps", Json::from(self.steps as usize)),
            ("epochs", Json::from(self.epochs)),
            (
                "batch",
                self.batch.map(Json::from).unwrap_or(Json::Null),
            ),
            ("lr", Json::from(self.lr as f64)),
            // a decimal string: JSON numbers are f64 and would corrupt
            // seeds above 2^53
            ("seed", Json::from(self.seed.to_string().as_str())),
            (
                "target_acc",
                self.target_acc.map(|t| Json::from(t as f64)).unwrap_or(Json::Null),
            ),
            ("eval_every_epochs", Json::from(self.eval_every_epochs)),
            ("cache_mb", Json::from(self.cache_mb)),
            ("artifacts", Json::from(self.artifacts.to_string_lossy().as_ref())),
            ("final_eval", Json::Bool(self.final_eval)),
            (
                "checkpoint",
                match &self.checkpoint {
                    None => Json::Null,
                    Some(c) => obj(vec![
                        ("dir", Json::from(c.dir.to_string_lossy().as_ref())),
                        ("every_steps", Json::from(c.every_steps as usize)),
                        ("keep", Json::from(c.keep)),
                    ]),
                },
            ),
            ("resume", Json::Bool(self.resume)),
            (
                "fault",
                match self.fault {
                    None => Json::Null,
                    Some(FaultSpec::KillRank { rank, step }) => obj(vec![
                        ("kind", Json::from("kill_rank")),
                        ("rank", Json::from(rank)),
                        ("step", Json::from(step as usize)),
                    ]),
                    Some(FaultSpec::StallRank { rank, step, ms }) => obj(vec![
                        ("kind", Json::from("stall_rank")),
                        ("rank", Json::from(rank)),
                        ("step", Json::from(step as usize)),
                        ("ms", Json::from(ms as usize)),
                    ]),
                    Some(FaultSpec::CorruptNewest) => {
                        obj(vec![("kind", Json::from("corrupt_newest"))])
                    }
                    Some(FaultSpec::TruncateNewest) => {
                        obj(vec![("kind", Json::from("truncate_newest"))])
                    }
                },
            ),
            ("transport", {
                // plain InProc with default tuning stays `null`; any tuned
                // field forces the object form so the values round-trip
                let tuned = self.tuning != TransportTuning::default();
                let ms = |v: Option<u32>| {
                    v.map(|x| Json::from(x as usize)).unwrap_or(Json::Null)
                };
                match &self.transport {
                    TransportSpec::InProc if !tuned => Json::Null,
                    tr => {
                        let ep = tr.endpoint_tag();
                        let mut kv = vec![("endpoint", Json::from(ep.as_str()))];
                        if let TransportSpec::Socket { rank, .. } = tr {
                            kv.push(("rank", rank.map(Json::from).unwrap_or(Json::Null)));
                        }
                        kv.push(("connect_timeout_ms", ms(self.tuning.connect_timeout_ms)));
                        kv.push(("heartbeat_ms", ms(self.tuning.heartbeat_ms)));
                        kv.push(("wait_timeout_ms", ms(self.tuning.wait_timeout_ms)));
                        kv.push(("rejoin_grace_ms", ms(self.tuning.rejoin_grace_ms)));
                        obj(kv)
                    }
                }
            }),
            (
                "chaos",
                match &self.chaos {
                    None => Json::Null,
                    Some(c) => obj(vec![
                        // a decimal string, like the top-level seed
                        ("seed", Json::from(c.seed.to_string().as_str())),
                        ("rate", Json::from(c.rate)),
                        (
                            "modes",
                            Json::Arr(c.modes.iter().map(|m| Json::from(m.tag())).collect()),
                        ),
                    ]),
                },
            ),
            ("sim", sim),
        ])
    }

    /// Compact JSON text of [`RunSpec::to_json`].
    pub fn to_json_string(&self) -> String {
        self.to_json().to_string()
    }

    /// Decode a spec from JSON, rejecting unknown keys and bad types with
    /// messages that name the field.
    pub fn from_json(j: &Json) -> Result<RunSpec, String> {
        let o = j.as_obj().ok_or("spec must be a JSON object")?;
        const KNOWN: [&str; 25] = [
            "backend", "dataset", "source", "sampler", "model", "grid", "precision", "overlap",
            "prefetch", "steps", "epochs", "batch", "lr", "seed", "target_acc",
            "eval_every_epochs", "cache_mb", "artifacts", "final_eval", "checkpoint", "resume",
            "fault", "transport", "chaos", "sim",
        ];
        for k in o.keys() {
            if !KNOWN.contains(&k.as_str()) {
                return Err(format!("unknown spec field '{k}'"));
            }
        }
        let str_field = |name: &str| -> Result<&str, String> {
            j.get(name)
                .and_then(Json::as_str)
                .ok_or_else(|| format!("spec field '{name}' must be a string"))
        };
        let backend = BackendKind::parse(str_field("backend")?)?;
        let dataset = str_field("dataset")?.to_string();
        let mut spec = RunSpec::new(backend, &dataset);

        if let Some(s) = j.get("source") {
            check_obj_keys(s, "source", &["kind", "store"])?;
            let kind = s
                .get("kind")
                .and_then(Json::as_str)
                .ok_or("source.kind must be \"mem\" or \"ooc\"")?;
            spec.source = match kind {
                "mem" => DataSource::Mem,
                "ooc" => DataSource::Ooc {
                    store: PathBuf::from(
                        s.get("store")
                            .and_then(Json::as_str)
                            .ok_or("source.store (a path) is required when source.kind = \"ooc\"")?,
                    ),
                },
                other => {
                    return Err(format!("source.kind must be \"mem\" or \"ooc\", got '{other}'"))
                }
            };
        }
        // typed string fields: a wrong-typed value is an error, never a
        // silent fall-back to the default
        let str_typed = |name: &str| -> Result<Option<&str>, String> {
            match j.get(name) {
                None | Some(Json::Null) => Ok(None),
                Some(Json::Str(s)) => Ok(Some(s.as_str())),
                Some(_) => Err(format!("spec field '{name}' must be a string")),
            }
        };
        if let Some(s) = str_typed("sampler")? {
            spec.sampler = SamplerKind::parse(s).ok_or_else(|| {
                format!("unknown sampler '{s}' (accepted: scalegnn, graphsage, graphsaint)")
            })?;
        }
        if let Some(m) = j.get("model") {
            check_obj_keys(m, "model", &["d_h", "layers", "dropout"])?;
            let num = |name: &str| -> Result<f64, String> {
                m.get(name)
                    .and_then(Json::as_f64)
                    .ok_or_else(|| format!("model.{name} must be a number"))
            };
            spec.model = ModelSpec {
                d_h: num("d_h")? as usize,
                layers: num("layers")? as usize,
                dropout: num("dropout")? as f32,
            };
        }
        if let Some(g) = str_typed("grid")? {
            spec.grid = GridSpec::parse(g)?;
        }
        if let Some(p) = str_typed("precision")? {
            spec.precision = match p {
                "fp32" => Precision::Fp32,
                "bf16" => Precision::Bf16,
                other => {
                    return Err(format!("precision must be fp32 or bf16, got '{other}'"))
                }
            };
        }
        let bool_field = |name: &str, dflt: bool| -> Result<bool, String> {
            match j.get(name) {
                None | Some(Json::Null) => Ok(dflt),
                Some(Json::Bool(b)) => Ok(*b),
                Some(_) => Err(format!("spec field '{name}' must be true or false")),
            }
        };
        spec.overlap = bool_field("overlap", spec.overlap)?;
        spec.prefetch = bool_field("prefetch", spec.prefetch)?;
        spec.final_eval = bool_field("final_eval", spec.final_eval)?;
        spec.resume = bool_field("resume", spec.resume)?;
        let num_field = |name: &str| -> Result<Option<f64>, String> {
            match j.get(name) {
                None | Some(Json::Null) => Ok(None),
                Some(v) => v
                    .as_f64()
                    .map(Some)
                    .ok_or_else(|| format!("spec field '{name}' must be a number")),
            }
        };
        if let Some(v) = num_field("steps")? {
            spec.steps = v as u64;
        }
        if let Some(v) = num_field("epochs")? {
            spec.epochs = v as usize;
        }
        spec.batch = num_field("batch")?.map(|v| v as usize);
        if let Some(v) = num_field("lr")? {
            spec.lr = v as f32;
        }
        // seed: a decimal string (lossless for the full u64 range) or,
        // for hand-written specs, a plain number
        match j.get("seed") {
            None | Some(Json::Null) => {}
            Some(Json::Str(s)) => {
                spec.seed = s
                    .parse::<u64>()
                    .map_err(|_| format!("spec field 'seed' must be a u64, got '{s}'"))?;
            }
            Some(v) => {
                spec.seed = v
                    .as_f64()
                    .ok_or("spec field 'seed' must be a number or decimal string")?
                    as u64;
            }
        }
        spec.target_acc = num_field("target_acc")?.map(|v| v as f32);
        if let Some(v) = num_field("eval_every_epochs")? {
            spec.eval_every_epochs = v as usize;
        }
        if let Some(v) = num_field("cache_mb")? {
            spec.cache_mb = v as usize;
        }
        if let Some(a) = str_typed("artifacts")? {
            spec.artifacts = PathBuf::from(a);
        }
        match j.get("checkpoint") {
            None | Some(Json::Null) => {}
            Some(c) => {
                check_obj_keys(c, "checkpoint", &["dir", "every_steps", "keep"])?;
                let dir = c
                    .get("dir")
                    .and_then(Json::as_str)
                    .ok_or("checkpoint.dir must be a path string")?;
                let every = c
                    .get("every_steps")
                    .and_then(Json::as_f64)
                    .ok_or("checkpoint.every_steps must be a number")?;
                let keep = match c.get("keep") {
                    None | Some(Json::Null) => 4.0,
                    Some(v) => v.as_f64().ok_or("checkpoint.keep must be a number")?,
                };
                spec.checkpoint = Some(CheckpointPolicy::new(
                    PathBuf::from(dir),
                    every as u64,
                    keep as usize,
                ));
            }
        }
        match j.get("fault") {
            None | Some(Json::Null) => {}
            Some(v) => {
                check_obj_keys(v, "fault", &["kind", "rank", "step", "ms"])?;
                let kind = v.get("kind").and_then(Json::as_str).ok_or(
                    "fault.kind must be \"kill_rank\", \"stall_rank\", \"corrupt_newest\" or \"truncate_newest\"",
                )?;
                let rank_step = |kind: &str| -> Result<(usize, u64), String> {
                    let rank = v.get("rank").and_then(Json::as_f64).ok_or_else(|| {
                        format!("fault.rank must be a number when fault.kind = \"{kind}\"")
                    })?;
                    let step = v.get("step").and_then(Json::as_f64).ok_or_else(|| {
                        format!("fault.step must be a number when fault.kind = \"{kind}\"")
                    })?;
                    Ok((rank as usize, step as u64))
                };
                spec.fault = Some(match kind {
                    "kill_rank" => {
                        let (rank, step) = rank_step("kill_rank")?;
                        FaultSpec::KillRank { rank, step }
                    }
                    "stall_rank" => {
                        let (rank, step) = rank_step("stall_rank")?;
                        let ms = v
                            .get("ms")
                            .and_then(Json::as_f64)
                            .ok_or("fault.ms must be a number when fault.kind = \"stall_rank\"")?;
                        FaultSpec::StallRank { rank, step, ms: ms as u64 }
                    }
                    "corrupt_newest" => FaultSpec::CorruptNewest,
                    "truncate_newest" => FaultSpec::TruncateNewest,
                    other => {
                        return Err(format!(
                            "fault.kind must be kill_rank, stall_rank, corrupt_newest or truncate_newest, got '{other}'"
                        ))
                    }
                });
            }
        }
        match j.get("transport") {
            None | Some(Json::Null) => {}
            // string shorthand: "inproc", "tcp:HOST:PORT", "unix:PATH"
            Some(Json::Str(s)) => spec.transport = TransportSpec::parse(s)?,
            Some(t) => {
                check_obj_keys(
                    t,
                    "transport",
                    &[
                        "endpoint",
                        "rank",
                        "connect_timeout_ms",
                        "heartbeat_ms",
                        "wait_timeout_ms",
                        "rejoin_grace_ms",
                    ],
                )?;
                let ep = t
                    .get("endpoint")
                    .and_then(Json::as_str)
                    .ok_or("transport.endpoint must be \"inproc\", \"tcp:HOST:PORT\" or \"unix:PATH\"")?;
                let mut tr = TransportSpec::parse(ep)?;
                match t.get("rank") {
                    None | Some(Json::Null) => {}
                    Some(v) => {
                        let r = v.as_f64().ok_or("transport.rank must be a number or null")?;
                        if matches!(tr, TransportSpec::InProc) {
                            return Err(
                                "transport.rank only applies to socket transports".to_string()
                            );
                        }
                        tr = tr_with_rank(tr, r as usize);
                    }
                }
                // tuning values must fit a u32 here (a type-level bound);
                // the [1, one-day] policy range is enforced by `validate`
                // so a bad value is reported as a structured SpecError
                let ms_field = |name: &str| -> Result<Option<u32>, String> {
                    match t.get(name) {
                        None | Some(Json::Null) => Ok(None),
                        Some(v) => {
                            let f = v.as_f64().ok_or_else(|| {
                                format!("transport.{name} must be a number of ms or null")
                            })?;
                            if !(f.is_finite() && (0.0..=u32::MAX as f64).contains(&f)) {
                                return Err(format!(
                                    "transport.{name} must be a u32 number of ms, got {f}"
                                ));
                            }
                            Ok(Some(f as u32))
                        }
                    }
                };
                spec.tuning = TransportTuning {
                    connect_timeout_ms: ms_field("connect_timeout_ms")?,
                    heartbeat_ms: ms_field("heartbeat_ms")?,
                    wait_timeout_ms: ms_field("wait_timeout_ms")?,
                    rejoin_grace_ms: ms_field("rejoin_grace_ms")?,
                };
                spec.transport = tr;
            }
        }
        match j.get("chaos") {
            None | Some(Json::Null) => {}
            Some(c) => {
                check_obj_keys(c, "chaos", &["seed", "rate", "modes"])?;
                // seed: a decimal string (like the top-level seed) or a
                // plain number for hand-written specs
                let seed = match c.get("seed") {
                    None | Some(Json::Null) => {
                        return Err("chaos.seed is required".to_string())
                    }
                    Some(Json::Str(s)) => s
                        .parse::<u64>()
                        .map_err(|_| format!("chaos.seed must be a u64, got '{s}'"))?,
                    Some(v) => v
                        .as_f64()
                        .ok_or("chaos.seed must be a number or decimal string")?
                        as u64,
                };
                let rate = c
                    .get("rate")
                    .and_then(Json::as_f64)
                    .ok_or("chaos.rate must be a number in (0, 1]")?;
                let chaos = match c.get("modes") {
                    None | Some(Json::Null) => ChaosSpec::new(seed, rate),
                    Some(v) => {
                        let arr =
                            v.as_arr().ok_or("chaos.modes must be an array of mode names")?;
                        let mut modes = Vec::with_capacity(arr.len());
                        for m in arr {
                            let s = m
                                .as_str()
                                .ok_or("chaos.modes must be an array of mode names")?;
                            modes.push(ChaosMode::parse(s).ok_or_else(|| {
                                format!(
                                    "unknown chaos mode '{s}' (accepted: delay, stall, drop, \
                                     corrupt, duplicate, partial)"
                                )
                            })?);
                        }
                        ChaosSpec::with_modes(seed, rate, modes)
                    }
                };
                spec.chaos = Some(chaos);
            }
        }
        match j.get("sim") {
            None | Some(Json::Null) => {}
            Some(s) => {
                check_obj_keys(s, "sim", &["machine", "hide_frac", "gd_sweep"])?;
                let machine = s
                    .get("machine")
                    .and_then(Json::as_str)
                    .ok_or("sim.machine must be a string")?
                    .to_string();
                let hide_frac = match s.get("hide_frac") {
                    None | Some(Json::Null) => None,
                    Some(v) => Some(
                        v.as_f64().ok_or("sim.hide_frac must be a number or null")?,
                    ),
                };
                let arr = s
                    .get("gd_sweep")
                    .and_then(Json::as_arr)
                    .ok_or("sim.gd_sweep must be an array of numbers")?;
                let mut gd_sweep = Vec::with_capacity(arr.len());
                for v in arr {
                    // strict: a non-numeric entry is an error, not a
                    // silently shrunken sweep
                    gd_sweep
                        .push(v.as_f64().ok_or("sim.gd_sweep must be an array of numbers")?
                            as usize);
                }
                spec.sim = Some(SimSpec { machine, hide_frac, gd_sweep });
            }
        }
        Ok(spec)
    }

    /// Parse a spec from JSON text.
    pub fn from_json_str(s: &str) -> Result<RunSpec, String> {
        RunSpec::from_json(&Json::parse(s)?)
    }
}

fn tr_with_rank(t: TransportSpec, r: usize) -> TransportSpec {
    match t {
        TransportSpec::Socket { endpoint, .. } => {
            TransportSpec::Socket { endpoint, rank: Some(r) }
        }
        t => t,
    }
}

/// Reject unknown keys inside a nested spec object so a typo'd field
/// (`hide_fraction` for `hide_frac`) errors instead of silently falling
/// back to a default.
fn check_obj_keys(j: &Json, ctx: &str, known: &[&str]) -> Result<(), String> {
    if let Some(o) = j.as_obj() {
        for k in o.keys() {
            if !known.contains(&k.as_str()) {
                return Err(format!("unknown spec field '{ctx}.{k}'"));
            }
        }
    }
    Ok(())
}

/// Canonical CLI/JSON tag of a sampler (the inverse of
/// `SamplerKind::parse`).
pub fn sampler_tag(s: SamplerKind) -> &'static str {
    match s {
        SamplerKind::ScaleGnnUniform => "scalegnn",
        SamplerKind::GraphSage => "graphsage",
        SamplerKind::GraphSaintNode => "graphsaint",
    }
}
