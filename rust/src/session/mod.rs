//! The unified session API: one [`RunSpec`], one [`run`] entry point,
//! streaming [`StepObserver`]s across every engine.
//!
//! ```text
//!   RunSpec ──validate()──► Backend::prepare() ──► Session
//!      │                                             │ step()*
//!      └──to_json/from_json (shareable artifact)     ▼
//!                                        StepReport ──► StepObserver(s)
//!                                                       (Log / Jsonl / Null)
//!                                             │ finish()
//!                                             ▼
//!                                         RunReport
//! ```
//!
//! The repo's four ways of training/projecting the §III–§V pipeline — the
//! PJRT reference trainer, the out-of-core trainer, the rank-thread 4D
//! PMM engine and the analytical simulator — sit behind one front door:
//! build a [`RunSpec`] (or load one with [`RunSpec::from_json`]), pick
//! observers, call [`run`].  The legacy entry points remain as thin
//! internals and a session run is bitwise identical to them for the same
//! spec (`tests/session.rs`).

mod backends;
pub mod observer;
pub mod report;
pub mod spec;

pub use backends::{backend_for, ooc_config, pmm_dims, train_config, Backend, Session};
pub use observer::{JsonlObserver, LogObserver, NullObserver, StepObserver};
pub use report::{
    AxisStats, FailureReport, PmmRunReport, RunReport, SimPoint, SimRunReport, StepReport,
};
pub use spec::{
    sampler_tag, BackendKind, DataSource, FaultSpec, GridSpec, ModelSpec, RunSpec, SimSpec,
    SpecError, TransportSpec, MAX_RANK_THREADS,
};

pub use crate::checkpoint::CheckpointPolicy;

use anyhow::{bail, Result};

/// Validate `spec`, prepare its backend, step it to completion streaming
/// every [`StepReport`] through `observers`, and return the final
/// [`RunReport`].  The canonical entry point behind `scalegnn run --spec`
/// and all the subcommands/examples.
pub fn run(spec: &RunSpec, observers: &mut [Box<dyn StepObserver>]) -> Result<RunReport> {
    if let Err(errs) = spec.validate() {
        let msgs: Vec<String> = errs.iter().map(|e| e.to_string()).collect();
        bail!("invalid spec: {}", msgs.join("; "));
    }
    let t0 = std::time::Instant::now();
    let mut sess = backend_for(spec.backend).prepare(spec)?;
    for o in observers.iter_mut() {
        o.on_start(spec);
    }
    loop {
        let Some(r) = sess.step()? else {
            break; // nothing (left) to stream, e.g. an evaluation-only run
        };
        let done = r.done;
        for o in observers.iter_mut() {
            o.on_step(&r);
        }
        if done {
            break;
        }
    }
    let mut report = sess.finish()?;
    report.wall_s = t0.elapsed().as_secs_f64();
    for o in observers.iter_mut() {
        o.on_finish(&report);
    }
    Ok(report)
}

/// [`run`] with no observers (tests / programmatic use).
pub fn run_silent(spec: &RunSpec) -> Result<RunReport> {
    run(spec, &mut [])
}
