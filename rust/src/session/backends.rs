//! The four [`Backend`] implementations wrapping the existing engines.
//!
//! Each backend turns a validated [`RunSpec`] into a [`Session`] that
//! yields one [`StepReport`] per step.  The training backends run the
//! legacy entry points (`trainer::train_with_progress`,
//! `trainer::train_from_store_with_progress`, the rank-thread PMM loop)
//! on worker threads and stream their [`trainer::StepEvent`]s — the
//! engines themselves are untouched, so a session run is bitwise
//! identical to the legacy entry point for the same spec
//! (`tests/session.rs` asserts this).

use std::collections::BTreeSet;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::checkpoint::{self, CheckpointManager, CheckpointPolicy, Snapshot};
use crate::comm::{
    ChaosSpec, CommError, CommWorld, Endpoint, Precision, TransportTuning, DEFAULT_CHUNK_ELEMS,
};
use crate::graph::{datasets, Dataset};
use crate::grid::{Axis, Grid4D};
use crate::model::GcnDims;
use crate::pmm::{PmmCtx, PmmGcn, PmmTimers};
use crate::sim;
use crate::trainer::{self, OocTrainConfig, OocTrainReport, StepEvent, TrainConfig, TrainReport};
use crate::util::json::{obj, Json};

use super::report::{
    breakdown_json, AxisStats, FailureReport, PmmRunReport, RunReport, SimPoint, SimRunReport,
    StepReport,
};
use super::spec::{BackendKind, DataSource, FaultSpec, RunSpec, TransportSpec};

/// How many times the PMM supervisor will re-form the world and replay
/// from the last checkpoint before declaring the run unrecoverable.
const MAX_PMM_RESTARTS: u64 = 3;

/// Apply a pre-run snapshot fault (`corrupt_newest` / `truncate_newest`)
/// to every rank tag, so the subsequent resume scan must detect the
/// damage and fall back to the previous valid snapshot.
fn apply_snapshot_fault(
    policy: Option<&CheckpointPolicy>,
    fault: FaultSpec,
    tags: &[String],
) -> Result<()> {
    let kind = match fault {
        FaultSpec::CorruptNewest => checkpoint::CorruptKind::FlipPayloadBit,
        FaultSpec::TruncateNewest => checkpoint::CorruptKind::Truncate,
        // armed in the rank loop instead
        FaultSpec::KillRank { .. } | FaultSpec::StallRank { .. } => return Ok(()),
    };
    let p = policy.ok_or_else(|| anyhow!("a snapshot fault requires a checkpoint section"))?;
    for tag in tags {
        let path = checkpoint::corrupt_newest(&p.dir, tag, kind)?;
        eprintln!("[fault] injected {fault:?} into {}", path.display());
    }
    Ok(())
}

/// A prepared, steppable run.
pub trait Session {
    /// Execute (or receive) the next step; `Ok(None)` when the session
    /// has no steps to stream (e.g. an evaluation-only run).
    fn step(&mut self) -> Result<Option<StepReport>>;
    /// Drain the backend and assemble the final [`RunReport`]
    /// (`wall_s` is stamped by [`super::run`]).
    fn finish(self: Box<Self>) -> Result<RunReport>;
}

/// A spec-to-session factory; one implementation per [`BackendKind`].
pub trait Backend {
    /// The kind this backend executes.
    fn kind(&self) -> BackendKind;
    /// Validate-adjacent setup: build the engine(s) for `spec` and return
    /// the steppable session.
    fn prepare(&self, spec: &RunSpec) -> Result<Box<dyn Session>>;
}

/// The backend registered for `kind`.
pub fn backend_for(kind: BackendKind) -> &'static dyn Backend {
    match kind {
        BackendKind::Reference => &ReferenceBackend,
        BackendKind::Ooc => &OocBackend,
        BackendKind::Pmm => &PmmBackend,
        BackendKind::Sim => &SimBackend,
    }
}

/// Translate a streamed [`StepEvent`] into the public [`StepReport`].
/// Anomalies ride along in `detail`: a batch that overflowed the
/// artifact's `edge_cap` reports its dropped-edge count as
/// `truncated_edges` instead of disappearing silently.
fn event_report(ev: StepEvent) -> StepReport {
    let mut fields: Vec<(&str, Json)> = Vec::new();
    if let Some((val, test)) = ev.eval {
        fields.push(("val", Json::from(val as f64)));
        fields.push(("test", Json::from(test as f64)));
    }
    if ev.truncated > 0 {
        fields.push(("truncated_edges", Json::from(ev.truncated)));
    }
    let detail = if fields.is_empty() { Json::Null } else { obj(fields) };
    StepReport {
        step: ev.step,
        loss: ev.loss,
        acc: ev.acc,
        wall_s: ev.wall_s,
        done: ev.done,
        detail,
    }
}

/// Receive the next event from a worker thread, surfacing the worker's
/// error (or panic) when the stream ends without a final event.
fn recv_event<R>(
    rx: &Receiver<StepEvent>,
    handle: &mut Option<JoinHandle<Result<R>>>,
    what: &str,
) -> Result<StepReport> {
    match rx.recv() {
        Ok(ev) => Ok(event_report(ev)),
        Err(_) => {
            let h = handle
                .take()
                .ok_or_else(|| anyhow!("{what} worker already joined"))?;
            match h.join() {
                Ok(Ok(_)) => bail!("{what} worker ended without a final step event"),
                Ok(Err(e)) => Err(e),
                Err(_) => bail!("{what} worker thread panicked"),
            }
        }
    }
}

fn join_worker<R>(handle: Option<JoinHandle<Result<R>>>, what: &str) -> Result<R> {
    handle
        .ok_or_else(|| anyhow!("{what} worker already joined"))?
        .join()
        .map_err(|_| anyhow!("{what} worker thread panicked"))?
}

// ---------------------------------------------------------------------------
// Reference backend (PJRT trainer)
// ---------------------------------------------------------------------------

/// `trainer::train` behind the session API.
struct ReferenceBackend;

struct ReferenceSession {
    rx: Receiver<StepEvent>,
    handle: Option<JoinHandle<Result<TrainReport>>>,
}

/// Build the legacy `TrainConfig` a spec maps onto (public-in-crate so the
/// bitwise-identity tests compare against exactly this mapping).
pub fn train_config(spec: &RunSpec) -> TrainConfig {
    let mut cfg = TrainConfig::quick(&spec.dataset, spec.sampler);
    cfg.dp = spec.grid.gd;
    cfg.lr = spec.lr;
    cfg.seed = spec.seed;
    cfg.prefetch = spec.prefetch;
    cfg.artifacts = spec.artifacts.clone();
    cfg.max_steps = spec.steps;
    cfg.max_epochs = spec.epochs;
    cfg.target_acc = spec.target_acc;
    cfg.eval_every_epochs = spec.eval_every_epochs.max(1);
    cfg.bf16_dp = spec.precision == Precision::Bf16;
    cfg.overlap = spec.overlap;
    cfg.verbose = false; // observers replace verbose printing
    cfg.checkpoint = spec.checkpoint.clone();
    cfg.resume = spec.resume;
    cfg
}

impl Backend for ReferenceBackend {
    fn kind(&self) -> BackendKind {
        BackendKind::Reference
    }

    fn prepare(&self, spec: &RunSpec) -> Result<Box<dyn Session>> {
        let cfg = train_config(spec);
        if let Some(f) = spec.fault {
            let tags: Vec<String> = (0..spec.grid.gd).map(|g| format!("ref-g{g}")).collect();
            apply_snapshot_fault(spec.checkpoint.as_ref(), f, &tags)?;
        }
        let (tx, rx) = channel();
        // PJRT clients are per-thread; the whole legacy entry point moves
        // to a coordinator thread and streams its group-0 events back
        let handle = std::thread::spawn(move || trainer::train_with_progress(&cfg, Some(tx)));
        Ok(Box::new(ReferenceSession { rx, handle: Some(handle) }))
    }
}

impl Session for ReferenceSession {
    fn step(&mut self) -> Result<Option<StepReport>> {
        recv_event(&self.rx, &mut self.handle, "reference").map(Some)
    }

    fn finish(mut self: Box<Self>) -> Result<RunReport> {
        let r = join_worker(self.handle.take(), "reference")?;
        Ok(RunReport {
            backend: Some(BackendKind::Reference),
            steps: r.steps,
            final_loss: r.final_loss,
            loss_curve: r.loss_curve.clone(),
            trainer: Some(r),
            ..RunReport::default()
        })
    }
}

// ---------------------------------------------------------------------------
// Out-of-core backend
// ---------------------------------------------------------------------------

/// `trainer::train_from_store` behind the session API.
struct OocBackend;

struct OocSession {
    rx: Receiver<StepEvent>,
    handle: Option<JoinHandle<Result<OocTrainReport>>>,
}

/// Build the legacy `OocTrainConfig` a spec maps onto.
pub fn ooc_config(spec: &RunSpec) -> OocTrainConfig {
    let store = match &spec.source {
        DataSource::Ooc { store } => store.clone(),
        DataSource::Mem => unreachable!("validate() rejects a mem source on the ooc backend"),
    };
    let mut cfg = OocTrainConfig::quick(store);
    cfg.dataset = Some(spec.dataset.clone());
    cfg.cache_bytes = spec.cache_mb << 20;
    cfg.batch = spec.batch.unwrap_or(cfg.batch);
    cfg.d_h = spec.model.d_h;
    cfg.layers = spec.model.layers;
    cfg.steps = spec.steps;
    cfg.lr = spec.lr;
    cfg.seed = spec.seed;
    cfg.prefetch = spec.prefetch;
    cfg.verbose = false;
    cfg.checkpoint = spec.checkpoint.clone();
    cfg.resume = spec.resume;
    cfg
}

impl Backend for OocBackend {
    fn kind(&self) -> BackendKind {
        BackendKind::Ooc
    }

    fn prepare(&self, spec: &RunSpec) -> Result<Box<dyn Session>> {
        let cfg = ooc_config(spec);
        if let Some(f) = spec.fault {
            apply_snapshot_fault(spec.checkpoint.as_ref(), f, &["ooc".to_string()])?;
        }
        let (tx, rx) = channel();
        let handle =
            std::thread::spawn(move || trainer::train_from_store_with_progress(&cfg, Some(tx)));
        Ok(Box::new(OocSession { rx, handle: Some(handle) }))
    }
}

impl Session for OocSession {
    fn step(&mut self) -> Result<Option<StepReport>> {
        recv_event(&self.rx, &mut self.handle, "ooc").map(Some)
    }

    fn finish(mut self: Box<Self>) -> Result<RunReport> {
        let r = join_worker(self.handle.take(), "ooc")?;
        Ok(RunReport {
            backend: Some(BackendKind::Ooc),
            steps: r.steps,
            final_loss: r.final_loss,
            loss_curve: r.loss_curve.clone(),
            ooc: Some(r),
            ..RunReport::default()
        })
    }
}

// ---------------------------------------------------------------------------
// PMM backend (rank-thread 4D engine)
// ---------------------------------------------------------------------------

/// The rank-thread 4D PMM engine behind the session API, wrapped in an
/// elastic supervisor: every rank body runs under `catch_unwind`, a
/// failed collective surfaces as a structured [`CommError`] origin
/// (rank/seq/op/axis), and when a rank dies the session joins the world,
/// re-forms it and replays from the newest checkpoint step every rank has
/// a valid snapshot for.
struct PmmBackend;

type PmmRankOut = (PmmTimers, (f32, f32), Option<(f32, f32)>);

/// Why a rank thread ended without completing its steps.
enum RankFailure {
    /// A collective died — locally or via the poison cascade; the payload
    /// carries the *origin* (rank/seq/op/axis) unchanged.
    Comm(CommError),
    /// A non-comm error or panic on the given rank.
    Other(usize, String),
}

/// Everything needed to (re)spawn the rank threads — kept by the session
/// so recovery can re-form the world from the last checkpoint.
#[derive(Clone)]
struct PmmRunCfg {
    grid: Grid4D,
    data: Arc<Dataset>,
    dims: GcnDims,
    batch: usize,
    steps: u64,
    lr: f32,
    seed: u64,
    prec: Precision,
    overlap: bool,
    final_eval: bool,
    ckpt: Option<CheckpointPolicy>,
    tuning: TransportTuning,
    chaos: Option<ChaosSpec>,
}

/// Per-rank run-configuration hash stored in every snapshot header, so a
/// resume refuses state from a different grid/model/seed/shard.
fn pmm_spec_hash(cfg: &PmmRunCfg, rank: usize) -> u64 {
    checkpoint::state_hash(&[
        0x504D_4D00, // backend tag "PMM"
        cfg.seed,
        cfg.dims.state_signature(),
        cfg.batch as u64,
        cfg.lr.to_bits() as u64,
        cfg.grid.gd as u64,
        cfg.grid.gx as u64,
        cfg.grid.gy as u64,
        cfg.grid.gz as u64,
        rank as u64,
    ])
}

/// The newest step every rank has a valid snapshot for, plus the loaded
/// (hash-checked) per-rank snapshots.  Torn/corrupt files are skipped
/// with a warning — the whole point of the fallback path.
fn pmm_resume_point(cfg: &PmmRunCfg) -> Result<(u64, Vec<Option<Snapshot>>)> {
    let policy = cfg
        .ckpt
        .as_ref()
        .ok_or_else(|| anyhow!("resume requires a checkpoint section"))?;
    let n = cfg.grid.world_size();
    let mut common: Option<BTreeSet<u64>> = None;
    for r in 0..n {
        let (steps, warnings) = checkpoint::valid_steps(&policy.dir, &format!("pmm-r{r}"));
        for w in warnings {
            eprintln!("warning: {w}");
        }
        let set: BTreeSet<u64> = steps.into_iter().collect();
        common = Some(match common {
            None => set,
            Some(c) => c.intersection(&set).copied().collect(),
        });
    }
    let step = common.and_then(|c| c.into_iter().next_back()).ok_or_else(|| {
        anyhow!(
            "no snapshot step is valid across all {n} rank(s) under {}",
            policy.dir.display()
        )
    })?;
    let mut snaps = Vec::with_capacity(n);
    for r in 0..n {
        let path = checkpoint::path_for(&policy.dir, &format!("pmm-r{r}"), step);
        let snap = checkpoint::load(&path)?;
        snap.check_hash(pmm_spec_hash(cfg, r), &format!("pmm rank {r}"))?;
        snaps.push(Some(snap));
    }
    Ok((step, snaps))
}

/// One rank's training body, `start..cfg.steps` — shared verbatim by the
/// in-process thread-per-rank world and the one-rank-per-process socket
/// world, so the two transports execute the identical step/checkpoint/
/// kill logic (the basis of the bitwise-identity guarantee).
fn run_pmm_rank(
    cfg: &PmmRunCfg,
    world: &CommWorld,
    r: usize,
    tx: Option<&Sender<StepEvent>>,
    start: u64,
    snap: Option<&Snapshot>,
    fault: Option<FaultSpec>,
) -> Result<PmmRankOut> {
    let hash = pmm_spec_hash(cfg, r);
    let ckpt = cfg
        .ckpt
        .as_ref()
        .map(|p| CheckpointManager::new(p.clone(), &format!("pmm-r{r}")));
    let ctx = PmmCtx::new(cfg.grid, r, world, cfg.prec);
    let mut eng = PmmGcn::new(ctx, cfg.dims, cfg.batch, cfg.data.clone(), cfg.seed);
    eng.set_overlap(cfg.overlap);
    if let Some(snap) = snap {
        eng.restore_state(&snap.tensors, &snap.m, &snap.v, snap.t)?;
    }
    let mut last = (0.0f32, 0.0f32);
    for s in start..cfg.steps {
        match fault {
            Some(FaultSpec::KillRank { rank: kr, step: ks }) if r == kr && s == ks => {
                // dies before issuing any step-s collective, so
                // no peer can reach a later save barrier (they
                // all stall inside step s's poisoned waits)
                world.fail(r, &format!("scripted fault: kill rank {kr} at step {ks}"));
            }
            Some(FaultSpec::StallRank { rank: sr, step: ss, ms }) if r == sr && s == ss => {
                // go silent without dying: no death notification is
                // ever sent, so only the deadline discipline can
                // detect this rank and poison the world as Stalled
                eprintln!("[fault] rank {sr} stalling {ms} ms at step {ss}");
                std::thread::sleep(Duration::from_millis(ms));
            }
            _ => {}
        }
        let t0 = Instant::now();
        let o = eng.train_step(s, cfg.lr);
        last = (o.loss, o.acc);
        if let Some(tx) = tx {
            let _ = tx.send(StepEvent {
                step: s,
                loss: o.loss,
                acc: o.acc,
                wall_s: t0.elapsed().as_secs_f64(),
                eval: None,
                truncated: 0,
                done: s + 1 == cfg.steps,
            });
        }
        if let Some(mgr) = &ckpt {
            if mgr.should_save(s) {
                // shard-consistent save: every rank finishes
                // step s (all collectives drained) before any
                // shard is written, so the per-rank snapshot
                // set forms one world-wide state
                for ax in [Axis::X, Axis::Y, Axis::Z, Axis::Dp] {
                    world.barrier(r, ax);
                }
                let (tensors, m, v, t) = eng.export_state();
                mgr.save(&Snapshot::from_flat(s + 1, cfg.seed, hash, tensors, m, v, t))?;
            }
        }
    }
    let eval = cfg.final_eval.then(|| eng.eval_full_graph());
    Ok((eng.timers, last, eval))
}

/// Run `f` under `catch_unwind`, classifying any unwind into a
/// structured [`RankFailure`]: a poisoned collective's `CommError`
/// payload is carried through unchanged (preserving the *origin*
/// rank/seq/op/axis), everything else becomes `Other`.
fn catch_rank<F>(r: usize, f: F) -> Result<PmmRankOut, RankFailure>
where
    F: FnOnce() -> Result<PmmRankOut>,
{
    match catch_unwind(AssertUnwindSafe(f)) {
        Ok(Ok(v)) => Ok(v),
        Ok(Err(e)) => Err(RankFailure::Other(r, format!("{e:#}"))),
        Err(payload) => Err(match payload.downcast_ref::<CommError>() {
            Some(ce) => RankFailure::Comm(ce.clone()),
            None => {
                let msg = payload
                    .downcast_ref::<&str>()
                    .map(|s| (*s).to_string())
                    .or_else(|| payload.downcast_ref::<String>().cloned())
                    .unwrap_or_else(|| "opaque panic payload".to_string());
                RankFailure::Other(r, msg)
            }
        }),
    }
}

/// Spawn one thread per rank, running `start..cfg.steps`.  Each body runs
/// under `catch_unwind` so a poisoned collective (or any panic) joins as
/// a structured [`RankFailure`] instead of an opaque unwind; `fault` arms
/// the deterministic `kill_rank` / `stall_rank` injection.
fn spawn_pmm_ranks(
    cfg: &PmmRunCfg,
    world: &Arc<CommWorld>,
    tx: Sender<StepEvent>,
    start: u64,
    mut snaps: Vec<Option<Snapshot>>,
    fault: Option<FaultSpec>,
) -> Vec<JoinHandle<Result<PmmRankOut, RankFailure>>> {
    let mut handles = Vec::with_capacity(cfg.grid.world_size());
    for r in 0..cfg.grid.world_size() {
        let w = world.clone();
        let cfg = cfg.clone();
        let tx = if r == 0 { Some(tx.clone()) } else { None };
        let snap = snaps[r].take();
        handles.push(std::thread::spawn(move || {
            catch_rank(r, || run_pmm_rank(&cfg, &w, r, tx.as_ref(), start, snap.as_ref(), fault))
        }));
    }
    handles
}

struct PmmSession {
    rx: Receiver<StepEvent>,
    handles: Vec<JoinHandle<Result<PmmRankOut, RankFailure>>>,
    world: Arc<CommWorld>,
    ranks: usize,
    steps: u64,
    loss_curve: Vec<(u64, f32)>,
    cfg: PmmRunCfg,
    failures: Vec<FailureReport>,
    restarts: u64,
}

/// The reference-model dims a spec maps onto for the PMM engine.
pub fn pmm_dims(spec: &RunSpec) -> GcnDims {
    let ds = datasets::spec(&spec.dataset).expect("validate() checked the dataset");
    GcnDims {
        d_in: ds.planted.d_in,
        d_h: spec.model.d_h,
        d_out: ds.planted.classes,
        layers: spec.model.layers,
        dropout: spec.model.dropout,
        weight_decay: 0.0,
    }
}

impl Backend for PmmBackend {
    fn kind(&self) -> BackendKind {
        BackendKind::Pmm
    }

    fn prepare(&self, spec: &RunSpec) -> Result<Box<dyn Session>> {
        let grid = Grid4D::from(spec.grid);
        let data = Arc::new(
            datasets::load(&spec.dataset)
                .ok_or_else(|| anyhow!("unknown dataset {}", spec.dataset))?,
        );
        let ds = datasets::spec(&spec.dataset).unwrap();
        let cfg = PmmRunCfg {
            grid,
            data,
            dims: pmm_dims(spec),
            batch: spec.batch.unwrap_or(ds.batch),
            steps: spec.steps,
            lr: spec.lr,
            seed: spec.seed,
            prec: spec.precision,
            overlap: spec.overlap,
            final_eval: spec.final_eval,
            ckpt: spec.checkpoint.clone(),
            tuning: spec.tuning,
            chaos: spec.chaos.clone(),
        };
        if let Some(fault) = spec.fault {
            let tags: Vec<String> =
                (0..grid.world_size()).map(|r| format!("pmm-r{r}")).collect();
            apply_snapshot_fault(cfg.ckpt.as_ref(), fault, &tags)?;
        }
        let fault = match spec.fault {
            f @ Some(FaultSpec::KillRank { .. } | FaultSpec::StallRank { .. }) => f,
            _ => None,
        };
        let (start, snaps) = if spec.resume {
            pmm_resume_point(&cfg)?
        } else {
            (0, vec![None; grid.world_size()])
        };
        if cfg.steps > 0 && start >= cfg.steps {
            bail!(
                "the snapshot already covers step {start} of {}; nothing left to resume \
                 (raise 'steps' to continue training)",
                cfg.steps
            );
        }
        if let TransportSpec::Socket { endpoint, rank } = &spec.transport {
            let rank = rank.ok_or_else(|| {
                anyhow!(
                    "socket transport needs the rank this process runs \
                     (--rank R or transport.rank in the spec)"
                )
            })?;
            let mut snaps = snaps;
            let snap = snaps[rank].take();
            let world = Arc::new(CommWorld::connect_with(
                grid,
                rank,
                endpoint,
                &cfg.tuning,
                cfg.chaos.as_ref(),
            )?);
            let (tx, rx) = channel();
            let (w, cfg2) = (world.clone(), cfg.clone());
            let handle = std::thread::spawn(move || {
                catch_rank(rank, || {
                    run_pmm_rank(&cfg2, &w, rank, Some(&tx), start, snap.as_ref(), fault)
                })
            });
            return Ok(Box::new(SocketPmmSession {
                rx,
                handle: Some(handle),
                world,
                rank,
                endpoint: endpoint.clone(),
                steps: cfg.steps,
                loss_curve: Vec::new(),
                cfg,
                failures: Vec::new(),
                restarts: 0,
            }));
        }
        let world = Arc::new(CommWorld::with_tuning(
            grid,
            DEFAULT_CHUNK_ELEMS,
            &cfg.tuning,
            cfg.chaos.as_ref(),
        ));
        let (tx, rx) = channel();
        let handles = spawn_pmm_ranks(&cfg, &world, tx, start, snaps, fault);
        Ok(Box::new(PmmSession {
            rx,
            handles,
            world,
            ranks: grid.world_size(),
            steps: cfg.steps,
            loss_curve: Vec::new(),
            cfg,
            failures: Vec::new(),
            restarts: 0,
        }))
    }
}

impl PmmSession {
    /// Join the dead world, extract the failure origin, and — when a
    /// checkpoint policy exists — re-form the world replaying from the
    /// newest step every rank has a valid snapshot for.
    fn recover(&mut self) -> Result<()> {
        let mut failures = Vec::new();
        for h in self.handles.drain(..) {
            match h.join() {
                Ok(Ok(_)) => {}
                Ok(Err(f)) => failures.push(f),
                Err(_) => {
                    failures.push(RankFailure::Other(
                        usize::MAX,
                        "rank panicked outside the harness".to_string(),
                    ));
                }
            }
        }
        if failures.is_empty() {
            // all ranks returned cleanly yet rank 0 never sent `done` —
            // a logic error, not something a restart can fix
            bail!("pmm worker ended without a final step event");
        }
        let mut report = None;
        for f in &failures {
            if let RankFailure::Comm(e) = f {
                report = Some(FailureReport {
                    rank: e.rank,
                    seq: e.seq,
                    op: e.op.to_string(),
                    axis: format!("{:?}", e.axis).to_lowercase(),
                    message: e.msg.clone(),
                    resumed_from_step: None,
                });
                break;
            }
        }
        let mut report = report.unwrap_or_else(|| {
            let (rank, msg) = match &failures[0] {
                RankFailure::Other(r, m) => (*r, m.clone()),
                RankFailure::Comm(_) => unreachable!("comm failures handled above"),
            };
            FailureReport {
                rank,
                seq: 0,
                op: "panic".to_string(),
                axis: String::new(),
                message: msg,
                resumed_from_step: None,
            }
        });
        let origin = format!(
            "rank {} died in {} (seq {}, axis '{}'): {}",
            report.rank, report.op, report.seq, report.axis, report.message
        );
        if self.cfg.ckpt.is_none() {
            bail!("pmm rank failed with no checkpoint to recover from: {origin}");
        }
        if self.restarts >= MAX_PMM_RESTARTS {
            bail!("giving up after {MAX_PMM_RESTARTS} recovery attempts: {origin}");
        }
        let (start, snaps) = pmm_resume_point(&self.cfg)
            .with_context(|| format!("recovering from: {origin}"))?;
        // re-streamed steps replace anything recorded past the snapshot
        self.loss_curve.retain(|&(s, _)| s < start);
        report.resumed_from_step = Some(start);
        eprintln!("[recover] {origin}; replaying from step {start}");
        self.failures.push(report);
        self.restarts += 1;
        // chaos is disarmed on replay, like the scripted fault below: the
        // recovered run must converge to the clean curve, not re-roll the
        // same schedule and die again
        let world = Arc::new(CommWorld::with_tuning(
            self.cfg.grid,
            DEFAULT_CHUNK_ELEMS,
            &self.cfg.tuning,
            None,
        ));
        let (tx, rx) = channel();
        // the scripted fault is disarmed on replay: a real cluster's
        // deterministic fault does not re-fire after the rank is replaced
        self.handles = spawn_pmm_ranks(&self.cfg, &world, tx, start, snaps, None);
        self.world = world;
        self.rx = rx;
        Ok(())
    }
}

impl Session for PmmSession {
    fn step(&mut self) -> Result<Option<StepReport>> {
        if self.steps == 0 {
            // evaluation-only session: no training steps to stream
            return Ok(None);
        }
        loop {
            match self.rx.recv() {
                Ok(ev) => {
                    self.loss_curve.push((ev.step, ev.loss));
                    return Ok(Some(event_report(ev)));
                }
                // rank 0's sender dropped before `done`: a rank died (the
                // poison cascade guarantees rank 0 is among the casualties)
                Err(_) => self.recover()?,
            }
        }
    }

    fn finish(self: Box<Self>) -> Result<RunReport> {
        let this = *self;
        let mut timers = PmmTimers::default();
        let mut last = None;
        let mut eval = None;
        for h in this.handles {
            let (t, l, e) = match h.join() {
                Ok(Ok(v)) => v,
                Ok(Err(RankFailure::Comm(e))) => bail!(
                    "pmm rank {} died in {} (seq {}, axis {:?}): {}",
                    e.rank,
                    e.op,
                    e.seq,
                    e.axis,
                    e.msg
                ),
                Ok(Err(RankFailure::Other(r, m))) => bail!("pmm rank {r} failed: {m}"),
                Err(_) => bail!("pmm rank thread panicked outside the harness"),
            };
            timers.add(&t);
            // rank 0 joins first; keep ITS values so final_loss/final_acc
            // agree with the streamed loss_curve (DP groups draw distinct
            // batches, so other ranks report different losses)
            last.get_or_insert(l);
            eval = eval.or(e);
        }
        let last = last.unwrap_or((f32::NAN, f32::NAN));
        let n = this.ranks as f64;
        let timers_mean = PmmTimers {
            sampling: timers.sampling / n,
            spmm: timers.spmm / n,
            gemm: timers.gemm / n,
            elementwise: timers.elementwise / n,
            tp_comm: timers.tp_comm / n,
            dp_comm: timers.dp_comm / n,
            reshard: timers.reshard / n,
            other: timers.other / n,
        };
        let axes = axis_stats_checked(&this.world, 0)
            .map_err(|e| anyhow!("pmm world poisoned at finish: {e}"))?;
        Ok(RunReport {
            backend: Some(BackendKind::Pmm),
            steps: this.loss_curve.len() as u64,
            final_loss: last.0,
            loss_curve: this.loss_curve,
            failures: this.failures,
            restarts: this.restarts,
            pmm: Some(PmmRunReport {
                final_acc: last.1,
                timers_mean,
                axes,
                tp_hidden_frac: this.world.tp_hidden_fraction(),
                eval,
            }),
            ..RunReport::default()
        })
    }
}

/// Per-axis traffic/timing snapshot for the final report, read through
/// the *checked* queries: a poisoned world answers with its failure
/// origin instead of misleading half-recorded numbers.
fn axis_stats_checked(world: &CommWorld, rank: usize) -> Result<Vec<AxisStats>, CommError> {
    [(Axis::X, "x"), (Axis::Y, "y"), (Axis::Z, "z"), (Axis::Dp, "dp")]
        .into_iter()
        .map(|(ax, name)| {
            let (ops, bytes) = world.stats_checked(rank, ax)?;
            let (comm_s, blocked_s) = world.timing_checked(rank, ax)?;
            Ok(AxisStats {
                axis: name,
                ops,
                bytes,
                comm_s,
                blocked_s,
                hidden_frac: world.hidden_fraction_checked(rank, ax)?,
            })
        })
        .collect()
}

/// One rank of a multi-process PMM world, attached to a coordinator over
/// a [`TransportSpec::Socket`] endpoint.  When the coordinator offers a
/// rejoin window (`rejoin_grace_ms > 0`) and a checkpoint exists, a world
/// failure re-registers this rank into the coordinator's next generation
/// and replays from the newest common snapshot; otherwise the failure
/// surfaces as a structured error naming the origin and the run is
/// relaunched (optionally with `resume` from the shared checkpoint dir).
struct SocketPmmSession {
    rx: Receiver<StepEvent>,
    handle: Option<JoinHandle<Result<PmmRankOut, RankFailure>>>,
    world: Arc<CommWorld>,
    rank: usize,
    endpoint: Endpoint,
    steps: u64,
    loss_curve: Vec<(u64, f32)>,
    cfg: PmmRunCfg,
    failures: Vec<FailureReport>,
    restarts: u64,
}

impl SocketPmmSession {
    /// Join the worker after its event channel closed early and return
    /// the structured failure it died of.
    fn join_failure(&mut self) -> Result<RankFailure> {
        match self.handle.take().map(JoinHandle::join) {
            Some(Ok(Ok(_))) => {
                bail!("pmm rank {} ended without a final step event", self.rank)
            }
            Some(Ok(Err(f))) => Ok(f),
            Some(Err(_)) => bail!("pmm rank thread panicked outside the harness"),
            None => bail!("pmm rank worker already joined"),
        }
    }

    /// Recover from a dead world: when the coordinator holds this rank's
    /// slot open (a rejoin was offered, or a grace window is configured)
    /// and snapshots exist, re-register into the next world generation
    /// and replay from the newest common step; otherwise surface the
    /// structured origin (the coordinator separately reports the same
    /// origin and the run is relaunched by hand).
    fn recover(&mut self) -> Result<()> {
        let failure = self.join_failure()?;
        let mut report = match &failure {
            RankFailure::Comm(e) => FailureReport {
                rank: e.rank,
                seq: e.seq,
                op: e.op.to_string(),
                axis: format!("{:?}", e.axis).to_lowercase(),
                message: e.msg.clone(),
                resumed_from_step: None,
            },
            RankFailure::Other(r, m) => FailureReport {
                rank: *r,
                seq: 0,
                op: "panic".to_string(),
                axis: String::new(),
                message: m.clone(),
                resumed_from_step: None,
            },
        };
        let origin = format!(
            "rank {} died in {} (seq {}, axis '{}'): {}",
            report.rank, report.op, report.seq, report.axis, report.message
        );
        let offered = self.world.rejoin_offered(self.rank)
            || self.cfg.tuning.rejoin_grace() > Duration::ZERO;
        if !offered {
            bail!(
                "pmm {origin} (relaunch the coordinator and all ranks, with --resume \
                 to replay from the shared checkpoint dir)"
            );
        }
        if self.cfg.ckpt.is_none() {
            bail!("pmm rank failed with no checkpoint to rejoin from: {origin}");
        }
        if self.restarts >= MAX_PMM_RESTARTS {
            bail!("giving up after {MAX_PMM_RESTARTS} rejoin attempts: {origin}");
        }
        let (start, mut snaps) = pmm_resume_point(&self.cfg)
            .with_context(|| format!("rejoining after: {origin}"))?;
        self.loss_curve.retain(|&(s, _)| s < start);
        report.resumed_from_step = Some(start);
        eprintln!(
            "[rejoin] rank {}: {origin}; re-registering and replaying from step {start}",
            self.rank
        );
        self.failures.push(report);
        self.restarts += 1;
        let snap = snaps[self.rank].take();
        // chaos and the scripted fault are disarmed on rejoin, like the
        // in-process recovery: the replayed run must converge to the
        // clean curve, not re-fire and die again
        let world = Arc::new(CommWorld::connect_with(
            self.cfg.grid,
            self.rank,
            &self.endpoint,
            &self.cfg.tuning,
            None,
        )?);
        let (tx, rx) = channel();
        let (w, cfg2, rank) = (world.clone(), self.cfg.clone(), self.rank);
        self.handle = Some(std::thread::spawn(move || {
            catch_rank(rank, || {
                run_pmm_rank(&cfg2, &w, rank, Some(&tx), start, snap.as_ref(), None)
            })
        }));
        self.world = world;
        self.rx = rx;
        Ok(())
    }
}

impl Session for SocketPmmSession {
    fn step(&mut self) -> Result<Option<StepReport>> {
        if self.steps == 0 {
            return Ok(None);
        }
        loop {
            match self.rx.recv() {
                Ok(ev) => {
                    self.loss_curve.push((ev.step, ev.loss));
                    return Ok(Some(event_report(ev)));
                }
                // the worker's sender dropped before `done`: the world
                // died (locally or via the poison cascade) — rejoin if
                // the coordinator holds our slot, else surface the origin
                Err(_) => self.recover()?,
            }
        }
    }

    fn finish(self: Box<Self>) -> Result<RunReport> {
        let mut this = *self;
        let (timers, last, eval) = match this.handle.take() {
            Some(h) => match h.join() {
                Ok(Ok(v)) => v,
                Ok(Err(RankFailure::Comm(e))) => bail!(
                    "pmm rank {} died in {} (seq {}, axis {:?}): {}",
                    e.rank,
                    e.op,
                    e.seq,
                    e.axis,
                    e.msg
                ),
                Ok(Err(RankFailure::Other(r, m))) => bail!("pmm rank {r} failed: {m}"),
                Err(_) => bail!("pmm rank thread panicked outside the harness"),
            },
            None => bail!("pmm rank worker already joined"),
        };
        // single-rank report: timers are this rank's own (no cross-rank
        // mean is possible from inside one process), and the loss curve
        // is this rank's stream — rank 0's matches the in-process run
        let axes = axis_stats_checked(&this.world, this.rank)
            .map_err(|e| anyhow!("pmm world poisoned at finish: {e}"))?;
        Ok(RunReport {
            backend: Some(BackendKind::Pmm),
            steps: this.loss_curve.len() as u64,
            final_loss: last.0,
            loss_curve: this.loss_curve,
            failures: this.failures,
            restarts: this.restarts,
            pmm: Some(PmmRunReport {
                final_acc: last.1,
                timers_mean: timers,
                axes,
                tp_hidden_frac: this.world.tp_hidden_fraction(),
                eval,
            }),
            ..RunReport::default()
        })
    }
}

// ---------------------------------------------------------------------------
// Sim backend (analytical projections)
// ---------------------------------------------------------------------------

/// `sim::scalegnn_epoch_with` behind the session API: one step per
/// `gd_sweep` entry.
struct SimBackend;

struct SimSession {
    w: sim::Workload,
    machine: sim::Machine,
    opts: sim::OptFlags,
    hide_frac: f64,
    base: (usize, usize, usize),
    sweep: Vec<usize>,
    i: usize,
    points: Vec<SimPoint>,
}

impl Backend for SimBackend {
    fn kind(&self) -> BackendKind {
        BackendKind::Sim
    }

    fn prepare(&self, spec: &RunSpec) -> Result<Box<dyn Session>> {
        let s = spec.sim.as_ref().expect("validate() requires the sim section");
        let ds = datasets::spec(&spec.dataset)
            .ok_or_else(|| anyhow!("unknown dataset {}", spec.dataset))?;
        let machine = sim::by_name(&s.machine)
            .ok_or_else(|| anyhow!("unknown machine {}", s.machine))?;
        Ok(Box::new(SimSession {
            w: sim::Workload::from_spec(&ds, spec.model.d_h as f64, spec.model.layers as f64),
            machine,
            opts: sim::OptFlags {
                prefetch: spec.prefetch,
                bf16: spec.precision == crate::comm::Precision::Bf16,
                fusion: true,
                overlap: spec.overlap,
            },
            hide_frac: s.hide_frac.unwrap_or(sim::DEFAULT_OVERLAP_HIDE_FRAC),
            base: (spec.grid.gx, spec.grid.gy, spec.grid.gz),
            sweep: s.gd_sweep.clone(),
            i: 0,
            points: Vec::new(),
        }))
    }
}

impl Session for SimSession {
    fn step(&mut self) -> Result<Option<StepReport>> {
        if self.i >= self.sweep.len() {
            return Ok(None);
        }
        let (x, y, z) = self.base;
        let gd = self.sweep[self.i];
        let grid = Grid4D::new(gd, x, y, z);
        let b = sim::scalegnn_epoch_with(&self.w, &self.machine, grid, self.opts, self.hide_frac);
        let point = SimPoint { gd, devices: grid.world_size(), breakdown: b };
        let detail = obj(vec![
            ("gd", Json::from(gd)),
            ("devices", Json::from(point.devices)),
            ("breakdown", breakdown_json(&b)),
        ]);
        self.points.push(point);
        let report = StepReport {
            step: self.i as u64,
            loss: f32::NAN,
            acc: f32::NAN,
            wall_s: b.total(),
            done: self.i + 1 == self.sweep.len(),
            detail,
        };
        self.i += 1;
        Ok(Some(report))
    }

    fn finish(self: Box<Self>) -> Result<RunReport> {
        let this = *self;
        Ok(RunReport {
            backend: Some(BackendKind::Sim),
            steps: this.points.len() as u64,
            final_loss: f32::NAN,
            sim: Some(SimRunReport {
                machine: this.machine.name.to_string(),
                hide_frac: this.hide_frac,
                points: this.points,
            }),
            ..RunReport::default()
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn event_report_surfaces_truncated_edges() {
        let ev = StepEvent {
            step: 3,
            loss: 1.0,
            acc: f32::NAN,
            wall_s: 0.1,
            eval: None,
            truncated: 17,
            done: false,
        };
        let r = event_report(ev);
        assert_eq!(r.detail.get("truncated_edges").and_then(Json::as_f64), Some(17.0));
        // a clean step keeps a Null detail (JSONL stays compact)
        let clean = StepEvent { truncated: 0, ..ev };
        assert_eq!(event_report(clean).detail, Json::Null);
    }

    #[test]
    fn event_report_keeps_eval_detail() {
        let ev = StepEvent {
            step: 0,
            loss: 0.5,
            acc: 0.9,
            wall_s: 0.2,
            eval: Some((0.7, 0.6)),
            truncated: 2,
            done: true,
        };
        let r = event_report(ev);
        assert_eq!(r.detail.get("val").and_then(Json::as_f64), Some(0.7f32 as f64));
        assert_eq!(r.detail.get("test").and_then(Json::as_f64), Some(0.6f32 as f64));
        assert_eq!(r.detail.get("truncated_edges").and_then(Json::as_f64), Some(2.0));
    }
}
