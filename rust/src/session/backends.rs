//! The four [`Backend`] implementations wrapping the existing engines.
//!
//! Each backend turns a validated [`RunSpec`] into a [`Session`] that
//! yields one [`StepReport`] per step.  The training backends run the
//! legacy entry points (`trainer::train_with_progress`,
//! `trainer::train_from_store_with_progress`, the rank-thread PMM loop)
//! on worker threads and stream their [`trainer::StepEvent`]s — the
//! engines themselves are untouched, so a session run is bitwise
//! identical to the legacy entry point for the same spec
//! (`tests/session.rs` asserts this).

use std::sync::mpsc::{channel, Receiver};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{anyhow, bail, Result};

use crate::comm::CommWorld;
use crate::graph::datasets;
use crate::grid::{Axis, Grid4D};
use crate::model::GcnDims;
use crate::pmm::{PmmCtx, PmmGcn, PmmTimers};
use crate::sim;
use crate::trainer::{self, OocTrainConfig, OocTrainReport, StepEvent, TrainConfig, TrainReport};
use crate::util::json::{obj, Json};

use super::report::{
    breakdown_json, AxisStats, PmmRunReport, RunReport, SimPoint, SimRunReport, StepReport,
};
use super::spec::{BackendKind, DataSource, RunSpec};

/// A prepared, steppable run.
pub trait Session {
    /// Execute (or receive) the next step; `Ok(None)` when the session
    /// has no steps to stream (e.g. an evaluation-only run).
    fn step(&mut self) -> Result<Option<StepReport>>;
    /// Drain the backend and assemble the final [`RunReport`]
    /// (`wall_s` is stamped by [`super::run`]).
    fn finish(self: Box<Self>) -> Result<RunReport>;
}

/// A spec-to-session factory; one implementation per [`BackendKind`].
pub trait Backend {
    /// The kind this backend executes.
    fn kind(&self) -> BackendKind;
    /// Validate-adjacent setup: build the engine(s) for `spec` and return
    /// the steppable session.
    fn prepare(&self, spec: &RunSpec) -> Result<Box<dyn Session>>;
}

/// The backend registered for `kind`.
pub fn backend_for(kind: BackendKind) -> &'static dyn Backend {
    match kind {
        BackendKind::Reference => &ReferenceBackend,
        BackendKind::Ooc => &OocBackend,
        BackendKind::Pmm => &PmmBackend,
        BackendKind::Sim => &SimBackend,
    }
}

/// Translate a streamed [`StepEvent`] into the public [`StepReport`].
/// Anomalies ride along in `detail`: a batch that overflowed the
/// artifact's `edge_cap` reports its dropped-edge count as
/// `truncated_edges` instead of disappearing silently.
fn event_report(ev: StepEvent) -> StepReport {
    let mut fields: Vec<(&str, Json)> = Vec::new();
    if let Some((val, test)) = ev.eval {
        fields.push(("val", Json::from(val as f64)));
        fields.push(("test", Json::from(test as f64)));
    }
    if ev.truncated > 0 {
        fields.push(("truncated_edges", Json::from(ev.truncated)));
    }
    let detail = if fields.is_empty() { Json::Null } else { obj(fields) };
    StepReport {
        step: ev.step,
        loss: ev.loss,
        acc: ev.acc,
        wall_s: ev.wall_s,
        done: ev.done,
        detail,
    }
}

/// Receive the next event from a worker thread, surfacing the worker's
/// error (or panic) when the stream ends without a final event.
fn recv_event<R>(
    rx: &Receiver<StepEvent>,
    handle: &mut Option<JoinHandle<Result<R>>>,
    what: &str,
) -> Result<StepReport> {
    match rx.recv() {
        Ok(ev) => Ok(event_report(ev)),
        Err(_) => {
            let h = handle
                .take()
                .ok_or_else(|| anyhow!("{what} worker already joined"))?;
            match h.join() {
                Ok(Ok(_)) => bail!("{what} worker ended without a final step event"),
                Ok(Err(e)) => Err(e),
                Err(_) => bail!("{what} worker thread panicked"),
            }
        }
    }
}

fn join_worker<R>(handle: Option<JoinHandle<Result<R>>>, what: &str) -> Result<R> {
    handle
        .ok_or_else(|| anyhow!("{what} worker already joined"))?
        .join()
        .map_err(|_| anyhow!("{what} worker thread panicked"))?
}

// ---------------------------------------------------------------------------
// Reference backend (PJRT trainer)
// ---------------------------------------------------------------------------

/// `trainer::train` behind the session API.
struct ReferenceBackend;

struct ReferenceSession {
    rx: Receiver<StepEvent>,
    handle: Option<JoinHandle<Result<TrainReport>>>,
}

/// Build the legacy `TrainConfig` a spec maps onto (public-in-crate so the
/// bitwise-identity tests compare against exactly this mapping).
pub fn train_config(spec: &RunSpec) -> TrainConfig {
    let mut cfg = TrainConfig::quick(&spec.dataset, spec.sampler);
    cfg.dp = spec.grid.gd;
    cfg.lr = spec.lr;
    cfg.seed = spec.seed;
    cfg.prefetch = spec.prefetch;
    cfg.artifacts = spec.artifacts.clone();
    cfg.max_steps = spec.steps;
    cfg.max_epochs = spec.epochs;
    cfg.target_acc = spec.target_acc;
    cfg.eval_every_epochs = spec.eval_every_epochs.max(1);
    cfg.bf16_dp = spec.precision == crate::comm::Precision::Bf16;
    cfg.overlap = spec.overlap;
    cfg.verbose = false; // observers replace verbose printing
    cfg
}

impl Backend for ReferenceBackend {
    fn kind(&self) -> BackendKind {
        BackendKind::Reference
    }

    fn prepare(&self, spec: &RunSpec) -> Result<Box<dyn Session>> {
        let cfg = train_config(spec);
        let (tx, rx) = channel();
        // PJRT clients are per-thread; the whole legacy entry point moves
        // to a coordinator thread and streams its group-0 events back
        let handle = std::thread::spawn(move || trainer::train_with_progress(&cfg, Some(tx)));
        Ok(Box::new(ReferenceSession { rx, handle: Some(handle) }))
    }
}

impl Session for ReferenceSession {
    fn step(&mut self) -> Result<Option<StepReport>> {
        recv_event(&self.rx, &mut self.handle, "reference").map(Some)
    }

    fn finish(mut self: Box<Self>) -> Result<RunReport> {
        let r = join_worker(self.handle.take(), "reference")?;
        Ok(RunReport {
            backend: Some(BackendKind::Reference),
            steps: r.steps,
            final_loss: r.final_loss,
            loss_curve: r.loss_curve.clone(),
            trainer: Some(r),
            ..RunReport::default()
        })
    }
}

// ---------------------------------------------------------------------------
// Out-of-core backend
// ---------------------------------------------------------------------------

/// `trainer::train_from_store` behind the session API.
struct OocBackend;

struct OocSession {
    rx: Receiver<StepEvent>,
    handle: Option<JoinHandle<Result<OocTrainReport>>>,
}

/// Build the legacy `OocTrainConfig` a spec maps onto.
pub fn ooc_config(spec: &RunSpec) -> OocTrainConfig {
    let store = match &spec.source {
        DataSource::Ooc { store } => store.clone(),
        DataSource::Mem => unreachable!("validate() rejects a mem source on the ooc backend"),
    };
    let mut cfg = OocTrainConfig::quick(store);
    cfg.dataset = Some(spec.dataset.clone());
    cfg.cache_bytes = spec.cache_mb << 20;
    cfg.batch = spec.batch.unwrap_or(cfg.batch);
    cfg.d_h = spec.model.d_h;
    cfg.layers = spec.model.layers;
    cfg.steps = spec.steps;
    cfg.lr = spec.lr;
    cfg.seed = spec.seed;
    cfg.prefetch = spec.prefetch;
    cfg.verbose = false;
    cfg
}

impl Backend for OocBackend {
    fn kind(&self) -> BackendKind {
        BackendKind::Ooc
    }

    fn prepare(&self, spec: &RunSpec) -> Result<Box<dyn Session>> {
        let cfg = ooc_config(spec);
        let (tx, rx) = channel();
        let handle =
            std::thread::spawn(move || trainer::train_from_store_with_progress(&cfg, Some(tx)));
        Ok(Box::new(OocSession { rx, handle: Some(handle) }))
    }
}

impl Session for OocSession {
    fn step(&mut self) -> Result<Option<StepReport>> {
        recv_event(&self.rx, &mut self.handle, "ooc").map(Some)
    }

    fn finish(mut self: Box<Self>) -> Result<RunReport> {
        let r = join_worker(self.handle.take(), "ooc")?;
        Ok(RunReport {
            backend: Some(BackendKind::Ooc),
            steps: r.steps,
            final_loss: r.final_loss,
            loss_curve: r.loss_curve.clone(),
            ooc: Some(r),
            ..RunReport::default()
        })
    }
}

// ---------------------------------------------------------------------------
// PMM backend (rank-thread 4D engine)
// ---------------------------------------------------------------------------

/// The rank-thread 4D PMM engine behind the session API.
struct PmmBackend;

type PmmRankOut = (PmmTimers, (f32, f32), Option<(f32, f32)>);

struct PmmSession {
    rx: Receiver<StepEvent>,
    handles: Vec<JoinHandle<PmmRankOut>>,
    world: Arc<CommWorld>,
    ranks: usize,
    steps: u64,
    loss_curve: Vec<(u64, f32)>,
}

/// The reference-model dims a spec maps onto for the PMM engine.
pub fn pmm_dims(spec: &RunSpec) -> GcnDims {
    let ds = datasets::spec(&spec.dataset).expect("validate() checked the dataset");
    GcnDims {
        d_in: ds.planted.d_in,
        d_h: spec.model.d_h,
        d_out: ds.planted.classes,
        layers: spec.model.layers,
        dropout: spec.model.dropout,
        weight_decay: 0.0,
    }
}

impl Backend for PmmBackend {
    fn kind(&self) -> BackendKind {
        BackendKind::Pmm
    }

    fn prepare(&self, spec: &RunSpec) -> Result<Box<dyn Session>> {
        let grid = Grid4D::from(spec.grid);
        let data = Arc::new(
            datasets::load(&spec.dataset)
                .ok_or_else(|| anyhow!("unknown dataset {}", spec.dataset))?,
        );
        let ds = datasets::spec(&spec.dataset).unwrap();
        let dims = pmm_dims(spec);
        let batch = spec.batch.unwrap_or(ds.batch);
        let (steps, lr, seed) = (spec.steps, spec.lr, spec.seed);
        let (prec, overlap, final_eval) = (spec.precision, spec.overlap, spec.final_eval);
        let world = Arc::new(CommWorld::new(grid));
        let (tx, rx) = channel();
        let mut handles = Vec::with_capacity(grid.world_size());
        for r in 0..grid.world_size() {
            let w = world.clone();
            let d = data.clone();
            let tx = if r == 0 { Some(tx.clone()) } else { None };
            handles.push(std::thread::spawn(move || -> PmmRankOut {
                let ctx = PmmCtx::new(grid, r, &w, prec);
                let mut eng = PmmGcn::new(ctx, dims, batch, d, seed);
                eng.set_overlap(overlap);
                let mut last = (0.0f32, 0.0f32);
                for s in 0..steps {
                    let t0 = Instant::now();
                    let o = eng.train_step(s, lr);
                    last = (o.loss, o.acc);
                    if let Some(tx) = &tx {
                        let _ = tx.send(StepEvent {
                            step: s,
                            loss: o.loss,
                            acc: o.acc,
                            wall_s: t0.elapsed().as_secs_f64(),
                            eval: None,
                            truncated: 0,
                            done: s + 1 == steps,
                        });
                    }
                }
                let eval = final_eval.then(|| eng.eval_full_graph());
                (eng.timers, last, eval)
            }));
        }
        Ok(Box::new(PmmSession {
            rx,
            handles,
            world,
            ranks: grid.world_size(),
            steps,
            loss_curve: Vec::new(),
        }))
    }
}

impl Session for PmmSession {
    fn step(&mut self) -> Result<Option<StepReport>> {
        if self.steps == 0 {
            // evaluation-only session: no training steps to stream
            return Ok(None);
        }
        match self.rx.recv() {
            Ok(ev) => {
                self.loss_curve.push((ev.step, ev.loss));
                Ok(Some(event_report(ev)))
            }
            Err(_) => bail!("a pmm rank thread panicked before finishing its steps"),
        }
    }

    fn finish(self: Box<Self>) -> Result<RunReport> {
        let this = *self;
        let mut timers = PmmTimers::default();
        let mut last = None;
        let mut eval = None;
        for h in this.handles {
            let (t, l, e) = h.join().map_err(|_| anyhow!("pmm rank thread panicked"))?;
            timers.add(&t);
            // rank 0 joins first; keep ITS values so final_loss/final_acc
            // agree with the streamed loss_curve (DP groups draw distinct
            // batches, so other ranks report different losses)
            last.get_or_insert(l);
            eval = eval.or(e);
        }
        let last = last.unwrap_or((f32::NAN, f32::NAN));
        let n = this.ranks as f64;
        let timers_mean = PmmTimers {
            sampling: timers.sampling / n,
            spmm: timers.spmm / n,
            gemm: timers.gemm / n,
            elementwise: timers.elementwise / n,
            tp_comm: timers.tp_comm / n,
            dp_comm: timers.dp_comm / n,
            reshard: timers.reshard / n,
            other: timers.other / n,
        };
        let axes = [(Axis::X, "x"), (Axis::Y, "y"), (Axis::Z, "z"), (Axis::Dp, "dp")]
            .into_iter()
            .map(|(ax, name)| {
                let (ops, bytes) = this.world.stats(ax);
                let (comm_s, blocked_s) = this.world.timing(ax);
                AxisStats {
                    axis: name,
                    ops,
                    bytes,
                    comm_s,
                    blocked_s,
                    hidden_frac: this.world.hidden_fraction(ax),
                }
            })
            .collect();
        Ok(RunReport {
            backend: Some(BackendKind::Pmm),
            steps: this.loss_curve.len() as u64,
            final_loss: last.0,
            loss_curve: this.loss_curve,
            pmm: Some(PmmRunReport {
                final_acc: last.1,
                timers_mean,
                axes,
                tp_hidden_frac: this.world.tp_hidden_fraction(),
                eval,
            }),
            ..RunReport::default()
        })
    }
}

// ---------------------------------------------------------------------------
// Sim backend (analytical projections)
// ---------------------------------------------------------------------------

/// `sim::scalegnn_epoch_with` behind the session API: one step per
/// `gd_sweep` entry.
struct SimBackend;

struct SimSession {
    w: sim::Workload,
    machine: sim::Machine,
    opts: sim::OptFlags,
    hide_frac: f64,
    base: (usize, usize, usize),
    sweep: Vec<usize>,
    i: usize,
    points: Vec<SimPoint>,
}

impl Backend for SimBackend {
    fn kind(&self) -> BackendKind {
        BackendKind::Sim
    }

    fn prepare(&self, spec: &RunSpec) -> Result<Box<dyn Session>> {
        let s = spec.sim.as_ref().expect("validate() requires the sim section");
        let ds = datasets::spec(&spec.dataset)
            .ok_or_else(|| anyhow!("unknown dataset {}", spec.dataset))?;
        let machine = sim::by_name(&s.machine)
            .ok_or_else(|| anyhow!("unknown machine {}", s.machine))?;
        Ok(Box::new(SimSession {
            w: sim::Workload::from_spec(&ds, spec.model.d_h as f64, spec.model.layers as f64),
            machine,
            opts: sim::OptFlags {
                prefetch: spec.prefetch,
                bf16: spec.precision == crate::comm::Precision::Bf16,
                fusion: true,
                overlap: spec.overlap,
            },
            hide_frac: s.hide_frac.unwrap_or(sim::DEFAULT_OVERLAP_HIDE_FRAC),
            base: (spec.grid.gx, spec.grid.gy, spec.grid.gz),
            sweep: s.gd_sweep.clone(),
            i: 0,
            points: Vec::new(),
        }))
    }
}

impl Session for SimSession {
    fn step(&mut self) -> Result<Option<StepReport>> {
        if self.i >= self.sweep.len() {
            return Ok(None);
        }
        let (x, y, z) = self.base;
        let gd = self.sweep[self.i];
        let grid = Grid4D::new(gd, x, y, z);
        let b = sim::scalegnn_epoch_with(&self.w, &self.machine, grid, self.opts, self.hide_frac);
        let point = SimPoint { gd, devices: grid.world_size(), breakdown: b };
        let detail = obj(vec![
            ("gd", Json::from(gd)),
            ("devices", Json::from(point.devices)),
            ("breakdown", breakdown_json(&b)),
        ]);
        self.points.push(point);
        let report = StepReport {
            step: self.i as u64,
            loss: f32::NAN,
            acc: f32::NAN,
            wall_s: b.total(),
            done: self.i + 1 == self.sweep.len(),
            detail,
        };
        self.i += 1;
        Ok(Some(report))
    }

    fn finish(self: Box<Self>) -> Result<RunReport> {
        let this = *self;
        Ok(RunReport {
            backend: Some(BackendKind::Sim),
            steps: this.points.len() as u64,
            final_loss: f32::NAN,
            sim: Some(SimRunReport {
                machine: this.machine.name.to_string(),
                hide_frac: this.hide_frac,
                points: this.points,
            }),
            ..RunReport::default()
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn event_report_surfaces_truncated_edges() {
        let ev = StepEvent {
            step: 3,
            loss: 1.0,
            acc: f32::NAN,
            wall_s: 0.1,
            eval: None,
            truncated: 17,
            done: false,
        };
        let r = event_report(ev);
        assert_eq!(r.detail.get("truncated_edges").and_then(Json::as_f64), Some(17.0));
        // a clean step keeps a Null detail (JSONL stays compact)
        let clean = StepEvent { truncated: 0, ..ev };
        assert_eq!(event_report(clean).detail, Json::Null);
    }

    #[test]
    fn event_report_keeps_eval_detail() {
        let ev = StepEvent {
            step: 0,
            loss: 0.5,
            acc: 0.9,
            wall_s: 0.2,
            eval: Some((0.7, 0.6)),
            truncated: 2,
            done: true,
        };
        let r = event_report(ev);
        assert_eq!(r.detail.get("val").and_then(Json::as_f64), Some(0.7f32 as f64));
        assert_eq!(r.detail.get("test").and_then(Json::as_f64), Some(0.6f32 as f64));
        assert_eq!(r.detail.get("truncated_edges").and_then(Json::as_f64), Some(2.0));
    }
}
