//! Step and run reports shared by every backend.

use crate::pmm::PmmTimers;
use crate::sim::EpochBreakdown;
use crate::trainer::{OocTrainReport, TrainReport};
use crate::util::json::{arr_f64, obj, Json};

use super::spec::BackendKind;

/// One streamed step result (one projected grid point on the sim
/// backend).
#[derive(Clone, Debug)]
pub struct StepReport {
    /// 0-based step index.
    pub step: u64,
    /// Training loss (NaN where not applicable, e.g. sim).
    pub loss: f32,
    /// Sampled train accuracy (NaN where the backend does not measure it).
    pub acc: f32,
    /// Measured wall-clock of this step — projected epoch seconds on the
    /// sim backend.
    pub wall_s: f64,
    /// Whether this was the last step of the run.
    pub done: bool,
    /// Backend-specific extras (reference: `val`/`test` at evals; sim:
    /// the per-component breakdown).
    pub detail: Json,
}

impl StepReport {
    /// JSON encoding (JSONL streaming / `--stats-json`).
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("step", Json::from(self.step as usize)),
            ("wall_s", Json::from(self.wall_s)),
            ("done", Json::Bool(self.done)),
        ];
        if self.loss.is_finite() {
            fields.push(("loss", Json::from(self.loss as f64)));
        }
        if self.acc.is_finite() {
            fields.push(("acc", Json::from(self.acc as f64)));
        }
        if self.detail != Json::Null {
            fields.push(("detail", self.detail.clone()));
        }
        obj(fields)
    }
}

/// Per-axis communication statistics of a PMM run (§V-D measurements).
#[derive(Clone, Debug, Default)]
pub struct AxisStats {
    /// Axis name: `x`, `y`, `z` or `dp`.
    pub axis: &'static str,
    /// Collective operations issued on the axis.
    pub ops: u64,
    /// Payload bytes moved on the axis.
    pub bytes: u64,
    /// Issue→completion seconds over nonblocking-issued ops.
    pub comm_s: f64,
    /// Seconds a rank actually blocked waiting.
    pub blocked_s: f64,
    /// Measured hidden-communication fraction.
    pub hidden_frac: f64,
}

/// Aggregate result of a PMM-backend run.
#[derive(Clone, Debug, Default)]
pub struct PmmRunReport {
    /// Sampled train accuracy of the final step.
    pub final_acc: f32,
    /// Per-rank mean phase timers.
    pub timers_mean: PmmTimers,
    /// Per-axis comm statistics (order: x, y, z, dp).
    pub axes: Vec<AxisStats>,
    /// Aggregate TP hidden fraction (feeds `sim::scalegnn_epoch_with`).
    pub tp_hidden_frac: f64,
    /// Final distributed full-graph (val, test) accuracy, when requested.
    pub eval: Option<(f32, f32)>,
}

/// One projected point of a sim-backend run.
#[derive(Clone, Debug)]
pub struct SimPoint {
    /// Data-parallel groups at this point.
    pub gd: usize,
    /// Total devices (`gd * gx * gy * gz`).
    pub devices: usize,
    /// Projected per-epoch component times.
    pub breakdown: EpochBreakdown,
}

/// Aggregate result of a sim-backend run.
#[derive(Clone, Debug, Default)]
pub struct SimRunReport {
    /// Machine profile name.
    pub machine: String,
    /// §V-D hide fraction the projection used.
    pub hide_frac: f64,
    /// One point per sweep entry.
    pub points: Vec<SimPoint>,
}

/// One recovered (or fatal) rank failure, with the structured origin the
/// comm layer carried through the poison cascade.
#[derive(Clone, Debug)]
pub struct FailureReport {
    /// Rank the failure originated on.
    pub rank: usize,
    /// Collective sequence number on the originating group (0 for
    /// injected faults and non-comm panics).
    pub seq: u64,
    /// Operation that died (`all_reduce`, `all_gather`, `injected-fault`,
    /// `panic`, ...).
    pub op: String,
    /// Grid axis of the originating group (empty for non-comm panics).
    pub axis: String,
    /// Human-readable cause.
    pub message: String,
    /// Step the supervisor replayed from, when recovery succeeded.
    pub resumed_from_step: Option<u64>,
}

/// Final aggregate of a session run.  The typed per-backend sections are
/// `Some` exactly for the backend that ran.
#[derive(Clone, Debug, Default)]
pub struct RunReport {
    /// Backend that executed (`None` only for `Default`).
    pub backend: Option<BackendKind>,
    /// Steps executed.
    pub steps: u64,
    /// Total wall-clock of the run.
    pub wall_s: f64,
    /// Loss of the final step (NaN on sim).
    pub final_loss: f32,
    /// (step, loss) curve — per-epoch on the reference backend, per-step
    /// on OOC/PMM, empty on sim.
    pub loss_curve: Vec<(u64, f32)>,
    /// Rank failures the run hit, recovered or fatal (PMM backend).
    pub failures: Vec<FailureReport>,
    /// World re-formations the supervisor performed.
    pub restarts: u64,
    /// Reference-backend report.
    pub trainer: Option<TrainReport>,
    /// OOC-backend report.
    pub ooc: Option<OocTrainReport>,
    /// PMM-backend report.
    pub pmm: Option<PmmRunReport>,
    /// Sim-backend report.
    pub sim: Option<SimRunReport>,
}

/// JSON encoding of a breakdown (shared by sim step details and reports).
pub fn breakdown_json(b: &EpochBreakdown) -> Json {
    obj(vec![
        ("total_s", Json::from(b.total())),
        ("sampling_s", Json::from(b.sampling)),
        ("spmm_s", Json::from(b.spmm)),
        ("gemm_s", Json::from(b.gemm)),
        ("elementwise_s", Json::from(b.elementwise)),
        ("tp_comm_s", Json::from(b.tp_comm)),
        ("dp_comm_s", Json::from(b.dp_comm)),
        ("other_s", Json::from(b.other)),
    ])
}

impl RunReport {
    /// JSON encoding (the `finish` line of [`super::JsonlObserver`], the
    /// `run --stats-json` payload).
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            (
                "backend",
                self.backend.map(|b| Json::from(b.tag())).unwrap_or(Json::Null),
            ),
            ("steps", Json::from(self.steps as usize)),
            ("wall_s", Json::from(self.wall_s)),
        ];
        if self.final_loss.is_finite() {
            fields.push(("final_loss", Json::from(self.final_loss as f64)));
        }
        if !self.loss_curve.is_empty() {
            fields.push((
                "loss_curve",
                Json::Arr(
                    self.loss_curve
                        .iter()
                        .map(|&(s, l)| {
                            Json::Arr(vec![Json::from(s as usize), Json::from(l as f64)])
                        })
                        .collect(),
                ),
            ));
        }
        if self.restarts > 0 {
            fields.push(("restarts", Json::from(self.restarts as usize)));
        }
        if !self.failures.is_empty() {
            fields.push((
                "failures",
                Json::Arr(
                    self.failures
                        .iter()
                        .map(|f| {
                            let mut ff = vec![
                                ("rank", Json::from(f.rank)),
                                ("seq", Json::from(f.seq as usize)),
                                ("op", Json::from(f.op.as_str())),
                                ("axis", Json::from(f.axis.as_str())),
                                ("message", Json::from(f.message.as_str())),
                            ];
                            if let Some(s) = f.resumed_from_step {
                                ff.push(("resumed_from_step", Json::from(s as usize)));
                            }
                            obj(ff)
                        })
                        .collect(),
                ),
            ));
        }
        if let Some(t) = &self.trainer {
            fields.push((
                "trainer",
                obj(vec![
                    ("epochs", Json::from(t.epochs)),
                    ("train_time_s", Json::from(t.train_time_s)),
                    ("eval_time_s", Json::from(t.eval_time_s)),
                    ("best_val_acc", Json::from(t.best_val_acc as f64)),
                    ("best_test_acc", Json::from(t.best_test_acc as f64)),
                    (
                        "time_to_target_s",
                        t.time_to_target_s.map(Json::from).unwrap_or(Json::Null),
                    ),
                    (
                        "per_step_s",
                        arr_f64(&[
                            t.breakdown.sample_wait_s,
                            t.breakdown.pack_s,
                            t.breakdown.exec_s,
                            t.breakdown.dp_comm_s,
                        ]),
                    ),
                ]),
            ));
        }
        if let Some(o) = &self.ooc {
            fields.push((
                "ooc",
                obj(vec![
                    ("final_train_acc", Json::from(o.final_train_acc as f64)),
                    ("sample_wait_s", Json::from(o.sample_wait_s)),
                    ("store_bytes", Json::from(o.store_bytes as usize)),
                    ("cache_resident_bytes", Json::from(o.cache_resident_bytes)),
                    ("cache_budget_bytes", Json::from(o.cache_budget_bytes)),
                    ("cache_hits", Json::from(o.cache_hits as usize)),
                    ("cache_misses", Json::from(o.cache_misses as usize)),
                ]),
            ));
        }
        if let Some(p) = &self.pmm {
            let axes = p
                .axes
                .iter()
                .map(|a| {
                    obj(vec![
                        ("axis", Json::from(a.axis)),
                        ("ops", Json::from(a.ops as usize)),
                        ("bytes", Json::from(a.bytes as usize)),
                        ("comm_s", Json::from(a.comm_s)),
                        ("blocked_s", Json::from(a.blocked_s)),
                        ("hidden_frac", Json::from(a.hidden_frac)),
                    ])
                })
                .collect();
            let t = &p.timers_mean;
            let mut pf = vec![
                ("final_acc", Json::from(p.final_acc as f64)),
                ("tp_hidden_frac", Json::from(p.tp_hidden_frac)),
                ("axes", Json::Arr(axes)),
                (
                    "per_rank_mean_s",
                    obj(vec![
                        ("sampling", Json::from(t.sampling)),
                        ("spmm", Json::from(t.spmm)),
                        ("gemm", Json::from(t.gemm)),
                        ("elementwise", Json::from(t.elementwise)),
                        ("tp_comm", Json::from(t.tp_comm)),
                        ("dp_comm", Json::from(t.dp_comm)),
                        ("reshard", Json::from(t.reshard)),
                    ]),
                ),
            ];
            if let Some((v, te)) = p.eval {
                pf.push(("eval_val", Json::from(v as f64)));
                pf.push(("eval_test", Json::from(te as f64)));
            }
            fields.push(("pmm", obj(pf)));
        }
        if let Some(s) = &self.sim {
            fields.push((
                "sim",
                obj(vec![
                    ("machine", Json::from(s.machine.as_str())),
                    ("hide_frac", Json::from(s.hide_frac)),
                    (
                        "points",
                        Json::Arr(
                            s.points
                                .iter()
                                .map(|p| {
                                    obj(vec![
                                        ("gd", Json::from(p.gd)),
                                        ("devices", Json::from(p.devices)),
                                        ("breakdown", breakdown_json(&p.breakdown)),
                                    ])
                                })
                                .collect(),
                        ),
                    ),
                ]),
            ));
        }
        obj(fields)
    }
}
